"""Pallas log-step pooling kernels vs the lax.reduce_window oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pooling, ref


def rand(shape, seed):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape, jnp.float32, -1.0, 1.0)


@pytest.mark.parametrize("k", [1, 2, 3, 4, 5, 7, 8, 13])
def test_max_pool_all_window_sizes_stride1(k):
    x = rand((1, 2, 16, 20), k)
    got = pooling.max_pool2d(x, k, stride=1)
    want = ref.max_pool2d(x, k, stride=1)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@pytest.mark.parametrize("k", [2, 3, 4])
def test_max_pool_nonoverlapping(k):
    x = rand((2, 3, 12, 12), 50 + k)
    got = pooling.max_pool2d(x, k)
    want = ref.max_pool2d(x, k)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@pytest.mark.parametrize("k", [2, 3, 5])
def test_avg_pool_matches_ref(k):
    x = rand((1, 2, 14, 15), 60 + k)
    got = pooling.avg_pool2d(x, k, stride=1)
    want = ref.avg_pool2d(x, k, stride=1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_max_pool_padded():
    x = rand((1, 1, 8, 8), 3)
    got = pooling.max_pool2d(x, 3, stride=1, pad=(1, 1))
    want = ref.max_pool2d(x, 3, stride=1, pad=(1, 1))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_rectangular_window():
    x = rand((1, 1, 10, 24), 4)
    got = pooling.max_pool2d(x, (2, 5), stride=(1, 2))
    want = ref.max_pool2d(x, (2, 5), stride=(1, 2))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@pytest.mark.parametrize("k", [1, 2, 3, 6, 9, 15, 16])
def test_sliding_sum_log_step(k):
    x = rand((64,), 70 + k)
    got = pooling.sliding_sum(x, k)
    want = ref.sliding_sum(x, k)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(4, 14),
    w=st.integers(4, 14),
    k=st.integers(1, 4),
    s=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_max_pool_hypothesis(h, w, k, s, seed):
    k = min(k, h, w)
    x = rand((1, 1, h, w), seed)
    got = pooling.max_pool2d(x, k, stride=s)
    want = ref.max_pool2d(x, k, stride=s)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
