"""Pallas Sliding Window kernels vs the pure-jnp oracle — the core L1
correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sliding


def rand(shape, seed):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape, jnp.float32, -1.0, 1.0)


@pytest.mark.parametrize("k", [1, 2, 3, 5, 7, 11])
def test_conv2d_sliding_matches_ref_filter_sizes(k):
    x = rand((1, 2, 16, 18), k)
    w = rand((3, 2, k, k), 100 + k)
    got = sliding.conv2d_sliding(x, w)
    want = ref.conv2d(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("pad", [(0, 0), (1, 1), (2, 3)])
def test_conv2d_sliding_padding(pad):
    x = rand((2, 3, 10, 12), 7)
    w = rand((4, 3, 3, 3), 8)
    got = sliding.conv2d_sliding(x, w, pad=pad)
    want = ref.conv2d(x, w, pad=pad)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("stride", [(1, 1), (2, 2), (1, 3)])
def test_conv2d_sliding_stride(stride):
    x = rand((1, 2, 13, 14), 9)
    w = rand((2, 2, 3, 3), 10)
    got = sliding.conv2d_sliding(x, w, stride=stride, pad=(1, 1))
    want = ref.conv2d(x, w, stride=stride, pad=(1, 1))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_rectangular_filter_via_khkw():
    x = rand((1, 1, 9, 30), 11)
    w = rand((1, 1, 2, 7), 12)
    got = sliding.conv2d_sliding(x, w)
    want = ref.conv2d(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("k", [1, 2, 5, 16, 17, 31])
def test_conv1d_sliding_matches_ref(k):
    x = rand((2, 64), k)
    w = rand((3, 2, k), 200 + k)
    got = sliding.conv1d_sliding(x, w)
    want = ref.conv1d(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv1d_sliding_padded():
    x = rand((1, 40), 1)
    w = rand((2, 1, 5), 2)
    got = sliding.conv1d_sliding(x, w, pad=2)
    want = ref.conv1d(x, w, pad=2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# Hypothesis sweep: the mandate's shape/dtype fuzzing for the L1 kernel.
@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 2),
    ci=st.integers(1, 3),
    co=st.integers(1, 3),
    h=st.integers(4, 12),
    w=st.integers(4, 12),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_sliding_hypothesis(n, ci, co, h, w, k, seed):
    kh = min(k, h)
    kw = min(k, w)
    x = rand((n, ci, h, w), seed)
    wt = rand((co, ci, kh, kw), seed + 1)
    got = sliding.conv2d_sliding(x, wt)
    want = ref.conv2d(x, wt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
