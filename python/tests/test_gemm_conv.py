"""Pallas im2col+GEMM baseline kernel vs the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm_conv, ref, sliding


def rand(shape, seed):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape, jnp.float32, -1.0, 1.0)


@pytest.mark.parametrize("k", [1, 3, 5, 7])
def test_gemm_conv_matches_ref(k):
    x = rand((1, 3, 12, 14), k)
    w = rand((4, 3, k, k), 300 + k)
    got = gemm_conv.conv2d_gemm(x, w)
    want = ref.conv2d(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gemm_conv_padding_and_stride():
    x = rand((2, 2, 11, 13), 5)
    w = rand((3, 2, 3, 3), 6)
    got = gemm_conv.conv2d_gemm(x, w, stride=(2, 2), pad=(1, 1))
    want = ref.conv2d(x, w, stride=(2, 2), pad=(1, 1))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gemm_and_sliding_agree():
    """The paper's two contenders must produce identical numerics."""
    x = rand((1, 3, 16, 16), 7)
    w = rand((8, 3, 5, 5), 8)
    a = gemm_conv.conv2d_gemm(x, w, pad=(2, 2))
    b = sliding.conv2d_sliding(x, w, pad=(2, 2))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    ci=st.integers(1, 3),
    co=st.integers(1, 4),
    hw=st.integers(5, 12),
    k=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_conv_hypothesis(ci, co, hw, k, seed):
    x = rand((1, ci, hw, hw), seed)
    w = rand((co, ci, k, k), seed + 1)
    got = gemm_conv.conv2d_gemm(x, w)
    want = ref.conv2d(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
