"""Layer-2 model: shapes, algo agreement, and AOT lowering round-trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def params():
    return model.init_params(seed=42)


def rand(shape, seed):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape, jnp.float32, -1.0, 1.0)


def test_model_output_shape(params):
    x = rand((2, 1, 28, 28), 1)
    y = model.simple_cnn(params, x, algo="ref")
    assert y.shape == (2, 10)


@pytest.mark.parametrize("algo", ["sliding", "gemm"])
def test_model_algos_match_ref(params, algo):
    x = rand((1, 1, 28, 28), 2)
    want = model.simple_cnn(params, x, algo="ref")
    got = model.simple_cnn(params, x, algo=algo)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_softmax_normalises(params):
    x = rand((3, 1, 28, 28), 3)
    p = model.softmax(model.simple_cnn(params, x, algo="ref"))
    np.testing.assert_allclose(np.sum(np.asarray(p), axis=-1), 1.0, rtol=1e-5)
    assert np.all(np.asarray(p) >= 0)


def test_params_deterministic():
    a = model.init_params(seed=7)
    b = model.init_params(seed=7)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_conv2d_rejects_unknown_algo(params):
    with pytest.raises(ValueError):
        model.conv2d(rand((1, 1, 8, 8), 4), rand((1, 1, 3, 3), 5), algo="winograd")


def test_aot_lower_conv2d_produces_hlo():
    spec, hlo = aot.lower_conv2d("sliding", c=1, hw=8, k=3, co=2)
    assert spec["name"] == "conv2d_sliding_c1_8x8_k3"
    assert spec["inputs"] == [[1, 1, 8, 8], [2, 1, 3, 3]]
    assert spec["output"] == [1, 2, 8, 8]
    assert "HloModule" in hlo
    # The artifact must be pure HLO text: no Mosaic custom-calls (those
    # would be un-runnable on the CPU PJRT plugin).
    assert "mosaic" not in hlo.lower()


def test_aot_lower_model_produces_hlo():
    spec, hlo = aot.lower_model("gemm", batch=2)
    assert spec["inputs"] == [[2, 1, 28, 28]]
    assert spec["output"] == [2, 10]
    assert "HloModule" in hlo
