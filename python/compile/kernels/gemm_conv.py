"""im2col + GEMM convolution as a Pallas kernel (the baseline, Layer 1).

This is the computation the paper argues *against*: every input window is
materialised into a column matrix (k^2 memory bloat) and the convolution
becomes one big matrix multiply. On a real TPU the ``jnp.dot`` maps to the
MXU systolic array — preserving the paper's CPU-vs-matrix-engine contrast
at the kernel level (the sliding kernel uses only the VPU lane network).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _im2col(x, kh, kw, oh, ow, stride):
    """x: [ci, hp, wp] -> col: [ci*kh*kw, oh*ow] (the memory bloat)."""
    sh, sw = stride
    cols = []
    for ky in range(kh):
        for kx in range(kw):
            win = x[:, ky : ky + (oh - 1) * sh + 1 : sh, kx : kx + (ow - 1) * sw + 1 : sw]
            cols.append(win.reshape(x.shape[0], oh * ow))
    # [kh*kw, ci, oh*ow] -> [ci, kh*kw, oh*ow] -> [ci*kh*kw, oh*ow]
    col = jnp.stack(cols, axis=0).transpose(1, 0, 2)
    return col.reshape(x.shape[0] * kh * kw, oh * ow)


def _gemm_conv_kernel(x_ref, w_ref, o_ref, *, kh, kw, oh, ow, stride):
    """One image: materialise the column matrix, run one GEMM (MXU)."""
    x = x_ref[0]                        # [ci, hp, wp]
    w = w_ref[...]                      # [co, ci, kh, kw]
    co = w.shape[0]
    col = _im2col(x, kh, kw, oh, ow, stride)          # [ci*kh*kw, oh*ow]
    wmat = w.reshape(co, -1)                          # [co, ci*kh*kw]
    y = jnp.dot(wmat, col, preferred_element_type=jnp.float32)
    o_ref[0] = y.reshape(co, oh, ow)


@functools.partial(jax.jit, static_argnames=("stride", "pad"))
def conv2d_gemm(x, w, *, stride=(1, 1), pad=(0, 0)):
    """im2col + GEMM 2-D convolution.

    x: [n, ci, h, w] f32, w: [co, ci, kh, kw] f32 -> [n, co, oh, ow].
    """
    n, ci, h, wdt = x.shape
    co, ci_w, kh, kw = w.shape
    assert ci == ci_w, f"c_in mismatch: {ci} vs {ci_w}"
    ph, pw = pad
    hp, wp = h + 2 * ph, wdt + 2 * pw
    sh, sw = stride
    oh, ow = (hp - kh) // sh + 1, (wp - kw) // sw + 1

    xp = jnp.pad(x, ((0, 0), (0, 0), (ph, ph), (pw, pw)))
    kernel = functools.partial(
        _gemm_conv_kernel, kh=kh, kw=kw, oh=oh, ow=ow, stride=stride
    )
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, ci, hp, wp), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((co, ci_w, kh, kw), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, co, oh, ow), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, co, oh, ow), jnp.float32),
        interpret=True,
    )(xp, w)
