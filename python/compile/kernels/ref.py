"""Pure-jnp reference implementations (correctness oracles).

Every Pallas kernel in this package is tested against these functions by
``python/tests``; the Rust kernels are in turn cross-checked against the
AOT-lowered versions of these graphs, closing the three-layer loop.

Conventions match the Rust side: NCHW images, ``[c_out, c_in, kh, kw]``
weights, cross-correlation (DNN convention), zero padding, unit dilation.
"""

import jax.numpy as jnp
from jax import lax


def conv2d(x, w, *, stride=(1, 1), pad=(0, 0)):
    """2-D convolution. x: [n, c, h, w], w: [co, ci, kh, kw] -> [n, co, oh, ow]."""
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=stride,
        padding=((pad[0], pad[0]), (pad[1], pad[1])),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv1d(x, w, *, stride=1, pad=0):
    """1-D convolution. x: [ci, l], w: [co, ci, k] -> [co, lo]."""
    y = conv2d(x[None, :, None, :], w[:, :, None, :], stride=(1, stride), pad=(0, pad))
    return y[0, :, 0, :]


def max_pool2d(x, k, *, stride=None, pad=(0, 0)):
    """Max pooling with -inf padding. x: [n, c, h, w]."""
    stride = stride or (k, k)
    if isinstance(k, int):
        k = (k, k)
    if isinstance(stride, int):
        stride = (stride, stride)
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 1, k[0], k[1]),
        window_strides=(1, 1, stride[0], stride[1]),
        padding=((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])),
    )


def avg_pool2d(x, k, *, stride=None, pad=(0, 0)):
    """Average pooling, count_include_pad=True (matches the Rust kernels)."""
    stride = stride or (k, k)
    if isinstance(k, int):
        k = (k, k)
    if isinstance(stride, int):
        stride = (stride, stride)
    s = lax.reduce_window(
        x,
        0.0,
        lax.add,
        window_dimensions=(1, 1, k[0], k[1]),
        window_strides=(1, 1, stride[0], stride[1]),
        padding=((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])),
    )
    return s / (k[0] * k[1])


def sliding_sum(x, k):
    """1-D sliding window sum: out[i] = sum(x[i:i+k]). x: [l] -> [l-k+1]."""
    return jnp.convolve(x, jnp.ones(k, x.dtype), mode="valid")
