"""Layer-1 Pallas kernels (build-time only; never imported at runtime).

Modules:
    ref       -- pure-jnp oracles every kernel is tested against.
    sliding   -- Sliding Window convolution kernels (the paper's
                 contribution) as Pallas kernels, interpret=True.
    pooling   -- sliding max/avg pooling kernels.
    gemm_conv -- im2col + dot kernel (the GEMM baseline; maps to the MXU
                 on a real TPU).
"""
