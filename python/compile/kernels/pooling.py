"""Pooling as log-step sliding window combines (Pallas, Layer 1).

The horizontal pass is the paper's doubling algorithm — O(log k) shifted
combines instead of k-1 — expressed as statically shifted slices of the
VMEM block (the TPU form of the register slide; see sliding.py). The
vertical pass is a plain elementwise combine across kh rows.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sliding_combine_1d(x, k, op):
    """Log-step sliding combine along the last axis.

    x: [..., L] -> [..., L - k + 1] where out[..., i] = op over x[..., i:i+k].
    Mirrors rust/src/kernels/pool.rs: process the bits of k from the
    second-most-significant down — double the window, then extend by one
    when the bit is set.
    """
    assert k >= 1
    s = x
    width = 1
    bits = k.bit_length()
    for bit in range(bits - 2, -1, -1):
        # Double: S_2w[i] = op(S_w[i], S_w[i+w]). Shifted slices keep every
        # lane needed by later steps valid.
        s = op(s[..., : s.shape[-1] - width], s[..., width:])
        width *= 2
        if (k >> bit) & 1:
            s = op(s[..., : x.shape[-1] - width], x[..., width : width + s.shape[-1]][..., : x.shape[-1] - width])
            width += 1
    assert width == k
    return s[..., : x.shape[-1] - k + 1]


def _pool_kernel(x_ref, o_ref, *, k, stride, op):
    """One (n, c) plane: horizontal log-step combine, vertical combine."""
    x = x_ref[0, 0]  # [hp, wp]
    kh, kw = k
    h1 = _sliding_combine_1d(x, kw, op)          # [hp, ow1]
    acc = h1[: h1.shape[0] - kh + 1]
    for ky in range(1, kh):
        acc = op(acc, h1[ky : ky + acc.shape[0]])
    sh, sw = stride
    o_ref[0, 0] = acc[::sh, ::sw]


def _pool2d(x, k, stride, pad, op, fill):
    n, c, h, wdt = x.shape
    if isinstance(k, int):
        k = (k, k)
    stride = stride or k
    if isinstance(stride, int):
        stride = (stride, stride)
    ph, pw = pad
    hp, wp = h + 2 * ph, wdt + 2 * pw
    oh1, ow1 = hp - k[0] + 1, wp - k[1] + 1
    oh = (oh1 + stride[0] - 1) // stride[0]
    ow = (ow1 + stride[1] - 1) // stride[1]
    xp = jnp.pad(x, ((0, 0), (0, 0), (ph, ph), (pw, pw)), constant_values=fill)
    kernel = functools.partial(_pool_kernel, k=k, stride=stride, op=op)
    return pl.pallas_call(
        kernel,
        grid=(n, c),
        in_specs=[pl.BlockSpec((1, 1, hp, wp), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, oh, ow), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, oh, ow), jnp.float32),
        interpret=True,
    )(xp)


@functools.partial(jax.jit, static_argnames=("k", "stride", "pad"))
def max_pool2d(x, k, *, stride=None, pad=(0, 0)):
    """Sliding max pooling (log-step). x: [n, c, h, w]."""
    return _pool2d(x, k, stride, pad, jnp.maximum, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("k", "stride", "pad"))
def avg_pool2d(x, k, *, stride=None, pad=(0, 0)):
    """Sliding average pooling, count_include_pad=True."""
    kk = (k, k) if isinstance(k, int) else k
    s = _pool2d(x, k, stride, pad, jnp.add, 0.0)
    return s / (kk[0] * kk[1])


@functools.partial(jax.jit, static_argnames=("k",))
def sliding_sum(x, k):
    """1-D log-step sliding window sum. x: [l] -> [l - k + 1]."""

    def kernel(x_ref, o_ref):
        o_ref[...] = _sliding_combine_1d(x_ref[...], k, jnp.add)

    (l,) = x.shape
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((l - k + 1,), jnp.float32),
        interpret=True,
    )(x)
