"""Sliding Window convolution as Pallas kernels (Layer 1).

HARDWARE ADAPTATION (DESIGN.md section "Hardware-Adaptation"): the paper's
CPU kernels slide an AVX-512 register across the row with ``valignd``. On
TPU the analogue is not a register shuffle but a *statically shifted slice
of a VMEM-resident block*: the lane network performs the shift for free,
and each filter tap becomes one shifted slice + FMA into a VMEM
accumulator. The HBM<->VMEM schedule expressed by the BlockSpec plays the
role the paper's cache blocking plays on the CPU; crucially there is no
im2col materialisation, so HBM traffic stays O(input), not O(k^2 * input).

The tap loops are unrolled at trace time (filter sizes are static), which
is exactly the "custom kernel generated per filter size" the paper
advocates ("generating custom kernels at run time might improve the
performance for every filter size").

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO, which both the pytest
suite and the Rust runtime execute. Real-TPU performance is *estimated*
structurally in DESIGN.md (VMEM footprint / MXU-vs-VPU balance).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv2d_plane_kernel(x_ref, w_ref, o_ref, *, kh, kw, oh1, ow1, stride):
    """One (image, out-channel) plane: accumulate kh*kw shifted-slice FMAs.

    x_ref: [1, ci, hp, wp] padded input block (VMEM)
    w_ref: [1, ci, kh, kw] this output channel's filter (VMEM)
    o_ref: [1, 1, oh, ow]  output block (VMEM)
    """
    x = x_ref[0]          # [ci, hp, wp]
    w = w_ref[0]          # [ci, kh, kw]
    ci = x.shape[0]
    acc = jnp.zeros((oh1, ow1), dtype=jnp.float32)
    # Vector Slide, TPU form: every tap is a statically shifted slice of
    # the VMEM block; the adds vectorise across the (8,128) lane tile.
    for c in range(ci):
        for ky in range(kh):
            for kx in range(kw):
                window = x[c, ky : ky + oh1, kx : kx + ow1]
                acc = acc + w[c, ky, kx] * window
    sh, sw = stride
    o_ref[0, 0] = acc[::sh, ::sw]


@functools.partial(jax.jit, static_argnames=("stride", "pad"))
def conv2d_sliding(x, w, *, stride=(1, 1), pad=(0, 0)):
    """Sliding Window 2-D convolution.

    x: [n, ci, h, wdt] f32, w: [co, ci, kh, kw] f32 -> [n, co, oh, ow].
    Grid is (n, co); each program produces one output plane from the
    padded input plane resident in VMEM.
    """
    n, ci, h, wdt = x.shape
    co, ci_w, kh, kw = w.shape
    assert ci == ci_w, f"c_in mismatch: {ci} vs {ci_w}"
    ph, pw = pad
    hp, wp = h + 2 * ph, wdt + 2 * pw
    oh1, ow1 = hp - kh + 1, wp - kw + 1
    sh, sw = stride
    oh, ow = (oh1 + sh - 1) // sh, (ow1 + sw - 1) // sw

    xp = jnp.pad(x, ((0, 0), (0, 0), (ph, ph), (pw, pw)))
    kernel = functools.partial(
        _conv2d_plane_kernel, kh=kh, kw=kw, oh1=oh1, ow1=ow1, stride=stride
    )
    return pl.pallas_call(
        kernel,
        grid=(n, co),
        in_specs=[
            pl.BlockSpec((1, ci, hp, wp), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, ci_w, kh, kw), lambda i, j: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, oh, ow), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, co, oh, ow), jnp.float32),
        interpret=True,
    )(xp, w)


def _conv1d_kernel(x_ref, w_ref, o_ref, *, k, lo):
    """One output channel of a 1-D convolution via shifted slices."""
    x = x_ref[...]        # [ci, lp]
    w = w_ref[0]          # [ci, k]
    ci = x.shape[0]
    acc = jnp.zeros((lo,), dtype=jnp.float32)
    for c in range(ci):
        for j in range(k):
            acc = acc + w[c, j] * x[c, j : j + lo]
    o_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("pad",))
def conv1d_sliding(x, w, *, pad=0):
    """Sliding Window 1-D convolution. x: [ci, l], w: [co, ci, k] -> [co, lo]."""
    ci, l = x.shape
    co, ci_w, k = w.shape
    assert ci == ci_w
    lp = l + 2 * pad
    lo = lp - k + 1
    xp = jnp.pad(x, ((0, 0), (pad, pad)))
    kernel = functools.partial(_conv1d_kernel, k=k, lo=lo)
    return pl.pallas_call(
        kernel,
        grid=(co,),
        in_specs=[
            pl.BlockSpec((ci, lp), lambda j: (0, 0)),
            pl.BlockSpec((1, ci_w, k), lambda j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, lo), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((co, lo), jnp.float32),
        interpret=True,
    )(xp, w)
