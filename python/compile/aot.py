"""AOT lowering: JAX/Pallas (L1+L2) -> HLO text artifacts for the Rust runtime.

Usage: ``python -m compile.aot --out-dir ../artifacts``  (see Makefile)

HLO *text* is the interchange format, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. Lowering uses
``return_tuple=True`` and the Rust side unwraps with ``to_tuple1()``.
(See /opt/xla-example/README.md.)

Artifacts produced (all f32, all deterministic):
  conv2d_{algo}_c{c}_{hw}x{hw}_k{k}  -- standalone conv with "same" padding
  model_simple_cnn_{algo}_b{b}       -- LeNet CNN fwd (weights baked in)
  simple_cnn_weights.bin             -- the same weights as raw little-
                                        endian f32 (conv1 | conv2 | fc, row
                                        major) so the Rust-native backends
                                        can serve the identical model
Each is recorded in ``manifest.json`` for rust/src/runtime/manifest.rs.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is ESSENTIAL: the default elides big
    # literals as "{...}", which the HLO text parser silently reparses as
    # zeros — a model artifact with all-zero weights (uniform softmax).
    return comp.as_hlo_text(print_large_constants=True)


def lower_conv2d(algo, c, hw, k, co=8):
    """Lower one standalone conv2d artifact ("same" padding, odd k)."""
    assert k % 2 == 1, "conv2d artifacts use same padding (odd k)"
    pad = (k // 2, k // 2)

    def fn(x, w):
        return (model_mod.conv2d(x, w, pad=pad, algo=algo),)

    x_spec = jax.ShapeDtypeStruct((1, c, hw, hw), jnp.float32)
    w_spec = jax.ShapeDtypeStruct((co, c, k, k), jnp.float32)
    lowered = jax.jit(fn).lower(x_spec, w_spec)
    return {
        "name": f"conv2d_{algo}_c{c}_{hw}x{hw}_k{k}",
        "kind": "conv2d",
        "algo": algo,
        "inputs": [list(x_spec.shape), list(w_spec.shape)],
        "output": [1, co, hw, hw],
    }, to_hlo_text(lowered)


def lower_model(algo, batch, classes=10, seed=42):
    """Lower the simple CNN forward (+softmax); weights baked as constants."""
    params = model_mod.init_params(seed=seed, classes=classes)

    def fn(x):
        return (model_mod.softmax(model_mod.simple_cnn(params, x, algo=algo)),)

    x_spec = jax.ShapeDtypeStruct((batch, 1, 28, 28), jnp.float32)
    lowered = jax.jit(fn).lower(x_spec)
    return {
        "name": f"model_simple_cnn_{algo}_b{batch}",
        "kind": "model",
        "algo": algo,
        "inputs": [list(x_spec.shape)],
        "output": [batch, classes],
    }, to_hlo_text(lowered)


def dump_weights(out_dir, seed=42, classes=10):
    """Write the model weights as raw f32 for the Rust-native backends."""
    import numpy as np

    params = model_mod.init_params(seed=seed, classes=classes)
    order = ["conv1", "conv2", "fc"]
    fname = "simple_cnn_weights.bin"
    path = os.path.join(out_dir, fname)
    with open(path, "wb") as f:
        for k in order:
            f.write(np.asarray(params[k], dtype="<f4").tobytes())
    print(f"wrote {path}")
    return {
        "name": "simple_cnn_weights",
        "kind": "weights",
        "algo": "none",
        "file": fname,
        "inputs": [list(params[k].shape) for k in order],
        "output": [],
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    jobs = []
    for algo in ("sliding", "gemm"):
        for k in (3, 5, 7):
            jobs.append(lower_conv2d(algo, c=3, hw=32, k=k))
        jobs.append(lower_model(algo, batch=args.batch))

    manifest = {"version": 1, "artifacts": [dump_weights(args.out_dir)]}
    for spec, hlo in jobs:
        fname = spec["name"] + ".hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(hlo)
        spec["file"] = fname
        manifest["artifacts"].append(spec)
        print(f"wrote {path} ({len(hlo)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
