"""Layer-2 JAX model: a small CNN whose conv layers call the L1 kernels.

The model mirrors ``rust/src/nn/zoo.rs::simple_cnn`` (LeNet geometry) so
the AOT artifact can be cross-checked against the Rust-native execution.
The convolution algorithm is a build-time choice (``algo``): "sliding"
routes through the Pallas Sliding Window kernel, "gemm" through the
im2col+GEMM Pallas kernel, "ref" through plain lax — all three lower to
HLO the Rust runtime executes identically.
"""

import jax.numpy as jnp

from .kernels import gemm_conv, pooling, ref, sliding


def conv2d(x, w, *, stride=(1, 1), pad=(0, 0), algo="sliding"):
    """Dispatch a 2-D convolution to one of the L1 kernels."""
    if algo == "sliding":
        return sliding.conv2d_sliding(x, w, stride=stride, pad=pad)
    if algo == "gemm":
        return gemm_conv.conv2d_gemm(x, w, stride=stride, pad=pad)
    if algo == "ref":
        return ref.conv2d(x, w, stride=stride, pad=pad)
    raise ValueError(f"unknown algo '{algo}'")


def init_params(seed=42, classes=10):
    """Deterministic He-initialised weights for the simple CNN.

    Plain numpy-free init via jax PRNG so artifacts are reproducible.
    """
    import jax

    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)

    def he(key, shape, fan_in):
        return jax.random.normal(key, shape, jnp.float32) * (2.0 / fan_in) ** 0.5

    return {
        "conv1": he(k1, (16, 1, 5, 5), 1 * 5 * 5),
        "conv2": he(k2, (32, 16, 5, 5), 16 * 5 * 5),
        "fc": he(k3, (classes, 32 * 7 * 7), 32 * 7 * 7),
    }


def simple_cnn(params, x, *, algo="sliding"):
    """LeNet-style forward pass. x: [n, 1, 28, 28] -> [n, classes] logits.

    conv5-same -> relu -> maxpool2 -> conv5-same -> relu -> maxpool2 ->
    flatten -> linear. Pooling always uses the sliding log-step kernel
    (pooling *is* a sliding window sum — the paper's abstract).
    """
    y = conv2d(x, params["conv1"], pad=(2, 2), algo=algo)
    y = jnp.maximum(y, 0.0)
    y = pooling.max_pool2d(y, 2) if algo != "ref" else ref.max_pool2d(y, 2)
    y = conv2d(y, params["conv2"], pad=(2, 2), algo=algo)
    y = jnp.maximum(y, 0.0)
    y = pooling.max_pool2d(y, 2) if algo != "ref" else ref.max_pool2d(y, 2)
    y = y.reshape(y.shape[0], -1)
    return y @ params["fc"].T


def softmax(logits):
    """Row softmax (matches the Rust nn layer)."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)
