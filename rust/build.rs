//! Build probe: gate the AVX-512 intrinsic kernels on compiler support.
//!
//! The `_mm512_*` f32 intrinsics were stabilized in Rust 1.89. Older
//! stable compilers must still build this crate (the dispatch layer then
//! tops out at AVX2), so instead of a hard `rustc` floor we probe the
//! compiler version here and emit the `swconv_avx512` cfg only when the
//! intrinsics exist. `cargo:rustc-check-cfg` registers the custom cfg so
//! `-D warnings` builds (clippy/check-cfg lints) stay clean either way.

use std::process::Command;

fn rustc_minor() -> Option<u32> {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = Command::new(rustc).arg("-V").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (abc 2025-08-01)" or "rustc 1.91.0-nightly (...)".
    let version = text.split_whitespace().nth(1)?;
    let mut parts = version.split('.');
    let major: u32 = parts.next()?.parse().ok()?;
    let minor: u32 = parts.next()?.parse().ok()?;
    if major != 1 {
        // A hypothetical 2.x compiler has everything 1.89 had.
        return Some(u32::MAX);
    }
    Some(minor)
}

fn main() {
    println!("cargo:rustc-check-cfg=cfg(swconv_avx512)");
    if rustc_minor().is_some_and(|minor| minor >= 89) {
        println!("cargo:rustc-cfg=swconv_avx512");
    }
    println!("cargo:rerun-if-changed=build.rs");
}
