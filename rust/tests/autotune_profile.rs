//! Integration tests for the autotune subsystem: profile persistence
//! (round-trip, corrupt-file fallback), tuned dispatch parity (the
//! tuned router must be a pure relabeling of existing kernels, bit for
//! bit), and the no-profile paper-policy fallback.

use std::path::PathBuf;
use std::sync::Arc;
use swconv::autotune::{autotune, AutotuneOpts, DispatchProfile, ProfileEntry, TunedAlgo};
use swconv::exec::ExecCtx;
use swconv::kernels::rowconv::RowKernel;
use swconv::kernels::{conv2d_ctx, Conv2dParams, ConvAlgo};
use swconv::simd::IsaLevel;
use swconv::tensor::{Dtype, Tensor};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("swconv_autotune_it_{name}"))
}

/// A hand-built profile covering all three conv-level choices across
/// the width range (no measurement needed, so tests stay fast and
/// deterministic on any machine).
fn handmade() -> DispatchProfile {
    DispatchProfile::from_entries(vec![
        ProfileEntry {
            k: 3,
            threads: 1,
            dtype: Dtype::F32,
            isa: IsaLevel::Scalar,
            algo: TunedAlgo::Sliding,
            slide: RowKernel::Custom,
            gflops: 8.0,
        },
        ProfileEntry {
            k: 7,
            threads: 1,
            dtype: Dtype::F32,
            isa: IsaLevel::Scalar,
            algo: TunedAlgo::Gemm,
            slide: RowKernel::Generic,
            gflops: 6.0,
        },
        ProfileEntry {
            k: 11,
            threads: 1,
            dtype: Dtype::F32,
            isa: IsaLevel::Scalar,
            algo: TunedAlgo::Sliding,
            slide: RowKernel::Compound,
            gflops: 5.0,
        },
        ProfileEntry {
            k: 19,
            threads: 4,
            dtype: Dtype::F32,
            isa: IsaLevel::Scalar,
            algo: TunedAlgo::Direct,
            slide: RowKernel::Compound,
            gflops: 1.0,
        },
    ])
}

/// The parity suite: geometries covering padding, stride, groups and
/// every dispatch regime (custom, generic, compound widths).
fn parity_cases() -> Vec<(Vec<usize>, Vec<usize>, Conv2dParams)> {
    vec![
        (vec![1, 3, 12, 14], vec![4, 3, 3, 3], Conv2dParams::same(3)),
        (vec![2, 2, 10, 16], vec![3, 2, 7, 7], Conv2dParams::same(7)),
        (
            vec![1, 4, 12, 14],
            vec![4, 1, 5, 5],
            Conv2dParams { stride: (2, 2), pad: (2, 2), groups: 4 },
        ),
        (vec![1, 1, 8, 40], vec![2, 1, 3, 19], Conv2dParams::default()),
    ]
}

/// PARITY — `ConvAlgo::Tuned` is routing, not arithmetic: on the full
/// parity suite it stays within the kernel tolerance of the `Direct`
/// oracle, for a profiled and an unprofiled ctx alike.
#[test]
fn tuned_dispatch_matches_direct_oracle_on_parity_suite() {
    let profile = Arc::new(handmade());
    for (i, (xd, wd, p)) in parity_cases().iter().enumerate() {
        let x = Tensor::randn(xd, 700 + i as u64);
        let w = Tensor::randn(wd, 710 + i as u64);
        let reference = conv2d_ctx(&x, &w, None, p, &ExecCtx::new(ConvAlgo::Direct));
        for profiled in [false, true] {
            let mut ctx = ExecCtx::new(ConvAlgo::Tuned);
            if profiled {
                ctx.set_profile(Arc::clone(&profile));
            }
            let y = conv2d_ctx(&x, &w, None, p, &ctx);
            let d = y.max_abs_diff(&reference);
            assert!(d < 2e-3, "case {i} profiled={profiled}: diff {d}");
        }
    }
}

/// DETERMINISM — whatever kernel the profile picks, the tuned output is
/// bit-identical to invoking that kernel directly (here: a profile
/// routing k=7 to GEMM must reproduce `Im2colGemm` exactly).
#[test]
fn tuned_is_bitwise_equal_to_the_routed_kernel() {
    let profile = Arc::new(handmade());
    let x = Tensor::randn(&[2, 2, 10, 16], 720);
    let w = Tensor::randn(&[3, 2, 7, 7], 721);
    let p = Conv2dParams::same(7);
    let tuned = conv2d_ctx(
        &x,
        &w,
        None,
        &p,
        &ExecCtx::new(ConvAlgo::Tuned).with_profile(Arc::clone(&profile)),
    );
    let gemm = conv2d_ctx(&x, &w, None, &p, &ExecCtx::new(ConvAlgo::Im2colGemm));
    assert_eq!(tuned.as_slice(), gemm.as_slice());
}

/// FALLBACK — with no profile attached, tuned dispatch *is* the paper
/// policy, bit for bit, on every parity-suite case.
#[test]
fn tuned_without_profile_is_bitwise_paper_policy() {
    for (i, (xd, wd, p)) in parity_cases().iter().enumerate() {
        let x = Tensor::randn(xd, 730 + i as u64);
        let w = Tensor::randn(wd, 740 + i as u64);
        let paper = conv2d_ctx(&x, &w, None, p, &ExecCtx::new(ConvAlgo::Sliding));
        let tuned = conv2d_ctx(&x, &w, None, p, &ExecCtx::new(ConvAlgo::Tuned));
        assert_eq!(paper.as_slice(), tuned.as_slice(), "case {i}");
    }
}

/// PERSISTENCE — a saved-then-loaded profile is equal to the in-memory
/// one and dispatches identically (bit for bit) on the parity suite.
#[test]
fn saved_and_loaded_profile_dispatch_identically() {
    let in_mem = Arc::new(handmade());
    let path = tmp("roundtrip.json");
    in_mem.save(&path).unwrap();
    let loaded = Arc::new(DispatchProfile::load(&path).unwrap());
    assert_eq!(*in_mem, *loaded);

    for (i, (xd, wd, p)) in parity_cases().iter().enumerate() {
        let x = Tensor::randn(xd, 750 + i as u64);
        let w = Tensor::randn(wd, 760 + i as u64);
        let a = conv2d_ctx(
            &x,
            &w,
            None,
            p,
            &ExecCtx::new(ConvAlgo::Tuned).with_profile(Arc::clone(&in_mem)),
        );
        let b = conv2d_ctx(
            &x,
            &w,
            None,
            p,
            &ExecCtx::new(ConvAlgo::Tuned).with_profile(Arc::clone(&loaded)),
        );
        assert_eq!(a.as_slice(), b.as_slice(), "case {i}");
    }
    let _ = std::fs::remove_file(path);
}

/// PERSISTENCE — a *measured* profile (tiny quick pass) round-trips
/// through save/load exactly, floats included.
#[test]
fn measured_profile_roundtrips_exactly() {
    let p = autotune(&AutotuneOpts::quick());
    assert!(!p.is_paper_policy());
    let path = tmp("measured.json");
    p.save(&path).unwrap();
    assert_eq!(p, DispatchProfile::load(&path).unwrap());
    let _ = std::fs::remove_file(path);
}

/// ROBUSTNESS — corrupt and truncated caches degrade to the paper
/// policy (with a warning) instead of panicking, and dispatch through
/// the degraded profile still matches the paper policy bit for bit.
#[test]
fn corrupt_or_truncated_profile_falls_back_to_paper_policy() {
    // A real profile, truncated mid-document (simulating a torn write).
    let full = tmp("torn_full.json");
    handmade().save(&full).unwrap();
    let text = std::fs::read_to_string(&full).unwrap();
    let torn = tmp("torn.json");
    std::fs::write(&torn, &text[..text.len() / 2]).unwrap();
    // And outright garbage.
    let garbage = tmp("garbage.json");
    std::fs::write(&garbage, "{\"version\": 1, \"lanes\": oops").unwrap();

    let x = Tensor::randn(&[1, 2, 10, 12], 770);
    let w = Tensor::randn(&[3, 2, 5, 5], 771);
    let p = Conv2dParams::default();
    let paper = conv2d_ctx(&x, &w, None, &p, &ExecCtx::new(ConvAlgo::Sliding));
    for path in [&torn, &garbage] {
        assert!(DispatchProfile::load(path).is_err(), "{} must not parse", path.display());
        let degraded = DispatchProfile::load_or_paper(path);
        assert!(degraded.is_paper_policy(), "{} must degrade", path.display());
        let y = conv2d_ctx(
            &x,
            &w,
            None,
            &p,
            &ExecCtx::new(ConvAlgo::Tuned).with_profile(Arc::new(degraded)),
        );
        assert_eq!(paper.as_slice(), y.as_slice());
    }
    for f in [full, torn, garbage] {
        let _ = std::fs::remove_file(f);
    }
}

/// The measured profile is *usable*: tuned dispatch through a freshly
/// autotuned table stays within tolerance of the direct oracle (the
/// acceptance gate tying measurement to dispatch).
#[test]
fn measured_profile_dispatches_correctly() {
    let profile = Arc::new(autotune(&AutotuneOpts::quick()));
    let x = Tensor::randn(&[1, 3, 14, 24], 780);
    let reference_ctx = ExecCtx::new(ConvAlgo::Direct);
    for k in [3usize, 5, 9, 19] {
        let w = Tensor::randn(&[2, 3, k.min(9), k], 781 + k as u64);
        let p = Conv2dParams::default();
        let want = conv2d_ctx(&x, &w, None, &p, &reference_ctx);
        let ctx = ExecCtx::new(ConvAlgo::Tuned).with_profile(Arc::clone(&profile));
        let got = conv2d_ctx(&x, &w, None, &p, &ctx);
        let d = got.max_abs_diff(&want);
        assert!(d < 2e-3, "k={k}: diff {d}");
    }
}
