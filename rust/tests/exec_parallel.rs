//! Integration tests for the exec subsystem: multi-threaded kernels must
//! be bit-identical to single-threaded ones, stay within the kernel
//! parity tolerance against the direct oracle, and run allocation-free
//! once the scratch arena is warm.

use swconv::exec::ExecCtx;
use swconv::kernels::pool::{avg_pool2d_ctx, max_pool2d_ctx, max_pool2d_naive};
use swconv::kernels::sliding1d::conv1d_sliding_ctx;
use swconv::kernels::sliding2d::{conv2d_sliding_ctx, SlideVariant};
use swconv::kernels::{
    conv1d_ctx, conv2d_ctx, Conv1dParams, Conv2dParams, ConvAlgo, PoolParams,
};
use swconv::tensor::Tensor;

/// DETERMINISM — threads=1 and threads=N produce identical bytes for the
/// sliding kernels: work items are whole output planes/rows computed
/// with the same instruction sequence on any partition.
#[test]
fn sliding2d_bitwise_deterministic_across_thread_counts() {
    let x = Tensor::randn(&[2, 3, 20, 24], 900);
    let w = Tensor::randn(&[6, 3, 5, 5], 901);
    let bias: Vec<f32> = (0..6).map(|i| 0.1 * i as f32).collect();
    let p = Conv2dParams::same(5);
    let one = ExecCtx::with_threads(ConvAlgo::Sliding, 1);
    let base = conv2d_sliding_ctx(&x, &w, Some(&bias), &p, SlideVariant::Auto, &one);
    for threads in [2usize, 3, 4, 7] {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads);
        let y = conv2d_sliding_ctx(&x, &w, Some(&bias), &p, SlideVariant::Auto, &ctx);
        assert_eq!(
            base.as_slice(),
            y.as_slice(),
            "threads={threads} not bit-identical"
        );
    }
}

#[test]
fn sliding1d_bitwise_deterministic_across_thread_counts() {
    let x = Tensor::randn(&[3, 200], 902);
    let w = Tensor::randn(&[5, 3, 9], 903);
    let p = Conv1dParams { stride: 1, pad: 4 };
    let one = ExecCtx::with_threads(ConvAlgo::Sliding, 1);
    let base = conv1d_sliding_ctx(&x, &w, None, &p, &one);
    for threads in [2usize, 5, 8] {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads);
        let y = conv1d_sliding_ctx(&x, &w, None, &p, &ctx);
        assert_eq!(base.as_slice(), y.as_slice(), "threads={threads}");
    }
}

#[test]
fn pooling_bitwise_deterministic_across_thread_counts() {
    let x = Tensor::randn(&[2, 4, 17, 19], 904);
    let p = PoolParams { k: (3, 3), stride: (2, 2), pad: (1, 1) };
    let one = ExecCtx::with_threads(ConvAlgo::Sliding, 1);
    let base_max = max_pool2d_ctx(&x, &p, &one);
    let base_avg = avg_pool2d_ctx(&x, &p, &one);
    for threads in [2usize, 4] {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads);
        assert_eq!(base_max.as_slice(), max_pool2d_ctx(&x, &p, &ctx).as_slice());
        assert_eq!(base_avg.as_slice(), avg_pool2d_ctx(&x, &p, &ctx).as_slice());
    }
    // And the sliding pool still matches the naive oracle exactly.
    assert_eq!(base_max.as_slice(), max_pool2d_naive(&x, &p).as_slice());
}

/// DETERMINISM — the ctx-taking dispatch entry points are bit-identical
/// to the legacy single-threaded wrappers for every algorithm.
#[test]
fn ctx_dispatch_matches_legacy_entry_points() {
    let x = Tensor::randn(&[1, 3, 14, 16], 905);
    let w = Tensor::randn(&[4, 3, 3, 3], 906);
    let p = Conv2dParams::same(3);
    for algo in ConvAlgo::ALL {
        let legacy = swconv::kernels::conv2d(&x, &w, None, &p, algo);
        for threads in [1usize, 4] {
            let ctx = ExecCtx::with_threads(algo, threads);
            let y = conv2d_ctx(&x, &w, None, &p, &ctx);
            assert_eq!(legacy.as_slice(), y.as_slice(), "{algo:?} threads={threads}");
        }
    }
}

/// PARITY — multi-threaded runs of every algorithm stay within the
/// existing 2e-3 tolerance of the direct oracle (strided + grouped too).
#[test]
fn multithreaded_parity_with_direct_oracle() {
    let cases = [
        (vec![2, 4, 13, 15], vec![6, 4, 3, 3], Conv2dParams::same(3)),
        // Strided, ungrouped (asymmetric stride).
        (
            vec![1, 3, 15, 17],
            vec![4, 3, 5, 5],
            Conv2dParams { stride: (2, 3), pad: (2, 2), groups: 1 },
        ),
        // Strided AND depthwise (groups == c_in).
        (
            vec![1, 4, 12, 14],
            vec![4, 1, 5, 5],
            Conv2dParams { stride: (2, 2), pad: (2, 2), groups: 4 },
        ),
    ];
    for (i, (xd, wd, p)) in cases.iter().enumerate() {
        let x = Tensor::randn(xd, 910 + i as u64);
        let w = Tensor::randn(wd, 920 + i as u64);
        let oracle = ExecCtx::with_threads(ConvAlgo::Direct, 3);
        let reference = conv2d_ctx(&x, &w, None, p, &oracle);
        for algo in ConvAlgo::ALL {
            if !algo.supports_width(wd[3]) {
                continue;
            }
            let ctx = ExecCtx::with_threads(algo, 4);
            let y = conv2d_ctx(&x, &w, None, p, &ctx);
            let d = y.max_abs_diff(&reference);
            assert!(d < 2e-3, "case {i} {algo:?}: diff {d}");
        }
    }
}

#[test]
fn conv1d_ctx_parity_all_algos() {
    let x = Tensor::randn(&[2, 90], 930);
    let w = Tensor::randn(&[3, 2, 7], 931);
    let p = Conv1dParams { stride: 1, pad: 3 };
    let reference = conv1d_ctx(&x, &w, None, &p, &ExecCtx::new(ConvAlgo::Direct));
    for algo in ConvAlgo::ALL {
        let ctx = ExecCtx::with_threads(algo, 4);
        let y = conv1d_ctx(&x, &w, None, &p, &ctx);
        let d = y.max_abs_diff(&reference);
        assert!(d < 2e-3, "{algo:?}: diff {d}");
    }
}

/// ARENA — after a warm-up call, the sliding2d hot loop performs zero
/// heap allocations: every padded/scratch buffer is reused from the
/// ctx's arena (this is the acceptance gate for serving workloads).
#[test]
fn sliding2d_steady_state_allocates_nothing() {
    let x = Tensor::randn(&[2, 3, 32, 32], 940);
    let w = Tensor::randn(&[8, 3, 5, 5], 941);
    let p = Conv2dParams::same(5);
    for threads in [1usize, 4] {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads);
        let warm = conv2d_ctx(&x, &w, None, &p, &ctx);
        let after_warmup = ctx.alloc_events();
        assert!(after_warmup > 0, "warm-up must have allocated scratch");
        for _ in 0..3 {
            let y = conv2d_ctx(&x, &w, None, &p, &ctx);
            assert_eq!(y.as_slice(), warm.as_slice());
        }
        assert_eq!(
            ctx.alloc_events(),
            after_warmup,
            "threads={threads}: steady-state conv must not allocate scratch"
        );
    }
}

#[test]
fn im2col_and_pool_steady_state_allocate_nothing() {
    let x = Tensor::randn(&[2, 3, 24, 24], 950);
    let w = Tensor::randn(&[4, 3, 3, 3], 951);
    let p = Conv2dParams::same(3);
    let ctx = ExecCtx::with_threads(ConvAlgo::Im2colGemm, 2);
    let _ = conv2d_ctx(&x, &w, None, &p, &ctx);
    let pp = PoolParams::with_stride(2, 2);
    let _ = max_pool2d_ctx(&x, &pp, &ctx);
    let marks = ctx.alloc_events();
    let _ = conv2d_ctx(&x, &w, None, &p, &ctx);
    let _ = max_pool2d_ctx(&x, &pp, &ctx);
    assert_eq!(ctx.alloc_events(), marks, "steady state must reuse the arena");
}

/// A model forward through a shared multi-threaded ctx matches the
/// single-threaded forward bit-for-bit (the coordinator-backend setup).
#[test]
fn model_forward_deterministic_across_thread_counts() {
    use swconv::nn::zoo;
    let m = zoo::simple_cnn(10, 7);
    let x = Tensor::randn(&[3, 1, 28, 28], 960);
    let one = m.forward(&x, &ExecCtx::with_threads(ConvAlgo::Sliding, 1));
    let many = m.forward(&x, &ExecCtx::with_threads(ConvAlgo::Sliding, 4));
    assert_eq!(one.as_slice(), many.as_slice());
}
