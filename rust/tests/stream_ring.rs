//! Property suite for the streaming substrate: the mirrored ring buffer
//! and the per-stage update recurrences, each checked against a
//! from-scratch recomputation **at every step** of random frame
//! sequences — not just on a final aggregate. Covers wrap-around (many
//! times the ring capacity), mid-sequence resets, stride > 1 emission
//! schedules, and window warmup.

mod common;

use common::assert_slices_bitwise;
use swconv::kernels::{Conv2dParams, ConvAlgo, PoolParams};
use swconv::nn::layers::{AvgPool2d, Conv2d};
use swconv::nn::{ExecCtx, Model};
use swconv::stream::{Ring, StreamSession};
use swconv::tensor::{Dtype, Tensor, XorShiftRng};

/// The ring's contiguous window must equal the tail of an ever-growing
/// from-scratch log after every push — across random channel/capacity
/// geometries, splat pushes, resets, and several wrap-arounds.
#[test]
fn ring_window_matches_a_from_scratch_log_under_random_traffic() {
    let mut rng = XorShiftRng::new(41);
    for trial in 0..24 {
        let channels = 1 + rng.uniform(0.0, 3.0) as usize;
        let cap = 1 + rng.uniform(0.0, 9.0) as usize;
        let mut r = Ring::<f32>::new(channels, cap);
        let mut log: Vec<Vec<f32>> = Vec::new();
        for step in 0..4 * cap + 13 {
            if rng.uniform(0.0, 1.0) < 0.1 {
                r.reset();
                log.clear();
            }
            if rng.uniform(0.0, 1.0) < 0.2 {
                r.push_splat(0.0);
                log.push(vec![0.0; channels]);
            } else {
                let col: Vec<f32> = (0..channels).map(|_| rng.gauss()).collect();
                r.push(&col);
                log.push(col);
            }
            assert_eq!(r.len(), log.len().min(cap), "trial {trial} step {step}: len");
            for w in 1..=r.len() {
                for ch in 0..channels {
                    let want: Vec<f32> = log[log.len() - w..].iter().map(|c| c[ch]).collect();
                    assert_slices_bitwise(
                        r.window(ch, w),
                        &want,
                        &format!("trial {trial} step {step} w={w} ch={ch}"),
                    );
                }
            }
        }
    }
}

/// The avg-pool running-sum recurrence must track a from-scratch mean
/// of exactly the last `k` frames at every emission, within the
/// documented drift bound `4·ε·max|x|·(pushes + k)` — the same formula
/// [`StreamSession::tolerance`] charges the stage with.
#[test]
fn avg_pool_recurrence_tracks_the_from_scratch_window_at_every_step() {
    let mut rng = XorShiftRng::new(42);
    for (k, stride) in [(2usize, 2usize), (3, 1), (4, 2), (5, 3)] {
        let channels = 2;
        let model = Model::new("avg-prop", &[channels, 1, 64])
            .push(AvgPool2d(PoolParams { k: (1, k), stride: (1, stride), pad: (0, 0) }));
        let mut sess = StreamSession::new(&model, ExecCtx::default()).unwrap();
        let mut log: Vec<Vec<f32>> = Vec::new();
        let mut amax = 0.0f32;
        for step in 0..200 {
            let frame: Vec<f32> = (0..channels).map(|_| rng.gauss() * 3.0).collect();
            for &v in &frame {
                amax = amax.max(v.abs());
            }
            log.push(frame.clone());
            if let Some(col) = sess.advance(&frame) {
                let bound = (4.0 * 1.2e-7 * amax * (log.len() + k) as f32).max(1e-6);
                for (ch, &got) in col.iter().enumerate() {
                    let want: f32 =
                        log[log.len() - k..].iter().map(|c| c[ch]).sum::<f32>() / k as f32;
                    let d = (got - want).abs();
                    assert!(
                        d <= bound,
                        "(k={k},s={stride}) step {step} ch={ch}: drift {d:e} > {bound:e}"
                    );
                }
            }
        }
    }
}

/// A stride-2 padded i8 conv: every emission — as it appears, flush
/// included — is bit-identical to the corresponding column of the batch
/// reference, and the emission count lands exactly on the batch output
/// width.
#[test]
fn strided_conv_emissions_match_batch_columns_bit_for_bit_as_they_appear() {
    let w = Tensor::randn(&[3, 2, 1, 5], 43).map(|v| v * 0.5);
    let model = Model::new("stride-prop", &[2, 1, 40]).push(Conv2d {
        w,
        bias: vec![0.01, -0.02, 0.03],
        params: Conv2dParams { stride: (1, 2), pad: (0, 2), groups: 1 },
    });
    let ctx = ExecCtx::new(ConvAlgo::Sliding).with_dtype(Dtype::I8);
    let mut sess = StreamSession::new(&model, ctx).unwrap();
    assert!(sess.is_bit_exact());
    let x = Tensor::randn(&[1, 2, 1, 40], 44);
    let want = sess.run_batch(&x);
    let mut t_out = 0usize;
    for t in 0..x.dim(3) {
        let frame = [x.at4(0, 0, 0, t), x.at4(0, 1, 0, t)];
        if let Some(col) = sess.advance(&frame) {
            let want_col: Vec<f32> = (0..3).map(|c| want.at4(0, c, 0, t_out)).collect();
            assert_slices_bitwise(&col, &want_col, &format!("emission {t_out} at frame {t}"));
            t_out += 1;
        }
    }
    for col in sess.flush() {
        let want_col: Vec<f32> = (0..3).map(|c| want.at4(0, c, 0, t_out)).collect();
        assert_slices_bitwise(&col, &want_col, &format!("flush emission {t_out}"));
        t_out += 1;
    }
    assert_eq!(t_out, want.dim(3), "total emissions vs batch output width");
}

/// Emission schedule across (k, stride, pad) geometries: the total
/// count equals the batch output width, and the first window completes
/// on frame `k − pad − 1` (the left padding is preloaded, so only
/// `k − pad` real frames are needed; stride never delays the *first*
/// emission because `(pushed − k) = 0` divides everything).
#[test]
fn warmup_and_stride_emission_schedule_matches_the_batch_geometry() {
    let cases = [(3usize, 1usize, 1usize), (5, 2, 2), (7, 3, 0), (4, 2, 1), (9, 1, 4)];
    for (k, stride, pad) in cases {
        let w = Tensor::randn(&[1, 1, 1, k], 45).map(|v| v * 0.3);
        let model = Model::new("sched-prop", &[1, 1, 48]).push(Conv2d {
            w,
            bias: vec![0.0],
            params: Conv2dParams { stride: (1, stride), pad: (0, pad), groups: 1 },
        });
        let mut sess = StreamSession::new(&model, ExecCtx::default()).unwrap();
        let x = Tensor::randn(&[1, 1, 1, 48], 46);
        let batch_w = sess.run_batch(&x).dim(3);
        let mut first = None;
        let mut count = 0usize;
        for t in 0..x.dim(3) {
            if sess.advance(&[x.at4(0, 0, 0, t)]).is_some() {
                first.get_or_insert(t);
                count += 1;
            }
        }
        count += sess.flush().len();
        assert_eq!(count, batch_w, "k={k} s={stride} p={pad}: emission count");
        assert_eq!(first, Some(k - pad - 1), "k={k} s={stride} p={pad}: first emission");
    }
}
