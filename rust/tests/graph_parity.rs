//! End-to-end parity of the graph compiler: the compiled plan — fused
//! (full pass pipeline) and verbatim (`compile_with(false)`, the
//! `SWCONV_NO_FUSE=1` shape) — must reproduce the layer-by-layer
//! `Model::forward` **bit-for-bit** for f32/bf16 and **exactly** for
//! int8, for every zoo model, per forced algorithm, per serving dtype,
//! per thread count and per ISA level. The pass pipeline is a traffic
//! knob, never an accuracy knob: fusing bias+ReLU into the output
//! write, eliding a pad copy into kernel edge handling, or exchanging
//! i8 activations between adjacent quantized convs must all leave the
//! produced numbers untouched.

mod common;

use common::{assert_bitwise, input_for};
use swconv::kernels::ConvAlgo;
use swconv::nn::{zoo, ExecCtx};
use swconv::simd::IsaLevel;
use swconv::tensor::Dtype;

/// Algorithms worth forcing per model: the small nets take the full
/// set (Tuned without a profile routes like Sliding); SlidingGeneric
/// caps at k = 17, so the k = 21 net skips it, and the bigger nets
/// skip the O(k²)-per-output Direct oracle to keep debug runs sane.
fn algos_for(name: &str) -> Vec<ConvAlgo> {
    match name {
        "simple-cnn" | "quantized-cnn" => ConvAlgo::ALL.to_vec(),
        "large-filter-net" => {
            vec![ConvAlgo::Im2colGemm, ConvAlgo::Sliding, ConvAlgo::SlidingCompound]
        }
        _ => vec![ConvAlgo::Im2colGemm, ConvAlgo::Sliding],
    }
}

/// Fused and verbatim plans equal `forward` bitwise for every zoo
/// model under every algorithm that model supports.
#[test]
fn compiled_plans_bit_identical_per_model_and_algo() {
    for name in zoo::MODEL_NAMES {
        let m = zoo::by_name(name, 4, 42).unwrap();
        let batch = if matches!(name, "simple-cnn" | "quantized-cnn") { 2 } else { 1 };
        let x = input_for(&m, batch, 7);
        let fused = m.compile_with(true);
        let plain = m.compile_with(false);
        for algo in algos_for(name) {
            let ctx = ExecCtx::new(algo);
            let want = m.forward(&x, &ctx);
            assert_bitwise(&fused.run(&x, &ctx), &want, &format!("{name} {algo:?} fused"));
            assert_bitwise(&plain.run(&x, &ctx), &want, &format!("{name} {algo:?} verbatim"));
        }
    }
}

/// The threading axis must not perturb plan parity (the plan hands the
/// same ctx to the same kernels the layers call).
#[test]
fn thread_counts_do_not_perturb_compiled_parity() {
    for name in ["simple-cnn", "quantized-cnn"] {
        let m = zoo::by_name(name, 4, 42).unwrap();
        let x = input_for(&m, 2, 11);
        let fused = m.compile_with(true);
        for algo in [ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            for threads in [1usize, 2, 4] {
                let ctx = ExecCtx::with_threads(algo, threads);
                let want = m.forward(&x, &ctx);
                assert_bitwise(
                    &fused.run(&x, &ctx),
                    &want,
                    &format!("{name} {algo:?} threads={threads}"),
                );
            }
        }
    }
}

/// The serving-dtype axis: bf16 and dynamic-int8 contexts run the plan
/// through the same reduced-precision kernels the layers use, so the
/// compiled output is bitwise equal to `forward` under the same ctx.
#[test]
fn serving_dtypes_match_the_layer_path_bitwise() {
    for name in ["simple-cnn", "quantized-cnn"] {
        let m = zoo::by_name(name, 4, 42).unwrap();
        let x = input_for(&m, 1, 13);
        let fused = m.compile_with(true);
        let plain = m.compile_with(false);
        for dtype in [Dtype::Bf16, Dtype::I8] {
            for algo in [ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
                let ctx = ExecCtx::new(algo).with_dtype(dtype);
                let want = m.forward(&x, &ctx);
                assert_bitwise(
                    &fused.run(&x, &ctx),
                    &want,
                    &format!("{name} {algo:?} {dtype:?} fused"),
                );
                assert_bitwise(
                    &plain.run(&x, &ctx),
                    &want,
                    &format!("{name} {algo:?} {dtype:?} verbatim"),
                );
            }
        }
    }
}

/// Per-ctx forced ISA levels: the plan inherits the ctx's level like
/// every kernel call does, and parity holds at each one (levels this
/// machine lacks degrade to the portable kernels inside dispatch, so
/// this passes — and still exercises every arm — on any host).
#[test]
fn forced_isa_levels_preserve_compiled_parity() {
    let m = zoo::simple_cnn(4, 42);
    let x = input_for(&m, 1, 17);
    let fused = m.compile_with(true);
    let scalar_ctx = ExecCtx::new(ConvAlgo::Sliding).with_isa(IsaLevel::Scalar);
    let reference = m.forward(&x, &scalar_ctx);
    for isa in IsaLevel::ALL {
        let ctx = ExecCtx::new(ConvAlgo::Sliding).with_isa(isa);
        let want = m.forward(&x, &ctx);
        assert_bitwise(&fused.run(&x, &ctx), &want, &format!("{isa} fused vs forward"));
        // And the ISA-invariance contract carries over to plans.
        assert_bitwise(&fused.run(&x, &ctx), &reference, &format!("{isa} vs scalar"));
    }
}

/// Structural checks: the passes actually fire on the models built to
/// exercise them, and firing shrinks the graph's activation traffic.
#[test]
fn pass_pipeline_fires_and_reduces_traffic() {
    let m = zoo::quantized_cnn(4, 42);
    let fused = m.compile_with(true);
    let plain = m.compile_with(false);
    assert_eq!(fused.summary.elided_pads, 1);
    assert_eq!(fused.summary.fused_relu, 3);
    assert_eq!(fused.summary.hoisted_quant, 1);
    assert!(fused.graph.nodes.len() < plain.graph.nodes.len());
    assert!(
        fused.activation_bytes(1) < plain.activation_bytes(1),
        "passes should shrink activation traffic: {} vs {}",
        fused.activation_bytes(1),
        plain.activation_bytes(1)
    );
    // Fusion folds the ReLU element pass into the conv write, so the
    // fused plan's counted FLOPs can only drop, never grow.
    assert!(fused.flops(2) > 0 && fused.flops(2) <= plain.flops(2));

    let s = zoo::simple_cnn(4, 42).compile_with(true);
    assert_eq!(s.summary.fused_relu, 2);
    assert_eq!(s.summary.elided_pads, 0);
    assert_eq!(s.summary.hoisted_quant, 0);
}
