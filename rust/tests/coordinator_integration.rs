//! Coordinator integration: batching invariants under concurrent load,
//! router correctness, replica sharding, failure behaviour (including
//! panicking backends), metrics accounting.

use std::sync::Arc;
use std::time::Duration;
use swconv::coordinator::{Backend, BackendSpec, BatchPolicy, Coordinator, InferError};
use swconv::kernels::ConvAlgo;
use swconv::nn::{zoo, ExecCtx};
use swconv::tensor::Tensor;

/// Identity backend over `[3]` items: batch in, batch out. Shared by
/// the stacking/splitting round-trip tests.
struct Echo;

impl Backend for Echo {
    fn name(&self) -> &str {
        "echo"
    }
    fn item_shape(&self) -> &[usize] {
        &[3]
    }
    fn infer(&mut self, batch: &Tensor) -> swconv::error::Result<Tensor> {
        Ok(batch.clone())
    }
}

fn coord(max_batch: usize, wait_ms: u64) -> Coordinator {
    Coordinator::new(
        vec![
            BackendSpec::native("sliding", zoo::simple_cnn(10, 1), ExecCtx::new(ConvAlgo::Sliding)),
            BackendSpec::native("gemm", zoo::simple_cnn(10, 1), ExecCtx::new(ConvAlgo::Im2colGemm)),
        ],
        BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms) },
    )
}

/// INVARIANT — no request is lost or duplicated under concurrent
/// multi-threaded submission; every id is answered exactly once.
#[test]
fn no_lost_or_duplicated_requests_under_concurrency() {
    let c = Arc::new(coord(4, 1));
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let c = Arc::clone(&c);
        handles.push(std::thread::spawn(move || {
            let mut ids = Vec::new();
            for i in 0..12 {
                let r = c
                    .infer("sliding", Tensor::randn(&[1, 28, 28], t * 100 + i))
                    .expect("infer");
                assert!(r.output.is_ok());
                ids.push(r.id);
            }
            ids
        }));
    }
    let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    let n = all.len();
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len(), n, "duplicate response ids");
    assert_eq!(n, 48);

    let m = c.metrics("sliding").unwrap();
    assert_eq!(m.count, 48, "all requests recorded");
    assert_eq!(m.items, 48, "all items processed");
    Arc::try_unwrap(c).ok().expect("sole owner").shutdown();
}

/// INVARIANT — replica sharding loses and duplicates nothing either:
/// the same concurrent-submission invariant over a 4-replica backend,
/// with per-replica metrics summing to the total.
#[test]
fn no_lost_or_duplicated_requests_with_replicas() {
    let c = Arc::new(Coordinator::new(
        vec![BackendSpec::native(
            "sliding",
            zoo::simple_cnn(10, 1),
            ExecCtx::new(ConvAlgo::Sliding),
        )
        .with_replicas(4)],
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
    ));
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let c = Arc::clone(&c);
        handles.push(std::thread::spawn(move || {
            let mut ids = Vec::new();
            for i in 0..12 {
                let r = c
                    .infer("sliding", Tensor::randn(&[1, 28, 28], t * 100 + i))
                    .expect("infer");
                assert!(r.output.is_ok(), "{:?}", r.output);
                ids.push(r.id);
            }
            ids
        }));
    }
    let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    let n = all.len();
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len(), n, "duplicate response ids");
    assert_eq!(n, 48);

    let agg = c.metrics("sliding").unwrap();
    assert_eq!(agg.count, 48, "all requests recorded across replicas");
    assert_eq!(agg.items, 48, "all items processed across replicas");
    let per = c.replica_metrics("sliding").unwrap();
    assert_eq!(per.len(), 4);
    assert_eq!(per.iter().map(|m| m.items).sum::<u64>(), 48);
    Arc::try_unwrap(c).ok().expect("sole owner").shutdown();
}

/// INVARIANT — replica sharding is invisible in the numbers: the same
/// submission set answered by a 1-replica and a 3-replica backend over
/// identical weights is bit-identical, request by request.
#[test]
fn replicated_responses_bit_identical_to_single() {
    let c = Coordinator::new(
        vec![
            BackendSpec::native(
                "one",
                zoo::simple_cnn(10, 1),
                ExecCtx::new(ConvAlgo::Sliding),
            ),
            BackendSpec::native(
                "many",
                zoo::simple_cnn(10, 1),
                ExecCtx::new(ConvAlgo::Sliding),
            )
            .with_replicas(3),
        ],
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
    );
    let inputs: Vec<Tensor> = (0..24).map(|i| Tensor::randn(&[1, 28, 28], i)).collect();
    // Submit the whole set to each backend (bursts, so the 3-replica
    // tier actually scatters sub-batches).
    let rx_one: Vec<_> =
        inputs.iter().map(|x| c.submit("one", x.clone()).unwrap()).collect();
    let rx_many: Vec<_> =
        inputs.iter().map(|x| c.submit("many", x.clone()).unwrap()).collect();
    for (i, (a, b)) in rx_one.into_iter().zip(rx_many).enumerate() {
        let ya = a.recv().unwrap().output.unwrap();
        let yb = b.recv().unwrap().output.unwrap();
        assert_eq!(ya.dims(), yb.dims());
        assert_eq!(ya.as_slice(), yb.as_slice(), "request {i} differs across replica counts");
    }
    c.shutdown();
}

/// INVARIANT — batches never exceed the policy's max_batch.
#[test]
fn batches_bounded_by_policy() {
    let c = coord(3, 50);
    let rxs: Vec<_> = (0..10)
        .map(|i| c.submit("gemm", Tensor::randn(&[1, 28, 28], i)).unwrap())
        .collect();
    for rx in rxs {
        assert!(rx.recv().unwrap().output.is_ok());
    }
    let m = c.metrics("gemm").unwrap();
    // 10 items in batches of <= 3 means at least 4 batches.
    assert!(m.batches >= 4, "batches {} too few for max_batch=3", m.batches);
    assert!(m.mean_batch() <= 3.0 + 1e-9);
    c.shutdown();
}

/// Router isolation: the same request routed to both backends gives the
/// same answer, and queues don't interfere.
#[test]
fn router_backends_isolated_and_equivalent() {
    let c = coord(8, 1);
    let x = Tensor::randn(&[1, 28, 28], 77);
    let a = c.infer("sliding", x.clone()).unwrap().output.unwrap();
    let b = c.infer("gemm", x).unwrap().output.unwrap();
    assert!(a.allclose(&b, 1e-4));
    assert_eq!(c.backends(), vec!["gemm".to_string(), "sliding".to_string()]);
    c.shutdown();
}

/// Failure injection: a backend whose factory fails must answer every
/// request with an error instead of hanging or panicking the router.
#[test]
fn failing_backend_factory_reports_errors() {
    let spec = BackendSpec::from_factory("broken", vec![1, 28, 28], |_replica| {
        swconv::bail!("injected construction failure")
    });
    let c = Coordinator::new(vec![spec], BatchPolicy::default());
    let r = c.infer("broken", Tensor::zeros(&[1, 28, 28])).unwrap();
    match r.output {
        Err(InferError::Backend(msg)) => assert!(msg.contains("injected")),
        other => panic!("expected backend error, got {other:?}"),
    }
    c.shutdown();
}

/// Failure injection: a backend that errors per-batch answers all batch
/// members with the error and keeps serving later requests.
#[test]
fn erroring_backend_answers_every_request() {
    struct Flaky {
        calls: usize,
    }
    impl Backend for Flaky {
        fn name(&self) -> &str {
            "flaky"
        }
        fn item_shape(&self) -> &[usize] {
            &[2]
        }
        fn infer(&mut self, batch: &Tensor) -> swconv::error::Result<Tensor> {
            self.calls += 1;
            if self.calls == 1 {
                swconv::bail!("transient failure");
            }
            Ok(batch.clone())
        }
    }
    let spec =
        BackendSpec::from_factory("flaky", vec![2], |_replica| Ok(Box::new(Flaky { calls: 0 })));
    let c = Coordinator::new(
        vec![spec],
        BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
    );
    let r1 = c.infer("flaky", Tensor::zeros(&[2])).unwrap();
    assert!(matches!(r1.output, Err(InferError::Backend(_))));
    let r2 = c.infer("flaky", Tensor::full(&[2], 3.0)).unwrap();
    assert_eq!(r2.output.unwrap().as_slice(), &[3.0, 3.0]);
    c.shutdown();
}

/// REGRESSION — a panic inside `Backend::infer` used to kill the worker
/// loop for good: the panicking batch hung and every later submit
/// surfaced as a misleading `Shutdown`. The serving path must instead
/// answer the batch with `InferError::Backend` and keep the replica
/// alive for subsequent requests.
#[test]
fn panicking_backend_keeps_serving() {
    struct PanicOnce {
        calls: usize,
    }
    impl Backend for PanicOnce {
        fn name(&self) -> &str {
            "panic-once"
        }
        fn item_shape(&self) -> &[usize] {
            &[2]
        }
        fn infer(&mut self, batch: &Tensor) -> swconv::error::Result<Tensor> {
            self.calls += 1;
            if self.calls == 1 {
                panic!("deliberate test panic");
            }
            Ok(batch.clone())
        }
    }
    let spec = BackendSpec::from_factory("panicky", vec![2], |_replica| {
        Ok(Box::new(PanicOnce { calls: 0 }))
    });
    let c = Coordinator::new(
        vec![spec],
        BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
    );
    let r1 = c.infer("panicky", Tensor::zeros(&[2])).unwrap();
    match r1.output {
        Err(InferError::Backend(msg)) => {
            assert!(msg.contains("panicked"), "error should name the panic: {msg}");
            assert!(msg.contains("deliberate test panic"), "payload lost: {msg}");
        }
        other => panic!("expected backend error, got {other:?}"),
    }
    // The queue is not wedged: the next request succeeds on the same
    // replica (this used to error with Shutdown).
    let r2 = c.infer("panicky", Tensor::full(&[2], 5.0)).unwrap();
    assert_eq!(r2.output.unwrap().as_slice(), &[5.0, 5.0]);
    c.shutdown();
}

/// REGRESSION — a backend returning the wrong output batch dimension
/// used to slice-panic (too few rows) or silently mis-route rows (too
/// many); the worker must turn it into a per-request error and survive.
#[test]
fn wrong_output_batch_dim_is_an_error_not_a_panic() {
    struct BadDim;
    impl Backend for BadDim {
        fn name(&self) -> &str {
            "bad-dim"
        }
        fn item_shape(&self) -> &[usize] {
            &[2]
        }
        fn infer(&mut self, batch: &Tensor) -> swconv::error::Result<Tensor> {
            // One row too many, whatever the batch size.
            Ok(Tensor::zeros(&[batch.dim(0) + 1, 2]))
        }
    }
    let spec = BackendSpec::from_factory("bad-dim", vec![2], |_replica| Ok(Box::new(BadDim)));
    let c = Coordinator::new(
        vec![spec],
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
    );
    for _ in 0..2 {
        let r = c.infer("bad-dim", Tensor::zeros(&[2])).unwrap();
        match r.output {
            Err(InferError::Backend(msg)) => {
                assert!(msg.contains("batch of"), "should describe the mismatch: {msg}")
            }
            other => panic!("expected backend error, got {other:?}"),
        }
    }
    c.shutdown();
}

/// Echo backend: batch stacking and splitting round-trips every item
/// bit-exactly in order.
#[test]
fn batch_split_preserves_item_identity_and_order() {
    let spec = BackendSpec::from_factory("echo", vec![3], |_replica| Ok(Box::new(Echo)));
    let c = Coordinator::new(
        vec![spec],
        BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(5) },
    );
    let rxs: Vec<_> = (0..32)
        .map(|i| {
            let t = Tensor::full(&[3], i as f32);
            c.submit("echo", t).unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let out = rx.recv().unwrap().output.unwrap();
        assert_eq!(out.as_slice(), &[i as f32; 3], "item {i} mangled");
    }
    c.shutdown();
}

/// Echo sharded: the round-trip identity also holds when the batch is
/// scattered across replicas.
#[test]
fn sharded_echo_preserves_item_identity() {
    let spec = BackendSpec::from_factory("echo", vec![3], |_replica| Ok(Box::new(Echo)))
        .with_replicas(4);
    let c = Coordinator::new(
        vec![spec],
        BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(5) },
    );
    let rxs: Vec<_> = (0..64)
        .map(|i| c.submit("echo", Tensor::full(&[3], i as f32)).unwrap())
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let out = rx.recv().unwrap().output.unwrap();
        assert_eq!(out.as_slice(), &[i as f32; 3], "item {i} mangled by sharding");
    }
    c.shutdown();
}

/// Shape validation is synchronous and precise.
#[test]
fn shape_validation() {
    let c = coord(2, 1);
    match c.infer("sliding", Tensor::zeros(&[28, 28])) {
        Err(InferError::BadShape { expected, got }) => {
            assert_eq!(expected, vec![1, 28, 28]);
            assert_eq!(got, vec![28, 28]);
        }
        other => panic!("expected BadShape, got {other:?}"),
    }
    c.shutdown();
}
