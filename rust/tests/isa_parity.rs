//! Exhaustive scalar-vs-intrinsic parity for the explicit SIMD
//! microkernels: every [`RowKernel`] family × every [`IsaLevel`] across
//! the full supported width range `k = 1..=COMPOUND_MAX_K`, on odd
//! plane widths (tail lanes), widths below one vector, and strided
//! convs — forced through the explicit-ISA dispatch seams.
//!
//! The invariant under test: the ISA level is a *speed* knob, never an
//! accuracy knob. Every f32 kernel preserves the portable path's
//! per-element ascending-tap fused-FMA order, so results are
//! bit-identical (`assert_eq!`, not a tolerance) at every level; int8
//! accumulation is exact integer arithmetic; bf16 replicates the
//! portable non-fused widening order bitwise. Levels this machine
//! cannot execute degrade to the portable kernel inside the dispatch
//! ([`RowKernel::row_fn_at`] is total), so this suite passes — and
//! still exercises every match arm — on any host.

mod common;

use common::{assert_bitwise, assert_exact_i32, assert_slices_bitwise, lcg_f32};
use swconv::exec::ExecCtx;
use swconv::kernels::rowconv::{row_conv_bf16_at, row_conv_q8_at, RowKernel, COMPOUND_MAX_K};
use swconv::kernels::sliding2d::{conv2d_sliding_bf16_ctx, conv2d_sliding_q8_raw_ctx};
use swconv::kernels::{conv2d_ctx, Conv2dParams, ConvAlgo};
use swconv::simd::{IsaLevel, LANES};
use swconv::tensor::{quantize, to_bf16, Bf16, QuantParams, Tensor};

/// Output widths covering the awkward cases: empty, sub-vector (< 4,
/// < 8, < 16 lanes), exactly one portable vector, one-past, odd tails
/// at every lane count, and a multi-vector run.
const WIDTHS: [usize; 10] = [0, 1, 3, 7, 15, 16, 17, 31, 40, 100];

/// Source rows long enough for the widest (k, width) pair under the
/// strictest kernel contract (`width - 1 + k - 1 + 2·LANES + 1`).
fn f32_src() -> Vec<f32> {
    let mut seed = 11;
    (0..COMPOUND_MAX_K + 100 + 2 * LANES + 8).map(|_| lcg_f32(&mut seed)).collect()
}

/// BIT PARITY (f32 rows) — every family × every level × every width
/// `1..=COMPOUND_MAX_K` × every odd output width is bit-identical to
/// the same family at `IsaLevel::Scalar` (the portable kernels).
#[test]
fn f32_row_kernels_bit_identical_at_every_level() {
    let src = f32_src();
    let mut seed = 12;
    for k in 1..=COMPOUND_MAX_K {
        let w: Vec<f32> = (0..k).map(|_| lcg_f32(&mut seed)).collect();
        for family in [RowKernel::Custom, RowKernel::Generic, RowKernel::Compound] {
            let reference = family.row_fn_at(k, IsaLevel::Scalar);
            for width in WIDTHS {
                // Non-zero prefill: the contract accumulates into dst,
                // so a kernel that overwrites instead of adding fails.
                let mut want = vec![0.5f32; width];
                reference(&src, &w, &mut want, width);
                for isa in IsaLevel::ALL {
                    let mut got = vec![0.5f32; width];
                    family.row_fn_at(k, isa)(&src, &w, &mut got, width);
                    assert_slices_bitwise(
                        &got,
                        &want,
                        &format!("{family:?} k={k} width={width} {isa}"),
                    );
                }
            }
        }
    }
}

/// EXACTNESS (int8 rows) — every level matches a freshly written naive
/// i32-accumulation reference exactly (not just the portable kernel:
/// this catches a portable bug replicated into the intrinsics).
#[test]
fn q8_row_kernel_exact_at_every_level() {
    let mut seed = 13;
    let src: Vec<i8> = (0..COMPOUND_MAX_K + 100 + 2 * LANES + 8)
        .map(|_| (lcg_f32(&mut seed) * 127.0) as i8)
        .collect();
    for k in [1usize, 2, 3, 5, 8, 9, 16, 17, 33, 64] {
        let w: Vec<i8> = (0..k).map(|_| (lcg_f32(&mut seed) * 127.0) as i8).collect();
        for width in WIDTHS {
            // Naive reference with the same accumulate-into contract.
            let mut want = vec![7i32; width];
            for (i, d) in want.iter_mut().enumerate() {
                let mut acc = 0i32;
                for (j, &wj) in w.iter().enumerate() {
                    acc += wj as i32 * src[i + j] as i32;
                }
                *d += acc;
            }
            for isa in IsaLevel::ALL {
                let mut got = vec![7i32; width];
                row_conv_q8_at(isa)(&src, &w, &mut got, width);
                assert_slices_bitwise(&got, &want, &format!("q8 k={k} width={width} {isa}"));
            }
        }
    }
}

/// BIT PARITY (bf16 rows) — every level reproduces the portable bf16
/// kernel's f32 row accumulator bitwise (the portable path is
/// deliberately non-fused; intrinsics must replicate that order).
#[test]
fn bf16_row_kernel_bitwise_at_every_level() {
    let mut seed = 14;
    let src: Vec<Bf16> = (0..COMPOUND_MAX_K + 100 + 2 * LANES + 8)
        .map(|_| Bf16::from_f32(lcg_f32(&mut seed)))
        .collect();
    for k in [1usize, 2, 3, 5, 9, 16, 17, 33, 64] {
        let w: Vec<f32> = (0..k).map(|_| lcg_f32(&mut seed)).collect();
        let reference = row_conv_bf16_at(IsaLevel::Scalar);
        for width in WIDTHS {
            let mut want = vec![0.5f32; width];
            reference(&src, &w, &mut want, width);
            for isa in IsaLevel::ALL {
                let mut got = vec![0.5f32; width];
                row_conv_bf16_at(isa)(&src, &w, &mut got, width);
                assert_slices_bitwise(&got, &want, &format!("bf16 k={k} width={width} {isa}"));
            }
        }
    }
}

/// Conv geometries covering every dispatch family plus the awkward
/// plane shapes: sub-vector plane width, stride 2, grouped, and a wide
/// filter that routes to the compound kernel.
fn conv_cases() -> Vec<(Vec<usize>, Vec<usize>, Conv2dParams)> {
    vec![
        // Custom k=3 on an even plane.
        (vec![1, 3, 12, 20], vec![4, 3, 3, 3], Conv2dParams::same(3)),
        // Plane narrower than one portable vector (width 7 < LANES).
        (vec![1, 2, 7, 7], vec![2, 2, 3, 3], Conv2dParams::same(3)),
        // Stride 2 + groups: strided reads from the row accumulator.
        (
            vec![1, 4, 12, 14],
            vec![4, 1, 5, 5],
            Conv2dParams { stride: (2, 2), pad: (2, 2), groups: 4 },
        ),
        // Generic k=9 on an odd plane width.
        (vec![1, 2, 10, 21], vec![3, 2, 9, 9], Conv2dParams::same(9)),
        // Compound k=19 (> GENERIC_MAX_K) row filter.
        (vec![1, 1, 8, 40], vec![2, 1, 3, 19], Conv2dParams::default()),
    ]
}

/// END TO END (f32) — a full sliding conv forced to each level via
/// [`ExecCtx::with_isa`] is bit-identical to the scalar-forced run at
/// every tested thread count (the threading axis must not perturb the
/// per-ISA parity, and vice versa).
#[test]
fn conv2d_forced_isa_bit_identical_across_levels_and_threads() {
    for (i, (xd, wd, p)) in conv_cases().iter().enumerate() {
        let x = Tensor::randn(xd, 900 + i as u64);
        let w = Tensor::randn(wd, 910 + i as u64);
        let reference_ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 1).with_isa(IsaLevel::Scalar);
        let want = conv2d_ctx(&x, &w, None, p, &reference_ctx);
        for threads in [1usize, 2, 4] {
            for isa in IsaLevel::ALL {
                let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads).with_isa(isa);
                let got = conv2d_ctx(&x, &w, None, p, &ctx);
                assert_bitwise(&got, &want, &format!("case {i} threads={threads} {isa}"));
            }
        }
    }
}

/// END TO END (int8) — the raw i32 accumulator conv matches the
/// scalar-forced run exactly at every level × thread count.
#[test]
fn conv2d_q8_forced_isa_exact_across_levels_and_threads() {
    let x = Tensor::randn(&[1, 2, 10, 21], 920);
    let w = Tensor::randn(&[3, 2, 3, 3], 921);
    let qx = quantize(&x, QuantParams::for_tensor(&x));
    let qw = quantize(&w, QuantParams::for_tensor(&w));
    let p = Conv2dParams::same(3);
    let reference_ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 1).with_isa(IsaLevel::Scalar);
    let want = conv2d_sliding_q8_raw_ctx(&qx, &qw, &p, &reference_ctx);
    for threads in [1usize, 2, 4] {
        for isa in IsaLevel::ALL {
            let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads).with_isa(isa);
            let got = conv2d_sliding_q8_raw_ctx(&qx, &qw, &p, &ctx);
            assert_exact_i32(&got, &want, &format!("q8 threads={threads} {isa}"));
        }
    }
}

/// END TO END (bf16) — the bf16 conv matches the scalar-forced run
/// bitwise at every level × thread count.
#[test]
fn conv2d_bf16_forced_isa_bitwise_across_levels_and_threads() {
    let x = to_bf16(&Tensor::randn(&[1, 2, 9, 19], 930));
    let w = to_bf16(&Tensor::randn(&[2, 2, 5, 5], 931));
    let p = Conv2dParams::same(5);
    let reference_ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 1).with_isa(IsaLevel::Scalar);
    let want = conv2d_sliding_bf16_ctx(&x, &w, None, &p, &reference_ctx);
    for threads in [1usize, 2, 4] {
        for isa in IsaLevel::ALL {
            let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads).with_isa(isa);
            let got = conv2d_sliding_bf16_ctx(&x, &w, None, &p, &ctx);
            assert_slices_bitwise(
                got.as_slice(),
                want.as_slice(),
                &format!("bf16 threads={threads} {isa}"),
            );
        }
    }
}
