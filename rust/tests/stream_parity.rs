//! Tentpole parity suite for streaming inference: a frame-by-frame
//! [`StreamSession`] must reproduce its own one-shot batch reference
//! (`run_batch` — the same kernels, same frozen scales) under every
//! serving configuration:
//!
//! - **i8**: bit-for-bit. The edge-audio chain is avg-pool-free, so
//!   quantization is pointwise and the i32 accumulation is
//!   order-independent — `is_bit_exact()` promises zero ulps and the
//!   suite holds it to that.
//! - **f32 / bf16**: within the session's *derived* tolerance
//!   ([`StreamSession::tolerance`] — composed per-stage bounds, never
//!   an eyeballed epsilon).
//!
//! The matrix covers both conv algorithms × three thread counts × every
//! ISA level (forced through the same `ExecCtx` seam as the batch
//! suites), warmup-frame behaviour, the full nominal 512-sample window,
//! and an ad-hoc avg-pool chain exercising the tolerance path.

mod common;

use common::{assert_bitwise, assert_within};
use swconv::kernels::{Conv2dParams, ConvAlgo, PoolParams};
use swconv::nn::layers::{AvgPool2d, Conv2d, ReLU};
use swconv::nn::{zoo, ExecCtx, Model};
use swconv::simd::IsaLevel;
use swconv::stream::StreamSession;
use swconv::tensor::{Dtype, Tensor};

/// A mono signal `[1, 1, 1, l]` for the edge-audio model.
fn audio(l: usize, seed: u64) -> Tensor {
    Tensor::randn(&[1, 1, 1, l], seed)
}

/// Stream the whole signal through `sess` (advance every column, then
/// flush) and pack the emitted columns into `[1, c_out, 1, t]` for
/// comparison against the batch reference.
fn stream_all(sess: &mut StreamSession, x: &Tensor) -> Tensor {
    let c = x.dim(1);
    let l = x.dim(3);
    let mut cols = Vec::new();
    for t in 0..l {
        let frame: Vec<f32> = (0..c).map(|ch| x.at4(0, ch, 0, t)).collect();
        if let Some(col) = sess.advance(&frame) {
            cols.push(col);
        }
    }
    cols.extend(sess.flush());
    let c_out = sess.out_channels();
    let t_out = cols.len();
    let mut data = vec![0.0f32; c_out * t_out];
    for (t, col) in cols.iter().enumerate() {
        for (ch, &v) in col.iter().enumerate() {
            data[ch * t_out + t] = v;
        }
    }
    Tensor::from_vec(data, &[1, c_out, 1, t_out])
}

/// BIT PARITY (i8) — streamed output equals the batch reference to the
/// last bit under both conv algorithms and every thread count. The
/// algorithm and threading axes route different kernels/partitions
/// underneath, but integer accumulation has one right answer.
#[test]
fn i8_streamed_bitwise_equals_batch_across_algos_and_threads() {
    let model = zoo::edge_audio(4, 42);
    let x = audio(160, 11);
    for algo in [ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
        for threads in [1usize, 2, 4] {
            let ctx = ExecCtx::with_threads(algo, threads).with_dtype(Dtype::I8);
            let mut sess = StreamSession::new(&model, ctx).unwrap();
            assert!(sess.is_bit_exact(), "edge-audio i8 chain must be bit-exact");
            let got = stream_all(&mut sess, &x);
            let want = sess.run_batch(&x);
            assert_bitwise(&got, &want, &format!("i8 {algo:?} threads={threads}"));
        }
    }
}

/// DERIVED TOLERANCE (f32 / bf16) — streamed output tracks the batch
/// reference within the session's composed per-stage bound under both
/// conv algorithms and every thread count.
#[test]
fn f32_and_bf16_streamed_within_derived_tolerance_across_algos_and_threads() {
    let model = zoo::edge_audio(4, 42);
    let x = audio(160, 12);
    for dtype in [Dtype::F32, Dtype::Bf16] {
        for algo in [ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            for threads in [1usize, 2, 4] {
                let ctx = ExecCtx::with_threads(algo, threads).with_dtype(dtype);
                let mut sess = StreamSession::new(&model, ctx).unwrap();
                let got = stream_all(&mut sess, &x);
                let want = sess.run_batch(&x);
                // tolerance() uses actual push counts: derive it after
                // streaming, per its contract.
                let tol = sess.tolerance();
                assert_within(&got, &want, tol, &format!("{dtype:?} {algo:?} threads={threads}"));
            }
        }
    }
}

/// ISA INVARIANCE — the ISA level is a speed knob for streaming too:
/// forcing each level produces bit-identical streamed outputs, and the
/// i8 batch parity holds at every level (levels this machine lacks
/// degrade to the portable kernels inside dispatch, so this passes —
/// and still exercises every arm — on any host).
#[test]
fn forced_isa_levels_do_not_perturb_streamed_outputs() {
    let model = zoo::edge_audio(4, 42);
    let x = audio(96, 13);
    for dtype in [Dtype::F32, Dtype::I8] {
        let scalar = ExecCtx::new(ConvAlgo::Sliding).with_isa(IsaLevel::Scalar).with_dtype(dtype);
        let mut reference = StreamSession::new(&model, scalar).unwrap();
        let want = stream_all(&mut reference, &x);
        for isa in IsaLevel::ALL {
            let ctx = ExecCtx::new(ConvAlgo::Sliding).with_isa(isa).with_dtype(dtype);
            let mut sess = StreamSession::new(&model, ctx).unwrap();
            let got = stream_all(&mut sess, &x);
            assert_bitwise(&got, &want, &format!("{dtype:?} {isa} vs scalar"));
            if sess.is_bit_exact() {
                assert_bitwise(&got, &sess.run_batch(&x), &format!("i8 {isa} vs batch"));
            }
        }
    }
}

/// WARMUP — the first frames fill windows and must emit nothing; once
/// columns start flowing, every one (flush included) matches its batch
/// counterpart bitwise, and the total count equals the batch output
/// width.
#[test]
fn warmup_frames_emit_none_then_every_column_matches_batch() {
    let model = zoo::edge_audio(4, 42);
    let x = audio(64, 14);
    let ctx = ExecCtx::new(ConvAlgo::Sliding).with_dtype(Dtype::I8);
    let mut sess = StreamSession::new(&model, ctx).unwrap();
    let want = sess.run_batch(&x);
    let t_out = want.dim(3);
    let mut cols = Vec::new();
    let mut warmup = 0usize;
    for t in 0..x.dim(3) {
        match sess.advance(&[x.at4(0, 0, 0, t)]) {
            Some(col) => cols.push(col),
            None if cols.is_empty() => warmup += 1,
            None => {} // stride swallowed an interior frame
        }
    }
    assert!(warmup > 0, "the leading frames must warm the windows up");
    cols.extend(sess.flush());
    assert_eq!(cols.len(), t_out, "streamed column count vs batch width");
    assert_eq!(sess.frames_out(), t_out);
    for (t, col) in cols.iter().enumerate() {
        for (c, &v) in col.iter().enumerate() {
            assert_eq!(
                v.to_bits(),
                want.at4(0, c, 0, t).to_bits(),
                "column {t} channel {c} diverges from the batch reference"
            );
        }
    }
}

/// FULL WINDOW — the nominal 512-sample edge-audio window streams
/// bit-exactly in i8 and lands on the documented `[1, classes, 1, 64]`
/// 8×-downsampled logit track.
#[test]
fn full_nominal_window_streams_bit_exact_in_i8() {
    let model = zoo::edge_audio(6, 7);
    let ctx = ExecCtx::new(ConvAlgo::Sliding).with_dtype(Dtype::I8);
    let mut sess = StreamSession::new(&model, ctx).unwrap();
    let x = audio(sess.input_len(), 16);
    let got = stream_all(&mut sess, &x);
    assert_eq!(got.dims(), &[1, 6, 1, 64], "8x-downsampled logit track");
    assert_bitwise(&got, &sess.run_batch(&x), "full 512-frame window, i8");
}

/// REFERENCE ANCHOR — in f32 the session's `run_batch` performs exactly
/// the compiled plan's kernel calls, so it is bitwise-equal to
/// `model.compile().run` under the same ctx. This pins the streamed
/// comparisons above to the real batch path, not a lookalike.
#[test]
fn f32_run_batch_is_bitwise_the_compiled_plan() {
    let model = zoo::edge_audio(4, 42);
    let x = audio(512, 15);
    let ctx = ExecCtx::new(ConvAlgo::Sliding);
    let sess = StreamSession::new(&model, ctx.clone()).unwrap();
    let want = model.compile().run(&x, &ctx);
    assert_bitwise(&sess.run_batch(&x), &want, "run_batch vs compiled plan");
}

/// AVG-POOL — the running-sum recurrence reassociates f32 sums, so an
/// avg-pool chain is *not* bit-exact; it must still land inside the
/// derived tolerance, and the session must not overclaim exactness.
#[test]
fn avg_pool_chain_streams_within_tolerance_but_is_not_bit_exact() {
    let w = Tensor::randn(&[4, 2, 1, 5], 921).map(|v| v * 0.4);
    let model = Model::new("avg-stream", &[2, 1, 48])
        .push(Conv2d {
            w,
            bias: vec![0.05, -0.02, 0.0, 0.03],
            params: Conv2dParams { stride: (1, 1), pad: (0, 2), groups: 1 },
        })
        .push(ReLU)
        .push(AvgPool2d(PoolParams { k: (1, 4), stride: (1, 2), pad: (0, 0) }));
    let x = Tensor::randn(&[1, 2, 1, 48], 17);
    let mut sess = StreamSession::new(&model, ExecCtx::new(ConvAlgo::Sliding)).unwrap();
    assert!(!sess.is_bit_exact(), "avg-pool must disqualify bit-exactness");
    let got = stream_all(&mut sess, &x);
    let want = sess.run_batch(&x);
    let tol = sess.tolerance();
    assert_within(&got, &want, tol, "avg-pool chain");
}
