//! The process-global ISA force (`--isa`), in its own integration
//! binary on purpose: cargo gives each integration-test file its own
//! process, and this is the only test in it — so pinning the global
//! level can never leak into another test's `IsaLevel::effective()`
//! resolution (inside the lib-test process it would silently pin every
//! subsequently built `ExecCtx` to scalar). Referenced from the NOTE in
//! `simd::isa`'s unit tests, which only cover the rejection path.

use swconv::exec::ExecCtx;
use swconv::kernels::{conv2d_ctx, Conv2dParams, ConvAlgo};
use swconv::simd::IsaLevel;
use swconv::tensor::Tensor;

/// Forcing the always-available scalar level succeeds, wins over
/// detection in [`IsaLevel::effective`], seeds fresh `ExecCtx`s, and
/// the forced ctx computes the same bytes as an explicitly scalar one.
#[test]
fn forcing_scalar_pins_effective_level_and_fresh_ctxs() {
    assert!(IsaLevel::forced().is_none(), "no force at process start");
    IsaLevel::force(IsaLevel::Scalar).expect("scalar is always available");
    assert_eq!(IsaLevel::forced(), Some(IsaLevel::Scalar));
    assert_eq!(IsaLevel::effective(), IsaLevel::Scalar);

    // A ctx built *after* the force inherits it (the `--isa` flow:
    // main() forces the level before any ctx exists).
    let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 2);
    assert_eq!(ctx.isa(), IsaLevel::Scalar);

    // And the forced ctx computes exactly what an explicit scalar
    // override computes.
    let x = Tensor::randn(&[1, 2, 8, 18], 940);
    let w = Tensor::randn(&[2, 2, 3, 3], 941);
    let p = Conv2dParams::same(3);
    let explicit = ExecCtx::with_threads(ConvAlgo::Sliding, 2).with_isa(IsaLevel::Scalar);
    let a = conv2d_ctx(&x, &w, None, &p, &ctx);
    let b = conv2d_ctx(&x, &w, None, &p, &explicit);
    assert_eq!(a.as_slice(), b.as_slice());

    // Re-forcing to another *available* level still works (the knob is
    // settable more than once; last force wins).
    IsaLevel::force(IsaLevel::detected()).expect("detected level is available");
    assert_eq!(IsaLevel::effective(), IsaLevel::detected());
}
