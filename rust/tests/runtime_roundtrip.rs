//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; every test is skipped (with a
//! note) when `artifacts/manifest.json` is absent so `cargo test` stays
//! green on a fresh checkout.

use swconv::kernels::{conv2d, Conv2dParams, ConvAlgo};
use swconv::nn::{zoo, ExecCtx};
use swconv::runtime::Engine;
use swconv::tensor::Tensor;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_loads_and_compiles_everything() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e = Engine::new(&dir).expect("engine");
    let n = e.load_all().expect("compile all");
    assert!(n >= 8, "expected >= 8 artifacts, got {n}");
    assert_eq!(e.platform(), "cpu");
}

#[test]
fn conv2d_artifacts_match_native_kernels() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e = Engine::new(&dir).expect("engine");
    let specs: Vec<_> = e.manifest().of_kind("conv2d").into_iter().cloned().collect();
    assert!(!specs.is_empty());
    for spec in specs {
        let x = Tensor::rand_uniform(&spec.inputs[0], -1.0, 1.0, 21);
        let w = Tensor::rand_uniform(&spec.inputs[1], -1.0, 1.0, 22);
        let y = e.execute(&spec.name, &[&x, &w]).expect("execute");
        let k = spec.inputs[1][2];
        let p = Conv2dParams::with_pad(k / 2, k / 2);
        for algo in [ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            let native = conv2d(&x, &w, None, &p, algo);
            let d = y.max_abs_diff(&native);
            assert!(d < 1e-3, "{} vs {:?}: {d}", spec.name, algo);
        }
    }
}

#[test]
fn model_artifact_matches_native_model_on_shared_weights() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e = Engine::new(&dir).expect("engine");
    let model = zoo::simple_cnn_from_weights_file(dir.join("simple_cnn_weights.bin"), 10)
        .expect("weights");
    let x = Tensor::rand_uniform(&[8, 1, 28, 28], -1.0, 1.0, 33);
    let y_pjrt = e.execute("model_simple_cnn_sliding_b8", &[&x]).expect("pjrt");
    let y_native = model.forward(&x, &ExecCtx::new(ConvAlgo::Sliding));
    let d = y_pjrt.max_abs_diff(&y_native);
    assert!(d < 1e-4, "pjrt vs native diverge: {d}");
}

#[test]
fn sliding_and_gemm_model_artifacts_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e = Engine::new(&dir).expect("engine");
    let x = Tensor::rand_uniform(&[8, 1, 28, 28], -1.0, 1.0, 34);
    let a = e.execute("model_simple_cnn_sliding_b8", &[&x]).expect("sliding");
    let b = e.execute("model_simple_cnn_gemm_b8", &[&x]).expect("gemm");
    let d = a.max_abs_diff(&b);
    assert!(d < 1e-4, "artifact algos diverge: {d}");
}

#[test]
fn execute_rejects_wrong_shapes_and_names() {
    let Some(dir) = artifacts_dir() else { return };
    let mut e = Engine::new(&dir).expect("engine");
    let bad = Tensor::zeros(&[1, 1, 28, 28]);
    assert!(e.execute("model_simple_cnn_sliding_b8", &[&bad]).is_err());
    assert!(e.execute("model_simple_cnn_sliding_b8", &[]).is_err());
    assert!(e.execute("no_such_artifact", &[&bad]).is_err());
}
