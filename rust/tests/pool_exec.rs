//! Integration tests for the persistent worker pool: lifecycle
//! (drop joins, panic poisons one region only, nesting runs inline) and
//! the acceptance gate that pooled execution is bit-identical to the
//! scoped-thread seed behaviour for every algorithm at every thread
//! count.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use swconv::exec::{ExecCtx, WorkerPool};
use swconv::kernels::{conv2d_ctx, Conv2dParams, ConvAlgo};
use swconv::nn::zoo;
use swconv::tensor::Tensor;

/// ACCEPTANCE — for every `ConvAlgo` at every tested thread count, a
/// pooled ctx and a scoped (`without_pool`) ctx produce bit-identical
/// conv outputs, and both match the single-threaded seed result.
#[test]
fn pooled_and_scoped_convs_bit_identical_for_every_algo() {
    let x = Tensor::randn(&[2, 3, 20, 22], 1000);
    let w = Tensor::randn(&[6, 3, 5, 5], 1001);
    let bias: Vec<f32> = (0..6).map(|i| 0.05 * i as f32).collect();
    let p = Conv2dParams::same(5);
    for algo in ConvAlgo::ALL {
        let seed = {
            let one = ExecCtx::with_threads(algo, 1).without_pool();
            conv2d_ctx(&x, &w, Some(&bias), &p, &one)
        };
        for threads in [1usize, 2, 7] {
            let scoped = ExecCtx::with_threads(algo, threads).without_pool();
            let ys = conv2d_ctx(&x, &w, Some(&bias), &p, &scoped);
            assert_eq!(
                seed.as_slice(),
                ys.as_slice(),
                "{algo:?} threads={threads}: scoped != single-threaded seed"
            );
            // An explicitly attached pool of `threads` workers…
            let pooled = ExecCtx::with_threads(algo, threads).with_pool(WorkerPool::new(threads));
            let yp = conv2d_ctx(&x, &w, Some(&bias), &p, &pooled);
            assert_eq!(
                seed.as_slice(),
                yp.as_slice(),
                "{algo:?} threads={threads}: pooled != scoped seed"
            );
            // …and the default (lazily resolved) path, whatever it is
            // under the current SWCONV_NO_POOL setting.
            let default_ctx = ExecCtx::with_threads(algo, threads);
            let yd = conv2d_ctx(&x, &w, Some(&bias), &p, &default_ctx);
            assert_eq!(seed.as_slice(), yd.as_slice(), "{algo:?} threads={threads}: default path");
        }
    }
}

/// Pool workers of sizes {1, 2, 7} all reproduce the scoped seed on a
/// whole-model forward (the serving configuration).
#[test]
fn model_forward_bit_identical_across_pool_sizes() {
    let m = zoo::simple_cnn(10, 7);
    let x = Tensor::randn(&[3, 1, 28, 28], 1010);
    let seed = m.forward(&x, &ExecCtx::with_threads(ConvAlgo::Sliding, 4).without_pool());
    for workers in [1usize, 2, 7] {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4).with_pool(WorkerPool::new(workers));
        let y = m.forward(&x, &ctx);
        assert_eq!(seed.as_slice(), y.as_slice(), "pool of {workers} workers diverged");
    }
}

/// LIFECYCLE — dropping the last pool handle joins every worker thread:
/// the live count is exactly zero right after `drop`, with no grace
/// period.
#[test]
fn dropping_the_pool_joins_its_workers() {
    let pool = WorkerPool::new(4);
    let probe = pool.live_workers_probe();
    // Construction waits (bounded) for startup; allow a loaded CI box a
    // little longer before asserting all four workers are live.
    let t0 = std::time::Instant::now();
    while probe.load(Ordering::Acquire) < 4 && t0.elapsed().as_secs() < 5 {
        std::thread::yield_now();
    }
    assert_eq!(pool.live_workers(), 4, "workers are up before first use");
    // Give the ctx a handle too: the pool must survive until the *last*
    // handle is gone.
    let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 5).with_pool(pool);
    let mut data = vec![0.0f32; 10];
    ctx.par_chunks(&mut data, 2, |i, c| c.fill(i as f32));
    assert_eq!(probe.load(Ordering::Acquire), 4, "ctx handle keeps workers alive");
    drop(ctx);
    assert_eq!(probe.load(Ordering::Acquire), 0, "drop must join every worker");
}

/// LIFECYCLE — a panic in one chunk fails that region's caller and only
/// it: earlier regions' results stand, the workers survive, and the
/// same ctx serves later regions.
#[test]
fn chunk_panic_poisons_region_and_pool_survives() {
    let pool = WorkerPool::new(2);
    let probe = pool.live_workers_probe();
    let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 3).with_pool(pool);
    let x = Tensor::randn(&[1, 2, 12, 12], 1020);
    let w = Tensor::randn(&[4, 2, 3, 3], 1021);
    let p = Conv2dParams::same(3);
    let before = conv2d_ctx(&x, &w, None, &p, &ctx);

    let mut data = vec![0.0f32; 12];
    let poisoned = catch_unwind(AssertUnwindSafe(|| {
        ctx.par_chunks(&mut data, 1, |i, _c| {
            if i == 7 {
                panic!("item 7 exploded");
            }
        });
    }));
    assert!(poisoned.is_err(), "the panic must surface on the submitter");
    assert_eq!(probe.load(Ordering::Acquire), 2, "workers must survive a region panic");

    let after = conv2d_ctx(&x, &w, None, &p, &ctx);
    assert_eq!(before.as_slice(), after.as_slice(), "pool must keep serving correctly");
}

/// LIFECYCLE — nested parallel regions (a ctx used from inside another
/// ctx's chunk body) complete without deadlock: the inner region runs
/// inline on the pool worker.
#[test]
fn nested_regions_from_pool_workers_do_not_deadlock() {
    let outer = ExecCtx::with_threads(ConvAlgo::Sliding, 4).with_pool(WorkerPool::new(3));
    let inner = ExecCtx::with_threads(ConvAlgo::Sliding, 4).with_pool(WorkerPool::new(3));
    let x = Tensor::randn(&[1, 2, 10, 10], 1030);
    let w = Tensor::randn(&[2, 2, 3, 3], 1031);
    let p = Conv2dParams::same(3);
    let expect = conv2d_ctx(&x, &w, None, &p, &inner);

    let mut out: Vec<f32> = vec![0.0; 8 * expect.as_slice().len()];
    let chunk = expect.as_slice().len();
    outer.par_chunks(&mut out, chunk, |_i, c| {
        // A full convolution from inside a chunk: its own parallel
        // region must run inline on this worker, not re-enter a pool.
        let y = conv2d_ctx(&x, &w, None, &p, &inner);
        c.copy_from_slice(y.as_slice());
    });
    for i in 0..8 {
        assert_eq!(
            &out[i * chunk..(i + 1) * chunk],
            expect.as_slice(),
            "nested conv {i} diverged"
        );
    }
}

/// The arena stays allocation-free in the steady state on the pooled
/// path, exactly as it did on scoped threads.
#[test]
fn pooled_steady_state_allocates_nothing() {
    let x = Tensor::randn(&[2, 3, 32, 32], 1040);
    let w = Tensor::randn(&[8, 3, 5, 5], 1041);
    let p = Conv2dParams::same(5);
    let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4).with_pool(WorkerPool::new(3));
    let warm = conv2d_ctx(&x, &w, None, &p, &ctx);
    let after_warmup = ctx.alloc_events();
    assert!(after_warmup > 0, "warm-up must have allocated scratch");
    for _ in 0..3 {
        let y = conv2d_ctx(&x, &w, None, &p, &ctx);
        assert_eq!(y.as_slice(), warm.as_slice());
    }
    assert_eq!(ctx.alloc_events(), after_warmup, "pooled steady state must not allocate");
}
