//! Zoo-wide end-to-end coverage of the *layer* path (the graph-plan
//! counterpart lives in `graph_parity.rs`): every model × every
//! algorithm it supports agrees with the im2col+GEMM baseline within a
//! post-softmax tolerance, the threading axis is bit-exact, and the
//! reduced-precision serving dtypes stay close to f32 on every model.

mod common;

use common::{assert_bitwise, assert_within, input_for};
use swconv::kernels::ConvAlgo;
use swconv::nn::{zoo, ExecCtx};
use swconv::tensor::Dtype;

/// Forcible algorithms per model (SlidingGeneric caps at k = 17, so
/// the k = 21 net skips it; Direct — the O(k²)-per-output oracle —
/// only runs on the small nets to keep debug runs sane).
fn algos_for(name: &str) -> Vec<ConvAlgo> {
    match name {
        "simple-cnn" | "quantized-cnn" => vec![
            ConvAlgo::Direct,
            ConvAlgo::Sliding,
            ConvAlgo::SlidingGeneric,
            ConvAlgo::SlidingCompound,
            ConvAlgo::Tuned,
        ],
        "large-filter-net" => vec![ConvAlgo::Sliding, ConvAlgo::SlidingCompound],
        _ => vec![ConvAlgo::Sliding],
    }
}

/// Every model × every supported algorithm agrees with the GEMM
/// baseline after softmax (different summation orders, so a tolerance
/// rather than bit equality across *algorithms*).
#[test]
fn every_model_agrees_with_the_gemm_baseline() {
    for name in zoo::MODEL_NAMES {
        let m = zoo::by_name(name, 4, 42).unwrap();
        let x = input_for(&m, 1, 23);
        let want = m.forward(&x, &ExecCtx::new(ConvAlgo::Im2colGemm));
        for algo in algos_for(name) {
            let got = m.forward(&x, &ExecCtx::new(algo));
            assert_within(&got, &want, 1e-3, &format!("{name} {algo:?} vs gemm"));
        }
    }
}

/// Splitting work across kernel threads must never change a single
/// bit, on any model (each output row/plane keeps its serial
/// accumulation order; only ownership is partitioned).
#[test]
fn thread_counts_are_bit_identical_on_every_model() {
    for name in zoo::MODEL_NAMES {
        let m = zoo::by_name(name, 4, 42).unwrap();
        let x = input_for(&m, 2, 29);
        for algo in [ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            let want = m.forward(&x, &ExecCtx::with_threads(algo, 1));
            for threads in [2usize, 4] {
                let got = m.forward(&x, &ExecCtx::with_threads(algo, threads));
                assert_bitwise(&got, &want, &format!("{name} {algo:?} threads={threads}"));
            }
        }
    }
}

/// The bf16 and dynamic-int8 serving dtypes run every model end to end
/// and land near the f32 output (post-softmax probabilities, so the
/// scale is [0, 1] and a loose bound is meaningful — quantization
/// noise compounds through the stack but must stay bounded).
#[test]
fn serving_dtypes_run_every_model_close_to_f32() {
    for name in zoo::MODEL_NAMES {
        let m = zoo::by_name(name, 4, 42).unwrap();
        let x = input_for(&m, 1, 31);
        let want = m.forward(&x, &ExecCtx::new(ConvAlgo::Sliding));
        for dtype in [Dtype::Bf16, Dtype::I8] {
            let ctx = ExecCtx::new(ConvAlgo::Sliding).with_dtype(dtype);
            let y = m.forward(&x, &ctx);
            assert_within(&y, &want, 0.25, &format!("{name} {dtype:?} post-softmax"));
            // Rows still normalise: the reduced-precision path feeds a
            // real probability vector out, not garbage that happens to
            // be close element-wise.
            let s: f32 = y.as_slice().iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "{name} {dtype:?}: row sum {s}");
        }
    }
}
