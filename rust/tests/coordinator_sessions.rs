//! Session lifecycle through the serving coordinator: affinity keeps a
//! stream's state on one replica, failover after a quarantine or an
//! idle eviction *always* surfaces as an explicit `reset` on a fresh
//! session (never a silent continuation from stale rings), and evicted
//! sessions give their arena scratch back. The numeric anchor is the
//! same as `stream_parity.rs`: an i8 edge-audio stream served through
//! the coordinator must equal a local [`StreamSession`] bit for bit.

use std::time::Duration;
use swconv::coordinator::{
    Backend, BackendSpec, BatchPolicy, Coordinator, InferError, NativeBackend,
};
use swconv::kernels::ConvAlgo;
use swconv::nn::{zoo, ExecCtx};
use swconv::stream::StreamSession;
use swconv::tensor::{Dtype, Tensor};

fn policy() -> BatchPolicy {
    BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) }
}

/// A mono signal `[1, 1, 1, l]` for the edge-audio model.
fn audio(l: usize, seed: u64) -> Tensor {
    Tensor::randn(&[1, 1, 1, l], seed)
}

/// AFFINITY + PARITY — streams pin to one replica for their whole life,
/// interleave with batch traffic on the same tier, and (i8, avg-pool
/// free) reproduce a local session's emissions bit for bit, warmup
/// `None`s included.
#[test]
fn streams_pin_to_one_replica_and_match_a_local_session_bitwise() {
    let model = zoo::edge_audio(4, 42);
    let spec = BackendSpec::native_streaming(
        "stream",
        model.clone(),
        ExecCtx::new(ConvAlgo::Sliding),
        Duration::from_secs(60),
    )
    .with_dtype(Dtype::I8)
    .with_replicas(2);
    let c = Coordinator::new(vec![spec], policy());

    // Least-streams placement: the first stream lands on replica 0, the
    // second on replica 1.
    let h1 = c.open_stream("stream").unwrap();
    let h2 = c.open_stream("stream").unwrap();
    let r1 = c.stream_replica(&h1).unwrap();
    let r2 = c.stream_replica(&h2).unwrap();
    assert_ne!(r1, r2, "two streams should spread across two replicas");
    assert_eq!(h1.backend(), "stream");
    assert_ne!(h1.id(), h2.id());

    let reference_ctx = ExecCtx::new(ConvAlgo::Sliding).with_dtype(Dtype::I8);
    let mut reference = StreamSession::new(&model, reference_ctx).unwrap();
    assert!(reference.is_bit_exact());

    let x = audio(96, 61);
    for t in 0..x.dim(3) {
        let frame = [x.at4(0, 0, 0, t)];
        let want = reference.advance(&frame);
        for h in [&h1, &h2] {
            let f = c.advance_stream(h, &frame).unwrap();
            assert!(!f.reset, "healthy stream must never reset (frame {t})");
            assert_eq!(f.output, want, "stream {} frame {t}", h.id());
        }
        // Affinity: the owner never migrates while the replica is
        // healthy.
        assert_eq!(c.stream_replica(&h1), Some(r1), "frame {t}");
        assert_eq!(c.stream_replica(&h2), Some(r2), "frame {t}");
        if t == 48 {
            // Batch traffic interleaves with live streams on the same
            // tier (frames bypass the batcher, shards don't touch
            // session state).
            let y = c.infer("stream", Tensor::randn(&[1, 1, 512], 62)).unwrap();
            assert!(y.output.is_ok(), "batch request on a streaming tier: {:?}", y.output);
        }
    }

    c.close_stream(&h1);
    assert_eq!(c.stream_replica(&h1), None, "closed stream has no owner");
    assert!(c.advance_stream(&h1, &[0.0]).is_err(), "advance after close must error");
    // Idempotent close; the second stream is unaffected.
    c.close_stream(&h1);
    assert!(c.advance_stream(&h2, &[0.0]).is_ok());
    c.shutdown();
}

/// FAILOVER — quarantining the owner moves the stream to a healthy
/// replica with `reset = true`, and the rebuilt session starts from
/// *fresh* state: it replays a new signal exactly like a brand-new
/// local session, warmup and all. Never a silent continuation.
#[test]
fn quarantined_replica_fails_over_with_an_explicit_reset_and_fresh_state() {
    let model = zoo::edge_audio(4, 42);
    let spec = BackendSpec::native_streaming(
        "stream",
        model.clone(),
        ExecCtx::new(ConvAlgo::Sliding),
        Duration::from_secs(60),
    )
    .with_dtype(Dtype::I8)
    .with_replicas(2);
    let c = Coordinator::new(vec![spec], policy());
    let h = c.open_stream("stream").unwrap();
    let owner = c.stream_replica(&h).unwrap();

    // Stream well past warmup so the rings hold real state.
    let a = audio(48, 63);
    let mut emitted = 0usize;
    for t in 0..a.dim(3) {
        let f = c.advance_stream(&h, &[a.at4(0, 0, 0, t)]).unwrap();
        assert!(!f.reset);
        emitted += usize::from(f.output.is_some());
    }
    assert!(emitted > 0, "48 frames must emit past warmup");

    assert!(c.quarantine_replica("stream", owner));
    assert!(!c.quarantine_replica("stream", 99), "unknown replica index");
    assert!(!c.quarantine_replica("nope", 0), "unknown backend");

    // The next frame fails over: new owner, explicit reset, and — since
    // a fresh session is warming up — no output yet.
    let b = audio(48, 64);
    let mut reference =
        StreamSession::new(&model, ExecCtx::new(ConvAlgo::Sliding).with_dtype(Dtype::I8))
            .unwrap();
    let want0 = reference.advance(&[b.at4(0, 0, 0, 0)]);
    let f0 = c.advance_stream(&h, &[b.at4(0, 0, 0, 0)]).unwrap();
    assert!(f0.reset, "failover must surface as an explicit reset");
    assert_eq!(f0.output, want0, "the reset frame runs on fresh state");
    let moved_to = c.stream_replica(&h).unwrap();
    assert_ne!(moved_to, owner, "stream must leave the quarantined replica");

    // From here on the stream is exactly a fresh session replaying `b`:
    // bitwise-equal emissions at every step, stable new owner.
    for t in 1..b.dim(3) {
        let frame = [b.at4(0, 0, 0, t)];
        let want = reference.advance(&frame);
        let f = c.advance_stream(&h, &frame).unwrap();
        assert!(!f.reset, "frame {t}: reset may happen only once per loss");
        assert_eq!(f.output, want, "frame {t} after failover");
        assert_eq!(c.stream_replica(&h), Some(moved_to), "frame {t}");
    }
    c.shutdown();
}

/// NO HEALTHY REPLICA — placement skips replicas whose factory failed;
/// once every replica is quarantined, streaming calls error instead of
/// hanging or silently dropping frames.
#[test]
fn placement_skips_broken_replicas_and_errors_when_none_remain() {
    let model = zoo::edge_audio(4, 42);
    let item_shape = model.input_shape.clone();
    let spec = BackendSpec::from_factory("half", item_shape, move |replica| {
        if replica == 0 {
            swconv::bail!("replica 0 refuses to start");
        }
        Ok(Box::new(NativeBackend::new("half", model.clone(), ExecCtx::default())))
    })
    .with_replicas(2);
    let c = Coordinator::new(vec![spec], policy());

    let h = c.open_stream("half").unwrap();
    assert_eq!(c.stream_replica(&h), Some(1), "placement must skip the broken replica");
    assert!(!c.advance_stream(&h, &[0.5]).unwrap().reset);

    assert!(c.quarantine_replica("half", 1));
    match c.advance_stream(&h, &[0.5]) {
        Err(InferError::Backend(msg)) => {
            assert!(msg.contains("no healthy replica"), "{msg}")
        }
        other => panic!("expected no-healthy-replica error, got {other:?}"),
    }
    match c.open_stream("half") {
        Err(InferError::Backend(msg)) => {
            assert!(msg.contains("no healthy replica"), "{msg}")
        }
        other => panic!("expected placement failure, got {other:?}"),
    }
    c.shutdown();
}

/// IDLE EVICTION (backend level) — an untouched session is dropped on
/// the housekeeping tick, its private arena bytes go back to zero, and
/// a later advance errors (the coordinator turns that into a reset; the
/// state itself never lingers).
#[test]
fn idle_eviction_frees_session_arena_bytes() {
    let mut b = NativeBackend::new("s", zoo::edge_audio(4, 42), ExecCtx::new(ConvAlgo::Sliding))
        .with_stream_idle(Duration::from_millis(60));
    assert!(b.idle_tick_period().is_some(), "stream_idle must arm the idle tick");
    assert_eq!(b.stream_count(), 0);
    assert_eq!(b.stream_arena_bytes(), 0);

    b.open_stream(7).unwrap();
    assert_eq!(b.stream_count(), 1);
    let x = audio(16, 65);
    let mut emitted = 0usize;
    for t in 0..x.dim(3) {
        emitted += usize::from(b.advance_stream(7, &[x.at4(0, 0, 0, t)]).unwrap().is_some());
    }
    assert!(emitted > 0);
    assert!(b.stream_arena_bytes() > 0, "a streaming session keeps warm arena scratch");
    // A frame with the wrong channel count errors without killing the
    // session.
    assert!(b.advance_stream(7, &[0.0, 1.0]).is_err());
    assert_eq!(b.stream_count(), 1);

    // Recently touched: the tick must keep it.
    b.idle_tick();
    assert_eq!(b.stream_count(), 1, "busy session must survive the tick");

    std::thread::sleep(Duration::from_millis(100));
    b.idle_tick();
    assert_eq!(b.stream_count(), 0, "idle session must be evicted");
    assert_eq!(b.stream_arena_bytes(), 0, "eviction must free the session arena");
    assert!(b.advance_stream(7, &[0.0]).is_err(), "evicted stream must not resume");
    b.close_stream(7); // unknown id: no-op
    // Re-opening starts from scratch.
    b.open_stream(7).unwrap();
    assert_eq!(b.advance_stream(7, &[0.25]).unwrap(), None, "fresh session warms up again");
}

/// IDLE EVICTION (coordinator level) — the replica worker drives the
/// eviction clock; the next frame on an evicted stream comes back with
/// `reset = true` on a fresh session, not an error and not stale state.
#[test]
fn idle_evicted_coordinator_stream_resumes_with_a_reset() {
    let model = zoo::edge_audio(4, 42);
    let spec = BackendSpec::native_streaming(
        "stream",
        model,
        ExecCtx::new(ConvAlgo::Sliding),
        Duration::from_millis(50),
    );
    let c = Coordinator::new(vec![spec], policy());
    let h = c.open_stream("stream").unwrap();
    let x = audio(32, 66);
    for t in 0..x.dim(3) {
        assert!(!c.advance_stream(&h, &[x.at4(0, 0, 0, t)]).unwrap().reset);
    }
    // Quiet long enough for several idle ticks to fire and evict.
    std::thread::sleep(Duration::from_millis(250));
    let f = c.advance_stream(&h, &[0.5]).unwrap();
    assert!(f.reset, "an evicted session must come back as an explicit reset");
    assert_eq!(f.output, None, "fresh session warms up from scratch");
    // The same replica keeps serving the rebuilt session.
    assert!(!c.advance_stream(&h, &[0.25]).unwrap().reset);
    c.shutdown();
}
