//! Cross-algorithm kernel equivalence over a broad grid of shapes.
//!
//! Every convolution algorithm must produce the same numbers as the
//! direct oracle for every geometry it claims to support — this is the
//! load-bearing correctness statement behind the Fig. 1 comparison
//! ("same arithmetic, different memory behaviour").

use swconv::kernels::{conv1d, conv2d, Conv1dParams, Conv2dParams, ConvAlgo};
use swconv::tensor::Tensor;

fn check_2d(xdims: &[usize], wdims: &[usize], p: &Conv2dParams, seed: u64) {
    let x = Tensor::randn(xdims, seed);
    let w = Tensor::randn(wdims, seed + 1);
    let bias: Vec<f32> = (0..wdims[0]).map(|i| 0.01 * i as f32 - 0.02).collect();
    let reference = conv2d(&x, &w, Some(&bias), p, ConvAlgo::Direct);
    for algo in ConvAlgo::ALL {
        if !algo.supports_width(wdims[3]) {
            continue;
        }
        let y = conv2d(&x, &w, Some(&bias), p, algo);
        let d = y.max_abs_diff(&reference);
        assert!(
            d < 3e-3,
            "{algo:?} x{xdims:?} w{wdims:?} p{p:?}: diff {d}"
        );
    }
}

#[test]
fn grid_of_filter_sizes_all_algos() {
    for k in [1usize, 2, 3, 4, 5, 6, 8, 11, 16, 17, 18, 25, 33] {
        check_2d(
            &[1, 2, 20, 40.max(k + 3)],
            &[3, 2, 2.min(k), k],
            &Conv2dParams::default(),
            1000 + k as u64,
        );
    }
}

#[test]
fn grid_of_image_sizes() {
    for hw in [5usize, 7, 16, 17, 31, 33, 64] {
        check_2d(
            &[1, 3, hw, hw],
            &[2, 3, 3, 3],
            &Conv2dParams::same(3),
            2000 + hw as u64,
        );
    }
}

#[test]
fn grid_of_channel_counts() {
    for c in [1usize, 2, 3, 4, 7, 16] {
        check_2d(
            &[1, c, 12, 12],
            &[c.max(2), c, 5, 5],
            &Conv2dParams::default(),
            3000 + c as u64,
        );
    }
}

#[test]
fn batches_strides_pads() {
    check_2d(&[3, 2, 14, 14], &[2, 2, 3, 3], &Conv2dParams::same(3), 4001);
    let p = Conv2dParams { stride: (2, 2), pad: (2, 2), groups: 1 };
    check_2d(&[2, 3, 15, 17], &[4, 3, 5, 5], &p, 4002);
    let p = Conv2dParams { stride: (3, 1), pad: (0, 4), groups: 1 };
    check_2d(&[1, 2, 13, 11], &[2, 2, 3, 3], &p, 4003);
}

#[test]
fn grouped_and_depthwise() {
    let p = Conv2dParams { stride: (1, 1), pad: (1, 1), groups: 4 };
    check_2d(&[1, 8, 10, 10], &[8, 2, 3, 3], &p, 5001);
    let dw = Conv2dParams { stride: (1, 1), pad: (2, 2), groups: 16 };
    check_2d(&[2, 16, 9, 9], &[16, 1, 5, 5], &dw, 5002);
}

#[test]
fn conv1d_all_algos_wide_grid() {
    for k in [1usize, 2, 3, 5, 9, 16, 17, 33, 64] {
        let x = Tensor::randn(&[2, 100 + k], 6000 + k as u64);
        let w = Tensor::randn(&[3, 2, k], 6100 + k as u64);
        let p = Conv1dParams { stride: 1, pad: k / 2 };
        let reference = conv1d(&x, &w, None, &p, ConvAlgo::Direct);
        for algo in ConvAlgo::ALL {
            if !algo.supports_width(k) {
                continue;
            }
            let y = conv1d(&x, &w, None, &p, algo);
            let d = y.max_abs_diff(&reference);
            assert!(d < 3e-3, "{algo:?} k={k}: diff {d}");
        }
    }
}

/// Adversarial values: extremes, denormals, signed zeros.
#[test]
fn extreme_values_stay_finite_and_equal() {
    let mut x = Tensor::zeros(&[1, 1, 8, 24]);
    let xs = x.as_mut_slice();
    xs[0] = 1e30;
    xs[10] = -1e30;
    xs[50] = 1e-38;
    xs[100] = -0.0;
    let w = Tensor::full(&[1, 1, 3, 3], 1e-6);
    let p = Conv2dParams::default();
    let reference = conv2d(&x, &w, None, &p, ConvAlgo::Direct);
    for algo in [ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
        let y = conv2d(&x, &w, None, &p, algo);
        for (a, b) in y.as_slice().iter().zip(reference.as_slice()) {
            assert_eq!(a.is_finite(), b.is_finite());
            if b.is_finite() {
                assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
    }
}
