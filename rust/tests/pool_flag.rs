//! The process-global pooling switch, in its own integration binary on
//! purpose: cargo gives each integration-test file its own process, and
//! this is the only test in it — so flipping the global flag can never
//! race another test's lazy pool resolution (inside the lib-test
//! process it would briefly re-enable pooling during the
//! `SWCONV_NO_POOL=1` CI leg, silently weakening the scoped-fallback
//! coverage that job exists for).

use swconv::exec::{pool, ExecCtx};
use swconv::kernels::ConvAlgo;

/// Disabling makes a fresh ctx resolve to scoped threads, enabling
/// makes it lazily build a persistent pool, and both paths compute
/// identical bytes.
#[test]
fn pooling_disable_flag_controls_lazy_pool() {
    let initial = pool::pooling_disabled();
    pool::set_pooling_disabled(true);
    assert!(pool::pooling_disabled());
    let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4);
    let mut a = vec![0i32; 8];
    ctx.par_chunks(&mut a, 2, |i, c| c.fill(i as i32));
    assert!(ctx.pool_handle().is_none(), "disabled ⇒ scoped threads");

    pool::set_pooling_disabled(false);
    let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4);
    let mut b = vec![0i32; 8];
    ctx.par_chunks(&mut b, 2, |i, c| c.fill(i as i32));
    assert!(ctx.pool_handle().is_some(), "enabled ⇒ lazy persistent pool");
    assert_eq!(ctx.pool_handle().unwrap().workers(), 3, "threads - 1 resident workers");
    assert_eq!(a, b, "pooled and scoped results are identical");
    pool::set_pooling_disabled(initial);
}
