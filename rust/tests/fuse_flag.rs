//! The process-global fusion switch, in its own integration binary on
//! purpose: cargo gives each integration-test file its own process, so
//! flipping the flag here can never race another test's `compile()`
//! (inside a shared process it would briefly re-enable the passes
//! during the `SWCONV_NO_FUSE=1` CI leg, silently weakening the
//! verbatim-plan coverage that job exists for).

use swconv::graph::{self, PassSummary};
use swconv::kernels::ConvAlgo;
use swconv::nn::{zoo, ExecCtx};
use swconv::tensor::Tensor;

/// Disabling makes `Model::compile` reproduce the layer stack verbatim
/// (no pass fires), enabling restores the pipeline — and both plans
/// compute bit-identical outputs.
#[test]
fn fusion_disable_flag_controls_compile() {
    let initial = graph::fusion_disabled();
    let m = zoo::quantized_cnn(4, 3);

    graph::set_fusion_disabled(true);
    assert!(graph::fusion_disabled());
    let plain = m.compile();
    assert_eq!(plain.summary, PassSummary::default(), "disabled ⇒ no pass fires");

    graph::set_fusion_disabled(false);
    assert!(!graph::fusion_disabled());
    let fused = m.compile();
    assert!(fused.summary.fused_relu > 0, "enabled ⇒ the pipeline runs");
    assert!(fused.graph.nodes.len() < plain.graph.nodes.len());

    let x = Tensor::randn(&[1, 3, 32, 32], 5);
    let ctx = ExecCtx::new(ConvAlgo::Sliding);
    let want = m.forward(&x, &ctx);
    assert_eq!(plain.run(&x, &ctx).as_slice(), want.as_slice(), "verbatim plan parity");
    assert_eq!(fused.run(&x, &ctx).as_slice(), want.as_slice(), "fused plan parity");
    graph::set_fusion_disabled(initial);
}
