//! End-to-end parity of cache-blocked tiled execution: with tiling
//! forced ([`swconv::graph::set_tiling_forced`] — the `--tile` /
//! `SWCONV_FORCE_TILE` lever), every fusable conv chain runs
//! tile-by-tile through the halo-aware region kernels, and the result
//! must reproduce untiled execution **bit-for-bit** for every zoo
//! model, serving dtype, thread count, forced tile shape (including
//! the degenerate 1×W strips and a tile covering the whole plane) and
//! ISA level. Tiling is a locality/footprint lever, never an accuracy
//! lever: the region kernels replay the untiled kernels' per-element
//! accumulation order on each rect, so `assert_eq!` on bits — no
//! tolerance anywhere in this suite.

mod common;

use std::sync::Mutex;

use common::{assert_bitwise, input_for};
use swconv::graph::{set_forced_tile_shape, set_tiling_forced, tiling, TileMode};
use swconv::kernels::ConvAlgo;
use swconv::nn::{zoo, ExecCtx};
use swconv::simd::IsaLevel;
use swconv::tensor::Dtype;

/// The forced-tiling switches are process-wide; serialize the tests
/// that flip them so each one sees the state it set. (A lost race
/// would still pass — tiled and untiled are bit-identical — but the
/// failure diagnostics would blame the wrong tile shape.)
static TILE_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with tiling forced at `shape`, restoring the untiled
/// default afterwards even if the shape sweep panics midway.
fn with_forced_tile<R>(shape: (usize, usize), f: impl FnOnce() -> R) -> R {
    struct Reset;
    impl Drop for Reset {
        fn drop(&mut self) {
            set_tiling_forced(false);
            set_forced_tile_shape(None);
        }
    }
    let _reset = Reset;
    set_forced_tile_shape(Some(shape));
    set_tiling_forced(true);
    f()
}

/// Tile shapes covering the awkward grids: degenerate one-row strips,
/// single-column strips, a tile larger than any zoo plane (one tile =
/// the whole plane, the tiled executor's identity case), a square
/// interior tile, and a small odd shape whose grid has ragged edges
/// both ways.
const TILES: [(usize, usize); 5] = [(1, 4096), (4096, 1), (4096, 4096), (8, 8), (3, 5)];

/// Every zoo model × serving dtype × threads {1, 4} × forced tile
/// shape: forced-tiled execution is bitwise-identical to the untiled
/// run under the same ctx. Models whose graphs yield no eligible chain
/// under some dtype simply run untiled — still a valid parity case
/// (the forced switch must be a no-op there), and the vacuity guard
/// below proves the sweep tiles real chains where it matters.
#[test]
fn forced_tiling_bit_identical_across_zoo_dtypes_threads_and_tiles() {
    let _g = TILE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for name in zoo::MODEL_NAMES {
        let m = zoo::by_name(name, 4, 42).unwrap();
        let batch = if matches!(name, "simple-cnn" | "quantized-cnn") { 2 } else { 1 };
        let x = input_for(&m, batch, 17);
        for dtype in [Dtype::F32, Dtype::Bf16, Dtype::I8] {
            for threads in [1usize, 4] {
                let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads).with_dtype(dtype);
                let compiled = m.compile();
                let want = compiled.run(&x, &ctx);
                for tile in TILES {
                    let got = with_forced_tile(tile, || m.compile().run(&x, &ctx));
                    assert_bitwise(
                        &got,
                        &want,
                        &format!(
                            "{name} {} threads={threads} tile={}x{}",
                            dtype.name(),
                            tile.0,
                            tile.1
                        ),
                    );
                }
            }
        }
    }
}

/// Vacuity guard for the sweep above: under the f32 sliding route the
/// analysis must actually find chains to tile in the conv zoo — and
/// the degenerate shapes must produce the grids they claim (1×W strips
/// one per output row; the oversized tile exactly one full-plane
/// tile). Otherwise the parity sweep could silently compare untiled
/// against untiled.
#[test]
fn analysis_finds_chains_and_degenerate_grids_cover_the_plane() {
    let _g = TILE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let m = zoo::by_name("simple-cnn", 4, 42).unwrap();
    let compiled = m.compile();
    let ctx = ExecCtx::new(ConvAlgo::Sliding);
    let strips = with_forced_tile((1, 4096), || {
        tiling::analyze(&compiled.graph, None, &ctx, 1, TileMode::ForceAll)
    });
    assert!(!strips.is_empty(), "simple-cnn must yield at least one fusable chain");
    for c in &strips.chains {
        let (oh, ow) = c.out_hw();
        let tiles = c.tiles();
        assert_eq!(tiles.len(), oh, "1xW strips: one tile per output row");
        assert_eq!(tiles.iter().map(|t| t.area()).sum::<usize>(), oh * ow);
    }
    let whole = with_forced_tile((4096, 4096), || {
        tiling::analyze(&compiled.graph, None, &ctx, 1, TileMode::ForceAll)
    });
    for c in &whole.chains {
        assert_eq!(c.tiles().len(), 1, "oversized tile clamps to one full-plane tile");
        assert_eq!(c.tiled_bytes, c.untiled_bytes, "full-plane tile costs the untiled set");
    }
}

/// Tiled execution × ISA levels: the tiled run forced to each level is
/// bit-identical to the scalar-forced *untiled* reference — the two
/// levers (region kernels, explicit SIMD dispatch) must compose
/// without perturbing the per-element accumulation order.
#[test]
fn tiled_execution_bit_identical_across_isa_levels() {
    let _g = TILE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let m = zoo::by_name("simple-cnn", 4, 42).unwrap();
    let x = input_for(&m, 1, 19);
    let reference_ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 1).with_isa(IsaLevel::Scalar);
    let want = m.compile().run(&x, &reference_ctx);
    for isa in IsaLevel::ALL {
        for threads in [1usize, 2] {
            let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads).with_isa(isa);
            let got = with_forced_tile((3, 5), || m.compile().run(&x, &ctx));
            assert_bitwise(&got, &want, &format!("tiled {isa} threads={threads}"));
        }
    }
}

/// The quantized zoo model end to end under forced tiling: int8 chain
/// heads hoist the whole-tensor quantization (the tile must never see
/// a tile-local max), so parity here is the regression test for that
/// hoisting.
#[test]
fn quantized_model_tiled_parity_all_dtypes() {
    let _g = TILE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let m = zoo::by_name("quantized-cnn", 4, 42).unwrap();
    let x = input_for(&m, 2, 23);
    for dtype in [Dtype::F32, Dtype::I8] {
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4).with_dtype(dtype);
        let want = m.compile().run(&x, &ctx);
        for tile in [(1, 4096), (2, 3)] {
            let got = with_forced_tile(tile, || m.compile().run(&x, &ctx));
            assert_bitwise(
                &got,
                &want,
                &format!("quantized-cnn {} tile={}x{}", dtype.name(), tile.0, tile.1),
            );
        }
    }
}

/// Planner-attached tiling (the `--mem-budget` route) composes with
/// planned choices: a budgeted plan whose cache-footprint pass adopted
/// tiled chains must still execute bit-identically to the default
/// compiled plan.
#[test]
fn budgeted_planned_tiling_stays_bit_identical() {
    let _g = TILE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for name in ["simple-cnn", "squeezenet-lite"] {
        let m = zoo::by_name(name, 4, 42).unwrap();
        let x = input_for(&m, 1, 29);
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 2);
        let compiled = m.compile();
        let want = compiled.run(&x, &ctx);
        let floor = swconv::graph::min_feasible_budget(&compiled, 1, &ctx);
        let mp = swconv::graph::plan_model(&compiled, 1, &ctx, Some(floor))
            .unwrap_or_else(|e| panic!("{name} at floor budget: {e}"));
        let planned = m.compile().with_choices(mp.choices).with_tiling(mp.tiling);
        assert_bitwise(&planned.run(&x, &ctx), &want, &format!("{name} budgeted+tiled"));
    }
}
