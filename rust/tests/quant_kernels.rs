//! Int8 / bf16 kernel-equivalence suite.
//!
//! Three layers of guarantees, from exact to bounded:
//!
//! 1. **Exact** — int8 sliding and int8 im2col+GEMM produce bit-identical
//!    i32 raw accumulators (both are exact integer arithmetic over the
//!    same codes; only the memory access pattern differs).
//! 2. **Bounded, analytically** — quantize → conv → dequantize stays
//!    within a *derived* tolerance of the f32 reference. With symmetric
//!    per-tensor scales `sx = max|x|/127`, `sw = max|w|/127`, each tap's
//!    error is at most `|w|·sx/2 + |x|·sw/2 + sx·sw/4 ≤
//!    sx·sw·(127 + 1/4)`, so a convolution with `taps = c_in/g · kh · kw`
//!    accumulated taps errs at most `taps · 128 · sx · sw` per output —
//!    the bound asserted below.
//! 3. **Property** — quantize/dequantize round-trip error is bounded by
//!    `scale / 2` for every value in the representable range, symmetric
//!    and affine parameters alike.

mod common;

use common::{assert_bitwise, assert_exact_i32, assert_within};
use swconv::exec::ExecCtx;
use swconv::kernels::im2col::conv2d_im2col_q8_raw_ctx;
use swconv::kernels::sliding1d::conv1d_sliding_q8_ctx;
use swconv::kernels::sliding2d::conv2d_sliding_q8_raw_ctx;
use swconv::kernels::{
    conv1d, conv2d, conv2d_bf16_ctx, conv2d_q8_ctx, Conv1dParams, Conv2dParams, ConvAlgo,
};
use swconv::tensor::{dequantize, quantize, QuantParams, Tensor, XorShiftRng};

/// The 2-D geometry suite: padding, stride, groups, every width regime
/// (custom / generic / compound and beyond-compound widths — the int8
/// row kernel is width-universal).
fn geometries() -> Vec<(Vec<usize>, Vec<usize>, Conv2dParams)> {
    vec![
        (vec![1, 3, 12, 14], vec![4, 3, 3, 3], Conv2dParams::same(3)),
        (vec![2, 2, 10, 16], vec![3, 2, 5, 5], Conv2dParams::same(5)),
        (vec![1, 1, 8, 60], vec![2, 1, 3, 19], Conv2dParams::default()),
        (
            vec![1, 4, 12, 14],
            vec![4, 1, 3, 3],
            Conv2dParams { stride: (2, 2), pad: (1, 1), groups: 4 },
        ),
        (
            vec![1, 4, 9, 9],
            vec![6, 2, 3, 3],
            Conv2dParams { stride: (1, 1), pad: (1, 1), groups: 2 },
        ),
        (vec![1, 1, 4, 200], vec![1, 1, 2, 120], Conv2dParams::default()),
    ]
}

/// EXACT — the int8 sliding kernel and the int8 im2col+GEMM baseline
/// agree bit for bit on raw i32 accumulators, on every geometry.
#[test]
fn q8_sliding_and_gemm_raw_accumulators_agree_bitwise() {
    let ctx = ExecCtx::default();
    for (i, (xd, wd, p)) in geometries().iter().enumerate() {
        let x = Tensor::randn(xd, 500 + i as u64);
        let w = Tensor::randn(wd, 510 + i as u64);
        let qx = quantize(&x, QuantParams::for_tensor(&x));
        let qw = quantize(&w, QuantParams::for_tensor(&w));
        let a = conv2d_sliding_q8_raw_ctx(&qx, &qw, p, &ctx);
        let b = conv2d_im2col_q8_raw_ctx(&qx, &qw, p, &ctx);
        assert_exact_i32(&a, &b, &format!("case {i} sliding vs gemm"));
    }
}

/// EXACT, multi-threaded — thread count never changes int8 results
/// (integer accumulation per independent plane).
#[test]
fn q8_results_bit_identical_across_thread_counts() {
    let x = Tensor::randn(&[2, 3, 16, 16], 520);
    let w = Tensor::randn(&[4, 3, 5, 5], 521);
    let p = Conv2dParams::same(5);
    let qx = quantize(&x, QuantParams::for_tensor(&x));
    let qw = quantize(&w, QuantParams::for_tensor(&w));
    let one_ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 1);
    let one = conv2d_sliding_q8_raw_ctx(&qx, &qw, &p, &one_ctx);
    for t in [2, 4, 7] {
        let many_ctx = ExecCtx::with_threads(ConvAlgo::Sliding, t);
        let many = conv2d_sliding_q8_raw_ctx(&qx, &qw, &p, &many_ctx);
        assert_exact_i32(&many, &one, &format!("threads={t}"));
    }
}

/// BOUNDED — quantize → conv → dequantize vs the f32 reference, within
/// the derived `taps · 128 · sx · sw` tolerance (see module docs).
#[test]
fn q8_conv_tracks_f32_within_documented_tolerance() {
    for (i, (xd, wd, p)) in geometries().iter().enumerate() {
        let x = Tensor::randn(xd, 530 + i as u64);
        let w = Tensor::randn(wd, 540 + i as u64);
        let bias: Vec<f32> = (0..wd[0]).map(|c| 0.05 * c as f32).collect();
        let want = conv2d(&x, &w, Some(&bias), p, ConvAlgo::Direct);

        let xq = QuantParams::for_tensor(&x);
        let wq = QuantParams::for_tensor(&w);
        let qw = quantize(&w, wq);
        let got = conv2d_q8_ctx(&x, &qw, wq, Some(&bias), p, &ExecCtx::default());

        let taps = (wd[1] * wd[2] * wd[3]) as f32;
        let atol = taps * 128.0 * xq.scale * wq.scale;
        assert_within(&got, &want, atol, &format!("case {i} q8 vs f32"));
    }
}

/// BOUNDED — the 1-D quantized sliding path tracks the f32 conv1d.
#[test]
fn q8_conv1d_tracks_f32() {
    let x = Tensor::randn(&[3, 70], 550);
    let w = Tensor::randn(&[2, 3, 7], 551);
    let p = Conv1dParams { stride: 1, pad: 3 };
    let bias = vec![0.1, -0.2];
    let want = conv1d(&x, &w, Some(&bias), &p, ConvAlgo::Direct);

    let xq = QuantParams::for_tensor(&x);
    let wq = QuantParams::for_tensor(&w);
    let got = conv1d_sliding_q8_ctx(
        &quantize(&x, xq),
        xq,
        &quantize(&w, wq),
        wq,
        Some(&bias),
        &p,
        &ExecCtx::default(),
    );
    let taps = (3 * 7) as f32;
    let atol = taps * 128.0 * xq.scale * wq.scale;
    assert_within(&got, &want, atol, "q8 conv1d vs f32");
}

/// BOUNDED — bf16 convolution vs f32: the only error source is the
/// storage rounding of the operands (≤ 2⁻⁸ relative each), so the
/// output errs at most `taps · max|x| · max|w| · 2⁻⁷` plus accumulation
/// noise.
#[test]
fn bf16_conv_tracks_f32_within_storage_rounding() {
    for (i, (xd, wd, p)) in geometries().iter().enumerate() {
        let x = Tensor::randn(xd, 560 + i as u64);
        let w = Tensor::randn(wd, 570 + i as u64);
        let want = conv2d(&x, &w, None, p, ConvAlgo::Direct);
        let got = conv2d_bf16_ctx(&x, &w, None, p, &ExecCtx::default());
        let taps = (wd[1] * wd[2] * wd[3]) as f32;
        let atol = taps * x.max_abs() * w.max_abs() / 128.0 + 1e-4;
        assert_within(&got, &want, atol, &format!("case {i} bf16 vs f32"));
    }
}

/// The layer-boundary router honours the ctx algorithm: gemm and
/// sliding int8 routes agree exactly (shared dequant of bit-identical
/// accumulators).
#[test]
fn q8_boundary_wrapper_routes_agree() {
    let x = Tensor::randn(&[1, 3, 12, 12], 580);
    let w = Tensor::randn(&[4, 3, 3, 3], 581);
    let p = Conv2dParams::same(3);
    let wq = QuantParams::for_tensor(&w);
    let qw = quantize(&w, wq);
    let s = conv2d_q8_ctx(&x, &qw, wq, None, &p, &ExecCtx::new(ConvAlgo::Sliding));
    let g = conv2d_q8_ctx(&x, &qw, wq, None, &p, &ExecCtx::new(ConvAlgo::Im2colGemm));
    let d = conv2d_q8_ctx(&x, &qw, wq, None, &p, &ExecCtx::new(ConvAlgo::Direct));
    assert_bitwise(&g, &s, "q8 gemm route vs sliding route");
    // Direct has no int8 kernel: routed to sliding, identical result.
    assert_bitwise(&d, &s, "q8 direct route vs sliding route");
}

/// PROPERTY — quantize/dequantize round-trip error is bounded by
/// `scale / 2` for every value inside the representable range, across
/// random tensors and both symmetric and affine parameters.
#[test]
fn quantize_roundtrip_error_bounded_by_half_scale() {
    let mut rng = XorShiftRng::new(590);
    for trial in 0..200 {
        let symmetric = trial % 2 == 0;
        let hi = rng.uniform(0.1, 50.0);
        let lo = if symmetric { -hi } else { hi - rng.uniform(0.2, 60.0) };
        let q = if symmetric {
            QuantParams::symmetric(hi)
        } else {
            QuantParams::affine(lo, hi)
        };
        assert_eq!(q.is_symmetric(), symmetric || q.zero_point == 0);
        // The property holds on the *representable* range (outside it,
        // codes saturate — covered by the saturation test below). The
        // affine zero-point rounds, so the representable range can fall
        // short of [lo, hi] by up to a step at either edge; intersect.
        let rep_lo = q.dequantize_value(i8::MIN).max(lo);
        let rep_hi = q.dequantize_value(i8::MAX).min(hi);
        for _ in 0..64 {
            let v = rng.uniform(rep_lo, rep_hi);
            let r = q.dequantize_value(q.quantize_value(v));
            assert!(
                (r - v).abs() <= q.scale / 2.0 + q.scale * 1e-3,
                "trial {trial}: {v} -> {r} (scale {})",
                q.scale
            );
        }
        // And as whole tensors.
        let t = Tensor::rand_uniform(&[4, 8], rep_lo, rep_hi, 600 + trial);
        let back = dequantize(&quantize(&t, q), q);
        assert!(t.max_abs_diff(&back) <= q.scale / 2.0 + q.scale * 1e-3, "trial {trial}");
    }
}

/// Out-of-range values saturate (clamp) instead of wrapping — the
/// complement of the in-range property above.
#[test]
fn quantize_saturates_out_of_range() {
    let q = QuantParams::symmetric(1.0);
    assert_eq!(q.quantize_value(10.0), 127);
    assert_eq!(q.quantize_value(-10.0), -128);
    let t = Tensor::from_vec(vec![100.0, -100.0], &[2]);
    let codes = quantize(&t, q);
    assert_eq!(codes.as_slice(), &[127, -128]);
}
