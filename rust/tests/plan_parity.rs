//! End-to-end parity of the whole-model planner: attaching a planner
//! plan to a compiled model ([`CompiledPlan::with_choices`]) re-routes
//! every conv node through the planned algorithm × worker split, and
//! must reproduce the default compiled execution **bit-for-bit** for
//! every zoo model, serving dtype and thread count — planning is a
//! footprint/throughput lever, never an accuracy lever. Budgeted plans
//! must keep their predicted peak within the budget, and an
//! unsatisfiable budget must be an explicit [`PlanError::Infeasible`],
//! never a silent over-budget plan.

mod common;

use common::{assert_bitwise, input_for};
use swconv::graph::{min_feasible_budget, plan_model, PlanError};
use swconv::kernels::ConvAlgo;
use swconv::nn::{zoo, ExecCtx};
use swconv::tensor::Dtype;

/// Every zoo model × serving dtype {f32, i8} × threads {1, 4}: the
/// planned plan's output is bitwise-identical to the default compiled
/// plan's under the same ctx. The sliding ctx covers the paper's
/// default route; the GEMM ctx at 4 threads exercises the planner's one
/// real f32 algorithm interchange (one-shot ↔ strip GEMM).
#[test]
fn planned_execution_bit_identical_across_the_zoo() {
    for name in zoo::MODEL_NAMES {
        let m = zoo::by_name(name, 4, 42).unwrap();
        let batch = if matches!(name, "simple-cnn" | "quantized-cnn") { 2 } else { 1 };
        let x = input_for(&m, batch, 7);
        for dtype in [Dtype::F32, Dtype::I8] {
            for (algo, threads) in
                [(ConvAlgo::Sliding, 1), (ConvAlgo::Sliding, 4), (ConvAlgo::Im2colGemm, 4)]
            {
                let ctx = ExecCtx::with_threads(algo, threads).with_dtype(dtype);
                let compiled = m.compile();
                let want = compiled.run(&x, &ctx);
                let mp = plan_model(&compiled, batch, &ctx, None).expect("unbudgeted plan");
                assert!(
                    mp.choices.iter().any(Option::is_some),
                    "{name}: plan covers no conv node"
                );
                let planned = m.compile().with_choices(mp.choices);
                assert_bitwise(
                    &planned.run(&x, &ctx),
                    &want,
                    &format!("{name} {} {algo:?} threads={threads} planned", dtype.name()),
                );
            }
        }
    }
}

/// Budgeted plans keep their predicted peak within the budget — at the
/// exact feasibility floor and with headroom — and still execute
/// bit-identically to the default plan.
#[test]
fn budgeted_plans_respect_the_budget_and_stay_bit_identical() {
    for name in ["simple-cnn", "squeezenet-lite", "quantized-cnn"] {
        let m = zoo::by_name(name, 4, 42).unwrap();
        let x = input_for(&m, 1, 11);
        // GEMM-routed ctx: the budget can force the strip variant, not
        // just narrower splits.
        let ctx = ExecCtx::with_threads(ConvAlgo::Im2colGemm, 4);
        let compiled = m.compile();
        let want = compiled.run(&x, &ctx);
        let floor = min_feasible_budget(&compiled, 1, &ctx);
        let unbounded = plan_model(&compiled, 1, &ctx, None).expect("unbudgeted plan");
        let peak = unbounded.predicted_peak_bytes.max(floor);
        for budget in [floor, floor + (peak - floor) / 2] {
            let mp = plan_model(&compiled, 1, &ctx, Some(budget))
                .unwrap_or_else(|e| panic!("{name} budget {budget}: {e}"));
            assert!(
                mp.predicted_peak_bytes <= budget,
                "{name}: predicted peak {} exceeds budget {budget}",
                mp.predicted_peak_bytes
            );
            let planned = m.compile().with_choices(mp.choices);
            assert_bitwise(
                &planned.run(&x, &ctx),
                &want,
                &format!("{name} budget={budget} planned"),
            );
        }
    }
}

/// A budget below the feasibility floor is an explicit error that names
/// the floor — the planner never silently hands back an over-budget
/// plan.
#[test]
fn infeasible_budgets_error_instead_of_silently_falling_back() {
    let m = zoo::simple_cnn(4, 42);
    let compiled = m.compile();
    let ctx = ExecCtx::new(ConvAlgo::Sliding);
    let floor = min_feasible_budget(&compiled, 1, &ctx);
    assert!(floor > 1, "floor must be a real footprint");
    let PlanError::Infeasible { min_bytes, budget, .. } =
        plan_model(&compiled, 1, &ctx, Some(floor - 1)).expect_err("sub-floor budget must fail");
    assert_eq!(min_bytes, floor, "error reports the smallest feasible budget");
    assert_eq!(budget, floor - 1);
    // And exactly at the floor, planning succeeds.
    assert!(plan_model(&compiled, 1, &ctx, Some(floor)).is_ok());
}

/// The process-wide `SWCONV_FORCE_PLAN` lever: with it set, every
/// `Model::compile` attaches a plan, and results stay bit-identical to
/// an explicitly unplanned compile.
#[test]
fn forced_planning_attaches_choices_and_preserves_results() {
    let m = zoo::simple_cnn(4, 42);
    let x = input_for(&m, 2, 13);
    let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 2);
    swconv::graph::set_plan_forced(false);
    let want = m.compile().run(&x, &ctx);
    swconv::graph::set_plan_forced(true);
    let forced = m.compile();
    swconv::graph::set_plan_forced(false);
    assert!(forced.choices().is_some(), "forced compile must attach a plan");
    assert_bitwise(&forced.run(&x, &ctx), &want, "forced-plan compile");
}
