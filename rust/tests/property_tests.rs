//! Property-based tests (randomised invariants).
//!
//! `proptest` is unavailable in this offline environment (DESIGN.md
//! §Substitutions), so properties are driven by a seeded case generator:
//! each test draws a few hundred random configurations from
//! [`swconv::tensor::XorShiftRng`] and asserts the invariant, printing
//! the failing seed so a case can be replayed exactly.

use swconv::kernels::rowconv::{row_conv_auto, COMPOUND_MAX_K};
use swconv::kernels::sliding1d::sliding_sum;
use swconv::kernels::{
    avg_pool2d, conv2d, max_pool2d, Conv2dParams, ConvAlgo, PoolParams,
};
use swconv::simd::{slide_dyn, CompoundF32, F32xL, LANES};
use swconv::tensor::{pad_row, Tensor, XorShiftRng};

/// PROPERTY — sliding == im2col+GEMM == direct on arbitrary geometry.
#[test]
fn prop_conv2d_algorithms_agree() {
    let mut rng = XorShiftRng::new(0xA11CE);
    for case in 0..120 {
        let n = 1 + rng.below(2);
        let ci = 1 + rng.below(4);
        let co = 1 + rng.below(4);
        let kh = 1 + rng.below(4);
        let kw = 1 + rng.below(24); // spans custom/generic/compound regimes
        let h = kh + rng.below(12);
        let w = kw + rng.below(24);
        let ph = rng.below(3);
        let pw = rng.below(3);
        let sh = 1 + rng.below(2);
        let sw = 1 + rng.below(2);
        let seed = rng.next_u64();

        let p = Conv2dParams { stride: (sh, sw), pad: (ph, pw), groups: 1 };
        let x = Tensor::randn(&[n, ci, h, w], seed);
        let wt = Tensor::randn(&[co, ci, kh, kw], seed ^ 1);
        let direct = conv2d(&x, &wt, None, &p, ConvAlgo::Direct);
        for algo in [ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            let y = conv2d(&x, &wt, None, &p, algo);
            let d = y.max_abs_diff(&direct);
            assert!(
                d < 3e-3,
                "case {case} (seed {seed}): {algo:?} diff {d} \
                 [n={n} ci={ci} co={co} k={kh}x{kw} hw={h}x{w} p=({ph},{pw}) s=({sh},{sw})]"
            );
        }
    }
}

/// PROPERTY — convolution is linear in the input:
/// conv(a·x1 + b·x2) == a·conv(x1) + b·conv(x2).
#[test]
fn prop_conv2d_linearity() {
    let mut rng = XorShiftRng::new(0xB0B);
    for case in 0..60 {
        let seed = rng.next_u64();
        let k = 1 + rng.below(7);
        let x1 = Tensor::randn(&[1, 2, 10, 10 + k], seed);
        let x2 = Tensor::randn(&[1, 2, 10, 10 + k], seed ^ 2);
        let w = Tensor::randn(&[2, 2, 1 + rng.below(3), k], seed ^ 3);
        let (a, b) = (rng.uniform(-2.0, 2.0), rng.uniform(-2.0, 2.0));
        let p = Conv2dParams::default();

        let combo = Tensor::from_vec(
            x1.as_slice()
                .iter()
                .zip(x2.as_slice())
                .map(|(u, v)| a * u + b * v)
                .collect(),
            x1.dims(),
        );
        let lhs = conv2d(&combo, &w, None, &p, ConvAlgo::Sliding);
        let y1 = conv2d(&x1, &w, None, &p, ConvAlgo::Sliding);
        let y2 = conv2d(&x2, &w, None, &p, ConvAlgo::Sliding);
        let rhs = Tensor::from_vec(
            y1.as_slice()
                .iter()
                .zip(y2.as_slice())
                .map(|(u, v)| a * u + b * v)
                .collect(),
            y1.dims(),
        );
        let d = lhs.max_abs_diff(&rhs);
        assert!(d < 1e-2, "case {case} (seed {seed}): linearity broken, diff {d}");
    }
}

/// PROPERTY — slide laws: slide_dyn(a,b,j) equals the lane-exact
/// concatenation for all j, and compound windows equal flat windows.
#[test]
fn prop_slide_and_compound_window_laws() {
    let mut rng = XorShiftRng::new(0xC0DE);
    for _ in 0..200 {
        let flat: Vec<f32> = (0..4 * LANES).map(|_| rng.uniform(-9.0, 9.0)).collect();
        let a = F32xL::load(&flat);
        let b = F32xL::load(&flat[LANES..]);
        let j = rng.below(LANES + 1);
        let s = slide_dyn(a, b, j);
        for i in 0..LANES {
            assert_eq!(s.0[i], flat[i + j]);
        }
        let c = CompoundF32::<4>::load(&flat);
        let wj = rng.below(3 * LANES + 1);
        let w = c.window(wj);
        for i in 0..LANES {
            assert_eq!(w.0[i], flat[wj + i], "window j={wj} lane {i}");
        }
    }
}

/// PROPERTY — the auto row kernel equals the scalar dot product for any
/// width up to COMPOUND_MAX_K.
#[test]
fn prop_row_conv_auto_matches_scalar() {
    let mut rng = XorShiftRng::new(0xD00D);
    for case in 0..100 {
        let k = 1 + rng.below(COMPOUND_MAX_K);
        let out_len = 1 + rng.below(3 * LANES);
        let seed = rng.next_u64();
        let mut lrng = XorShiftRng::new(seed);
        let raw: Vec<f32> = (0..out_len + k).map(|_| lrng.uniform(-1.0, 1.0)).collect();
        let w: Vec<f32> = (0..k).map(|_| lrng.uniform(-1.0, 1.0)).collect();
        let src = pad_row(&raw, 0, 2 * LANES + k, 0.0);
        let mut dst = vec![0.0f32; out_len];
        row_conv_auto(&src, &w, &mut dst, out_len);
        for i in 0..out_len {
            let want: f32 = (0..k).map(|j| w[j] * src[i + j]).sum();
            assert!(
                (dst[i] - want).abs() < 1e-3,
                "case {case} (seed {seed}) k={k} i={i}: {} vs {want}",
                dst[i]
            );
        }
    }
}

/// PROPERTY — max pooling is idempotent under window 1, monotone under
/// input ordering, and equals the naive oracle for random shapes.
#[test]
fn prop_pooling_laws() {
    let mut rng = XorShiftRng::new(0xE0E0);
    for case in 0..60 {
        let seed = rng.next_u64();
        let h = 4 + rng.below(12);
        let w = 4 + rng.below(12);
        let k = 1 + rng.below(h.min(w).min(6));
        let x = Tensor::randn(&[1, 2, h, w], seed);
        let p = PoolParams::with_stride(k, 1 + rng.below(2));

        // window 1 + stride 1 is identity
        let ident = PoolParams::with_stride(1, 1);
        assert_eq!(max_pool2d(&x, &ident), x, "case {case}");

        // max >= avg elementwise
        let mx = max_pool2d(&x, &p);
        let av = avg_pool2d(&x, &p);
        for (m, a) in mx.as_slice().iter().zip(av.as_slice()) {
            assert!(m + 1e-5 >= *a, "case {case} (seed {seed}): max {m} < avg {a}");
        }
    }
}

/// PROPERTY — sliding_sum equals prefix-sum differences.
#[test]
fn prop_sliding_sum_equals_prefix_diff() {
    let mut rng = XorShiftRng::new(0xF00);
    for case in 0..80 {
        let seed = rng.next_u64();
        let mut lrng = XorShiftRng::new(seed);
        let n = 8 + rng.below(120);
        let k = 1 + rng.below(n.min(LANES));
        let x: Vec<f32> = (0..n).map(|_| lrng.uniform(-1.0, 1.0)).collect();
        let got = sliding_sum(&x, k);
        let mut prefix = vec![0.0f64; n + 1];
        for i in 0..n {
            prefix[i + 1] = prefix[i] + x[i] as f64;
        }
        assert_eq!(got.len(), n - k + 1);
        for i in 0..got.len() {
            let want = (prefix[i + k] - prefix[i]) as f32;
            assert!(
                (got[i] - want).abs() < 1e-3,
                "case {case} (seed {seed}) n={n} k={k} i={i}: {} vs {want}",
                got[i]
            );
        }
    }
}

/// PROPERTY — tensor stride math: offset4 equals the dot product of the
/// index with strides for random shapes.
#[test]
fn prop_tensor_strides() {
    let mut rng = XorShiftRng::new(0xFEED);
    for _ in 0..100 {
        let dims = [
            1 + rng.below(4),
            1 + rng.below(5),
            1 + rng.below(6),
            1 + rng.below(7),
        ];
        let t = Tensor::zeros(&dims);
        let s = t.strides();
        let idx = [
            rng.below(dims[0]),
            rng.below(dims[1]),
            rng.below(dims[2]),
            rng.below(dims[3]),
        ];
        let want: usize = idx.iter().zip(&s).map(|(i, st)| i * st).sum();
        assert_eq!(t.offset4(idx[0], idx[1], idx[2], idx[3]), want);
    }
}
