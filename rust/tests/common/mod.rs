//! Shared parity-assertion helpers for the integration suites.
//!
//! Every `tests/*.rs` binary compiles its own copy via `mod common;`.
//! The helpers encode the repo's three equivalence grades so each
//! suite asserts them the same way, with the same failure messages:
//!
//! 1. **Bitwise** ([`assert_bitwise`], [`assert_slices_bitwise`]) —
//!    f32/bf16 results that must match to the last bit (thread counts,
//!    ISA levels, compiled plans, streamed-vs-batch in i8).
//! 2. **Exact integers** ([`assert_exact_i32`]) — int8 kernels'
//!    raw i32 accumulators, exact by construction.
//! 3. **Derived tolerance** ([`assert_within`]) — reduced-precision
//!    paths compared against f32 under an analytically derived bound
//!    (never an eyeballed epsilon).

#![allow(dead_code)] // each test binary uses its own subset

use swconv::nn::Model;
use swconv::tensor::{Tensor, TensorT};

/// Two f32 tensors must be bit-for-bit identical (same shape, same
/// bits). `what` names the comparison in the failure message.
pub fn assert_bitwise(got: &Tensor, want: &Tensor, what: &str) {
    assert_eq!(got.dims(), want.dims(), "{what}: shape mismatch");
    assert_eq!(got.as_slice(), want.as_slice(), "{what}: results must be bit-identical");
}

/// Two raw slices must be bit-for-bit identical (row-kernel outputs,
/// streamed columns).
pub fn assert_slices_bitwise<T: PartialEq + std::fmt::Debug>(got: &[T], want: &[T], what: &str) {
    assert_eq!(got, want, "{what}: results must be bit-identical");
}

/// Two i32 accumulator tensors must be exactly equal — integer
/// arithmetic over identical codes has one right answer.
pub fn assert_exact_i32(got: &TensorT<i32>, want: &TensorT<i32>, what: &str) {
    assert_eq!(got.dims(), want.dims(), "{what}: shape mismatch");
    assert_eq!(got.as_slice(), want.as_slice(), "{what}: integer accumulators must be exact");
}

/// `max |got − want|` must not exceed a *derived* bound (pass the
/// analytic tolerance, not a guess).
pub fn assert_within(got: &Tensor, want: &Tensor, bound: f32, what: &str) {
    assert_eq!(got.dims(), want.dims(), "{what}: shape mismatch");
    let d = got.max_abs_diff(want);
    assert!(d <= bound, "{what}: diff {d:.3e} > derived bound {bound:.3e}");
}

/// A deterministic `[batch, …model.input_shape]` input.
pub fn input_for(m: &Model, batch: usize, seed: u64) -> Tensor {
    let dims: Vec<usize> = std::iter::once(batch).chain(m.input_shape.iter().copied()).collect();
    Tensor::randn(&dims, seed)
}

/// Deterministic pseudo-random f32 in (−1, 1) — no rand crate offline.
pub fn lcg_f32(seed: &mut u64) -> f32 {
    *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    ((*seed >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
}
