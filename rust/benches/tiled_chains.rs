//! BENCH — cache-blocked tiled execution: every zoo model run untiled
//! (the baseline executor, full-plane intermediates) vs tiled (the same
//! compiled plan with the tiling analysis' fusable chains attached, run
//! tile-by-tile through the halo-aware region kernels). Tiled rows
//! sweep the cache-budget-sized `auto` shape plus two forced shapes, so
//! the report shows both the working-set shrink the analysis predicts
//! (`chain_ws_bytes`, per-tile vs full-plane) and what that locality
//! actually buys or costs in wall time on this machine's cache
//! hierarchy (`swconv cache-info`).
//!
//! Parity is asserted before anything is timed: tiled execution must
//! reproduce the untiled run **bit for bit** (every dtype — the region
//! kernels replay the untiled per-element accumulation order), or the
//! bench aborts. The analysis' footprint invariant is asserted too:
//! a chain's per-tile working set never exceeds its untiled set, and
//! strictly shrinks whenever the tile is smaller than the plane.
//!
//! Emits `target/reports/BENCH_tile.json` (schema:
//! [`swconv::harness::report::TileBenchRecord`]) with `bench` =
//! `"tile"`: one `untiled` record plus one `tiled` record per
//! (model, dtype, tile shape) with at least one fusable chain.

use swconv::graph::{set_forced_tile_shape, tiling, TileMode};
use swconv::harness::report::{dur, f3, write_tile_bench_json, Table, TileBenchRecord};
use swconv::harness::timing::bench;
use swconv::kernels::ConvAlgo;
use swconv::nn::{zoo, ExecCtx};
use swconv::tensor::{Dtype, Tensor};

const BATCH: usize = 2;
const THREADS: usize = 4;

/// Tile-shape sweep: the cache-budget autosize plus two forced shapes
/// (interior tile, small tile — more halo overlap, less footprint).
const SHAPES: [(&str, Option<(usize, usize)>); 3] =
    [("auto", None), ("8x8", Some((8, 8))), ("4x4", Some((4, 4)))];

fn main() {
    let mut t = Table::new(
        format!("Tiled vs untiled fused chains (batch {BATCH}, {THREADS} threads)"),
        &["model", "dtype", "mode", "tile", "chains", "chain ws", "median", "GF/s"],
    );
    let mut records: Vec<TileBenchRecord> = Vec::new();
    for name in zoo::MODEL_NAMES {
        let m = zoo::by_name(name, 10, 42).unwrap();
        let mut shape = vec![BATCH];
        shape.extend_from_slice(&m.input_shape);
        let x = Tensor::randn(&shape, 1);
        for dtype in [Dtype::F32, Dtype::I8] {
            let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, THREADS).with_dtype(dtype);
            let compiled = m.compile();
            let flops = compiled.flops(BATCH);
            let want = compiled.run(&x, &ctx);
            // The auto analysis names the chains; its untiled estimate
            // is shape-independent, so it prices the baseline row too.
            let auto = tiling::analyze(&compiled.graph, None, &ctx, BATCH, TileMode::ForceAll);
            let untiled_ws: u64 = auto.chains.iter().map(|c| c.untiled_bytes).sum();

            let stats = bench(|| compiled.run(&x, &ctx));
            t.row(vec![
                name.into(),
                dtype.name().into(),
                "untiled".into(),
                "-".into(),
                auto.chains.len().to_string(),
                format!("{:.0}KiB", untiled_ws as f64 / 1024.0),
                dur(stats.median),
                f3(stats.gflops(flops)),
            ]);
            records.push(TileBenchRecord {
                bench: "tile".into(),
                model: name.into(),
                dtype: dtype.name().into(),
                threads: THREADS,
                mode: "untiled".into(),
                tile: "-".into(),
                chains: auto.chains.len(),
                chain_ws_bytes: untiled_ws,
                ns_per_iter: stats.median.as_secs_f64() * 1e9,
                gflops: stats.gflops(flops),
            });
            if auto.is_empty() {
                eprintln!("{name} {}: no fusable chain — tiled rows skipped", dtype.name());
                continue;
            }
            for (label, forced) in SHAPES {
                set_forced_tile_shape(forced);
                let analysis =
                    tiling::analyze(&compiled.graph, None, &ctx, BATCH, TileMode::ForceAll);
                set_forced_tile_shape(None);
                if analysis.is_empty() {
                    eprintln!("{name} {}: tile {label} rejected by the grid validator", dtype.name());
                    continue;
                }
                let mut ws = 0u64;
                for c in &analysis.chains {
                    // The analysis' footprint invariant, priced per chain.
                    assert!(
                        c.tiled_bytes <= c.untiled_bytes,
                        "{name} {label}: tiling must never grow the working set"
                    );
                    let (oh, ow) = c.out_hw();
                    if (c.tile.0 < oh || c.tile.1 < ow) && c.tiled_bytes == c.untiled_bytes {
                        // Possible only when every link's halo already
                        // clamps to its full input plane — worth seeing.
                        eprintln!(
                            "{name} {label}: sub-plane tile did not shrink chain %{}..%{}",
                            c.start, c.end
                        );
                    }
                    ws += c.tiled_bytes;
                }
                let tiled = m.compile().with_tiling(analysis.clone());
                // Parity gate: timing a wrong answer is worse than none.
                assert_eq!(
                    tiled.run(&x, &ctx).as_slice(),
                    want.as_slice(),
                    "{name} {} tile {label}: tiled execution must be bit-identical",
                    dtype.name()
                );
                let stats = bench(|| tiled.run(&x, &ctx));
                t.row(vec![
                    name.into(),
                    dtype.name().into(),
                    "tiled".into(),
                    label.into(),
                    analysis.chains.len().to_string(),
                    format!("{:.0}KiB", ws as f64 / 1024.0),
                    dur(stats.median),
                    f3(stats.gflops(flops)),
                ]);
                records.push(TileBenchRecord {
                    bench: "tile".into(),
                    model: name.into(),
                    dtype: dtype.name().into(),
                    threads: THREADS,
                    mode: "tiled".into(),
                    tile: label.into(),
                    chains: analysis.chains.len(),
                    chain_ws_bytes: ws,
                    ns_per_iter: stats.median.as_secs_f64() * 1e9,
                    gflops: stats.gflops(flops),
                });
            }
        }
    }
    println!("{}", t.render());
    write_tile_bench_json("target/reports/BENCH_tile.json", &records).expect("json");
    eprintln!("wrote target/reports/BENCH_tile.json ({} records)", records.len());
}
