//! BENCH — the precursor result paper §2 recalls: 1-D convolution speedup
//! of the Vector Slide kernel over GEMM/direct, "roughly proportional to
//! the logarithm of the filter width".

use swconv::exec::ExecCtx;
use swconv::harness::report::{f3, Table};
use swconv::harness::timing::bench_quick;
use swconv::kernels::{conv1d_ctx, Conv1dParams, ConvAlgo};
use swconv::tensor::Tensor;

fn main() {
    let l = 1 << 15; // 32k samples
    let c_in = 2;
    let c_out = 4;
    let ks = [2usize, 3, 4, 5, 7, 9, 12, 16, 17, 20, 24, 31, 33, 48, 64];

    let mut t = Table::new(
        format!("1-D convolution speedup (cin={c_in}, cout={c_out}, L={l})"),
        &["k", "t_gemm_ms", "t_direct_ms", "t_sliding_ms", "speedup_vs_gemm", "speedup_vs_direct"],
    );
    // One ctx per algorithm for the whole sweep: the timed iterations
    // reuse arena scratch across filter sizes instead of paying a fresh
    // column/pad allocation per k.
    let gemm = ExecCtx::new(ConvAlgo::Im2colGemm);
    let direct = ExecCtx::new(ConvAlgo::Direct);
    let sliding = ExecCtx::new(ConvAlgo::Sliding);
    for &k in &ks {
        let x = Tensor::rand_uniform(&[c_in, l], -1.0, 1.0, k as u64);
        let w = Tensor::rand_uniform(&[c_out, c_in, k], -1.0, 1.0, 1 + k as u64);
        let p = Conv1dParams::default();
        let tg = bench_quick(|| conv1d_ctx(&x, &w, None, &p, &gemm)).secs();
        let td = bench_quick(|| conv1d_ctx(&x, &w, None, &p, &direct)).secs();
        let ts = bench_quick(|| conv1d_ctx(&x, &w, None, &p, &sliding)).secs();
        t.row(vec![
            k.to_string(),
            f3(tg * 1e3),
            f3(td * 1e3),
            f3(ts * 1e3),
            f3(tg / ts),
            f3(td / ts),
        ]);
    }
    println!("{}", t.render());
    t.write_csv("target/reports/fig1d.csv").expect("csv");
    println!("CSV in target/reports/fig1d.csv");
}
