//! BENCH — quantized sliding convolution vs its baselines on the fig2
//! workload shape.
//!
//! The paper's closing argument is low-power/low-memory deployment; the
//! low-memory GEMM line (arXiv:1709.03395) shows reduced precision is
//! where commodity inference wins. This bench races, per filter size on
//! the Fig. 2 plane (c=4, 64×64):
//!
//! * `sliding-f32`  — the paper's f32 sliding kernel (reference speed),
//! * `sliding-q8`   — int8 sliding, exact i32 accumulators
//!   (`conv2d_sliding_q8_raw_ctx`),
//! * `gemm-q8`      — int8 im2col+GEMM (`conv2d_im2col_q8_raw_ctx`),
//!   the quantized `MlasConv` stand-in.
//!
//! Both int8 series compute bit-identical raw accumulators (asserted
//! here), so the comparison isolates the memory access pattern: the
//! sliding kernel streams the padded input once per tap, the GEMM
//! baseline materialises and re-reads the `k²`-bloated column matrix.
//!
//! ## `BENCH_quant.json` schema
//!
//! Machine-readable records land in `target/reports/BENCH_quant.json` —
//! the shared `BENCH_*.json` array schema (see
//! [`swconv::harness::report::BenchRecord`]) with `bench` = `"quant"`,
//! `algo` ∈ {`"sliding-f32"`, `"sliding-q8"`, `"gemm-q8"`} and `shape`
//! a `ConvCase::id`. `gflops` counts the same 2·MAC arithmetic for
//! every series (integer MACs counted like FLOPs), so the three
//! throughputs are directly comparable.

use swconv::exec::ExecCtx;
use swconv::harness::report::{f3, write_bench_json, BenchRecord, Table};
use swconv::harness::timing::bench_quick;
use swconv::harness::ConvCase;
use swconv::kernels::im2col::conv2d_im2col_q8_raw_ctx;
use swconv::kernels::sliding2d::conv2d_sliding_q8_raw_ctx;
use swconv::kernels::{conv2d_ctx, ConvAlgo};
use swconv::tensor::{quantize, QuantParams};

const C: usize = 4;
const HW: usize = 64;
const KS: [usize; 4] = [3, 5, 9, 17];

fn main() {
    let mut table = Table::new(
        format!("quantized sliding conv — c{C}, {HW}x{HW} (single thread)"),
        &["k", "sliding-f32", "sliding-q8", "gemm-q8", "q8 slide/gemm speedup"],
    );
    let mut records = Vec::new();
    let mut q8_wins_fig2_shape = true;
    // One ctx per series for the whole sweep: arena scratch warms once
    // and is recycled across filter sizes and timed iterations.
    let f32_ctx = ExecCtx::new(ConvAlgo::Sliding);
    let slide_ctx = ExecCtx::new(ConvAlgo::Sliding);
    let gemm_ctx = ExecCtx::new(ConvAlgo::Im2colGemm);
    for &k in &KS {
        let case = ConvCase::square(C, HW, k);
        let flops = case.flops();
        let x = case.input();
        let w = case.weights();
        let qx = quantize(&x, QuantParams::for_tensor(&x));
        let qw = quantize(&w, QuantParams::for_tensor(&w));

        // Honesty check before timing: both int8 kernels must produce
        // the same raw accumulators bit for bit.
        let a = conv2d_sliding_q8_raw_ctx(&qx, &qw, &case.params, &slide_ctx);
        let b = conv2d_im2col_q8_raw_ctx(&qx, &qw, &case.params, &gemm_ctx);
        assert_eq!(a.as_slice(), b.as_slice(), "k={k}: int8 kernels disagree");

        let s_f32 =
            bench_quick(|| conv2d_ctx(&x, &w, None, &case.params, &f32_ctx)).gflops(flops);
        let s_q8 = bench_quick(|| conv2d_sliding_q8_raw_ctx(&qx, &qw, &case.params, &slide_ctx))
            .gflops(flops);
        let g_q8 = bench_quick(|| conv2d_im2col_q8_raw_ctx(&qx, &qw, &case.params, &gemm_ctx))
            .gflops(flops);
        if s_q8 <= g_q8 {
            q8_wins_fig2_shape = false;
        }

        table.row(vec![
            k.to_string(),
            f3(s_f32),
            f3(s_q8),
            f3(g_q8),
            f3(s_q8 / g_q8),
        ]);
        for (algo, gflops) in
            [("sliding-f32", s_f32), ("sliding-q8", s_q8), ("gemm-q8", g_q8)]
        {
            records.push(BenchRecord {
                bench: "quant".into(),
                algo: algo.into(),
                shape: case.id(),
                threads: 1,
                replicas: 1,
                // flops [FLOP] / gflops [1e9 FLOP/s] = 1e-9 s = 1 ns units.
                ns_per_iter: flops as f64 / gflops,
                gflops,
            });
        }
    }
    println!("{}", table.render());
    println!(
        "int8 sliding {} int8 im2col-GEMM on the fig2 workload shape (c{C}, {HW}x{HW})",
        if q8_wins_fig2_shape { "beats" } else { "does NOT beat" }
    );
    write_bench_json("target/reports/BENCH_quant.json", &records).expect("json");
    println!("records in target/reports/BENCH_quant.json");
}
