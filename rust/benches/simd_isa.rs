//! BENCH — explicit `std::arch` row microkernels vs the per-ISA compute
//! roof (the tentpole measurement for the SIMD dispatch layer).
//!
//! For every instruction-set level available on this machine
//! ([`IsaLevel::available_levels`]) and every row-kernel family, this
//! bench times the raw row routine returned by the dispatch seam
//! ([`RowKernel::row_fn_at`], [`row_conv_q8_at`], [`row_conv_bf16_at`])
//! on an L1-resident 4096-wide row, then divides achieved GFLOP/s by
//! that level's *measured* FMA roof ([`swconv::harness::isa_peak`]) —
//! the roofline fraction Advisor would report per kernel × ISA.
//!
//! Before any timing, every level's output is asserted bit-identical
//! (f32/bf16) or exactly equal (i8/i32) to the Scalar level on the same
//! inputs — the dispatch layer is a speed knob, never an accuracy knob.
//!
//! ## `BENCH_simd.json` schema
//!
//! Unlike the shared `BenchRecord` schema, per-ISA records carry the
//! roof they were judged against, so the file is its own array shape:
//!
//! ```json
//! [
//!   {"bench": "simd", "kernel": "generic", "isa": "avx2", "k": 9,
//!    "width": 4096, "gflops": 41.2, "peak_gflops": 55.1,
//!    "roofline_frac": 0.748}
//! ]
//! ```
//!
//! `kernel` ∈ {`custom3`, `custom5`, `generic`, `compound`, `q8`,
//! `bf16`}; `isa` is an [`IsaLevel::name`]; `peak_gflops` is the f32
//! FMA roof of that level. Integer MACs are counted like FLOPs (the
//! `BENCH_quant.json` convention), so the `q8` fraction may exceed 1.0
//! where the integer pipeline out-issues f32 FMA.

use std::io::Write;
use swconv::harness::isa_peak;
use swconv::harness::report::{f3, Table};
use swconv::harness::timing::bench_quick;
use swconv::kernels::rowconv::{row_conv_bf16_at, row_conv_q8_at, RowKernel};
use swconv::simd::{IsaLevel, LANES};
use swconv::tensor::Bf16;

/// Output row width: 16 KiB of f32 — resident in L1, so the measurement
/// probes the compute roof, not DRAM.
const WIDTH: usize = 4096;

struct SimdRecord {
    kernel: &'static str,
    isa: IsaLevel,
    k: usize,
    gflops: f64,
    peak_gflops: f64,
}

/// Deterministic pseudo-random f32 in (-1, 1) — no rand crate offline.
fn lcg_f32(seed: &mut u64) -> f32 {
    *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    ((*seed >> 40) as f32 / (1u64 << 24) as f32) * 2.0 - 1.0
}

/// Time one f32 row family at one level, asserting bit-parity with the
/// Scalar level first. Returns achieved GFLOP/s.
fn bench_f32(family: RowKernel, k: usize, isa: IsaLevel) -> f64 {
    let mut seed = 0x5eed_0000 + k as u64;
    let src: Vec<f32> = (0..WIDTH + k + 2 * LANES + 8).map(|_| lcg_f32(&mut seed)).collect();
    let w: Vec<f32> = (0..k).map(|_| lcg_f32(&mut seed)).collect();
    let row = family.row_fn_at(k, isa);

    // Parity gate: same bias-prefilled dst, one call, bit-for-bit.
    let scalar = family.row_fn_at(k, IsaLevel::Scalar);
    let mut want = vec![0.25f32; WIDTH];
    let mut got = vec![0.25f32; WIDTH];
    scalar(&src, &w, &mut want, WIDTH);
    row(&src, &w, &mut got, WIDTH);
    assert_eq!(want, got, "{family:?} k={k} at {isa} diverges from scalar");

    // Accumulation is the kernel's contract; |w·src| ≤ k per call keeps
    // the running dst finite for any realistic iteration count.
    let mut dst = vec![0.25f32; WIDTH];
    let stats = bench_quick(|| {
        row(&src, &w, &mut dst, WIDTH);
        dst[0]
    });
    stats.gflops((2 * k * WIDTH) as u64)
}

/// Time the int8 row kernel at one level (exact i32 parity asserted).
fn bench_q8(k: usize, isa: IsaLevel) -> f64 {
    let mut seed = 0x5eed_1000 + k as u64;
    let src: Vec<i8> = (0..WIDTH + k + 2 * LANES + 8)
        .map(|_| (lcg_f32(&mut seed) * 127.0) as i8)
        .collect();
    let w: Vec<i8> = (0..k).map(|_| (lcg_f32(&mut seed) * 127.0) as i8).collect();
    let row = row_conv_q8_at(isa);

    let scalar = row_conv_q8_at(IsaLevel::Scalar);
    let mut want = vec![0i32; WIDTH];
    let mut got = vec![0i32; WIDTH];
    scalar(&src, &w, &mut want, WIDTH);
    row(&src, &w, &mut got, WIDTH);
    assert_eq!(want, got, "q8 k={k} at {isa} diverges from scalar");

    // Zero the accumulator inside the loop (an in-L1 16 KiB fill) so the
    // running i32 sum cannot wrap; the fill is noise next to k taps of
    // widening multiplies.
    let mut dst = vec![0i32; WIDTH];
    let stats = bench_quick(|| {
        dst.fill(0);
        row(&src, &w, &mut dst, WIDTH);
        dst[0]
    });
    stats.gflops((2 * k * WIDTH) as u64)
}

/// Time the bf16 row kernel at one level (bitwise f32 parity asserted).
fn bench_bf16(k: usize, isa: IsaLevel) -> f64 {
    let mut seed = 0x5eed_2000 + k as u64;
    let src: Vec<Bf16> = (0..WIDTH + k + 2 * LANES + 8)
        .map(|_| Bf16::from_f32(lcg_f32(&mut seed)))
        .collect();
    let w: Vec<f32> = (0..k).map(|_| lcg_f32(&mut seed)).collect();
    let row = row_conv_bf16_at(isa);

    let scalar = row_conv_bf16_at(IsaLevel::Scalar);
    let mut want = vec![0.25f32; WIDTH];
    let mut got = vec![0.25f32; WIDTH];
    scalar(&src, &w, &mut want, WIDTH);
    row(&src, &w, &mut got, WIDTH);
    assert_eq!(want, got, "bf16 k={k} at {isa} diverges from scalar");

    let mut dst = vec![0.25f32; WIDTH];
    let stats = bench_quick(|| {
        row(&src, &w, &mut dst, WIDTH);
        dst[0]
    });
    stats.gflops((2 * k * WIDTH) as u64)
}

fn write_simd_json(path: &str, records: &[SimdRecord]) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "[")?;
    for (i, r) in records.iter().enumerate() {
        let sep = if i + 1 == records.len() { "" } else { "," };
        writeln!(
            f,
            "  {{\"bench\": \"simd\", \"kernel\": \"{}\", \"isa\": \"{}\", \"k\": {}, \
             \"width\": {WIDTH}, \"gflops\": {:.4}, \"peak_gflops\": {:.4}, \
             \"roofline_frac\": {:.4}}}{sep}",
            r.kernel, r.isa.name(), r.k, r.gflops, r.peak_gflops, r.gflops / r.peak_gflops
        )?;
    }
    writeln!(f, "]")?;
    Ok(())
}

fn main() {
    let levels = IsaLevel::available_levels();
    println!(
        "detected {} — racing {} level(s): {}",
        IsaLevel::detected(),
        levels.len(),
        levels.iter().map(|l| l.name()).collect::<Vec<_>>().join(", ")
    );

    let mut table = Table::new(
        format!("row microkernels vs per-ISA FMA roof ({WIDTH}-wide row, single thread)"),
        &["kernel", "k", "isa", "GFLOP/s", "peak", "frac"],
    );
    let mut records = Vec::new();
    let series: [(&str, Option<RowKernel>, usize); 6] = [
        ("custom3", Some(RowKernel::Custom), 3),
        ("custom5", Some(RowKernel::Custom), 5),
        ("generic", Some(RowKernel::Generic), 9),
        ("compound", Some(RowKernel::Compound), 33),
        ("q8", None, 9),
        ("bf16", None, 9),
    ];
    for (kernel, family, k) in series {
        for &isa in &levels {
            let gflops = match (kernel, family) {
                ("q8", _) => bench_q8(k, isa),
                ("bf16", _) => bench_bf16(k, isa),
                (_, Some(fam)) => bench_f32(fam, k, isa),
                _ => unreachable!("f32 series carry a family"),
            };
            let peak = isa_peak(isa).expect("available level has a roof").gflops;
            table.row(vec![
                kernel.to_string(),
                k.to_string(),
                isa.name().to_string(),
                f3(gflops),
                f3(peak),
                f3(gflops / peak),
            ]);
            records.push(SimdRecord { kernel, isa, k, gflops, peak_gflops: peak });
        }
    }
    println!("{}", table.render());
    write_simd_json("target/reports/BENCH_simd.json", &records).expect("json");
    println!("records in target/reports/BENCH_simd.json");
}
