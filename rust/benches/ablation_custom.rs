//! BENCH — ablation for paper §2: "custom implementations are indeed
//! faster than their generic counterparts" at k = 3 and k = 5.
//!
//! Measures both the raw row kernels (isolated inner loop) and the full
//! 2-D convolution with each row kernel forced.

use swconv::harness::report::{f3, Table};
use swconv::harness::timing::bench;
use swconv::kernels::rowconv::{
    row_conv_compound, row_conv_custom3, row_conv_custom5, row_conv_generic,
};
use swconv::kernels::sliding2d::{conv2d_sliding, SlideVariant};
use swconv::kernels::Conv2dParams;
use swconv::simd::LANES;
use swconv::tensor::{pad_row, Tensor};

fn bench_row(kernel: fn(&[f32], &[f32], &mut [f32], usize), k: usize) -> f64 {
    let out_len = 4096;
    let raw: Vec<f32> = (0..out_len + k).map(|i| (i % 17) as f32 * 0.1).collect();
    let src = pad_row(&raw, 0, LANES + k, 0.0);
    let w: Vec<f32> = (0..k).map(|i| 0.2 + i as f32 * 0.05).collect();
    let mut dst = vec![0.0f32; out_len];
    bench(|| {
        kernel(&src, &w, &mut dst, out_len);
        dst[0]
    })
    .secs()
}

fn main() {
    // Raw row kernels.
    let mut t = Table::new(
        "Ablation — row kernel time per 4096-column row (lower is better)",
        &["k", "custom_us", "generic_us", "compound_us", "generic/custom", "compound/custom"],
    );
    for (k, custom) in [
        (3usize, row_conv_custom3 as fn(&[f32], &[f32], &mut [f32], usize)),
        (5, row_conv_custom5),
    ] {
        let tc = bench_row(custom, k);
        let tg = bench_row(row_conv_generic, k);
        let tp = bench_row(row_conv_compound, k);
        t.row(vec![
            k.to_string(),
            f3(tc * 1e6),
            f3(tg * 1e6),
            f3(tp * 1e6),
            f3(tg / tc),
            f3(tp / tc),
        ]);
    }
    println!("{}", t.render());
    t.write_csv("target/reports/ablation_custom_row.csv").expect("csv");

    // Full 2-D convolution with each variant forced.
    let mut t2 = Table::new(
        "Ablation — full conv2d (c=4, 64x64), auto(custom) vs forced generic/compound",
        &["k", "t_auto_ms", "t_generic_ms", "t_compound_ms"],
    );
    for k in [3usize, 5] {
        let x = Tensor::rand_uniform(&[1, 4, 64, 64], -1.0, 1.0, k as u64);
        let w = Tensor::rand_uniform(&[4, 4, k, k], -1.0, 1.0, 9);
        let p = Conv2dParams::default();
        let ta = bench(|| conv2d_sliding(&x, &w, None, &p, SlideVariant::Auto)).secs();
        let tg = bench(|| conv2d_sliding(&x, &w, None, &p, SlideVariant::Generic)).secs();
        let tc = bench(|| conv2d_sliding(&x, &w, None, &p, SlideVariant::Compound)).secs();
        t2.row(vec![k.to_string(), f3(ta * 1e3), f3(tg * 1e3), f3(tc * 1e3)]);
    }
    println!("{}", t2.render());
    t2.write_csv("target/reports/ablation_custom_conv.csv").expect("csv");
}
