//! BENCH — the whole-model planner's payoff: every zoo model served
//! three ways on identical weights — `planned` (the planner's per-layer
//! algorithm × worker-split choices, at three memory budgets from the
//! feasibility floor up to unbudgeted), `greedy-tuned` (per-kernel
//! tuned dispatch from the autotune cache when one exists — the
//! no-whole-model-view baseline) and `paper-policy` (the paper's fixed
//! k-threshold dispatch). The planner's thesis is that a layer-wise
//! view beats greedy per-kernel choices under a memory cap: the low-mem
//! strip GEMM and narrower worker splits trade predicted throughput for
//! footprint only where the budget forces it. The planned rows run
//! under a GEMM-routed ctx — the family where f32 planning has a real
//! algorithm lever (one-shot ↔ strip; int8 roams the full kernel set
//! whatever the ctx routes).
//!
//! Parity is asserted before anything is timed: every planned plan must
//! equal the default compiled plan bit-for-bit under its own ctx (f32
//! and i8), or the bench aborts. The tuned/paper baselines run other
//! FP-summation families, so their gate is exact for i8 (integer
//! accumulation has one right answer) and the kernel-equivalence
//! tolerance for f32.
//!
//! Emits `target/reports/BENCH_plan.json` (schema:
//! [`swconv::harness::report::PlanBenchRecord`]) with `bench` =
//! `"plan"`: one `planned` record per budget plus one `greedy-tuned`
//! and one `paper-policy` record per (model, dtype).

use std::sync::Arc;
use swconv::autotune::{default_profile_path, DispatchProfile};
use swconv::graph::{min_feasible_budget, plan_model};
use swconv::harness::report::{dur, f3, write_plan_bench_json, PlanBenchRecord, Table};
use swconv::harness::timing::bench;
use swconv::kernels::ConvAlgo;
use swconv::nn::{zoo, ExecCtx};
use swconv::tensor::{Dtype, Tensor};

const BATCH: usize = 2;
const THREADS: usize = 4;
/// Cross-algorithm f32 tolerance — the kernel-equivalence suite's bound.
const CROSS_ALGO_TOL: f32 = 3e-3;

fn assert_parity(got: &Tensor, want: &Tensor, dtype: Dtype, what: &str) {
    assert_eq!(got.dims(), want.dims(), "{what}: shape");
    if dtype == Dtype::I8 {
        // Exact integer accumulation: every route agrees bit for bit.
        assert_eq!(got.as_slice(), want.as_slice(), "{what}: i8 must be exact");
    } else {
        let d = got
            .as_slice()
            .iter()
            .zip(want.as_slice())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(d < CROSS_ALGO_TOL, "{what}: max |diff| {d} over {CROSS_ALGO_TOL}");
    }
}

fn main() {
    let mut t = Table::new(
        format!("Whole-model planner vs greedy dispatch (batch {BATCH}, {THREADS} threads)"),
        &["model", "dtype", "policy", "budget", "pred peak", "pred GF/s", "median", "GF/s"],
    );
    let mut records: Vec<PlanBenchRecord> = Vec::new();
    // greedy-tuned dispatches from the machine's autotune cache when one
    // has been measured; otherwise it degrades to the paper policy (the
    // bench still contrasts whole-model vs per-kernel routing).
    let tuned_profile = Arc::new(DispatchProfile::load_or_paper(default_profile_path()));
    let paper_profile = Arc::new(DispatchProfile::paper_policy());
    for name in zoo::MODEL_NAMES {
        let m = zoo::by_name(name, 10, 42).unwrap();
        let mut shape = vec![BATCH];
        shape.extend_from_slice(&m.input_shape);
        let x = Tensor::randn(&shape, 1);
        for dtype in [Dtype::F32, Dtype::I8] {
            let ctx = ExecCtx::with_threads(ConvAlgo::Im2colGemm, THREADS).with_dtype(dtype);
            let compiled = m.compile();
            let want = compiled.run(&x, &ctx);
            let flops = compiled.flops(BATCH);

            // The three budgets: the feasibility floor, halfway to the
            // unbudgeted peak, and unbounded (0 in the JSON).
            let floor = min_feasible_budget(&compiled, BATCH, &ctx);
            let free = plan_model(&compiled, BATCH, &ctx, None).expect("unbudgeted plan");
            let peak = free.predicted_peak_bytes.max(floor);
            let budgets = [Some(floor), Some(floor + (peak - floor) / 2), None];
            for budget in budgets {
                let mp = plan_model(&compiled, BATCH, &ctx, budget)
                    .unwrap_or_else(|e| panic!("{name} {}: {e}", dtype.name()));
                let planned = m.compile().with_choices(mp.choices.clone());
                // Parity gate: a planned plan must reproduce its own
                // ctx's default route bit for bit, f32 and i8 alike —
                // timing a wrong answer is worse than none.
                assert_eq!(
                    planned.run(&x, &ctx).as_slice(),
                    want.as_slice(),
                    "{name} {} budget {budget:?}: planned parity",
                    dtype.name()
                );
                let stats = bench(|| planned.run(&x, &ctx));
                t.row(vec![
                    name.into(),
                    dtype.name().into(),
                    "planned".into(),
                    budget.map_or("-".into(), |b| format!("{:.0}KiB", b as f64 / 1024.0)),
                    format!("{:.0}KiB", mp.predicted_peak_bytes as f64 / 1024.0),
                    f3(mp.predicted_gflops()),
                    dur(stats.median),
                    f3(stats.gflops(flops)),
                ]);
                records.push(PlanBenchRecord {
                    bench: "plan".into(),
                    model: name.into(),
                    policy: "planned".into(),
                    dtype: dtype.name().into(),
                    threads: THREADS,
                    budget_bytes: budget.unwrap_or(0),
                    predicted_peak_bytes: mp.predicted_peak_bytes,
                    predicted_gflops: mp.predicted_gflops(),
                    ns_per_iter: stats.median.as_secs_f64() * 1e9,
                    gflops: stats.gflops(flops),
                });
            }

            for (policy, profile) in
                [("greedy-tuned", &tuned_profile), ("paper-policy", &paper_profile)]
            {
                let mut pctx =
                    ExecCtx::with_threads(ConvAlgo::Tuned, THREADS).with_dtype(dtype);
                pctx.set_profile(Arc::clone(profile));
                assert_parity(
                    &compiled.run(&x, &pctx),
                    &want,
                    dtype,
                    &format!("{name} {}: {policy}", dtype.name()),
                );
                let stats = bench(|| compiled.run(&x, &pctx));
                t.row(vec![
                    name.into(),
                    dtype.name().into(),
                    policy.into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    dur(stats.median),
                    f3(stats.gflops(flops)),
                ]);
                records.push(PlanBenchRecord {
                    bench: "plan".into(),
                    model: name.into(),
                    policy: policy.into(),
                    dtype: dtype.name().into(),
                    threads: THREADS,
                    budget_bytes: 0,
                    predicted_peak_bytes: 0,
                    predicted_gflops: 0.0,
                    ns_per_iter: stats.median.as_secs_f64() * 1e9,
                    gflops: stats.gflops(flops),
                });
            }
        }
    }
    println!("{}", t.render());
    write_plan_bench_json("target/reports/BENCH_plan.json", &records).expect("json");
    eprintln!("wrote target/reports/BENCH_plan.json ({} records)", records.len());
}
