//! BENCH — Paper Fig. 1: speedup of 2-D Sliding Window convolution over
//! the im2col+GEMM (MlasConv-style) baseline, as a function of filter
//! size. Single core, NCHW f32, c=4 channels, 64x64 images (and a second
//! 128x128 single-channel series like the paper's large-image regime).
//!
//! Expected shape (paper): speedup > 1 everywhere, growing roughly
//! logarithmically with k; custom kernels (k=3,5) above the generic
//! trend; zigzag in the compound regime from hardware-vector alignment.

use swconv::harness::report::{f3, Table};
use swconv::harness::sweep::{default_k_grid, fig1_speedup_sweep};
use swconv::harness::ConvCase;

fn run(title: &str, c: usize, hw: usize, csv: &str) {
    let ks = default_k_grid();
    let rows = fig1_speedup_sweep(&ks, |k| ConvCase::square(c, hw, k));
    let mut t = Table::new(
        title,
        &["k", "kernel", "t_gemm_ms", "t_sliding_ms", "t_generic_ms", "t_compound_ms", "speedup"],
    );
    for r in &rows {
        t.row(vec![
            r.k.to_string(),
            r.kernel_used.into(),
            f3(r.t_gemm * 1e3),
            f3(r.t_sliding * 1e3),
            r.t_generic.map_or("-".into(), |v| f3(v * 1e3)),
            r.t_compound.map_or("-".into(), |v| f3(v * 1e3)),
            f3(r.speedup),
        ]);
    }
    println!("{}", t.render());
    t.write_csv(format!("target/reports/{csv}")).expect("csv");
}

fn main() {
    run("Fig 1a — speedup vs k (c=4, 64x64)", 4, 64, "fig1_c4_64.csv");
    run("Fig 1b — speedup vs k (c=1, 128x128)", 1, 128, "fig1_c1_128.csv");
    println!("CSV series in target/reports/fig1_*.csv");
}
