//! BENCH — Paper Fig. 1: speedup of 2-D Sliding Window convolution over
//! the im2col+GEMM (MlasConv-style) baseline, as a function of filter
//! size. NCHW f32, c=4 channels, 64x64 images (and a second 128x128
//! single-channel series like the paper's large-image regime). The
//! paper's configuration is single core; a second multi-core series
//! (every hardware thread through the exec subsystem) is reported when
//! the machine has more than one.
//!
//! Expected shape (paper): speedup > 1 everywhere, growing roughly
//! logarithmically with k; custom kernels (k=3,5) above the generic
//! trend; zigzag in the compound regime from hardware-vector alignment.
//!
//! Machine-readable records land in `target/reports/BENCH_fig1.json`.

use swconv::harness::report::{f3, write_bench_json, BenchRecord, Table};
use swconv::harness::sweep::{default_k_grid, fig1_speedup_sweep};
use swconv::harness::ConvCase;

fn run(
    title: &str,
    c: usize,
    hw: usize,
    threads: usize,
    csv: &str,
    records: &mut Vec<BenchRecord>,
) {
    let ks = default_k_grid();
    // One workload builder shared by the sweep and the JSON records, so
    // the recorded shape/flops always describe what was actually timed.
    let make_case = |k| ConvCase::square(c, hw, k);
    let rows = fig1_speedup_sweep(&ks, threads, make_case);
    let mut t = Table::new(
        title,
        &["k", "kernel", "t_gemm_ms", "t_sliding_ms", "t_generic_ms", "t_compound_ms", "speedup"],
    );
    for r in &rows {
        t.row(vec![
            r.k.to_string(),
            r.kernel_used.into(),
            f3(r.t_gemm * 1e3),
            f3(r.t_sliding * 1e3),
            r.t_generic.map_or("-".into(), |v| f3(v * 1e3)),
            r.t_compound.map_or("-".into(), |v| f3(v * 1e3)),
            f3(r.speedup),
        ]);
        let case = make_case(r.k);
        let flops = case.flops() as f64;
        let mut push = |algo: &str, secs: f64| {
            records.push(BenchRecord {
                bench: "fig1".into(),
                algo: algo.into(),
                shape: case.id(),
                threads,
                replicas: 1,
                ns_per_iter: secs * 1e9,
                gflops: flops / secs / 1e9,
            });
        };
        push("gemm", r.t_gemm);
        push("sliding", r.t_sliding);
        if let Some(s) = r.t_generic {
            push("sliding-generic", s);
        }
        if let Some(s) = r.t_compound {
            push("sliding-compound", s);
        }
    }
    println!("{}", t.render());
    t.write_csv(format!("target/reports/{csv}")).expect("csv");
}

fn main() {
    let all = swconv::exec::available_threads();
    let mut records = Vec::new();
    run("Fig 1a — speedup vs k (c=4, 64x64, 1 thread)", 4, 64, 1, "fig1_c4_64.csv", &mut records);
    run(
        "Fig 1b — speedup vs k (c=1, 128x128, 1 thread)",
        1,
        128,
        1,
        "fig1_c1_128.csv",
        &mut records,
    );
    if all > 1 {
        run(
            &format!("Fig 1a' — speedup vs k (c=4, 64x64, {all} threads)"),
            4,
            64,
            all,
            "fig1_c4_64_mt.csv",
            &mut records,
        );
    }
    write_bench_json("target/reports/BENCH_fig1.json", &records).expect("json");
    println!("CSV series in target/reports/fig1_*.csv; records in target/reports/BENCH_fig1.json");
}
