//! BENCH — end-to-end model forward passes: every zoo model with GEMM vs
//! Sliding Window convolutions on identical weights. This is the paper's
//! §1.2/§3 discussion quantified: 1x1-heavy nets (SqueezeNet fires,
//! MobileNet pointwise) benefit least; the large-filter net benefits most.

use swconv::harness::report::{dur, f3, Table};
use swconv::harness::timing::bench;
use swconv::kernels::ConvAlgo;
use swconv::nn::{zoo, ExecCtx};
use swconv::tensor::Tensor;

fn main() {
    let mut t = Table::new(
        "Model forward (batch 4): GEMM vs Sliding",
        &["model", "MFLOP", "t_gemm", "t_sliding", "t_direct", "sliding_speedup"],
    );
    // One ctx per algorithm for the whole bench: scratch arenas warm up
    // once and are recycled across models and iterations (the serving
    // configuration) instead of re-allocating per model.
    let gemm = ExecCtx::new(ConvAlgo::Im2colGemm);
    let sliding = ExecCtx::new(ConvAlgo::Sliding);
    let direct = ExecCtx::new(ConvAlgo::Direct);
    for name in zoo::MODEL_NAMES {
        let m = zoo::by_name(name, 10, 42).unwrap();
        let mut shape = vec![4];
        shape.extend_from_slice(&m.input_shape);
        let x = Tensor::randn(&shape, 1);
        let tg = bench(|| m.forward(&x, &gemm)).median;
        let ts = bench(|| m.forward(&x, &sliding)).median;
        let td = bench(|| m.forward(&x, &direct)).median;
        t.row(vec![
            name.into(),
            f3(m.flops(4) as f64 / 1e6),
            dur(tg),
            dur(ts),
            dur(td),
            f3(tg.as_secs_f64() / ts.as_secs_f64()),
        ]);
    }
    println!("{}", t.render());
    t.write_csv("target/reports/e2e_models.csv").expect("csv");
}
