//! BENCH — spawn-per-region scoped threads vs the persistent worker
//! pool, across plane sizes.
//!
//! The paper's sliding kernels win on the *small* layers, exactly where
//! a per-region thread spawn (~10 µs) is a visible fraction of the
//! convolution itself; on big planes the spawn amortises away. This
//! bench runs the same k=3 sliding convolution on square planes from
//! 16×16 to 512×512, once on an `ExecCtx` that spawns scoped threads
//! per parallel region (`without_pool`, the pre-pool behaviour) and once
//! on the persistent pool (the default path), asserting first that both
//! produce bit-identical outputs.
//!
//! ## `BENCH_pool.json` schema
//!
//! Machine-readable records land in `target/reports/BENCH_pool.json` —
//! the shared `BENCH_*.json` array schema (see
//! [`swconv::harness::report::BenchRecord`]) with `bench` = `"pool"`,
//! `algo` ∈ {`"scoped"`, `"pooled"`} and `shape` a `ConvCase::id`. Both
//! series run the identical kernel at the identical thread count, so
//! `ns_per_iter(scoped) - ns_per_iter(pooled)` is the per-region
//! threading overhead the pool retires.

use swconv::exec::{available_threads, ExecCtx, WorkerPool};
use swconv::harness::report::{f3, write_bench_json, BenchRecord, Table};
use swconv::harness::timing::bench_quick;
use swconv::harness::ConvCase;
use swconv::kernels::{conv2d_ctx, ConvAlgo};

const C: usize = 4;
const K: usize = 3;
const HWS: [usize; 6] = [16, 32, 64, 128, 256, 512];

fn main() {
    // Overhead only shows with real fan-out; 1 hardware thread still
    // runs (trivially — both paths execute inline) so CI stays green.
    let threads = available_threads().clamp(2, 8);
    let mut table = Table::new(
        format!("per-region threading overhead — c{C} k{K}, {threads} threads"),
        &["plane", "scoped", "pooled", "pooled speedup"],
    );
    let mut records = Vec::new();
    // One ctx per series for the whole sweep: the worker pool spawns
    // once and the arenas warm once, instead of paying a fresh pool
    // spawn + cold scratch per plane size.
    let scoped_ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads).without_pool();
    let pooled_ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads)
        .with_pool(WorkerPool::new(threads.saturating_sub(1).max(1)));
    for &hw in &HWS {
        let case = ConvCase::square(C, hw, K);
        let flops = case.flops();
        let x = case.input();
        let w = case.weights();

        // The acceptance gate before any timing: pooled and scoped
        // execution are the same computation, bit for bit.
        let a = conv2d_ctx(&x, &w, None, &case.params, &scoped_ctx);
        let b = conv2d_ctx(&x, &w, None, &case.params, &pooled_ctx);
        assert_eq!(a.as_slice(), b.as_slice(), "hw={hw}: pooled != scoped");

        let scoped =
            bench_quick(|| conv2d_ctx(&x, &w, None, &case.params, &scoped_ctx)).gflops(flops);
        let pooled =
            bench_quick(|| conv2d_ctx(&x, &w, None, &case.params, &pooled_ctx)).gflops(flops);

        table.row(vec![
            format!("{hw}x{hw}"),
            f3(scoped),
            f3(pooled),
            f3(pooled / scoped),
        ]);
        for (algo, gflops) in [("scoped", scoped), ("pooled", pooled)] {
            records.push(BenchRecord {
                bench: "pool".into(),
                algo: algo.into(),
                shape: case.id(),
                threads,
                replicas: 1,
                // flops [FLOP] / gflops [1e9 FLOP/s] = 1e-9 s = 1 ns units.
                ns_per_iter: flops as f64 / gflops,
                gflops,
            });
        }
    }
    println!("{}", table.render());
    println!(
        "speedup > 1 means the persistent pool beat spawn-per-region; \
         expect the gap to be largest on the smallest planes"
    );
    write_bench_json("target/reports/BENCH_pool.json", &records).expect("json");
    println!("records in target/reports/BENCH_pool.json");
}
