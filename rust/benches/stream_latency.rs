//! BENCH — streaming inference latency: O(taps) incremental frame
//! updates vs recomputing the full window every frame.
//!
//! The paper's closing argument is low-power/edge deployment; the
//! streaming session is how the sliding-window kernels serve there —
//! each new sample costs one window-kernel call per conv stage plus an
//! O(1) running-sum update per pooling stage, instead of a full batch
//! forward over the whole signal (what a naive streamer pays per
//! frame). This bench feeds the `edge-audio` zoo model one sample at a
//! time and reports per-frame p50/p99/mean for both modes, in f32 and
//! int8.
//!
//! Parity is asserted before anything is timed: the streamed output
//! must equal the batch path — bit for bit in i8 (edge-audio is
//! avg-pool-free), within the session's derived bound in f32 — or the
//! bench aborts. Timing a wrong answer is worse than no answer.
//!
//! Emits `target/reports/BENCH_stream.json` (schema:
//! [`swconv::harness::report::StreamBenchRecord`]) with `bench` =
//! `"stream"` and one `"incremental"`/`"full"` record pair per dtype.

use std::time::{Duration, Instant};
use swconv::harness::report::{dur, f3, write_stream_bench_json, StreamBenchRecord, Table};
use swconv::kernels::ConvAlgo;
use swconv::nn::{zoo, ExecCtx};
use swconv::stream::StreamSession;
use swconv::tensor::{Dtype, Tensor};

const MODEL: &str = "edge-audio";
/// Full-recompute samples: each one is a whole batch forward, so a
/// handful gives a stable per-frame figure for the naive streamer.
const FULL_REPS: usize = 48;

fn pctl(sorted: &[Duration], p: f64) -> Duration {
    sorted[((sorted.len() - 1) as f64 * p).round() as usize]
}

fn mean(xs: &[Duration]) -> Duration {
    xs.iter().sum::<Duration>() / xs.len() as u32
}

fn main() {
    let mut table = Table::new(
        format!("streaming latency — {MODEL}, 1 thread: incremental advance vs full recompute"),
        &["dtype", "mode", "p50", "p99", "mean", "speedup@p50"],
    );
    let mut records = Vec::new();
    for dtype in [Dtype::F32, Dtype::I8] {
        let model = zoo::by_name(MODEL, 10, 42).unwrap();
        let c_in = model.input_shape[0];
        let frames = model.input_shape[2];
        let ctx = ExecCtx::new(ConvAlgo::Sliding).with_dtype(dtype);
        let mut sess = StreamSession::new(&model, ctx).expect("edge-audio must stream");
        let signal = Tensor::randn(&[1, c_in, 1, frames], 7);
        let s = signal.as_slice();
        let mut col = vec![0.0f32; c_in];

        // Parity gate: streamed must equal the batch path before any
        // number is trusted.
        let mut streamed: Vec<Vec<f32>> = Vec::new();
        for t in 0..frames {
            for (c, v) in col.iter_mut().enumerate() {
                *v = s[c * frames + t];
            }
            streamed.extend(sess.advance(&col));
        }
        streamed.extend(sess.flush());
        let reference = sess.run_batch(&signal);
        let t_out = reference.dim(3);
        assert_eq!(streamed.len(), t_out, "{}: streamed column count", dtype.name());
        let r = reference.as_slice();
        let mut maxd = 0.0f32;
        for (t, c2) in streamed.iter().enumerate() {
            for (c, &v) in c2.iter().enumerate() {
                maxd = maxd.max((v - r[c * t_out + t]).abs());
            }
        }
        if sess.is_bit_exact() {
            assert_eq!(maxd, 0.0, "{}: streamed != batch bit-for-bit", dtype.name());
        } else {
            let tol = sess.tolerance();
            assert!(maxd <= tol, "{}: diff {maxd:.3e} > bound {tol:.3e}", dtype.name());
        }

        // Incremental: one advance per frame, timed individually.
        sess.reset();
        let mut inc = Vec::with_capacity(frames);
        for t in 0..frames {
            for (c, v) in col.iter_mut().enumerate() {
                *v = s[c * frames + t];
            }
            let t0 = Instant::now();
            let _ = sess.advance(&col);
            inc.push(t0.elapsed());
        }
        inc.sort();

        // Full recompute: the naive streamer pays one whole batch
        // forward per frame; each sample here is that per-frame cost.
        let mut full = Vec::with_capacity(FULL_REPS);
        for _ in 0..FULL_REPS {
            let t0 = Instant::now();
            let _ = sess.run_batch(&signal);
            full.push(t0.elapsed());
        }
        full.sort();

        let speedup = pctl(&full, 0.5).as_secs_f64() / pctl(&inc, 0.5).as_secs_f64().max(1e-12);
        assert!(
            speedup > 1.0,
            "{}: incremental p50 must beat full recompute (got {speedup:.2}x)",
            dtype.name()
        );
        for (mode, lat, cell) in [
            ("incremental", &inc, f3(speedup)),
            ("full", &full, "1.000".to_string()),
        ] {
            table.row(vec![
                dtype.name().into(),
                mode.into(),
                dur(pctl(lat, 0.50)),
                dur(pctl(lat, 0.99)),
                dur(mean(lat)),
                cell,
            ]);
            records.push(StreamBenchRecord {
                bench: "stream".into(),
                model: MODEL.into(),
                dtype: dtype.name().into(),
                mode: mode.into(),
                threads: 1,
                frames: lat.len(),
                p50_ns: pctl(lat, 0.50).as_secs_f64() * 1e9,
                p99_ns: pctl(lat, 0.99).as_secs_f64() * 1e9,
                mean_ns: mean(lat).as_secs_f64() * 1e9,
            });
        }
    }
    println!("{}", table.render());
    write_stream_bench_json("target/reports/BENCH_stream.json", &records).expect("json");
    eprintln!("wrote target/reports/BENCH_stream.json ({} records)", records.len());
}
