//! BENCH — replica sharding vs kernel threads on the fig2 workload.
//!
//! ZNNi's question (arXiv:1606.05688), asked of our serving tier: given
//! a fixed core budget T, is convolution throughput higher with
//!
//! * **1 × T** — one backend replica whose `ExecCtx` parallelizes
//!   *inside* the kernel with T threads (intra-request),
//! * **T × 1** — T single-threaded replicas, the coordinator's shard
//!   planner scattering batches across them (inter-request), or
//! * **mixed** — a middle split (replicas × threads ≈ T)?
//!
//! The workload is the paper's Fig. 2 point (c=4, 64×64, sliding
//! kernel) at a small and a large filter size, served end-to-end through
//! the coordinator (router → batcher → shard planner → replicas), so
//! dispatch and reassembly overheads are included — this is the serving
//! answer, not the kernel answer.
//!
//! Machine-readable records land in
//! `target/reports/BENCH_fig2_sharding.json` (the `replicas` field
//! distinguishes the splits).

use std::time::{Duration, Instant};
use swconv::coordinator::{Backend, BackendSpec, BatchPolicy, Coordinator};
use swconv::error::Result;
use swconv::exec::{available_threads, ExecCtx};
use swconv::harness::report::{f3, write_bench_json, BenchRecord, Table};
use swconv::harness::ConvCase;
use swconv::kernels::{conv2d_ctx, ConvAlgo};
use swconv::tensor::Tensor;

const C: usize = 4;
const HW: usize = 64;
const KS: [usize; 2] = [5, 17];
const N_REQUESTS: usize = 96;

/// A fig2 convolution as a serving backend: one conv over the batch.
struct ConvBackend {
    case: ConvCase,
    w: Tensor,
    ctx: ExecCtx,
    item_shape: Vec<usize>,
}

impl ConvBackend {
    fn new(k: usize, threads: usize) -> Self {
        let case = ConvCase::square(C, HW, k);
        let w = case.weights();
        ConvBackend {
            item_shape: vec![case.c_in, case.h, case.w],
            w,
            ctx: ExecCtx::with_threads(ConvAlgo::Sliding, threads),
            case,
        }
    }
}

impl Backend for ConvBackend {
    fn name(&self) -> &str {
        "fig2-conv"
    }

    fn item_shape(&self) -> &[usize] {
        &self.item_shape
    }

    fn infer(&mut self, batch: &Tensor) -> Result<Tensor> {
        Ok(conv2d_ctx(batch, &self.w, None, &self.case.params, &self.ctx))
    }
}

/// Serve `N_REQUESTS` single-item requests through a coordinator with
/// the given core-budget split; returns (wall seconds, GFLOP/s).
/// `max_batch` is passed in so every split runs under the *same* batch
/// policy — otherwise batching amortisation would confound the
/// intra-vs-inter comparison this bench exists to make.
fn run_config(k: usize, replicas: usize, threads: usize, max_batch: usize) -> (f64, f64) {
    let case = ConvCase::square(C, HW, k);
    let spec = BackendSpec::from_factory(
        "conv",
        vec![case.c_in, case.h, case.w],
        move |_replica| Ok(Box::new(ConvBackend::new(k, threads)) as Box<dyn Backend>),
    )
    .with_replicas(replicas);
    let coord = Coordinator::new(
        vec![spec],
        BatchPolicy { max_batch, max_wait: Duration::from_millis(1) },
    );

    let input = case.input().reshape(&[case.c_in, case.h, case.w]);
    // Warm up every replica's scratch arena (and fault in the weights).
    let warm: Vec<_> = (0..replicas * 2)
        .map(|_| coord.submit("conv", input.clone()).unwrap())
        .collect();
    for rx in warm {
        rx.recv().unwrap().output.unwrap();
    }

    let t0 = Instant::now();
    let rxs: Vec<_> = (0..N_REQUESTS)
        .map(|_| coord.submit("conv", input.clone()).unwrap())
        .collect();
    for rx in rxs {
        rx.recv().unwrap().output.unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    coord.shutdown();

    let gflops = case.flops() as f64 * N_REQUESTS as f64 / wall / 1e9;
    (wall, gflops)
}

fn main() {
    let t = available_threads();
    // The three core-budget splits the ROADMAP asks to compare. On a
    // single-core machine the splits coincide but are still emitted so
    // the JSON schema is stable across machines.
    let mixed_r = if t >= 4 { 2 } else { t.max(1) };
    let configs: [(&str, usize, usize); 3] = [
        ("1xT (intra)", 1, t),
        ("Tx1 (inter)", t, 1),
        ("mixed", mixed_r, (t / mixed_r).max(1)),
    ];

    // One batch policy for every split: big enough for the T-replica
    // config to scatter across the whole tier.
    let max_batch = (t * 4).max(8);
    println!(
        "core budget: {t} hardware thread(s); {N_REQUESTS} requests per config, \
         max_batch {max_batch}\n"
    );
    let mut table = Table::new(
        format!("fig2 sharding — replicas x threads on c{C}_{HW}x{HW} sliding conv"),
        &["k", "split", "replicas", "threads", "wall_s", "GFLOP/s", "req/s"],
    );
    let mut records = Vec::new();
    for &k in &KS {
        for &(label, replicas, threads) in &configs {
            let (wall, gflops) = run_config(k, replicas, threads, max_batch);
            let case = ConvCase::square(C, HW, k);
            table.row(vec![
                k.to_string(),
                label.into(),
                replicas.to_string(),
                threads.to_string(),
                f3(wall),
                f3(gflops),
                f3(N_REQUESTS as f64 / wall),
            ]);
            records.push(BenchRecord {
                bench: "fig2_sharding".into(),
                algo: "sliding".into(),
                shape: case.id(),
                threads,
                replicas,
                ns_per_iter: wall * 1e9 / N_REQUESTS as f64,
                gflops,
            });
        }
    }
    println!("{}", table.render());

    // Which split won at each k (the intra-vs-inter answer for this
    // machine; recorded in ROADMAP when run on the reference box).
    for &k in &KS {
        let best = records
            .iter()
            .filter(|r| r.shape.ends_with(&format!("k{k}")))
            .max_by(|a, b| a.gflops.total_cmp(&b.gflops))
            .unwrap();
        println!(
            "k={k}: best split is {} replicas x {} threads ({} GFLOP/s)",
            best.replicas,
            best.threads,
            f3(best.gflops)
        );
    }
    write_bench_json("target/reports/BENCH_fig2_sharding.json", &records).expect("json");
    println!("records in target/reports/BENCH_fig2_sharding.json");
}
