//! BENCH — Paper Fig. 2: arithmetic throughput (GFLOP/s) of the sliding
//! and GEMM convolution kernels vs filter size, against the measured
//! roofline (Intel-Advisor stand-in; see harness::roofline). Reported at
//! 1 thread (the paper's configuration) and, when the machine has more
//! cores, at every hardware thread through the exec subsystem — the
//! multi/single ratio is the wall-clock speedup the `ExecCtx` thread
//! pool buys.
//!
//! Expected shape (paper): sliding throughput approaches the hardware
//! limit as the filter grows; GEMM stays below it (its im2col traffic
//! caps arithmetic intensity); misalignment with the vector length shows
//! as matching dips in both series.
//!
//! Machine-readable records land in `target/reports/BENCH_fig2.json`.

use swconv::harness::report::{f3, write_bench_json, BenchRecord, Table};
use swconv::harness::sweep::{default_k_grid, fig2_throughput_sweep, Fig2Row};
use swconv::harness::{machine_peaks, ConvCase};

const C: usize = 4;
const HW: usize = 64;

// One workload builder shared by the sweeps and the JSON records, so the
// recorded shape/flops always describe what was actually timed.
fn make_case(k: usize) -> ConvCase {
    ConvCase::square(C, HW, k)
}

fn push_records(rows: &[Fig2Row], records: &mut Vec<BenchRecord>) {
    for r in rows {
        let case = make_case(r.k);
        let flops = case.flops() as f64;
        for (algo, gflops) in [("sliding", r.sliding_gflops), ("gemm", r.gemm_gflops)] {
            records.push(BenchRecord {
                bench: "fig2".into(),
                algo: algo.into(),
                shape: case.id(),
                threads: r.threads,
                replicas: 1,
                ns_per_iter: flops / gflops, // flops / (gflop/s * 1e9) * 1e9 ns
                gflops,
            });
        }
    }
}

fn main() {
    let peaks = machine_peaks();
    println!(
        "machine: {:.2} GFLOP/s peak, {:.2} GB/s bandwidth, ridge {:.2} FLOP/B\n",
        peaks.gflops,
        peaks.bandwidth_gbs,
        peaks.ridge()
    );
    let ks = default_k_grid();
    let all = swconv::exec::available_threads();

    let rows1 = fig2_throughput_sweep(&ks, 1, make_case);
    let rows_mt = if all > 1 {
        Some(fig2_throughput_sweep(&ks, all, make_case))
    } else {
        None
    };

    let mt_note = if all > 1 {
        format!("; xN = {all}-thread speedup")
    } else {
        String::new()
    };
    let mut t = Table::new(
        format!("Fig 2 — throughput GFLOP/s (c={C}, {HW}x{HW}{mt_note})"),
        &["k", "sliding", "gemm", "roof(sliding)", "peak", "sliding/peak", "sliding_mt", "xN"],
    );
    for (i, r) in rows1.iter().enumerate() {
        let mt = rows_mt.as_ref().map(|rs| rs[i].sliding_gflops);
        t.row(vec![
            r.k.to_string(),
            f3(r.sliding_gflops),
            f3(r.gemm_gflops),
            f3(r.sliding_roof),
            f3(r.peak),
            f3(r.sliding_gflops / r.peak),
            mt.map_or("-".into(), f3),
            mt.map_or("-".into(), |m| f3(m / r.sliding_gflops)),
        ]);
    }
    println!("{}", t.render());
    t.write_csv("target/reports/fig2_c4_64.csv").expect("csv");

    let mut records = Vec::new();
    push_records(&rows1, &mut records);
    if let Some(rs) = &rows_mt {
        push_records(rs, &mut records);
        let gm: f64 = rows1
            .iter()
            .zip(rs)
            .map(|(a, b)| (b.sliding_gflops / a.sliding_gflops).ln())
            .sum::<f64>()
            / rows1.len() as f64;
        println!(
            "geomean sliding speedup at {all} threads vs 1: {:.2}x",
            gm.exp()
        );
    }
    write_bench_json("target/reports/BENCH_fig2.json", &records).expect("json");
    println!("CSV in target/reports/fig2_c4_64.csv; records in target/reports/BENCH_fig2.json");
}
