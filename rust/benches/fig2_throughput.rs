//! BENCH — Paper Fig. 2: arithmetic throughput (GFLOP/s) of the sliding
//! and GEMM convolution kernels vs filter size, against the measured
//! roofline (Intel-Advisor stand-in; see harness::roofline).
//!
//! Expected shape (paper): sliding throughput approaches the hardware
//! limit as the filter grows; GEMM stays below it (its im2col traffic
//! caps arithmetic intensity); misalignment with the vector length shows
//! as matching dips in both series.

use swconv::harness::report::{f3, Table};
use swconv::harness::sweep::{default_k_grid, fig2_throughput_sweep};
use swconv::harness::{machine_peaks, ConvCase};

fn main() {
    let peaks = machine_peaks();
    println!(
        "machine: {:.2} GFLOP/s peak, {:.2} GB/s bandwidth, ridge {:.2} FLOP/B\n",
        peaks.gflops,
        peaks.bandwidth_gbs,
        peaks.ridge()
    );
    let ks = default_k_grid();
    let rows = fig2_throughput_sweep(&ks, |k| ConvCase::square(4, 64, k));
    let mut t = Table::new(
        "Fig 2 — throughput GFLOP/s (c=4, 64x64)",
        &["k", "sliding", "gemm", "roof(sliding)", "roof(gemm)", "peak", "sliding/peak", "gemm/peak"],
    );
    for r in &rows {
        t.row(vec![
            r.k.to_string(),
            f3(r.sliding_gflops),
            f3(r.gemm_gflops),
            f3(r.sliding_roof),
            f3(r.gemm_roof),
            f3(r.peak),
            f3(r.sliding_gflops / r.peak),
            f3(r.gemm_gflops / r.peak),
        ]);
    }
    println!("{}", t.render());
    t.write_csv("target/reports/fig2_c4_64.csv").expect("csv");
    println!("CSV in target/reports/fig2_c4_64.csv");
}
