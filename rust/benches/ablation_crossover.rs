//! BENCH — ablation for paper §2's k = 17 observation: filter width 17 can
//! be evaluated by either the in-vector generic kernel or the compound
//! kernel; the paper found the compound variant "significantly faster"
//! and flagged it worth studying. We sweep the crossover region k=13..20
//! with both kernels forced.

use swconv::harness::report::{f3, Table};
use swconv::harness::timing::bench;
use swconv::kernels::rowconv::GENERIC_MAX_K;
use swconv::kernels::sliding2d::{conv2d_sliding, SlideVariant};
use swconv::kernels::Conv2dParams;
use swconv::tensor::Tensor;

fn main() {
    let mut t = Table::new(
        "Crossover — generic (in-vector) vs compound around k=17 (c=2, 96x96)",
        &["k", "t_generic_ms", "t_compound_ms", "compound/generic", "winner"],
    );
    for k in 13..=20usize {
        let x = Tensor::rand_uniform(&[1, 2, 96, 96], -1.0, 1.0, k as u64);
        let w = Tensor::rand_uniform(&[2, 2, 3, k], -1.0, 1.0, 5);
        let p = Conv2dParams::default();
        let tg = if k <= GENERIC_MAX_K {
            Some(bench(|| conv2d_sliding(&x, &w, None, &p, SlideVariant::Generic)).secs())
        } else {
            None
        };
        let tc = bench(|| conv2d_sliding(&x, &w, None, &p, SlideVariant::Compound)).secs();
        let (ratio, winner) = match tg {
            Some(tg) => (
                f3(tc / tg),
                if tc < tg { "compound" } else { "generic" },
            ),
            None => ("-".into(), "compound (only option)"),
        };
        t.row(vec![
            k.to_string(),
            tg.map_or("-".into(), |v| f3(v * 1e3)),
            f3(tc * 1e3),
            ratio,
            winner.into(),
        ]);
    }
    println!("{}", t.render());
    t.write_csv("target/reports/ablation_crossover.csv").expect("csv");
}
