//! BENCH — baseline fairness: the blocked SGEMM substrate's standalone
//! throughput. The Fig. 1 comparison is only meaningful if the GEMM the
//! im2col path calls is a respectable fraction of machine peak on
//! conv-shaped problems (tall-skinny: M=c_out, K=c_in*k*k, N=oh*ow).

use swconv::harness::report::{f3, Table};
use swconv::harness::timing::bench;
use swconv::harness::machine_peaks;
use swconv::kernels::gemm::sgemm;
use swconv::tensor::XorShiftRng;

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut r = XorShiftRng::new(seed);
    (0..n).map(|_| r.uniform(-1.0, 1.0)).collect()
}

fn main() {
    let peaks = machine_peaks();
    println!("machine peak: {:.2} GFLOP/s\n", peaks.gflops);
    let mut t = Table::new(
        "SGEMM throughput (C += A*B)",
        &["M", "K", "N", "GFLOP/s", "frac_of_peak"],
    );
    let cases = [
        // Square problems.
        (256usize, 256usize, 256usize),
        (512, 512, 512),
        // conv-shaped: M=c_out, K=c_in*k*k, N=oh*ow.
        (8, 36, 3844),   // c=4, k=3, 64x64
        (8, 100, 3600),  // c=4, k=5
        (8, 1156, 2304), // c=4, k=17
        (32, 288, 3136), // c=32, k=3, 58x58-ish
    ];
    for (m, k, n) in cases {
        let a = rand_vec(m * k, 1);
        let b = rand_vec(k * n, 2);
        let mut c = vec![0.0f32; m * n];
        let s = bench(|| {
            c.iter_mut().for_each(|v| *v = 0.0);
            sgemm(m, k, n, &a, &b, &mut c);
            c[0]
        });
        let gf = s.gflops((2 * m * k * n) as u64);
        t.row(vec![
            m.to_string(),
            k.to_string(),
            n.to_string(),
            f3(gf),
            f3(gf / peaks.gflops),
        ]);
    }
    println!("{}", t.render());
    t.write_csv("target/reports/gemm.csv").expect("csv");
}
