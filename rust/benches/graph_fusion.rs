//! BENCH — the graph compiler's payoff: every zoo model forwarded
//! three ways on identical weights — layer by layer, through the
//! verbatim compiled plan (passes off, the `SWCONV_NO_FUSE=1` shape),
//! and through the fused plan (epilogue fusion + pad elision + quant
//! hoisting). The passes exist to cut *memory traffic* — the paper's
//! whole argument is that conv is bandwidth-bound on commodity
//! hardware — so the table reports both wall time and the plans'
//! static activation-byte accounting side by side.
//!
//! Parity is asserted before anything is timed: both plans must equal
//! the layer path bit-for-bit, or the bench aborts.
//!
//! Emits `target/reports/BENCH_graph.json` (schema:
//! [`swconv::harness::report::GraphBenchRecord`]) with `bench` =
//! `"graph"` and one `"fused"`/`"unfused"` record pair per model.

use swconv::harness::report::{dur, f3, write_graph_bench_json, GraphBenchRecord, Table};
use swconv::harness::timing::bench;
use swconv::kernels::ConvAlgo;
use swconv::nn::{zoo, ExecCtx};
use swconv::tensor::Tensor;

const BATCH: usize = 4;

fn main() {
    let mut t = Table::new(
        format!("Graph compiler: fused plan vs unfused plan vs layers (batch {BATCH}, sliding)"),
        &["model", "MFLOP", "t_layers", "t_unfused", "t_fused", "act_unfused", "act_fused", "traffic"],
    );
    let mut records: Vec<GraphBenchRecord> = Vec::new();
    // One ctx for the whole bench: scratch buffers warm up once and are
    // recycled across iterations — the serving configuration.
    let ctx = ExecCtx::new(ConvAlgo::Sliding);
    for name in zoo::MODEL_NAMES {
        let m = zoo::by_name(name, 10, 42).unwrap();
        let mut shape = vec![BATCH];
        shape.extend_from_slice(&m.input_shape);
        let x = Tensor::randn(&shape, 1);
        let fused = m.compile_with(true);
        let plain = m.compile_with(false);

        // Parity gate: timing a wrong answer is worse than no answer.
        let want = m.forward(&x, &ctx);
        assert_eq!(fused.run(&x, &ctx).as_slice(), want.as_slice(), "{name}: fused parity");
        assert_eq!(plain.run(&x, &ctx).as_slice(), want.as_slice(), "{name}: unfused parity");

        let tl = bench(|| m.forward(&x, &ctx));
        let tu = bench(|| plain.run(&x, &ctx));
        let tf = bench(|| fused.run(&x, &ctx));
        let (ub, fb) = (plain.activation_bytes(BATCH), fused.activation_bytes(BATCH));
        let flops = m.flops(BATCH);
        t.row(vec![
            name.into(),
            f3(flops as f64 / 1e6),
            dur(tl.median),
            dur(tu.median),
            dur(tf.median),
            format!("{:.1}KiB", ub as f64 / 1024.0),
            format!("{:.1}KiB", fb as f64 / 1024.0),
            format!("{:+.1}%", (fb as f64 / ub as f64 - 1.0) * 100.0),
        ]);
        for (mode, stats, bytes) in [("unfused", &tu, ub), ("fused", &tf, fb)] {
            records.push(GraphBenchRecord {
                bench: "graph".into(),
                model: name.into(),
                mode: mode.into(),
                threads: 1,
                ns_per_iter: stats.median.as_secs_f64() * 1e9,
                gflops: stats.gflops(flops),
                activation_bytes: bytes,
            });
        }
    }
    println!("{}", t.render());
    write_graph_bench_json("target/reports/BENCH_graph.json", &records).expect("json");
    eprintln!("wrote target/reports/BENCH_graph.json ({} records)", records.len());
}
