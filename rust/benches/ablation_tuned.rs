//! BENCH — measured (tuned) dispatch vs the paper's hard-coded policy.
//!
//! The paper's §2 selection (custom 3/5 → generic ≤17 → compound) is
//! calibrated to one Xeon; this ablation asks what *this* machine's
//! measured crossover table buys. It autotunes a profile in-process,
//! then times the same Fig. 1/2 workload twice per filter size:
//!
//! * **paper** — `ConvAlgo::Sliding` with no profile (the hard-coded
//!   k=17 policy, exactly what every PR before the autotuner ran), and
//! * **tuned** — `ConvAlgo::Tuned` dispatching from the measured
//!   profile (which may route a width to GEMM or direct where those
//!   actually win).
//!
//! Machine-readable records land in `target/reports/BENCH_tuned.json`
//! (the `BENCH_*.json` array-of-records schema of
//! `swconv::harness::report::write_bench_json`; `algo` is `"sliding"`
//! for the paper rows and `"tuned"` for the profiled rows). The tuned
//! series should never lose by more than noise: where the paper policy
//! is already optimal the profile picks the same kernel.

use std::sync::Arc;
use swconv::autotune::{autotune, profile_table, AutotuneOpts};
use swconv::exec::ExecCtx;
use swconv::harness::report::{f3, write_bench_json, BenchRecord, Table};
use swconv::harness::timing::bench_quick;
use swconv::harness::ConvCase;
use swconv::kernels::{conv2d_ctx, ConvAlgo};

const C: usize = 4;
const HW: usize = 64;

fn main() {
    // Measure the machine (single-threaded, the paper's configuration;
    // the profile's thread dimension is exercised by `serve --profile`).
    let opts = AutotuneOpts { c: C, hw: HW, threads: vec![1], verbose: true, ..Default::default() };
    let profile = Arc::new(autotune(&opts));
    println!("{}", profile_table(&profile).render());

    let mut table = Table::new(
        format!("tuned vs paper-policy dispatch (c{C}, {HW}x{HW}, 1 thread)"),
        &["k", "paper GFLOP/s", "tuned GFLOP/s", "tuned/paper"],
    );
    let mut records = Vec::new();
    // One ctx per series for the whole sweep: arena scratch warms once
    // and is recycled across filter sizes and timed iterations.
    let paper_ctx = ExecCtx::new(ConvAlgo::Sliding);
    let tuned_ctx = ExecCtx::new(ConvAlgo::Tuned).with_profile(Arc::clone(&profile));
    for &k in &opts.ks {
        let case = ConvCase::square(C, HW.max(k + 1), k);
        let x = case.input();
        let w = case.weights();
        let flops = case.flops();

        let paper = bench_quick(|| conv2d_ctx(&x, &w, None, &case.params, &paper_ctx))
            .gflops(flops);
        let tuned = bench_quick(|| conv2d_ctx(&x, &w, None, &case.params, &tuned_ctx))
            .gflops(flops);

        table.row(vec![
            k.to_string(),
            f3(paper),
            f3(tuned),
            f3(tuned / paper),
        ]);
        for (algo, gflops) in [("sliding", paper), ("tuned", tuned)] {
            records.push(BenchRecord {
                bench: "ablation_tuned".into(),
                algo: algo.into(),
                shape: case.id(),
                threads: 1,
                replicas: 1,
                ns_per_iter: flops as f64 / gflops, // GFLOP/s ⇒ ns = flops/gflops
                gflops,
            });
        }
    }
    println!("{}", table.render());
    write_bench_json("target/reports/BENCH_tuned.json", &records).expect("json");
    println!("records in target/reports/BENCH_tuned.json");
}
