//! BENCH — pooling as a sliding window sum (paper abstract): the log-step
//! kernels vs the naïve window loop, max and avg, window sizes 2..16.
//! Expected: the sliding kernel's advantage grows with the window (it
//! does O(log k) work per output vs O(k)).

use swconv::harness::report::{f3, Table};
use swconv::harness::timing::bench;
use swconv::kernels::pool::{avg_pool2d, avg_pool2d_naive, max_pool2d, max_pool2d_naive};
use swconv::kernels::PoolParams;
use swconv::tensor::Tensor;

fn main() {
    let x = Tensor::rand_uniform(&[1, 4, 128, 128], -1.0, 1.0, 3);
    let mut t = Table::new(
        "Pooling — log-step sliding vs naive (c=4, 128x128, stride 1)",
        &["k", "max_sliding_ms", "max_naive_ms", "max_speedup", "avg_sliding_ms", "avg_naive_ms", "avg_speedup"],
    );
    for k in [2usize, 3, 4, 5, 6, 8, 10, 12, 16] {
        let p = PoolParams::with_stride(k, 1);
        let ms = bench(|| max_pool2d(&x, &p)).secs();
        let mn = bench(|| max_pool2d_naive(&x, &p)).secs();
        let as_ = bench(|| avg_pool2d(&x, &p)).secs();
        let an = bench(|| avg_pool2d_naive(&x, &p)).secs();
        t.row(vec![
            k.to_string(),
            f3(ms * 1e3),
            f3(mn * 1e3),
            f3(mn / ms),
            f3(as_ * 1e3),
            f3(an * 1e3),
            f3(an / as_),
        ]);
    }
    println!("{}", t.render());
    t.write_csv("target/reports/pool.csv").expect("csv");
}
