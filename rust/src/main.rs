//! swconv CLI — the leader entrypoint.
//!
//! Subcommands (clap is unavailable offline; parsing is hand-rolled):
//!
//! * `bench-fig1`  — regenerate the paper's Fig. 1 (speedup vs filter size)
//! * `bench-fig2`  — regenerate Fig. 2 (throughput vs roofline)
//! * `peaks`       — measure machine compute/bandwidth ceilings
//! * `autotune`    — measure this machine's dispatch crossovers and cache
//!   them as `target/autotune/profile.json`
//! * `run-model`   — one forward pass of a zoo model, timed per algorithm
//! * `serve`       — demo serving run through the coordinator
//! * `stream`      — frame-by-frame streaming inference (O(taps) per
//!   sample): per-frame latency vs full recompute, parity against the
//!   batch path, and stateful sessions through the coordinator
//! * `plan`        — whole-model inference planner: per-layer algorithm ×
//!   worker-split choices maximizing predicted throughput under a
//!   `--mem-budget` peak-memory cap, printed with predicted vs. budget
//!   memory and predicted throughput
//! * `summary`     — layer/FLOP summary of a zoo model
//! * `compile`     — lower a zoo model into the graph IR and show the
//!   before/after of the pass pipeline (fusion, pad elision, quantize
//!   hoisting) with FLOP and activation-byte accounting, per-node
//!   activation bytes, and each fusable chain's tile geometry + the
//!   footprint policy's tiled/untiled decision
//! * `cache-info`  — print the detected cache hierarchy (sysfs probe,
//!   `SWCONV_L2_KB`/`SWCONV_L3_KB` overrides) and the tile working-set
//!   budget tiled chain execution sizes its tiles against
//! * `artifacts-check` — load every AOT artifact and cross-check numerics
//!   against the native kernels
//!
//! `bench-fig1`, `bench-fig2`, `run-model` and `serve` accept
//! `--profile <path>` to dispatch from a cached profile (a missing or
//! corrupt file falls back to the paper's policy with a warning), plus
//! `--pin <cores>` (confine/pin to a core set) and `--no-pool` (scoped
//! spawn-per-region threads instead of the persistent worker pool).
//! `autotune --dtype i8` additionally fills the profile's int8 buckets.
//! Every command accepts `--isa scalar|avx2|avx512|neon` to force the
//! instruction-set level kernels dispatch at (process-wide, via
//! [`swconv::simd::IsaLevel::force`]); results are bit-identical at
//! every level. Every command that runs compiled plans accepts
//! `--tile HxW` (or `--tile auto`, or `SWCONV_FORCE_TILE=1`) to force
//! cache-blocked tiled execution of fused conv chains — also
//! bit-identical, purely a locality/footprint lever.

use std::sync::Arc;
use std::time::{Duration, Instant};
use swconv::autotune::{
    autotune, default_profile_path, profile_table, race_tile_shapes, AutotuneOpts,
    DispatchProfile, ProfileEntry, TileCandidate,
};
use swconv::coordinator::{BackendSpec, BatchPolicy, Coordinator, PinPolicy};
use swconv::error::{anyhow, bail, Context, Result};
use swconv::exec::{affinity, pool, CoreSet};
use swconv::harness::report::{dur, f3, Table};
use swconv::harness::{
    bench, fig1_speedup_sweep_dtyped, fig2_throughput_sweep_dtyped, isa_peaks, machine_peaks,
    sweep, ConvCase,
};
use swconv::kernels::{conv2d, Conv2dParams, ConvAlgo};
use swconv::nn::{zoo, ExecCtx};
use swconv::runtime::{engine::default_artifacts_dir, Engine};
use swconv::simd::IsaLevel;
use swconv::stream::StreamSession;
use swconv::tensor::{Dtype, Tensor};

/// Flags that take no value (present = on).
const BOOL_FLAGS: [&str; 2] = ["no-pool", "no-fuse"];

/// Tiny flag parser: `--key value` pairs after the subcommand, plus the
/// valueless [`BOOL_FLAGS`].
struct Args {
    cmd: String,
    kv: Vec<(String, String)>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut kv = Vec::new();
        while let Some(k) = it.next() {
            let k = k
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{k}'"))?
                .to_string();
            if BOOL_FLAGS.contains(&k.as_str()) {
                kv.push((k, "1".to_string()));
                continue;
            }
            let v = it.next().ok_or_else(|| anyhow!("--{k} needs a value"))?;
            kv.push((k, v));
        }
        Ok(Args { cmd, kv })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn flag(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }
}

/// `--threads N` (default 1, the paper's single-core setup); `0` means
/// "all hardware threads".
fn parse_threads(args: &Args) -> Result<usize> {
    let t = args.usize("threads", 1)?;
    Ok(if t == 0 { swconv::exec::available_threads() } else { t })
}

/// `--dtype f32|bf16|i8` — the element type benches/serving run in
/// (default f32, the paper's configuration and the bit-exact baseline).
fn parse_dtype(args: &Args) -> Result<Dtype> {
    match args.get("dtype") {
        None => Ok(Dtype::F32),
        Some(s) => {
            let d = Dtype::parse(s)
                .ok_or_else(|| anyhow!("unknown dtype '{s}' (expected f32, bf16 or i8)"))?;
            if !Dtype::SERVING.contains(&d) {
                bail!("dtype '{s}' is an accumulator type, not a serving dtype");
            }
            Ok(d)
        }
    }
}

/// `--pin 0-3,8 | auto` as a serving policy: replica `i` of a tier gets
/// core slice `i`. Absent ⇒ no pinning.
fn parse_pin_policy(args: &Args) -> Result<PinPolicy> {
    match args.get("pin") {
        None => Ok(PinPolicy::None),
        Some("auto") => Ok(PinPolicy::Auto),
        Some(s) => Ok(PinPolicy::Cores(CoreSet::parse(s)?)),
    }
}

/// `--pin` for the single-process commands (benches, run-model): pin the
/// main thread to the set — lazily built pool workers and scoped threads
/// both inherit the mask, so the whole run is confined to those cores.
fn apply_pin_current(args: &Args) -> Result<()> {
    let set = match args.get("pin") {
        None => return Ok(()),
        Some("auto") => CoreSet::all(swconv::exec::available_threads()),
        Some(s) => CoreSet::parse(s)?,
    };
    if affinity::pin_current(&set) {
        eprintln!("pinned to cores {set}");
    } else {
        eprintln!("warning: could not pin to cores {set} (unsupported platform or sandbox)");
    }
    Ok(())
}

/// `--tile HxW` (any command that runs compiled plans) — force tiled
/// execution with this output-tile shape for every fusable conv/pool
/// chain. Equivalent to `SWCONV_FORCE_TILE=1` with an explicit shape;
/// `--tile auto` forces tiling with cache-budget-sized tiles. Tiled
/// execution is bit-identical to untiled, so this is a pure
/// footprint/locality lever.
fn apply_tile_flag(args: &Args) -> Result<()> {
    let Some(s) = args.get("tile") else {
        return Ok(());
    };
    if s.eq_ignore_ascii_case("auto") {
        swconv::graph::set_forced_tile_shape(None);
        swconv::graph::set_tiling_forced(true);
        eprintln!("tiled execution forced: cache-budget-sized tiles per fused chain");
        return Ok(());
    }
    let (h, w) = s
        .to_ascii_lowercase()
        .split_once('x')
        .and_then(|(a, b)| Some((a.trim().parse::<usize>().ok()?, b.trim().parse::<usize>().ok()?)))
        .filter(|&(h, w)| h > 0 && w > 0)
        .ok_or_else(|| anyhow!("--tile {s}: expected HxW (positive integers) or 'auto'"))?;
    swconv::graph::set_forced_tile_shape(Some((h, w)));
    swconv::graph::set_tiling_forced(true);
    eprintln!("tiled execution forced: {h}x{w} output tiles per fused chain");
    Ok(())
}

/// `--mem-budget 64M`-style size: plain bytes, or a binary K/M/G suffix
/// (case-insensitive, `KB`/`KiB` spellings accepted). `None` when the
/// flag is absent — an unbudgeted plan.
fn parse_mem_budget(args: &Args) -> Result<Option<u64>> {
    let Some(raw) = args.get("mem-budget") else {
        return Ok(None);
    };
    let s = raw.trim().to_ascii_lowercase();
    let (digits, mult) = match s.find(|c: char| !c.is_ascii_digit()) {
        None => (s.as_str(), 1u64),
        Some(i) => {
            let mult = match &s[i..] {
                "k" | "kb" | "kib" => 1u64 << 10,
                "m" | "mb" | "mib" => 1u64 << 20,
                "g" | "gb" | "gib" => 1u64 << 30,
                other => bail!("--mem-budget: unknown unit '{other}' (use K, M or G)"),
            };
            (&s[..i], mult)
        }
    };
    let n: u64 = digits.parse().with_context(|| format!("--mem-budget {raw}"))?;
    Ok(Some(n.saturating_mul(mult)))
}

fn parse_ks(args: &Args) -> Result<Vec<usize>> {
    match args.get("ks") {
        None => Ok(sweep::default_k_grid()),
        Some(s) => s
            .split(',')
            .map(|t| t.trim().parse::<usize>().with_context(|| format!("bad k '{t}'")))
            .collect(),
    }
}

/// `--profile PATH` — load a cached dispatch profile; a missing or
/// corrupt file degrades to the paper policy with a warning. `None`
/// when the flag is absent (pure paper-policy dispatch, no lookup).
fn parse_profile(args: &Args) -> Option<Arc<DispatchProfile>> {
    args.get("profile").map(|path| {
        let p = DispatchProfile::load_or_paper(path);
        if p.is_paper_policy() {
            eprintln!("profile {path}: dispatching with the paper's k=17 policy");
        } else {
            eprintln!("profile {path}: {} measured buckets", p.entries().len());
        }
        Arc::new(p)
    })
}

fn cmd_fig1(args: &Args) -> Result<()> {
    let c = args.usize("c", 4)?;
    let hw = args.usize("hw", 64)?;
    let threads = parse_threads(args)?;
    let ks = parse_ks(args)?;
    let profile = parse_profile(args);
    let dtype = parse_dtype(args)?;
    apply_pin_current(args)?;
    eprintln!("fig1: c={c} hw={hw} ks={ks:?} threads={threads} dtype={}", dtype.name());
    let rows =
        fig1_speedup_sweep_dtyped(&ks, threads, profile, dtype, |k| ConvCase::square(c, hw, k));
    let mut t = Table::new(
        format!(
            "Fig 1 — 2-D convolution speedup vs MlasConv-style GEMM (c={c}, {hw}x{hw}, {threads} thread(s), {})",
            dtype.name()
        ),
        &["k", "kernel", "t_gemm", "t_sliding", "t_generic", "t_compound", "speedup"],
    );
    for r in &rows {
        t.row(vec![
            r.k.to_string(),
            r.kernel_used.to_string(),
            format!("{:.3}ms", r.t_gemm * 1e3),
            format!("{:.3}ms", r.t_sliding * 1e3),
            r.t_generic.map_or("-".into(), |v| format!("{:.3}ms", v * 1e3)),
            r.t_compound.map_or("-".into(), |v| format!("{:.3}ms", v * 1e3)),
            f3(r.speedup),
        ]);
    }
    println!("{}", t.render());
    if let Some(path) = args.get("csv") {
        t.write_csv(path)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_fig2(args: &Args) -> Result<()> {
    let c = args.usize("c", 4)?;
    let hw = args.usize("hw", 64)?;
    let threads = parse_threads(args)?;
    let ks = parse_ks(args)?;
    apply_pin_current(args)?;
    let peaks = machine_peaks();
    eprintln!(
        "fig2: c={c} hw={hw} threads={threads}; machine peak {:.1} GFLOP/s, bw {:.1} GB/s, ridge {:.2} FLOP/B",
        peaks.gflops,
        peaks.bandwidth_gbs,
        peaks.ridge()
    );
    let dtype = parse_dtype(args)?;
    let rows = fig2_throughput_sweep_dtyped(&ks, threads, parse_profile(args), dtype, |k| {
        ConvCase::square(c, hw, k)
    });
    let mut t = Table::new(
        format!(
            "Fig 2 — 2-D convolution throughput, GFLOP/s (c={c}, {hw}x{hw}, {threads} thread(s), {})",
            dtype.name()
        ),
        &["k", "sliding", "gemm", "roof(sliding)", "roof(gemm)", "peak", "sliding/peak"],
    );
    for r in &rows {
        t.row(vec![
            r.k.to_string(),
            f3(r.sliding_gflops),
            f3(r.gemm_gflops),
            f3(r.sliding_roof),
            f3(r.gemm_roof),
            f3(r.peak),
            f3(r.sliding_gflops / r.peak),
        ]);
    }
    println!("{}", t.render());
    if let Some(path) = args.get("csv") {
        t.write_csv(path)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_peaks() -> Result<()> {
    let p = machine_peaks();
    println!("compute peak : {:.2} GFLOP/s (single core, f32 FMA)", p.gflops);
    println!("bandwidth    : {:.2} GB/s (stream triad)", p.bandwidth_gbs);
    println!("ridge point  : {:.2} FLOP/byte", p.ridge());
    println!("isa          : {} detected", IsaLevel::detected());
    for roof in isa_peaks() {
        println!(
            "  {:<7}: {:.2} GFLOP/s ({} lanes, f32 FMA)",
            roof.isa.name(),
            roof.gflops,
            roof.lanes
        );
    }
    Ok(())
}

/// `autotune` — measure this machine's dispatch crossovers and cache
/// them (default `target/autotune/profile.json`) for every later
/// `--profile` consumer. `--dtype i8` runs the int8 pass (sliding-q8 vs
/// gemm-q8); per-dtype passes **merge** into the cache, so
/// `autotune && autotune --dtype i8` leaves one profile with both
/// families' buckets.
fn cmd_autotune(args: &Args) -> Result<()> {
    let base = AutotuneOpts::default();
    let ks = match args.get("ks") {
        Some(_) => parse_ks(args)?,
        None => base.ks.clone(),
    };
    // --threads N measures {1, N}; --threads 0 measures {1, all}; the
    // default grid already covers {1, all hardware threads}.
    let threads = match args.get("threads") {
        Some(_) => {
            let t = parse_threads(args)?;
            if t <= 1 {
                vec![1]
            } else {
                vec![1, t]
            }
        }
        None => base.threads.clone(),
    };
    let dtype = parse_dtype(args)?;
    if !matches!(dtype, Dtype::F32 | Dtype::I8) {
        bail!(
            "autotune measures the f32 or i8 kernel families; '{}' has no \
             family split to tune",
            dtype.name()
        );
    }
    apply_pin_current(args)?;
    let opts = AutotuneOpts {
        c: args.usize("c", base.c)?,
        hw: args.usize("hw", base.hw)?,
        ks,
        threads,
        dtype,
        verbose: true,
        ..base
    };
    let out = args.get("out").map(std::path::PathBuf::from).unwrap_or_else(default_profile_path);

    eprintln!(
        "autotune: c={} hw={} ks={:?} threads={:?} dtype={}",
        opts.c,
        opts.hw,
        opts.ks,
        opts.threads,
        dtype.name()
    );
    let measured = autotune(&opts);
    // Merge with the cache: this pass replaces its own dtype's buckets
    // and keeps every other dtype's, so f32 and i8 passes accumulate.
    let mut entries: Vec<ProfileEntry> = Vec::new();
    if out.exists() {
        match DispatchProfile::load_versioned(&out) {
            Ok((prev, version)) => {
                // Surface what was merged from: a degraded v1/v2 cache
                // loads silently, so the version is worth printing.
                println!(
                    "loaded cache {} (schema v{version}, {} entries)",
                    out.display(),
                    prev.entries().len()
                );
                entries.extend(prev.entries().iter().filter(|e| e.dtype != dtype).copied());
            }
            Err(e) => eprintln!("warning: replacing unreadable profile {}: {e}", out.display()),
        }
    }
    entries.extend(measured.entries().iter().copied());
    let profile = DispatchProfile::from_entries(entries);
    println!("{}", profile_table(&profile).render());
    profile.save(&out).with_context(|| format!("writing {}", out.display()))?;
    println!(
        "cached {} buckets in {} (use --profile {} on bench/serve)",
        profile.entries().len(),
        out.display(),
        out.display()
    );

    // --tile-race MODEL: race output-tile shapes for one zoo model on
    // this machine's cache hierarchy. The winner is a per-model
    // `--tile` argument — deliberately *not* a profile bucket, so the
    // cached schema is unchanged.
    if let Some(name) = args.get("tile-race") {
        let m = zoo::by_name(name, 10, 42)
            .ok_or_else(|| anyhow!("unknown model '{name}' (try {:?})", zoo::MODEL_NAMES))?;
        let t = *opts.threads.iter().max().unwrap_or(&1);
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, t).with_dtype(dtype);
        let cands = [
            TileCandidate::Untiled,
            TileCandidate::Auto,
            TileCandidate::Fixed(16, 16),
            TileCandidate::Fixed(8, 8),
            TileCandidate::Fixed(4, 4),
        ];
        let rows = race_tile_shapes(&m, 1, &ctx, &cands, opts.samples, opts.sample_target);
        let mut table = Table::new(
            format!("tile race — {name} ({} threads, dtype {})", t, dtype.name()),
            &["tile", "chains", "chain ws", "GFLOP/s"],
        );
        for r in &rows {
            table.row(vec![
                r.candidate.name(),
                r.chains.to_string(),
                format!("{:.0}KiB", r.ws_bytes as f64 / 1024.0),
                f3(r.gflops),
            ]);
        }
        println!("{}", table.render());
        match rows.iter().max_by(|a, b| a.gflops.total_cmp(&b.gflops)) {
            Some(w) if w.candidate != TileCandidate::Untiled => println!(
                "winner: --tile {} ({} chains L2-blocked, bit-identical output)",
                w.candidate.name(),
                w.chains
            ),
            Some(_) => println!("winner: untiled — this model's chains already fit cache"),
            None => println!("no fusable chain to race (model stays untiled)"),
        }
    }
    Ok(())
}

fn cmd_run_model(args: &Args) -> Result<()> {
    let name = args.get("model").unwrap_or("simple-cnn");
    let batch = args.usize("batch", 1)?;
    let threads = parse_threads(args)?;
    apply_pin_current(args)?;
    let model = zoo::by_name(name, 10, 42)
        .ok_or_else(|| anyhow!("unknown model '{name}' (try {:?})", zoo::MODEL_NAMES))?;
    let dtype = parse_dtype(args)?;
    let mut in_shape = vec![batch];
    in_shape.extend_from_slice(&model.input_shape);
    let x = Tensor::randn(&in_shape, 7);
    let mut t = Table::new(
        format!(
            "{name} forward, batch {batch}, {threads} thread(s), {} ({} FLOP)",
            dtype.name(),
            model.flops(batch)
        ),
        &["algo", "median", "GFLOP/s"],
    );
    // With --profile, add the tuned dispatch as a fourth series.
    let profile = parse_profile(args);
    let mut algos = vec![ConvAlgo::Im2colGemm, ConvAlgo::Sliding, ConvAlgo::Direct];
    if profile.is_some() {
        algos.push(ConvAlgo::Tuned);
    }
    let mut outputs: Vec<(ConvAlgo, Tensor)> = Vec::new();
    for algo in algos {
        let mut ctx = ExecCtx::with_threads(algo, threads).with_dtype(dtype);
        if let Some(p) = &profile {
            ctx.set_profile(Arc::clone(p));
        }
        let stats = bench(|| model.forward(&x, &ctx));
        t.row(vec![
            algo.name().into(),
            dur(stats.median),
            f3(stats.gflops(model.flops(batch))),
        ]);
        outputs.push((algo, model.forward(&x, &ctx)));
    }
    println!("{}", t.render());
    for w in outputs.windows(2) {
        let d = w[0].1.max_abs_diff(&w[1].1);
        println!(
            "outputs {} vs {}: max |diff| = {d:.2e}",
            w[0].0.name(),
            w[1].0.name()
        );
    }
    Ok(())
}

/// `plan` — run the whole-model planner over a zoo model (or all of
/// them): per-conv-layer algorithm × worker-split × dtype choices
/// maximizing predicted throughput while keeping live activations +
/// workspace under `--mem-budget`. Prints one line per planned node,
/// the predicted peak vs. the budget, the predicted throughput, and the
/// smallest budget any plan could satisfy. An infeasible budget is an
/// explicit error — never a silent over-budget plan. `--profile` plans
/// from that cache's measured crossovers instead of the analytic model.
/// `--algo` picks the serving route the plan must stay bit-identical
/// to: f32 nodes only re-route within that route's FP-summation family
/// (`gemm` exposes the one-shot ↔ strip-GEMM memory lever; `sliding`
/// plans worker splits only); int8 nodes roam the full kernel set
/// either way.
fn cmd_plan(args: &Args) -> Result<()> {
    let batch = args.usize("batch", 1)?.max(1);
    let threads = parse_threads(args)?;
    let dtype = parse_dtype(args)?;
    let budget = parse_mem_budget(args)?;
    let profile = parse_profile(args);
    let algo = match args.get("algo") {
        None | Some("sliding") => ConvAlgo::Sliding,
        Some("gemm") => ConvAlgo::Im2colGemm,
        Some("tuned") => ConvAlgo::Tuned,
        Some(other) => bail!("unknown --algo '{other}' (expected sliding, gemm or tuned)"),
    };
    let names: Vec<&str> = match args.get("model") {
        Some(n) => vec![n],
        None => zoo::MODEL_NAMES.to_vec(),
    };
    for name in names {
        let model = zoo::by_name(name, 10, 42)
            .ok_or_else(|| anyhow!("unknown model '{name}' (try {:?})", zoo::MODEL_NAMES))?;
        let compiled = model.compile();
        let mut ctx = ExecCtx::with_threads(algo, threads).with_dtype(dtype);
        if let Some(p) = &profile {
            ctx.set_profile(Arc::clone(p));
        }
        let floor = swconv::graph::min_feasible_budget(&compiled, batch, &ctx);
        match swconv::graph::plan_model(&compiled, batch, &ctx, budget) {
            Ok(mp) => {
                print!("{}", mp.render(&compiled.graph));
                println!("  smallest feasible budget: {floor} B\n");
            }
            Err(e) => bail!("{e} (smallest feasible budget: {floor} bytes)"),
        }
    }
    Ok(())
}

fn cmd_summary(args: &Args) -> Result<()> {
    let name = args.get("model").unwrap_or("simple-cnn");
    let model = zoo::by_name(name, 10, 42)
        .ok_or_else(|| anyhow!("unknown model '{name}' (try {:?})", zoo::MODEL_NAMES))?;
    print!("{}", model.summary(args.usize("batch", 1)?));
    Ok(())
}

/// `compile` — lower a zoo model (or all of them) into the graph IR,
/// run the pass pipeline and print the before/after graphs with pass
/// counts and FLOP/activation-byte accounting, plus the tiling layer's
/// view of the result: per-node activation bytes and, per fusable
/// conv/pool chain, the cache-sized tile geometry and whether the
/// footprint policy would run it tiled. `--no-fuse` (or
/// `SWCONV_NO_FUSE=1`) shows the verbatim plan instead.
fn cmd_compile(args: &Args) -> Result<()> {
    use swconv::graph::{tiling, TileMode};

    let batch = args.usize("batch", 1)?;
    let dtype = parse_dtype(args)?;
    let names: Vec<&str> = match args.get("model") {
        Some(n) => vec![n],
        None => zoo::MODEL_NAMES.to_vec(),
    };
    for name in names {
        let model = zoo::by_name(name, 10, 42)
            .ok_or_else(|| anyhow!("unknown model '{name}' (try {:?})", zoo::MODEL_NAMES))?;
        let unfused = model.compile_with(false);
        let fused = model.compile();
        println!("== {name} (input {:?}, batch {batch}) ==", model.input_shape);
        println!("lowered ({} nodes):", unfused.graph.nodes.len());
        print!("{}", unfused.render());
        if swconv::graph::fusion_disabled() {
            println!("fusion disabled (--no-fuse / SWCONV_NO_FUSE): plan runs verbatim");
        } else {
            let s = fused.summary;
            println!(
                "optimized ({} nodes): {} relu fused, {} pad(s) elided, {} quant boundary(ies) hoisted:",
                fused.graph.nodes.len(),
                s.fused_relu,
                s.elided_pads,
                s.hoisted_quant
            );
            print!("{}", fused.render());
        }
        let (fb, ub) = (fused.activation_bytes(batch), unfused.activation_bytes(batch));
        println!("flops       : {}", fused.flops(batch));
        println!(
            "activations : {ub} B unfused -> {fb} B compiled ({:+.1}%)",
            (fb as f64 / ub as f64 - 1.0) * 100.0
        );
        println!("per-node activations (batch {batch}):");
        for (id, node) in fused.graph.nodes.iter().enumerate().skip(1) {
            println!(
                "  %{id:<3} {:<14} {:>12} B  {:?}",
                node.op.name(),
                fused.graph.node_activation_bytes(id, batch),
                node.shape
            );
        }
        // The tiling layer's view: every fusable conv/pool chain with
        // its cache-sized tile (ForceAll = geometry for all candidates),
        // labeled by the footprint policy's decision (OverBudget = tile
        // only the chains whose untiled working set spills the L2 tile
        // budget; see `swconv cache-info`). Either way results are
        // bit-identical — the label is a locality decision, not a
        // numerics one.
        let ctx = ExecCtx::new(ConvAlgo::Sliding).with_dtype(dtype);
        let all = tiling::analyze(&fused.graph, None, &ctx, batch, TileMode::ForceAll);
        let spill = tiling::analyze(&fused.graph, None, &ctx, batch, TileMode::OverBudget);
        if all.is_empty() {
            println!(
                "tiled chains: none (no fusable sliding conv/pool chain at dtype {})",
                dtype.name()
            );
        } else {
            println!("tiled chains (dtype {}):", dtype.name());
            for c in &all.chains {
                let decision = if spill.chains.iter().any(|d| d.start == c.start) {
                    "TILE  "
                } else {
                    "untile"
                };
                println!("  [{decision}] {}", c.render());
            }
        }
        println!();
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let name = args.get("model").unwrap_or("squeezenet-lite");
    let n_req = args.usize("requests", 64)?;
    let max_batch = args.usize("max-batch", 8)?;
    let wait_ms = args.usize("max-wait-ms", 2)?;
    // The intra x inter core budget: each backend runs `replicas` worker
    // replicas, each replica's ExecCtx runs `threads` kernel threads.
    let threads = parse_threads(args)?;
    let replicas = match args.usize("replicas", 1)? {
        0 => swconv::exec::available_threads(),
        r => r,
    };
    // Arena retention: 0 (default) keeps the high-water scratch for
    // maximum steady-state speed; N caps each replica's retained arena
    // at N MiB after every batch. --trim-idle-ms M additionally drops
    // all retained scratch once a replica has been quiet for M ms.
    let trim_mb = args.usize("trim-mb", 0)?;
    let trim_idle_ms = args.usize("trim-idle-ms", 0)?;
    // --dtype: every tier serves in this element type (f32 default).
    let dtype = parse_dtype(args)?;
    // --pin: replica i of every tier runs on core slice i ("auto" =
    // round-robin all hardware threads); each native replica's kernel
    // threads are pooled and pinned inside its slice.
    let pinning = parse_pin_policy(args)?;
    // --profile: every tier dispatches from the cached crossover table,
    // and a third "tuned" backend (ConvAlgo::Tuned) joins the race.
    let profile = parse_profile(args);
    let model_a = zoo::by_name(name, 10, 42).ok_or_else(|| anyhow!("unknown model '{name}'"))?;
    let model_b = zoo::by_name(name, 10, 42).unwrap();
    let item_shape = model_a.input_shape.clone();

    let spec = |key: &str, model, algo| {
        let ctx = ExecCtx::with_threads(algo, threads);
        let trim_after = if trim_mb > 0 { Some(trim_mb << 18) } else { None }; // MiB -> f32s
        let trim_idle = if trim_idle_ms > 0 {
            Some(Duration::from_millis(trim_idle_ms as u64))
        } else {
            None
        };
        let mut s = BackendSpec::native_retention(key, model, ctx, trim_after, trim_idle)
            .with_dtype(dtype)
            .with_pinning(pinning.clone());
        if let Some(p) = &profile {
            s = s.with_profile(Arc::clone(p));
        }
        s.with_replicas(replicas)
    };
    let mut backends = vec![
        spec("sliding", model_a, ConvAlgo::Sliding),
        spec("gemm", model_b, ConvAlgo::Im2colGemm),
    ];
    let mut backend_names = vec!["sliding", "gemm"];
    if profile.is_some() {
        backends.push(spec("tuned", zoo::by_name(name, 10, 42).unwrap(), ConvAlgo::Tuned));
        backend_names.push("tuned");
    }
    let coord = Coordinator::new(
        backends,
        BatchPolicy { max_batch, max_wait: Duration::from_millis(wait_ms as u64) },
    );

    eprintln!(
        "serve: {replicas} replica(s) x {threads} kernel thread(s) per backend, dtype {}{}",
        dtype.name(),
        match &pinning {
            PinPolicy::None => String::new(),
            PinPolicy::Auto => ", pinned (auto slices)".to_string(),
            PinPolicy::Cores(set) => format!(", pinned to {set}"),
        }
    );
    for backend in backend_names {
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n_req)
            .map(|i| coord.submit(backend, Tensor::randn(&item_shape, i as u64)).unwrap())
            .collect();
        for rx in rxs {
            rx.recv()
                .map_err(|_| anyhow!("worker died"))?
                .output
                .map_err(|e| anyhow!("{e}"))?;
        }
        let wall = t0.elapsed();
        let m = coord.metrics(backend).unwrap();
        println!(
            "{backend:>8}: {n_req} reqs in {} = {:.1} req/s | {}",
            dur(wall),
            n_req as f64 / wall.as_secs_f64(),
            m.summary()
        );
        if replicas > 1 {
            for (i, rm) in coord.replica_metrics(backend).unwrap().iter().enumerate() {
                println!(
                    "          r{i}: {} items in {} shards (avg {:.1}/shard)",
                    rm.items,
                    rm.batches,
                    rm.mean_batch()
                );
            }
        }
    }
    coord.shutdown();
    Ok(())
}

/// `stream` — frame-by-frame inference over an audio-style zoo model.
/// Feeds a synthetic signal one sample at a time through a
/// [`StreamSession`] (O(taps) work per frame), times each `advance`,
/// checks the streamed output against the batch forward (bit-exact in
/// i8 for avg-pool-free models, within the session's derived bound in
/// f32/bf16), then demos stateful serving through the coordinator:
/// N concurrent streams pinned to replicas by session affinity.
fn cmd_stream(args: &Args) -> Result<()> {
    let name = args.get("model").unwrap_or("edge-audio");
    let frames = args.usize("frames", 512)?.max(1);
    let n_streams = args.usize("streams", 2)?.max(1);
    let replicas = match args.usize("replicas", 2)? {
        0 => swconv::exec::available_threads(),
        r => r,
    };
    let threads = parse_threads(args)?;
    let dtype = parse_dtype(args)?;
    let algo = match args.get("algo") {
        None | Some("sliding") => ConvAlgo::Sliding,
        Some("gemm") => ConvAlgo::Im2colGemm,
        Some(other) => bail!("unknown --algo '{other}' (expected sliding or gemm)"),
    };
    apply_pin_current(args)?;
    let model = zoo::by_name(name, 10, 42)
        .ok_or_else(|| anyhow!("unknown model '{name}' (try {:?})", zoo::MODEL_NAMES))?;
    let c_in = model.input_shape[0];

    // Incremental path: one session, one frame per advance.
    let ctx = ExecCtx::with_threads(algo, threads).with_dtype(dtype);
    let mut sess = StreamSession::new(&model, ctx).map_err(|e| anyhow!("{e}"))?;
    let signal = Tensor::randn(&[1, c_in, 1, frames], 7);
    let s = signal.as_slice();
    let mut col = vec![0.0f32; c_in];
    let mut lat = Vec::with_capacity(frames);
    let mut streamed: Vec<Vec<f32>> = Vec::new();
    for t in 0..frames {
        for (c, v) in col.iter_mut().enumerate() {
            *v = s[c * frames + t];
        }
        let t0 = Instant::now();
        let out = sess.advance(&col);
        lat.push(t0.elapsed());
        streamed.extend(out);
    }
    streamed.extend(sess.flush());

    // Parity + the naive alternative: recomputing the whole signal
    // every frame costs one full batch forward per sample.
    let reference = sess.run_batch(&signal);
    let t0 = Instant::now();
    let _ = sess.run_batch(&signal);
    let full = t0.elapsed();
    let t_out = reference.dim(3);
    if streamed.len() != t_out {
        bail!("streamed {} columns, batch produced {t_out}", streamed.len());
    }
    let r = reference.as_slice();
    let mut maxd = 0.0f32;
    for (t, c2) in streamed.iter().enumerate() {
        for (c, &v) in c2.iter().enumerate() {
            maxd = maxd.max((v - r[c * t_out + t]).abs());
        }
    }
    let tol = sess.tolerance();
    let exact = sess.is_bit_exact();
    if (exact && maxd != 0.0) || maxd > tol {
        bail!("streamed output diverged from batch: max|diff| = {maxd:.3e} (bound {tol:.3e})");
    }

    lat.sort();
    let pctl = |p: f64| lat[((lat.len() - 1) as f64 * p).round() as usize];
    let mean = lat.iter().sum::<Duration>() / lat.len() as u32;
    let mut t = Table::new(
        format!(
            "stream — {name}, {frames} frames x {c_in} ch, {threads} thread(s), {} ({})",
            dtype.name(),
            algo.name()
        ),
        &["metric", "value"],
    );
    t.row(vec!["frames in / columns out".into(), format!("{frames} / {t_out}")]);
    t.row(vec!["per-frame p50".into(), dur(pctl(0.50))]);
    t.row(vec!["per-frame p99".into(), dur(pctl(0.99))]);
    t.row(vec!["per-frame mean".into(), dur(mean)]);
    t.row(vec!["full recompute (per frame)".into(), dur(full)]);
    t.row(vec![
        "speedup vs full recompute".into(),
        f3(full.as_secs_f64() / pctl(0.50).as_secs_f64().max(1e-12)),
    ]);
    t.row(vec![
        "parity vs batch".into(),
        if exact {
            format!("bit-exact (max|diff| = {maxd:.1e})")
        } else {
            format!("max|diff| = {maxd:.2e} (bound {tol:.2e})")
        },
    ]);
    println!("{}", t.render());

    // Stateful serving: N concurrent streams on a replicated tier.
    // open_stream places each on the least-loaded replica and keeps it
    // there (session affinity); frames bypass the batcher.
    let tier = BackendSpec::native_streaming(
        "stream",
        zoo::by_name(name, 10, 42).unwrap(),
        ExecCtx::with_threads(algo, threads),
        Duration::from_secs(30),
    )
    .with_dtype(dtype)
    .with_replicas(replicas);
    let coord = Coordinator::new(
        vec![tier],
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
    );
    let handles = (0..n_streams)
        .map(|_| coord.open_stream("stream"))
        .collect::<std::result::Result<Vec<_>, _>>()
        .map_err(|e| anyhow!("{e}"))?;
    let serve_frames = frames.min(128);
    let mut served = vec![0usize; n_streams];
    for t in 0..serve_frames {
        for (c, v) in col.iter_mut().enumerate() {
            *v = s[c * frames + t];
        }
        for (i, h) in handles.iter().enumerate() {
            let f = coord.advance_stream(h, &col).map_err(|e| anyhow!("{e}"))?;
            if f.reset {
                bail!("stream {i} was reset mid-run (unexpected failover)");
            }
            if f.output.is_some() {
                served[i] += 1;
            }
        }
    }
    println!(
        "coordinator: {n_streams} stream(s) x {serve_frames} frames over {replicas} replica(s)"
    );
    for (i, h) in handles.iter().enumerate() {
        println!(
            "  stream {i}: replica {}, {} column(s) emitted",
            coord
                .stream_replica(h)
                .map_or("-".to_string(), |r| r.to_string()),
            served[i]
        );
        coord.close_stream(h);
    }
    coord.shutdown();
    Ok(())
}

fn cmd_artifacts_check(args: &Args) -> Result<()> {
    let dir = args
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let mut engine = Engine::new(&dir).with_context(|| {
        format!("loading artifacts from {} (run `make artifacts`)", dir.display())
    })?;
    let n = engine.load_all()?;
    println!("compiled {n} artifacts on {}", engine.platform());

    // Cross-check every conv2d artifact against the native kernels.
    let specs: Vec<_> = engine.manifest().of_kind("conv2d").into_iter().cloned().collect();
    let mut checked = 0;
    for spec in specs {
        let x = Tensor::rand_uniform(&spec.inputs[0], -1.0, 1.0, 11);
        let w = Tensor::rand_uniform(&spec.inputs[1], -1.0, 1.0, 12);
        let y = engine.execute(&spec.name, &[&x, &w])?;
        // aot.py lowers conv2d artifacts with "same" padding for odd k.
        let pad = spec.inputs[1][2].saturating_sub(1) / 2;
        let params = Conv2dParams::with_pad(pad, pad);
        let native = conv2d(&x, &w, None, &params, ConvAlgo::Sliding);
        let d = y.max_abs_diff(&native);
        if d > 1e-3 {
            bail!("artifact {} differs from native kernels: {d}", spec.name);
        }
        println!("  {:<40} max|diff| = {d:.2e}  OK", spec.name);
        checked += 1;
    }
    println!("artifacts-check OK ({checked} conv2d artifacts cross-checked)");
    Ok(())
}

/// `cache-info` — print the detected cache hierarchy (sysfs probe with
/// per-level env overrides) and the derived tile working-set budget the
/// tiling layer sizes chain tiles against.
fn cmd_cache_info() -> Result<()> {
    print!("{}", swconv::exec::CacheInfo::detect().render());
    Ok(())
}

fn help() {
    println!(
        "swconv — Sliding-Window convolution reproduction

USAGE: swconv <command> [--flag value]...

COMMANDS
  bench-fig1       [--c 4] [--hw 64] [--ks 2,3,...] [--threads N] [--csv out.csv]
                   [--profile PATH] [--dtype f32|bf16|i8] [--pin CORES] [--no-pool]
  bench-fig2       [--c 4] [--hw 64] [--ks 2,3,...] [--threads N] [--csv out.csv]
                   [--profile PATH] [--dtype f32|bf16|i8] [--pin CORES] [--no-pool]
  peaks
  autotune         [--c 4] [--hw 64] [--ks 2,3,...] [--threads N] [--dtype f32|i8]
                   [--out target/autotune/profile.json] [--pin CORES] [--no-pool]
                   [--tile-race MODEL]
  run-model        [--model NAME] [--batch N] [--threads N] [--profile PATH]
                   [--dtype f32|bf16|i8] [--pin CORES] [--no-pool]
  plan             [--model NAME] [--batch N] [--threads N] [--dtype f32|bf16|i8]
                   [--algo sliding|gemm|tuned] [--mem-budget N[K|M|G]] [--profile PATH]
  summary          [--model NAME] [--batch N]
  compile          [--model NAME] [--batch N] [--dtype f32|bf16|i8] [--no-fuse]
  cache-info
  serve            [--model NAME] [--requests N] [--max-batch N] [--max-wait-ms MS]
                   [--threads N] [--replicas N] [--trim-mb N] [--trim-idle-ms MS]
                   [--profile PATH] [--dtype f32|bf16|i8] [--pin CORES|auto] [--no-pool]
                   [--no-fuse]
  stream           [--model edge-audio] [--frames N] [--streams N] [--replicas N]
                   [--threads N] [--algo sliding|gemm] [--dtype f32|bf16|i8]
                   [--pin CORES] [--no-pool] [--no-fuse]
  artifacts-check  [--dir artifacts]

  --threads 0 means \"use all hardware threads\"; the default 1 matches
  the paper's single-core configuration. serve's --replicas N spawns N
  worker replicas per backend (0 = all hardware threads) and shards
  batches across them — the intra (--threads) x inter (--replicas)
  core-budget split. --trim-mb caps each replica's retained scratch
  arena after every batch (0 = keep the high-water mark);
  --trim-idle-ms drops all retained scratch once a replica has been
  quiet that long (0 = never).

  compile lowers a model into the typed graph IR and prints the graph
  before and after the pass pipeline (bias+ReLU epilogue fusion, pad
  elision into kernel edge handling, quantize-boundary hoisting between
  adjacent int8 convs) with FLOP and activation-byte accounting; serve
  executes every backend through the same compiled plan (shared across
  a tier's replicas like the weights). --no-fuse — or SWCONV_NO_FUSE=1
  — skips every pass, so the plan reproduces the layer stack verbatim;
  results are bit-identical either way (see `cargo bench --bench
  graph_fusion`, which emits BENCH_graph.json).

  plan runs the whole-model planner: for every conv layer it picks an
  algorithm and a worker split that maximize predicted end-to-end
  throughput while keeping live activations + workspace under
  --mem-budget (plain bytes or a binary K/M/G suffix; absent =
  unbounded). Planned execution is bit-identical to the unplanned
  --algo route, so f32 layers only re-route within that route's
  FP-summation family: --algo gemm exposes the one-shot ↔ gemm-lowmem
  lever (the accumulating strip-im2col variant — a bounded column strip
  instead of the full patch matrix, order-exact output), --algo sliding
  plans worker splits only, and int8 layers roam the full exact kernel
  set either way. An infeasible budget is an explicit error reporting
  the smallest budget that would work — never a silent over-budget
  plan. With --profile the planner costs candidates from the measured
  crossover cache. SWCONV_FORCE_PLAN=1 makes every compiled model
  attach an unbudgeted plan (the CI leg); `cargo bench --bench
  plan_model` emits BENCH_plan.json comparing planned vs greedy-tuned
  vs paper-policy execution across budgets.

  Tiled execution keeps fused conv chains L2-resident: instead of
  materializing each whole activation plane, a chain runs tile by tile
  through halo-aware region kernels, recycling per-tile intermediates
  through the scratch arena. Tiles are sized so a tile's working set
  fits the detected tile budget (3/4 of L2; see `swconv cache-info` —
  SWCONV_L2_KB / SWCONV_L3_KB override the sysfs probe), and tiles
  parallelize across the worker pool. --tile HxW (any command that runs
  compiled plans) forces that output-tile shape on every fusable chain;
  --tile auto — or SWCONV_FORCE_TILE=1, the CI leg — forces tiling with
  cache-sized tiles; plan --mem-budget additionally tiles the chains
  whose untiled working set spills the budget whenever that lowers the
  predicted peak. Results are bit-identical to untiled execution for
  every dtype, thread count and ISA level (see tests/tile_parity.rs and
  `cargo bench --bench tiled_chains`, which emits BENCH_tile.json).

  stream runs frame-by-frame inference: a StreamSession keeps per-layer
  ring buffers so each new sample costs O(taps) instead of a full
  recompute, and the output is checked against the batch path every run
  (bit-exact in i8 for avg-pool-free models like edge-audio, within a
  derived error bound in f32/bf16). The coordinator demo opens
  --streams sessions on --replicas replicas: each stream is pinned to
  one replica (session affinity), frames bypass the batcher, idle
  sessions are evicted, and a broken replica's streams fail over with
  an explicit state reset. See also `cargo bench --bench
  stream_latency`, which emits BENCH_stream.json.

  Kernel threads run on a persistent, work-stealing worker pool per
  execution context (one spawn at startup instead of one per parallel
  region). --no-pool — or SWCONV_NO_POOL=1 — restores scoped
  spawn-per-region threads; results are bit-identical either way.
  --isa scalar|avx2|avx512|neon (any command) forces the instruction-set
  level kernels dispatch at: the detected level is the default, scalar
  forces the portable F32xL kernels, and forcing a level the machine
  lacks is an error. Results are bit-identical at every level — the
  explicit std::arch microkernels only change throughput.
  --pin 0-3,8 confines a run to those cores (Linux only, best-effort);
  on serve, --pin slices the set round-robin across each tier's
  replicas — replica i pins to slice i and pools its kernel threads
  pinned inside the slice (--pin auto slices all hardware threads), so
  first-touched scratch stays on the replica's own cores/NUMA node.

  --dtype picks the element type (default f32, bit-exact with the
  paper's kernels): bf16 halves storage traffic with f32 accumulation;
  i8 serves quantized — conv layers dynamically quantize activations
  (per-tensor symmetric), run int8 sliding (or int8 im2col+GEMM under
  the gemm algorithm) with exact i32 accumulation, and dequantize at
  layer boundaries. bench-fig1/bench-fig2 with --dtype i8 race int8
  sliding against the int8 GEMM baseline (see also
  `cargo bench --bench quant_slide`, which emits BENCH_quant.json).

  autotune races direct/GEMM/sliding-generic/compound/custom kernels per
  (filter width, thread count) and caches the winners; with --dtype i8
  it instead races int8 sliding vs the int8 im2col+GEMM baseline and
  fills the cache's i8 buckets (passes merge, so run both). --profile
  PATH makes bench/run-model/serve dispatch from that cache (run-model
  and serve then also race a \"tuned\" series/backend). A missing or
  corrupt profile falls back to the paper's k=17 policy with a warning.
  --tile-race MODEL additionally races output-tile shapes (untiled vs
  auto vs fixed HxW, bit-identical by contract) for that zoo model and
  prints the --tile argument this machine's cache hierarchy prefers —
  a per-model property, so it is not cached in the profile.

MODELS: {:?}",
        zoo::MODEL_NAMES
    );
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    // --no-pool (or SWCONV_NO_POOL=1 in the environment) restores the
    // scoped spawn-per-region threads for the whole process; results
    // are bit-identical either way.
    if args.flag("no-pool") {
        pool::set_pooling_disabled(true);
        eprintln!("persistent worker pools disabled (--no-pool): scoped threads per region");
    }
    // --no-fuse (or SWCONV_NO_FUSE=1) skips the graph pass pipeline:
    // compiled plans reproduce the layer stack verbatim. Bit-identical
    // results either way — this is the A/B escape hatch.
    if args.flag("no-fuse") {
        swconv::graph::set_fusion_disabled(true);
        eprintln!("graph passes disabled (--no-fuse): plans run the layer stack verbatim");
    }
    // --isa pins the instruction-set level process-wide: every ExecCtx
    // built after this dispatches the forced level's kernels. Forcing
    // an unavailable level is an error (scalar is always available);
    // results are bit-identical at every level.
    if let Some(s) = args.get("isa") {
        let isa = IsaLevel::parse(s)
            .ok_or_else(|| anyhow!("unknown isa '{s}' (expected scalar, avx2, avx512 or neon)"))?;
        IsaLevel::force(isa)?;
        eprintln!("isa forced to {isa} (detected: {})", IsaLevel::detected());
    }
    // --tile HxW (or `auto`) forces tiled chain execution process-wide;
    // bit-identical results either way, so this is a locality lever.
    apply_tile_flag(&args)?;
    match args.cmd.as_str() {
        "bench-fig1" => cmd_fig1(&args),
        "bench-fig2" => cmd_fig2(&args),
        "peaks" => cmd_peaks(),
        "autotune" => cmd_autotune(&args),
        "run-model" => cmd_run_model(&args),
        "plan" => cmd_plan(&args),
        "summary" => cmd_summary(&args),
        "compile" => cmd_compile(&args),
        "cache-info" => cmd_cache_info(),
        "serve" => cmd_serve(&args),
        "stream" => cmd_stream(&args),
        "artifacts-check" => cmd_artifacts_check(&args),
        "help" | "--help" | "-h" => {
            help();
            Ok(())
        }
        other => {
            help();
            bail!("unknown command '{other}'");
        }
    }
}
