//! The paper's evaluation sweeps.
//!
//! * [`fig1_speedup_sweep`] — Fig. 1: speedup of 2-D Sliding Window
//!   convolution over the GEMM (`MlasConv`-style) baseline as a function
//!   of filter size, for the auto policy and the forced generic/compound
//!   variants.
//! * [`fig2_throughput_sweep`] — Fig. 2: arithmetic throughput (GFLOP/s)
//!   of each kernel against the measured roofline.

use super::roofline::machine_peaks;
use super::timing::{bench_quick, Stats};
use super::workload::ConvCase;
use crate::autotune::DispatchProfile;
use crate::exec::ExecCtx;
use crate::kernels::im2col::{conv2d_im2col_ctx, conv2d_im2col_q8_raw_ctx};
use crate::kernels::rowconv::{RowKernel, COMPOUND_MAX_K};
use crate::kernels::sliding2d::{conv2d_sliding_bf16_ctx, conv2d_sliding_q8_raw_ctx};
use crate::kernels::{conv2d_ctx, ConvAlgo};
use crate::tensor::{from_bf16, quantize, to_bf16, Dtype, QuantParams, Tensor};
use std::sync::Arc;

/// One Fig. 1 data point.
#[derive(Clone, Debug)]
pub struct Fig1Row {
    /// Filter size `k`.
    pub k: usize,
    /// Worker threads every kernel ran with.
    pub threads: usize,
    /// GEMM baseline time (seconds).
    pub t_gemm: f64,
    /// Sliding (auto policy) time.
    pub t_sliding: f64,
    /// Forced generic kernel time, if the width is supported.
    pub t_generic: Option<f64>,
    /// Forced compound kernel time.
    pub t_compound: Option<f64>,
    /// Auto-policy speedup over GEMM.
    pub speedup: f64,
    /// Which row kernel the auto policy used ("custom"/"generic"/"compound").
    pub kernel_used: &'static str,
}

/// One Fig. 2 data point.
#[derive(Clone, Debug)]
pub struct Fig2Row {
    /// Filter size `k`.
    pub k: usize,
    /// Worker threads every kernel ran with.
    pub threads: usize,
    /// Sliding kernel throughput, GFLOP/s.
    pub sliding_gflops: f64,
    /// GEMM kernel throughput, GFLOP/s.
    pub gemm_gflops: f64,
    /// Roofline ceiling at the sliding kernel's arithmetic intensity.
    pub sliding_roof: f64,
    /// Roofline ceiling at the GEMM kernel's arithmetic intensity.
    pub gemm_roof: f64,
    /// Machine compute peak, GFLOP/s.
    pub peak: f64,
}

fn time_algo(
    case: &ConvCase,
    x: &Tensor,
    w: &Tensor,
    algo: ConvAlgo,
    threads: usize,
    profile: Option<&Arc<DispatchProfile>>,
) -> Option<Stats> {
    if !algo.supports_width(case.k) {
        return None;
    }
    // One ctx per series: scratch buffers are warmed by the bench's
    // calibration runs, so the timed iterations are allocation-free.
    let mut ctx = ExecCtx::with_threads(algo, threads);
    if let Some(p) = profile {
        ctx.set_profile(Arc::clone(p));
    }
    Some(bench_quick(|| conv2d_ctx(x, w, None, &case.params, &ctx)))
}

/// Which row kernel the auto policy picks for width `k` — a thin
/// naming wrapper over the single policy encoding,
/// [`RowKernel::paper_policy`].
pub fn auto_kernel_name(k: usize) -> &'static str {
    RowKernel::paper_policy(k.min(COMPOUND_MAX_K)).name()
}

/// Run the Fig. 1 sweep over the given filter sizes with `threads`
/// worker threads per kernel (1 reproduces the paper's single-core
/// setup; more lets Fig. 1 report multi-core speedups).
///
/// `make_case` maps a filter size to a workload (use
/// `ConvCase::square(c, hw, k)` for the paper's setup).
pub fn fig1_speedup_sweep(
    ks: &[usize],
    threads: usize,
    make_case: impl Fn(usize) -> ConvCase,
) -> Vec<Fig1Row> {
    fig1_speedup_sweep_profiled(ks, threads, None, make_case)
}

/// Time the two series of a reduced-precision sweep point:
/// `(t_gemm, t_sliding)`.
///
/// * `I8` — the quantized sliding kernel vs the quantized im2col+GEMM
///   baseline, both on *raw* i32 accumulators (identical arithmetic,
///   identical outputs bit for bit — the comparison is purely memory
///   access pattern; quantize/dequantize sit outside the timed loop at
///   layer boundaries in real serving too).
/// * `Bf16` — the bf16 sliding kernel vs the f32 im2col+GEMM baseline
///   on the same bf16-rounded operands (there is no bf16 GEMM kernel;
///   the baseline computes identical values at full storage width).
fn time_reduced(case: &ConvCase, threads: usize, dtype: Dtype) -> (f64, f64) {
    let x = case.input();
    let w = case.weights();
    let gemm_ctx = ExecCtx::with_threads(ConvAlgo::Im2colGemm, threads);
    let slide_ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads);
    match dtype {
        Dtype::I8 => {
            let qx = quantize(&x, QuantParams::for_tensor(&x));
            let qw = quantize(&w, QuantParams::for_tensor(&w));
            let t_gemm = bench_quick(|| conv2d_im2col_q8_raw_ctx(&qx, &qw, &case.params, &gemm_ctx))
                .secs();
            let t_sliding =
                bench_quick(|| conv2d_sliding_q8_raw_ctx(&qx, &qw, &case.params, &slide_ctx))
                    .secs();
            (t_gemm, t_sliding)
        }
        _ => {
            let xb = to_bf16(&x);
            let wb = to_bf16(&w);
            let (xr, wr) = (from_bf16(&xb), from_bf16(&wb));
            let t_gemm = bench_quick(|| conv2d_im2col_ctx(&xr, &wr, None, &case.params, &gemm_ctx))
                .secs();
            let t_sliding =
                bench_quick(|| conv2d_sliding_bf16_ctx(&xb, &wb, None, &case.params, &slide_ctx))
                    .secs();
            (t_gemm, t_sliding)
        }
    }
}

/// [`fig1_speedup_sweep_profiled`] with a dtype dimension — the CLI's
/// `bench-fig1 --dtype` path. `F32` is exactly the profiled sweep; for
/// `I8`/`Bf16` the gemm and sliding series come from `time_reduced`
/// (the forced generic/compound columns are `None`: the
/// reduced-precision row kernels are width-universal, so there is no
/// family ablation to run) and `kernel_used` reports the dtype.
pub fn fig1_speedup_sweep_dtyped(
    ks: &[usize],
    threads: usize,
    profile: Option<Arc<DispatchProfile>>,
    dtype: Dtype,
    make_case: impl Fn(usize) -> ConvCase,
) -> Vec<Fig1Row> {
    if dtype == Dtype::F32 {
        return fig1_speedup_sweep_profiled(ks, threads, profile, make_case);
    }
    ks.iter()
        .map(|&k| {
            let case = make_case(k);
            let (t_gemm, t_sliding) = time_reduced(&case, threads, dtype);
            Fig1Row {
                k,
                threads,
                t_gemm,
                t_sliding,
                t_generic: None,
                t_compound: None,
                speedup: t_gemm / t_sliding,
                kernel_used: if dtype == Dtype::I8 { "q8" } else { "bf16" },
            }
        })
        .collect()
}

/// [`fig1_speedup_sweep`] with an optional measured dispatch profile:
/// the sliding (auto) series then dispatches tuned rows — the CLI's
/// `bench-fig1 --profile` path — while the forced series are unchanged.
pub fn fig1_speedup_sweep_profiled(
    ks: &[usize],
    threads: usize,
    profile: Option<Arc<DispatchProfile>>,
    make_case: impl Fn(usize) -> ConvCase,
) -> Vec<Fig1Row> {
    let profile = profile.as_ref();
    let mut rows = Vec::with_capacity(ks.len());
    for &k in ks {
        let case = make_case(k);
        let x = case.input();
        let w = case.weights();
        let t_gemm =
            time_algo(&case, &x, &w, ConvAlgo::Im2colGemm, threads, profile).unwrap().secs();
        let t_sliding =
            time_algo(&case, &x, &w, ConvAlgo::Sliding, threads, profile).unwrap().secs();
        let t_generic =
            time_algo(&case, &x, &w, ConvAlgo::SlidingGeneric, threads, profile).map(|s| s.secs());
        let t_compound = time_algo(&case, &x, &w, ConvAlgo::SlidingCompound, threads, profile)
            .map(|s| s.secs());
        rows.push(Fig1Row {
            k,
            threads,
            t_gemm,
            t_sliding,
            t_generic,
            t_compound,
            speedup: t_gemm / t_sliding,
            kernel_used: match profile {
                Some(p) => p.row_kernel(k, threads).name(),
                None => auto_kernel_name(k),
            },
        });
    }
    rows
}

/// Run the Fig. 2 sweep over the given filter sizes with `threads`
/// worker threads per kernel.
pub fn fig2_throughput_sweep(
    ks: &[usize],
    threads: usize,
    make_case: impl Fn(usize) -> ConvCase,
) -> Vec<Fig2Row> {
    fig2_throughput_sweep_profiled(ks, threads, None, make_case)
}

/// [`fig2_throughput_sweep_profiled`] with a dtype dimension — the
/// CLI's `bench-fig2 --dtype` path. `F32` delegates; for `I8`/`Bf16`
/// both series come from `time_reduced` and the roofline ceilings use
/// the dtype-scaled traffic models ([`ConvCase::sliding_bytes_for`] /
/// [`ConvCase::gemm_bytes_for`]) — reduced precision moves the ridge,
/// not the arithmetic.
pub fn fig2_throughput_sweep_dtyped(
    ks: &[usize],
    threads: usize,
    profile: Option<Arc<DispatchProfile>>,
    dtype: Dtype,
    make_case: impl Fn(usize) -> ConvCase,
) -> Vec<Fig2Row> {
    if dtype == Dtype::F32 {
        return fig2_throughput_sweep_profiled(ks, threads, profile, make_case);
    }
    let peaks = machine_peaks();
    // The bf16 gemm series is the f32 GEMM on bf16-rounded operands
    // (there is no bf16 GEMM kernel — see `time_reduced`), so its
    // roofline must model the f32 traffic it actually streams; only
    // the int8 series runs an actually-narrower GEMM.
    let gemm_traffic = if dtype == Dtype::Bf16 { Dtype::F32 } else { dtype };
    ks.iter()
        .map(|&k| {
            let case = make_case(k);
            let flops = case.flops() as f64;
            let (t_gemm, t_sliding) = time_reduced(&case, threads, dtype);
            Fig2Row {
                k,
                threads,
                sliding_gflops: flops / t_sliding / 1e9,
                gemm_gflops: flops / t_gemm / 1e9,
                sliding_roof: peaks.attainable(case.intensity(case.sliding_bytes_for(dtype))),
                gemm_roof: peaks.attainable(case.intensity(case.gemm_bytes_for(gemm_traffic))),
                peak: peaks.gflops,
            }
        })
        .collect()
}

/// [`fig2_throughput_sweep`] with an optional measured dispatch profile
/// steering the sliding series (the CLI's `bench-fig2 --profile` path).
pub fn fig2_throughput_sweep_profiled(
    ks: &[usize],
    threads: usize,
    profile: Option<Arc<DispatchProfile>>,
    make_case: impl Fn(usize) -> ConvCase,
) -> Vec<Fig2Row> {
    let profile = profile.as_ref();
    let peaks = machine_peaks();
    let mut rows = Vec::with_capacity(ks.len());
    for &k in ks {
        let case = make_case(k);
        let x = case.input();
        let w = case.weights();
        let flops = case.flops();
        let sliding =
            time_algo(&case, &x, &w, ConvAlgo::Sliding, threads, profile).unwrap().gflops(flops);
        let gemm =
            time_algo(&case, &x, &w, ConvAlgo::Im2colGemm, threads, profile).unwrap().gflops(flops);
        rows.push(Fig2Row {
            k,
            threads,
            sliding_gflops: sliding,
            gemm_gflops: gemm,
            sliding_roof: peaks.attainable(case.intensity(case.sliding_bytes())),
            gemm_roof: peaks.attainable(case.intensity(case.gemm_bytes())),
            peak: peaks.gflops,
        });
    }
    rows
}

/// Default Fig. 1 / Fig. 2 filter-size grid: every size 2–18 (the custom
/// and generic regimes plus the crossover), then the compound regime
/// sampled to 49 where the zigzag lives.
pub fn default_k_grid() -> Vec<usize> {
    let mut ks: Vec<usize> = (2..=18).collect();
    ks.extend([20, 22, 24, 26, 28, 31, 32, 33, 40, 47, 48, 49]);
    ks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_kernel_policy() {
        assert_eq!(auto_kernel_name(3), "custom");
        assert_eq!(auto_kernel_name(5), "custom");
        assert_eq!(auto_kernel_name(4), "generic");
        assert_eq!(auto_kernel_name(17), "generic");
        assert_eq!(auto_kernel_name(18), "compound");
    }

    #[test]
    fn sweeps_produce_rows() {
        // Tiny geometry so the test is fast even in debug builds.
        let ks = [3, 18];
        let rows = fig1_speedup_sweep(&ks, 1, |k| ConvCase::square(1, 32, k));
        assert_eq!(rows.len(), 2);
        assert!(rows[0].t_gemm > 0.0 && rows[0].t_sliding > 0.0);
        assert!(rows[0].t_generic.is_some());
        assert!(rows[1].t_generic.is_none(), "k=18 exceeds generic");
        assert_eq!(rows[0].threads, 1);
        let rows2 = fig2_throughput_sweep(&[3], 2, |k| ConvCase::square(1, 32, k));
        assert!(rows2[0].sliding_gflops > 0.0);
        assert!(rows2[0].peak >= rows2[0].sliding_roof * 0.99);
        assert_eq!(rows2[0].threads, 2);
    }

    #[test]
    fn dtyped_sweeps_produce_rows() {
        // Tiny geometry; exercises the q8 and bf16 timing paths.
        for d in [Dtype::I8, Dtype::Bf16] {
            let rows =
                fig1_speedup_sweep_dtyped(&[3], 1, None, d, |k| ConvCase::square(1, 24, k));
            assert_eq!(rows.len(), 1);
            assert!(rows[0].t_gemm > 0.0 && rows[0].t_sliding > 0.0);
            assert!(rows[0].t_generic.is_none(), "no family ablation below f32");
            assert_eq!(rows[0].kernel_used, if d == Dtype::I8 { "q8" } else { "bf16" });
            let r2 =
                fig2_throughput_sweep_dtyped(&[3], 1, None, d, |k| ConvCase::square(1, 24, k));
            assert!(r2[0].sliding_gflops > 0.0 && r2[0].gemm_gflops > 0.0);
        }
        // F32 delegates to the profiled sweep (same row shape).
        let rows =
            fig1_speedup_sweep_dtyped(&[3], 1, None, Dtype::F32, |k| ConvCase::square(1, 24, k));
        assert!(rows[0].t_generic.is_some());
    }

    #[test]
    fn grid_covers_regimes() {
        let g = default_k_grid();
        assert!(g.contains(&3) && g.contains(&17) && g.contains(&18) && g.contains(&33));
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }
}
