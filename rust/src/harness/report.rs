//! Plain-text table + CSV report output (what the paper's figures print
//! as series).

use std::io::Write;
use std::path::Path;

/// A simple column-aligned table that can also be saved as CSV.
///
/// # Examples
///
/// ```
/// use swconv::harness::report::Table;
///
/// let mut t = Table::new("speedups", &["k", "speedup"]);
/// t.row(vec!["3".into(), "1.52".into()]);
/// let text = t.render();
/// assert!(text.contains("== speedups ==") && text.contains("1.52"));
/// ```
#[derive(Clone, Debug, Default)]
pub struct Table {
    /// Table title (printed above, not in the CSV).
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given title and column headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when there are no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Write as CSV (headers + rows).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// One machine-readable benchmark measurement — one element of the
/// `BENCH_*.json` schema: which figure, which algorithm, which workload
/// shape, how many threads/replicas, how long per iteration and the
/// resulting throughput.
///
/// ## `BENCH_*.json` schema
///
/// Every bench target writes `target/reports/BENCH_<name>.json` via
/// [`write_bench_json`]: a JSON **array**, one object per record, each
/// with exactly these fields —
///
/// ```json
/// [
///   {"bench": "fig1", "algo": "sliding", "shape": "c4_64x64_k5",
///    "threads": 1, "replicas": 1, "ns_per_iter": 81234.5, "gflops": 9.3210}
/// ]
/// ```
///
/// `bench`/`algo`/`shape` are program-generated identifiers (no
/// escaping needed); `algo` is a [`crate::kernels::ConvAlgo::name`]
/// string or a bench-specific label (e.g. `"tuned"` vs `"sliding"` in
/// `BENCH_tuned.json`); `shape` is a `ConvCase::id`. This is a
/// *measurement log* — contrast the dispatch cache
/// `target/autotune/profile.json`, whose schema lives with
/// [`crate::autotune::profile`].
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// Figure/series id, e.g. `"fig1"`.
    pub bench: String,
    /// Algorithm name (a [`crate::kernels::ConvAlgo::name`] string).
    pub algo: String,
    /// Workload id, e.g. `c4_64x64_k5` (see `ConvCase::id`).
    pub shape: String,
    /// Worker threads the kernel ran with (per replica, for serving
    /// benches).
    pub threads: usize,
    /// Backend replicas serving concurrently (1 for plain kernel
    /// benches; the coordinator's inter-request parallelism axis).
    pub replicas: usize,
    /// Median time per iteration, nanoseconds.
    pub ns_per_iter: f64,
    /// Arithmetic throughput, GFLOP/s.
    pub gflops: f64,
}

/// The one JSON-array writer behind every `BENCH_*.json` emitter:
/// creates the parent directory, writes `[`, one `fmt_line`-rendered
/// object per record (comma-separated, two-space indented), `]`. Each
/// `fmt_line` must return a complete JSON object (`{...}`) built from
/// program-generated identifiers — no escaping is applied.
pub fn write_records<T>(
    path: impl AsRef<Path>,
    records: &[T],
    fmt_line: impl Fn(&T) -> String,
) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "[")?;
    for (i, r) in records.iter().enumerate() {
        let sep = if i + 1 == records.len() { "" } else { "," };
        writeln!(f, "  {}{sep}", fmt_line(r))?;
    }
    writeln!(f, "]")?;
    Ok(())
}

/// Write benchmark records as a JSON array (one object per record) so
/// the perf trajectory can be tracked across PRs by any tooling. All
/// field values are program-generated identifiers, so no string escaping
/// is needed.
pub fn write_bench_json(path: impl AsRef<Path>, records: &[BenchRecord]) -> std::io::Result<()> {
    write_records(path, records, |r| {
        format!(
            "{{\"bench\": \"{}\", \"algo\": \"{}\", \"shape\": \"{}\", \
             \"threads\": {}, \"replicas\": {}, \"ns_per_iter\": {:.1}, \"gflops\": {:.4}}}",
            r.bench, r.algo, r.shape, r.threads, r.replicas, r.ns_per_iter, r.gflops
        )
    })
}

/// One graph-compiler benchmark measurement — one element of the
/// `BENCH_graph.json` schema, produced by `benches/graph_fusion.rs`.
///
/// ## `BENCH_graph.json` schema
///
/// A JSON **array**, one object per (model, mode) pair:
///
/// ```json
/// [
///   {"bench": "graph", "model": "quantized-cnn", "mode": "fused",
///    "threads": 1, "ns_per_iter": 812345.0, "gflops": 2.4513,
///    "activation_bytes": 123456}
/// ]
/// ```
///
/// `mode` is `"fused"` (full pass pipeline) or `"unfused"` (the plan
/// reproducing the layer stack verbatim, as under `SWCONV_NO_FUSE=1`);
/// `activation_bytes` is the plan's static per-batch activation
/// traffic from [`crate::graph::CompiledPlan::activation_bytes`] — the
/// memory the passes exist to avoid moving. Comparing the two modes'
/// rows gives both the traffic reduction and the wall-time effect.
#[derive(Clone, Debug, PartialEq)]
pub struct GraphBenchRecord {
    /// Series id, `"graph"`.
    pub bench: String,
    /// Zoo model name.
    pub model: String,
    /// `"fused"` or `"unfused"`.
    pub mode: String,
    /// Worker threads the plan ran with.
    pub threads: usize,
    /// Median time per forward, nanoseconds.
    pub ns_per_iter: f64,
    /// Arithmetic throughput, GFLOP/s.
    pub gflops: f64,
    /// Static activation traffic of the plan for the benched batch,
    /// bytes (quantized i8 edges count one byte per element).
    pub activation_bytes: u64,
}

/// Write graph-compiler bench records as a JSON array (the
/// `BENCH_graph.json` writer — same conventions as
/// [`write_bench_json`]: program-generated identifiers, no escaping).
pub fn write_graph_bench_json(
    path: impl AsRef<Path>,
    records: &[GraphBenchRecord],
) -> std::io::Result<()> {
    write_records(path, records, |r| {
        format!(
            "{{\"bench\": \"{}\", \"model\": \"{}\", \"mode\": \"{}\", \
             \"threads\": {}, \"ns_per_iter\": {:.1}, \"gflops\": {:.4}, \
             \"activation_bytes\": {}}}",
            r.bench, r.model, r.mode, r.threads, r.ns_per_iter, r.gflops, r.activation_bytes
        )
    })
}

/// One streaming-inference benchmark measurement — one element of the
/// `BENCH_stream.json` schema, produced by `benches/stream_latency.rs`.
///
/// ## `BENCH_stream.json` schema
///
/// A JSON **array**, one object per (model, dtype, mode) triple:
///
/// ```json
/// [
///   {"bench": "stream", "model": "edge-audio", "dtype": "f32",
///    "mode": "incremental", "threads": 1, "frames": 512,
///    "p50_ns": 4321.0, "p99_ns": 9876.0, "mean_ns": 5000.0}
/// ]
/// ```
///
/// `mode` is `"incremental"` (one `StreamSession::advance` per frame —
/// O(taps) work) or `"full"` (the naive streamer: recompute the whole
/// window with the batch path on every frame). All latencies are
/// per-frame, nanoseconds; comparing the two modes' rows of the same
/// (model, dtype) gives the streaming speedup the session exists for.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamBenchRecord {
    /// Series id, `"stream"`.
    pub bench: String,
    /// Zoo model name.
    pub model: String,
    /// Serving dtype name (`"f32"`, `"bf16"`, `"i8"`).
    pub dtype: String,
    /// `"incremental"` or `"full"`.
    pub mode: String,
    /// Worker threads the session ran with.
    pub threads: usize,
    /// Frames fed in this measurement.
    pub frames: usize,
    /// Median per-frame latency, nanoseconds.
    pub p50_ns: f64,
    /// 99th-percentile per-frame latency, nanoseconds.
    pub p99_ns: f64,
    /// Mean per-frame latency, nanoseconds.
    pub mean_ns: f64,
}

/// Write streaming bench records as a JSON array (the
/// `BENCH_stream.json` writer — same conventions as
/// [`write_bench_json`]: program-generated identifiers, no escaping).
pub fn write_stream_bench_json(
    path: impl AsRef<Path>,
    records: &[StreamBenchRecord],
) -> std::io::Result<()> {
    write_records(path, records, |r| {
        format!(
            "{{\"bench\": \"{}\", \"model\": \"{}\", \"dtype\": \"{}\", \
             \"mode\": \"{}\", \"threads\": {}, \"frames\": {}, \
             \"p50_ns\": {:.1}, \"p99_ns\": {:.1}, \"mean_ns\": {:.1}}}",
            r.bench, r.model, r.dtype, r.mode, r.threads, r.frames, r.p50_ns, r.p99_ns, r.mean_ns
        )
    })
}

/// One whole-model-planner benchmark measurement — one element of the
/// `BENCH_plan.json` schema, produced by `benches/plan_model.rs`.
///
/// ## `BENCH_plan.json` schema
///
/// A JSON **array**, one object per (model, policy, budget) triple:
///
/// ```json
/// [
///   {"bench": "plan", "model": "squeezenet-lite", "policy": "planned",
///    "dtype": "f32", "threads": 4, "budget_bytes": 1048576,
///    "predicted_peak_bytes": 912345, "predicted_gflops": 3.8123,
///    "ns_per_iter": 812345.0, "gflops": 2.4513}
/// ]
/// ```
///
/// `policy` is `"planned"` (the whole-model planner's per-layer choices
/// under the row's budget), `"greedy-tuned"` (per-kernel tuned dispatch
/// — `ConvAlgo::Tuned` with no whole-model view) or `"paper-policy"`
/// (the paper's fixed k-threshold dispatch). `budget_bytes` is `0` for
/// an unbudgeted row; `predicted_peak_bytes`/`predicted_gflops` are the
/// planner's own cost-model numbers (`0` on the non-planned policies,
/// which don't predict). Parity is asserted before timing, so every row
/// of one model describes bitwise-identical outputs.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanBenchRecord {
    /// Series id, `"plan"`.
    pub bench: String,
    /// Zoo model name.
    pub model: String,
    /// `"planned"`, `"greedy-tuned"` or `"paper-policy"`.
    pub policy: String,
    /// Serving dtype name (`"f32"`, `"i8"`).
    pub dtype: String,
    /// Ctx worker threads.
    pub threads: usize,
    /// Peak-memory budget the row ran under, bytes (`0` = unbudgeted).
    pub budget_bytes: u64,
    /// Planner-predicted peak of live activations + workspace, bytes
    /// (`0` for non-planned policies).
    pub predicted_peak_bytes: u64,
    /// Planner-predicted end-to-end throughput, GFLOP/s (`0` for
    /// non-planned policies).
    pub predicted_gflops: f64,
    /// Median time per forward, nanoseconds.
    pub ns_per_iter: f64,
    /// Measured throughput, GFLOP/s.
    pub gflops: f64,
}

/// Write planner bench records as a JSON array (the `BENCH_plan.json`
/// writer — same conventions as [`write_bench_json`]:
/// program-generated identifiers, no escaping).
pub fn write_plan_bench_json(
    path: impl AsRef<Path>,
    records: &[PlanBenchRecord],
) -> std::io::Result<()> {
    write_records(path, records, |r| {
        format!(
            "{{\"bench\": \"{}\", \"model\": \"{}\", \"policy\": \"{}\", \
             \"dtype\": \"{}\", \"threads\": {}, \"budget_bytes\": {}, \
             \"predicted_peak_bytes\": {}, \"predicted_gflops\": {:.4}, \
             \"ns_per_iter\": {:.1}, \"gflops\": {:.4}}}",
            r.bench,
            r.model,
            r.policy,
            r.dtype,
            r.threads,
            r.budget_bytes,
            r.predicted_peak_bytes,
            r.predicted_gflops,
            r.ns_per_iter,
            r.gflops
        )
    })
}

/// One cache-blocked-tiling benchmark measurement — one element of the
/// `BENCH_tile.json` schema, produced by `benches/tiled_chains.rs`.
///
/// ## `BENCH_tile.json` schema
///
/// A JSON **array**, one object per (model, dtype, mode) triple:
///
/// ```json
/// [
///   {"bench": "tile", "model": "simple-cnn", "dtype": "f32",
///    "threads": 4, "mode": "tiled", "tile": "8x8", "chains": 2,
///    "chain_ws_bytes": 73728, "ns_per_iter": 812345.0,
///    "gflops": 2.4513}
/// ]
/// ```
///
/// `mode` is `"untiled"` (the baseline full-plane executor) or
/// `"tiled"` (the same compiled plan with the chains of the tiling
/// analysis attached). `tile` is the forced output-tile shape of a
/// tiled row (`"auto"` = cache-budget-sized) and `"-"` on untiled
/// rows. `chains` counts the fusable chains the analysis tiled, and
/// `chain_ws_bytes` sums their estimated intra-chain working sets —
/// per-tile on tiled rows, full-plane on the untiled row — so
/// tiled-vs-untiled rows of one model quantify the activation-footprint
/// shrink alongside the wall-time delta. Bitwise parity between the
/// two modes is asserted before anything is timed.
#[derive(Clone, Debug, PartialEq)]
pub struct TileBenchRecord {
    /// Series id, `"tile"`.
    pub bench: String,
    /// Zoo model name.
    pub model: String,
    /// Serving dtype name (`"f32"`, `"bf16"`, `"i8"`).
    pub dtype: String,
    /// Ctx worker threads.
    pub threads: usize,
    /// `"untiled"` or `"tiled"`.
    pub mode: String,
    /// Forced tile shape of a tiled row (`"auto"`, `"8x8"`, …); `"-"`
    /// on untiled rows.
    pub tile: String,
    /// Fusable chains the analysis tiled (also set on the untiled row
    /// — the same chains at full-plane cost).
    pub chains: usize,
    /// Summed estimated intra-chain working set, bytes (per-tile on
    /// tiled rows, full-plane on untiled rows).
    pub chain_ws_bytes: u64,
    /// Median time per forward, nanoseconds.
    pub ns_per_iter: f64,
    /// Measured throughput, GFLOP/s.
    pub gflops: f64,
}

/// Write tiling bench records as a JSON array (the `BENCH_tile.json`
/// writer — same conventions as [`write_bench_json`]:
/// program-generated identifiers, no escaping).
pub fn write_tile_bench_json(
    path: impl AsRef<Path>,
    records: &[TileBenchRecord],
) -> std::io::Result<()> {
    write_records(path, records, |r| {
        format!(
            "{{\"bench\": \"{}\", \"model\": \"{}\", \"dtype\": \"{}\", \
             \"threads\": {}, \"mode\": \"{}\", \"tile\": \"{}\", \
             \"chains\": {}, \"chain_ws_bytes\": {}, \
             \"ns_per_iter\": {:.1}, \"gflops\": {:.4}}}",
            r.bench,
            r.model,
            r.dtype,
            r.threads,
            r.mode,
            r.tile,
            r.chains,
            r.chain_ws_bytes,
            r.ns_per_iter,
            r.gflops
        )
    })
}

/// Format a float with 3 significant decimals for table cells.
pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

/// Format a duration in adaptive units.
pub fn dur(d: std::time::Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}us")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1000.0)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["k", "speedup"]);
        t.row(vec!["3".into(), "1.5".into()]);
        t.row(vec!["17".into(), "2.25".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("speedup"));
        assert_eq!(t.len(), 2);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let p = std::env::temp_dir().join("swconv_test_table.csv");
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "a,b\n1,2\n");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn bench_json_roundtrips_through_parser() {
        let recs = vec![
            BenchRecord {
                bench: "fig1".into(),
                algo: "sliding".into(),
                shape: "c4_64x64_k5".into(),
                threads: 2,
                replicas: 1,
                ns_per_iter: 1234.5,
                gflops: 3.21,
            },
            BenchRecord {
                bench: "fig1".into(),
                algo: "gemm".into(),
                shape: "c4_64x64_k5".into(),
                threads: 1,
                replicas: 4,
                ns_per_iter: 2000.0,
                gflops: 1.5,
            },
        ];
        let p = std::env::temp_dir().join("swconv_test_bench.json");
        write_bench_json(&p, &recs).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let j = crate::runtime::json::Json::parse(&text).expect("valid JSON");
        let arr = match &j {
            crate::runtime::json::Json::Arr(a) => a,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("algo").and_then(|v| v.as_str()), Some("sliding"));
        assert_eq!(arr[1].get("threads").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(arr[1].get("replicas").and_then(|v| v.as_usize()), Some(4));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn graph_bench_json_roundtrips_through_parser() {
        let recs = vec![
            GraphBenchRecord {
                bench: "graph".into(),
                model: "quantized-cnn".into(),
                mode: "fused".into(),
                threads: 1,
                ns_per_iter: 812345.0,
                gflops: 2.45,
                activation_bytes: 123456,
            },
            GraphBenchRecord {
                bench: "graph".into(),
                model: "quantized-cnn".into(),
                mode: "unfused".into(),
                threads: 1,
                ns_per_iter: 901234.0,
                gflops: 2.21,
                activation_bytes: 234567,
            },
        ];
        let p = std::env::temp_dir().join("swconv_test_graph_bench.json");
        write_graph_bench_json(&p, &recs).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let j = crate::runtime::json::Json::parse(&text).expect("valid JSON");
        let arr = match &j {
            crate::runtime::json::Json::Arr(a) => a,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("mode").and_then(|v| v.as_str()), Some("fused"));
        assert_eq!(arr[0].get("activation_bytes").and_then(|v| v.as_usize()), Some(123456));
        assert_eq!(arr[1].get("model").and_then(|v| v.as_str()), Some("quantized-cnn"));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn stream_bench_json_roundtrips_through_parser() {
        let recs = vec![
            StreamBenchRecord {
                bench: "stream".into(),
                model: "edge-audio".into(),
                dtype: "f32".into(),
                mode: "incremental".into(),
                threads: 1,
                frames: 512,
                p50_ns: 4321.0,
                p99_ns: 9876.0,
                mean_ns: 5000.0,
            },
            StreamBenchRecord {
                bench: "stream".into(),
                model: "edge-audio".into(),
                dtype: "f32".into(),
                mode: "full".into(),
                threads: 1,
                frames: 512,
                p50_ns: 87654.0,
                p99_ns: 99999.0,
                mean_ns: 90000.0,
            },
        ];
        let p = std::env::temp_dir().join("swconv_test_stream_bench.json");
        write_stream_bench_json(&p, &recs).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let j = crate::runtime::json::Json::parse(&text).expect("valid JSON");
        let arr = match &j {
            crate::runtime::json::Json::Arr(a) => a,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("mode").and_then(|v| v.as_str()), Some("incremental"));
        assert_eq!(arr[0].get("frames").and_then(|v| v.as_usize()), Some(512));
        assert_eq!(arr[1].get("mode").and_then(|v| v.as_str()), Some("full"));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn write_records_emits_a_valid_array_for_any_line_shape() {
        let p = std::env::temp_dir().join("swconv_test_write_records.json");
        write_records(&p, &[1usize, 2, 3], |n| format!("{{\"n\": {n}}}")).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let j = crate::runtime::json::Json::parse(&text).expect("valid JSON");
        let arr = match &j {
            crate::runtime::json::Json::Arr(a) => a,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("n").and_then(|v| v.as_usize()), Some(3));
        // Empty record sets are still a valid (empty) array.
        write_records(&p, &[] as &[usize], |_| unreachable!()).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(matches!(
            crate::runtime::json::Json::parse(&text),
            Ok(crate::runtime::json::Json::Arr(a)) if a.is_empty()
        ));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn plan_bench_json_roundtrips_through_parser() {
        let recs = vec![
            PlanBenchRecord {
                bench: "plan".into(),
                model: "squeezenet-lite".into(),
                policy: "planned".into(),
                dtype: "f32".into(),
                threads: 4,
                budget_bytes: 1 << 20,
                predicted_peak_bytes: 912345,
                predicted_gflops: 3.81,
                ns_per_iter: 812345.0,
                gflops: 2.45,
            },
            PlanBenchRecord {
                bench: "plan".into(),
                model: "squeezenet-lite".into(),
                policy: "paper-policy".into(),
                dtype: "f32".into(),
                threads: 4,
                budget_bytes: 0,
                predicted_peak_bytes: 0,
                predicted_gflops: 0.0,
                ns_per_iter: 901234.0,
                gflops: 2.21,
            },
        ];
        let p = std::env::temp_dir().join("swconv_test_plan_bench.json");
        write_plan_bench_json(&p, &recs).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let j = crate::runtime::json::Json::parse(&text).expect("valid JSON");
        let arr = match &j {
            crate::runtime::json::Json::Arr(a) => a,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("policy").and_then(|v| v.as_str()), Some("planned"));
        assert_eq!(arr[0].get("budget_bytes").and_then(|v| v.as_usize()), Some(1 << 20));
        assert_eq!(arr[1].get("budget_bytes").and_then(|v| v.as_usize()), Some(0));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn tile_bench_json_roundtrips_through_parser() {
        let recs = vec![
            TileBenchRecord {
                bench: "tile".into(),
                model: "simple-cnn".into(),
                dtype: "f32".into(),
                threads: 4,
                mode: "untiled".into(),
                tile: "-".into(),
                chains: 2,
                chain_ws_bytes: 1 << 18,
                ns_per_iter: 901234.0,
                gflops: 2.21,
            },
            TileBenchRecord {
                bench: "tile".into(),
                model: "simple-cnn".into(),
                dtype: "f32".into(),
                threads: 4,
                mode: "tiled".into(),
                tile: "8x8".into(),
                chains: 2,
                chain_ws_bytes: 73728,
                ns_per_iter: 812345.0,
                gflops: 2.45,
            },
        ];
        let p = std::env::temp_dir().join("swconv_test_tile_bench.json");
        write_tile_bench_json(&p, &recs).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let j = crate::runtime::json::Json::parse(&text).expect("valid JSON");
        let arr = match &j {
            crate::runtime::json::Json::Arr(a) => a,
            other => panic!("expected array, got {other:?}"),
        };
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("mode").and_then(|v| v.as_str()), Some("untiled"));
        assert_eq!(arr[1].get("tile").and_then(|v| v.as_str()), Some("8x8"));
        assert_eq!(arr[1].get("chain_ws_bytes").and_then(|v| v.as_usize()), Some(73728));
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(f3(1.23456), "1.235");
        assert!(dur(std::time::Duration::from_micros(5)).ends_with("us"));
        assert!(dur(std::time::Duration::from_millis(5)).ends_with("ms"));
        assert!(dur(std::time::Duration::from_secs(5)).ends_with('s'));
    }
}
