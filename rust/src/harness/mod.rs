//! Benchmark harness: timing, workload generation, parameter sweeps and
//! the Advisor-style roofline model.
//!
//! criterion is not available in this offline environment, so [`timing`]
//! implements the measurement loop (warm-up, adaptive iteration count,
//! median-of-samples) the benches use; the substitution is recorded in
//! DESIGN.md. [`roofline`] replaces Intel Advisor for Fig. 2: machine
//! peaks are *measured* (FMA micro-kernel, stream triad) and each kernel's
//! arithmetic intensity is *counted* analytically.

pub mod report;
pub mod roofline;
pub mod sweep;
pub mod timing;
pub mod workload;

pub use roofline::{isa_peak, isa_peaks, machine_peaks, IsaPeak, MachinePeaks};
pub use sweep::{
    fig1_speedup_sweep, fig1_speedup_sweep_dtyped, fig1_speedup_sweep_profiled,
    fig2_throughput_sweep, fig2_throughput_sweep_dtyped, fig2_throughput_sweep_profiled,
    Fig1Row, Fig2Row,
};
pub use timing::{bench, Stats};
pub use workload::ConvCase;
