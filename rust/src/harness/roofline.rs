//! Advisor-style roofline model (the paper's Fig. 2 instrumentation).
//!
//! Intel Advisor is not available here, so we reconstruct what it reports:
//!
//! * **Compute peak** — measured by timing a register-resident FMA chain
//!   (6 independent accumulators × 16 lanes × 2 FLOP per FMA), guarded
//!   by an in-cache SGEMM measurement (max of the two is the roof).
//! * **Memory bandwidth** — measured by a stream-triad over a buffer far
//!   larger than LLC.
//! * **Arithmetic intensity** — counted analytically per kernel from the
//!   traffic models in [`crate::harness::workload::ConvCase`].
//!
//! Attainable throughput at intensity `I` is `min(peak, I · bw)` — the
//! classic roofline. Fig. 2 plots measured kernel GFLOP/s against this
//! ceiling.
//!
//! With the explicit `std::arch` microkernels the compute roof is also
//! measured **per instruction-set level** ([`isa_peaks`]): each
//! available [`IsaLevel`] gets its own six-chain FMA peak, so
//! `benches/simd_isa.rs` can report achieved-vs-peak roofline fractions
//! per kernel × ISA instead of comparing an 8-lane AVX2 kernel against
//! a 16-lane portable roof.

use crate::simd::{F32xL, IsaLevel, LANES};
use std::time::Instant;

/// Measured machine ceilings.
#[derive(Clone, Copy, Debug)]
pub struct MachinePeaks {
    /// Peak single-core f32 FMA throughput, GFLOP/s.
    pub gflops: f64,
    /// Sustained DRAM bandwidth (stream triad), GB/s.
    pub bandwidth_gbs: f64,
}

impl MachinePeaks {
    /// Roofline ceiling at arithmetic intensity `i` (FLOP/byte).
    pub fn attainable(&self, i: f64) -> f64 {
        self.gflops.min(i * self.bandwidth_gbs)
    }

    /// The ridge point: intensity where the machine turns compute-bound.
    pub fn ridge(&self) -> f64 {
        self.gflops / self.bandwidth_gbs
    }
}

/// Measure peak FMA throughput with a register-resident kernel.
///
/// Six independent accumulator chains hide the FMA latency; the loop
/// body performs `6 × LANES × 2` FLOP per iteration with no memory
/// traffic. The result is cross-checked against an in-cache SGEMM run
/// (see below) and the max is reported.
pub fn measure_peak_gflops() -> f64 {
    const CHAINS: usize = 6;
    const INNER: usize = 100_000;

    // Warm-up + measure best of 5. The FMA chains must live in
    // registers for the whole inner loop: black_box only at the end of
    // a timed repetition, never inside it (a black_box inside forces a
    // stack round-trip per iteration and under-reports peak by >10x).
    let mut best = f64::MAX;
    for rep in 0..5 {
        let t = Instant::now();
        let out = portable_fma_loop(0.1 + rep as f32 * 1e-3, INNER);
        let dt = t.elapsed().as_secs_f64();
        std::hint::black_box(out);
        best = best.min(dt);
    }
    let flops = (INNER * CHAINS * LANES * 2) as f64;
    let synthetic = flops / best / 1e9;

    // LLVM occasionally re-vectorises the synthetic chain at a narrower
    // width than the real kernels get, under-reporting peak. Guard with a
    // second estimate: the register-blocked SGEMM micro-kernel on an
    // in-cache problem (A 64 KiB, B 256 KiB — resident in L2). Peak is
    // the max of the two; Advisor's "compute roof" is likewise the best
    // measured FMA kernel, not a datasheet number.
    let (m, k, n) = (64usize, 256usize, 256usize);
    let a = vec![1.0f32; m * k];
    let b = vec![1.0f32; k * n];
    let mut c = vec![0.0f32; m * n];
    let mut best_gemm = f64::MAX;
    for _ in 0..5 {
        let t = Instant::now();
        crate::kernels::gemm::sgemm(m, k, n, &a, &b, &mut c);
        best_gemm = best_gemm.min(t.elapsed().as_secs_f64());
        std::hint::black_box(&mut c);
    }
    let gemm_peak = (2 * m * k * n) as f64 / best_gemm / 1e9;
    synthetic.max(gemm_peak)
}

/// The portable six-chain FMA loop behind both [`measure_peak_gflops`]
/// and the scalar entry of [`isa_peaks`]. FLOPs =
/// `iters · 6 chains · LANES lanes · 2`.
#[inline(never)]
fn portable_fma_loop(seed: f32, iters: usize) -> f32 {
    let a = F32xL::splat(1.000_000_1);
    let b = F32xL::splat(1e-9);
    // PERF: named locals, not an array — LLVM keeps indexed arrays on
    // the stack and every FMA becomes a memory round-trip (measured
    // ~4 GFLOP/s instead of >100; EXPERIMENTS.md §Perf). Six named
    // accumulators = enough independent chains to hide the 4-cycle
    // FMA latency at 2 issues/cycle.
    let (mut c0, mut c1, mut c2) = (F32xL::splat(seed), F32xL::splat(seed), F32xL::splat(seed));
    let (mut c3, mut c4, mut c5) = (F32xL::splat(seed), F32xL::splat(seed), F32xL::splat(seed));
    for _ in 0..iters {
        c0 = c0.mul_add(a, b);
        c1 = c1.mul_add(a, b);
        c2 = c2.mul_add(a, b);
        c3 = c3.mul_add(a, b);
        c4 = c4.mul_add(a, b);
        c5 = c5.mul_add(a, b);
    }
    let s = ((c0 + c1) + (c2 + c3)) + (c4 + c5);
    s.reduce_sum()
}

/// One timed repetition of `isa`'s six-chain FMA loop: the explicit
/// intrinsic loop for a SIMD level (availability re-checked, so an
/// impossible level degrades to the portable loop instead of faulting),
/// the portable [`F32xL`] loop for `Scalar`. Returns the chain sum so
/// the caller can keep it live.
fn isa_fma_rep(isa: IsaLevel, iters: usize, seed: f32) -> f32 {
    match isa {
        #[cfg(target_arch = "x86_64")]
        IsaLevel::Avx2 if IsaLevel::Avx2.available() => {
            // SAFETY: AVX2+FMA availability checked by the guard.
            unsafe { crate::simd::x86::fma_peak_avx2(iters) }
        }
        #[cfg(all(target_arch = "x86_64", swconv_avx512))]
        IsaLevel::Avx512 if IsaLevel::Avx512.available() => {
            // SAFETY: AVX-512F availability checked by the guard.
            unsafe { crate::simd::x86::fma_peak_avx512(iters) }
        }
        #[cfg(target_arch = "aarch64")]
        IsaLevel::Neon if IsaLevel::Neon.available() => {
            // SAFETY: NEON availability checked by the guard.
            unsafe { crate::simd::neon::fma_peak_neon(iters) }
        }
        _ => portable_fma_loop(seed, iters),
    }
}

/// Measured peak FMA throughput at one instruction-set level.
#[derive(Clone, Copy, Debug)]
pub struct IsaPeak {
    /// The level this roof was measured at.
    pub isa: IsaLevel,
    /// f32 lanes one of the level's FMA instructions operates on
    /// ([`IsaLevel::lanes`]).
    pub lanes: usize,
    /// Peak single-core f32 FMA throughput at this level, GFLOP/s.
    pub gflops: f64,
}

/// Measure the compute roof of one instruction-set level: best of 5
/// timed repetitions of the level's six-chain register-resident FMA
/// loop. Unlike [`measure_peak_gflops`] there is no SGEMM guard — the
/// point here is the roof of *this level's* FMA issue width, and the
/// explicit intrinsic loops cannot be re-vectorised by LLVM.
pub fn measure_isa_peak(isa: IsaLevel) -> IsaPeak {
    const CHAINS: usize = 6;
    const INNER: usize = 100_000;
    let mut best = f64::MAX;
    for rep in 0..5 {
        let t = Instant::now();
        let out = isa_fma_rep(isa, INNER, 0.1 + rep as f32 * 1e-3);
        let dt = t.elapsed().as_secs_f64();
        std::hint::black_box(out);
        best = best.min(dt);
    }
    let lanes = isa.lanes();
    let gflops = (INNER * CHAINS * lanes * 2) as f64 / best / 1e9;
    IsaPeak { isa, lanes, gflops }
}

/// The per-level compute roofs of every [`IsaLevel::available_levels`]
/// on this machine, measured once per process (scalar first, in
/// [`IsaLevel::ALL`] order).
pub fn isa_peaks() -> &'static [IsaPeak] {
    use std::sync::OnceLock;
    static PEAKS: OnceLock<Vec<IsaPeak>> = OnceLock::new();
    PEAKS.get_or_init(|| IsaLevel::available_levels().into_iter().map(measure_isa_peak).collect())
}

/// The measured compute roof of `isa`, or `None` when the level is not
/// available on this machine.
pub fn isa_peak(isa: IsaLevel) -> Option<IsaPeak> {
    isa_peaks().iter().find(|p| p.isa == isa).copied()
}

/// Measure sustained memory bandwidth with a stream triad
/// (`a[i] = b[i] + s·c[i]`, 3 × 4 bytes moved per element).
pub fn measure_bandwidth_gbs() -> f64 {
    let n = 32 * 1024 * 1024 / 4; // 32 MiB per array, > LLC
    let b = vec![1.0f32; n];
    let c = vec![2.0f32; n];
    let mut a = vec![0.0f32; n];
    let s = 3.0f32;

    let mut best = f64::MAX;
    for _ in 0..5 {
        let t = Instant::now();
        for i in 0..n {
            a[i] = b[i] + s * c[i];
        }
        std::hint::black_box(&mut a);
        best = best.min(t.elapsed().as_secs_f64());
    }
    (3 * n * 4) as f64 / best / 1e9
}

/// Measure both ceilings (cached per process — the measurement itself
/// takes ~100 ms).
pub fn machine_peaks() -> MachinePeaks {
    use std::sync::OnceLock;
    static PEAKS: OnceLock<MachinePeaks> = OnceLock::new();
    *PEAKS.get_or_init(|| MachinePeaks {
        gflops: measure_peak_gflops(),
        bandwidth_gbs: measure_bandwidth_gbs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attainable_is_min_of_roofs() {
        let p = MachinePeaks { gflops: 100.0, bandwidth_gbs: 10.0 };
        assert_eq!(p.attainable(1.0), 10.0);
        assert_eq!(p.attainable(1000.0), 100.0);
        assert_eq!(p.ridge(), 10.0);
    }

    #[test]
    fn measured_peaks_plausible() {
        // Debug builds are slow; just require strictly positive and sane
        // ordering (compute roof above 0.1 GFLOP/s, bandwidth above
        // 0.1 GB/s on any machine this runs on).
        let p = machine_peaks();
        assert!(p.gflops > 0.1, "peak {p:?}");
        assert!(p.bandwidth_gbs > 0.1, "bw {p:?}");
    }

    #[test]
    fn isa_peaks_cover_every_available_level() {
        let peaks = isa_peaks();
        let levels = IsaLevel::available_levels();
        assert_eq!(peaks.len(), levels.len());
        for (p, isa) in peaks.iter().zip(levels) {
            assert_eq!(p.isa, isa);
            assert_eq!(p.lanes, isa.lanes());
            assert!(p.gflops > 0.0, "{p:?}: no throughput measured");
        }
        // Scalar is always measurable, and lookup round-trips.
        let s = isa_peak(IsaLevel::Scalar).expect("scalar roof");
        assert_eq!(s.lanes, crate::simd::LANES);
        // An unavailable level has no roof.
        for isa in IsaLevel::ALL {
            assert_eq!(isa_peak(isa).is_some(), isa.available(), "{isa}");
        }
    }
}
