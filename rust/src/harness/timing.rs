//! Measurement loop: warm-up, adaptive iteration count, robust statistics.
//!
//! All paper experiments are single-threaded (paper §2: "all tests have
//! been run in a single-core configuration"), so a simple wall-clock loop
//! with median aggregation is accurate and deterministic enough; the
//! benches report median and MAD so outliers (scheduler preemption) are
//! visible instead of folded into a mean.

use std::time::{Duration, Instant};

/// Robust summary of one benchmark.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    /// Median time per iteration.
    pub median: Duration,
    /// Minimum observed time per iteration.
    pub min: Duration,
    /// Median absolute deviation.
    pub mad: Duration,
    /// Number of timed samples.
    pub samples: usize,
    /// Iterations per sample.
    pub iters_per_sample: usize,
}

impl Stats {
    /// Median in seconds.
    pub fn secs(&self) -> f64 {
        self.median.as_secs_f64()
    }

    /// Throughput in GFLOP/s given the per-iteration FLOP count.
    pub fn gflops(&self, flops: u64) -> f64 {
        flops as f64 / self.secs() / 1e9
    }
}

/// Benchmark a closure: warm up, pick an iteration count so one sample
/// takes ≳ `sample_target`, then time `samples` samples and report robust
/// statistics.
///
/// The closure should return something observable (its result is passed
/// to `std::hint::black_box` to stop dead-code elimination).
pub fn bench_config<T>(
    mut f: impl FnMut() -> T,
    samples: usize,
    sample_target: Duration,
) -> Stats {
    // Warm-up and calibration: run until we have a stable single-shot
    // estimate (at least 3 runs, at least ~5 ms total).
    let mut one = Duration::ZERO;
    let calib_start = Instant::now();
    let mut calib_runs = 0u32;
    while calib_runs < 3 || calib_start.elapsed() < Duration::from_millis(5) {
        let t = Instant::now();
        std::hint::black_box(f());
        one = t.elapsed().max(Duration::from_nanos(1));
        calib_runs += 1;
        if calib_runs > 1000 {
            break;
        }
    }
    let iters = (sample_target.as_nanos() / one.as_nanos()).clamp(1, 1_000_000) as usize;

    let mut times: Vec<Duration> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        times.push((t.elapsed() / iters as u32).max(Duration::from_nanos(1)));
    }
    times.sort();
    let median = times[times.len() / 2];
    let min = times[0];
    let mut devs: Vec<Duration> = times
        .iter()
        .map(|&t| if t > median { t - median } else { median - t })
        .collect();
    devs.sort();
    Stats { median, min, mad: devs[devs.len() / 2], samples, iters_per_sample: iters }
}

/// Benchmark with the default configuration (9 samples of ≥ 20 ms).
pub fn bench<T>(f: impl FnMut() -> T) -> Stats {
    bench_config(f, 9, Duration::from_millis(20))
}

/// Quick benchmark for sweeps with many points (5 samples of ≥ 10 ms).
pub fn bench_quick<T>(f: impl FnMut() -> T) -> Stats {
    bench_config(f, 5, Duration::from_millis(10))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_orders_correctly() {
        let mut x = 0u64;
        let s = bench_config(
            || {
                // black_box inside the loop so the whole body cannot be
                // const-folded away in release builds.
                for i in 0..100u64 {
                    x = x.wrapping_add(std::hint::black_box(i * i));
                }
                x
            },
            5,
            Duration::from_micros(500),
        );
        assert!(s.min <= s.median);
        assert!(s.samples == 5);
        assert!(s.iters_per_sample >= 1);
        assert!(s.secs() > 0.0);
    }

    #[test]
    fn gflops_conversion() {
        let s = Stats {
            median: Duration::from_secs(1),
            min: Duration::from_secs(1),
            mad: Duration::ZERO,
            samples: 1,
            iters_per_sample: 1,
        };
        assert!((s.gflops(2_000_000_000) - 2.0).abs() < 1e-9);
    }
}
