//! Workload generation for the paper's experiments.
//!
//! A [`ConvCase`] captures one point of the Fig. 1 / Fig. 2 sweeps:
//! geometry + filter size, with deterministic input/weight tensors and
//! the analytic FLOP/byte counts the roofline model needs.

use crate::kernels::{im2col::im2col_bytes, Conv2dParams};
use crate::tensor::{Dtype, Tensor};

/// One convolution benchmark case.
#[derive(Clone, Debug)]
pub struct ConvCase {
    /// Batch size.
    pub n: usize,
    /// Input channels.
    pub c_in: usize,
    /// Output channels.
    pub c_out: usize,
    /// Input height.
    pub h: usize,
    /// Input width.
    pub w: usize,
    /// Filter size (kh = kw = k).
    pub k: usize,
    /// Stride/pad/groups.
    pub params: Conv2dParams,
    /// RNG seed for the tensors.
    pub seed: u64,
}

impl ConvCase {
    /// The paper's Fig. 1/2 style case: single image, square geometry,
    /// valid padding, unit stride.
    pub fn square(c: usize, hw: usize, k: usize) -> Self {
        ConvCase {
            n: 1,
            c_in: c,
            c_out: c,
            h: hw,
            w: hw,
            k,
            params: Conv2dParams::default(),
            seed: 0xC0FFEE + k as u64,
        }
    }

    /// Output spatial size.
    pub fn out_size(&self) -> (usize, usize) {
        self.params.out_size(self.h, self.w, self.k, self.k)
    }

    /// Deterministic input tensor `[n, c_in, h, w]`.
    pub fn input(&self) -> Tensor {
        Tensor::rand_uniform(&[self.n, self.c_in, self.h, self.w], -1.0, 1.0, self.seed)
    }

    /// Deterministic weight tensor `[c_out, c_in/g, k, k]`.
    pub fn weights(&self) -> Tensor {
        Tensor::rand_uniform(
            &[self.c_out, self.c_in / self.params.groups, self.k, self.k],
            -1.0,
            1.0,
            self.seed + 1,
        )
    }

    /// FLOPs of one convolution (2 per multiply-accumulate).
    pub fn flops(&self) -> u64 {
        let (oh, ow) = self.out_size();
        let taps = (self.c_in / self.params.groups) * self.k * self.k;
        (2 * self.n * self.c_out * oh * ow * taps) as u64
    }

    /// Minimum HBM/DRAM traffic in bytes for the *sliding* kernel: read
    /// the input once per filter row tap that misses cache — model as one
    /// input read + one output write + weights (compulsory misses only).
    pub fn sliding_bytes(&self) -> u64 {
        self.sliding_bytes_for(Dtype::F32)
    }

    /// [`ConvCase::sliding_bytes`] for an arbitrary storage dtype:
    /// input/weights stream at `dtype.bytes()` per element (1 for int8
    /// codes, 2 for bf16) while the output writes at the accumulator
    /// width (i32/f32 — 4 bytes; bf16 rounds back to 2). This is where
    /// the quantized roofline moves: less traffic at identical
    /// arithmetic.
    pub fn sliding_bytes_for(&self, dtype: Dtype) -> u64 {
        let (oh, ow) = self.out_size();
        let input = self.n * self.c_in * self.h * self.w;
        let output = self.n * self.c_out * oh * ow;
        let weights = self.c_out * (self.c_in / self.params.groups) * self.k * self.k;
        let out_bytes = match dtype {
            Dtype::Bf16 => 2,
            _ => 4,
        };
        (dtype.bytes() * (input + weights) + out_bytes * output) as u64
    }

    /// DRAM traffic for the `im2col` baseline: the column matrix is both
    /// written and read back (k² bloat), plus output and weights.
    pub fn gemm_bytes(&self) -> u64 {
        self.gemm_bytes_for(Dtype::F32)
    }

    /// [`ConvCase::gemm_bytes`] for an arbitrary storage dtype: the k²
    /// column-matrix bloat scales with the element width (an int8
    /// column matrix is 4× smaller in bytes but still k²× the input),
    /// the output writes at accumulator width.
    pub fn gemm_bytes_for(&self, dtype: Dtype) -> u64 {
        let (oh, ow) = self.out_size();
        // im2col_bytes counts f32 columns; rescale to this dtype.
        let col = self.n
            * im2col_bytes(self.c_in / self.params.groups, self.k, self.k, oh, ow)
            * self.params.groups
            * dtype.bytes()
            / 4;
        let input = dtype.bytes() * self.n * self.c_in * self.h * self.w;
        let out_bytes = match dtype {
            Dtype::Bf16 => 2,
            _ => 4,
        };
        let output = out_bytes * self.n * self.c_out * oh * ow;
        let weights =
            dtype.bytes() * self.c_out * (self.c_in / self.params.groups) * self.k * self.k;
        (input + 2 * col + output + weights) as u64
    }

    /// Arithmetic intensity (FLOP/byte) for the given algorithm's traffic
    /// model.
    pub fn intensity(&self, bytes: u64) -> f64 {
        self.flops() as f64 / bytes as f64
    }

    /// Short id for reports: `c{c}_{h}x{w}_k{k}`.
    pub fn id(&self) -> String {
        format!("c{}_{}x{}_k{}", self.c_in, self.h, self.w, self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_case_geometry() {
        let c = ConvCase::square(4, 64, 5);
        assert_eq!(c.out_size(), (60, 60));
        assert_eq!(c.input().dims(), &[1, 4, 64, 64]);
        assert_eq!(c.weights().dims(), &[4, 4, 5, 5]);
    }

    #[test]
    fn flop_count_matches_manual() {
        let c = ConvCase::square(2, 10, 3);
        // 2 * 1 * 2 * 8*8 * (2*9) = 4608
        assert_eq!(c.flops(), 2 * 2 * 64 * 18);
    }

    #[test]
    fn gemm_traffic_exceeds_sliding() {
        let c = ConvCase::square(8, 64, 7);
        assert!(c.gemm_bytes() > c.sliding_bytes());
        // The bloat grows with k².
        let c2 = ConvCase::square(8, 64, 14);
        let ratio7 = c.gemm_bytes() as f64 / c.sliding_bytes() as f64;
        let ratio14 = c2.gemm_bytes() as f64 / c2.sliding_bytes() as f64;
        assert!(ratio14 > ratio7);
    }

    #[test]
    fn intensity_positive() {
        let c = ConvCase::square(4, 32, 5);
        assert!(c.intensity(c.sliding_bytes()) > c.intensity(c.gemm_bytes()));
    }

    #[test]
    fn dtype_scales_traffic_models() {
        let c = ConvCase::square(4, 32, 5);
        assert_eq!(c.sliding_bytes_for(Dtype::F32), c.sliding_bytes());
        assert_eq!(c.gemm_bytes_for(Dtype::F32), c.gemm_bytes());
        assert!(c.sliding_bytes_for(Dtype::I8) < c.sliding_bytes_for(Dtype::Bf16));
        assert!(c.sliding_bytes_for(Dtype::Bf16) < c.sliding_bytes());
        assert!(c.gemm_bytes_for(Dtype::I8) < c.gemm_bytes());
        // The bloat ratio is dtype-independent in elements, so int8
        // still pays the k² column matrix relative to its own input.
        assert!(c.gemm_bytes_for(Dtype::I8) > c.sliding_bytes_for(Dtype::I8));
    }

    #[test]
    fn ids_stable() {
        assert_eq!(ConvCase::square(3, 32, 5).id(), "c3_32x32_k5");
    }
}
