//! # swconv — Sliding-Window convolution primitives for commodity hardware
//!
//! Reproduction of *"Accelerating Machine Learning Primitives on Commodity
//! Hardware"* (R. Snytsar, 2023): 1-D and 2-D convolution and pooling
//! expressed as **sliding window sums** and evaluated by SIMD "vector
//! slide" kernels that operate on the unmodified input, instead of the
//! usual `im2col` + GEMM route that bloats memory by the filter size.
//!
//! The crate is organised in layers:
//!
//! * [`simd`] — the portable "hardware vector" ([`simd::F32xL`], 16 × f32 =
//!   one AVX-512 register) with the *slide* (lane-shift) primitives the
//!   paper's kernels are built from, plus compound (multi-register) slides —
//!   and the explicit lane: runtime instruction-set detection
//!   ([`simd::IsaLevel`], forceable via `--isa`) selecting hand-written
//!   `std::arch` row microkernels (AVX2+FMA / AVX-512F / NEON) that are
//!   bit-identical to the portable path.
//! * [`tensor`] — a minimal NCHW tensor library (owned buffers, stride
//!   math, zero-padding), **generic over its element type**: the
//!   [`tensor::Element`] layer defines `f32`, bfloat16
//!   ([`tensor::Bf16`]) and quantized int8 (i8 codes under a per-tensor
//!   [`tensor::QuantParams`]) storage with their accumulator types —
//!   adding a dtype is a trait impl, not a fork of the kernel tree.
//! * [`exec`] — the execution-context subsystem: [`exec::ExecCtx`] carries
//!   the algorithm choice, the serving element type
//!   ([`tensor::Dtype`]), a worker-thread count backed by a persistent
//!   work-stealing worker pool ([`exec::WorkerPool`] — built lazily,
//!   optionally pinned to cores via [`exec::affinity`]; scoped
//!   spawn-per-region threads remain as the `SWCONV_NO_POOL=1` /
//!   `--no-pool` fallback, bit-identical), a dtype-generic reusable
//!   scratch arena (byte-based retention accounting) and (optionally)
//!   the machine's measured dispatch profile; every kernel has a `*_ctx`
//!   variant that parallelises over independent output planes/rows and
//!   draws its padded/scratch/column buffers from the arena instead of
//!   allocating per call.
//! * [`kernels`] — the paper's contribution and its baselines:
//!   sliding-window 1-D/2-D convolution (generic, compound, and custom
//!   k=3/k=5 kernels), sliding max/avg pooling, plus the `im2col` + blocked
//!   GEMM baseline (our stand-in for ONNX Runtime's `MlasConv`) and a naïve
//!   direct convolution oracle — each sliding primitive also in `_q8`
//!   (int8 codes, exact i32 accumulation) and `_bf16` variants, with an
//!   int8 `im2col`+GEMM baseline keeping the quantized comparison honest.
//! * [`autotune`] — per-machine dispatch autotuning: a microbenchmark
//!   pass races the kernels per (filter width, thread count, dtype,
//!   ISA level) and caches the winners as a [`autotune::DispatchProfile`]
//!   (`target/autotune/profile.json`); [`kernels::ConvAlgo::Tuned`] and
//!   the sliding kernel's `Auto` row selection dispatch from it, falling
//!   back to the paper's k=17 policy when no profile exists.
//! * [`nn`] — a small layer/graph library (Conv2d, Pool, ReLU, Linear, …)
//!   and a model zoo (SqueezeNet-lite, MobileNet-lite, SimpleCNN, a
//!   quantized CNN) so the primitives can be exercised inside real
//!   networks.
//! * [`graph`] — the compilation layer: models lower into a typed
//!   graph IR ([`graph::Graph`]), a pass pipeline fuses conv/GEMM
//!   epilogues (bias + ReLU at the output write), elides explicit
//!   zero-pads into kernel edge handling and hoists quantize boundaries
//!   so adjacent int8 convs exchange i8 activations directly; the
//!   optimized [`graph::CompiledPlan`] executes bit-identically to the
//!   layer-by-layer path (`SWCONV_NO_FUSE=1` / `--no-fuse` disables the
//!   passes). On top sits the whole-model planner
//!   ([`graph::plan_model`]): per-conv-node algorithm × worker-split
//!   choices maximizing predicted end-to-end throughput under a
//!   peak-memory budget, costed from the cached
//!   [`autotune::DispatchProfile`] — planned execution stays
//!   bit-identical to the unplanned route (f32 re-routes only within
//!   the ctx route's FP-summation family; int8 roams the full exact
//!   kernel set), and an infeasible budget is an explicit
//!   [`graph::PlanError::Infeasible`] naming the feasibility floor
//!   ([`graph::min_feasible_budget`]).
//! * [`stream`] — streaming inference: mirrored ring buffers and
//!   [`stream::StreamSession`], which advances a compiled model one
//!   frame at a time in O(taps) per sample (conv windows run the batch
//!   kernels on the live ring window; avg-pool uses the
//!   sliding-window-sum recurrence), with a batch reference and a
//!   derived error bound so streamed == batch is checkable — bit-exact
//!   in i8, within `StreamSession::tolerance` in f32/bf16.
//! * [`harness`] — workload generators, parameter sweeps, the
//!   Advisor-style roofline model, and the report builders that regenerate
//!   the paper's Fig. 1 (speedup) and Fig. 2 (throughput).
//! * [`runtime`] — PJRT wrapper that loads the AOT artifacts produced by
//!   `python/compile/aot.py` (JAX/Pallas lowered to HLO text) and executes
//!   them from Rust; Python is never on the request path.
//! * [`coordinator`] — the serving driver: request queue, dynamic batcher,
//!   per-algorithm router, replicated backends (a shard planner splits
//!   formed batches across N replica workers, each owning its own
//!   [`exec::ExecCtx`]) and per-replica latency/throughput metrics with
//!   an aggregated view; the batch path is panic-proof.
//! * [`error`] — string-backed `anyhow` substitute (offline build).
//!
//! ## Quickstart
//!
//! ```
//! use swconv::tensor::Tensor;
//! use swconv::kernels::{conv2d, Conv2dParams, ConvAlgo};
//!
//! let x = Tensor::randn(&[1, 3, 32, 32], 42);     // NCHW
//! let w = Tensor::randn(&[8, 3, 5, 5], 7);        // [Cout, Cin, kh, kw]
//! let p = Conv2dParams::default();
//! let y_sliding = conv2d(&x, &w, None, &p, ConvAlgo::Sliding);
//! let y_gemm    = conv2d(&x, &w, None, &p, ConvAlgo::Im2colGemm);
//! assert!(y_sliding.allclose(&y_gemm, 1e-4));
//! ```

pub mod error;
pub mod simd;
pub mod tensor;
pub mod exec;
pub mod kernels;
pub mod autotune;
pub mod graph;
pub mod nn;
pub mod stream;
pub mod harness;
pub mod runtime;
pub mod coordinator;
