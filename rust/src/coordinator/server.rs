//! The coordinator: a router in front of per-backend serving tiers,
//! each a batch planner plus N replica worker threads.
//!
//! ```text
//! client ──submit(backend, item)──▶ router ──queue──▶ planner(backend A)
//!                                        └────queue──▶ planner(backend B)
//! planner: next_batch → ShardPlanner → per-replica sub-batches
//! replica: stack shard → Backend::infer (panic-proof) → split → reply
//! ```
//!
//! Each backend runs `replicas` worker threads (see
//! [`super::backend::BackendSpec::with_replicas`]); every replica
//! constructs its own backend instance *on* its thread, so non-`Send`
//! backends (PJRT) and per-replica scratch (`ExecCtx` arenas) both work.
//! The planner splits formed batches across idle replicas — round-robin
//! for small batches, scatter/gather for large ones (policy in
//! [`super::shard`]) — and each request's reply channel reassembles the
//! answer, so no request is lost or duplicated by sharding.
//!
//! The serving path is panic-proof: a panic inside `Backend::infer`
//! answers the shard with [`InferError::Backend`] and the replica keeps
//! serving later requests instead of wedging its queue.
//!
//! ## Streaming sessions
//!
//! Besides batches, a tier serves stateful streams
//! ([`Coordinator::open_stream`] / [`Coordinator::advance_stream`]):
//! stream commands bypass the batcher and go straight to the replica
//! that owns the session — **session affinity** pins each stream to one
//! replica so its ring buffers and arena scratch stay hot between
//! frames. A replica that breaks (or is quarantined via
//! [`Coordinator::quarantine_replica`]) has its streams failed over to
//! a healthy replica with a **fresh session** and `reset = true` on the
//! response — a stream never silently resumes from stale state.

use super::backend::{Backend, BackendFactory, BackendSpec, PinPolicy};
use super::batcher::{next_batch, BatchOutcome, BatchPolicy};
use super::metrics::{LatencyHistogram, MetricsSnapshot};
use super::shard::{ShardPlanner, BROKEN_REPLICA_BIAS};
use crate::tensor::Tensor;
use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A completed inference.
#[derive(Debug)]
pub struct InferResponse {
    /// Request id (assigned by the coordinator, monotonically increasing).
    pub id: u64,
    /// Model output for this item (batch dimension removed).
    pub output: Result<Tensor, InferError>,
    /// End-to-end latency (submit → reply).
    pub latency: std::time::Duration,
}

/// Inference failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InferError {
    /// Unknown backend name.
    UnknownBackend(String),
    /// Input shape didn't match the backend's item shape.
    BadShape {
        /// What the backend expects.
        expected: Vec<usize>,
        /// What the request carried.
        got: Vec<usize>,
    },
    /// The backend failed (an `Err` from `Backend::infer`, a panic
    /// inside it, or a malformed output batch).
    Backend(String),
    /// The coordinator is shutting down.
    Shutdown,
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::UnknownBackend(b) => write!(f, "unknown backend '{b}'"),
            InferError::BadShape { expected, got } => {
                write!(f, "bad input shape {got:?}, expected {expected:?}")
            }
            InferError::Backend(e) => write!(f, "backend error: {e}"),
            InferError::Shutdown => write!(f, "coordinator shut down"),
        }
    }
}

impl std::error::Error for InferError {}

struct Request {
    id: u64,
    input: Tensor,
    submitted: Instant,
    reply: Sender<InferResponse>,
}

/// Client handle to one open stream on a backend tier. Obtained from
/// [`Coordinator::open_stream`]; pass it to
/// [`Coordinator::advance_stream`] / [`Coordinator::close_stream`].
#[derive(Debug)]
pub struct StreamHandle {
    backend: String,
    sid: u64,
}

impl StreamHandle {
    /// Coordinator-assigned stream id (unique within this coordinator).
    pub fn id(&self) -> u64 {
        self.sid
    }

    /// Name of the backend tier this stream is open on.
    pub fn backend(&self) -> &str {
        &self.backend
    }
}

/// One frame's outcome on a coordinator-managed stream.
#[derive(Debug)]
pub struct StreamFrame {
    /// The column this frame produced, if the model emitted one
    /// (streaming models emit nothing during window warm-up).
    pub output: Option<Vec<f32>>,
    /// `true` when the session was rebuilt before serving this frame —
    /// replica failover or idle eviction. The session state restarted
    /// from scratch (warm-up replays), so earlier frames of this stream
    /// did **not** contribute to `output`; callers that need exact
    /// continuity must re-send their window.
    pub reset: bool,
}

/// Messages a replica worker consumes: planner-formed batch shards,
/// or stream commands routed directly by the coordinator (bypassing
/// the batcher — streams are latency-bound and already placed).
enum ReplicaMsg {
    Shard(Vec<Request>),
    Stream(StreamCmd),
}

/// One stream operation, answered on its own reply channel.
struct StreamCmd {
    sid: u64,
    op: StreamOp,
    reply: Sender<StreamReply>,
}

enum StreamOp {
    Open,
    Advance(Vec<f32>),
    Close,
}

enum StreamReply {
    /// Operation succeeded; `Advance` carries the emitted column.
    Done(Option<Vec<f32>>),
    /// Session-level failure (unknown/evicted sid, bad frame, backend
    /// without streaming support). The replica itself is fine.
    Err(String),
    /// Replica-level failure (factory never produced a backend): no
    /// stream can ever be served here, fail over.
    Broken(String),
}

/// Planner-side handle to one replica worker.
struct ReplicaHandle {
    queue: Sender<ReplicaMsg>,
    /// Shards dispatched but not yet finished (queue depth); the shard
    /// planner treats a replica with zero as idle. A replica whose
    /// factory failed — or whose thread died — carries
    /// [`BROKEN_REPLICA_BIAS`] so the planner excludes it while healthy
    /// replicas remain.
    in_flight: Arc<AtomicUsize>,
}

/// One backend's serving tier, as seen by the router.
struct Worker {
    queue: Sender<Request>,
    item_shape: Vec<usize>,
    /// One histogram per replica, index-aligned with the replica threads.
    replica_metrics: Vec<Arc<LatencyHistogram>>,
    /// Direct per-replica senders for stream commands (same channels the
    /// planner shards into, so batch and stream work interleave on one
    /// queue and never race the backend).
    replica_queues: Vec<Sender<ReplicaMsg>>,
    /// The planner's queue-depth counters, shared here so stream
    /// placement can skip tombstoned replicas (depth ≥
    /// [`BROKEN_REPLICA_BIAS`]).
    replica_load: Vec<Arc<AtomicUsize>>,
    /// Stream affinity: sid → owning replica. A stream stays on its
    /// replica for life unless that replica breaks.
    streams: Mutex<HashMap<u64, usize>>,
    /// Replicas quarantined for stream placement (observed broken, or
    /// marked via [`Coordinator::quarantine_replica`]).
    dead: Mutex<HashSet<usize>>,
    /// Planner thread + replica threads.
    joins: Vec<JoinHandle<()>>,
}

/// The request router + replicated worker pool.
pub struct Coordinator {
    workers: HashMap<String, Worker>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Build a coordinator: per backend spec, one planner thread plus
    /// `spec.replicas` replica worker threads, each constructing its own
    /// backend instance *on* the replica thread (PJRT handles are not
    /// `Send`). A factory that fails — or panics — turns that replica
    /// into an error responder instead of wedging the tier.
    pub fn new(backends: Vec<BackendSpec>, policy: BatchPolicy) -> Self {
        let mut workers = HashMap::new();
        for spec in backends {
            let BackendSpec { name, item_shape, replicas, factory, profile, dtype, pinning } =
                spec;
            let replicas = replicas.max(1);
            let (tx, rx) = channel::<Request>();
            let mut replica_metrics = Vec::with_capacity(replicas);
            let mut replica_queues = Vec::with_capacity(replicas);
            let mut replica_load = Vec::with_capacity(replicas);
            let mut joins = Vec::with_capacity(replicas + 1);
            let mut handles = Vec::with_capacity(replicas);
            for r in 0..replicas {
                let (stx, srx) = channel::<ReplicaMsg>();
                let metrics = Arc::new(LatencyHistogram::new());
                let in_flight = Arc::new(AtomicUsize::new(0));
                let m2 = Arc::clone(&metrics);
                let if2 = Arc::clone(&in_flight);
                let f2: BackendFactory = Arc::clone(&factory);
                let p2 = profile.clone();
                // Replica r of n gets core slice r: pinned on the
                // replica thread itself (below), so everything the
                // factory allocates — weights aside — first-touches on
                // the replica's own core group.
                let pin = pinning.slice_for(r, replicas);
                let join = std::thread::Builder::new()
                    .name(format!("swconv-{name}-r{r}"))
                    .spawn(move || replica_main(&f2, r, p2, dtype, pin, &srx, &m2, &if2))
                    .expect("spawn replica worker");
                replica_metrics.push(metrics);
                replica_queues.push(stx.clone());
                replica_load.push(Arc::clone(&in_flight));
                joins.push(join);
                handles.push(ReplicaHandle { queue: stx, in_flight });
            }
            // The batcher/planner thread does no kernel work; under an
            // explicit core set it is confined to that set so it never
            // preempts a foreign tier's pinned workers.
            let planner_pin = match &pinning {
                PinPolicy::Cores(set) => Some(set.clone()),
                _ => None,
            };
            let join = std::thread::Builder::new()
                .name(format!("swconv-{name}-planner"))
                .spawn(move || {
                    if let Some(set) = &planner_pin {
                        crate::exec::affinity::pin_current(set);
                    }
                    planner_loop(&rx, policy, handles)
                })
                .expect("spawn batch planner");
            joins.push(join);
            workers.insert(
                name,
                Worker {
                    queue: tx,
                    item_shape,
                    replica_metrics,
                    replica_queues,
                    replica_load,
                    streams: Mutex::new(HashMap::new()),
                    dead: Mutex::new(HashSet::new()),
                    joins,
                },
            );
        }
        Coordinator { workers, next_id: AtomicU64::new(0) }
    }

    /// Registered backend names (sorted).
    pub fn backends(&self) -> Vec<String> {
        let mut v: Vec<String> = self.workers.keys().cloned().collect();
        v.sort();
        v
    }

    /// Replica count for one backend.
    pub fn replicas(&self, backend: &str) -> Option<usize> {
        self.workers.get(backend).map(|w| w.replica_metrics.len())
    }

    /// Submit one item to a backend; the response arrives on the returned
    /// channel. Shape is validated here so errors are immediate.
    pub fn submit(
        &self,
        backend: &str,
        input: Tensor,
    ) -> Result<Receiver<InferResponse>, InferError> {
        let w = self
            .workers
            .get(backend)
            .ok_or_else(|| InferError::UnknownBackend(backend.to_string()))?;
        if input.dims() != &w.item_shape[..] {
            return Err(InferError::BadShape {
                expected: w.item_shape.clone(),
                got: input.dims().to_vec(),
            });
        }
        let (reply, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        w.queue
            .send(Request { id, input, submitted: Instant::now(), reply })
            .map_err(|_| InferError::Shutdown)?;
        Ok(rx)
    }

    /// Submit and block for the result.
    pub fn infer(&self, backend: &str, input: Tensor) -> Result<InferResponse, InferError> {
        let rx = self.submit(backend, input)?;
        rx.recv().map_err(|_| InferError::Shutdown)
    }

    /// Open a stateful stream on a backend tier. The stream is placed on
    /// the healthy replica currently owning the fewest streams and stays
    /// there (**session affinity**) — its ring buffers and arena scratch
    /// live on one thread for the stream's whole life. Fails if the
    /// backend doesn't support streaming (see
    /// [`super::backend::BackendSpec::native_streaming`]) or no healthy
    /// replica remains.
    pub fn open_stream(&self, backend: &str) -> Result<StreamHandle, InferError> {
        let w = self
            .workers
            .get(backend)
            .ok_or_else(|| InferError::UnknownBackend(backend.to_string()))?;
        let sid = self.next_id.fetch_add(1, Ordering::Relaxed);
        let replica = self.place_stream(w, sid)?;
        w.streams.lock().unwrap().insert(sid, replica);
        Ok(StreamHandle { backend: backend.to_string(), sid })
    }

    /// Feed one frame (`in_channels` samples) to an open stream and
    /// block for the outcome. If the stream's session was lost — its
    /// replica broke or was quarantined, or the session was idle-evicted
    /// — a fresh session is opened (on a healthy replica) and this frame
    /// is served from it with `reset = true`; a stream never silently
    /// continues from stale state.
    pub fn advance_stream(
        &self,
        h: &StreamHandle,
        frame: &[f32],
    ) -> Result<StreamFrame, InferError> {
        let w = self
            .workers
            .get(&h.backend)
            .ok_or_else(|| InferError::UnknownBackend(h.backend.clone()))?;
        let replica = *w.streams.lock().unwrap().get(&h.sid).ok_or_else(|| {
            InferError::Backend(format!("stream {} is not open on '{}'", h.sid, h.backend))
        })?;
        if !replica_healthy(w, replica) {
            // The owner was tombstoned since the last frame: fail over
            // before even trying it.
            return self.fail_over(w, h.sid, frame);
        }
        match stream_rpc(w, replica, h.sid, StreamOp::Advance(frame.to_vec())) {
            Ok(StreamReply::Done(output)) => Ok(StreamFrame { output, reset: false }),
            Ok(StreamReply::Err(_)) => {
                // Session-level loss (typically idle eviction). The
                // replica is fine: rebuild the session in place and
                // replay this frame on the fresh state.
                match stream_rpc(w, replica, h.sid, StreamOp::Open) {
                    Ok(StreamReply::Done(_)) => {
                        match stream_rpc(w, replica, h.sid, StreamOp::Advance(frame.to_vec())) {
                            Ok(StreamReply::Done(output)) => {
                                Ok(StreamFrame { output, reset: true })
                            }
                            Ok(StreamReply::Err(e)) => Err(InferError::Backend(e)),
                            Ok(StreamReply::Broken(_)) | Err(_) => {
                                w.dead.lock().unwrap().insert(replica);
                                self.fail_over(w, h.sid, frame)
                            }
                        }
                    }
                    Ok(StreamReply::Err(e)) => Err(InferError::Backend(e)),
                    Ok(StreamReply::Broken(_)) | Err(_) => {
                        w.dead.lock().unwrap().insert(replica);
                        self.fail_over(w, h.sid, frame)
                    }
                }
            }
            Ok(StreamReply::Broken(_)) | Err(_) => {
                // Replica-level loss: quarantine it for streams and move
                // the session elsewhere.
                w.dead.lock().unwrap().insert(replica);
                self.fail_over(w, h.sid, frame)
            }
        }
    }

    /// Close a stream, freeing its session state on the owning replica.
    /// Best-effort and idempotent.
    pub fn close_stream(&self, h: &StreamHandle) {
        let Some(w) = self.workers.get(&h.backend) else { return };
        let Some(replica) = w.streams.lock().unwrap().remove(&h.sid) else { return };
        let (reply, _keep) = channel();
        let _ = w.replica_queues[replica].send(ReplicaMsg::Stream(StreamCmd {
            sid: h.sid,
            op: StreamOp::Close,
            reply,
        }));
    }

    /// Which replica currently owns a stream (`None` if closed). Exposed
    /// so affinity and failover are observable by tests and operators.
    pub fn stream_replica(&self, h: &StreamHandle) -> Option<usize> {
        self.workers.get(&h.backend)?.streams.lock().unwrap().get(&h.sid).copied()
    }

    /// Quarantine one replica for **stream placement**: existing streams
    /// fail over (with a state reset) on their next frame and no new
    /// stream lands there. The batch path is not affected — batch
    /// routing is governed by the planner's queue-depth bias. Returns
    /// `false` for an unknown backend or replica index.
    pub fn quarantine_replica(&self, backend: &str, replica: usize) -> bool {
        match self.workers.get(backend) {
            Some(w) if replica < w.replica_queues.len() => {
                w.dead.lock().unwrap().insert(replica);
                true
            }
            _ => false,
        }
    }

    /// Place a new session: try healthy replicas in ascending
    /// stream-count order, opening on the first that accepts. A replica
    /// that proves broken is quarantined and the next is tried; a
    /// session-level refusal (backend without streaming support) aborts
    /// immediately — every replica runs the same backend.
    fn place_stream(&self, w: &Worker, sid: u64) -> Result<usize, InferError> {
        let mut counts = vec![0usize; w.replica_queues.len()];
        for (_, &r) in w.streams.lock().unwrap().iter() {
            counts[r] += 1;
        }
        let mut order: Vec<usize> = (0..w.replica_queues.len()).collect();
        order.sort_by_key(|&r| (counts[r], r));
        for r in order {
            if !replica_healthy(w, r) {
                continue;
            }
            match stream_rpc(w, r, sid, StreamOp::Open) {
                Ok(StreamReply::Done(_)) => return Ok(r),
                Ok(StreamReply::Err(e)) => return Err(InferError::Backend(e)),
                Ok(StreamReply::Broken(_)) | Err(_) => {
                    w.dead.lock().unwrap().insert(r);
                }
            }
        }
        Err(InferError::Backend("no healthy replica accepts streams".to_string()))
    }

    /// Move a stream to a fresh session on a healthy replica and serve
    /// `frame` from it. The returned frame has `reset = true`: the new
    /// session replays its warm-up, so prior frames are gone by design
    /// rather than silently half-remembered.
    fn fail_over(&self, w: &Worker, sid: u64, frame: &[f32]) -> Result<StreamFrame, InferError> {
        let replica = self.place_stream(w, sid)?;
        w.streams.lock().unwrap().insert(sid, replica);
        match stream_rpc(w, replica, sid, StreamOp::Advance(frame.to_vec())) {
            Ok(StreamReply::Done(output)) => Ok(StreamFrame { output, reset: true }),
            Ok(StreamReply::Err(e)) | Ok(StreamReply::Broken(e)) => Err(InferError::Backend(e)),
            Err(_) => Err(InferError::Shutdown),
        }
    }

    /// Aggregated metrics snapshot for one backend (all replicas merged;
    /// `batches` counts executed shards).
    pub fn metrics(&self, backend: &str) -> Option<MetricsSnapshot> {
        self.workers
            .get(backend)
            .map(|w| LatencyHistogram::aggregate(w.replica_metrics.iter().map(Arc::as_ref)))
    }

    /// Per-replica metrics snapshots for one backend, index-aligned with
    /// the replica threads.
    pub fn replica_metrics(&self, backend: &str) -> Option<Vec<MetricsSnapshot>> {
        self.workers
            .get(backend)
            .map(|w| w.replica_metrics.iter().map(|m| m.snapshot()).collect())
    }

    /// Shut down: close queues and join planners + replicas. In-flight
    /// requests are completed first.
    pub fn shutdown(self) {
        let mut joins = Vec::new();
        for (_, w) in self.workers {
            drop(w.queue);
            joins.extend(w.joins);
        }
        for j in joins {
            let _ = j.join();
        }
    }
}

/// A replica is eligible for streams unless quarantined or tombstoned
/// by the planner (queue-depth bias set when its factory failed or its
/// thread died).
fn replica_healthy(w: &Worker, replica: usize) -> bool {
    !w.dead.lock().unwrap().contains(&replica)
        && w.replica_load[replica].load(Ordering::Acquire) < BROKEN_REPLICA_BIAS
}

/// Send one stream command to a replica and block for its reply.
/// `Err(())` means the channel itself failed (replica thread gone).
fn stream_rpc(
    w: &Worker,
    replica: usize,
    sid: u64,
    op: StreamOp,
) -> Result<StreamReply, ()> {
    let (reply, rx) = channel();
    w.replica_queues[replica]
        .send(ReplicaMsg::Stream(StreamCmd { sid, op, reply }))
        .map_err(|_| ())?;
    rx.recv().map_err(|_| ())
}

/// Per-backend batch planner: form batches, split them across replicas.
/// Exits (dropping the replica queues, which stops the replicas) when
/// the router side closes.
fn planner_loop(rx: &Receiver<Request>, policy: BatchPolicy, replicas: Vec<ReplicaHandle>) {
    let mut planner = ShardPlanner::new(replicas.len());
    let mut in_flight = vec![0usize; replicas.len()];
    loop {
        let mut batch = match next_batch(rx, &policy) {
            BatchOutcome::Batch(b) => b,
            BatchOutcome::Closed => return,
        };
        for (c, h) in in_flight.iter_mut().zip(&replicas) {
            *c = h.in_flight.load(Ordering::Acquire);
        }
        for (replica, range) in planner.plan(batch.len(), &in_flight) {
            // Ranges are ascending and contiguous: peel off the front.
            let rest = batch.split_off(range.len());
            let shard = std::mem::replace(&mut batch, rest);
            let h = &replicas[replica];
            h.in_flight.fetch_add(1, Ordering::AcqRel);
            if let Err(e) = h.queue.send(ReplicaMsg::Shard(shard)) {
                // Replica thread is gone (a catastrophic panic outside
                // the guarded region): answer rather than drop, and
                // tombstone the replica so the planner stops routing to
                // it. The guard keeps repeated failures from wrapping
                // the counter; only this planner thread writes the bias.
                let ReplicaMsg::Shard(shard) = e.0 else { unreachable!() };
                for r in shard {
                    let latency = r.submitted.elapsed();
                    let _ = r.reply.send(InferResponse {
                        id: r.id,
                        output: Err(InferError::Shutdown),
                        latency,
                    });
                }
                h.in_flight.fetch_sub(1, Ordering::AcqRel);
                if h.in_flight.load(Ordering::Acquire) < BROKEN_REPLICA_BIAS {
                    h.in_flight.fetch_add(BROKEN_REPLICA_BIAS, Ordering::AcqRel);
                }
            }
        }
    }
}

/// Replica thread body: pin to the replica's core slice (before the
/// factory runs, so construction-time allocations first-touch locally),
/// build the backend (guarding against factory errors *and* panics),
/// install the spec's dispatch profile, serving dtype and core slice,
/// then serve shards until the planner hangs up.
#[allow(clippy::too_many_arguments)]
fn replica_main(
    factory: &BackendFactory,
    replica: usize,
    profile: Option<Arc<crate::autotune::DispatchProfile>>,
    dtype: crate::tensor::Dtype,
    pin: Option<crate::exec::CoreSet>,
    rx: &Receiver<ReplicaMsg>,
    metrics: &LatencyHistogram,
    in_flight: &AtomicUsize,
) {
    if let Some(slice) = &pin {
        // Best-effort: threads spawned from here (scoped kernel workers
        // under --no-pool) inherit this mask even before the backend
        // installs its own pinned pool.
        crate::exec::affinity::pin_current(slice);
    }
    match catch_unwind(AssertUnwindSafe(|| factory.as_ref()(replica))) {
        Ok(Ok(mut backend)) => {
            if let Some(p) = profile {
                backend.set_profile(p);
            }
            backend.set_dtype(dtype);
            if let Some(slice) = &pin {
                backend.set_pinning(slice);
            }
            replica_loop(&mut *backend, rx, metrics, in_flight)
        }
        Ok(Err(e)) => answer_all_with_error(rx, in_flight, &e.to_string()),
        Err(p) => answer_all_with_error(
            rx,
            in_flight,
            &format!("backend factory panicked: {}", panic_message(&p)),
        ),
    }
}

/// Construction failed: answer every shard with the error — and every
/// stream command with [`StreamReply::Broken`], so the coordinator
/// fails its streams over — until close. The bias marks this replica
/// dead so the planner routes around it while any healthy replica
/// remains.
fn answer_all_with_error(rx: &Receiver<ReplicaMsg>, in_flight: &AtomicUsize, msg: &str) {
    in_flight.fetch_add(BROKEN_REPLICA_BIAS, Ordering::AcqRel);
    while let Ok(msg_in) = rx.recv() {
        match msg_in {
            ReplicaMsg::Shard(shard) => {
                for r in shard {
                    let _ = r.reply.send(InferResponse {
                        id: r.id,
                        output: Err(InferError::Backend(msg.to_string())),
                        latency: r.submitted.elapsed(),
                    });
                }
                in_flight.fetch_sub(1, Ordering::AcqRel);
            }
            ReplicaMsg::Stream(cmd) => {
                let _ = cmd.reply.send(StreamReply::Broken(msg.to_string()));
            }
        }
    }
}

fn replica_loop(
    backend: &mut dyn Backend,
    rx: &Receiver<ReplicaMsg>,
    metrics: &LatencyHistogram,
    in_flight: &AtomicUsize,
) {
    let item_shape = backend.item_shape().to_vec();
    let item: usize = item_shape.iter().product();
    let mut serve = |backend: &mut dyn Backend, msg: ReplicaMsg| match msg {
        ReplicaMsg::Shard(shard) => {
            run_shard(backend, &item_shape, item, shard, metrics);
            in_flight.fetch_sub(1, Ordering::AcqRel);
        }
        ReplicaMsg::Stream(cmd) => run_stream_cmd(backend, cmd),
    };
    // Backends with housekeeping (e.g. NativeBackend's trim-after-idle
    // and stream idle eviction) ask for periodic wakeups while the queue
    // is quiet; everyone else blocks on the queue with no timer churn.
    match backend.idle_tick_period() {
        None => {
            while let Ok(msg) = rx.recv() {
                serve(backend, msg);
            }
        }
        Some(tick) => loop {
            match rx.recv_timeout(tick) {
                Ok(msg) => serve(backend, msg),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => backend.idle_tick(),
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
            }
        },
    }
}

/// Execute one stream command on the replica thread, panic-proof like
/// the batch path: a panicking `advance` closes the session (so the
/// stream can never resume from a half-updated ring) and answers with
/// the panic message instead of wedging the replica.
fn run_stream_cmd(backend: &mut dyn Backend, cmd: StreamCmd) {
    let StreamCmd { sid, op, reply } = cmd;
    let out = match op {
        StreamOp::Open => {
            match catch_unwind(AssertUnwindSafe(|| backend.open_stream(sid))) {
                Ok(Ok(())) => StreamReply::Done(None),
                Ok(Err(e)) => StreamReply::Err(e.to_string()),
                Err(p) => StreamReply::Err(format!(
                    "backend '{}' panicked opening stream {sid}: {}",
                    backend.name(),
                    panic_message(&p)
                )),
            }
        }
        StreamOp::Advance(frame) => {
            match catch_unwind(AssertUnwindSafe(|| backend.advance_stream(sid, &frame))) {
                Ok(Ok(output)) => StreamReply::Done(output),
                Ok(Err(e)) => StreamReply::Err(e.to_string()),
                Err(p) => {
                    backend.close_stream(sid);
                    StreamReply::Err(format!(
                        "backend '{}' panicked on stream {sid}: {}",
                        backend.name(),
                        panic_message(&p)
                    ))
                }
            }
        }
        StreamOp::Close => {
            backend.close_stream(sid);
            StreamReply::Done(None)
        }
    };
    let _ = reply.send(out);
}

/// Execute one sub-batch end to end: stack, infer (panic-proof),
/// validate the output batch dimension, split and reply per request.
fn run_shard(
    backend: &mut dyn Backend,
    item_shape: &[usize],
    item: usize,
    batch: Vec<Request>,
    metrics: &LatencyHistogram,
) {
    let b = batch.len();

    // A panicking backend must not kill the replica: convert the panic
    // into a per-request error and keep the worker loop alive. The
    // guard covers the batch *stacking* too — a backend whose
    // `item_shape()` disagrees with its spec would otherwise panic the
    // thread in `Tensor::from_vec` before `infer` even runs. (The
    // backend's own state is assumed recoverable — true for the native
    // kernels, whose scratch is checked back in between batches.)
    let outcome = match catch_unwind(AssertUnwindSafe(|| {
        // Stack items into [b, …item_shape].
        let mut data = Vec::with_capacity(b * item);
        for r in &batch {
            data.extend_from_slice(r.input.as_slice());
        }
        let mut shape = vec![b];
        shape.extend_from_slice(item_shape);
        backend.infer(&Tensor::from_vec(data, &shape))
    })) {
        Ok(Ok(out)) => {
            // Never trust the backend's output geometry: a wrong batch
            // dimension would slice-panic or silently mis-route rows.
            if out.dims().is_empty() || out.dim(0) != b {
                Err(InferError::Backend(format!(
                    "backend '{}' returned output shape {:?} for a batch of {b}",
                    backend.name(),
                    out.dims()
                )))
            } else {
                Ok(out)
            }
        }
        Ok(Err(e)) => Err(InferError::Backend(e.to_string())),
        Err(p) => Err(InferError::Backend(format!(
            "backend '{}' panicked: {}",
            backend.name(),
            panic_message(&p)
        ))),
    };

    match outcome {
        Ok(out) => {
            // Batch accounting happens only for *served* shards so that
            // items/batches stay consistent with count/latency (which
            // also exclude failures).
            metrics.record_batch(b);
            let out_item: usize = out.dims()[1..].iter().product();
            let out_shape = out.dims()[1..].to_vec();
            for (i, r) in batch.into_iter().enumerate() {
                let row = out.as_slice()[i * out_item..(i + 1) * out_item].to_vec();
                let latency = r.submitted.elapsed();
                metrics.record(latency);
                let _ = r.reply.send(InferResponse {
                    id: r.id,
                    output: Ok(Tensor::from_vec(row, &out_shape)),
                    latency,
                });
            }
        }
        Err(e) => {
            // Errored requests are answered but not recorded as
            // latencies: the histogram tracks served inferences.
            for r in batch {
                let latency = r.submitted.elapsed();
                let _ = r.reply.send(InferResponse {
                    id: r.id,
                    output: Err(e.clone()),
                    latency,
                });
            }
        }
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(p: &(dyn std::any::Any + Send)) -> &str {
    p.downcast_ref::<&'static str>()
        .copied()
        .or_else(|| p.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("opaque panic payload")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::BackendSpec;
    use crate::kernels::ConvAlgo;
    use crate::nn::zoo::simple_cnn;
    use crate::nn::ExecCtx;
    use std::time::Duration;

    fn coord() -> Coordinator {
        let backends = vec![
            BackendSpec::native("sliding", simple_cnn(10, 1), ExecCtx::new(ConvAlgo::Sliding)),
            BackendSpec::native("gemm", simple_cnn(10, 1), ExecCtx::new(ConvAlgo::Im2colGemm)),
        ];
        Coordinator::new(
            backends,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        )
    }

    #[test]
    fn single_request_roundtrip() {
        let c = coord();
        let x = Tensor::randn(&[1, 28, 28], 1);
        let r = c.infer("sliding", x).unwrap();
        let y = r.output.unwrap();
        assert_eq!(y.dims(), &[10]);
        let s: f32 = y.as_slice().iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        c.shutdown();
    }

    #[test]
    fn unknown_backend_rejected() {
        let c = coord();
        let x = Tensor::zeros(&[1, 28, 28]);
        assert!(matches!(
            c.infer("nope", x),
            Err(InferError::UnknownBackend(_))
        ));
        c.shutdown();
    }

    #[test]
    fn bad_shape_rejected_immediately() {
        let c = coord();
        let x = Tensor::zeros(&[3, 28, 28]);
        assert!(matches!(c.infer("sliding", x), Err(InferError::BadShape { .. })));
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_all_answered_and_batched() {
        let c = coord();
        let rxs: Vec<_> = (0..16)
            .map(|i| c.submit("sliding", Tensor::randn(&[1, 28, 28], i as u64)).unwrap())
            .collect();
        let mut ids = Vec::new();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.output.is_ok());
            ids.push(r.id);
        }
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 16, "no lost or duplicated responses");
        let m = c.metrics("sliding").unwrap();
        assert_eq!(m.items, 16);
        assert!(m.batches < 16, "some batching should occur: {m:?}");
        c.shutdown();
    }

    #[test]
    fn backends_agree_through_the_server() {
        let c = coord();
        let x = Tensor::randn(&[1, 28, 28], 33);
        let a = c.infer("sliding", x.clone()).unwrap().output.unwrap();
        let b = c.infer("gemm", x).unwrap().output.unwrap();
        assert!(a.allclose(&b, 1e-4));
        c.shutdown();
    }

    #[test]
    fn replicated_backend_serves_and_aggregates_metrics() {
        let c = Coordinator::new(
            vec![BackendSpec::native(
                "sliding",
                simple_cnn(10, 1),
                ExecCtx::new(ConvAlgo::Sliding),
            )
            .with_replicas(3)],
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        );
        assert_eq!(c.replicas("sliding"), Some(3));
        let rxs: Vec<_> = (0..24)
            .map(|i| c.submit("sliding", Tensor::randn(&[1, 28, 28], i as u64)).unwrap())
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().output.is_ok());
        }
        let agg = c.metrics("sliding").unwrap();
        assert_eq!(agg.count, 24);
        assert_eq!(agg.items, 24);
        let per = c.replica_metrics("sliding").unwrap();
        assert_eq!(per.len(), 3);
        assert_eq!(per.iter().map(|m| m.items).sum::<u64>(), 24);
        c.shutdown();
    }

    /// REGRESSION — a replica whose factory failed must not attract
    /// traffic: its error responder biases its queue depth, so after at
    /// most one error the planner steers every subsequent request to
    /// the healthy replica. Without the bias, the broken replica reads
    /// as permanently idle and the idle preference keeps feeding it.
    #[test]
    fn broken_replica_does_not_attract_traffic() {
        struct Echo;
        impl Backend for Echo {
            fn name(&self) -> &str {
                "echo"
            }
            fn item_shape(&self) -> &[usize] {
                &[2]
            }
            fn infer(&mut self, batch: &Tensor) -> crate::error::Result<Tensor> {
                Ok(batch.clone())
            }
        }
        let spec = BackendSpec::from_factory("half-broken", vec![2], |replica| {
            if replica == 0 {
                crate::bail!("replica 0 refuses to start");
            }
            Ok(Box::new(Echo))
        })
        .with_replicas(2);
        let c = Coordinator::new(
            vec![spec],
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
        );
        // Warm-up: the first requests may race the broken replica's
        // startup (its bias might not be set when the planner first
        // looks). Two sequential round trips guarantee the planner has
        // either routed to replica 0 (whose error reply proves the bias
        // is set) or already observed the bias and avoided it.
        let mut warmup_errors = 0;
        for _ in 0..2 {
            let r = c.infer("half-broken", Tensor::zeros(&[2])).unwrap();
            if r.output.is_err() {
                warmup_errors += 1;
            }
        }
        assert!(warmup_errors <= 1, "healthy replica must answer at least one warm-up");
        // Steady state, small batches: every request lands on the
        // healthy replica.
        for i in 0..10 {
            let r = c.infer("half-broken", Tensor::full(&[2], i as f32)).unwrap();
            assert!(r.output.is_ok(), "small batch routed to dead replica: {:?}", r.output);
        }
        // Steady state, burst: formed batches are > 1 item, so this
        // exercises the scatter path, which must exclude the dead
        // replica rather than hand it a sub-batch.
        let rxs: Vec<_> = (0..16)
            .map(|i| c.submit("half-broken", Tensor::full(&[2], i as f32)).unwrap())
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.output.is_ok(), "burst shard routed to dead replica: {:?}", r.output);
        }
        c.shutdown();
    }

    /// The spec's profile knob reaches every replica: a tuned tier
    /// whose profile routes all convolutions to GEMM must answer
    /// bit-identically to a plain GEMM tier.
    #[test]
    fn profiled_tier_dispatches_tuned_on_every_replica() {
        use crate::autotune::{DispatchProfile, ProfileEntry, TunedAlgo};
        use crate::kernels::rowconv::RowKernel;
        let profile = Arc::new(DispatchProfile::from_entries(vec![ProfileEntry {
            k: 3,
            threads: 1,
            dtype: crate::tensor::Dtype::F32,
            isa: crate::simd::IsaLevel::Scalar,
            algo: TunedAlgo::Gemm,
            slide: RowKernel::Generic,
            gflops: 1.0,
        }]));
        let c = Coordinator::new(
            vec![
                BackendSpec::native("tuned", simple_cnn(10, 1), ExecCtx::new(ConvAlgo::Tuned))
                    .with_profile(Arc::clone(&profile))
                    .with_replicas(2),
                BackendSpec::native("gemm", simple_cnn(10, 1), ExecCtx::new(ConvAlgo::Im2colGemm)),
            ],
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        for seed in 0..4 {
            let x = Tensor::randn(&[1, 28, 28], 50 + seed);
            let a = c.infer("tuned", x.clone()).unwrap().output.unwrap();
            let b = c.infer("gemm", x).unwrap().output.unwrap();
            assert_eq!(
                a.as_slice(),
                b.as_slice(),
                "tuned tier must route every conv to the profiled winner"
            );
        }
        c.shutdown();
    }

    /// A `with_dtype(I8)` tier serves through the coordinator: same
    /// output geometry as the f32 tier, values within quantization
    /// error, and the knob reaches every replica.
    #[test]
    fn quantized_tier_serves_through_the_coordinator() {
        use crate::kernels::Conv2dParams;
        use crate::nn::layers::Conv2d;
        use crate::nn::Model;
        use crate::tensor::Dtype;
        let model = || {
            Model::new("one-conv", &[2, 10, 10])
                .push(Conv2d::new(2, 3, 3, Conv2dParams::same(3), 41))
        };
        let c = Coordinator::new(
            vec![
                BackendSpec::native("f32", model(), ExecCtx::default()),
                BackendSpec::native("i8", model(), ExecCtx::default())
                    .with_dtype(Dtype::I8)
                    .with_replicas(2),
            ],
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        for seed in 0..4 {
            let x = Tensor::randn(&[2, 10, 10], 80 + seed);
            let a = c.infer("f32", x.clone()).unwrap().output.unwrap();
            let b = c.infer("i8", x).unwrap().output.unwrap();
            assert_eq!(a.dims(), b.dims());
            let d = a.max_abs_diff(&b);
            assert!(d < 0.25, "seed {seed}: quantized tier diverged ({d})");
        }
        c.shutdown();
    }

    /// A trim-idle tier keeps serving correctly (the idle ticks between
    /// requests must not disturb results).
    #[test]
    fn trim_idle_tier_serves_across_idle_gaps() {
        let spec = BackendSpec::native_retention(
            "sliding",
            simple_cnn(10, 1),
            ExecCtx::new(ConvAlgo::Sliding),
            None,
            Some(Duration::from_millis(10)),
        );
        let c = Coordinator::new(
            vec![spec],
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        let x = Tensor::randn(&[1, 28, 28], 90);
        let first = c.infer("sliding", x.clone()).unwrap().output.unwrap();
        // Let several idle ticks fire (each may drop the arena).
        std::thread::sleep(Duration::from_millis(60));
        let second = c.infer("sliding", x).unwrap().output.unwrap();
        assert_eq!(first.as_slice(), second.as_slice(), "idle trim must not change results");
        c.shutdown();
    }

    /// An auto-pinned, replicated tier answers bit-identically to an
    /// unpinned one: pinning places threads, it never touches numerics
    /// (and on platforms without affinity support it degrades to a
    /// no-op).
    #[test]
    fn pinned_tier_serves_identically_to_unpinned() {
        let c = Coordinator::new(
            vec![
                BackendSpec::native(
                    "plain",
                    simple_cnn(10, 1),
                    ExecCtx::with_threads(ConvAlgo::Sliding, 2),
                ),
                BackendSpec::native(
                    "pinned",
                    simple_cnn(10, 1),
                    ExecCtx::with_threads(ConvAlgo::Sliding, 2),
                )
                .with_replicas(2)
                .with_pinning(PinPolicy::Auto),
            ],
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        for seed in 0..4 {
            let x = Tensor::randn(&[1, 28, 28], 70 + seed);
            let a = c.infer("plain", x.clone()).unwrap().output.unwrap();
            let b = c.infer("pinned", x).unwrap().output.unwrap();
            assert_eq!(a.as_slice(), b.as_slice(), "pinning must never change results");
        }
        c.shutdown();
    }

    /// REGRESSION — a panicking factory answers requests with the panic
    /// message instead of hanging the tier.
    #[test]
    fn panicking_factory_reports_errors() {
        let spec = BackendSpec::from_factory("boom", vec![2], |_r| {
            panic!("factory exploded")
        });
        let c = Coordinator::new(
            vec![spec],
            BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
        );
        let r = c.infer("boom", Tensor::zeros(&[2])).unwrap();
        match r.output {
            Err(InferError::Backend(msg)) => assert!(msg.contains("factory exploded"), "{msg}"),
            other => panic!("expected backend error, got {other:?}"),
        }
        c.shutdown();
    }
}
