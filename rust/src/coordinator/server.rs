//! The coordinator: a router in front of per-backend worker threads,
//! each running a dynamic-batching loop.
//!
//! ```text
//! client ──submit(backend, item)──▶ router ──queue──▶ worker(backend A)
//!                                        └────queue──▶ worker(backend B)
//! worker: next_batch → stack items → Backend::infer → split → reply
//! ```

use super::backend::{Backend, BackendSpec};
use super::batcher::{next_batch, BatchOutcome, BatchPolicy};
use super::metrics::{LatencyHistogram, MetricsSnapshot};
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// A completed inference.
#[derive(Debug)]
pub struct InferResponse {
    /// Request id (assigned by the coordinator, monotonically increasing).
    pub id: u64,
    /// Model output for this item (batch dimension removed).
    pub output: Result<Tensor, InferError>,
    /// End-to-end latency (submit → reply).
    pub latency: std::time::Duration,
}

/// Inference failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InferError {
    /// Unknown backend name.
    UnknownBackend(String),
    /// Input shape didn't match the backend's item shape.
    BadShape {
        /// What the backend expects.
        expected: Vec<usize>,
        /// What the request carried.
        got: Vec<usize>,
    },
    /// The backend failed.
    Backend(String),
    /// The coordinator is shutting down.
    Shutdown,
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::UnknownBackend(b) => write!(f, "unknown backend '{b}'"),
            InferError::BadShape { expected, got } => {
                write!(f, "bad input shape {got:?}, expected {expected:?}")
            }
            InferError::Backend(e) => write!(f, "backend error: {e}"),
            InferError::Shutdown => write!(f, "coordinator shut down"),
        }
    }
}

impl std::error::Error for InferError {}

struct Request {
    id: u64,
    input: Tensor,
    submitted: Instant,
    reply: Sender<InferResponse>,
}

struct Worker {
    queue: Sender<Request>,
    item_shape: Vec<usize>,
    metrics: Arc<LatencyHistogram>,
    join: JoinHandle<()>,
}

/// The request router + worker pool.
pub struct Coordinator {
    workers: HashMap<String, Worker>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Build a coordinator: one worker thread per backend spec, each with
    /// its own queue and batch policy. The backend itself is constructed
    /// *on* the worker thread (PJRT handles are not `Send`); if the
    /// factory fails, the worker answers every request with the error.
    pub fn new(backends: Vec<BackendSpec>, policy: BatchPolicy) -> Self {
        let mut workers = HashMap::new();
        for spec in backends {
            let (tx, rx) = channel::<Request>();
            let metrics = Arc::new(LatencyHistogram::new());
            let m2 = Arc::clone(&metrics);
            let name = spec.name.clone();
            let item_shape = spec.item_shape.clone();
            let factory = spec.factory;
            let join = std::thread::Builder::new()
                .name(format!("swconv-worker-{name}"))
                .spawn(move || match factory() {
                    Ok(mut b) => worker_loop(&mut *b, &rx, policy, &m2),
                    Err(e) => {
                        let msg = e.to_string();
                        // Answer everything with the construction error.
                        while let Ok(r) = rx.recv() {
                            let _ = r.reply.send(InferResponse {
                                id: r.id,
                                output: Err(InferError::Backend(msg.clone())),
                                latency: r.submitted.elapsed(),
                            });
                        }
                    }
                })
                .expect("spawn worker");
            workers.insert(name, Worker { queue: tx, item_shape, metrics, join });
        }
        Coordinator { workers, next_id: AtomicU64::new(0) }
    }

    /// Registered backend names (sorted).
    pub fn backends(&self) -> Vec<String> {
        let mut v: Vec<String> = self.workers.keys().cloned().collect();
        v.sort();
        v
    }

    /// Submit one item to a backend; the response arrives on the returned
    /// channel. Shape is validated here so errors are immediate.
    pub fn submit(
        &self,
        backend: &str,
        input: Tensor,
    ) -> Result<Receiver<InferResponse>, InferError> {
        let w = self
            .workers
            .get(backend)
            .ok_or_else(|| InferError::UnknownBackend(backend.to_string()))?;
        if input.dims() != &w.item_shape[..] {
            return Err(InferError::BadShape {
                expected: w.item_shape.clone(),
                got: input.dims().to_vec(),
            });
        }
        let (reply, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        w.queue
            .send(Request { id, input, submitted: Instant::now(), reply })
            .map_err(|_| InferError::Shutdown)?;
        Ok(rx)
    }

    /// Submit and block for the result.
    pub fn infer(&self, backend: &str, input: Tensor) -> Result<InferResponse, InferError> {
        let rx = self.submit(backend, input)?;
        rx.recv().map_err(|_| InferError::Shutdown)
    }

    /// Metrics snapshot for one backend.
    pub fn metrics(&self, backend: &str) -> Option<MetricsSnapshot> {
        self.workers.get(backend).map(|w| w.metrics.snapshot())
    }

    /// Shut down: close queues and join workers. In-flight requests are
    /// completed first.
    pub fn shutdown(self) {
        let mut joins = Vec::new();
        for (_, w) in self.workers {
            drop(w.queue);
            joins.push(w.join);
        }
        for j in joins {
            let _ = j.join();
        }
    }
}

fn worker_loop(
    backend: &mut dyn Backend,
    rx: &Receiver<Request>,
    policy: BatchPolicy,
    metrics: &LatencyHistogram,
) {
    let item_shape = backend.item_shape().to_vec();
    let item: usize = item_shape.iter().product();
    loop {
        let batch = match next_batch(rx, &policy) {
            BatchOutcome::Batch(b) => b,
            BatchOutcome::Closed => return,
        };
        let b = batch.len();
        metrics.record_batch(b);

        // Stack items into [b, …item_shape].
        let mut data = Vec::with_capacity(b * item);
        for r in &batch {
            data.extend_from_slice(r.input.as_slice());
        }
        let mut shape = vec![b];
        shape.extend_from_slice(&item_shape);
        let stacked = Tensor::from_vec(data, &shape);

        match backend.infer(&stacked) {
            Ok(out) => {
                let out_item: usize = out.dims()[1..].iter().product();
                let out_shape = out.dims()[1..].to_vec();
                for (i, r) in batch.into_iter().enumerate() {
                    let row = out.as_slice()[i * out_item..(i + 1) * out_item].to_vec();
                    let latency = r.submitted.elapsed();
                    metrics.record(latency);
                    let _ = r.reply.send(InferResponse {
                        id: r.id,
                        output: Ok(Tensor::from_vec(row, &out_shape)),
                        latency,
                    });
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for r in batch {
                    let latency = r.submitted.elapsed();
                    let _ = r.reply.send(InferResponse {
                        id: r.id,
                        output: Err(InferError::Backend(msg.clone())),
                        latency,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ConvAlgo;
    use crate::nn::zoo::simple_cnn;
    use crate::nn::ExecCtx;
    use crate::coordinator::backend::BackendSpec;
    use std::time::Duration;

    fn coord() -> Coordinator {
        let backends = vec![
            BackendSpec::native("sliding", simple_cnn(10, 1), ExecCtx::new(ConvAlgo::Sliding)),
            BackendSpec::native("gemm", simple_cnn(10, 1), ExecCtx::new(ConvAlgo::Im2colGemm)),
        ];
        Coordinator::new(
            backends,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        )
    }

    #[test]
    fn single_request_roundtrip() {
        let c = coord();
        let x = Tensor::randn(&[1, 28, 28], 1);
        let r = c.infer("sliding", x).unwrap();
        let y = r.output.unwrap();
        assert_eq!(y.dims(), &[10]);
        let s: f32 = y.as_slice().iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        c.shutdown();
    }

    #[test]
    fn unknown_backend_rejected() {
        let c = coord();
        let x = Tensor::zeros(&[1, 28, 28]);
        assert!(matches!(
            c.infer("nope", x),
            Err(InferError::UnknownBackend(_))
        ));
        c.shutdown();
    }

    #[test]
    fn bad_shape_rejected_immediately() {
        let c = coord();
        let x = Tensor::zeros(&[3, 28, 28]);
        assert!(matches!(c.infer("sliding", x), Err(InferError::BadShape { .. })));
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_all_answered_and_batched() {
        let c = coord();
        let rxs: Vec<_> = (0..16)
            .map(|i| c.submit("sliding", Tensor::randn(&[1, 28, 28], i as u64)).unwrap())
            .collect();
        let mut ids = Vec::new();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.output.is_ok());
            ids.push(r.id);
        }
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 16, "no lost or duplicated responses");
        let m = c.metrics("sliding").unwrap();
        assert_eq!(m.items, 16);
        assert!(m.batches < 16, "some batching should occur: {m:?}");
        c.shutdown();
    }

    #[test]
    fn backends_agree_through_the_server() {
        let c = coord();
        let x = Tensor::randn(&[1, 28, 28], 33);
        let a = c.infer("sliding", x.clone()).unwrap().output.unwrap();
        let b = c.infer("gemm", x).unwrap().output.unwrap();
        assert!(a.allclose(&b, 1e-4));
        c.shutdown();
    }
}
