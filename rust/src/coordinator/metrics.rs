//! Latency/throughput metrics for the serving path.
//!
//! Each backend **replica** owns one [`LatencyHistogram`] (recorded from
//! its worker thread only, so the lock is uncontended); the coordinator
//! builds the backend-level view by merging the per-replica histograms
//! with [`LatencyHistogram::aggregate`].

use std::sync::Mutex;
use std::time::Duration;

/// Log-scale latency histogram (power-of-two microsecond buckets) plus
/// counters. Cheap to record (one atomic-free locked increment; the
/// coordinator records from a single worker thread per replica).
#[derive(Debug)]
pub struct LatencyHistogram {
    inner: Mutex<Inner>,
}

#[derive(Debug, Clone)]
struct Inner {
    /// `bucket[i]` counts latencies in `[2^i, 2^(i+1))` microseconds.
    buckets: [u64; 32],
    count: u64,
    total_us: u64,
    max_us: u64,
    /// Items processed (for batch backends this exceeds request count).
    items: u64,
    batches: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        LatencyHistogram { inner: Mutex::new(Inner::empty()) }
    }

    /// Record one request latency.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(31);
        let mut g = self.inner.lock().unwrap();
        g.buckets[bucket] += 1;
        g.count += 1;
        g.total_us += us;
        g.max_us = g.max_us.max(us);
    }

    /// Record a processed batch of `n` items.
    pub fn record_batch(&self, n: usize) {
        let mut g = self.inner.lock().unwrap();
        g.items += n as u64;
        g.batches += 1;
    }

    /// Snapshot for reporting.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.inner.lock().unwrap().snapshot()
    }

    /// Merge any number of histograms (one per replica) into a single
    /// backend-level snapshot. Quantiles are computed on the summed
    /// buckets, so the aggregate has the same log-bucket resolution as
    /// any individual histogram — not an average of averages.
    pub fn aggregate<'a>(
        histograms: impl IntoIterator<Item = &'a LatencyHistogram>,
    ) -> MetricsSnapshot {
        let mut acc = Inner::empty();
        for h in histograms {
            acc.absorb(&h.inner.lock().unwrap());
        }
        acc.snapshot()
    }
}

impl Inner {
    fn empty() -> Inner {
        Inner { buckets: [0; 32], count: 0, total_us: 0, max_us: 0, items: 0, batches: 0 }
    }

    /// Add another histogram's counts into this one.
    fn absorb(&mut self, o: &Inner) {
        for (a, b) in self.buckets.iter_mut().zip(o.buckets.iter()) {
            *a += b;
        }
        self.count += o.count;
        self.total_us += o.total_us;
        self.max_us = self.max_us.max(o.max_us);
        self.items += o.items;
        self.batches += o.batches;
    }

    fn snapshot(&self) -> MetricsSnapshot {
        let mean_us = if self.count == 0 { 0 } else { self.total_us / self.count };
        MetricsSnapshot {
            count: self.count,
            mean: Duration::from_micros(mean_us),
            p50: self.quantile(0.50),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            max: Duration::from_micros(self.max_us),
            items: self.items,
            batches: self.batches,
        }
    }

    /// Upper edge of the bucket containing quantile `q` (log-bucket
    /// resolution: within 2× of the true value).
    fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return Duration::from_micros(1 << (i + 1));
            }
        }
        Duration::from_micros(self.max_us)
    }
}

/// Point-in-time metrics view.
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    /// Requests recorded.
    pub count: u64,
    /// Mean latency.
    pub mean: Duration,
    /// Median (bucket upper edge).
    pub p50: Duration,
    /// 95th percentile (bucket upper edge).
    pub p95: Duration,
    /// 99th percentile (bucket upper edge).
    pub p99: Duration,
    /// Maximum latency.
    pub max: Duration,
    /// Items processed in batches.
    pub items: u64,
    /// Batches processed (for a replicated backend: shards executed).
    pub batches: u64,
}

impl MetricsSnapshot {
    /// Mean batch size.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.items as f64 / self.batches as f64
        }
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:?} p50={:?} p95={:?} p99={:?} max={:?} batches={} (avg {:.1}/batch)",
            self.count,
            self.mean,
            self.p50,
            self.p95,
            self.p99,
            self.max,
            self.batches,
            self.mean_batch()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, Duration::ZERO);
        assert_eq!(s.mean_batch(), 0.0);
    }

    #[test]
    fn quantiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99);
        // p50 of 1..1000us is ~500us; log-bucket answer within 2x.
        assert!(s.p50 >= Duration::from_micros(256) && s.p50 <= Duration::from_micros(1024));
        assert!(s.max == Duration::from_micros(1000));
    }

    #[test]
    fn batch_accounting() {
        let h = LatencyHistogram::new();
        h.record_batch(4);
        h.record_batch(8);
        let s = h.snapshot();
        assert_eq!(s.items, 12);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn summary_is_printable() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_millis(3));
        assert!(h.snapshot().summary().contains("n=1"));
    }

    #[test]
    fn aggregate_sums_replica_histograms() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        for i in 1..=10u64 {
            a.record(Duration::from_micros(i * 10));
        }
        b.record(Duration::from_millis(50));
        a.record_batch(3);
        b.record_batch(5);
        let s = LatencyHistogram::aggregate([&a, &b]);
        assert_eq!(s.count, 11);
        assert_eq!(s.items, 8);
        assert_eq!(s.batches, 2);
        assert_eq!(s.max, Duration::from_millis(50));
        // The slow outlier lives in the aggregate's tail, not its median.
        assert!(s.p50 < Duration::from_millis(1));
        assert!(s.p99 >= Duration::from_millis(32));
        // Aggregating one histogram is the identity.
        let solo = LatencyHistogram::aggregate([&b]);
        assert_eq!(solo.count, 1);
        assert_eq!(solo.items, 5);
    }

    #[test]
    fn aggregate_of_nothing_is_empty() {
        let s = LatencyHistogram::aggregate(std::iter::empty());
        assert_eq!(s.count, 0);
        assert_eq!(s.batches, 0);
        assert_eq!(s.p95, Duration::ZERO);
    }
}
