//! Dynamic batching: collect queued requests into a batch bounded by
//! size and deadline — the standard serving trade-off (larger batches
//! amortise per-call overhead; the deadline caps queueing latency).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batch formation policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum requests per batch.
    pub max_batch: usize,
    /// Maximum time to wait for the batch to fill after the first
    /// request arrives.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Outcome of waiting for a batch.
pub enum BatchOutcome<T> {
    /// A non-empty batch.
    Batch(Vec<T>),
    /// The channel closed and no requests remain.
    Closed,
}

/// Block for the next batch on `rx` under `policy`.
///
/// Semantics: wait indefinitely for the first request; then drain
/// whatever arrives until the batch is full or `max_wait` has elapsed
/// since the first request.
pub fn next_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> BatchOutcome<T> {
    let first = match rx.recv() {
        Ok(r) => r,
        Err(_) => return BatchOutcome::Closed,
    };
    let mut batch = Vec::with_capacity(policy.max_batch);
    batch.push(first);
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) => batch.push(r),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    BatchOutcome::Batch(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::thread;

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let p = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) };
        match next_batch(&rx, &p) {
            BatchOutcome::Batch(b) => assert_eq!(b, vec![0, 1, 2, 3]),
            _ => panic!("expected batch"),
        }
        match next_batch(&rx, &p) {
            BatchOutcome::Batch(b) => assert_eq!(b, vec![4, 5, 6, 7]),
            _ => panic!("expected batch"),
        }
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = channel::<u32>();
        tx.send(1).unwrap();
        // Generous wait + halved lower bound: slow CI runners only make
        // the elapsed time *longer*, and coarse platform timers can cut
        // a recv_timeout slightly short, so the margin is wide on
        // purpose. The sender stays alive, so the flush can only come
        // from the deadline — which is what this test pins down.
        let p = BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(50) };
        let t = Instant::now();
        match next_batch(&rx, &p) {
            BatchOutcome::Batch(b) => {
                assert_eq!(b, vec![1]);
                assert!(t.elapsed() >= Duration::from_millis(25), "flushed before deadline");
            }
            _ => panic!("expected batch"),
        }
        drop(tx);
    }

    #[test]
    fn closed_channel_reports_closed() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(matches!(
            next_batch(&rx, &BatchPolicy::default()),
            BatchOutcome::Closed
        ));
    }

    #[test]
    fn drains_requests_arriving_during_wait() {
        let (tx, rx) = channel();
        // The deadline only needs to outlast the sender's scheduling
        // delay; it is deliberately enormous so a preempted CI runner
        // can't flush the batch early and fail the assertion. The test
        // still finishes promptly: next_batch returns the moment the
        // third item lands.
        let p = BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(30) };
        let sender = thread::spawn(move || {
            tx.send(1).unwrap();
            thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
            tx.send(3).unwrap();
        });
        match next_batch(&rx, &p) {
            BatchOutcome::Batch(b) => assert_eq!(b, vec![1, 2, 3]),
            _ => panic!("expected batch"),
        }
        sender.join().unwrap();
    }
}
