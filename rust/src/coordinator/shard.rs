//! The shard planner: how a formed batch is spread across a backend's
//! replicas.
//!
//! ZNNi's core observation (arXiv:1606.05688) is that CPU inference
//! throughput is a question of *where* to spend cores — inside the
//! kernel (intra-parallelism, `ExecCtx` threads) or across concurrent
//! inputs (inter-parallelism, backend replicas). The coordinator's
//! replica tier implements the second axis, and this module decides the
//! split for each batch the batcher forms:
//!
//! * **Small batches** are routed whole, round-robin, preferring an idle
//!   replica — splitting them would only add dispatch overhead.
//! * **Large batches** are scattered: contiguous per-replica sub-batches
//!   over the idle replicas (over the least-loaded replicas when fewer
//!   than two are idle), so a burst is absorbed by every core at once.
//!   Each request carries its own reply channel, so the "gather" is
//!   per-request and needs no extra synchronisation barrier.
//!
//! The planner is pure (it maps a batch length + per-replica in-flight
//! counts to index ranges), which keeps the policy unit-testable without
//! threads or tensors.

use std::ops::Range;

/// Batches shorter than this are never split: one sub-batch per item
/// only pays per-shard dispatch and wake-up cost without adding
/// parallelism the kernel couldn't get from its own threads.
pub const MIN_SCATTER_BATCH: usize = 2;

/// Queue-depth level at which a replica counts as *dead* rather than
/// busy. The coordinator adds this bias to a replica whose factory
/// failed (its queue is answered by an error responder) or whose worker
/// thread is gone, so the planner excludes it from every plan unless no
/// live replica remains — without the bias an error responder drains
/// instantly and the idle preference would steer *more* traffic at the
/// broken replica than at healthy-but-busy ones. Huge but far from
/// overflow: per-shard increments/decrements stay balanced on top.
pub const BROKEN_REPLICA_BIAS: usize = usize::MAX / 2;

/// Decides which replica(s) execute each formed batch.
///
/// Stateful only in its round-robin cursor; the in-flight counts come
/// from the caller on every [`ShardPlanner::plan`] call so the planner
/// never holds locks.
///
/// # Examples
///
/// ```
/// use swconv::coordinator::ShardPlanner;
///
/// let mut planner = ShardPlanner::new(3);
/// // A burst of 6 requests while every replica is idle: scattered as
/// // contiguous sub-batches covering 0..6 exactly.
/// let plan = planner.plan(6, &[0, 0, 0]);
/// assert_eq!(plan.iter().map(|(_, r)| r.len()).sum::<usize>(), 6);
/// // A single request is routed whole to one replica.
/// let single = planner.plan(1, &[0, 1, 0]);
/// assert_eq!(single.len(), 1);
/// assert_eq!(single[0].1, 0..1);
/// ```
#[derive(Debug)]
pub struct ShardPlanner {
    replicas: usize,
    rr: usize,
}

impl ShardPlanner {
    /// Planner over `replicas` replicas (clamped to ≥ 1).
    pub fn new(replicas: usize) -> Self {
        ShardPlanner { replicas: replicas.max(1), rr: 0 }
    }

    /// Number of replicas being planned over.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Split a batch of `batch_len` requests into per-replica shards.
    ///
    /// `in_flight[i]` is replica `i`'s current queue depth (shards
    /// dispatched but not yet finished); a replica is *idle* when it is
    /// zero. Returns `(replica index, request index range)` assignments
    /// whose ranges are ascending, disjoint and cover `0..batch_len`
    /// exactly — the dispatcher peels sub-batches off the front in
    /// order.
    ///
    /// # Panics
    /// If `in_flight.len()` differs from the planner's replica count.
    pub fn plan(&mut self, batch_len: usize, in_flight: &[usize]) -> Vec<(usize, Range<usize>)> {
        assert_eq!(in_flight.len(), self.replicas, "in-flight counts per replica");
        if batch_len == 0 {
            return Vec::new();
        }
        if self.replicas == 1 {
            return vec![(0, 0..batch_len)];
        }
        // Plan over the live replicas only; a dead replica (depth at or
        // past [`BROKEN_REPLICA_BIAS`]) receives traffic only when
        // nothing else is left, so its errors still surface instead of
        // requests hanging.
        let mut pool: Vec<usize> = (0..self.replicas)
            .filter(|&i| in_flight[i] < BROKEN_REPLICA_BIAS)
            .collect();
        if pool.is_empty() {
            pool = (0..self.replicas).collect();
        }
        let idle: Vec<usize> = pool.iter().copied().filter(|&i| in_flight[i] == 0).collect();

        if batch_len < MIN_SCATTER_BATCH {
            // Route whole: the first idle replica at or after the
            // round-robin cursor, else round-robin over the live pool.
            let start = self.rr % self.replicas;
            let target = idle
                .iter()
                .copied()
                .find(|&i| i >= start)
                .or_else(|| idle.first().copied())
                .or_else(|| pool.iter().copied().find(|&i| i >= start))
                .or_else(|| pool.first().copied())
                .unwrap_or(start);
            self.rr = target + 1;
            return vec![(target, 0..batch_len)];
        }

        // Scatter targets: the idle live replicas; when fewer than two
        // are idle, the least-loaded live replicas instead, so a burst
        // formed while everyone is busy still spreads over the tier
        // rather than queueing behind one replica.
        let targets: Vec<usize> = if idle.len() >= 2 {
            idle
        } else {
            let mut by_load = pool;
            by_load.sort_by_key(|&i| in_flight[i]);
            by_load
        };

        // Contiguous balanced sub-batches (first `rem` shards take one
        // extra request).
        let shards = targets.len().min(batch_len);
        let base = batch_len / shards;
        let rem = batch_len % shards;
        let mut plan = Vec::with_capacity(shards);
        let mut start = 0;
        for (s, &replica) in targets.iter().take(shards).enumerate() {
            let len = base + usize::from(s < rem);
            plan.push((replica, start..start + len));
            start += len;
        }
        self.rr = targets[shards - 1] + 1;
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Ranges must be ascending, disjoint and cover 0..len.
    fn check_coverage(plan: &[(usize, Range<usize>)], len: usize, replicas: usize) {
        let mut at = 0;
        for (r, range) in plan {
            assert!(*r < replicas, "replica {r} out of bounds");
            assert_eq!(range.start, at, "ranges not contiguous");
            assert!(range.end > range.start, "empty shard");
            at = range.end;
        }
        assert_eq!(at, len, "plan does not cover the batch");
    }

    #[test]
    fn single_replica_takes_everything() {
        let mut p = ShardPlanner::new(1);
        assert_eq!(p.plan(5, &[0]), vec![(0, 0..5)]);
        assert_eq!(p.plan(1, &[3]), vec![(0, 0..1)]);
        assert!(p.plan(0, &[0]).is_empty());
    }

    #[test]
    fn small_batches_round_robin_over_idle_replicas() {
        let mut p = ShardPlanner::new(3);
        let idle = [0, 0, 0];
        let targets: Vec<usize> = (0..6).map(|_| p.plan(1, &idle)[0].0).collect();
        assert_eq!(targets, vec![0, 1, 2, 0, 1, 2], "rotation over idle replicas");
    }

    #[test]
    fn small_batches_prefer_idle_replica() {
        let mut p = ShardPlanner::new(3);
        // Replica 0 busy: a single-item batch starting from cursor 0
        // must skip to the first idle replica.
        let plan = p.plan(1, &[4, 0, 0]);
        assert_eq!(plan, vec![(1, 0..1)]);
        // Replicas 1,2 busy next time: falls to the only idle one.
        assert_eq!(p.plan(1, &[0, 9, 9]), vec![(0, 0..1)]);
    }

    #[test]
    fn all_busy_small_batch_still_rotates() {
        let mut p = ShardPlanner::new(2);
        let a = p.plan(1, &[2, 2]);
        check_coverage(&a, 1, 2);
        let b = p.plan(1, &[2, 2]);
        check_coverage(&b, 1, 2);
        assert_ne!(a[0].0, b[0].0, "round-robin must rotate when all busy");
    }

    #[test]
    fn burst_with_no_idle_scatters_by_load() {
        let mut p = ShardPlanner::new(3);
        // Everyone busy: a large batch must still spread over the tier,
        // least-loaded replicas first.
        let plan = p.plan(6, &[5, 1, 9]);
        check_coverage(&plan, 6, 3);
        let replicas: Vec<usize> = plan.iter().map(|(r, _)| *r).collect();
        assert_eq!(replicas, vec![1, 0, 2], "targets ordered by queue depth");
        assert!(plan.iter().all(|(_, r)| r.len() == 2), "balanced split");
    }

    #[test]
    fn dead_replica_excluded_from_every_plan() {
        let mut p = ShardPlanner::new(4);
        // Replica 3 is dead (biased queue depth): bursts spread over the
        // live, busy replicas only.
        let plan = p.plan(3, &[1, 2, 3, BROKEN_REPLICA_BIAS]);
        check_coverage(&plan, 3, 4);
        let replicas: Vec<usize> = plan.iter().map(|(r, _)| *r).collect();
        assert_eq!(replicas, vec![0, 1, 2], "dead replica dropped from scatter");
        // Even when the batch is large enough to want every replica.
        let plan = p.plan(40, &[1, 2, 3, BROKEN_REPLICA_BIAS + 7]);
        check_coverage(&plan, 40, 4);
        assert!(
            plan.iter().all(|(r, _)| *r != 3),
            "dead replica must receive nothing while live ones exist: {plan:?}"
        );
        // Small batches skip it too.
        for _ in 0..8 {
            let plan = p.plan(1, &[0, 0, 0, BROKEN_REPLICA_BIAS]);
            assert_ne!(plan[0].0, 3);
        }
    }

    #[test]
    fn all_dead_tier_still_routes_so_errors_surface() {
        let mut p = ShardPlanner::new(2);
        let dead = [BROKEN_REPLICA_BIAS, BROKEN_REPLICA_BIAS + 1];
        let plan = p.plan(4, &dead);
        check_coverage(&plan, 4, 2);
        let a = p.plan(1, &dead);
        let b = p.plan(1, &dead);
        check_coverage(&a, 1, 2);
        assert_ne!(a[0].0, b[0].0, "round-robin over a fully-dead tier");
    }

    #[test]
    fn large_batches_scatter_balanced_over_idle() {
        let mut p = ShardPlanner::new(4);
        let plan = p.plan(10, &[0, 0, 0, 0]);
        check_coverage(&plan, 10, 4);
        assert_eq!(plan.len(), 4);
        let sizes: Vec<usize> = plan.iter().map(|(_, r)| r.len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2], "balanced contiguous split");
    }

    #[test]
    fn scatter_skips_busy_replicas() {
        let mut p = ShardPlanner::new(4);
        let plan = p.plan(6, &[0, 7, 0, 7]);
        check_coverage(&plan, 6, 4);
        let replicas: Vec<usize> = plan.iter().map(|(r, _)| *r).collect();
        assert_eq!(replicas, vec![0, 2], "only idle replicas receive shards");
    }

    #[test]
    fn never_more_shards_than_requests() {
        let mut p = ShardPlanner::new(8);
        let plan = p.plan(3, &[0; 8]);
        check_coverage(&plan, 3, 8);
        assert_eq!(plan.len(), 3, "one request per shard at most");
        assert!(plan.iter().all(|(_, r)| r.len() == 1));
    }

    #[test]
    fn plan_is_exhaustive_over_random_like_inputs() {
        let mut p = ShardPlanner::new(5);
        // Deterministic pseudo-random in-flight patterns.
        for step in 0..100usize {
            let len = step % 13 + 1;
            let inflight: Vec<usize> =
                (0..5).map(|i| (step * 7 + i * 3) % 4 % 2).collect();
            let plan = p.plan(len, &inflight);
            check_coverage(&plan, len, 5);
        }
    }

    #[test]
    #[should_panic(expected = "in-flight")]
    fn wrong_inflight_len_panics() {
        ShardPlanner::new(2).plan(1, &[0]);
    }
}
