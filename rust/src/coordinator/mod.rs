//! The serving coordinator — L3 of the stack.
//!
//! The paper's contribution is a kernel, so the coordinator is a thin but
//! real inference driver: a request router in front of per-backend worker
//! threads, each with a dynamic batcher (size + deadline), latency
//! metrics, and a choice of backend:
//!
//! * [`backend::NativeBackend`] — the Rust kernel library executing a
//!   [`crate::nn::Model`] with a per-backend [`crate::nn::ExecCtx`]
//!   (i.e. GEMM vs Sliding Window on identical weights).
//! * [`backend::PjrtBackend`] — an AOT JAX/Pallas artifact executed via
//!   [`crate::runtime::Engine`] (Python never on the request path).
//!
//! tokio is unavailable in this offline environment; the coordinator uses
//! std threads + channels, which for a single-node single-core serving
//! driver is equivalent (documented in DESIGN.md §Substitutions).

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod server;

pub use backend::{Backend, BackendSpec, NativeBackend, PjrtBackend};
pub use batcher::BatchPolicy;
pub use metrics::{LatencyHistogram, MetricsSnapshot};
pub use server::{Coordinator, InferError, InferResponse};
