//! The serving coordinator — L3 of the stack.
//!
//! The paper's contribution is a kernel, so the coordinator is a thin but
//! real inference driver: a request router in front of per-backend
//! serving tiers. Each tier is a dynamic batcher (size + deadline)
//! feeding a [`shard::ShardPlanner`] that splits formed batches across
//! `replicas` worker threads — the *inter*-request parallelism axis,
//! complementing the *intra*-kernel threads each replica's
//! [`crate::nn::ExecCtx`] owns (ZNNi's core/batch trade-off,
//! arXiv:1606.05688). Backends:
//!
//! * [`backend::NativeBackend`] — the Rust kernel library executing a
//!   [`crate::nn::Model`] with a per-replica [`crate::nn::ExecCtx`]
//!   (i.e. GEMM vs Sliding Window on identical, `Arc`-shared weights).
//! * [`backend::PjrtBackend`] — an AOT JAX/Pallas artifact executed via
//!   [`crate::runtime::Engine`] (Python never on the request path).
//!
//! The serving path is panic-proof: a panic inside `Backend::infer` (or
//! its factory) is caught, answered as [`server::InferError::Backend`],
//! and the replica keeps draining its queue. Per-replica
//! [`metrics::LatencyHistogram`]s merge into a backend-level snapshot
//! via [`metrics::LatencyHistogram::aggregate`].
//!
//! A spec built with [`backend::BackendSpec::with_profile`] carries a
//! measured [`crate::autotune::DispatchProfile`]; the coordinator
//! installs it on every replica right after construction, so one cached
//! `profile.json` makes the whole tier dispatch tuned.
//!
//! Tiers built with [`backend::BackendSpec::native_streaming`] also
//! serve stateful streams ([`crate::stream::StreamSession`] per open
//! stream): [`server::Coordinator::open_stream`] pins each session to
//! one replica (affinity), frames bypass the batcher, idle sessions are
//! evicted on the replica's housekeeping tick, and a broken replica's
//! streams fail over to a healthy one with an explicit state reset.
//!
//! tokio is unavailable in this offline environment; the coordinator uses
//! std threads + channels, which for a single-node serving driver is
//! equivalent (documented in DESIGN.md §Substitutions).

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod server;
pub mod shard;

pub use backend::{Backend, BackendFactory, BackendSpec, NativeBackend, PinPolicy, PjrtBackend};
pub use batcher::BatchPolicy;
pub use metrics::{LatencyHistogram, MetricsSnapshot};
pub use server::{Coordinator, InferError, InferResponse, StreamFrame, StreamHandle};
pub use shard::ShardPlanner;
