//! Inference backends: what a coordinator worker actually runs.

use crate::error::{bail, Result};
use crate::nn::{ExecCtx, Model};
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// A batched inference backend. Workers own their backend exclusively
/// (`&mut self`), so implementations may keep scratch state.
///
/// Backends are **not** required to be `Send`: PJRT handles contain
/// `Rc`s, so the coordinator constructs each backend *inside* its worker
/// thread via [`BackendSpec`].
pub trait Backend {
    /// Backend name (router key).
    fn name(&self) -> &str;
    /// Expected per-item input shape `[c, h, w]`-style (no batch dim).
    fn item_shape(&self) -> &[usize];
    /// Run a batch `[b, …item_shape]` and return `[b, …out]`.
    fn infer(&mut self, batch: &Tensor) -> Result<Tensor>;
}

/// Native backend: a [`Model`] executed by the Rust kernels with a fixed
/// [`ExecCtx`] (the router registers one backend per algorithm). The ctx
/// — and with it the scratch arena — lives as long as the backend, so
/// batched inference reuses buffers across requests instead of paying
/// allocation churn per call.
pub struct NativeBackend {
    name: String,
    model: Model,
    ctx: ExecCtx,
}

impl NativeBackend {
    /// Wrap a model + algorithm choice.
    pub fn new(name: impl Into<String>, model: Model, ctx: ExecCtx) -> Self {
        NativeBackend { name: name.into(), model, ctx }
    }

    /// The wrapped model.
    pub fn model(&self) -> &Model {
        &self.model
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn item_shape(&self) -> &[usize] {
        &self.model.input_shape
    }

    fn infer(&mut self, batch: &Tensor) -> Result<Tensor> {
        Ok(self.model.forward(batch, &self.ctx))
    }
}

/// How a coordinator worker constructs its backend. The factory runs on
/// the worker thread itself (PJRT handles are not `Send`), so only the
/// spec — not the backend — crosses threads.
pub struct BackendSpec {
    /// Router key.
    pub name: String,
    /// Per-item input shape the router validates against.
    pub item_shape: Vec<usize>,
    /// Constructor, run once on the worker thread.
    pub factory: Box<dyn FnOnce() -> Result<Box<dyn Backend>> + Send>,
}

impl BackendSpec {
    /// Spec for a native (Rust kernels) backend.
    pub fn native(name: impl Into<String>, model: Model, ctx: ExecCtx) -> Self {
        let name = name.into();
        let item_shape = model.input_shape.clone();
        let n2 = name.clone();
        BackendSpec {
            name,
            item_shape,
            factory: Box::new(move || {
                Ok(Box::new(NativeBackend::new(n2, model, ctx)) as Box<dyn Backend>)
            }),
        }
    }

    /// Spec for a PJRT artifact backend. `item_shape` must match the
    /// artifact's input with the batch dimension stripped (validated when
    /// the worker constructs the backend).
    pub fn pjrt(
        name: impl Into<String>,
        artifacts_dir: impl Into<std::path::PathBuf>,
        artifact: impl Into<String>,
        item_shape: Vec<usize>,
    ) -> Self {
        let name = name.into();
        let dir = artifacts_dir.into();
        let artifact = artifact.into();
        let n2 = name.clone();
        let expect = item_shape.clone();
        BackendSpec {
            name,
            item_shape,
            factory: Box::new(move || {
                let engine = Engine::new(dir)?;
                let b = PjrtBackend::new(n2, engine, &artifact)?;
                if b.item_shape() != expect {
                    bail!(
                        "artifact '{artifact}' item shape {:?} != declared {:?}",
                        b.item_shape(),
                        expect
                    );
                }
                Ok(Box::new(b) as Box<dyn Backend>)
            }),
        }
    }
}

/// PJRT backend: an AOT artifact with a *fixed* batch dimension. Smaller
/// batches are zero-padded to the artifact batch and the outputs sliced
/// back; larger batches are split into chunks.
pub struct PjrtBackend {
    name: String,
    engine: Engine,
    artifact: String,
    item_shape: Vec<usize>,
    artifact_batch: usize,
}

impl PjrtBackend {
    /// Create over an existing engine. The artifact must take a single
    /// `[b, …]` input.
    pub fn new(name: impl Into<String>, mut engine: Engine, artifact: &str) -> Result<Self> {
        let spec = engine.load(artifact)?.clone();
        if spec.inputs.len() != 1 {
            bail!("PjrtBackend needs a single-input artifact, '{artifact}' has {}", spec.inputs.len());
        }
        let shape = &spec.inputs[0];
        if shape.is_empty() {
            bail!("artifact '{artifact}' input has rank 0");
        }
        Ok(PjrtBackend {
            name: name.into(),
            engine,
            artifact: artifact.to_string(),
            item_shape: shape[1..].to_vec(),
            artifact_batch: shape[0],
        })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn item_shape(&self) -> &[usize] {
        &self.item_shape
    }

    fn infer(&mut self, batch: &Tensor) -> Result<Tensor> {
        let b = batch.dim(0);
        let item: usize = self.item_shape.iter().product();
        let spec_out = self
            .engine
            .manifest()
            .find(&self.artifact)
            .expect("artifact known")
            .output
            .clone();
        let out_item: usize = spec_out[1..].iter().product();
        let mut out_data = Vec::with_capacity(b * out_item);

        let mut done = 0;
        while done < b {
            let chunk = (b - done).min(self.artifact_batch);
            // Pad the chunk to the artifact's fixed batch.
            let mut padded =
                vec![0.0f32; self.artifact_batch * item];
            padded[..chunk * item]
                .copy_from_slice(&batch.as_slice()[done * item..(done + chunk) * item]);
            let mut in_shape = vec![self.artifact_batch];
            in_shape.extend_from_slice(&self.item_shape);
            let t = Tensor::from_vec(padded, &in_shape);
            let y = self.engine.execute(&self.artifact, &[&t])?;
            out_data.extend_from_slice(&y.as_slice()[..chunk * out_item]);
            done += chunk;
        }
        let mut out_shape = vec![b];
        out_shape.extend_from_slice(&spec_out[1..]);
        Ok(Tensor::from_vec(out_data, &out_shape))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ConvAlgo;
    use crate::nn::zoo::simple_cnn;

    #[test]
    fn native_backend_runs_batches() {
        let mut b = NativeBackend::new(
            "sliding",
            simple_cnn(10, 1),
            ExecCtx::new(ConvAlgo::Sliding),
        );
        assert_eq!(b.item_shape(), &[1, 28, 28]);
        let x = Tensor::randn(&[3, 1, 28, 28], 4);
        let y = b.infer(&x).unwrap();
        assert_eq!(y.dims(), &[3, 10]);
        assert_eq!(b.name(), "sliding");
    }

    #[test]
    fn native_backends_agree_across_algos() {
        let x = Tensor::randn(&[2, 1, 28, 28], 5);
        let mut g = NativeBackend::new(
            "gemm",
            simple_cnn(10, 1),
            ExecCtx::new(ConvAlgo::Im2colGemm),
        );
        let mut s = NativeBackend::new(
            "sliding",
            simple_cnn(10, 1),
            ExecCtx::new(ConvAlgo::Sliding),
        );
        let yg = g.infer(&x).unwrap();
        let ys = s.infer(&x).unwrap();
        assert!(yg.allclose(&ys, 1e-4), "diff {}", yg.max_abs_diff(&ys));
    }

    #[test]
    fn multithreaded_backend_matches_single_threaded() {
        let x = Tensor::randn(&[4, 1, 28, 28], 6);
        let mut one = NativeBackend::new(
            "sliding-1t",
            simple_cnn(10, 1),
            ExecCtx::with_threads(ConvAlgo::Sliding, 1),
        );
        let mut many = NativeBackend::new(
            "sliding-4t",
            simple_cnn(10, 1),
            ExecCtx::with_threads(ConvAlgo::Sliding, 4),
        );
        let a = one.infer(&x).unwrap();
        let b = many.infer(&x).unwrap();
        // Work items are computed identically on every partition, so the
        // outputs are bit-identical, not merely close.
        assert_eq!(a.as_slice(), b.as_slice());
    }
}
