//! Inference backends: what a coordinator replica actually runs.

use crate::autotune::DispatchProfile;
use crate::error::{bail, Result};
use crate::exec::{available_threads, CoreSet, WorkerPool};
use crate::graph::CompiledPlan;
use crate::nn::{ExecCtx, Model};
use crate::runtime::Engine;
use crate::stream::StreamSession;
use crate::tensor::{Dtype, Tensor};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How a serving tier places its replicas on cores. The replica is the
/// pinning unit: replica `i` of `n` gets core slice `i` of the policy's
/// base set ([`PinPolicy::slice_for`]), the replica thread pins itself
/// to the whole slice, and a native backend re-pools its `ExecCtx` onto
/// a [`WorkerPool`] whose workers pin 1:1 to the slice's cores — so each
/// replica's kernel threads stay resident on one core group (one NUMA
/// node, when slices follow node boundaries) and the scratch they
/// first-touch stays local.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum PinPolicy {
    /// No pinning: the OS schedules replica and kernel threads freely
    /// (the default, and the only option off Linux).
    #[default]
    None,
    /// Round-robin every hardware thread (`0..available_threads()`)
    /// across the replicas.
    Auto,
    /// Round-robin an explicit core set (the CLI's `--pin 0-3,8`)
    /// across the replicas.
    Cores(CoreSet),
}

impl PinPolicy {
    /// The core slice replica `replica` of `replicas` should run on:
    /// `None` when the policy doesn't pin. Slices are never empty, so
    /// every replica always has somewhere to run. [`PinPolicy::Auto`] is
    /// topology-aware: when sysfs exposes the machine's NUMA nodes
    /// ([`crate::exec::numa_nodes`]) the slices follow node boundaries
    /// ([`CoreSet::split_by_nodes`]) — one replica's threads never
    /// straddle a node — and fall back to round-robin
    /// ([`CoreSet::split`]) where sysfs is absent. Explicit
    /// [`PinPolicy::Cores`] sets stay plain round-robin: the operator
    /// who typed the core list owns its layout.
    pub fn slice_for(&self, replica: usize, replicas: usize) -> Option<CoreSet> {
        let base = match self {
            PinPolicy::None => return None,
            PinPolicy::Auto => CoreSet::all(available_threads()),
            PinPolicy::Cores(set) => set.clone(),
        };
        if base.is_empty() {
            return None;
        }
        let replicas = replicas.max(1);
        let slices = match (self, crate::exec::numa_nodes()) {
            (PinPolicy::Auto, Some(nodes)) => base.split_by_nodes(replicas, &nodes),
            _ => base.split(replicas),
        };
        Some(slices[replica % replicas].clone())
    }
}

/// A batched inference backend. Replica workers own their backend
/// exclusively (`&mut self`), so implementations may keep scratch state.
///
/// Backends are **not** required to be `Send`: PJRT handles contain
/// `Rc`s, so the coordinator constructs each backend *inside* its worker
/// thread via [`BackendSpec`].
pub trait Backend {
    /// Backend name (router key).
    fn name(&self) -> &str;
    /// Expected per-item input shape `[c, h, w]`-style (no batch dim).
    fn item_shape(&self) -> &[usize];
    /// Run a batch `[b, …item_shape]` and return `[b, …out]`.
    fn infer(&mut self, batch: &Tensor) -> Result<Tensor>;
    /// Install a measured dispatch profile ([`crate::autotune`]). The
    /// coordinator calls this once, right after construction, on every
    /// replica of a spec built with [`BackendSpec::with_profile`].
    /// Default: ignored (PJRT artifacts are compiled ahead of time, so
    /// there is nothing to tune at dispatch).
    fn set_profile(&mut self, _profile: Arc<DispatchProfile>) {}
    /// Install the element type this replica should serve in
    /// ([`crate::tensor::Dtype`]). The coordinator calls this once,
    /// right after construction, on every replica of a spec built with
    /// [`BackendSpec::with_dtype`]. Default: ignored (PJRT artifacts
    /// bake their precision in at compile time).
    fn set_dtype(&mut self, _dtype: Dtype) {}
    /// Install this replica's core slice ([`BackendSpec::with_pinning`];
    /// the replica worker has already pinned its own thread to the
    /// slice before calling). Native backends re-pool their `ExecCtx`
    /// onto workers pinned 1:1 inside the slice. Default: ignored —
    /// thread-per-replica backends (PJRT) are fully placed by the
    /// replica thread's own pin.
    fn set_pinning(&mut self, _cores: &CoreSet) {}
    /// How often the replica worker should call [`Backend::idle_tick`]
    /// while its queue is quiet; `None` (default) means never — the
    /// worker blocks on its queue with no wakeups.
    fn idle_tick_period(&self) -> Option<Duration> {
        None
    }
    /// Housekeeping hook, called by the replica worker between requests
    /// when the queue has been quiet for [`Backend::idle_tick_period`]
    /// — never concurrently with [`Backend::infer`]. Default: no-op.
    fn idle_tick(&mut self) {}
    /// Open the streaming session `sid` — or, if `sid` already exists,
    /// **replace** it with a fresh one (a re-open is always a clean
    /// state reset, never a resume from stale rings). Default: streaming
    /// unsupported.
    fn open_stream(&mut self, _sid: u64) -> Result<()> {
        bail!("backend '{}' does not support streaming", self.name())
    }
    /// Feed one frame to session `sid`; `Ok(Some(col))` when the frame
    /// propagated to an output column, `Ok(None)` during window warmup
    /// or stride gaps, `Err` when the session does not exist (e.g. it
    /// was evicted as idle — the caller re-opens and replays or accepts
    /// the gap). Default: streaming unsupported.
    fn advance_stream(&mut self, _sid: u64, _frame: &[f32]) -> Result<Option<Vec<f32>>> {
        bail!("backend '{}' does not support streaming", self.name())
    }
    /// Drop session `sid`'s state; unknown ids are a no-op.
    fn close_stream(&mut self, _sid: u64) {}
    /// Live streaming sessions held by this backend (introspection).
    fn stream_count(&self) -> usize {
        0
    }
}

/// Native backend: a [`Model`] compiled to a [`CompiledPlan`] (typed
/// graph IR + fusion passes, see [`crate::graph`]) and executed by the
/// Rust kernels with a fixed [`ExecCtx`] (the router registers one
/// backend per algorithm). The plan is compiled **once per tier** and
/// shared across replicas behind an `Arc`, exactly like the model
/// weights it contains; each replica keeps only its own ctx/arena.
/// `SWCONV_NO_FUSE=1` (or `--no-fuse`) makes the plan reproduce the
/// layer stack verbatim — either way `infer` is bit-identical to
/// `model.forward`. The ctx — and with it the scratch arena — lives as
/// long as the backend, so batched inference reuses buffers across
/// requests instead of paying allocation churn per call.
///
/// By default the arena keeps its high-water scratch forever (fastest
/// steady state); [`NativeBackend::with_trim_after`] caps the retained
/// capacity after every batch so one outsized request can't pin memory
/// for the backend's lifetime, and [`NativeBackend::with_trim_idle`]
/// releases *all* of it once the backend has been quiet for a while
/// (the replica worker drives the idle clock via
/// [`Backend::idle_tick`]).
pub struct NativeBackend {
    name: String,
    model: Model,
    plan: Arc<CompiledPlan>,
    ctx: ExecCtx,
    trim_after: Option<usize>,
    trim_idle: Option<Duration>,
    /// Live streaming sessions keyed by id, with last-touch times for
    /// idle eviction. Each session owns a private `ExecCtx` clone, so
    /// its ring/arena state stays hot on this replica between frames —
    /// the whole point of session affinity.
    sessions: HashMap<u64, (StreamSession, Instant)>,
    stream_idle: Option<Duration>,
}

impl NativeBackend {
    /// Wrap a model + execution context (algorithm, worker threads,
    /// scratch arena and — if attached — the dispatch profile). The
    /// model is compiled here; to share one compiled plan across
    /// replicas, use [`NativeBackend::with_plan`].
    pub fn new(name: impl Into<String>, model: Model, ctx: ExecCtx) -> Self {
        let plan = Arc::new(model.compile());
        Self::with_plan(name, model, plan, ctx)
    }

    /// Wrap an already-compiled plan (shared across a tier's replicas
    /// by [`BackendSpec::native_retention`]'s factory) together with
    /// the model it came from.
    pub fn with_plan(
        name: impl Into<String>,
        model: Model,
        plan: Arc<CompiledPlan>,
        ctx: ExecCtx,
    ) -> Self {
        NativeBackend {
            name: name.into(),
            model,
            plan,
            ctx,
            trim_after: None,
            trim_idle: None,
            sessions: HashMap::new(),
            stream_idle: None,
        }
    }

    /// Arena retention knob: after each batch, trim the ctx's scratch
    /// arena to at most `max_floats` retained `f32`s (see
    /// [`ExecCtx::trim`]). The working set of the *current* batch is
    /// unaffected — only what stays cached between batches is bounded.
    pub fn with_trim_after(mut self, max_floats: usize) -> Self {
        self.trim_after = Some(max_floats);
        self
    }

    /// Time-based arena retention: once the backend has served nothing
    /// for `idle`, drop every cached scratch buffer
    /// ([`ExecCtx::trim_after_idle`]). The replica worker polls
    /// [`Backend::idle_tick`] at a fraction of `idle` while its queue
    /// is quiet, so a burst's high-water scratch is released during the
    /// lull instead of pinned until the next burst.
    pub fn with_trim_idle(mut self, idle: Duration) -> Self {
        self.trim_idle = Some(idle);
        self
    }

    /// Streaming-session retention: evict any session untouched for
    /// `idle` on the next [`Backend::idle_tick`], freeing its rings and
    /// its private arena (see [`NativeBackend::stream_arena_bytes`]).
    /// A later `advance_stream` on an evicted id errors, and the
    /// coordinator re-opens a *fresh* session — state never silently
    /// resumes. `None` (the default) keeps sessions until closed.
    pub fn with_stream_idle(mut self, idle: Duration) -> Self {
        self.stream_idle = Some(idle);
        self
    }

    /// Bytes of scratch retained by live streaming sessions' private
    /// arenas (idle eviction drives this back to zero).
    pub fn stream_arena_bytes(&self) -> usize {
        self.sessions.values().map(|(s, _)| s.ctx().arena_bytes()).sum()
    }

    /// The wrapped model.
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// The compiled plan this backend serves.
    pub fn plan(&self) -> &Arc<CompiledPlan> {
        &self.plan
    }

    /// The backend-owned execution context (scratch arena + threads).
    pub fn ctx(&self) -> &ExecCtx {
        &self.ctx
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn item_shape(&self) -> &[usize] {
        &self.model.input_shape
    }

    fn infer(&mut self, batch: &Tensor) -> Result<Tensor> {
        let out = self.plan.run(batch, &self.ctx);
        if let Some(cap) = self.trim_after {
            self.ctx.trim(cap);
        }
        Ok(out)
    }

    fn set_profile(&mut self, profile: Arc<DispatchProfile>) {
        self.ctx.set_profile(profile);
    }

    fn set_dtype(&mut self, dtype: Dtype) {
        self.ctx.set_dtype(dtype);
    }

    fn set_pinning(&mut self, cores: &CoreSet) {
        // Swap the replica's ctx onto a pool whose workers pin 1:1 to
        // the slice cores, so kernel threads — and the arena pages they
        // first-touch — stay inside the replica's core group. Under
        // `--no-pool` the scoped threads simply inherit the replica
        // thread's affinity mask instead.
        let threads = self.ctx.threads();
        if threads > 1 && !crate::exec::pool::pooling_disabled() {
            self.ctx.set_pool(Some(WorkerPool::pinned(threads - 1, cores.clone())));
        }
    }

    fn idle_tick_period(&self) -> Option<Duration> {
        // Poll at a quarter of the tightest idle threshold (≥ 5 ms so a
        // tiny threshold can't busy-spin the worker): the arena is
        // released at most 1.25 × `idle` after the last request, and
        // idle sessions are evicted on the same clock.
        let d = match (self.trim_idle, self.stream_idle) {
            (Some(a), Some(b)) => a.min(b),
            (Some(a), None) => a,
            (None, Some(b)) => b,
            (None, None) => return None,
        };
        Some((d / 4).max(Duration::from_millis(5)))
    }

    fn idle_tick(&mut self) {
        if let Some(idle) = self.trim_idle {
            self.ctx.trim_after_idle(idle);
        }
        if let Some(idle) = self.stream_idle {
            // Dropping a session drops its private ctx and with it every
            // arena buffer the session kept hot.
            self.sessions.retain(|_, (_, touched)| touched.elapsed() < idle);
        }
    }

    fn open_stream(&mut self, sid: u64) -> Result<()> {
        // A re-open of a live id *replaces* the session: always a clean
        // reset, never a resume from whatever state was left behind.
        let session = StreamSession::new(&self.model, self.ctx.clone())?;
        self.sessions.insert(sid, (session, Instant::now()));
        Ok(())
    }

    fn advance_stream(&mut self, sid: u64, frame: &[f32]) -> Result<Option<Vec<f32>>> {
        let Some((session, touched)) = self.sessions.get_mut(&sid) else {
            bail!("stream {sid} is not open on this replica (evicted or never opened)");
        };
        if frame.len() != session.in_channels() {
            bail!(
                "stream {sid}: frame has {} channels, model wants {}",
                frame.len(),
                session.in_channels()
            );
        }
        *touched = Instant::now();
        Ok(session.advance(frame))
    }

    fn close_stream(&mut self, sid: u64) {
        self.sessions.remove(&sid);
    }

    fn stream_count(&self) -> usize {
        self.sessions.len()
    }
}

/// The factory a replica worker runs (on its own thread — PJRT handles
/// are not `Send`, so only the spec crosses threads) to build its
/// backend instance. Called once per replica with the replica index.
pub type BackendFactory = Arc<dyn Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync>;

/// How the coordinator constructs a backend's serving tier: the router
/// key, the validated item shape, how many replica workers to spawn and
/// the factory each replica runs. With `replicas > 1` the coordinator
/// shards formed batches across the replicas (see
/// [`super::shard::ShardPlanner`]); each replica gets its own backend
/// instance and therefore its own `ExecCtx`/engine state, while native
/// replicas share model weights through [`Model`]'s `Arc`-backed clone.
///
/// # Examples
///
/// A replicated, profile-tuned native tier served end to end:
///
/// ```
/// use std::sync::Arc;
/// use std::time::Duration;
/// use swconv::autotune::DispatchProfile;
/// use swconv::coordinator::{BackendSpec, BatchPolicy, Coordinator};
/// use swconv::kernels::ConvAlgo;
/// use swconv::nn::{zoo, ExecCtx};
/// use swconv::tensor::Tensor;
///
/// let profile = Arc::new(DispatchProfile::paper_policy()); // or load_or_paper(path)
/// let spec = BackendSpec::native(
///     "sliding",
///     zoo::simple_cnn(10, 1),
///     ExecCtx::with_threads(ConvAlgo::Sliding, 2),
/// )
/// .with_replicas(2)
/// .with_profile(profile);
///
/// let coord = Coordinator::new(
///     vec![spec],
///     BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
/// );
/// let y = coord
///     .infer("sliding", Tensor::randn(&[1, 28, 28], 7))
///     .unwrap()
///     .output
///     .unwrap();
/// assert_eq!(y.dims(), &[10]);
/// coord.shutdown();
/// ```
pub struct BackendSpec {
    /// Router key.
    pub name: String,
    /// Per-item input shape the router validates against.
    pub item_shape: Vec<usize>,
    /// Replica worker threads (clamped to ≥ 1 by the coordinator).
    pub replicas: usize,
    /// Constructor, run once per replica on the replica's thread.
    pub factory: BackendFactory,
    /// Measured dispatch profile installed on every replica right after
    /// its factory runs ([`Backend::set_profile`]); `None` leaves each
    /// replica on the paper's hard-coded dispatch policy.
    pub profile: Option<Arc<DispatchProfile>>,
    /// Element type installed on every replica right after its factory
    /// runs ([`Backend::set_dtype`]): `F32` (the default) is the
    /// bit-exact baseline, `Bf16`/`I8` make native replicas serve the
    /// reduced-precision kernels.
    pub dtype: Dtype,
    /// Core placement for the tier's replicas: replica `i` gets core
    /// slice `i` ([`PinPolicy::slice_for`]) — the replica thread pins
    /// itself and hands the slice to its backend
    /// ([`Backend::set_pinning`]). Default [`PinPolicy::None`].
    pub pinning: PinPolicy,
}

impl BackendSpec {
    /// Spec from a raw factory closure (the replica index is passed in;
    /// most factories ignore it).
    pub fn from_factory(
        name: impl Into<String>,
        item_shape: Vec<usize>,
        factory: impl Fn(usize) -> Result<Box<dyn Backend>> + Send + Sync + 'static,
    ) -> Self {
        BackendSpec {
            name: name.into(),
            item_shape,
            replicas: 1,
            factory: Arc::new(factory),
            profile: None,
            dtype: Dtype::F32,
            pinning: PinPolicy::None,
        }
    }

    /// Set the replica count (builder style; clamped to ≥ 1).
    pub fn with_replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas.max(1);
        self
    }

    /// Set the tier's core placement (builder style): with
    /// [`PinPolicy::Auto`] or an explicit [`PinPolicy::Cores`] set,
    /// replica `i` pins to core slice `i` and native replicas run their
    /// kernel threads on a pool pinned inside that slice — the NUMA
    /// serving setup (one replica per node) the ROADMAP calls for.
    pub fn with_pinning(mut self, pinning: PinPolicy) -> Self {
        self.pinning = pinning;
        self
    }

    /// Set the serving element type (builder style): the coordinator
    /// installs it on every replica's backend right after construction,
    /// so one knob switches a whole tier to bf16 or int8 serving (the
    /// CLI's `serve --dtype`).
    pub fn with_dtype(mut self, dtype: Dtype) -> Self {
        self.dtype = dtype;
        self
    }

    /// Attach a measured dispatch profile (builder style): every
    /// replica of this tier dispatches tuned — the coordinator installs
    /// the shared profile on each replica's backend right after the
    /// factory constructs it, so one `autotune` run (or one cached
    /// `profile.json`) steers the whole tier.
    pub fn with_profile(mut self, profile: Arc<DispatchProfile>) -> Self {
        self.profile = Some(profile);
        self
    }

    /// Spec for a native (Rust kernels) backend. Every replica clones
    /// the model (sharing weights) and the ctx (fresh arena, same
    /// algorithm + thread count).
    pub fn native(name: impl Into<String>, model: Model, ctx: ExecCtx) -> Self {
        Self::native_retention(name, model, ctx, None, None)
    }

    /// [`BackendSpec::native`] with the size-based arena retention knob:
    /// each replica trims its scratch arena to `trim_after` floats after
    /// every batch (see [`NativeBackend::with_trim_after`]).
    pub fn native_trimmed(
        name: impl Into<String>,
        model: Model,
        ctx: ExecCtx,
        trim_after: usize,
    ) -> Self {
        Self::native_retention(name, model, ctx, Some(trim_after), None)
    }

    /// [`BackendSpec::native`] with both arena retention knobs:
    /// `trim_after` caps the retained floats after every batch (size
    /// policy, `None` = unbounded) and `trim_idle` drops all retained
    /// scratch once a replica has been quiet that long (time policy,
    /// `None` = never; see [`NativeBackend::with_trim_idle`]). The two
    /// compose: cap the steady state, release it entirely across lulls.
    pub fn native_retention(
        name: impl Into<String>,
        model: Model,
        ctx: ExecCtx,
        trim_after: Option<usize>,
        trim_idle: Option<Duration>,
    ) -> Self {
        let name = name.into();
        let item_shape = model.input_shape.clone();
        let n2 = name.clone();
        // Compile once per tier: every replica serves this one plan
        // (graph + weights) and keeps only its own ctx/arena private.
        let plan = Arc::new(model.compile());
        BackendSpec {
            name,
            item_shape,
            replicas: 1,
            factory: Arc::new(move |_replica| {
                let mut b = NativeBackend::with_plan(
                    n2.clone(),
                    model.clone(),
                    Arc::clone(&plan),
                    ctx.clone(),
                );
                if let Some(cap) = trim_after {
                    b = b.with_trim_after(cap);
                }
                if let Some(idle) = trim_idle {
                    b = b.with_trim_idle(idle);
                }
                Ok(Box::new(b) as Box<dyn Backend>)
            }),
            profile: None,
            dtype: Dtype::F32,
            pinning: PinPolicy::None,
        }
    }

    /// [`BackendSpec::native`] with a whole-model planner plan: the
    /// tier compiles once, runs [`crate::graph::plan_model`] at batch
    /// size `plan_batch` under `budget_bytes`, and every replica serves
    /// the *planned* [`CompiledPlan`] — per-node algorithm ×
    /// worker-split choices attached via
    /// [`CompiledPlan::with_choices`] — shared behind one `Arc` exactly
    /// like the weights. Planning only re-routes between bit-identical
    /// kernels, so a planned tier's outputs match an unplanned one's
    /// byte for byte; the plan's dtype follows `ctx`'s serving dtype.
    /// Errors when no plan fits the budget
    /// ([`crate::graph::PlanError::Infeasible`]) — an explicit refusal,
    /// never a silent fallback to an over-budget plan.
    pub fn native_planned(
        name: impl Into<String>,
        model: Model,
        ctx: ExecCtx,
        plan_batch: usize,
        budget_bytes: Option<u64>,
    ) -> Result<Self> {
        let name = name.into();
        let item_shape = model.input_shape.clone();
        let n2 = name.clone();
        let compiled = model.compile();
        let planned = match crate::graph::plan_model(&compiled, plan_batch, &ctx, budget_bytes) {
            Ok(mp) => mp,
            Err(e) => bail!("planned tier '{name}': {e}"),
        };
        // Attach both planner products: per-node kernel choices and the
        // cache-footprint term's tiled chains (empty when nothing
        // spills the L2 tile budget). Both are bit-identical levers.
        let plan = Arc::new(compiled.with_choices(planned.choices).with_tiling(planned.tiling));
        Ok(BackendSpec {
            name,
            item_shape,
            replicas: 1,
            factory: Arc::new(move |_replica| {
                let b = NativeBackend::with_plan(
                    n2.clone(),
                    model.clone(),
                    Arc::clone(&plan),
                    ctx.clone(),
                );
                Ok(Box::new(b) as Box<dyn Backend>)
            }),
            profile: None,
            dtype: Dtype::F32,
            pinning: PinPolicy::None,
        })
    }

    /// [`BackendSpec::native`] with streaming-session idle eviction:
    /// every replica evicts sessions untouched for `stream_idle` on its
    /// idle tick ([`NativeBackend::with_stream_idle`]). Use for tiers
    /// that serve [`super::Coordinator::open_stream`] traffic.
    pub fn native_streaming(
        name: impl Into<String>,
        model: Model,
        ctx: ExecCtx,
        stream_idle: Duration,
    ) -> Self {
        let name = name.into();
        let item_shape = model.input_shape.clone();
        let n2 = name.clone();
        let plan = Arc::new(model.compile());
        BackendSpec {
            name,
            item_shape,
            replicas: 1,
            factory: Arc::new(move |_replica| {
                let b = NativeBackend::with_plan(
                    n2.clone(),
                    model.clone(),
                    Arc::clone(&plan),
                    ctx.clone(),
                )
                .with_stream_idle(stream_idle);
                Ok(Box::new(b) as Box<dyn Backend>)
            }),
            profile: None,
            dtype: Dtype::F32,
            pinning: PinPolicy::None,
        }
    }

    /// Spec for a PJRT artifact backend. `item_shape` must match the
    /// artifact's input with the batch dimension stripped (validated when
    /// each replica constructs its backend; every replica loads its own
    /// engine, since PJRT handles cannot be shared across threads).
    pub fn pjrt(
        name: impl Into<String>,
        artifacts_dir: impl Into<std::path::PathBuf>,
        artifact: impl Into<String>,
        item_shape: Vec<usize>,
    ) -> Self {
        let name = name.into();
        let dir = artifacts_dir.into();
        let artifact = artifact.into();
        let n2 = name.clone();
        let expect = item_shape.clone();
        BackendSpec {
            name,
            item_shape,
            replicas: 1,
            profile: None,
            dtype: Dtype::F32,
            pinning: PinPolicy::None,
            factory: Arc::new(move |_replica| {
                let engine = Engine::new(dir.clone())?;
                let b = PjrtBackend::new(n2.clone(), engine, &artifact)?;
                if b.item_shape() != expect {
                    bail!(
                        "artifact '{artifact}' item shape {:?} != declared {:?}",
                        b.item_shape(),
                        expect
                    );
                }
                Ok(Box::new(b) as Box<dyn Backend>)
            }),
        }
    }
}

/// PJRT backend: an AOT artifact with a *fixed* batch dimension. Smaller
/// batches are zero-padded to the artifact batch and the outputs sliced
/// back; larger batches are split into chunks.
pub struct PjrtBackend {
    name: String,
    engine: Engine,
    artifact: String,
    item_shape: Vec<usize>,
    artifact_batch: usize,
    /// Output shape with the batch dimension stripped, captured from the
    /// manifest at construction — a manifest miss is therefore a
    /// construction-time `Err`, never a request-path panic.
    out_item_shape: Vec<usize>,
}

impl PjrtBackend {
    /// Create over an existing engine. The artifact must take a single
    /// `[b, …]` input.
    pub fn new(name: impl Into<String>, mut engine: Engine, artifact: &str) -> Result<Self> {
        let spec = engine.load(artifact)?.clone();
        if spec.inputs.len() != 1 {
            bail!("PjrtBackend needs a single-input artifact, '{artifact}' has {}", spec.inputs.len());
        }
        let shape = &spec.inputs[0];
        if shape.is_empty() {
            bail!("artifact '{artifact}' input has rank 0");
        }
        if spec.output.is_empty() {
            bail!("artifact '{artifact}' output has rank 0");
        }
        Ok(PjrtBackend {
            name: name.into(),
            engine,
            artifact: artifact.to_string(),
            item_shape: shape[1..].to_vec(),
            artifact_batch: shape[0],
            out_item_shape: spec.output[1..].to_vec(),
        })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn item_shape(&self) -> &[usize] {
        &self.item_shape
    }

    fn infer(&mut self, batch: &Tensor) -> Result<Tensor> {
        let b = batch.dim(0);
        let item: usize = self.item_shape.iter().product();
        let out_item: usize = self.out_item_shape.iter().product();
        let mut out_data = Vec::with_capacity(b * out_item);

        let mut done = 0;
        while done < b {
            let chunk = (b - done).min(self.artifact_batch);
            // Pad the chunk to the artifact's fixed batch.
            let mut padded =
                vec![0.0f32; self.artifact_batch * item];
            padded[..chunk * item]
                .copy_from_slice(&batch.as_slice()[done * item..(done + chunk) * item]);
            let mut in_shape = vec![self.artifact_batch];
            in_shape.extend_from_slice(&self.item_shape);
            let t = Tensor::from_vec(padded, &in_shape);
            let y = self.engine.execute(&self.artifact, &[&t])?;
            out_data.extend_from_slice(&y.as_slice()[..chunk * out_item]);
            done += chunk;
        }
        let mut out_shape = vec![b];
        out_shape.extend_from_slice(&self.out_item_shape);
        Ok(Tensor::from_vec(out_data, &out_shape))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ConvAlgo;
    use crate::nn::zoo::simple_cnn;

    #[test]
    fn native_backend_runs_batches() {
        let mut b = NativeBackend::new(
            "sliding",
            simple_cnn(10, 1),
            ExecCtx::new(ConvAlgo::Sliding),
        );
        assert_eq!(b.item_shape(), &[1, 28, 28]);
        let x = Tensor::randn(&[3, 1, 28, 28], 4);
        let y = b.infer(&x).unwrap();
        assert_eq!(y.dims(), &[3, 10]);
        assert_eq!(b.name(), "sliding");
    }

    #[test]
    fn native_backends_agree_across_algos() {
        let x = Tensor::randn(&[2, 1, 28, 28], 5);
        let mut g = NativeBackend::new(
            "gemm",
            simple_cnn(10, 1),
            ExecCtx::new(ConvAlgo::Im2colGemm),
        );
        let mut s = NativeBackend::new(
            "sliding",
            simple_cnn(10, 1),
            ExecCtx::new(ConvAlgo::Sliding),
        );
        let yg = g.infer(&x).unwrap();
        let ys = s.infer(&x).unwrap();
        assert!(yg.allclose(&ys, 1e-4), "diff {}", yg.max_abs_diff(&ys));
    }

    #[test]
    fn multithreaded_backend_matches_single_threaded() {
        let x = Tensor::randn(&[4, 1, 28, 28], 6);
        let mut one = NativeBackend::new(
            "sliding-1t",
            simple_cnn(10, 1),
            ExecCtx::with_threads(ConvAlgo::Sliding, 1),
        );
        let mut many = NativeBackend::new(
            "sliding-4t",
            simple_cnn(10, 1),
            ExecCtx::with_threads(ConvAlgo::Sliding, 4),
        );
        let a = one.infer(&x).unwrap();
        let b = many.infer(&x).unwrap();
        // Work items are computed identically on every partition, so the
        // outputs are bit-identical, not merely close.
        assert_eq!(a.as_slice(), b.as_slice());
    }

    /// REGRESSION (arena retention knob) — after a one-off huge request,
    /// a trimmed backend's retained scratch stays bounded while an
    /// untrimmed one keeps its high-water mark.
    #[test]
    fn trim_after_bounds_retained_scratch() {
        const CAP: usize = 64 * 1024; // 64 Ki floats = 256 KiB of scratch
        let mut capped = NativeBackend::new(
            "capped",
            simple_cnn(10, 1),
            ExecCtx::new(ConvAlgo::Im2colGemm),
        )
        .with_trim_after(CAP);
        let mut uncapped = NativeBackend::new(
            "uncapped",
            simple_cnn(10, 1),
            ExecCtx::new(ConvAlgo::Im2colGemm),
        );

        // One-off huge batch, then a small steady-state request.
        let huge = Tensor::randn(&[16, 1, 28, 28], 7);
        let small = Tensor::randn(&[1, 1, 28, 28], 8);
        let a = capped.infer(&huge).unwrap();
        let b = uncapped.infer(&huge).unwrap();
        assert_eq!(a.as_slice(), b.as_slice(), "trimming must not change results");
        capped.infer(&small).unwrap();
        uncapped.infer(&small).unwrap();

        assert!(
            capped.ctx().arena_bytes() <= 4 * CAP,
            "retained {} bytes > cap {}",
            capped.ctx().arena_bytes(),
            4 * CAP
        );
        assert!(
            uncapped.ctx().arena_bytes() > capped.ctx().arena_bytes(),
            "untrimmed backend should retain its high-water scratch \
             (untrimmed {}, trimmed {})",
            uncapped.ctx().arena_bytes(),
            capped.ctx().arena_bytes()
        );
    }

    /// REGRESSION (trim-after-idle) — the time-based retention policy:
    /// after a quiet period the idle tick releases every retained
    /// buffer; a busy backend is left alone.
    #[test]
    fn idle_tick_releases_scratch_after_quiet_period() {
        let mut b = NativeBackend::new(
            "idle",
            simple_cnn(10, 1),
            ExecCtx::new(ConvAlgo::Im2colGemm),
        )
        .with_trim_idle(Duration::from_millis(150));
        assert!(b.idle_tick_period().is_some());
        b.infer(&Tensor::randn(&[2, 1, 28, 28], 11)).unwrap();
        assert!(b.ctx().arena_bytes() > 0, "warm arena expected");
        // Immediately after serving: not idle yet, nothing released.
        b.idle_tick();
        assert!(b.ctx().arena_bytes() > 0, "busy backend must keep scratch");
        std::thread::sleep(Duration::from_millis(200));
        b.idle_tick();
        assert_eq!(b.ctx().arena_bytes(), 0, "idle backend must release scratch");
        // And serving afterwards still works (arena rebuilds).
        b.infer(&Tensor::randn(&[1, 1, 28, 28], 12)).unwrap();
        assert!(b.ctx().arena_bytes() > 0);
    }

    /// The dtype knob reaches the replica ctx, changes the numerics of
    /// an int8 tier only within quantization error, and keeps the f32
    /// tier bit-identical.
    #[test]
    fn spec_dtype_knob_switches_replicas_to_quantized_serving() {
        use crate::kernels::Conv2dParams;
        use crate::nn::layers::Conv2d;
        let model = || {
            Model::new("one-conv", &[2, 10, 10])
                .push(Conv2d::new(2, 3, 3, Conv2dParams::same(3), 40))
        };
        let spec = BackendSpec::native("q", model(), ExecCtx::default()).with_dtype(Dtype::I8);
        assert_eq!(spec.dtype, Dtype::I8);
        let x = Tensor::randn(&[2, 2, 10, 10], 13);
        let mut f32_b = NativeBackend::new("f", model(), ExecCtx::default());
        let yf = f32_b.infer(&x).unwrap();
        let mut q_b = spec.factory.as_ref()(0).unwrap();
        q_b.set_dtype(spec.dtype);
        let yq = q_b.infer(&x).unwrap();
        assert_eq!(yq.dims(), yf.dims());
        let d = yq.max_abs_diff(&yf);
        assert!(d < 0.25, "int8 serving should track f32 (diff {d})");
        assert!(d > 0.0, "dtype knob must actually engage the int8 path");
    }

    #[test]
    fn spec_builders_set_replicas() {
        let s = BackendSpec::native("a", simple_cnn(10, 1), ExecCtx::default());
        assert_eq!(s.replicas, 1);
        let s = s.with_replicas(4);
        assert_eq!(s.replicas, 4);
        assert_eq!(s.with_replicas(0).replicas, 1, "clamped to >= 1");
    }

    /// The profile knob: installing a profile must not change results
    /// when the profile agrees with the paper policy, and the spec
    /// carries it for the coordinator to install per replica.
    #[test]
    fn spec_profile_knob_and_native_set_profile() {
        let profile = Arc::new(DispatchProfile::paper_policy());
        let spec = BackendSpec::native("sliding", simple_cnn(10, 1), ExecCtx::default())
            .with_profile(Arc::clone(&profile));
        assert!(spec.profile.is_some());

        let x = Tensor::randn(&[2, 1, 28, 28], 10);
        let mut plain = spec.factory.as_ref()(0).unwrap();
        let baseline = plain.infer(&x).unwrap();
        let mut tuned = spec.factory.as_ref()(1).unwrap();
        tuned.set_profile(Arc::clone(&profile));
        let y = tuned.infer(&x).unwrap();
        assert_eq!(baseline.as_slice(), y.as_slice());
    }

    /// Pin policies slice deterministically: replica `i` of `n` gets
    /// the round-robin slice `i`, `None` never pins, and the slice math
    /// agrees with [`CoreSet::split`].
    #[test]
    fn pin_policy_slices_cores_per_replica() {
        assert_eq!(PinPolicy::None.slice_for(0, 4), None);
        let set = CoreSet::parse("0-5").unwrap();
        let policy = PinPolicy::Cores(set.clone());
        assert_eq!(policy.slice_for(0, 2), Some(CoreSet::from_cores(&[0, 2, 4])));
        assert_eq!(policy.slice_for(1, 2), Some(CoreSet::from_cores(&[1, 3, 5])));
        // Degenerate replica counts clamp rather than panic.
        assert_eq!(policy.slice_for(0, 0), Some(set.clone()));
        // Auto slices every hardware thread.
        let auto = PinPolicy::Auto.slice_for(0, 1).expect("auto always pins");
        assert_eq!(auto, CoreSet::all(available_threads()));
        // Default is no pinning.
        assert_eq!(PinPolicy::default(), PinPolicy::None);
        assert_eq!(PinPolicy::Cores(CoreSet::from_cores(&[])).slice_for(0, 2), None);
    }

    /// `set_pinning` swaps a multi-threaded native backend onto a pool
    /// pinned to the slice — and must not change a single byte of the
    /// results.
    #[test]
    fn set_pinning_installs_pinned_pool_without_changing_results() {
        let x = Tensor::randn(&[2, 1, 28, 28], 21);
        let mut plain = NativeBackend::new(
            "plain",
            simple_cnn(10, 1),
            ExecCtx::with_threads(ConvAlgo::Sliding, 2),
        );
        let baseline = plain.infer(&x).unwrap();
        let mut pinned = NativeBackend::new(
            "pinned",
            simple_cnn(10, 1),
            ExecCtx::with_threads(ConvAlgo::Sliding, 2),
        );
        let slice = CoreSet::all(available_threads());
        pinned.set_pinning(&slice);
        // Under global pool disablement set_pinning leaves the ctx
        // unpooled; whenever it *did* install a pool, it must be the
        // slice-pinned one.
        if let Some(p) = pinned.ctx().pool_handle() {
            assert_eq!(p.cores(), Some(&slice), "installed pool must pin to the slice");
            assert_eq!(p.workers(), 1, "threads - 1 pinned workers");
        }
        let y = pinned.infer(&x).unwrap();
        assert_eq!(baseline.as_slice(), y.as_slice());
        // Single-threaded ctx: nothing to pool, still a no-op result-wise.
        let mut one = NativeBackend::new("one", simple_cnn(10, 1), ExecCtx::new(ConvAlgo::Sliding));
        one.set_pinning(&slice);
        assert!(one.ctx().pool_handle().is_none());
        assert_eq!(one.infer(&x).unwrap().as_slice(), baseline.as_slice());
    }

    /// The backend serves a compiled plan — bit-identical to the
    /// layer-by-layer forward — and the shared-plan constructor lets a
    /// tier's replicas serve one plan object.
    #[test]
    fn backend_serves_the_compiled_plan_bitwise() {
        let m = simple_cnn(10, 1);
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let x = Tensor::randn(&[2, 1, 28, 28], 30);
        let want = m.forward(&x, &ctx);
        let mut b = NativeBackend::new("p", m.clone(), ctx.clone());
        assert_eq!(b.infer(&x).unwrap().as_slice(), want.as_slice());
        let plan = Arc::new(m.compile_with(true));
        assert_eq!(plan.summary.fused_relu, 2, "both conv ReLUs fuse");
        let mut r0 = NativeBackend::with_plan("r0", m.clone(), Arc::clone(&plan), ctx.clone());
        let mut r1 = NativeBackend::with_plan("r1", m.clone(), Arc::clone(&plan), ctx.clone());
        assert!(Arc::ptr_eq(r0.plan(), r1.plan()), "replicas share one plan");
        assert_eq!(r0.infer(&x).unwrap().as_slice(), want.as_slice());
        assert_eq!(r1.infer(&x).unwrap().as_slice(), want.as_slice());
    }

    /// A planner-driven tier serves the planned plan bit-identically to
    /// an unplanned native tier, and replicas share the one planned
    /// plan object the way they share weights.
    #[test]
    fn native_planned_replicas_match_unplanned_bitwise() {
        let x = Tensor::randn(&[3, 1, 28, 28], 17);
        let mut plain = NativeBackend::new(
            "plain",
            simple_cnn(10, 1),
            ExecCtx::with_threads(ConvAlgo::Sliding, 2),
        );
        let want = plain.infer(&x).unwrap();
        let spec = BackendSpec::native_planned(
            "planned",
            simple_cnn(10, 1),
            ExecCtx::with_threads(ConvAlgo::Sliding, 2),
            1,
            None,
        )
        .expect("unbudgeted planning always succeeds");
        let mut r0 = spec.factory.as_ref()(0).unwrap();
        let mut r1 = spec.factory.as_ref()(1).unwrap();
        assert_eq!(r0.infer(&x).unwrap().as_slice(), want.as_slice());
        assert_eq!(r1.infer(&x).unwrap().as_slice(), want.as_slice());
    }

    /// An infeasible memory budget is a constructor-time error — the
    /// tier refuses to exist rather than silently serving over budget.
    #[test]
    fn native_planned_rejects_infeasible_budgets() {
        let err = BackendSpec::native_planned(
            "squeezed",
            simple_cnn(10, 1),
            ExecCtx::new(ConvAlgo::Sliding),
            1,
            Some(1),
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("no feasible plan"), "got: {msg}");
        assert!(msg.contains("squeezed"), "names the tier: {msg}");
    }

    #[test]
    fn native_factory_is_repeatable_and_replicas_agree() {
        let spec = BackendSpec::native(
            "sliding",
            simple_cnn(10, 1),
            ExecCtx::new(ConvAlgo::Sliding),
        );
        let mut r0 = spec.factory.as_ref()(0).unwrap();
        let mut r1 = spec.factory.as_ref()(1).unwrap();
        let x = Tensor::randn(&[2, 1, 28, 28], 9);
        let a = r0.infer(&x).unwrap();
        let b = r1.infer(&x).unwrap();
        assert_eq!(a.as_slice(), b.as_slice(), "replicas share weights");
    }
}
