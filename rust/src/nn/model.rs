//! The sequential model executor with shape/FLOP introspection, and the
//! entry point into the graph compiler ([`Model::compile`]).

use super::layers::{ExecCtx, Layer};
use crate::graph::{optimize, CompiledPlan, Graph, Op, PassSummary};
use crate::tensor::Tensor;
use std::sync::Arc;

/// A sequential stack of layers with a name and a fixed input shape
/// (batch dimension excluded — models accept any batch size).
///
/// Layers are immutable once pushed and held behind `Arc`, so cloning a
/// model is cheap and the clones *share* weights — the coordinator's
/// backend replicas all serve one copy of the parameters while keeping
/// their own scratch state in their [`ExecCtx`].
#[derive(Clone)]
pub struct Model {
    /// Model name (used by the CLI, the manifest and reports).
    pub name: String,
    /// Input shape `[c, h, w]` (no batch).
    pub input_shape: Vec<usize>,
    layers: Vec<Arc<dyn Layer>>,
}

impl Model {
    /// Empty model.
    pub fn new(name: impl Into<String>, input_shape: &[usize]) -> Self {
        Model { name: name.into(), input_shape: input_shape.to_vec(), layers: Vec::new() }
    }

    /// Append a layer (builder style).
    pub fn push(mut self, layer: impl Layer + 'static) -> Self {
        self.layers.push(Arc::new(layer));
        self
    }

    /// Number of layers.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// True when the model has no layers.
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Output shape for a batch of `n` inputs.
    ///
    /// # Panics
    /// If any layer rejects its input shape.
    pub fn out_shape(&self, n: usize) -> Vec<usize> {
        let mut shape: Vec<usize> =
            std::iter::once(n).chain(self.input_shape.iter().copied()).collect();
        for l in &self.layers {
            shape = l.out_shape(&shape);
        }
        shape
    }

    /// Total forward FLOPs for a batch of `n`.
    pub fn flops(&self, n: usize) -> u64 {
        let mut shape: Vec<usize> =
            std::iter::once(n).chain(self.input_shape.iter().copied()).collect();
        let mut total = 0u64;
        for l in &self.layers {
            total += l.flops(&shape);
            shape = l.out_shape(&shape);
        }
        total
    }

    /// Forward pass.
    ///
    /// # Panics
    /// If `x`'s trailing dims don't match `input_shape`.
    pub fn forward(&self, x: &Tensor, ctx: &ExecCtx) -> Tensor {
        assert_eq!(
            &x.dims()[1..],
            &self.input_shape[..],
            "model {} expects input {:?}",
            self.name,
            self.input_shape
        );
        // The first layer reads the caller's tensor directly — no
        // defensive clone of the input.
        let mut cur: Option<Tensor> = None;
        for l in &self.layers {
            cur = Some(l.forward(cur.as_ref().unwrap_or(x), ctx));
        }
        cur.unwrap_or_else(|| x.clone())
    }

    /// Lower the layer stack into the typed graph IR, un-optimized.
    /// Layers without a typed lowering become [`Op::Opaque`] nodes that
    /// still execute via their [`Layer::forward`].
    pub fn lower(&self) -> Graph {
        let mut g = Graph::new(self.name.clone(), &self.input_shape);
        let mut cur = 0;
        for l in &self.layers {
            cur = match l.lower_into(&mut g, cur) {
                Some(id) => id,
                None => g.add(Op::Opaque(Arc::clone(l)), vec![cur]),
            };
        }
        g.set_output(cur);
        g
    }

    /// Compile the model: lower into the graph IR and run the pass
    /// pipeline — unless `SWCONV_NO_FUSE` /
    /// [`crate::graph::set_fusion_disabled`] turned fusion off, in
    /// which case the plan reproduces the layer stack verbatim.
    pub fn compile(&self) -> CompiledPlan {
        self.compile_with(!crate::graph::fusion_disabled())
    }

    /// Compile with an explicit fusion choice (`fuse == false` skips
    /// every pass — the A/B baseline the parity tests and the fusion
    /// benchmark compare against).
    pub fn compile_with(&self, fuse: bool) -> CompiledPlan {
        let mut g = self.lower();
        let summary = if fuse { optimize(&mut g) } else { PassSummary::default() };
        let plan = CompiledPlan::new(g, summary);
        if crate::graph::plan_forced() {
            // `SWCONV_FORCE_PLAN` (CI's planned-routing leg): attach an
            // unbudgeted planner plan so every compiled model runs the
            // per-node planned kernels. Safe under every execution ctx:
            // int8 routes are exact, and the executor honours an f32
            // choice only inside the running ctx's bitwise family —
            // elsewhere the node degrades to the ctx route with just
            // the (value-safe) worker cap applied.
            let ctx = crate::exec::ExecCtx::auto(crate::kernels::ConvAlgo::Sliding);
            if let Ok(mp) = crate::graph::plan_model(&plan, 1, &ctx, None) {
                return plan.with_choices(mp.choices);
            }
        }
        plan
    }

    /// Per-layer summary table: description, output shape, FLOPs.
    pub fn summary(&self, n: usize) -> String {
        let mut shape: Vec<usize> =
            std::iter::once(n).chain(self.input_shape.iter().copied()).collect();
        let mut s = format!("{} (input {:?})\n", self.name, shape);
        let mut total = 0u64;
        for l in &self.layers {
            let f = l.flops(&shape);
            shape = l.out_shape(&shape);
            total += f;
            s.push_str(&format!("  {:<40} -> {:?} [{} FLOP]\n", l.describe(), shape, f));
        }
        s.push_str(&format!("  total: {total} FLOP\n"));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Conv2dParams, ConvAlgo, PoolParams};
    use crate::nn::layers::{Conv2d, Flatten, Linear, MaxPool2d, ReLU, Softmax};

    fn tiny() -> Model {
        Model::new("tiny", &[1, 8, 8])
            .push(Conv2d::new(1, 4, 3, Conv2dParams::same(3), 1))
            .push(ReLU)
            .push(MaxPool2d(PoolParams::square(2)))
            .push(Flatten)
            .push(Linear::new(4 * 4 * 4, 10, 2))
            .push(Softmax)
    }

    #[test]
    fn shapes_propagate() {
        let m = tiny();
        assert_eq!(m.out_shape(3), vec![3, 10]);
        assert_eq!(m.len(), 6);
    }

    #[test]
    fn forward_runs_and_normalises() {
        let m = tiny();
        let x = Tensor::randn(&[2, 1, 8, 8], 5);
        let y = m.forward(&x, &ExecCtx::default());
        assert_eq!(y.dims(), &[2, 10]);
        for r in 0..2 {
            let s: f32 = y.as_slice()[r * 10..(r + 1) * 10].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn algos_agree_end_to_end() {
        let m = tiny();
        let x = Tensor::randn(&[1, 1, 8, 8], 6);
        let a = m.forward(&x, &ExecCtx::new(ConvAlgo::Direct));
        let b = m.forward(&x, &ExecCtx::new(ConvAlgo::Im2colGemm));
        let c = m.forward(&x, &ExecCtx::new(ConvAlgo::Sliding));
        assert!(a.allclose(&b, 1e-4));
        assert!(a.allclose(&c, 1e-4));
    }

    #[test]
    fn clones_share_weights_and_agree_bitwise() {
        let m = tiny();
        let c = m.clone();
        let x = Tensor::randn(&[1, 1, 8, 8], 7);
        let a = m.forward(&x, &ExecCtx::default());
        let b = c.forward(&x, &ExecCtx::default());
        assert_eq!(a.as_slice(), b.as_slice());
        assert_eq!(c.len(), m.len());
    }

    #[test]
    fn flops_positive_and_additive() {
        let m = tiny();
        assert!(m.flops(1) > 0);
        assert_eq!(m.flops(2), 2 * m.flops(1));
    }

    #[test]
    #[should_panic(expected = "expects input")]
    fn forward_rejects_wrong_shape() {
        tiny().forward(&Tensor::zeros(&[1, 2, 8, 8]), &ExecCtx::default());
    }

    #[test]
    fn compiled_plan_matches_forward_bitwise() {
        let m = tiny();
        let x = Tensor::randn(&[2, 1, 8, 8], 8);
        for algo in [ConvAlgo::Direct, ConvAlgo::Im2colGemm, ConvAlgo::Sliding] {
            let ctx = ExecCtx::new(algo);
            let want = m.forward(&x, &ctx);
            let fused = m.compile_with(true).run(&x, &ctx);
            let plain = m.compile_with(false).run(&x, &ctx);
            assert_eq!(fused.as_slice(), want.as_slice(), "{algo:?} fused");
            assert_eq!(plain.as_slice(), want.as_slice(), "{algo:?} unfused");
        }
    }

    #[test]
    fn compile_fuses_the_tiny_models_relu() {
        let m = tiny();
        let plan = m.compile_with(true);
        assert_eq!(plan.summary.fused_relu, 1);
        // input + 6 layers, minus the fused ReLU node.
        assert_eq!(plan.graph.nodes.len(), 6);
        let unfused = m.compile_with(false);
        assert_eq!(unfused.graph.nodes.len(), 7);
        assert!(plan.activation_bytes(1) < unfused.activation_bytes(1));
    }

    #[test]
    fn summary_mentions_layers() {
        let s = tiny().summary(1);
        assert!(s.contains("Conv2d"));
        assert!(s.contains("total:"));
    }
}
