//! Layers. Each layer is a [`Layer`] trait object with forward execution,
//! shape inference and FLOP accounting (the roofline harness uses the
//! latter two without running anything).

use crate::graph::{Graph, NodeId, Op};
use crate::kernels::{
    avg_pool2d_ctx, conv2d_bf16_ctx, conv2d_ctx, conv2d_q8_epi_ctx, max_pool2d_ctx, Conv2dParams,
    PoolParams,
};
use crate::tensor::{
    pad2d, quantize, quantize_per_channel, Dtype, QuantParams, Tensor, TensorT, WeightScales,
};

// The execution context grew into its own subsystem (threads + scratch
// arena + optional dispatch profile); re-exported here so
// `nn::layers::ExecCtx` keeps working.
pub use crate::exec::ExecCtx;

/// A neural-network layer.
pub trait Layer: Send + Sync {
    /// Human-readable description (used in model summaries).
    fn describe(&self) -> String;
    /// Output shape for a given input shape.
    ///
    /// # Panics
    /// If the input shape is incompatible.
    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize>;
    /// Floating-point operations for one forward pass at this input shape
    /// (multiply and add counted separately, the usual convention).
    fn flops(&self, in_shape: &[usize]) -> u64;
    /// Run the layer.
    fn forward(&self, x: &Tensor, ctx: &ExecCtx) -> Tensor;
    /// Lower this layer into typed graph nodes consuming `input`,
    /// returning the output node — or `None` when the layer has no
    /// typed lowering, in which case [`crate::nn::Model::lower`] wraps
    /// it in an [`Op::Opaque`] node that the passes leave alone.
    fn lower_into(&self, g: &mut Graph, input: NodeId) -> Option<NodeId> {
        let _ = (g, input);
        None
    }
}

// ------------------------------------------------- shared forward bodies
//
// The layer `forward`s and the graph executor
// ([`crate::graph::CompiledPlan`]) must produce bit-identical results,
// so the op bodies with any numerical content live here as free
// functions both call.

/// Row-wise softmax over the last dimension, in place.
pub(crate) fn softmax_rows_inplace(x: &mut Tensor) {
    let cols = *x.dims().last().expect("softmax needs rank >= 1");
    for row in x.as_mut_slice().chunks_mut(cols) {
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        let inv = 1.0 / z;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Fully connected forward: `y = x · Wᵀ + b`, optional fused ReLU.
pub(crate) fn linear_forward(x: &Tensor, w: &Tensor, bias: &[f32], relu: bool) -> Tensor {
    let (n, d_in) = (x.dim(0), x.dim(1));
    let d_out = w.dim(0);
    assert_eq!(d_in, w.dim(1), "Linear dim mismatch");
    let mut out = Tensor::zeros(&[n, d_out]);
    let xs = x.as_slice();
    let ws = w.as_slice();
    for i in 0..n {
        let xrow = &xs[i * d_in..(i + 1) * d_in];
        let orow = &mut out.as_mut_slice()[i * d_out..(i + 1) * d_out];
        for (o, ov) in orow.iter_mut().enumerate() {
            let wrow = &ws[o * d_in..(o + 1) * d_in];
            let mut acc = bias[o];
            for (xv, wv) in xrow.iter().zip(wrow) {
                acc += xv * wv;
            }
            *ov = if relu { acc.max(0.0) } else { acc };
        }
    }
    out
}

/// Global average pooling body: `[n, c, h, w]` → `[n, c, 1, 1]`.
pub(crate) fn global_avg_pool(x: &Tensor) -> Tensor {
    let (n, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let inv = 1.0 / (h * w) as f32;
    let mut out = Tensor::zeros(&[n, c, 1, 1]);
    for ni in 0..n {
        for ci in 0..c {
            let s: f32 = x.plane(ni, ci).iter().sum();
            *out.at4_mut(ni, ci, 0, 0) = s * inv;
        }
    }
    out
}

/// Explicit zero padding of the two spatial dims (no slack).
pub(crate) fn zero_pad2d(x: &Tensor, ph: usize, pw: usize) -> Tensor {
    pad2d(x, ph, pw, 0, 0.0f32)
}

// ---------------------------------------------------------------- Conv2d

/// 2-D convolution layer. The per-request [`ExecCtx`] supplies
/// everything execution-related: the algorithm (GEMM / sliding /
/// tuned), the worker threads, the scratch arena, the element type
/// ([`ExecCtx::dtype`] — `Bf16` runs the bf16 sliding kernel on
/// storage-rounded operands, `I8` dynamically quantizes per call; both
/// keep f32 tensors at layer boundaries) and — when one is attached —
/// the measured dispatch profile. For a model that should carry
/// *pre-quantized* weights, see [`QuantizedConv2d`].
pub struct Conv2d {
    /// Weights `[c_out, c_in/groups, kh, kw]`.
    pub w: Tensor,
    /// Bias `[c_out]`.
    pub bias: Vec<f32>,
    /// Stride / padding / groups.
    pub params: Conv2dParams,
}

impl Conv2d {
    /// He-initialised convolution layer, deterministic in `seed`.
    pub fn new(
        c_in: usize,
        c_out: usize,
        k: usize,
        params: Conv2dParams,
        seed: u64,
    ) -> Self {
        let c_in_g = c_in / params.groups;
        let fan_in = (c_in_g * k * k) as f32;
        let scale = (2.0 / fan_in).sqrt();
        let w = Tensor::randn(&[c_out, c_in_g, k, k], seed).map(|v| v * scale);
        Conv2d { w, bias: vec![0.0; c_out], params }
    }
}

impl Layer for Conv2d {
    fn describe(&self) -> String {
        let d = self.w.dims();
        format!(
            "Conv2d {}x{}x{}x{} s{:?} p{:?} g{}",
            d[0], d[1], d[2], d[3], self.params.stride, self.params.pad, self.params.groups
        )
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        assert_eq!(in_shape.len(), 4, "Conv2d input must be NCHW");
        let (kh, kw) = (self.w.dim(2), self.w.dim(3));
        assert_eq!(
            in_shape[1],
            self.w.dim(1) * self.params.groups,
            "Conv2d channel mismatch"
        );
        let (oh, ow) = self.params.out_size(in_shape[2], in_shape[3], kh, kw);
        vec![in_shape[0], self.w.dim(0), oh, ow]
    }

    fn flops(&self, in_shape: &[usize]) -> u64 {
        let out = self.out_shape(in_shape);
        let taps = self.w.dim(1) * self.w.dim(2) * self.w.dim(3);
        // 2 FLOPs (mul+add) per tap per output element, plus the bias add.
        (out.iter().product::<usize>() * (2 * taps + 1)) as u64
    }

    fn forward(&self, x: &Tensor, ctx: &ExecCtx) -> Tensor {
        match ctx.dtype() {
            // The accumulator-only I32 tag never reaches a serving ctx;
            // treat it like the default.
            Dtype::F32 | Dtype::I32 => {
                conv2d_ctx(x, &self.w, Some(&self.bias), &self.params, ctx)
            }
            Dtype::Bf16 => conv2d_bf16_ctx(x, &self.w, Some(&self.bias), &self.params, ctx),
            Dtype::I8 => {
                // Dynamic quantization of the f32 weights per call —
                // honest but repeated work; QuantizedConv2d caches the
                // codes instead.
                let wq = QuantParams::for_tensor(&self.w);
                let qw = quantize(&self.w, wq);
                conv2d_q8_epi_ctx(
                    x,
                    &qw,
                    &WeightScales::PerTensor(wq),
                    Some(&self.bias),
                    false,
                    &self.params,
                    ctx,
                )
            }
        }
    }

    fn lower_into(&self, g: &mut Graph, input: NodeId) -> Option<NodeId> {
        Some(g.add(
            Op::Conv2d { w: self.w.clone(), bias: self.bias.clone(), params: self.params },
            vec![input],
        ))
    }
}

// ------------------------------------------------------ QuantizedConv2d

/// 2-D convolution with **pre-quantized int8 weights** — the
/// first-class quantized layer the paper's low-memory-devices argument
/// asks for.
///
/// Weights are quantized once at construction — **per output channel**
/// by default ([`quantize_per_channel`]: each `c_out` row of the filter
/// gets its own symmetric scale, so one large-magnitude channel no
/// longer flattens the resolution of the rest) — and stored as i8
/// codes, a 4× parameter memory saving over [`Conv2d`]. Each forward
/// pass dynamically quantizes the activations, runs the int8 kernel the
/// ctx's algorithm routes to (sliding by default, im2col+GEMM for
/// `Im2colGemm`, the dtype-aware profile winner for `Tuned`), and
/// dequantizes back to f32 with the per-channel scales — quantize/
/// dequantize live at the layer boundary, so this layer composes with
/// every f32 layer around it regardless of the ctx's [`Dtype`].
pub struct QuantizedConv2d {
    /// Weight codes `[c_out, c_in/groups, kh, kw]`.
    pub qw: TensorT<i8>,
    /// The weights' symmetric scales (per-channel by default; per-tensor
    /// via [`QuantizedConv2d::from_conv2d_per_tensor`]).
    pub wq: WeightScales,
    /// Bias `[c_out]`, kept in f32 (added after dequantization).
    pub bias: Vec<f32>,
    /// Stride / padding / groups.
    pub params: Conv2dParams,
}

impl QuantizedConv2d {
    /// Quantize an existing f32 convolution layer's weights (the
    /// post-training-quantization path), one symmetric scale per
    /// output channel.
    pub fn from_conv2d(conv: &Conv2d) -> Self {
        let (qw, wq) = quantize_per_channel(&conv.w);
        QuantizedConv2d { qw, wq, bias: conv.bias.clone(), params: conv.params }
    }

    /// Per-tensor variant of [`QuantizedConv2d::from_conv2d`] — a
    /// single scale for the whole filter bank. Kept as the accuracy
    /// baseline the per-channel parity tests compare against.
    pub fn from_conv2d_per_tensor(conv: &Conv2d) -> Self {
        let wq = QuantParams::for_tensor(&conv.w);
        QuantizedConv2d {
            qw: quantize(&conv.w, wq),
            wq: WeightScales::PerTensor(wq),
            bias: conv.bias.clone(),
            params: conv.params,
        }
    }

    /// He-initialised quantized layer, deterministic in `seed`
    /// ([`Conv2d::new`] then weight quantization).
    pub fn new(c_in: usize, c_out: usize, k: usize, params: Conv2dParams, seed: u64) -> Self {
        Self::from_conv2d(&Conv2d::new(c_in, c_out, k, params, seed))
    }
}

impl Layer for QuantizedConv2d {
    fn describe(&self) -> String {
        let d = self.qw.dims();
        format!(
            "QuantizedConv2d(i8) {}x{}x{}x{} s{:?} p{:?} g{}",
            d[0], d[1], d[2], d[3], self.params.stride, self.params.pad, self.params.groups
        )
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        assert_eq!(in_shape.len(), 4, "QuantizedConv2d input must be NCHW");
        let (kh, kw) = (self.qw.dim(2), self.qw.dim(3));
        assert_eq!(
            in_shape[1],
            self.qw.dim(1) * self.params.groups,
            "QuantizedConv2d channel mismatch"
        );
        let (oh, ow) = self.params.out_size(in_shape[2], in_shape[3], kh, kw);
        vec![in_shape[0], self.qw.dim(0), oh, ow]
    }

    fn flops(&self, in_shape: &[usize]) -> u64 {
        // Integer MACs counted like FLOPs (the roofline comparisons
        // stay apples-to-apples across dtypes).
        let out = self.out_shape(in_shape);
        let taps = self.qw.dim(1) * self.qw.dim(2) * self.qw.dim(3);
        (out.iter().product::<usize>() * (2 * taps + 1)) as u64
    }

    fn forward(&self, x: &Tensor, ctx: &ExecCtx) -> Tensor {
        conv2d_q8_epi_ctx(x, &self.qw, &self.wq, Some(&self.bias), false, &self.params, ctx)
    }

    fn lower_into(&self, g: &mut Graph, input: NodeId) -> Option<NodeId> {
        Some(g.add(
            Op::QuantConv2d {
                qw: self.qw.clone(),
                wq: self.wq.clone(),
                bias: self.bias.clone(),
                params: self.params,
            },
            vec![input],
        ))
    }
}

// --------------------------------------------------------------- Pooling

/// Max-pooling layer (sliding-window kernel).
pub struct MaxPool2d(pub PoolParams);

impl Layer for MaxPool2d {
    fn describe(&self) -> String {
        format!("MaxPool2d k{:?} s{:?}", self.0.k, self.0.stride)
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        let (oh, ow) = self.0.out_size(in_shape[2], in_shape[3]);
        vec![in_shape[0], in_shape[1], oh, ow]
    }

    fn flops(&self, in_shape: &[usize]) -> u64 {
        let out = self.out_shape(in_shape);
        (out.iter().product::<usize>() * (self.0.k.0 * self.0.k.1 - 1)) as u64
    }

    fn forward(&self, x: &Tensor, ctx: &ExecCtx) -> Tensor {
        max_pool2d_ctx(x, &self.0, ctx)
    }

    fn lower_into(&self, g: &mut Graph, input: NodeId) -> Option<NodeId> {
        Some(g.add(Op::MaxPool2d(self.0), vec![input]))
    }
}

/// Average-pooling layer (sliding-window sum kernel).
pub struct AvgPool2d(pub PoolParams);

impl Layer for AvgPool2d {
    fn describe(&self) -> String {
        format!("AvgPool2d k{:?} s{:?}", self.0.k, self.0.stride)
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        let (oh, ow) = self.0.out_size(in_shape[2], in_shape[3]);
        vec![in_shape[0], in_shape[1], oh, ow]
    }

    fn flops(&self, in_shape: &[usize]) -> u64 {
        let out = self.out_shape(in_shape);
        (out.iter().product::<usize>() * (self.0.k.0 * self.0.k.1)) as u64
    }

    fn forward(&self, x: &Tensor, ctx: &ExecCtx) -> Tensor {
        avg_pool2d_ctx(x, &self.0, ctx)
    }

    fn lower_into(&self, g: &mut Graph, input: NodeId) -> Option<NodeId> {
        Some(g.add(Op::AvgPool2d(self.0), vec![input]))
    }
}

/// Global average pooling: collapses H×W to 1×1.
pub struct GlobalAvgPool;

impl Layer for GlobalAvgPool {
    fn describe(&self) -> String {
        "GlobalAvgPool".into()
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        vec![in_shape[0], in_shape[1], 1, 1]
    }

    fn flops(&self, in_shape: &[usize]) -> u64 {
        in_shape.iter().product::<usize>() as u64
    }

    fn forward(&self, x: &Tensor, _ctx: &ExecCtx) -> Tensor {
        global_avg_pool(x)
    }

    fn lower_into(&self, g: &mut Graph, input: NodeId) -> Option<NodeId> {
        Some(g.add(Op::GlobalAvgPool, vec![input]))
    }
}

// ----------------------------------------------------------- Activations

/// Rectified linear unit.
pub struct ReLU;

impl Layer for ReLU {
    fn describe(&self) -> String {
        "ReLU".into()
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        in_shape.to_vec()
    }

    fn flops(&self, in_shape: &[usize]) -> u64 {
        in_shape.iter().product::<usize>() as u64
    }

    fn forward(&self, x: &Tensor, _ctx: &ExecCtx) -> Tensor {
        x.map(|v| v.max(0.0))
    }

    fn lower_into(&self, g: &mut Graph, input: NodeId) -> Option<NodeId> {
        Some(g.add(Op::Relu, vec![input]))
    }
}

/// Row-wise softmax over the last dimension.
pub struct Softmax;

impl Layer for Softmax {
    fn describe(&self) -> String {
        "Softmax".into()
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        in_shape.to_vec()
    }

    fn flops(&self, in_shape: &[usize]) -> u64 {
        (3 * in_shape.iter().product::<usize>()) as u64
    }

    fn forward(&self, x: &Tensor, _ctx: &ExecCtx) -> Tensor {
        let mut out = x.clone();
        softmax_rows_inplace(&mut out);
        out
    }

    fn lower_into(&self, g: &mut Graph, input: NodeId) -> Option<NodeId> {
        Some(g.add(Op::Softmax, vec![input]))
    }
}

// ------------------------------------------------------- Shape plumbing

/// Flatten `[n, …]` to `[n, prod(rest)]`.
pub struct Flatten;

impl Layer for Flatten {
    fn describe(&self) -> String {
        "Flatten".into()
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        vec![in_shape[0], in_shape[1..].iter().product()]
    }

    fn flops(&self, _in_shape: &[usize]) -> u64 {
        0
    }

    fn forward(&self, x: &Tensor, _ctx: &ExecCtx) -> Tensor {
        let shape = self.out_shape(x.dims());
        x.clone().reshape(&shape)
    }

    fn lower_into(&self, g: &mut Graph, input: NodeId) -> Option<NodeId> {
        Some(g.add(Op::Flatten, vec![input]))
    }
}

// ---------------------------------------------------------------- Linear

/// Fully connected layer: `y = x · Wᵀ + b` for `x [n, in]`, `W [out, in]`.
pub struct Linear {
    /// Weights `[out, in]`.
    pub w: Tensor,
    /// Bias `[out]`.
    pub bias: Vec<f32>,
}

impl Linear {
    /// He-initialised linear layer, deterministic in `seed`.
    pub fn new(d_in: usize, d_out: usize, seed: u64) -> Self {
        let scale = (2.0 / d_in as f32).sqrt();
        Linear {
            w: Tensor::randn(&[d_out, d_in], seed).map(|v| v * scale),
            bias: vec![0.0; d_out],
        }
    }
}

impl Layer for Linear {
    fn describe(&self) -> String {
        format!("Linear {}x{}", self.w.dim(0), self.w.dim(1))
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        assert_eq!(in_shape.len(), 2, "Linear input must be [n, d]");
        assert_eq!(in_shape[1], self.w.dim(1), "Linear dim mismatch");
        vec![in_shape[0], self.w.dim(0)]
    }

    fn flops(&self, in_shape: &[usize]) -> u64 {
        (in_shape[0] * self.w.dim(0) * (2 * self.w.dim(1) + 1)) as u64
    }

    fn forward(&self, x: &Tensor, _ctx: &ExecCtx) -> Tensor {
        linear_forward(x, &self.w, &self.bias, false)
    }

    fn lower_into(&self, g: &mut Graph, input: NodeId) -> Option<NodeId> {
        Some(g.add(Op::Linear { w: self.w.clone(), bias: self.bias.clone() }, vec![input]))
    }
}

// ----------------------------------------------------------------- Pad2d

/// Explicit zero padding of the spatial dims — the layer the pad-elision
/// pass exists to absorb: a compiled plan feeds the padding amounts into
/// the consuming convolution's own edge handling instead of
/// materialising the padded copy.
pub struct Pad2d {
    /// Rows added on top and bottom.
    pub ph: usize,
    /// Columns added left and right.
    pub pw: usize,
}

impl Layer for Pad2d {
    fn describe(&self) -> String {
        format!("Pad2d p({}, {})", self.ph, self.pw)
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        assert_eq!(in_shape.len(), 4, "Pad2d input must be NCHW");
        vec![
            in_shape[0],
            in_shape[1],
            in_shape[2] + 2 * self.ph,
            in_shape[3] + 2 * self.pw,
        ]
    }

    fn flops(&self, _in_shape: &[usize]) -> u64 {
        0
    }

    fn forward(&self, x: &Tensor, _ctx: &ExecCtx) -> Tensor {
        zero_pad2d(x, self.ph, self.pw)
    }

    fn lower_into(&self, g: &mut Graph, input: NodeId) -> Option<NodeId> {
        Some(g.add(Op::Pad2d { ph: self.ph, pw: self.pw }, vec![input]))
    }
}

// ------------------------------------------------------------------ Fire

/// SqueezeNet *fire module*: 1×1 squeeze → (1×1 expand ‖ 3×3 expand),
/// channel-concatenated, ReLU between stages.
pub struct Fire {
    squeeze: Conv2d,
    expand1: Conv2d,
    expand3: Conv2d,
}

impl Fire {
    /// `c_in → s` squeeze, then `s → e1` (1×1) and `s → e3` (3×3) expands;
    /// output has `e1 + e3` channels at the input's spatial size.
    pub fn new(c_in: usize, s: usize, e1: usize, e3: usize, seed: u64) -> Self {
        Fire {
            squeeze: Conv2d::new(c_in, s, 1, Conv2dParams::default(), seed),
            expand1: Conv2d::new(s, e1, 1, Conv2dParams::default(), seed + 1),
            expand3: Conv2d::new(s, e3, 3, Conv2dParams::same(3), seed + 2),
        }
    }
}

impl Layer for Fire {
    fn describe(&self) -> String {
        format!(
            "Fire s{} e1:{} e3:{}",
            self.squeeze.w.dim(0),
            self.expand1.w.dim(0),
            self.expand3.w.dim(0)
        )
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        let s = self.squeeze.out_shape(in_shape);
        let e1 = self.expand1.out_shape(&s);
        let e3 = self.expand3.out_shape(&s);
        assert_eq!(e1[2..], e3[2..], "fire expand spatial mismatch");
        vec![e1[0], e1[1] + e3[1], e1[2], e1[3]]
    }

    fn flops(&self, in_shape: &[usize]) -> u64 {
        let s = self.squeeze.out_shape(in_shape);
        self.squeeze.flops(in_shape)
            + self.expand1.flops(&s)
            + self.expand3.flops(&s)
            + 2 * s.iter().product::<usize>() as u64 // ReLUs
    }

    fn forward(&self, x: &Tensor, ctx: &ExecCtx) -> Tensor {
        let s = self.squeeze.forward(x, ctx).map(|v| v.max(0.0));
        let a = self.expand1.forward(&s, ctx);
        let b = self.expand3.forward(&s, ctx);
        concat_channels(&a, &b).map(|v| v.max(0.0))
    }

    fn lower_into(&self, g: &mut Graph, input: NodeId) -> Option<NodeId> {
        // Mirrors `forward` op for op; the fusion pass then folds the
        // two ReLUs into the convolutions' epilogues.
        let s = self.squeeze.lower_into(g, input)?;
        let sr = g.add(Op::Relu, vec![s]);
        let a = self.expand1.lower_into(g, sr)?;
        let b = self.expand3.lower_into(g, sr)?;
        let cat = g.add(Op::Concat, vec![a, b]);
        Some(g.add(Op::Relu, vec![cat]))
    }
}

/// Concatenate two NCHW tensors along channels.
pub fn concat_channels(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.dim(0), b.dim(0), "batch mismatch");
    assert_eq!(a.dims()[2..], b.dims()[2..], "spatial mismatch");
    let (n, ca, cb) = (a.dim(0), a.dim(1), b.dim(1));
    let (h, w) = (a.dim(2), a.dim(3));
    let mut out = Tensor::zeros(&[n, ca + cb, h, w]);
    for ni in 0..n {
        for ci in 0..ca {
            out.plane_mut(ni, ci).copy_from_slice(a.plane(ni, ci));
        }
        for ci in 0..cb {
            out.plane_mut(ni, ca + ci).copy_from_slice(b.plane(ni, ci));
        }
    }
    out
}

// --------------------------------------------- Depthwise separable block

/// MobileNet block: depthwise 3×3 (groups = channels) + pointwise 1×1,
/// ReLU after each.
pub struct DepthwiseSeparable {
    dw: Conv2d,
    pw: Conv2d,
}

impl DepthwiseSeparable {
    /// `c_in` channels depthwise (stride `s`), then pointwise to `c_out`.
    pub fn new(c_in: usize, c_out: usize, stride: usize, seed: u64) -> Self {
        let dw_params = Conv2dParams { stride: (stride, stride), pad: (1, 1), groups: c_in };
        DepthwiseSeparable {
            dw: Conv2d::new(c_in, c_in, 3, dw_params, seed),
            pw: Conv2d::new(c_in, c_out, 1, Conv2dParams::default(), seed + 1),
        }
    }
}

impl Layer for DepthwiseSeparable {
    fn describe(&self) -> String {
        format!(
            "DwSep {}→{} s{}",
            self.dw.w.dim(0),
            self.pw.w.dim(0),
            self.dw.params.stride.0
        )
    }

    fn out_shape(&self, in_shape: &[usize]) -> Vec<usize> {
        self.pw.out_shape(&self.dw.out_shape(in_shape))
    }

    fn flops(&self, in_shape: &[usize]) -> u64 {
        let mid = self.dw.out_shape(in_shape);
        self.dw.flops(in_shape)
            + self.pw.flops(&mid)
            + (mid.iter().product::<usize>() + self.out_shape(in_shape).iter().product::<usize>())
                as u64
    }

    fn forward(&self, x: &Tensor, ctx: &ExecCtx) -> Tensor {
        let mid = self.dw.forward(x, ctx).map(|v| v.max(0.0));
        self.pw.forward(&mid, ctx).map(|v| v.max(0.0))
    }

    fn lower_into(&self, g: &mut Graph, input: NodeId) -> Option<NodeId> {
        let d = self.dw.lower_into(g, input)?;
        let dr = g.add(Op::Relu, vec![d]);
        let p = self.pw.lower_into(g, dr)?;
        Some(g.add(Op::Relu, vec![p]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ConvAlgo;

    #[test]
    fn conv2d_layer_shapes_and_flops() {
        let l = Conv2d::new(3, 8, 5, Conv2dParams::same(5), 1);
        assert_eq!(l.out_shape(&[2, 3, 16, 16]), vec![2, 8, 16, 16]);
        // 2*3*5*5+1 = 151 flops per output element
        assert_eq!(l.flops(&[1, 3, 16, 16]), (8 * 16 * 16 * 151) as u64);
    }

    #[test]
    #[should_panic(expected = "channel mismatch")]
    fn conv2d_layer_rejects_bad_channels() {
        let l = Conv2d::new(3, 8, 3, Conv2dParams::default(), 1);
        l.out_shape(&[1, 4, 8, 8]);
    }

    #[test]
    fn relu_clamps() {
        let x = Tensor::from_vec(vec![-1.0, 2.0], &[1, 2]);
        let y = ReLU.forward(&x, &ExecCtx::default());
        assert_eq!(y.as_slice(), &[0.0, 2.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = Tensor::randn(&[3, 7], 2);
        let y = Softmax.forward(&x, &ExecCtx::default());
        for r in 0..3 {
            let s: f32 = y.as_slice()[r * 7..(r + 1) * 7].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(y.as_slice()[r * 7..(r + 1) * 7].iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn flatten_shape() {
        let x = Tensor::iota(&[2, 3, 4, 5]);
        let y = Flatten.forward(&x, &ExecCtx::default());
        assert_eq!(y.dims(), &[2, 60]);
        assert_eq!(y.as_slice()[59], 59.0);
    }

    #[test]
    fn linear_matches_manual() {
        let mut l = Linear::new(2, 2, 3);
        l.w = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        l.bias = vec![0.5, -0.5];
        let x = Tensor::from_vec(vec![1.0, 1.0], &[1, 2]);
        let y = l.forward(&x, &ExecCtx::default());
        assert_eq!(y.as_slice(), &[3.5, 6.5]);
    }

    #[test]
    fn global_avg_pool_values() {
        let x = Tensor::iota(&[1, 2, 2, 2]);
        let y = GlobalAvgPool.forward(&x, &ExecCtx::default());
        assert_eq!(y.dims(), &[1, 2, 1, 1]);
        assert_eq!(y.as_slice(), &[1.5, 5.5]);
    }

    #[test]
    fn concat_channels_layout() {
        let a = Tensor::full(&[1, 1, 2, 2], 1.0);
        let b = Tensor::full(&[1, 2, 2, 2], 2.0);
        let c = concat_channels(&a, &b);
        assert_eq!(c.dims(), &[1, 3, 2, 2]);
        assert_eq!(c.plane(0, 0), &[1.0; 4]);
        assert_eq!(c.plane(0, 2), &[2.0; 4]);
    }

    #[test]
    fn conv2d_dtype_knob_keeps_f32_boundaries() {
        let l = Conv2d::new(2, 3, 3, Conv2dParams::same(3), 21);
        let x = Tensor::randn(&[1, 2, 10, 10], 22);
        let f = l.forward(&x, &ExecCtx::default());
        // f32 ctx: bit-identical to calling the kernel directly.
        assert_eq!(
            f.as_slice(),
            conv2d_ctx(&x, &l.w, Some(&l.bias), &l.params, &ExecCtx::default()).as_slice()
        );
        // bf16/i8 ctxs: same shape, close values, f32 tensors out.
        for d in [Dtype::Bf16, Dtype::I8] {
            let y = l.forward(&x, &ExecCtx::default().with_dtype(d));
            assert_eq!(y.dims(), f.dims());
            let diff = y.max_abs_diff(&f);
            assert!(diff < 0.25, "{d:?}: diff {diff}");
            assert!(diff > 0.0, "{d:?}: reduced precision should differ somewhere");
        }
    }

    #[test]
    fn quantized_conv2d_tracks_its_f32_source() {
        let conv = Conv2d::new(3, 4, 5, Conv2dParams::same(5), 31);
        let q = QuantizedConv2d::from_conv2d(&conv);
        assert_eq!(q.out_shape(&[1, 3, 12, 12]), conv.out_shape(&[1, 3, 12, 12]));
        assert_eq!(q.flops(&[1, 3, 12, 12]), conv.flops(&[1, 3, 12, 12]));
        assert!(q.describe().contains("i8"));
        let x = Tensor::randn(&[1, 3, 12, 12], 32);
        let yf = conv.forward(&x, &ExecCtx::default());
        // Sliding and GEMM int8 routes agree exactly (shared dequant of
        // a bit-identical accumulator) and track the f32 layer.
        let ys = q.forward(&x, &ExecCtx::new(ConvAlgo::Sliding));
        let yg = q.forward(&x, &ExecCtx::new(ConvAlgo::Im2colGemm));
        assert_eq!(ys.as_slice(), yg.as_slice());
        assert!(ys.max_abs_diff(&yf) < 0.25, "diff {}", ys.max_abs_diff(&yf));
    }

    #[test]
    fn fire_shape_and_consistency_across_algos() {
        let f = Fire::new(8, 4, 6, 6, 9);
        let x = Tensor::randn(&[1, 8, 7, 7], 10);
        assert_eq!(f.out_shape(x.dims()), vec![1, 12, 7, 7]);
        let g = f.forward(&x, &ExecCtx::new(ConvAlgo::Im2colGemm));
        let s = f.forward(&x, &ExecCtx::new(ConvAlgo::Sliding));
        assert!(g.allclose(&s, 1e-4), "diff {}", g.max_abs_diff(&s));
    }

    #[test]
    fn pad2d_layer_shape_and_values() {
        let l = Pad2d { ph: 1, pw: 2 };
        assert_eq!(l.out_shape(&[1, 2, 3, 3]), vec![1, 2, 5, 7]);
        let x = Tensor::full(&[1, 1, 2, 2], 3.0);
        let mut y = l.forward(&x, &ExecCtx::default());
        assert_eq!(y.dims(), &[1, 1, 4, 6]);
        let s: f32 = y.as_slice().iter().sum();
        assert_eq!(s, 12.0); // the four 3.0s survive, the rest is zero
        assert_eq!(y.as_slice()[0], 0.0);
        assert_eq!(*y.at4_mut(0, 0, 1, 2), 3.0);
    }

    #[test]
    fn pad2d_then_unpadded_conv_matches_padded_conv() {
        // The identity pad elision relies on: conv(pad2d(x), pad=0) ==
        // conv(x, pad=1), exactly, per algorithm.
        let conv1 = Conv2d::new(2, 3, 3, Conv2dParams::same(3), 41);
        let mut conv0 = Conv2d::new(2, 3, 3, Conv2dParams::default(), 41);
        conv0.w = conv1.w.clone();
        conv0.bias = conv1.bias.clone();
        let x = Tensor::randn(&[1, 2, 9, 9], 42);
        let padded = Pad2d { ph: 1, pw: 1 }.forward(&x, &ExecCtx::default());
        for algo in [ConvAlgo::Direct, ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            let ctx = ExecCtx::new(algo);
            let a = conv1.forward(&x, &ctx);
            let b = conv0.forward(&padded, &ctx);
            assert_eq!(a.as_slice(), b.as_slice(), "{algo:?}");
        }
    }

    #[test]
    fn per_channel_scales_beat_per_tensor_on_skewed_weights() {
        // One outlier output channel: a shared scale crushes the other
        // channels' resolution, per-channel scales do not.
        let mut conv = Conv2d::new(2, 3, 3, Conv2dParams::same(3), 51);
        let c_stride = conv.w.numel() / 3;
        for v in &mut conv.w.as_mut_slice()[2 * c_stride..] {
            *v *= 60.0;
        }
        let x = Tensor::randn(&[1, 2, 8, 8], 52);
        let f = conv.forward(&x, &ExecCtx::default());
        let qc = QuantizedConv2d::from_conv2d(&conv);
        let qt = QuantizedConv2d::from_conv2d_per_tensor(&conv);
        assert!(matches!(qc.wq, WeightScales::PerChannel(_)));
        let ec = qc.forward(&x, &ExecCtx::default()).max_abs_diff(&f);
        let et = qt.forward(&x, &ExecCtx::default()).max_abs_diff(&f);
        assert!(ec < et, "per-channel err {ec} should beat per-tensor {et}");
        assert!(ec < 0.25, "per-channel err {ec}");
    }

    #[test]
    fn depthwise_separable_shapes() {
        let l = DepthwiseSeparable::new(8, 16, 2, 11);
        assert_eq!(l.out_shape(&[1, 8, 8, 8]), vec![1, 16, 4, 4]);
        let x = Tensor::randn(&[1, 8, 8, 8], 12);
        let g = l.forward(&x, &ExecCtx::new(ConvAlgo::Im2colGemm));
        let s = l.forward(&x, &ExecCtx::new(ConvAlgo::Sliding));
        assert!(g.allclose(&s, 1e-4));
    }
}
