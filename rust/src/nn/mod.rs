//! A small neural-network layer library and model zoo.
//!
//! The paper's primitives don't live in isolation — §1.2 discusses the
//! network families (SqueezeNet, MobileNet, ShuffleNet) whose filter-size
//! choices interact with the Sliding Window advantage. This module lets us
//! run those interactions end-to-end: every [`layers::Conv2d`] takes its
//! algorithm — and its persistent worker pool and scratch arena, see
//! [`crate::exec`] — from the per-request [`ExecCtx`], so the same model
//! can be served with GEMM or Sliding Window backends (single- or
//! multi-core) and compared on identical weights (the coordinator's
//! router does exactly that).
//!
//! * [`layers`] — Conv2d (dtype-aware: the ctx's
//!   [`crate::tensor::Dtype`] switches it to the bf16 or quantized int8
//!   kernels with f32 tensors kept at layer boundaries),
//!   QuantizedConv2d (pre-quantized int8 weights), pooling, ReLU,
//!   Linear, Softmax, Flatten, Fire (SqueezeNet), DepthwiseSeparable
//!   (MobileNet).
//! * [`model`] — the sequential executor with shape/FLOP introspection
//!   and [`Model::compile`], the entry point into [`crate::graph`]'s
//!   typed IR, pass pipeline and compiled-plan executor.
//! * [`zoo`] — SimpleCNN, SqueezeNet-lite, MobileNet-lite,
//!   LargeFilterNet, QuantizedCNN.

pub mod layers;
pub mod model;
pub mod zoo;

pub use layers::{ExecCtx, Layer};
pub use model::Model;
