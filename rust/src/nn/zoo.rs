//! Model zoo: the network families the paper's §1.2/§3 discussion turns
//! on, scaled to edge-device sizes (the paper's target hardware class).
//!
//! * [`simple_cnn`] — a plain LeNet-style CNN with k=5 filters.
//! * [`squeezenet_lite`] — fire modules (1×1-heavy: the regime where the
//!   Sliding Window advantage shrinks, per §3).
//! * [`mobilenet_lite`] — depthwise-separable blocks (depthwise 3×3 is
//!   the custom-kernel sweet spot; pointwise 1×1 is pure GEMM).
//! * [`large_filter_net`] — the architecture direction §3 *encourages*:
//!   "fewer layers with larger convolution filters", where the sliding
//!   kernels shine (k = 11/17/21 layers).
//! * [`quantized_cnn`] — pre-quantized int8 convolutions (per-channel
//!   weight scales) with an explicit pad layer: the model the graph
//!   compiler's pad-elision and quantize-boundary passes bite on.
//! * [`edge_audio`] — height-1 conv/pool chain over a mono sample
//!   stream: the streaming-inference workload (`stream` module/CLI).

use super::layers::{
    AvgPool2d, Conv2d, DepthwiseSeparable, Fire, Flatten, GlobalAvgPool, Linear, MaxPool2d, Pad2d,
    QuantizedConv2d, ReLU, Softmax,
};
use super::model::Model;
use crate::kernels::{Conv2dParams, PoolParams};
use crate::tensor::Tensor;

/// All zoo model names, as accepted by [`by_name`].
pub const MODEL_NAMES: [&str; 6] = [
    "simple-cnn",
    "squeezenet-lite",
    "mobilenet-lite",
    "large-filter-net",
    "quantized-cnn",
    "edge-audio",
];

/// Look a model up by CLI name (`classes` output classes, deterministic
/// weights from `seed`).
pub fn by_name(name: &str, classes: usize, seed: u64) -> Option<Model> {
    match name {
        "simple-cnn" => Some(simple_cnn(classes, seed)),
        "squeezenet-lite" => Some(squeezenet_lite(classes, seed)),
        "mobilenet-lite" => Some(mobilenet_lite(classes, seed)),
        "large-filter-net" => Some(large_filter_net(classes, seed)),
        "quantized-cnn" => Some(quantized_cnn(classes, seed)),
        "edge-audio" => Some(edge_audio(classes, seed)),
        _ => None,
    }
}

/// LeNet-style CNN with explicit weights (same topology as
/// [`simple_cnn`]). Used to serve the *identical* model that
/// `python/compile/aot.py` baked into the PJRT artifact.
pub fn simple_cnn_with_weights(conv1: Tensor, conv2: Tensor, fc: Tensor) -> Model {
    use crate::kernels::Conv2dParams;
    assert_eq!(conv1.dims(), &[16, 1, 5, 5], "conv1 shape");
    assert_eq!(conv2.dims(), &[32, 16, 5, 5], "conv2 shape");
    assert_eq!(fc.dim(1), 32 * 7 * 7, "fc fan-in");
    let classes = fc.dim(0);
    let c1 = Conv2d { w: conv1, bias: vec![0.0; 16], params: Conv2dParams::same(5) };
    let c2 = Conv2d { w: conv2, bias: vec![0.0; 32], params: Conv2dParams::same(5) };
    let lin = Linear { w: fc, bias: vec![0.0; classes] };
    Model::new("simple-cnn", &[1, 28, 28])
        .push(c1)
        .push(ReLU)
        .push(MaxPool2d(PoolParams::square(2)))
        .push(c2)
        .push(ReLU)
        .push(MaxPool2d(PoolParams::square(2)))
        .push(Flatten)
        .push(lin)
        .push(Softmax)
}

/// Load `simple_cnn_weights.bin` (written by `python/compile/aot.py`:
/// conv1 ‖ conv2 ‖ fc as little-endian f32) and build the model.
pub fn simple_cnn_from_weights_file(
    path: impl AsRef<std::path::Path>,
    classes: usize,
) -> std::io::Result<Model> {
    let bytes = std::fs::read(path)?;
    let n1 = 16 * 5 * 5;
    let n2 = 32 * 16 * 5 * 5;
    let n3 = classes * 32 * 7 * 7;
    let want = 4 * (n1 + n2 + n3);
    if bytes.len() != want {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("weights file is {} bytes, expected {want}", bytes.len()),
        ));
    }
    let floats: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let conv1 = Tensor::from_vec(floats[..n1].to_vec(), &[16, 1, 5, 5]);
    let conv2 = Tensor::from_vec(floats[n1..n1 + n2].to_vec(), &[32, 16, 5, 5]);
    let fc = Tensor::from_vec(floats[n1 + n2..].to_vec(), &[classes, 32 * 7 * 7]);
    Ok(simple_cnn_with_weights(conv1, conv2, fc))
}

/// LeNet-style CNN for 1×28×28 inputs (MNIST geometry).
pub fn simple_cnn(classes: usize, seed: u64) -> Model {
    Model::new("simple-cnn", &[1, 28, 28])
        .push(Conv2d::new(1, 16, 5, Conv2dParams::same(5), seed))
        .push(ReLU)
        .push(MaxPool2d(PoolParams::square(2)))
        .push(Conv2d::new(16, 32, 5, Conv2dParams::same(5), seed + 1))
        .push(ReLU)
        .push(MaxPool2d(PoolParams::square(2)))
        .push(Flatten)
        .push(Linear::new(32 * 7 * 7, classes, seed + 2))
        .push(Softmax)
}

/// SqueezeNet-lite for 3×64×64 inputs: conv5/2 → pool → 3 fire modules →
/// global pool → linear.
pub fn squeezenet_lite(classes: usize, seed: u64) -> Model {
    Model::new("squeezenet-lite", &[3, 64, 64])
        .push(Conv2d::new(
            3,
            32,
            5,
            Conv2dParams { stride: (2, 2), pad: (2, 2), groups: 1 },
            seed,
        ))
        .push(ReLU)
        .push(MaxPool2d(PoolParams::with_stride(3, 2)))
        .push(Fire::new(32, 16, 32, 32, seed + 1))
        .push(Fire::new(64, 16, 32, 32, seed + 4))
        .push(MaxPool2d(PoolParams::with_stride(3, 2)))
        .push(Fire::new(64, 32, 64, 64, seed + 7))
        .push(GlobalAvgPool)
        .push(Flatten)
        .push(Linear::new(128, classes, seed + 10))
        .push(Softmax)
}

/// MobileNet-lite for 3×64×64 inputs: conv3/2 + 4 depthwise-separable
/// blocks → global pool → linear.
pub fn mobilenet_lite(classes: usize, seed: u64) -> Model {
    Model::new("mobilenet-lite", &[3, 64, 64])
        .push(Conv2d::new(
            3,
            16,
            3,
            Conv2dParams { stride: (2, 2), pad: (1, 1), groups: 1 },
            seed,
        ))
        .push(ReLU)
        .push(DepthwiseSeparable::new(16, 32, 1, seed + 1))
        .push(DepthwiseSeparable::new(32, 64, 2, seed + 3))
        .push(DepthwiseSeparable::new(64, 64, 1, seed + 5))
        .push(DepthwiseSeparable::new(64, 128, 2, seed + 7))
        .push(GlobalAvgPool)
        .push(Flatten)
        .push(Linear::new(128, classes, seed + 9))
        .push(Softmax)
}

/// The §3 "future work" architecture: few layers, large filters
/// (k = 11, 17, 21) for 1×96×96 inputs — the Sliding Window sweet spot.
pub fn large_filter_net(classes: usize, seed: u64) -> Model {
    Model::new("large-filter-net", &[1, 96, 96])
        .push(Conv2d::new(1, 8, 11, Conv2dParams::same(11), seed))
        .push(ReLU)
        .push(MaxPool2d(PoolParams::square(2)))
        .push(Conv2d::new(8, 16, 17, Conv2dParams::same(17), seed + 1))
        .push(ReLU)
        .push(MaxPool2d(PoolParams::square(2)))
        .push(Conv2d::new(16, 16, 21, Conv2dParams::same(21), seed + 2))
        .push(ReLU)
        .push(AvgPool2d(PoolParams::square(3)))
        .push(Flatten)
        .push(Linear::new(16 * 8 * 8, classes, seed + 3))
        .push(Softmax)
}

/// Int8-weight CNN for 3×32×32 inputs — the model that exercises every
/// graph pass at once: an explicit [`Pad2d`] for the elision pass, a
/// back-to-back [`QuantizedConv2d`] pair for quantize-boundary
/// hoisting, and ReLUs after each conv for epilogue fusion.
pub fn quantized_cnn(classes: usize, seed: u64) -> Model {
    Model::new("quantized-cnn", &[3, 32, 32])
        .push(Pad2d { ph: 1, pw: 1 })
        .push(QuantizedConv2d::new(3, 8, 3, Conv2dParams::default(), seed))
        .push(ReLU)
        .push(QuantizedConv2d::new(8, 8, 3, Conv2dParams::same(3), seed + 1))
        .push(ReLU)
        .push(MaxPool2d(PoolParams::square(2)))
        .push(QuantizedConv2d::new(8, 16, 3, Conv2dParams::same(3), seed + 2))
        .push(ReLU)
        .push(GlobalAvgPool)
        .push(Flatten)
        .push(Linear::new(16, classes, seed + 3))
        .push(Softmax)
}

/// `edge-audio`: a 1-D (height-1) conv/ReLU/max-pool stack over a
/// 512-sample mono frame — the streaming workload
/// (`stream::StreamSession`, the `stream` CLI subcommand, the
/// `stream_latency` bench). Deliberately **avg-pool-free**: conv
/// windows and max have position-independent / order-free per-element
/// forms, so the int8 streamed path stays bit-exact against the batch
/// reference (avg-pool's running-sum recurrence reassociates f32 sums;
/// see `stream::session`). Weights are He-scaled so activations stay
/// O(1) down the chain. Output is a per-frame class logit track
/// `[classes, 1, 64]` (8× downsampled), not a softmax head — streaming
/// emits one logit column at a time.
pub fn edge_audio(classes: usize, seed: u64) -> Model {
    let conv = |c_out: usize, c_in: usize, k: usize, sd: u64| {
        let scale = (2.0 / (c_in * k) as f32).sqrt();
        Tensor::randn(&[c_out, c_in, 1, k], sd).map(|v| v * scale)
    };
    let bias = |n: usize, sd: u64| Tensor::rand_uniform(&[n], -0.1, 0.1, sd).into_vec();
    Model::new("edge-audio", &[1, 1, 512])
        .push(Conv2d {
            w: conv(8, 1, 9, seed),
            bias: bias(8, seed + 100),
            params: Conv2dParams { stride: (1, 1), pad: (0, 4), groups: 1 },
        })
        .push(ReLU)
        .push(MaxPool2d(PoolParams { k: (1, 2), stride: (1, 2), pad: (0, 0) }))
        .push(Conv2d {
            w: conv(16, 8, 5, seed + 1),
            bias: bias(16, seed + 101),
            params: Conv2dParams { stride: (1, 2), pad: (0, 2), groups: 1 },
        })
        .push(ReLU)
        .push(MaxPool2d(PoolParams { k: (1, 2), stride: (1, 2), pad: (0, 0) }))
        .push(Conv2d {
            w: conv(classes, 16, 3, seed + 2),
            bias: bias(classes, seed + 102),
            params: Conv2dParams { stride: (1, 1), pad: (0, 1), groups: 1 },
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ConvAlgo;
    use crate::nn::layers::ExecCtx;

    #[test]
    fn zoo_lookup() {
        for name in MODEL_NAMES {
            assert!(by_name(name, 10, 1).is_some(), "{name}");
        }
        assert!(by_name("resnet-152", 10, 1).is_none());
    }

    #[test]
    fn shapes_all_models() {
        assert_eq!(simple_cnn(10, 1).out_shape(2), vec![2, 10]);
        assert_eq!(squeezenet_lite(10, 1).out_shape(1), vec![1, 10]);
        assert_eq!(mobilenet_lite(5, 1).out_shape(3), vec![3, 5]);
        assert_eq!(large_filter_net(7, 1).out_shape(1), vec![1, 7]);
        assert_eq!(quantized_cnn(6, 1).out_shape(2), vec![2, 6]);
        assert_eq!(edge_audio(10, 1).out_shape(2), vec![2, 10, 1, 64]);
    }

    #[test]
    fn quantized_cnn_compiles_with_every_pass_firing() {
        let plan = quantized_cnn(4, 9).compile_with(true);
        assert_eq!(plan.summary.elided_pads, 1);
        assert_eq!(plan.summary.fused_relu, 3);
        assert_eq!(plan.summary.hoisted_quant, 1);
    }

    #[test]
    fn gemm_and_sliding_agree_on_every_model() {
        for name in MODEL_NAMES {
            let m = by_name(name, 4, 42).unwrap();
            let x = Tensor::randn(
                &std::iter::once(1).chain(m.input_shape.iter().copied()).collect::<Vec<_>>(),
                7,
            );
            let g = m.forward(&x, &ExecCtx::new(ConvAlgo::Im2colGemm));
            let s = m.forward(&x, &ExecCtx::new(ConvAlgo::Sliding));
            let d = g.max_abs_diff(&s);
            assert!(d < 1e-3, "{name}: diff {d}");
        }
    }

    #[test]
    fn flop_counts_sane() {
        // MobileNet-lite should be cheaper than the large-filter net.
        let mb = mobilenet_lite(10, 1).flops(1);
        let lf = large_filter_net(10, 1).flops(1);
        assert!(mb > 1_000_000);
        assert!(lf > mb, "large filters should dominate: {lf} vs {mb}");
    }
}
