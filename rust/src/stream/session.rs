//! Frame-by-frame inference sessions over a compiled model plan.
//!
//! A [`StreamSession`] compiles a model's graph once, checks that it is
//! a linear chain of 1-D (height-1) convolutions, pools, and ReLUs, and
//! then advances it one *frame* (one input column across channels) at a
//! time. Each stage keeps a mirrored ring of its most recent input
//! columns (see [`super::ring`]), so a new frame costs O(taps) per
//! stage instead of a full-plane recompute:
//!
//! - **Conv stages** run the regular batch conv kernel on the ring
//!   window `[1, c_in, 1, k] → [1, c_out, 1, 1]`. That *is* the
//!   O(taps) incremental update, and because the kernels accumulate
//!   each output element over its taps in a position-independent
//!   order, it reproduces the batch kernel's summation tree.
//! - **Average pooling** uses the sliding-window-sum recurrence
//!   `sum[i] = sum[i-1] − x[i-1] + x[i+w-1]` (arXiv 2305.16513): O(1)
//!   per frame. The recurrence reassociates the f32 sum, so avg-pool
//!   outputs match the batch path within a *derived* tolerance, never
//!   bit-for-bit — [`StreamSession::tolerance`] computes the bound.
//! - **Max pooling and ReLU** have exact windowed/pointwise forms
//!   (max and clamp are order-free), so they add no error.
//!
//! ## Int8 exactness
//!
//! Dynamic per-tensor activation scales (`QuantParams::for_tensor` over
//! the whole plane, what the batch executor does) are ill-defined for a
//! causal stream — frame `t` cannot see frame `t+1` before choosing its
//! scale. A session therefore **freezes** each conv stage's activation
//! scale at construction from a calibration pass, and its
//! [`StreamSession::run_batch`] reference applies the same frozen
//! scales to the full plane with the real batch kernels. Quantization
//! is pointwise and the i32 accumulation is order-independent, so the
//! streamed i8 output equals `run_batch` **bit-for-bit**, provided the
//! chain contains no average pooling (which runs in f32 and
//! reassociates). In f32 mode `run_batch` performs exactly the kernel
//! calls of the compiled plan, so it is bitwise-equal to `plan.run`.

use super::ring::Ring;
use crate::error::Result;
use crate::exec::ExecCtx;
use crate::graph::Op;
use crate::kernels::{
    avg_pool2d_ctx, conv2d_bf16_epi_ctx, conv2d_epi_ctx, conv2d_q8_raw_routed_ctx,
    dequantize_conv_acc, max_pool2d_ctx, Conv2dParams, Epilogue, PoolParams,
};
use crate::nn::Model;
use crate::tensor::{quantize, Dtype, QuantParams, Tensor, TensorT, WeightScales};

/// Seed of the default calibration signal used by [`StreamSession::new`].
const CALIB_SEED: u64 = 0x57E4_A0D1_0;

/// f32 machine epsilon with headroom, used by the tolerance derivation.
const EPS: f32 = 1.2e-7;

/// Per-stage compute kind plus the state that kind needs.
enum StageKernel {
    /// f32 convolution (also used for the `I32` dtype, like the plan).
    ConvF32 {
        /// Weights `[c_out, c_in, 1, k]`.
        w: Tensor,
        /// Bias `[c_out]`.
        bias: Vec<f32>,
        /// Fused ReLU on the output write.
        relu: bool,
    },
    /// bf16 convolution (f32 ring; the kernel converts internally).
    ConvBf16 {
        /// Weights `[c_out, c_in, 1, k]`.
        w: Tensor,
        /// Bias `[c_out]`.
        bias: Vec<f32>,
        /// Fused ReLU on the output write.
        relu: bool,
    },
    /// Int8 convolution over a ring of i8 *codes*. Used both for
    /// `QuantConv2d` nodes (any dtype) and for plain `Conv2d` nodes
    /// when the session dtype is `I8`.
    ConvI8 {
        /// Weight codes `[c_out, c_in, 1, k]`.
        qw: TensorT<i8>,
        /// Weight scales.
        wq: WeightScales,
        /// Activation scale, frozen at calibration.
        xq: QuantParams,
        /// Bias `[c_out]` in f32.
        bias: Vec<f32>,
        /// Fused ReLU on the output write.
        relu: bool,
        /// Ring of quantized input columns.
        ring_q: Ring<i8>,
        /// Reused scratch for quantizing one incoming column.
        qcol: Vec<i8>,
    },
    /// Windowed max (exact: max is order-free).
    MaxPool,
    /// Running-sum recurrence state, one sum per channel.
    AvgPool {
        /// Sum of the last `min(pushed, k)` columns, per channel.
        sums: Vec<f32>,
    },
    /// Pointwise `max(v, 0)`; no ring, no state.
    Relu,
}

/// One layer of the streaming chain: geometry + ring + kernel state.
struct Stage {
    kernel: StageKernel,
    /// Window width along the signal (1 for pointwise stages).
    k: usize,
    /// Stride along the signal.
    stride: usize,
    /// Zero padding on each end of the signal (convs only).
    pad: usize,
    c_in: usize,
    c_out: usize,
    /// f32 input ring; `None` for pointwise and i8-code stages.
    ring_f: Option<Ring<f32>>,
    /// Columns pushed since reset (left padding included).
    pushed: usize,
    /// Output columns emitted since reset.
    emitted: usize,
    /// Max |input value| seen (seeded from calibration), for the
    /// tolerance derivation.
    act_max: f32,
    /// Calibration-time `act_max`, restored by reset.
    act_max_seed: f32,
}

impl Stage {
    /// Batch reference for this stage: the same kernel the compiled
    /// plan would run, with the frozen i8 activation scale where the
    /// plan would re-derive one per plane.
    fn run_batch(&self, x: &Tensor, ctx: &ExecCtx) -> Tensor {
        let p = Conv2dParams { stride: (1, self.stride), pad: (0, self.pad), groups: 1 };
        let pool = PoolParams { k: (1, self.k), stride: (1, self.stride), pad: (0, 0) };
        match &self.kernel {
            StageKernel::ConvF32 { w, bias, relu } => {
                let epi = Epilogue::from_bias(Some(bias)).with_relu(*relu);
                conv2d_epi_ctx(x, w, epi, &p, ctx)
            }
            StageKernel::ConvBf16 { w, bias, relu } => {
                conv2d_bf16_epi_ctx(x, w, Some(bias), *relu, &p, ctx)
            }
            StageKernel::ConvI8 { qw, wq, xq, bias, relu, .. } => {
                let qx = quantize(x, *xq);
                let raw = conv2d_q8_raw_routed_ctx(&qx, qw, &p, ctx);
                dequantize_conv_acc(&raw, *xq, wq, Some(bias), *relu)
            }
            StageKernel::MaxPool => max_pool2d_ctx(x, &pool, ctx),
            StageKernel::AvgPool { .. } => avg_pool2d_ctx(x, &pool, ctx),
            StageKernel::Relu => x.map(|v| v.max(0.0)),
        }
    }

    /// Push one input column; returns the output column if this push
    /// completes a window (at most one emission per push).
    fn push(&mut self, col: &[f32], ctx: &ExecCtx) -> Option<Vec<f32>> {
        debug_assert_eq!(col.len(), self.c_in, "stage fed {} of {} channels", col.len(), self.c_in);
        if let StageKernel::Relu = self.kernel {
            self.pushed += 1;
            self.emitted += 1;
            return Some(col.iter().map(|v| v.max(0.0)).collect());
        }
        for &v in col {
            self.act_max = self.act_max.max(v.abs());
        }
        match &mut self.kernel {
            StageKernel::ConvI8 { xq, ring_q, qcol, .. } => {
                qcol.clear();
                qcol.extend(col.iter().map(|&v| xq.quantize_value(v)));
                ring_q.push(qcol);
            }
            StageKernel::AvgPool { sums } => {
                let ring = self.ring_f.as_mut().expect("avg-pool stage has an f32 ring");
                ring.push(col);
                for (c, s) in sums.iter_mut().enumerate() {
                    *s += col[c];
                    if ring.pushed() > self.k {
                        // The column that just left the k-wide window
                        // is the oldest of the last k+1 (ring cap).
                        *s -= ring.window(c, self.k + 1)[0];
                    }
                }
            }
            _ => self.ring_f.as_mut().expect("windowed stage has an f32 ring").push(col),
        }
        self.pushed += 1;
        if self.pushed < self.k || (self.pushed - self.k) % self.stride != 0 {
            return None;
        }
        self.emitted += 1;
        Some(self.emit(ctx))
    }

    /// Push one all-zero column (padding), without a caller buffer.
    fn push_zero(&mut self, ctx: &ExecCtx) -> Option<Vec<f32>> {
        let zeros = vec![0.0f32; self.c_in];
        self.push(&zeros, ctx)
    }

    /// Compute the output column for the window just completed.
    fn emit(&mut self, ctx: &ExecCtx) -> Vec<f32> {
        let unit = Conv2dParams::default();
        match &self.kernel {
            StageKernel::ConvF32 { w, bias, relu } => {
                let x = self.window_tensor(ctx);
                let epi = Epilogue::from_bias(Some(bias)).with_relu(*relu);
                let y = conv2d_epi_ctx(&x, w, epi, &unit, ctx);
                ctx.put(x.into_vec());
                y.into_vec()
            }
            StageKernel::ConvBf16 { w, bias, relu } => {
                let x = self.window_tensor(ctx);
                let y = conv2d_bf16_epi_ctx(&x, w, Some(bias), *relu, &unit, ctx);
                ctx.put(x.into_vec());
                y.into_vec()
            }
            StageKernel::ConvI8 { qw, wq, xq, bias, relu, ring_q, .. } => {
                let mut buf = ctx.take_elems_unfilled::<i8>(self.c_in * self.k);
                for c in 0..self.c_in {
                    buf[c * self.k..(c + 1) * self.k].copy_from_slice(ring_q.window(c, self.k));
                }
                let qx = TensorT::from_vec(buf, &[1, self.c_in, 1, self.k]);
                let raw = conv2d_q8_raw_routed_ctx(&qx, qw, &unit, ctx);
                ctx.put_elems(qx.into_vec());
                dequantize_conv_acc(&raw, *xq, wq, Some(bias), *relu).into_vec()
            }
            StageKernel::MaxPool => {
                let ring = self.ring_f.as_ref().expect("max-pool stage has an f32 ring");
                (0..self.c_in)
                    .map(|c| {
                        ring.window(c, self.k).iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v))
                    })
                    .collect()
            }
            StageKernel::AvgPool { sums } => {
                let inv = 1.0 / self.k as f32;
                sums.iter().map(|&s| s * inv).collect()
            }
            StageKernel::Relu => unreachable!("relu emits inline"),
        }
    }

    /// Borrow the last `k` columns from the f32 ring into an arena
    /// buffer shaped `[1, c_in, 1, k]` for the window kernels.
    fn window_tensor(&self, ctx: &ExecCtx) -> Tensor {
        let ring = self.ring_f.as_ref().expect("conv stage has an f32 ring");
        let mut buf = ctx.take_unfilled(self.c_in * self.k);
        for c in 0..self.c_in {
            buf[c * self.k..(c + 1) * self.k].copy_from_slice(ring.window(c, self.k));
        }
        Tensor::from_vec(buf, &[1, self.c_in, 1, self.k])
    }

    /// Drop buffered columns and re-preload the left padding.
    fn reset(&mut self) {
        if let Some(r) = self.ring_f.as_mut() {
            r.reset();
        }
        match &mut self.kernel {
            StageKernel::ConvI8 { ring_q, .. } => ring_q.reset(),
            StageKernel::AvgPool { sums } => sums.fill(0.0),
            _ => {}
        }
        for _ in 0..self.pad {
            if let Some(r) = self.ring_f.as_mut() {
                r.push_splat(0.0);
            }
            if let StageKernel::ConvI8 { ring_q, .. } = &mut self.kernel {
                // Symmetric quantization: real 0.0 is exactly code 0,
                // so zero-padding is the same column in both domains.
                ring_q.push_splat(0);
            }
        }
        self.pushed = self.pad;
        self.emitted = 0;
        self.act_max = self.act_max_seed;
    }

    /// Largest per-output-channel L1 norm of an f32 filter.
    fn l1_max(w: &Tensor) -> f32 {
        let taps = w.numel() / w.dim(0);
        w.as_slice()
            .chunks(taps)
            .map(|ch| ch.iter().map(|v| v.abs()).sum::<f32>())
            .fold(0.0f32, f32::max)
    }

    /// Largest per-output-channel L1 norm of a dequantized i8 filter.
    fn l1_deq_max(qw: &TensorT<i8>, wq: &WeightScales) -> f32 {
        let taps = qw.numel() / qw.dim(0);
        qw.as_slice()
            .chunks(taps)
            .enumerate()
            .map(|(co, ch)| {
                wq.scale(co) * ch.iter().map(|&c| (c as i32).unsigned_abs()).sum::<u32>() as f32
            })
            .fold(0.0f32, f32::max)
    }
}

/// A stateful, frame-by-frame inference session over one model.
///
/// Construct with [`StreamSession::new`] (or
/// [`StreamSession::with_calibration`] to control the i8 scale-freezing
/// input), feed frames with [`StreamSession::advance`], and finish the
/// signal with [`StreamSession::flush`]. [`StreamSession::run_batch`]
/// is the one-shot batch reference the streamed outputs are verified
/// against, and [`StreamSession::tolerance`] derives the comparison
/// bound (0 ulps in i8 without avg-pool; a composed f32 bound
/// otherwise).
pub struct StreamSession {
    name: String,
    ctx: ExecCtx,
    dtype: Dtype,
    stages: Vec<Stage>,
    in_channels: usize,
    input_len: usize,
    frames_in: usize,
    flushed: bool,
}

impl StreamSession {
    /// Build a session for `model`, calibrating i8 activation scales
    /// (and tolerance bookkeeping) on a fixed-seed Gaussian signal of
    /// the model's nominal input length.
    ///
    /// Fails if the model is not a linear chain of height-1 conv /
    /// pool / ReLU stages (see module docs).
    pub fn new(model: &Model, ctx: ExecCtx) -> Result<Self> {
        if model.input_shape.len() != 3 || model.input_shape[1] != 1 {
            crate::bail!(
                "streaming needs a [c, 1, l] input shape, got {:?}",
                model.input_shape
            );
        }
        let dims = [1, model.input_shape[0], 1, model.input_shape[2]];
        Self::with_calibration(model, ctx, &Tensor::randn(&dims, CALIB_SEED))
    }

    /// Like [`StreamSession::new`] with an explicit calibration signal
    /// `[1, c, 1, l]` (the range it covers becomes the frozen i8
    /// activation range; values outside it saturate identically on the
    /// streamed and batch paths).
    pub fn with_calibration(model: &Model, ctx: ExecCtx, calib: &Tensor) -> Result<Self> {
        if model.input_shape.len() != 3 || model.input_shape[1] != 1 {
            crate::bail!(
                "streaming needs a [c, 1, l] input shape, got {:?}",
                model.input_shape
            );
        }
        let in_channels = model.input_shape[0];
        let input_len = model.input_shape[2];
        if calib.rank() != 4 || calib.dim(0) != 1 || calib.dim(1) != in_channels || calib.dim(2) != 1
        {
            crate::bail!(
                "calibration signal must be [1, {in_channels}, 1, l], got {:?}",
                calib.dims()
            );
        }
        let plan = model.compile();
        let g = &plan.graph;
        if g.nodes.is_empty() || !matches!(g.nodes[0].op, Op::Input) {
            crate::bail!("compiled graph has no input node");
        }
        if g.output != g.nodes.len() - 1 {
            crate::bail!("streaming requires the last node to be the output");
        }
        let dtype = ctx.dtype();
        let mut stages = Vec::with_capacity(g.nodes.len() - 1);
        let mut channels = in_channels;
        for (id, node) in g.nodes.iter().enumerate().skip(1) {
            if node.inputs != [id - 1] {
                crate::bail!("streaming requires a linear chain; node {id} branches");
            }
            if node.quant_out {
                crate::bail!("hoisted quantize boundaries have no streaming form yet");
            }
            if node.shape.len() == 3 && node.shape[1] != 1 {
                crate::bail!("stage {id} leaves the height-1 signal domain: {:?}", node.shape);
            }
            let stage = match &node.op {
                Op::Conv2d { w, bias, params } => {
                    conv_stage(w, bias, params, node.fused_relu, dtype, channels)?
                }
                Op::QuantConv2d { qw, wq, bias, params } => {
                    quant_conv_stage(qw, wq, bias, params, node.fused_relu, channels)?
                }
                Op::Relu => Stage {
                    kernel: StageKernel::Relu,
                    k: 1,
                    stride: 1,
                    pad: 0,
                    c_in: channels,
                    c_out: channels,
                    ring_f: None,
                    pushed: 0,
                    emitted: 0,
                    act_max: 0.0,
                    act_max_seed: 0.0,
                },
                Op::MaxPool2d(p) => pool_stage(p, channels, /*avg=*/ false)?,
                Op::AvgPool2d(p) => pool_stage(p, channels, /*avg=*/ true)?,
                other => crate::bail!("op `{}` (node {id}) has no streaming form", other.name()),
            };
            channels = stage.c_out;
            stages.push(stage);
        }
        if stages.is_empty() {
            crate::bail!("model has no layers to stream");
        }
        let mut s = StreamSession {
            name: g.name.clone(),
            ctx,
            dtype,
            stages,
            in_channels,
            input_len,
            frames_in: 0,
            flushed: false,
        };
        s.calibrate(calib);
        s.reset();
        Ok(s)
    }

    /// Freeze i8 activation scales and seed `act_max` per stage from
    /// one batch pass over the calibration signal.
    fn calibrate(&mut self, calib: &Tensor) {
        let mut x = calib.clone();
        for stage in &mut self.stages {
            stage.act_max_seed = x.max_abs();
            if let StageKernel::ConvI8 { xq, .. } = &mut stage.kernel {
                *xq = QuantParams::for_tensor(&x);
            }
            x = stage.run_batch(&x, &self.ctx);
        }
    }

    /// Feed one frame (`frame[c]` is channel `c`'s new sample) and run
    /// every stage whose window completes. Returns the model's output
    /// column when the frame propagates all the way through, `None`
    /// while windows are still warming up or strides swallow it.
    pub fn advance(&mut self, frame: &[f32]) -> Option<Vec<f32>> {
        assert!(!self.flushed, "advance after flush; call reset() first");
        assert_eq!(frame.len(), self.in_channels, "frame has wrong channel count");
        self.frames_in += 1;
        let mut col = frame.to_vec();
        for stage in &mut self.stages {
            col = stage.push(&col, &self.ctx)?;
        }
        Some(col)
    }

    /// End the signal: push every stage's right-side zero padding and
    /// cascade the resulting emissions downstream. Returns the final
    /// output columns, in order. After a flush the session must be
    /// [`StreamSession::reset`] before advancing again.
    pub fn flush(&mut self) -> Vec<Vec<f32>> {
        assert!(!self.flushed, "flush called twice; call reset() first");
        self.flushed = true;
        let mut out = Vec::new();
        for i in 0..self.stages.len() {
            for _ in 0..self.stages[i].pad {
                if let Some(col) = self.stages[i].push_zero(&self.ctx) {
                    self.cascade(i + 1, col, &mut out);
                }
            }
        }
        out
    }

    /// Run `col` through stages `start..`, collecting a final output.
    fn cascade(&mut self, start: usize, mut col: Vec<f32>, out: &mut Vec<Vec<f32>>) {
        for stage in &mut self.stages[start..] {
            match stage.push(&col, &self.ctx) {
                Some(next) => col = next,
                None => return,
            }
        }
        out.push(col);
    }

    /// Forget all signal state (rings, running sums, padding preload)
    /// while keeping the compiled stages, frozen scales, and the warm
    /// arena. A reset session behaves exactly like a fresh one.
    pub fn reset(&mut self) {
        for stage in &mut self.stages {
            stage.reset();
        }
        self.frames_in = 0;
        self.flushed = false;
    }

    /// One-shot batch reference: the full signal `[1, c, 1, l]` through
    /// the same kernels stage by stage. In f32/bf16 mode these are
    /// exactly the compiled plan's kernel calls; in i8 mode the frozen
    /// activation scales replace the plan's per-plane dynamic ones
    /// (see module docs for why streaming requires that).
    pub fn run_batch(&self, x: &Tensor) -> Tensor {
        let mut y = x.clone();
        for stage in &self.stages {
            y = stage.run_batch(&y, &self.ctx);
        }
        y
    }

    /// Derived bound on |streamed − `run_batch`| per output value,
    /// composed stage by stage (see module docs):
    ///
    /// - conv stages amplify incoming divergence by their largest
    ///   per-channel L1 norm and add `4·ε·taps·‖w‖₁·max|x|` of their
    ///   own (different, but position-independent, summation trees);
    ///   bf16 convs additionally re-round diverged inputs to 8
    ///   mantissa bits (`max|x|/128` per side);
    /// - i8 convs are exact on exact inputs; on diverged inputs a code
    ///   can flip, bounded by `‖w‖₁·(tol + scale)`;
    /// - avg-pool adds running-sum drift `4·ε·max|x|·(pushes + k)`;
    ///   max-pool and ReLU are 1-Lipschitz and exact.
    ///
    /// Uses the actual per-stage push counts and value ranges, so call
    /// it *after* streaming. Floored at `1e-6`.
    pub fn tolerance(&self) -> f32 {
        let mut tol = 0.0f32;
        for stage in &self.stages {
            let taps = (stage.c_in * stage.k) as f32;
            let amax = stage.act_max;
            match &stage.kernel {
                StageKernel::ConvF32 { w, .. } => {
                    let l1 = Stage::l1_max(w);
                    tol = l1 * tol + 4.0 * EPS * taps * l1 * amax;
                }
                StageKernel::ConvBf16 { w, .. } => {
                    let l1 = Stage::l1_max(w);
                    let restep = if tol > 0.0 { amax / 128.0 } else { 0.0 };
                    tol = l1 * (tol + restep) + 4.0 * EPS * taps * l1 * amax;
                }
                StageKernel::ConvI8 { qw, wq, xq, .. } => {
                    if tol > 0.0 {
                        tol = Stage::l1_deq_max(qw, wq) * (tol + xq.scale);
                    }
                }
                StageKernel::AvgPool { .. } => {
                    tol += 4.0 * EPS * amax * (stage.pushed + stage.k) as f32;
                }
                StageKernel::MaxPool | StageKernel::Relu => {}
            }
        }
        tol.max(1e-6)
    }

    /// Model name (from the compiled graph).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Dtype the session was compiled for.
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// The session-private execution context (its arena holds the
    /// session's hot scratch state; see `ExecCtx::arena_bytes`).
    pub fn ctx(&self) -> &ExecCtx {
        &self.ctx
    }

    /// Channels per input frame.
    pub fn in_channels(&self) -> usize {
        self.in_channels
    }

    /// Channels per output column.
    pub fn out_channels(&self) -> usize {
        self.stages.last().expect("session has stages").c_out
    }

    /// The model's nominal batch signal length (frames per window).
    pub fn input_len(&self) -> usize {
        self.input_len
    }

    /// Frames fed since the last reset.
    pub fn frames_in(&self) -> usize {
        self.frames_in
    }

    /// Output columns produced since the last reset (flush included).
    pub fn frames_out(&self) -> usize {
        self.stages.last().expect("session has stages").emitted
    }

    /// True once avg-pool-free, i8-quantized: every streamed output is
    /// bit-for-bit equal to [`StreamSession::run_batch`].
    pub fn is_bit_exact(&self) -> bool {
        self.stages.iter().all(|s| {
            matches!(
                s.kernel,
                StageKernel::ConvI8 { .. } | StageKernel::MaxPool | StageKernel::Relu
            )
        })
    }
}

/// Validate 1-D conv geometry shared by f32/bf16/i8 conv stages.
fn conv_geometry(
    dims: &[usize],
    params: &Conv2dParams,
    channels: usize,
) -> Result<(usize, usize, usize, usize, usize)> {
    if params.groups != 1 {
        crate::bail!("grouped convolutions have no streaming form");
    }
    if dims.len() != 4 || dims[2] != 1 {
        crate::bail!("streaming conv needs [c_out, c_in, 1, k] weights, got {dims:?}");
    }
    if params.stride.0 != 1 || params.pad.0 != 0 {
        crate::bail!("streaming conv must not stride or pad the height axis");
    }
    if dims[1] != channels {
        crate::bail!("conv expects {} input channels, chain provides {channels}", dims[1]);
    }
    Ok((dims[0], dims[1], dims[3], params.stride.1, params.pad.1))
}

/// Build a conv stage for `Op::Conv2d`, routed by the session dtype
/// exactly as the plan executor routes it (i8 weights are frozen with
/// the same deterministic per-tensor quantization the plan applies).
fn conv_stage(
    w: &Tensor,
    bias: &[f32],
    params: &Conv2dParams,
    relu: bool,
    dtype: Dtype,
    channels: usize,
) -> Result<Stage> {
    let (c_out, c_in, k, stride, pad) = conv_geometry(w.dims(), params, channels)?;
    let kernel = match dtype {
        Dtype::F32 | Dtype::I32 => {
            StageKernel::ConvF32 { w: w.clone(), bias: bias.to_vec(), relu }
        }
        Dtype::Bf16 => StageKernel::ConvBf16 { w: w.clone(), bias: bias.to_vec(), relu },
        Dtype::I8 => {
            let wqp = QuantParams::for_tensor(w);
            StageKernel::ConvI8 {
                qw: quantize(w, wqp),
                wq: WeightScales::PerTensor(wqp),
                xq: QuantParams::symmetric(1.0),
                bias: bias.to_vec(),
                relu,
                ring_q: Ring::new(c_in, k),
                qcol: Vec::with_capacity(c_in),
            }
        }
    };
    let ring_f = match kernel {
        StageKernel::ConvI8 { .. } => None,
        _ => Some(Ring::new(c_in, k)),
    };
    Ok(Stage {
        kernel,
        k,
        stride,
        pad,
        c_in,
        c_out,
        ring_f,
        pushed: 0,
        emitted: 0,
        act_max: 0.0,
        act_max_seed: 0.0,
    })
}

/// Build a conv stage for `Op::QuantConv2d` (i8 codes in every dtype
/// mode, like the plan executor).
fn quant_conv_stage(
    qw: &TensorT<i8>,
    wq: &WeightScales,
    bias: &[f32],
    params: &Conv2dParams,
    relu: bool,
    channels: usize,
) -> Result<Stage> {
    let (c_out, c_in, k, stride, pad) = conv_geometry(qw.dims(), params, channels)?;
    Ok(Stage {
        kernel: StageKernel::ConvI8 {
            qw: qw.clone(),
            wq: wq.clone(),
            xq: QuantParams::symmetric(1.0),
            bias: bias.to_vec(),
            relu,
            ring_q: Ring::new(c_in, k),
            qcol: Vec::with_capacity(c_in),
        },
        k,
        stride,
        pad,
        c_in,
        c_out,
        ring_f: None,
        pushed: 0,
        emitted: 0,
        act_max: 0.0,
        act_max_seed: 0.0,
    })
}

/// Build a pooling stage (height-1, unpadded windows only).
fn pool_stage(p: &PoolParams, channels: usize, avg: bool) -> Result<Stage> {
    if p.k.0 != 1 || p.stride.0 != 1 {
        crate::bail!("streaming pool must not window or stride the height axis");
    }
    if p.pad != (0, 0) {
        crate::bail!("padded pooling has no streaming form");
    }
    let k = p.k.1;
    let kernel = if avg {
        StageKernel::AvgPool { sums: vec![0.0; channels] }
    } else {
        StageKernel::MaxPool
    };
    // Avg-pool needs the column *leaving* the window for the
    // running-sum recurrence, hence one extra slot.
    let cap = if avg { k + 1 } else { k };
    Ok(Stage {
        kernel,
        k,
        stride: p.stride.1,
        pad: 0,
        c_in: channels,
        c_out: channels,
        ring_f: Some(Ring::new(channels, cap)),
        pushed: 0,
        emitted: 0,
        act_max: 0.0,
        act_max_seed: 0.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ConvAlgo;
    use crate::nn::layers::{AvgPool2d, Conv2d, MaxPool2d, ReLU};
    use crate::tensor::XorShiftRng;

    fn tiny_model(avg: bool) -> Model {
        let scale = |t: Tensor, s: f32| t.map(|v| v * s);
        let m = Model::new("tiny-stream", &[2, 1, 32])
            .push(Conv2d {
                w: scale(Tensor::randn(&[4, 2, 1, 5], 901), 0.4),
                bias: vec![0.05, -0.02, 0.0, 0.03],
                params: Conv2dParams { stride: (1, 1), pad: (0, 2), groups: 1 },
            })
            .push(ReLU);
        let m = if avg {
            m.push(AvgPool2d(PoolParams { k: (1, 2), stride: (1, 2), pad: (0, 0) }))
        } else {
            m.push(MaxPool2d(PoolParams { k: (1, 2), stride: (1, 2), pad: (0, 0) }))
        };
        m.push(Conv2d {
            w: scale(Tensor::randn(&[3, 4, 1, 3], 902), 0.3),
            bias: vec![0.01, 0.02, -0.01],
            params: Conv2dParams { stride: (1, 1), pad: (0, 1), groups: 1 },
        })
    }

    fn signal(c: usize, l: usize, seed: u64) -> Tensor {
        Tensor::randn(&[1, c, 1, l], seed)
    }

    /// Stream the whole signal, collecting every output column into a
    /// `[1, c_out, 1, t]` tensor for comparison against the batch ref.
    fn stream_all(sess: &mut StreamSession, x: &Tensor) -> Tensor {
        let c = x.dim(1);
        let l = x.dim(3);
        let mut cols = Vec::new();
        for t in 0..l {
            let frame: Vec<f32> = (0..c).map(|ch| x.at4(0, ch, 0, t)).collect();
            if let Some(col) = sess.advance(&frame) {
                cols.push(col);
            }
        }
        cols.extend(sess.flush());
        let c_out = sess.out_channels();
        let t_out = cols.len();
        let mut data = vec![0.0f32; c_out * t_out];
        for (t, col) in cols.iter().enumerate() {
            for (ch, &v) in col.iter().enumerate() {
                data[ch * t_out + t] = v;
            }
        }
        Tensor::from_vec(data, &[1, c_out, 1, t_out])
    }

    #[test]
    fn streamed_matches_batch_within_tolerance_f32() {
        for avg in [false, true] {
            let model = tiny_model(avg);
            let x = signal(2, 32, 77);
            let mut sess = StreamSession::new(&model, ExecCtx::new(ConvAlgo::Sliding)).unwrap();
            let got = stream_all(&mut sess, &x);
            let want = sess.run_batch(&x);
            assert_eq!(got.dims(), want.dims(), "avg={avg}");
            let diff = got.max_abs_diff(&want);
            let tol = sess.tolerance();
            assert!(diff <= tol, "avg={avg}: diff {diff} > tolerance {tol}");
        }
    }

    #[test]
    fn f32_run_batch_is_bitwise_the_model_forward() {
        let model = tiny_model(true);
        let x = signal(2, 32, 78);
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let sess = StreamSession::new(&model, ctx.clone()).unwrap();
        let want = model.compile().run(&x, &ctx);
        let got = sess.run_batch(&x);
        assert_eq!(got.as_slice(), want.as_slice());
    }

    #[test]
    fn i8_stream_is_bit_exact_without_avg_pool() {
        let model = tiny_model(false);
        let x = signal(2, 32, 79);
        let ctx = ExecCtx::new(ConvAlgo::Sliding).with_dtype(Dtype::I8);
        let mut sess = StreamSession::new(&model, ctx).unwrap();
        assert!(sess.is_bit_exact());
        let got = stream_all(&mut sess, &x);
        let want = sess.run_batch(&x);
        assert_eq!(got.as_slice(), want.as_slice(), "i8 streamed != batch");
    }

    #[test]
    fn warmup_frames_emit_nothing_and_flush_completes_the_count() {
        let model = tiny_model(false);
        let mut sess = StreamSession::new(&model, ExecCtx::default()).unwrap();
        let mut rng = XorShiftRng::new(5);
        let mut emitted = 0;
        for _ in 0..32 {
            let frame = [rng.gauss(), rng.gauss()];
            emitted += usize::from(sess.advance(&frame).is_some());
        }
        emitted += sess.flush().len();
        let want_t = sess.run_batch(&signal(2, 32, 1)).dim(3);
        assert_eq!(emitted, want_t);
        assert_eq!(sess.frames_out(), want_t);
    }

    #[test]
    fn reset_replays_identically() {
        let model = tiny_model(true);
        let x = signal(2, 32, 80);
        let mut sess = StreamSession::new(&model, ExecCtx::default()).unwrap();
        let first = stream_all(&mut sess, &x);
        sess.reset();
        let second = stream_all(&mut sess, &x);
        assert_eq!(first.as_slice(), second.as_slice());
    }

    #[test]
    fn non_streamable_models_are_rejected() {
        // 2-D input shape (height > 1) has no frame axis.
        let m = Model::new("not-1d", &[3, 8, 8]).push(ReLU);
        assert!(StreamSession::new(&m, ExecCtx::default()).is_err());
        // Height-windowed pooling leaves the signal domain.
        let m = Model::new("bad-pool", &[2, 1, 16])
            .push(MaxPool2d(PoolParams { k: (2, 2), stride: (2, 2), pad: (0, 0) }));
        assert!(StreamSession::new(&m, ExecCtx::default()).is_err());
    }
}
