//! Mirrored ring buffers for streaming input planes.
//!
//! A [`Ring`] holds the last `cap` samples of a multi-channel signal.
//! Each channel row is stored **twice** (`2 * cap` slots, the second
//! half mirroring the first), so the window of the most recent `w ≤
//! cap` samples is always a *contiguous* slice — the conv/pool window
//! kernels can borrow it directly with no copy and no wrap-around
//! branch. Sample number `p` (0-based since the last reset) lives at
//! `p % cap` and at `p % cap + cap`; the newest sample is therefore
//! always at a mirrored index `≥ cap`, and the `w` samples ending at
//! it occupy `[idx + 1 - w, idx + 1)` with `idx ≥ cap > w - 1`.

/// Fixed-capacity multi-channel ring buffer with mirrored storage.
///
/// Generic over the element so the f32 activation planes and the i8
/// code planes of quantized streams share one implementation.
#[derive(Clone, Debug)]
pub struct Ring<T> {
    channels: usize,
    cap: usize,
    /// Samples pushed since the last [`Ring::reset`].
    pushed: usize,
    /// `channels` rows of `2 * cap` mirrored slots.
    data: Vec<T>,
}

impl<T: Copy + Default> Ring<T> {
    /// Empty ring holding up to `cap` samples of `channels` channels.
    pub fn new(channels: usize, cap: usize) -> Self {
        assert!(channels > 0 && cap > 0, "degenerate ring {channels}x{cap}");
        Ring { channels, cap, pushed: 0, data: vec![T::default(); channels * 2 * cap] }
    }

    /// Number of channels per sample.
    pub fn channels(&self) -> usize {
        self.channels
    }

    /// Maximum window width this ring can serve.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Samples pushed since the last reset (not clamped to `cap`).
    pub fn pushed(&self) -> usize {
        self.pushed
    }

    /// Samples currently available: `min(pushed, cap)`.
    pub fn len(&self) -> usize {
        self.pushed.min(self.cap)
    }

    /// True until the first push after construction or reset.
    pub fn is_empty(&self) -> bool {
        self.pushed == 0
    }

    /// Append one sample (`col[c]` is channel `c`'s new value).
    pub fn push(&mut self, col: &[T]) {
        assert_eq!(col.len(), self.channels, "ring push channel mismatch");
        let slot = self.pushed % self.cap;
        for (ch, &v) in col.iter().enumerate() {
            let row = ch * 2 * self.cap;
            self.data[row + slot] = v;
            self.data[row + slot + self.cap] = v;
        }
        self.pushed += 1;
    }

    /// Append one sample with the same value in every channel
    /// (zero-padding columns, without a scratch buffer).
    pub fn push_splat(&mut self, v: T) {
        let slot = self.pushed % self.cap;
        for ch in 0..self.channels {
            let row = ch * 2 * self.cap;
            self.data[row + slot] = v;
            self.data[row + slot + self.cap] = v;
        }
        self.pushed += 1;
    }

    /// The most recent `w` samples of channel `ch`, oldest first, as a
    /// contiguous slice. Requires `w ≤ len()`.
    pub fn window(&self, ch: usize, w: usize) -> &[T] {
        assert!(w <= self.len(), "window {w} wider than {} buffered samples", self.len());
        assert!(ch < self.channels, "channel {ch} out of {}", self.channels);
        let row = ch * 2 * self.cap;
        // Mirrored index of the newest sample, always ≥ cap.
        let idx = (self.pushed - 1) % self.cap + self.cap;
        &self.data[row + idx + 1 - w..row + idx + 1]
    }

    /// Forget all samples (storage is retained and re-zeroed lazily by
    /// subsequent pushes; `window` can never observe stale slots
    /// because `len()` gates it).
    pub fn reset(&mut self) {
        self.pushed = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// From-scratch reference: the last `w` of an ever-growing log.
    fn naive_window(log: &[Vec<f32>], ch: usize, w: usize) -> Vec<f32> {
        log[log.len() - w..].iter().map(|col| col[ch]).collect()
    }

    #[test]
    fn window_is_contiguous_across_wraparound() {
        let mut r = Ring::<f32>::new(3, 5);
        let mut log: Vec<Vec<f32>> = Vec::new();
        for p in 0..23 {
            let col: Vec<f32> = (0..3).map(|c| (p * 10 + c) as f32).collect();
            r.push(&col);
            log.push(col);
            for w in 1..=r.len() {
                for ch in 0..3 {
                    assert_eq!(r.window(ch, w), naive_window(&log, ch, w), "p={p} w={w} ch={ch}");
                }
            }
        }
    }

    #[test]
    fn splat_and_reset() {
        let mut r = Ring::<i8>::new(2, 4);
        r.push_splat(7);
        r.push(&[1, 2]);
        assert_eq!(r.window(0, 2), &[7, 1]);
        assert_eq!(r.window(1, 2), &[7, 2]);
        r.reset();
        assert!(r.is_empty());
        r.push(&[3, 4]);
        assert_eq!(r.len(), 1);
        assert_eq!(r.window(1, 1), &[4]);
    }

    #[test]
    #[should_panic(expected = "wider than")]
    fn overwide_window_panics() {
        let mut r = Ring::<f32>::new(1, 4);
        r.push(&[1.0]);
        r.window(0, 2);
    }
}
