//! Streaming (frame-by-frame) inference.
//!
//! The batch path computes a whole signal at once; this module keeps
//! per-layer state so a model can consume a signal one sample at a
//! time in O(taps) per frame — the low-power/edge scenario the paper
//! targets. Two pieces:
//!
//! - [`ring`]: mirrored ring buffers whose most-recent-`w` window is
//!   always a contiguous slice, so the batch conv kernels can run
//!   directly on the live window without copies or wrap branches.
//! - [`session`]: [`StreamSession`], which compiles a model's graph
//!   once, validates it has a streaming form, and advances it frame by
//!   frame — with a batch reference (`run_batch`) and a derived error
//!   bound (`tolerance`) so equivalence with the batch path is
//!   checkable, not assumed (bit-for-bit in i8; see the session docs).
//!
//! The coordinator builds on this for stateful serving: sessions are
//! pinned to one replica so their rings and arena scratch stay hot
//! (see `coordinator`).

pub mod ring;
pub mod session;

pub use ring::Ring;
pub use session::StreamSession;
