//! Graph rewrite passes. [`optimize`] runs them in a fixed order —
//! epilogue fusion, pad elision, quantize-boundary hoisting — then
//! compacts the graph. Each pass only rewires edges *backwards* (to
//! smaller node ids), so topological order is preserved throughout and
//! the executor can keep evaluating nodes in index order.
//!
//! Legality notes (the reasons each rewrite is exact, not just close):
//!
//! * **Epilogue fusion** — `relu` is folded into a producer's output
//!   write as `v.max(0.0)` on the exact value the unfused kernel would
//!   have stored; a separate ReLU pass computes the same expression on
//!   the same bits. Only producers with a single consumer are eligible
//!   (another consumer would observe pre-activation values).
//! * **Pad elision** — a `pad2d` copy feeding a convolution is absorbed
//!   into the conv's own `pad` parameter: the sliding kernels
//!   materialise an identical padded buffer either way, and
//!   `avg_pool2d` pads with the same zero (count-include-pad). Max
//!   pooling is **excluded**: its internal padding identity is −∞, not
//!   zero, so absorbing an explicit zero pad would change values.
//! * **Quantize-boundary hoisting** — a `quant-conv2d` whose consumers
//!   are all `quant-conv2d` emits i8 codes + scale directly
//!   ([`crate::kernels`]' `quantize_conv_acc` computes bit-identically
//!   the same codes the unfused dequantize → re-quantize round trip
//!   produces), so the intermediate f32 tensor is never written.
//!   Restricted to *direct* edges: hoisting across e.g. a pooling node
//!   would requantize with that node's output statistics instead.

use super::ir::{Graph, NodeId, Op};

/// What [`optimize`] did — surfaced by the CLI `compile` subcommand and
/// asserted on by the structural tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PassSummary {
    /// ReLU nodes folded into a producer's output epilogue.
    pub fused_relu: usize,
    /// `pad2d` nodes absorbed into consumer edge handling.
    pub elided_pads: usize,
    /// Convolutions now exchanging i8 activations directly.
    pub hoisted_quant: usize,
}

/// Run the full pass pipeline in place.
pub fn optimize(g: &mut Graph) -> PassSummary {
    let summary = PassSummary {
        fused_relu: fuse_epilogues(g),
        elided_pads: elide_pads(g),
        hoisted_quant: hoist_quant_boundaries(g),
    };
    g.compact();
    summary
}

/// Can this op apply a fused ReLU in its output write?
fn is_conv_like(op: &Op) -> bool {
    matches!(op, Op::Conv2d { .. } | Op::QuantConv2d { .. } | Op::Linear { .. })
}

/// Replace every use of `from` (edges and the graph output) with `to`.
fn rewire(g: &mut Graph, from: NodeId, to: NodeId) {
    for n in &mut g.nodes {
        for i in &mut n.inputs {
            if *i == from {
                *i = to;
            }
        }
    }
    if g.output == from {
        g.output = to;
    }
}

/// Pass 1: fold ReLU nodes into the output epilogue of their producer.
/// Handles the direct `conv → relu` edge and the `(conv ‖ conv) →
/// concat → relu` shape (Fire modules), pushing the ReLU into both
/// branches — legal because `relu(concat(a, b)) == concat(relu(a),
/// relu(b))`.
pub fn fuse_epilogues(g: &mut Graph) -> usize {
    let mut fused = 0;
    for r in 1..g.nodes.len() {
        if !matches!(g.nodes[r].op, Op::Relu) {
            continue;
        }
        let p = g.nodes[r].inputs[0];
        let counts = g.consumer_counts();
        if counts[p] != 1 {
            continue; // someone else observes the pre-activation values
        }
        if is_conv_like(&g.nodes[p].op) && !g.nodes[p].fused_relu {
            g.nodes[p].fused_relu = true;
            rewire(g, r, p);
            fused += 1;
        } else if matches!(g.nodes[p].op, Op::Concat) {
            let branches = g.nodes[p].inputs.clone();
            let eligible = branches.iter().all(|&b| {
                counts[b] == 1 && is_conv_like(&g.nodes[b].op) && !g.nodes[b].fused_relu
            });
            if eligible {
                for &b in &branches {
                    g.nodes[b].fused_relu = true;
                }
                rewire(g, r, p);
                fused += 1;
            }
        }
    }
    fused
}

/// Pass 2: absorb explicit `pad2d` copies into the consumers' own edge
/// handling. Walks ids high-to-low so chained pads collapse in one
/// sweep.
pub fn elide_pads(g: &mut Graph) -> usize {
    let mut elided = 0;
    for d in (1..g.nodes.len()).rev() {
        let (ph, pw) = match g.nodes[d].op {
            Op::Pad2d { ph, pw } => (ph, pw),
            _ => continue,
        };
        if g.output == d {
            continue;
        }
        let src = g.nodes[d].inputs[0];
        let consumers: Vec<NodeId> = (0..g.nodes.len())
            .filter(|&c| g.nodes[c].inputs.contains(&d))
            .collect();
        let absorbable = !consumers.is_empty()
            && consumers.iter().all(|&c| {
                matches!(
                    g.nodes[c].op,
                    Op::Conv2d { .. } | Op::QuantConv2d { .. } | Op::AvgPool2d(_)
                )
            });
        if !absorbable {
            continue;
        }
        for &c in &consumers {
            match &mut g.nodes[c].op {
                Op::Conv2d { params, .. } | Op::QuantConv2d { params, .. } => {
                    params.pad = (params.pad.0 + ph, params.pad.1 + pw);
                }
                Op::AvgPool2d(p) => {
                    p.pad = (p.pad.0 + ph, p.pad.1 + pw);
                }
                _ => unreachable!(),
            }
            for i in &mut g.nodes[c].inputs {
                if *i == d {
                    *i = src;
                }
            }
        }
        elided += 1;
    }
    elided
}

/// Pass 3: mark `quant-conv2d` nodes whose every consumer is another
/// `quant-conv2d` as emitting i8 activations directly.
pub fn hoist_quant_boundaries(g: &mut Graph) -> usize {
    let mut hoisted = 0;
    for q in 1..g.nodes.len() {
        if !matches!(g.nodes[q].op, Op::QuantConv2d { .. }) || g.output == q {
            continue;
        }
        let mut any = false;
        let all_quant = (0..g.nodes.len())
            .filter(|&c| g.nodes[c].inputs.contains(&q))
            .all(|c| {
                any = true;
                matches!(g.nodes[c].op, Op::QuantConv2d { .. })
            });
        if any && all_quant {
            g.nodes[q].quant_out = true;
            hoisted += 1;
        }
    }
    hoisted
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Conv2dParams, PoolParams};
    use crate::tensor::{quantize_per_channel, Tensor};

    fn conv(c_in: usize, c_out: usize, k: usize, params: Conv2dParams) -> Op {
        Op::Conv2d {
            w: Tensor::randn(&[c_out, c_in, k, k], 7),
            bias: vec![0.0; c_out],
            params,
        }
    }

    fn qconv(c_in: usize, c_out: usize, k: usize, params: Conv2dParams) -> Op {
        let (qw, wq) = quantize_per_channel(&Tensor::randn(&[c_out, c_in, k, k], 8));
        Op::QuantConv2d { qw, wq, bias: vec![0.0; c_out], params }
    }

    #[test]
    fn relu_fuses_into_single_consumer_conv() {
        let mut g = Graph::new("t", &[3, 8, 8]);
        let c = g.add(conv(3, 4, 3, Conv2dParams::same(3)), vec![0]);
        let r = g.add(Op::Relu, vec![c]);
        g.add(Op::Flatten, vec![r]);
        let s = optimize(&mut g);
        assert_eq!(s.fused_relu, 1);
        assert_eq!(g.nodes.len(), 3); // input, conv(+relu), flatten
        assert!(g.nodes[1].fused_relu);
        assert!(matches!(g.nodes[2].op, Op::Flatten));
        assert_eq!(g.nodes[2].inputs, vec![1]);
    }

    #[test]
    fn relu_not_fused_when_preactivation_is_observed() {
        let mut g = Graph::new("t", &[3, 8, 8]);
        let c = g.add(conv(3, 4, 3, Conv2dParams::same(3)), vec![0]);
        let r = g.add(Op::Relu, vec![c]);
        // Second consumer of the conv: a concat of pre- and post-relu.
        g.add(Op::Concat, vec![c, r]);
        let s = optimize(&mut g);
        assert_eq!(s.fused_relu, 0);
        assert!(!g.nodes[1].fused_relu);
    }

    #[test]
    fn relu_after_concat_pushes_into_both_branches() {
        let mut g = Graph::new("t", &[3, 8, 8]);
        let a = g.add(conv(3, 4, 1, Conv2dParams::default()), vec![0]);
        let b = g.add(conv(3, 4, 3, Conv2dParams::same(3)), vec![0]);
        let cat = g.add(Op::Concat, vec![a, b]);
        g.add(Op::Relu, vec![cat]);
        let s = optimize(&mut g);
        assert_eq!(s.fused_relu, 1);
        assert!(g.nodes[1].fused_relu && g.nodes[2].fused_relu);
        assert!(matches!(g.nodes[g.output].op, Op::Concat));
    }

    #[test]
    fn pad_elides_into_conv_but_not_max_pool() {
        let mut g = Graph::new("t", &[3, 8, 8]);
        let p = g.add(Op::Pad2d { ph: 1, pw: 1 }, vec![0]);
        let c = g.add(conv(3, 4, 3, Conv2dParams::default()), vec![p]);
        let p2 = g.add(Op::Pad2d { ph: 1, pw: 1 }, vec![c]);
        g.add(Op::MaxPool2d(PoolParams::square(2)), vec![p2]);
        let s = optimize(&mut g);
        // First pad absorbed; the max-pool one must survive (its
        // internal pad identity is −∞, not zero).
        assert_eq!(s.elided_pads, 1);
        let conv_node = &g.nodes[1];
        match &conv_node.op {
            Op::Conv2d { params, .. } => assert_eq!(params.pad, (1, 1)),
            other => panic!("expected conv, got {}", other.name()),
        }
        assert_eq!(conv_node.inputs, vec![0]);
        assert!(g.nodes.iter().any(|n| matches!(n.op, Op::Pad2d { .. })));
    }

    #[test]
    fn chained_pads_collapse_in_one_sweep() {
        let mut g = Graph::new("t", &[3, 8, 8]);
        let p1 = g.add(Op::Pad2d { ph: 1, pw: 0 }, vec![0]);
        let p2 = g.add(Op::Pad2d { ph: 0, pw: 1 }, vec![p1]);
        g.add(conv(3, 4, 3, Conv2dParams::default()), vec![p2]);
        let s = optimize(&mut g);
        assert_eq!(s.elided_pads, 2);
        match &g.nodes[1].op {
            Op::Conv2d { params, .. } => assert_eq!(params.pad, (1, 1)),
            other => panic!("expected conv, got {}", other.name()),
        }
        assert_eq!(g.nodes.len(), 2);
    }

    #[test]
    fn quant_hoists_only_between_quant_convs() {
        let mut g = Graph::new("t", &[3, 8, 8]);
        let q1 = g.add(qconv(3, 4, 3, Conv2dParams::same(3)), vec![0]);
        let q2 = g.add(qconv(4, 4, 3, Conv2dParams::same(3)), vec![q1]);
        let q3 = g.add(qconv(4, 2, 3, Conv2dParams::same(3)), vec![q2]);
        g.add(Op::Flatten, vec![q3]);
        let s = optimize(&mut g);
        // q1 and q2 feed quant convs; q3 feeds a flatten.
        assert_eq!(s.hoisted_quant, 2);
        assert!(g.nodes[1].quant_out && g.nodes[2].quant_out);
        assert!(!g.nodes[3].quant_out);
    }

    #[test]
    fn quant_does_not_hoist_across_pooling() {
        let mut g = Graph::new("t", &[3, 8, 8]);
        let q1 = g.add(qconv(3, 4, 3, Conv2dParams::same(3)), vec![0]);
        let m = g.add(Op::MaxPool2d(PoolParams::square(2)), vec![q1]);
        g.add(qconv(4, 4, 3, Conv2dParams::same(3)), vec![m]);
        let s = optimize(&mut g);
        assert_eq!(s.hoisted_quant, 0);
    }
}
