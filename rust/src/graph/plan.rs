//! The compiled-plan executor: evaluates an optimized [`Graph`] through
//! an [`ExecCtx`], honouring the fusion facts the passes left behind.
//!
//! Execution walks the nodes in index order (the graph is topological
//! by construction). Activations live in a slot array the plan owns for
//! the duration of the call: the model input is *borrowed* (node 0 —
//! the executor never clones it, unlike the historical
//! `Model::forward`), every other value is owned, and a tensor's buffer
//! is returned to the ctx arena the moment its last consumer has run —
//! so peak activation memory is the live frontier of the graph, not the
//! whole activation set, and the next node's output allocation is
//! usually served straight from the arena.
//!
//! Numerical contract: for every graph a [`crate::nn::Model`] lowers
//! to, `plan.run(x, ctx)` is **bit-identical** to the layer-by-layer
//! `model.forward(x, ctx)` in f32 and bf16, and exactly equal in i8 —
//! per algorithm, per ISA level, per thread count. The op bodies here
//! either are the very functions the layers call, or fused variants
//! whose exactness arguments live in [`super::passes`] and
//! [`crate::kernels::Epilogue`].

use super::ir::{Graph, Op};
use super::passes::PassSummary;
use super::planner::{PlanAlgo, PlannedChoice};
use super::tiling::{self, ChainTiling, Link, TileMode, TilingPlan};
use crate::exec::ExecCtx;
use crate::kernels::direct::conv2d_direct_epi_ctx;
use crate::kernels::im2col::{
    conv2d_im2col_epi_ctx, conv2d_im2col_lowmem_epi_ctx, conv2d_im2col_lowmem_q8_raw_ctx,
    conv2d_im2col_q8_raw_ctx,
};
use crate::kernels::region::{
    conv2d_sliding_bf16_region_ctx, conv2d_sliding_q8_region_ctx, conv2d_sliding_region_epi_ctx,
    pool2d_sliding_region, Rect, RegionScratch, SrcView,
};
use crate::kernels::sliding2d::{conv2d_sliding_epi_ctx, conv2d_sliding_q8_raw_ctx, SlideVariant};
use crate::kernels::{
    avg_pool2d_ctx, conv2d_bf16_epi_ctx, conv2d_epi_ctx, conv2d_q8_epi_ctx,
    conv2d_q8_raw_routed_ctx, dequantize_conv_acc, max_pool2d_ctx, quantize_conv_acc, Conv2dParams,
    Epilogue,
};
use crate::nn::layers::{
    concat_channels, global_avg_pool, linear_forward, softmax_rows_inplace, zero_pad2d,
};
use crate::tensor::{quantize, to_bf16, Dtype, QuantParams, Tensor, TensorT, WeightScales};

/// An activation value flowing along a graph edge.
enum Value {
    /// Ordinary f32 tensor.
    F32(Tensor),
    /// Hoisted quantize boundary: i8 codes plus their params, produced
    /// by a `quant_out` node and consumed directly by quantized convs.
    Q8(TensorT<i8>, QuantParams),
}

/// One activation slot during a plan run.
enum Slot<'a> {
    /// Not produced yet, or already recycled.
    Empty,
    /// The caller's input tensor (node 0) — never cloned.
    Borrowed(&'a Tensor),
    /// A plan-owned intermediate.
    Owned(Value),
}

/// An executable, optimized graph — what [`crate::nn::Model::compile`]
/// returns and what the serving backends share across replicas (the
/// weights inside the graph are cloned once at lowering, then the whole
/// plan travels behind an `Arc` exactly like the model it came from).
pub struct CompiledPlan {
    /// The optimized graph.
    pub graph: Graph,
    /// What the passes did (empty summary when compiled with fusion
    /// off).
    pub summary: PassSummary,
    /// Consumer count per node (+1 on the output), fixed at compile
    /// time; each run counts down a copy to recycle buffers eagerly.
    uses: Vec<usize>,
    /// Planner-assigned per-node kernel choices
    /// ([`CompiledPlan::with_choices`]); `None` = default routing. When
    /// present, conv nodes run the chosen algorithm with the chosen
    /// worker cap — bit-identical to the default route: int8 routes are
    /// exact, and an f32 choice is honoured only while it sits in the
    /// same FP-summation family as the ctx's own route
    /// ([`super::planner::f32_family_compatible`]); outside that family
    /// (a plan made for a different serving ctx) the node degrades to
    /// the ctx's routing, keeping the worker cap — capping is always
    /// value-safe.
    choices: Option<Vec<Option<PlannedChoice>>>,
    /// Tiled-execution plan ([`CompiledPlan::with_tiling`]); `None` = run
    /// node by node. Independently, the process-wide
    /// [`crate::graph::set_tiling_forced`] switch makes [`CompiledPlan::run`]
    /// analyze and tile every eligible chain on the fly.
    tiling: Option<TilingPlan>,
}

impl CompiledPlan {
    /// Wrap an optimized graph.
    pub(crate) fn new(graph: Graph, summary: PassSummary) -> Self {
        let uses = graph.consumer_counts();
        CompiledPlan { graph, summary, uses, choices: None, tiling: None }
    }

    /// Attach a planner-produced per-node choice vector (one entry per
    /// graph node; [`crate::graph::ModelPlan::choices`]). The executor
    /// then routes each planned conv node to its chosen kernel under
    /// its chosen worker cap.
    ///
    /// # Panics
    /// If the vector's length differs from the node count.
    pub fn with_choices(mut self, choices: Vec<Option<PlannedChoice>>) -> Self {
        assert_eq!(choices.len(), self.graph.nodes.len(), "one choice slot per node");
        self.choices = Some(choices);
        self
    }

    /// The attached per-node plan, if any.
    pub fn choices(&self) -> Option<&[Option<PlannedChoice>]> {
        self.choices.as_deref()
    }

    /// Attach a tiled-execution plan ([`crate::graph::tiling::analyze`]).
    /// Each chain then runs fused, tile by tile, through the halo-aware
    /// region kernels — bit-identical to the untiled path. Chains that no
    /// longer route to their analyzed links under the serving ctx (a plan
    /// made for a different ctx or dtype) degrade to untiled node-by-node
    /// execution, values unchanged.
    ///
    /// # Panics
    /// If a chain's node range or geometry length is inconsistent with
    /// the graph.
    pub fn with_tiling(mut self, tiling: TilingPlan) -> Self {
        for c in &tiling.chains {
            assert!(
                c.start >= 1 && c.start < c.end && c.end < self.graph.nodes.len(),
                "tiled chain {}..{} out of range",
                c.start,
                c.end
            );
        }
        self.tiling = Some(tiling);
        self
    }

    /// The attached tiling plan, if any.
    pub fn tiling(&self) -> Option<&TilingPlan> {
        self.tiling.as_ref()
    }

    /// Model name this plan was compiled from.
    pub fn name(&self) -> &str {
        &self.graph.name
    }

    /// Total FLOPs for one run at batch `n`.
    pub fn flops(&self, n: usize) -> u64 {
        self.graph.flops(n)
    }

    /// Activation bytes written per run at batch `n` (the fusion
    /// benchmark's memory-traffic metric).
    pub fn activation_bytes(&self, n: usize) -> u64 {
        self.graph.activation_bytes(n)
    }

    /// Render the optimized graph.
    pub fn render(&self) -> String {
        self.graph.render()
    }

    /// Execute the plan.
    ///
    /// # Panics
    /// If the input's per-example shape differs from the shape the
    /// model was lowered for.
    pub fn run(&self, x: &Tensor, ctx: &ExecCtx) -> Tensor {
        assert_eq!(
            &x.dims()[1..],
            &self.graph.input_shape[..],
            "plan for {} expects input {:?}",
            self.graph.name,
            self.graph.input_shape
        );
        let n = self.graph.nodes.len();
        // Tiled execution: an attached plan wins; otherwise the
        // process-wide force switch analyzes on the fly (a cheap graph
        // walk) against the actual ctx, choices and batch.
        let forced_tiling;
        let tiling = match &self.tiling {
            Some(t) => Some(t),
            None if super::tiling_forced() => {
                forced_tiling =
                    tiling::analyze(&self.graph, self.choices(), ctx, x.dim(0), TileMode::ForceAll);
                Some(&forced_tiling)
            }
            None => None,
        };
        let tiling = tiling.filter(|t| !t.is_empty());
        let mut slots: Vec<Slot> = Vec::with_capacity(n);
        slots.push(Slot::Borrowed(x));
        for _ in 1..n {
            slots.push(Slot::Empty);
        }
        let mut remaining = self.uses.clone();
        let mut id = 1;
        while id < n {
            if remaining[id] == 0 {
                id += 1;
                continue; // dead node (kept only in an uncompacted graph)
            }
            // A tiled chain starting here runs fused, tile by tile; its
            // intermediates never materialise at full size. The chain
            // must still route to the analyzed links under *this* ctx —
            // an attached plan may have been made for another — else the
            // nodes simply run untiled below (same values).
            if let Some(chain) = tiling.and_then(|t| t.chain_starting_at(id)) {
                if self.chain_valid(chain, ctx) {
                    let value = self.run_chain_tiled(chain, &slots, ctx);
                    slots[chain.end] = Slot::Owned(value);
                    let head_in = self.graph.nodes[id].inputs[0];
                    remaining[head_in] -= 1;
                    if remaining[head_in] == 0 {
                        recycle_slot(&mut slots, head_in, ctx);
                    }
                    // Interior nodes never materialised, so there is
                    // nothing to recycle — just retire their counts.
                    for r in &mut remaining[id..chain.end] {
                        *r = 0;
                    }
                    id = chain.end + 1;
                    continue;
                }
            }
            let value = self.eval(id, &slots, ctx);
            slots[id] = Slot::Owned(value);
            for &i in &self.graph.nodes[id].inputs {
                remaining[i] -= 1;
                if remaining[i] == 0 {
                    recycle_slot(&mut slots, i, ctx);
                }
            }
            id += 1;
        }
        match std::mem::replace(&mut slots[self.graph.output], Slot::Empty) {
            Slot::Owned(Value::F32(t)) => t,
            Slot::Borrowed(t) => t.clone(), // identity graph
            Slot::Owned(Value::Q8(..)) => {
                unreachable!("the passes never hoist the output node")
            }
            Slot::Empty => unreachable!("output slot was recycled"),
        }
    }

    /// The planner's choice for node `id`, when a plan is attached.
    fn choice_at(&self, id: usize) -> Option<&PlannedChoice> {
        self.choices.as_ref().and_then(|c| c[id].as_ref())
    }

    /// Does this chain still route to its analyzed links under the
    /// running ctx and the attached choices? An attached tiling plan
    /// may have been computed for a different serving ctx; a mismatched
    /// chain runs untiled instead (same values, untiled footprint).
    fn chain_valid(&self, chain: &ChainTiling, ctx: &ExecCtx) -> bool {
        chain.geoms.len() == chain.end - chain.start + 1
            && (chain.start..=chain.end).all(|id| {
                let node = &self.graph.nodes[id];
                tiling::link_kind(node, self.choice_at(id), ctx, id == chain.start)
                    == Some(chain.geoms[id - chain.start].link)
            })
    }

    /// Execute one tiled chain: each tile of the chain-end output plane
    /// runs the whole chain through the halo-aware region kernels
    /// ([`crate::kernels::region`]), per-tile intermediates recycle
    /// through the ctx arena, and tiles fan out across the worker pool
    /// (tile = work item). Planned per-node worker caps are ignored
    /// inside a chain — the tile grid is the parallel unit — which is
    /// value-safe: thread counts never change results. Bit-identical to
    /// the untiled node-by-node path by the region kernels' contract.
    fn run_chain_tiled(&self, chain: &ChainTiling, slots: &[Slot<'_>], ctx: &ExecCtx) -> Value {
        let head = &self.graph.nodes[chain.start];
        let head_in = &slots[head.inputs[0]];
        let head_f32: Option<&Tensor> = match head_in {
            Slot::Borrowed(t) => Some(*t),
            Slot::Owned(Value::F32(t)) => Some(t),
            _ => None,
        };
        let head_codes: Option<(&TensorT<i8>, QuantParams)> = match head_in {
            Slot::Owned(Value::Q8(c, q)) => Some((c, *q)),
            _ => None,
        };
        let n = head_f32
            .map(|t| t.dim(0))
            .or_else(|| head_codes.map(|(c, _)| c.dim(0)))
            .expect("chain head input not materialised");
        // Chain-invariant weight/input preparation, hoisted out of the
        // tile loop — exactly what the untiled eval computes per node.
        // An int8 head over an f32 input quantizes the *whole* tensor
        // once (QuantParams::for_tensor must see every element).
        let q8_head: Option<(TensorT<i8>, QuantParams)> = match (chain.geoms[0].link, head_f32) {
            (Link::ConvQ8, Some(x)) => {
                let xq = QuantParams::for_tensor(x);
                Some((quantize(x, xq), xq))
            }
            _ => None,
        };
        let q8_w: Option<(TensorT<i8>, WeightScales)> = match (&head.op, chain.geoms[0].link) {
            (Op::Conv2d { w, .. }, Link::ConvQ8) => {
                let wq = QuantParams::for_tensor(w);
                Some((quantize(w, wq), WeightScales::PerTensor(wq)))
            }
            _ => None,
        };
        let mut bf16_w: Vec<Option<(Vec<f32>, (usize, usize, usize, usize))>> =
            vec![None; chain.geoms.len()];
        for (j, g) in chain.geoms.iter().enumerate() {
            if g.link == Link::ConvBf16 {
                if let Op::Conv2d { w, .. } = &self.graph.nodes[chain.start + j].op {
                    let wf: Vec<f32> = to_bf16(w).as_slice().iter().map(|b| b.to_f32()).collect();
                    bf16_w[j] = Some((wf, (w.dim(0), w.dim(1), w.dim(2), w.dim(3))));
                }
            }
        }
        let head_codes: Option<(&TensorT<i8>, QuantParams)> =
            head_codes.or_else(|| q8_head.as_ref().map(|(c, q)| (c, *q)));
        let lg = chain.geoms.last().expect("chains have >= 2 nodes");
        let (oh, ow) = lg.out_hw;
        let c_out = lg.c_out;
        let mut out = ctx.take_unfilled(n * c_out * oh * ow);
        let out_ptr = SendPtr(out.as_mut_ptr());
        let tiles = chain.tiles();
        let mut items = vec![0u8; tiles.len()];
        ctx.par_chunks_with(
            &mut items,
            1,
            || TileScratch {
                a: ctx.take_unfilled(0),
                b: ctx.take_unfilled(0),
                rs: RegionScratch::from_ctx(ctx),
            },
            |ti, _item, scr| {
                self.eval_chain_tile(
                    chain,
                    tiles[ti],
                    head_f32,
                    head_codes,
                    q8_w.as_ref(),
                    &bf16_w,
                    n,
                    out_ptr,
                    scr,
                    ctx,
                );
            },
            |scr| {
                ctx.put(scr.a);
                ctx.put(scr.b);
                scr.rs.release(ctx);
            },
        );
        Value::F32(Tensor::from_vec(out, &[n, c_out, oh, ow]))
    }

    /// One tile of one chain: walk the links start → end over the
    /// tile's backward halo rects, ping-ponging two per-worker buffers,
    /// then copy the final dense tile into its rect of the chain
    /// output.
    #[allow(clippy::too_many_arguments)]
    fn eval_chain_tile(
        &self,
        chain: &ChainTiling,
        tile: Rect,
        head_f32: Option<&Tensor>,
        head_codes: Option<(&TensorT<i8>, QuantParams)>,
        q8_w: Option<&(TensorT<i8>, WeightScales)>,
        bf16_w: &[Option<(Vec<f32>, (usize, usize, usize, usize))>],
        n: usize,
        out_ptr: SendPtr<f32>,
        scr: &mut TileScratch,
        ctx: &ExecCtx,
    ) {
        let rects = chain.backward_rects(tile);
        let TileScratch { a, b, rs } = scr;
        // After the head link the live value sits in `a`; every
        // non-identity link thereafter flips buffers.
        let mut cur_in_a = true;
        for (j, g) in chain.geoms.iter().enumerate() {
            let node = &self.graph.nodes[chain.start + j];
            let r = rects[j];
            if j == 0 {
                let full = Rect::full(g.in_hw.0, g.in_hw.1);
                a.clear();
                a.resize(n * g.c_out * r.area(), 0.0);
                match g.link {
                    Link::ConvF32(variant) => {
                        let Op::Conv2d { w, bias, params } = &node.op else {
                            unreachable!("ConvF32 links are Conv2d nodes")
                        };
                        let x = head_f32.expect("f32 chain head input");
                        let src =
                            SrcView { data: x.as_slice(), c: g.c_in, rect: full, full: g.in_hw };
                        let epi = Epilogue::from_bias(Some(bias)).with_relu(node.fused_relu);
                        conv2d_sliding_region_epi_ctx(
                            n, &src, w, epi, params, variant, r, &mut *a, &mut *rs, ctx,
                        );
                    }
                    Link::ConvBf16 => {
                        let Op::Conv2d { bias, params, .. } = &node.op else {
                            unreachable!("ConvBf16 links are Conv2d nodes")
                        };
                        let x = head_f32.expect("f32 chain head input");
                        let src =
                            SrcView { data: x.as_slice(), c: g.c_in, rect: full, full: g.in_hw };
                        let (wf, wdims) = bf16_w[0].as_ref().expect("bf16 weights prepared");
                        conv2d_sliding_bf16_region_ctx(
                            n,
                            &src,
                            wf,
                            *wdims,
                            Some(bias),
                            node.fused_relu,
                            params,
                            r,
                            &mut *a,
                            &mut *rs,
                            ctx,
                        );
                    }
                    Link::ConvQ8 => {
                        let (codes, xq) = head_codes.expect("int8 chain head input");
                        let (qw, wq): (&TensorT<i8>, &WeightScales) = match &node.op {
                            Op::QuantConv2d { qw, wq, .. } => (qw, wq),
                            Op::Conv2d { .. } => {
                                let (qw, wq) = q8_w.expect("int8 weights prepared");
                                (qw, wq)
                            }
                            _ => unreachable!("ConvQ8 links are conv nodes"),
                        };
                        let (bias, params) = match &node.op {
                            Op::Conv2d { bias, params, .. }
                            | Op::QuantConv2d { bias, params, .. } => (bias, params),
                            _ => unreachable!(),
                        };
                        let src = SrcView {
                            data: codes.as_slice(),
                            c: g.c_in,
                            rect: full,
                            full: g.in_hw,
                        };
                        conv2d_sliding_q8_region_ctx(
                            n,
                            &src,
                            qw,
                            xq,
                            wq,
                            Some(bias),
                            node.fused_relu,
                            params,
                            r,
                            &mut *a,
                            &mut *rs,
                            ctx,
                        );
                    }
                    Link::Pool(max) => {
                        let (Op::MaxPool2d(p) | Op::AvgPool2d(p)) = &node.op else {
                            unreachable!("Pool links are pool nodes")
                        };
                        let x = head_f32.expect("f32 chain head input");
                        let src =
                            SrcView { data: x.as_slice(), c: g.c_in, rect: full, full: g.in_hw };
                        pool2d_sliding_region(n, &src, p, max, r, &mut *a, &mut *rs);
                    }
                    Link::Relu => {
                        // Cannot mutate the borrowed head input: crop
                        // the tile's rect while applying the max.
                        let x = head_f32.expect("f32 chain head input");
                        let src =
                            SrcView { data: x.as_slice(), c: g.c_in, rect: full, full: g.in_hw };
                        relu_crop(&src, n, r, &mut *a);
                    }
                }
            } else {
                let prev = rects[j - 1];
                if g.link == Link::Relu {
                    // Identity geometry (rects[j] == rects[j-1]): apply
                    // in place — the untiled elementwise max exactly.
                    let buf: &mut Vec<f32> = if cur_in_a { &mut *a } else { &mut *b };
                    for v in buf.iter_mut() {
                        *v = v.max(0.0);
                    }
                    continue;
                }
                let (src_buf, dst_buf): (&Vec<f32>, &mut Vec<f32>) =
                    if cur_in_a { (&*a, &mut *b) } else { (&*b, &mut *a) };
                dst_buf.clear();
                dst_buf.resize(n * g.c_out * r.area(), 0.0);
                let src =
                    SrcView { data: src_buf.as_slice(), c: g.c_in, rect: prev, full: g.in_hw };
                match g.link {
                    Link::ConvF32(variant) => {
                        let Op::Conv2d { w, bias, params } = &node.op else {
                            unreachable!("ConvF32 links are Conv2d nodes")
                        };
                        let epi = Epilogue::from_bias(Some(bias)).with_relu(node.fused_relu);
                        conv2d_sliding_region_epi_ctx(
                            n, &src, w, epi, params, variant, r, dst_buf, &mut *rs, ctx,
                        );
                    }
                    Link::ConvBf16 => {
                        let Op::Conv2d { bias, params, .. } = &node.op else {
                            unreachable!("ConvBf16 links are Conv2d nodes")
                        };
                        let (wf, wdims) = bf16_w[j].as_ref().expect("bf16 weights prepared");
                        conv2d_sliding_bf16_region_ctx(
                            n,
                            &src,
                            wf,
                            *wdims,
                            Some(bias),
                            node.fused_relu,
                            params,
                            r,
                            dst_buf,
                            &mut *rs,
                            ctx,
                        );
                    }
                    Link::Pool(max) => {
                        let (Op::MaxPool2d(p) | Op::AvgPool2d(p)) = &node.op else {
                            unreachable!("Pool links are pool nodes")
                        };
                        pool2d_sliding_region(n, &src, p, max, r, dst_buf, &mut *rs);
                    }
                    Link::ConvQ8 | Link::Relu => {
                        unreachable!("int8 links are head-only; Relu handled above")
                    }
                }
                cur_in_a = !cur_in_a;
            }
        }
        // Strided copy of the dense tile into its output rect.
        let fin: &[f32] = if cur_in_a { a } else { b };
        let lg = chain.geoms.last().expect("chains have >= 2 nodes");
        let (oh, ow) = lg.out_hw;
        let (th, tw) = (tile.h(), tile.w());
        debug_assert_eq!(fin.len(), n * lg.c_out * th * tw);
        for ni in 0..n {
            for co in 0..lg.c_out {
                let splane = &fin[(ni * lg.c_out + co) * th * tw..][..th * tw];
                let base = (ni * lg.c_out + co) * oh * ow + tile.y0 * ow + tile.x0;
                for ty in 0..th {
                    // SAFETY: each tile writes only its own disjoint
                    // rect of the output planes, and par_chunks_with
                    // joins all workers before `out` is read.
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            splane[ty * tw..].as_ptr(),
                            out_ptr.0.add(base + ty * ow),
                            tw,
                        );
                    }
                }
            }
        }
    }

    fn eval(&self, id: usize, slots: &[Slot<'_>], ctx: &ExecCtx) -> Value {
        let node = &self.graph.nodes[id];
        let f32_in = |i: usize| -> &Tensor {
            match &slots[node.inputs[i]] {
                Slot::Borrowed(t) => t,
                Slot::Owned(Value::F32(t)) => t,
                Slot::Owned(Value::Q8(..)) => {
                    panic!("{} fed i8 activations it cannot consume", node.op.name())
                }
                Slot::Empty => panic!("{} input not materialised", node.op.name()),
            }
        };
        match &node.op {
            Op::Input => unreachable!("node 0 is pre-filled"),
            Op::Conv2d { w, bias, params } => {
                let x = f32_in(0);
                let choice = self.choice_at(id);
                let _cap = choice.map(|c| CapGuard::set(ctx, c.threads));
                // Mirrors Conv2d::forward's dtype dispatch, with the
                // fused epilogue threaded into each route; a planned
                // node runs its chosen kernel instead of the ctx-wide
                // routing (same values either way — the plan only picks
                // among parity-tested implementations).
                Value::F32(match ctx.dtype() {
                    Dtype::F32 | Dtype::I32 => {
                        let epi = Epilogue::from_bias(Some(bias)).with_relu(node.fused_relu);
                        // An f32 choice is honoured only inside the
                        // ctx route's FP-summation family — a plan made
                        // for another serving ctx must never change
                        // bits, so it degrades to the ctx's routing
                        // (the worker cap above still applies).
                        let route = super::planner::default_route(ctx, w.dim(3), ctx.dtype());
                        match choice {
                            Some(c) if super::planner::f32_family_compatible(c.algo, route) => {
                                conv2d_planned_epi_ctx(x, w, epi, params, c.algo, ctx)
                            }
                            _ => conv2d_epi_ctx(x, w, epi, params, ctx),
                        }
                    }
                    // bf16 is a sliding-only dtype: the planned route
                    // and the default route are the same kernel.
                    Dtype::Bf16 => {
                        conv2d_bf16_epi_ctx(x, w, Some(bias), node.fused_relu, params, ctx)
                    }
                    Dtype::I8 => {
                        let wq = QuantParams::for_tensor(w);
                        let qw = quantize(w, wq);
                        match choice {
                            Some(c) => {
                                // conv2d_q8_epi_ctx's exact sequence
                                // with the raw kernel forced to the
                                // planned algorithm (exact i32 either
                                // way).
                                let xq = QuantParams::for_tensor(x);
                                let qx = quantize(x, xq);
                                let raw =
                                    conv2d_q8_raw_planned_ctx(&qx, &qw, params, c.algo, ctx);
                                dequantize_conv_acc(
                                    &raw,
                                    xq,
                                    &WeightScales::PerTensor(wq),
                                    Some(bias),
                                    node.fused_relu,
                                )
                            }
                            None => conv2d_q8_epi_ctx(
                                x,
                                &qw,
                                &WeightScales::PerTensor(wq),
                                Some(bias),
                                node.fused_relu,
                                params,
                                ctx,
                            ),
                        }
                    }
                })
            }
            Op::QuantConv2d { qw, wq, bias, params } => {
                let choice = self.choice_at(id);
                let _cap = choice.map(|c| CapGuard::set(ctx, c.threads));
                let raw_of = |qx: &TensorT<i8>| match choice {
                    Some(c) => conv2d_q8_raw_planned_ctx(qx, qw, params, c.algo, ctx),
                    None => conv2d_q8_raw_routed_ctx(qx, qw, params, ctx),
                };
                match &slots[node.inputs[0]] {
                    Slot::Owned(Value::Q8(qx, xq)) => {
                        // Hoisted boundary: consume the producer's codes
                        // directly — no f32 tensor in between.
                        let raw = raw_of(qx);
                        if node.quant_out {
                            let (codes, q) =
                                quantize_conv_acc(&raw, *xq, wq, Some(bias), node.fused_relu);
                            Value::Q8(codes, q)
                        } else {
                            Value::F32(dequantize_conv_acc(
                                &raw,
                                *xq,
                                wq,
                                Some(bias),
                                node.fused_relu,
                            ))
                        }
                    }
                    _ => {
                        let x = f32_in(0);
                        let xq = QuantParams::for_tensor(x);
                        let qx = quantize(x, xq);
                        let raw = raw_of(&qx);
                        if node.quant_out {
                            let (codes, q) =
                                quantize_conv_acc(&raw, xq, wq, Some(bias), node.fused_relu);
                            Value::Q8(codes, q)
                        } else {
                            // The conv2d_q8_epi_ctx sequence inlined:
                            // dynamic per-tensor activation quantization
                            // around the routed (or planned) raw kernel.
                            Value::F32(dequantize_conv_acc(
                                &raw,
                                xq,
                                wq,
                                Some(bias),
                                node.fused_relu,
                            ))
                        }
                    }
                }
            }
            Op::Linear { w, bias } => {
                Value::F32(linear_forward(f32_in(0), w, bias, node.fused_relu))
            }
            Op::Relu => Value::F32(f32_in(0).map(|v| v.max(0.0))),
            Op::Softmax => {
                let mut y = f32_in(0).clone();
                softmax_rows_inplace(&mut y);
                Value::F32(y)
            }
            Op::Flatten => {
                let x = f32_in(0);
                let shape = [x.dim(0), x.numel() / x.dim(0)];
                Value::F32(x.clone().reshape(&shape))
            }
            Op::MaxPool2d(p) => Value::F32(max_pool2d_ctx(f32_in(0), p, ctx)),
            Op::AvgPool2d(p) => Value::F32(avg_pool2d_ctx(f32_in(0), p, ctx)),
            Op::GlobalAvgPool => Value::F32(global_avg_pool(f32_in(0))),
            Op::Pad2d { ph, pw } => Value::F32(zero_pad2d(f32_in(0), *ph, *pw)),
            Op::Concat => Value::F32(concat_channels(f32_in(0), f32_in(1))),
            Op::Opaque(l) => Value::F32(l.forward(f32_in(0), ctx)),
        }
    }
}

/// Return a slot's buffer to the ctx arena once its last consumer ran.
/// Borrowed slots (the caller's input) are simply dropped.
fn recycle_slot(slots: &mut [Slot<'_>], i: usize, ctx: &ExecCtx) {
    if let Slot::Owned(v) = std::mem::replace(&mut slots[i], Slot::Empty) {
        match v {
            Value::F32(t) => ctx.put(t.into_vec()),
            Value::Q8(codes, _) => ctx.put_elems(codes.into_vec()),
        }
    }
}

/// Raw output pointer a tile fan-out shares across workers. Each tile
/// writes a disjoint rect of the output planes, so concurrent writes
/// never alias.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Per-worker tile state: two ping-pong intermediate buffers plus the
/// region kernels' scratch, checked out of the ctx arena once per worker
/// (the `par_chunks_with` init/fini hooks).
struct TileScratch {
    a: Vec<f32>,
    b: Vec<f32>,
    rs: RegionScratch,
}

/// Crop `r` out of a source view while applying `max(0)` — a ReLU at
/// the head of a tiled chain, where the input tensor is borrowed and
/// cannot be rewritten in place.
fn relu_crop(src: &SrcView<'_, f32>, n: usize, r: Rect, dst: &mut [f32]) {
    let (rh, rw) = (src.rect.h(), src.rect.w());
    let (th, tw) = (r.h(), r.w());
    for ni in 0..n {
        for ci in 0..src.c {
            let plane = &src.data[(ni * src.c + ci) * rh * rw..][..rh * rw];
            let dplane = &mut dst[(ni * src.c + ci) * th * tw..][..th * tw];
            for ty in 0..th {
                let sy = r.y0 + ty - src.rect.y0;
                let srow = &plane[sy * rw + (r.x0 - src.rect.x0)..][..tw];
                for (d, s) in dplane[ty * tw..][..tw].iter_mut().zip(srow) {
                    *d = s.max(0.0);
                }
            }
        }
    }
}

/// RAII worker cap for one planned node's kernels: narrows the ctx to
/// the plan's worker count on construction, clears the cap on drop —
/// panic included — so the next node starts uncapped. Capping is a pure
/// footprint/speed knob: partitioning is deterministic per worker
/// count, so results stay bit-identical.
struct CapGuard<'a> {
    ctx: &'a ExecCtx,
}

impl<'a> CapGuard<'a> {
    fn set(ctx: &'a ExecCtx, threads: usize) -> Self {
        ctx.set_thread_cap(threads);
        CapGuard { ctx }
    }
}

impl Drop for CapGuard<'_> {
    fn drop(&mut self) {
        self.ctx.set_thread_cap(0);
    }
}

/// Forced f32 conv routing for a planned node: run exactly the kernel
/// the planner chose. The caller has already checked the choice sits in
/// the ctx route's bitwise family, so the choice affects footprint and
/// speed, never values.
fn conv2d_planned_epi_ctx(
    x: &Tensor,
    w: &Tensor,
    epi: Epilogue<'_>,
    p: &Conv2dParams,
    algo: PlanAlgo,
    ctx: &ExecCtx,
) -> Tensor {
    match algo {
        PlanAlgo::Direct => conv2d_direct_epi_ctx(x, w, epi, p, ctx),
        PlanAlgo::Gemm => conv2d_im2col_epi_ctx(x, w, epi, p, ctx),
        PlanAlgo::GemmLowMem => conv2d_im2col_lowmem_epi_ctx(x, w, epi, p, ctx),
        PlanAlgo::Sliding => conv2d_sliding_epi_ctx(x, w, epi, p, SlideVariant::Auto, ctx),
    }
}

/// Forced int8 raw accumulation for a planned node. All three kernels
/// produce the identical exact-i32 accumulator; `Direct` (which has no
/// int8 kernel, and which the planner never emits for int8) degrades to
/// the sliding kernel — same values.
fn conv2d_q8_raw_planned_ctx(
    qx: &TensorT<i8>,
    qw: &TensorT<i8>,
    p: &Conv2dParams,
    algo: PlanAlgo,
    ctx: &ExecCtx,
) -> TensorT<i32> {
    match algo {
        PlanAlgo::Gemm => conv2d_im2col_q8_raw_ctx(qx, qw, p, ctx),
        PlanAlgo::GemmLowMem => conv2d_im2col_lowmem_q8_raw_ctx(qx, qw, p, ctx),
        PlanAlgo::Direct | PlanAlgo::Sliding => conv2d_sliding_q8_raw_ctx(qx, qw, p, ctx),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::passes::optimize;
    use crate::kernels::{Conv2dParams, ConvAlgo};
    use crate::nn::layers::{Conv2d, Layer, QuantizedConv2d, ReLU};

    fn plan_of(mut g: Graph, fuse: bool) -> CompiledPlan {
        let summary = if fuse { optimize(&mut g) } else { PassSummary::default() };
        CompiledPlan::new(g, summary)
    }

    #[test]
    fn fused_conv_relu_is_bit_identical_to_layers() {
        let conv = Conv2d::new(3, 4, 3, Conv2dParams::same(3), 61);
        let x = Tensor::randn(&[2, 3, 10, 10], 62);
        for algo in [ConvAlgo::Direct, ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            let ctx = ExecCtx::new(algo);
            let want = ReLU.forward(&conv.forward(&x, &ctx), &ctx);

            let mut g = Graph::new("t", &[3, 10, 10]);
            let c = conv.lower_into(&mut g, 0).unwrap();
            g.add(Op::Relu, vec![c]);
            let plan = plan_of(g, true);
            assert_eq!(plan.summary.fused_relu, 1);
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
        }
    }

    #[test]
    fn hoisted_quant_chain_matches_unfused_exactly() {
        let q1 = QuantizedConv2d::new(3, 4, 3, Conv2dParams::same(3), 63);
        let q2 = QuantizedConv2d::new(4, 2, 3, Conv2dParams::same(3), 64);
        let x = Tensor::randn(&[1, 3, 9, 9], 65);
        for algo in [ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            let ctx = ExecCtx::new(algo);
            let want = q2.forward(&q1.forward(&x, &ctx), &ctx);

            let mut g = Graph::new("t", &[3, 9, 9]);
            let a = q1.lower_into(&mut g, 0).unwrap();
            q2.lower_into(&mut g, a).unwrap();
            let plan = plan_of(g, true);
            assert_eq!(plan.summary.hoisted_quant, 1);
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
        }
    }

    #[test]
    fn elided_pad_matches_explicit_pad_layer() {
        let conv = Conv2d::new(2, 3, 3, Conv2dParams::default(), 66);
        let x = Tensor::randn(&[1, 2, 8, 8], 67);
        for algo in [ConvAlgo::Direct, ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            let ctx = ExecCtx::new(algo);
            let padded = zero_pad2d(&x, 1, 1);
            let want = conv.forward(&padded, &ctx);

            let mut g = Graph::new("t", &[2, 8, 8]);
            let p = g.add(Op::Pad2d { ph: 1, pw: 1 }, vec![0]);
            conv.lower_into(&mut g, p).unwrap();
            let plan = plan_of(g, true);
            assert_eq!(plan.summary.elided_pads, 1);
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
        }
    }

    #[test]
    fn unfused_plan_reproduces_the_graph_verbatim() {
        let conv = Conv2d::new(3, 4, 3, Conv2dParams::same(3), 68);
        let x = Tensor::randn(&[1, 3, 8, 8], 69);
        let ctx = ExecCtx::default();
        let want = ReLU.forward(&conv.forward(&x, &ctx), &ctx);

        let mut g = Graph::new("t", &[3, 8, 8]);
        let c = conv.lower_into(&mut g, 0).unwrap();
        g.add(Op::Relu, vec![c]);
        let plan = plan_of(g, false);
        assert_eq!(plan.summary, PassSummary::default());
        assert_eq!(plan.graph.nodes.len(), 3);
        assert_eq!(plan.run(&x, &ctx).as_slice(), want.as_slice());
    }

    #[test]
    #[should_panic(expected = "expects input")]
    fn plan_rejects_wrong_input_shape() {
        let g = Graph::new("t", &[3, 8, 8]);
        let plan = plan_of(g, false);
        plan.run(&Tensor::zeros(&[1, 3, 4, 4]), &ExecCtx::default());
    }

    fn conv_relu_plan(conv: &Conv2d) -> CompiledPlan {
        let mut g = Graph::new("t", &[3, 16, 16]);
        let c = conv.lower_into(&mut g, 0).unwrap();
        g.add(Op::Relu, vec![c]);
        plan_of(g, true)
    }

    fn forced(
        algo: PlanAlgo,
        threads: usize,
        dtype: Dtype,
        nodes: usize,
    ) -> Vec<Option<PlannedChoice>> {
        let mut choices = vec![None; nodes];
        choices[1] = Some(PlannedChoice {
            algo,
            threads,
            dtype,
            workspace_bytes: 0,
            predicted_gflops: 1.0,
        });
        choices
    }

    #[test]
    fn planned_f32_choices_route_bit_identically_within_the_gemm_family() {
        // One-shot ↔ strip GEMM is the real f32 interchange: under a
        // GEMM-routed ctx, both forced choices reproduce the default
        // route bit for bit (the strip decomposition is order-exact).
        let conv = Conv2d::new(3, 4, 5, Conv2dParams::same(5), 71);
        let x = Tensor::randn(&[2, 3, 16, 16], 72);
        let ctx = ExecCtx::with_threads(ConvAlgo::Im2colGemm, 4);
        let want = conv_relu_plan(&conv).run(&x, &ctx);
        for algo in [PlanAlgo::Gemm, PlanAlgo::GemmLowMem] {
            let plan = conv_relu_plan(&conv);
            let n = plan.graph.nodes.len();
            let plan = plan.with_choices(forced(algo, 2, Dtype::F32, n));
            assert!(plan.choices().is_some());
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
            assert_eq!(ctx.threads(), 4, "{algo:?}: cap must clear after the node");
        }
    }

    #[test]
    fn cross_family_f32_choices_degrade_to_the_ctx_route() {
        // A plan made for a different serving ctx must never change
        // bits: an out-of-family forced algorithm keeps the ctx's own
        // routing (only the worker cap — always value-safe — applies).
        let conv = Conv2d::new(3, 4, 5, Conv2dParams::same(5), 71);
        let x = Tensor::randn(&[2, 3, 16, 16], 72);
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4);
        let want = conv_relu_plan(&conv).run(&x, &ctx);
        for algo in [PlanAlgo::Direct, PlanAlgo::Gemm, PlanAlgo::GemmLowMem] {
            let plan = conv_relu_plan(&conv);
            let n = plan.graph.nodes.len();
            let plan = plan.with_choices(forced(algo, 2, Dtype::F32, n));
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
        }
        // The in-family choice still routes bit-identically.
        let plan = conv_relu_plan(&conv);
        let n = plan.graph.nodes.len();
        let plan = plan.with_choices(forced(PlanAlgo::Sliding, 2, Dtype::F32, n));
        assert_eq!(plan.run(&x, &ctx).as_slice(), want.as_slice());
    }

    #[test]
    fn planned_q8_choices_route_exactly() {
        let q = QuantizedConv2d::new(3, 4, 3, Conv2dParams::same(3), 73);
        let x = Tensor::randn(&[1, 3, 12, 12], 74);
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 2);
        let build = || {
            let mut g = Graph::new("t", &[3, 12, 12]);
            q.lower_into(&mut g, 0).unwrap();
            plan_of(g, true)
        };
        let want = build().run(&x, &ctx);
        for algo in [PlanAlgo::Sliding, PlanAlgo::Gemm, PlanAlgo::GemmLowMem] {
            let plan = build();
            let n = plan.graph.nodes.len();
            let plan = plan.with_choices(forced(algo, 1, Dtype::I8, n));
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
        }
    }

    #[test]
    #[should_panic(expected = "one choice slot per node")]
    fn with_choices_rejects_wrong_length() {
        let conv = Conv2d::new(3, 4, 3, Conv2dParams::same(3), 75);
        let mut g = Graph::new("t", &[3, 16, 16]);
        conv.lower_into(&mut g, 0).unwrap();
        plan_of(g, false).with_choices(vec![None]);
    }

    /// conv(fused relu) → conv → maxpool on a 13×11 input — a 3-link
    /// chain with a "same"-padded k=5 middle conv and a strided pool,
    /// so tile halos cross both padding and stride boundaries.
    fn deep_chain_plan() -> (Conv2d, Conv2d, CompiledPlan) {
        let c1 = Conv2d::new(3, 8, 3, Conv2dParams::same(3), 81);
        let c2 = Conv2d::new(8, 6, 5, Conv2dParams::same(5), 82);
        let mut g = Graph::new("t", &[3, 13, 11]);
        let a = c1.lower_into(&mut g, 0).unwrap();
        let r = g.add(Op::Relu, vec![a]);
        let b = c2.lower_into(&mut g, r).unwrap();
        g.add(Op::MaxPool2d(crate::kernels::PoolParams::with_stride(2, 2)), vec![b]);
        (c1, c2, plan_of(g, true))
    }

    #[test]
    fn attached_tiling_is_bit_identical_across_dtypes_threads_and_tiles() {
        // The hard contract: tiled execution reproduces the untiled
        // path bit for bit — every dtype, thread count and tile shape,
        // including degenerate 1×W strips and the full output plane.
        let x = Tensor::randn(&[2, 3, 13, 11], 83);
        for dtype in [Dtype::F32, Dtype::Bf16, Dtype::I8] {
            for threads in [1, 4] {
                let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, threads).with_dtype(dtype);
                let (_, _, plan) = deep_chain_plan();
                let want = plan.run(&x, &ctx);
                for tile in [(1, 64), (3, 4), (2, 1), (64, 64)] {
                    let (_, _, plan) = deep_chain_plan();
                    let t = tiling::analyze_with(
                        &plan.graph,
                        None,
                        &ctx,
                        2,
                        TileMode::ForceAll,
                        u64::MAX,
                        Some(tile),
                    );
                    assert!(!t.is_empty(), "{dtype:?}: chain expected");
                    let got = plan.with_tiling(t).run(&x, &ctx);
                    assert_eq!(
                        got.as_slice(),
                        want.as_slice(),
                        "{dtype:?} threads={threads} tile={tile:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn forced_tiling_switch_is_bit_identical() {
        // The SWCONV_FORCE_TILE path: run() analyzes on the fly.
        let x = Tensor::randn(&[2, 3, 13, 11], 85);
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 2);
        let (_, _, plan) = deep_chain_plan();
        let want = plan.run(&x, &ctx);
        crate::graph::set_forced_tile_shape(Some((3, 5)));
        crate::graph::set_tiling_forced(true);
        let (_, _, plan) = deep_chain_plan();
        let got = plan.run(&x, &ctx);
        crate::graph::set_tiling_forced(false);
        crate::graph::set_forced_tile_shape(None);
        assert_eq!(got.as_slice(), want.as_slice());
    }

    #[test]
    fn tiled_hoisted_quant_chain_matches_untiled_exactly() {
        // A QuantConv2d head consuming hoisted i8 codes: the q8 region
        // kernel runs over the code plane directly.
        let q1 = QuantizedConv2d::new(3, 4, 3, Conv2dParams::same(3), 86);
        let q2 = QuantizedConv2d::new(4, 2, 3, Conv2dParams::same(3), 87);
        let x = Tensor::randn(&[1, 3, 9, 9], 88);
        let build = || {
            let mut g = Graph::new("t", &[3, 9, 9]);
            let a = q1.lower_into(&mut g, 0).unwrap();
            let b = q2.lower_into(&mut g, a).unwrap();
            g.add(Op::Relu, vec![b]);
            plan_of(g, true)
        };
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 2);
        let want = build().run(&x, &ctx);
        let plan = build();
        let t = tiling::analyze_with(&plan.graph, None, &ctx, 1, TileMode::ForceAll, u64::MAX, Some((2, 3)));
        assert!(!t.is_empty(), "quant-head chain expected");
        let got = plan.with_tiling(t).run(&x, &ctx);
        assert_eq!(got.as_slice(), want.as_slice());
    }

    #[test]
    fn attached_tiling_degrades_safely_under_another_ctx() {
        // Tiling analyzed for a sliding ctx but served under a GEMM
        // ctx: the links no longer match, so the chain silently runs
        // untiled — values unchanged.
        let x = Tensor::randn(&[2, 3, 13, 11], 89);
        let sliding = ExecCtx::new(ConvAlgo::Sliding);
        let (_, _, plan) = deep_chain_plan();
        let t = tiling::analyze_with(
            &plan.graph,
            None,
            &sliding,
            2,
            TileMode::ForceAll,
            u64::MAX,
            Some((3, 4)),
        );
        assert!(!t.is_empty());
        let plan = plan.with_tiling(t);
        let gemm = ExecCtx::new(ConvAlgo::Im2colGemm);
        let (_, _, reference) = deep_chain_plan();
        let want = reference.run(&x, &gemm);
        assert_eq!(plan.run(&x, &gemm).as_slice(), want.as_slice());
    }
}
