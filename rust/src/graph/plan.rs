//! The compiled-plan executor: evaluates an optimized [`Graph`] through
//! an [`ExecCtx`], honouring the fusion facts the passes left behind.
//!
//! Execution walks the nodes in index order (the graph is topological
//! by construction). Activations live in a slot array the plan owns for
//! the duration of the call: the model input is *borrowed* (node 0 —
//! the executor never clones it, unlike the historical
//! `Model::forward`), every other value is owned, and a tensor's buffer
//! is returned to the ctx arena the moment its last consumer has run —
//! so peak activation memory is the live frontier of the graph, not the
//! whole activation set, and the next node's output allocation is
//! usually served straight from the arena.
//!
//! Numerical contract: for every graph a [`crate::nn::Model`] lowers
//! to, `plan.run(x, ctx)` is **bit-identical** to the layer-by-layer
//! `model.forward(x, ctx)` in f32 and bf16, and exactly equal in i8 —
//! per algorithm, per ISA level, per thread count. The op bodies here
//! either are the very functions the layers call, or fused variants
//! whose exactness arguments live in [`super::passes`] and
//! [`crate::kernels::Epilogue`].

use super::ir::{Graph, Op};
use super::passes::PassSummary;
use super::planner::{PlanAlgo, PlannedChoice};
use crate::exec::ExecCtx;
use crate::kernels::direct::conv2d_direct_epi_ctx;
use crate::kernels::im2col::{
    conv2d_im2col_epi_ctx, conv2d_im2col_lowmem_epi_ctx, conv2d_im2col_lowmem_q8_raw_ctx,
    conv2d_im2col_q8_raw_ctx,
};
use crate::kernels::sliding2d::{conv2d_sliding_epi_ctx, conv2d_sliding_q8_raw_ctx, SlideVariant};
use crate::kernels::{
    avg_pool2d_ctx, conv2d_bf16_epi_ctx, conv2d_epi_ctx, conv2d_q8_epi_ctx,
    conv2d_q8_raw_routed_ctx, dequantize_conv_acc, max_pool2d_ctx, quantize_conv_acc, Conv2dParams,
    Epilogue,
};
use crate::nn::layers::{
    concat_channels, global_avg_pool, linear_forward, softmax_rows_inplace, zero_pad2d,
};
use crate::tensor::{quantize, Dtype, QuantParams, Tensor, TensorT, WeightScales};

/// An activation value flowing along a graph edge.
enum Value {
    /// Ordinary f32 tensor.
    F32(Tensor),
    /// Hoisted quantize boundary: i8 codes plus their params, produced
    /// by a `quant_out` node and consumed directly by quantized convs.
    Q8(TensorT<i8>, QuantParams),
}

/// One activation slot during a plan run.
enum Slot<'a> {
    /// Not produced yet, or already recycled.
    Empty,
    /// The caller's input tensor (node 0) — never cloned.
    Borrowed(&'a Tensor),
    /// A plan-owned intermediate.
    Owned(Value),
}

/// An executable, optimized graph — what [`crate::nn::Model::compile`]
/// returns and what the serving backends share across replicas (the
/// weights inside the graph are cloned once at lowering, then the whole
/// plan travels behind an `Arc` exactly like the model it came from).
pub struct CompiledPlan {
    /// The optimized graph.
    pub graph: Graph,
    /// What the passes did (empty summary when compiled with fusion
    /// off).
    pub summary: PassSummary,
    /// Consumer count per node (+1 on the output), fixed at compile
    /// time; each run counts down a copy to recycle buffers eagerly.
    uses: Vec<usize>,
    /// Planner-assigned per-node kernel choices
    /// ([`CompiledPlan::with_choices`]); `None` = default routing. When
    /// present, conv nodes run the chosen algorithm with the chosen
    /// worker cap — bit-identical to the default route: int8 routes are
    /// exact, and an f32 choice is honoured only while it sits in the
    /// same FP-summation family as the ctx's own route
    /// ([`super::planner::f32_family_compatible`]); outside that family
    /// (a plan made for a different serving ctx) the node degrades to
    /// the ctx's routing, keeping the worker cap — capping is always
    /// value-safe.
    choices: Option<Vec<Option<PlannedChoice>>>,
}

impl CompiledPlan {
    /// Wrap an optimized graph.
    pub(crate) fn new(graph: Graph, summary: PassSummary) -> Self {
        let uses = graph.consumer_counts();
        CompiledPlan { graph, summary, uses, choices: None }
    }

    /// Attach a planner-produced per-node choice vector (one entry per
    /// graph node; [`crate::graph::ModelPlan::choices`]). The executor
    /// then routes each planned conv node to its chosen kernel under
    /// its chosen worker cap.
    ///
    /// # Panics
    /// If the vector's length differs from the node count.
    pub fn with_choices(mut self, choices: Vec<Option<PlannedChoice>>) -> Self {
        assert_eq!(choices.len(), self.graph.nodes.len(), "one choice slot per node");
        self.choices = Some(choices);
        self
    }

    /// The attached per-node plan, if any.
    pub fn choices(&self) -> Option<&[Option<PlannedChoice>]> {
        self.choices.as_deref()
    }

    /// Model name this plan was compiled from.
    pub fn name(&self) -> &str {
        &self.graph.name
    }

    /// Total FLOPs for one run at batch `n`.
    pub fn flops(&self, n: usize) -> u64 {
        self.graph.flops(n)
    }

    /// Activation bytes written per run at batch `n` (the fusion
    /// benchmark's memory-traffic metric).
    pub fn activation_bytes(&self, n: usize) -> u64 {
        self.graph.activation_bytes(n)
    }

    /// Render the optimized graph.
    pub fn render(&self) -> String {
        self.graph.render()
    }

    /// Execute the plan.
    ///
    /// # Panics
    /// If the input's per-example shape differs from the shape the
    /// model was lowered for.
    pub fn run(&self, x: &Tensor, ctx: &ExecCtx) -> Tensor {
        assert_eq!(
            &x.dims()[1..],
            &self.graph.input_shape[..],
            "plan for {} expects input {:?}",
            self.graph.name,
            self.graph.input_shape
        );
        let n = self.graph.nodes.len();
        let mut slots: Vec<Slot> = Vec::with_capacity(n);
        slots.push(Slot::Borrowed(x));
        for _ in 1..n {
            slots.push(Slot::Empty);
        }
        let mut remaining = self.uses.clone();
        for id in 1..n {
            if remaining[id] == 0 {
                continue; // dead node (kept only in an uncompacted graph)
            }
            let value = self.eval(id, &slots, ctx);
            slots[id] = Slot::Owned(value);
            for &i in &self.graph.nodes[id].inputs {
                remaining[i] -= 1;
                if remaining[i] == 0 {
                    if let Slot::Owned(v) = std::mem::replace(&mut slots[i], Slot::Empty) {
                        match v {
                            Value::F32(t) => ctx.put(t.into_vec()),
                            Value::Q8(codes, _) => ctx.put_elems(codes.into_vec()),
                        }
                    }
                }
            }
        }
        match std::mem::replace(&mut slots[self.graph.output], Slot::Empty) {
            Slot::Owned(Value::F32(t)) => t,
            Slot::Borrowed(t) => t.clone(), // identity graph
            Slot::Owned(Value::Q8(..)) => {
                unreachable!("the passes never hoist the output node")
            }
            Slot::Empty => unreachable!("output slot was recycled"),
        }
    }

    /// The planner's choice for node `id`, when a plan is attached.
    fn choice_at(&self, id: usize) -> Option<&PlannedChoice> {
        self.choices.as_ref().and_then(|c| c[id].as_ref())
    }

    fn eval(&self, id: usize, slots: &[Slot<'_>], ctx: &ExecCtx) -> Value {
        let node = &self.graph.nodes[id];
        let f32_in = |i: usize| -> &Tensor {
            match &slots[node.inputs[i]] {
                Slot::Borrowed(t) => t,
                Slot::Owned(Value::F32(t)) => t,
                Slot::Owned(Value::Q8(..)) => {
                    panic!("{} fed i8 activations it cannot consume", node.op.name())
                }
                Slot::Empty => panic!("{} input not materialised", node.op.name()),
            }
        };
        match &node.op {
            Op::Input => unreachable!("node 0 is pre-filled"),
            Op::Conv2d { w, bias, params } => {
                let x = f32_in(0);
                let choice = self.choice_at(id);
                let _cap = choice.map(|c| CapGuard::set(ctx, c.threads));
                // Mirrors Conv2d::forward's dtype dispatch, with the
                // fused epilogue threaded into each route; a planned
                // node runs its chosen kernel instead of the ctx-wide
                // routing (same values either way — the plan only picks
                // among parity-tested implementations).
                Value::F32(match ctx.dtype() {
                    Dtype::F32 | Dtype::I32 => {
                        let epi = Epilogue::from_bias(Some(bias)).with_relu(node.fused_relu);
                        // An f32 choice is honoured only inside the
                        // ctx route's FP-summation family — a plan made
                        // for another serving ctx must never change
                        // bits, so it degrades to the ctx's routing
                        // (the worker cap above still applies).
                        let route = super::planner::default_route(ctx, w.dim(3), ctx.dtype());
                        match choice {
                            Some(c) if super::planner::f32_family_compatible(c.algo, route) => {
                                conv2d_planned_epi_ctx(x, w, epi, params, c.algo, ctx)
                            }
                            _ => conv2d_epi_ctx(x, w, epi, params, ctx),
                        }
                    }
                    // bf16 is a sliding-only dtype: the planned route
                    // and the default route are the same kernel.
                    Dtype::Bf16 => {
                        conv2d_bf16_epi_ctx(x, w, Some(bias), node.fused_relu, params, ctx)
                    }
                    Dtype::I8 => {
                        let wq = QuantParams::for_tensor(w);
                        let qw = quantize(w, wq);
                        match choice {
                            Some(c) => {
                                // conv2d_q8_epi_ctx's exact sequence
                                // with the raw kernel forced to the
                                // planned algorithm (exact i32 either
                                // way).
                                let xq = QuantParams::for_tensor(x);
                                let qx = quantize(x, xq);
                                let raw =
                                    conv2d_q8_raw_planned_ctx(&qx, &qw, params, c.algo, ctx);
                                dequantize_conv_acc(
                                    &raw,
                                    xq,
                                    &WeightScales::PerTensor(wq),
                                    Some(bias),
                                    node.fused_relu,
                                )
                            }
                            None => conv2d_q8_epi_ctx(
                                x,
                                &qw,
                                &WeightScales::PerTensor(wq),
                                Some(bias),
                                node.fused_relu,
                                params,
                                ctx,
                            ),
                        }
                    }
                })
            }
            Op::QuantConv2d { qw, wq, bias, params } => {
                let choice = self.choice_at(id);
                let _cap = choice.map(|c| CapGuard::set(ctx, c.threads));
                let raw_of = |qx: &TensorT<i8>| match choice {
                    Some(c) => conv2d_q8_raw_planned_ctx(qx, qw, params, c.algo, ctx),
                    None => conv2d_q8_raw_routed_ctx(qx, qw, params, ctx),
                };
                match &slots[node.inputs[0]] {
                    Slot::Owned(Value::Q8(qx, xq)) => {
                        // Hoisted boundary: consume the producer's codes
                        // directly — no f32 tensor in between.
                        let raw = raw_of(qx);
                        if node.quant_out {
                            let (codes, q) =
                                quantize_conv_acc(&raw, *xq, wq, Some(bias), node.fused_relu);
                            Value::Q8(codes, q)
                        } else {
                            Value::F32(dequantize_conv_acc(
                                &raw,
                                *xq,
                                wq,
                                Some(bias),
                                node.fused_relu,
                            ))
                        }
                    }
                    _ => {
                        let x = f32_in(0);
                        let xq = QuantParams::for_tensor(x);
                        let qx = quantize(x, xq);
                        let raw = raw_of(&qx);
                        if node.quant_out {
                            let (codes, q) =
                                quantize_conv_acc(&raw, xq, wq, Some(bias), node.fused_relu);
                            Value::Q8(codes, q)
                        } else {
                            // The conv2d_q8_epi_ctx sequence inlined:
                            // dynamic per-tensor activation quantization
                            // around the routed (or planned) raw kernel.
                            Value::F32(dequantize_conv_acc(
                                &raw,
                                xq,
                                wq,
                                Some(bias),
                                node.fused_relu,
                            ))
                        }
                    }
                }
            }
            Op::Linear { w, bias } => {
                Value::F32(linear_forward(f32_in(0), w, bias, node.fused_relu))
            }
            Op::Relu => Value::F32(f32_in(0).map(|v| v.max(0.0))),
            Op::Softmax => {
                let mut y = f32_in(0).clone();
                softmax_rows_inplace(&mut y);
                Value::F32(y)
            }
            Op::Flatten => {
                let x = f32_in(0);
                let shape = [x.dim(0), x.numel() / x.dim(0)];
                Value::F32(x.clone().reshape(&shape))
            }
            Op::MaxPool2d(p) => Value::F32(max_pool2d_ctx(f32_in(0), p, ctx)),
            Op::AvgPool2d(p) => Value::F32(avg_pool2d_ctx(f32_in(0), p, ctx)),
            Op::GlobalAvgPool => Value::F32(global_avg_pool(f32_in(0))),
            Op::Pad2d { ph, pw } => Value::F32(zero_pad2d(f32_in(0), *ph, *pw)),
            Op::Concat => Value::F32(concat_channels(f32_in(0), f32_in(1))),
            Op::Opaque(l) => Value::F32(l.forward(f32_in(0), ctx)),
        }
    }
}

/// RAII worker cap for one planned node's kernels: narrows the ctx to
/// the plan's worker count on construction, clears the cap on drop —
/// panic included — so the next node starts uncapped. Capping is a pure
/// footprint/speed knob: partitioning is deterministic per worker
/// count, so results stay bit-identical.
struct CapGuard<'a> {
    ctx: &'a ExecCtx,
}

impl<'a> CapGuard<'a> {
    fn set(ctx: &'a ExecCtx, threads: usize) -> Self {
        ctx.set_thread_cap(threads);
        CapGuard { ctx }
    }
}

impl Drop for CapGuard<'_> {
    fn drop(&mut self) {
        self.ctx.set_thread_cap(0);
    }
}

/// Forced f32 conv routing for a planned node: run exactly the kernel
/// the planner chose. The caller has already checked the choice sits in
/// the ctx route's bitwise family, so the choice affects footprint and
/// speed, never values.
fn conv2d_planned_epi_ctx(
    x: &Tensor,
    w: &Tensor,
    epi: Epilogue<'_>,
    p: &Conv2dParams,
    algo: PlanAlgo,
    ctx: &ExecCtx,
) -> Tensor {
    match algo {
        PlanAlgo::Direct => conv2d_direct_epi_ctx(x, w, epi, p, ctx),
        PlanAlgo::Gemm => conv2d_im2col_epi_ctx(x, w, epi, p, ctx),
        PlanAlgo::GemmLowMem => conv2d_im2col_lowmem_epi_ctx(x, w, epi, p, ctx),
        PlanAlgo::Sliding => conv2d_sliding_epi_ctx(x, w, epi, p, SlideVariant::Auto, ctx),
    }
}

/// Forced int8 raw accumulation for a planned node. All three kernels
/// produce the identical exact-i32 accumulator; `Direct` (which has no
/// int8 kernel, and which the planner never emits for int8) degrades to
/// the sliding kernel — same values.
fn conv2d_q8_raw_planned_ctx(
    qx: &TensorT<i8>,
    qw: &TensorT<i8>,
    p: &Conv2dParams,
    algo: PlanAlgo,
    ctx: &ExecCtx,
) -> TensorT<i32> {
    match algo {
        PlanAlgo::Gemm => conv2d_im2col_q8_raw_ctx(qx, qw, p, ctx),
        PlanAlgo::GemmLowMem => conv2d_im2col_lowmem_q8_raw_ctx(qx, qw, p, ctx),
        PlanAlgo::Direct | PlanAlgo::Sliding => conv2d_sliding_q8_raw_ctx(qx, qw, p, ctx),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::passes::optimize;
    use crate::kernels::{Conv2dParams, ConvAlgo};
    use crate::nn::layers::{Conv2d, Layer, QuantizedConv2d, ReLU};

    fn plan_of(mut g: Graph, fuse: bool) -> CompiledPlan {
        let summary = if fuse { optimize(&mut g) } else { PassSummary::default() };
        CompiledPlan::new(g, summary)
    }

    #[test]
    fn fused_conv_relu_is_bit_identical_to_layers() {
        let conv = Conv2d::new(3, 4, 3, Conv2dParams::same(3), 61);
        let x = Tensor::randn(&[2, 3, 10, 10], 62);
        for algo in [ConvAlgo::Direct, ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            let ctx = ExecCtx::new(algo);
            let want = ReLU.forward(&conv.forward(&x, &ctx), &ctx);

            let mut g = Graph::new("t", &[3, 10, 10]);
            let c = conv.lower_into(&mut g, 0).unwrap();
            g.add(Op::Relu, vec![c]);
            let plan = plan_of(g, true);
            assert_eq!(plan.summary.fused_relu, 1);
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
        }
    }

    #[test]
    fn hoisted_quant_chain_matches_unfused_exactly() {
        let q1 = QuantizedConv2d::new(3, 4, 3, Conv2dParams::same(3), 63);
        let q2 = QuantizedConv2d::new(4, 2, 3, Conv2dParams::same(3), 64);
        let x = Tensor::randn(&[1, 3, 9, 9], 65);
        for algo in [ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            let ctx = ExecCtx::new(algo);
            let want = q2.forward(&q1.forward(&x, &ctx), &ctx);

            let mut g = Graph::new("t", &[3, 9, 9]);
            let a = q1.lower_into(&mut g, 0).unwrap();
            q2.lower_into(&mut g, a).unwrap();
            let plan = plan_of(g, true);
            assert_eq!(plan.summary.hoisted_quant, 1);
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
        }
    }

    #[test]
    fn elided_pad_matches_explicit_pad_layer() {
        let conv = Conv2d::new(2, 3, 3, Conv2dParams::default(), 66);
        let x = Tensor::randn(&[1, 2, 8, 8], 67);
        for algo in [ConvAlgo::Direct, ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            let ctx = ExecCtx::new(algo);
            let padded = zero_pad2d(&x, 1, 1);
            let want = conv.forward(&padded, &ctx);

            let mut g = Graph::new("t", &[2, 8, 8]);
            let p = g.add(Op::Pad2d { ph: 1, pw: 1 }, vec![0]);
            conv.lower_into(&mut g, p).unwrap();
            let plan = plan_of(g, true);
            assert_eq!(plan.summary.elided_pads, 1);
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
        }
    }

    #[test]
    fn unfused_plan_reproduces_the_graph_verbatim() {
        let conv = Conv2d::new(3, 4, 3, Conv2dParams::same(3), 68);
        let x = Tensor::randn(&[1, 3, 8, 8], 69);
        let ctx = ExecCtx::default();
        let want = ReLU.forward(&conv.forward(&x, &ctx), &ctx);

        let mut g = Graph::new("t", &[3, 8, 8]);
        let c = conv.lower_into(&mut g, 0).unwrap();
        g.add(Op::Relu, vec![c]);
        let plan = plan_of(g, false);
        assert_eq!(plan.summary, PassSummary::default());
        assert_eq!(plan.graph.nodes.len(), 3);
        assert_eq!(plan.run(&x, &ctx).as_slice(), want.as_slice());
    }

    #[test]
    #[should_panic(expected = "expects input")]
    fn plan_rejects_wrong_input_shape() {
        let g = Graph::new("t", &[3, 8, 8]);
        let plan = plan_of(g, false);
        plan.run(&Tensor::zeros(&[1, 3, 4, 4]), &ExecCtx::default());
    }

    fn conv_relu_plan(conv: &Conv2d) -> CompiledPlan {
        let mut g = Graph::new("t", &[3, 16, 16]);
        let c = conv.lower_into(&mut g, 0).unwrap();
        g.add(Op::Relu, vec![c]);
        plan_of(g, true)
    }

    fn forced(
        algo: PlanAlgo,
        threads: usize,
        dtype: Dtype,
        nodes: usize,
    ) -> Vec<Option<PlannedChoice>> {
        let mut choices = vec![None; nodes];
        choices[1] = Some(PlannedChoice {
            algo,
            threads,
            dtype,
            workspace_bytes: 0,
            predicted_gflops: 1.0,
        });
        choices
    }

    #[test]
    fn planned_f32_choices_route_bit_identically_within_the_gemm_family() {
        // One-shot ↔ strip GEMM is the real f32 interchange: under a
        // GEMM-routed ctx, both forced choices reproduce the default
        // route bit for bit (the strip decomposition is order-exact).
        let conv = Conv2d::new(3, 4, 5, Conv2dParams::same(5), 71);
        let x = Tensor::randn(&[2, 3, 16, 16], 72);
        let ctx = ExecCtx::with_threads(ConvAlgo::Im2colGemm, 4);
        let want = conv_relu_plan(&conv).run(&x, &ctx);
        for algo in [PlanAlgo::Gemm, PlanAlgo::GemmLowMem] {
            let plan = conv_relu_plan(&conv);
            let n = plan.graph.nodes.len();
            let plan = plan.with_choices(forced(algo, 2, Dtype::F32, n));
            assert!(plan.choices().is_some());
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
            assert_eq!(ctx.threads(), 4, "{algo:?}: cap must clear after the node");
        }
    }

    #[test]
    fn cross_family_f32_choices_degrade_to_the_ctx_route() {
        // A plan made for a different serving ctx must never change
        // bits: an out-of-family forced algorithm keeps the ctx's own
        // routing (only the worker cap — always value-safe — applies).
        let conv = Conv2d::new(3, 4, 5, Conv2dParams::same(5), 71);
        let x = Tensor::randn(&[2, 3, 16, 16], 72);
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 4);
        let want = conv_relu_plan(&conv).run(&x, &ctx);
        for algo in [PlanAlgo::Direct, PlanAlgo::Gemm, PlanAlgo::GemmLowMem] {
            let plan = conv_relu_plan(&conv);
            let n = plan.graph.nodes.len();
            let plan = plan.with_choices(forced(algo, 2, Dtype::F32, n));
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
        }
        // The in-family choice still routes bit-identically.
        let plan = conv_relu_plan(&conv);
        let n = plan.graph.nodes.len();
        let plan = plan.with_choices(forced(PlanAlgo::Sliding, 2, Dtype::F32, n));
        assert_eq!(plan.run(&x, &ctx).as_slice(), want.as_slice());
    }

    #[test]
    fn planned_q8_choices_route_exactly() {
        let q = QuantizedConv2d::new(3, 4, 3, Conv2dParams::same(3), 73);
        let x = Tensor::randn(&[1, 3, 12, 12], 74);
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 2);
        let build = || {
            let mut g = Graph::new("t", &[3, 12, 12]);
            q.lower_into(&mut g, 0).unwrap();
            plan_of(g, true)
        };
        let want = build().run(&x, &ctx);
        for algo in [PlanAlgo::Sliding, PlanAlgo::Gemm, PlanAlgo::GemmLowMem] {
            let plan = build();
            let n = plan.graph.nodes.len();
            let plan = plan.with_choices(forced(algo, 1, Dtype::I8, n));
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
        }
    }

    #[test]
    #[should_panic(expected = "one choice slot per node")]
    fn with_choices_rejects_wrong_length() {
        let conv = Conv2d::new(3, 4, 3, Conv2dParams::same(3), 75);
        let mut g = Graph::new("t", &[3, 16, 16]);
        conv.lower_into(&mut g, 0).unwrap();
        plan_of(g, false).with_choices(vec![None]);
    }
}
