//! The compiled-plan executor: evaluates an optimized [`Graph`] through
//! an [`ExecCtx`], honouring the fusion facts the passes left behind.
//!
//! Execution walks the nodes in index order (the graph is topological
//! by construction). Activations live in a slot array the plan owns for
//! the duration of the call: the model input is *borrowed* (node 0 —
//! the executor never clones it, unlike the historical
//! `Model::forward`), every other value is owned, and a tensor's buffer
//! is returned to the ctx arena the moment its last consumer has run —
//! so peak activation memory is the live frontier of the graph, not the
//! whole activation set, and the next node's output allocation is
//! usually served straight from the arena.
//!
//! Numerical contract: for every graph a [`crate::nn::Model`] lowers
//! to, `plan.run(x, ctx)` is **bit-identical** to the layer-by-layer
//! `model.forward(x, ctx)` in f32 and bf16, and exactly equal in i8 —
//! per algorithm, per ISA level, per thread count. The op bodies here
//! either are the very functions the layers call, or fused variants
//! whose exactness arguments live in [`super::passes`] and
//! [`crate::kernels::Epilogue`].

use super::ir::{Graph, Op};
use super::passes::PassSummary;
use crate::exec::ExecCtx;
use crate::kernels::{
    avg_pool2d_ctx, conv2d_bf16_epi_ctx, conv2d_epi_ctx, conv2d_q8_epi_ctx,
    conv2d_q8_raw_routed_ctx, dequantize_conv_acc, max_pool2d_ctx, quantize_conv_acc, Epilogue,
};
use crate::nn::layers::{
    concat_channels, global_avg_pool, linear_forward, softmax_rows_inplace, zero_pad2d,
};
use crate::tensor::{quantize, Dtype, QuantParams, Tensor, TensorT, WeightScales};

/// An activation value flowing along a graph edge.
enum Value {
    /// Ordinary f32 tensor.
    F32(Tensor),
    /// Hoisted quantize boundary: i8 codes plus their params, produced
    /// by a `quant_out` node and consumed directly by quantized convs.
    Q8(TensorT<i8>, QuantParams),
}

/// One activation slot during a plan run.
enum Slot<'a> {
    /// Not produced yet, or already recycled.
    Empty,
    /// The caller's input tensor (node 0) — never cloned.
    Borrowed(&'a Tensor),
    /// A plan-owned intermediate.
    Owned(Value),
}

/// An executable, optimized graph — what [`crate::nn::Model::compile`]
/// returns and what the serving backends share across replicas (the
/// weights inside the graph are cloned once at lowering, then the whole
/// plan travels behind an `Arc` exactly like the model it came from).
pub struct CompiledPlan {
    /// The optimized graph.
    pub graph: Graph,
    /// What the passes did (empty summary when compiled with fusion
    /// off).
    pub summary: PassSummary,
    /// Consumer count per node (+1 on the output), fixed at compile
    /// time; each run counts down a copy to recycle buffers eagerly.
    uses: Vec<usize>,
}

impl CompiledPlan {
    /// Wrap an optimized graph.
    pub(crate) fn new(graph: Graph, summary: PassSummary) -> Self {
        let uses = graph.consumer_counts();
        CompiledPlan { graph, summary, uses }
    }

    /// Model name this plan was compiled from.
    pub fn name(&self) -> &str {
        &self.graph.name
    }

    /// Total FLOPs for one run at batch `n`.
    pub fn flops(&self, n: usize) -> u64 {
        self.graph.flops(n)
    }

    /// Activation bytes written per run at batch `n` (the fusion
    /// benchmark's memory-traffic metric).
    pub fn activation_bytes(&self, n: usize) -> u64 {
        self.graph.activation_bytes(n)
    }

    /// Render the optimized graph.
    pub fn render(&self) -> String {
        self.graph.render()
    }

    /// Execute the plan.
    ///
    /// # Panics
    /// If the input's per-example shape differs from the shape the
    /// model was lowered for.
    pub fn run(&self, x: &Tensor, ctx: &ExecCtx) -> Tensor {
        assert_eq!(
            &x.dims()[1..],
            &self.graph.input_shape[..],
            "plan for {} expects input {:?}",
            self.graph.name,
            self.graph.input_shape
        );
        let n = self.graph.nodes.len();
        let mut slots: Vec<Slot> = Vec::with_capacity(n);
        slots.push(Slot::Borrowed(x));
        for _ in 1..n {
            slots.push(Slot::Empty);
        }
        let mut remaining = self.uses.clone();
        for id in 1..n {
            if remaining[id] == 0 {
                continue; // dead node (kept only in an uncompacted graph)
            }
            let value = self.eval(id, &slots, ctx);
            slots[id] = Slot::Owned(value);
            for &i in &self.graph.nodes[id].inputs {
                remaining[i] -= 1;
                if remaining[i] == 0 {
                    if let Slot::Owned(v) = std::mem::replace(&mut slots[i], Slot::Empty) {
                        match v {
                            Value::F32(t) => ctx.put(t.into_vec()),
                            Value::Q8(codes, _) => ctx.put_elems(codes.into_vec()),
                        }
                    }
                }
            }
        }
        match std::mem::replace(&mut slots[self.graph.output], Slot::Empty) {
            Slot::Owned(Value::F32(t)) => t,
            Slot::Borrowed(t) => t.clone(), // identity graph
            Slot::Owned(Value::Q8(..)) => {
                unreachable!("the passes never hoist the output node")
            }
            Slot::Empty => unreachable!("output slot was recycled"),
        }
    }

    fn eval(&self, id: usize, slots: &[Slot<'_>], ctx: &ExecCtx) -> Value {
        let node = &self.graph.nodes[id];
        let f32_in = |i: usize| -> &Tensor {
            match &slots[node.inputs[i]] {
                Slot::Borrowed(t) => t,
                Slot::Owned(Value::F32(t)) => t,
                Slot::Owned(Value::Q8(..)) => {
                    panic!("{} fed i8 activations it cannot consume", node.op.name())
                }
                Slot::Empty => panic!("{} input not materialised", node.op.name()),
            }
        };
        match &node.op {
            Op::Input => unreachable!("node 0 is pre-filled"),
            Op::Conv2d { w, bias, params } => {
                let x = f32_in(0);
                // Mirrors Conv2d::forward's dtype dispatch, with the
                // fused epilogue threaded into each route.
                Value::F32(match ctx.dtype() {
                    Dtype::F32 | Dtype::I32 => conv2d_epi_ctx(
                        x,
                        w,
                        Epilogue::from_bias(Some(bias)).with_relu(node.fused_relu),
                        params,
                        ctx,
                    ),
                    Dtype::Bf16 => {
                        conv2d_bf16_epi_ctx(x, w, Some(bias), node.fused_relu, params, ctx)
                    }
                    Dtype::I8 => {
                        let wq = QuantParams::for_tensor(w);
                        let qw = quantize(w, wq);
                        conv2d_q8_epi_ctx(
                            x,
                            &qw,
                            &WeightScales::PerTensor(wq),
                            Some(bias),
                            node.fused_relu,
                            params,
                            ctx,
                        )
                    }
                })
            }
            Op::QuantConv2d { qw, wq, bias, params } => {
                match &slots[node.inputs[0]] {
                    Slot::Owned(Value::Q8(qx, xq)) => {
                        // Hoisted boundary: consume the producer's codes
                        // directly — no f32 tensor in between.
                        let raw = conv2d_q8_raw_routed_ctx(qx, qw, params, ctx);
                        if node.quant_out {
                            let (codes, q) =
                                quantize_conv_acc(&raw, *xq, wq, Some(bias), node.fused_relu);
                            Value::Q8(codes, q)
                        } else {
                            Value::F32(dequantize_conv_acc(
                                &raw,
                                *xq,
                                wq,
                                Some(bias),
                                node.fused_relu,
                            ))
                        }
                    }
                    _ => {
                        let x = f32_in(0);
                        if node.quant_out {
                            let xq = QuantParams::for_tensor(x);
                            let qx = quantize(x, xq);
                            let raw = conv2d_q8_raw_routed_ctx(&qx, qw, params, ctx);
                            let (codes, q) =
                                quantize_conv_acc(&raw, xq, wq, Some(bias), node.fused_relu);
                            Value::Q8(codes, q)
                        } else {
                            Value::F32(conv2d_q8_epi_ctx(
                                x,
                                qw,
                                wq,
                                Some(bias),
                                node.fused_relu,
                                params,
                                ctx,
                            ))
                        }
                    }
                }
            }
            Op::Linear { w, bias } => {
                Value::F32(linear_forward(f32_in(0), w, bias, node.fused_relu))
            }
            Op::Relu => Value::F32(f32_in(0).map(|v| v.max(0.0))),
            Op::Softmax => {
                let mut y = f32_in(0).clone();
                softmax_rows_inplace(&mut y);
                Value::F32(y)
            }
            Op::Flatten => {
                let x = f32_in(0);
                let shape = [x.dim(0), x.numel() / x.dim(0)];
                Value::F32(x.clone().reshape(&shape))
            }
            Op::MaxPool2d(p) => Value::F32(max_pool2d_ctx(f32_in(0), p, ctx)),
            Op::AvgPool2d(p) => Value::F32(avg_pool2d_ctx(f32_in(0), p, ctx)),
            Op::GlobalAvgPool => Value::F32(global_avg_pool(f32_in(0))),
            Op::Pad2d { ph, pw } => Value::F32(zero_pad2d(f32_in(0), *ph, *pw)),
            Op::Concat => Value::F32(concat_channels(f32_in(0), f32_in(1))),
            Op::Opaque(l) => Value::F32(l.forward(f32_in(0), ctx)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::passes::optimize;
    use crate::kernels::{Conv2dParams, ConvAlgo};
    use crate::nn::layers::{Conv2d, Layer, QuantizedConv2d, ReLU};

    fn plan_of(mut g: Graph, fuse: bool) -> CompiledPlan {
        let summary = if fuse { optimize(&mut g) } else { PassSummary::default() };
        CompiledPlan::new(g, summary)
    }

    #[test]
    fn fused_conv_relu_is_bit_identical_to_layers() {
        let conv = Conv2d::new(3, 4, 3, Conv2dParams::same(3), 61);
        let x = Tensor::randn(&[2, 3, 10, 10], 62);
        for algo in [ConvAlgo::Direct, ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            let ctx = ExecCtx::new(algo);
            let want = ReLU.forward(&conv.forward(&x, &ctx), &ctx);

            let mut g = Graph::new("t", &[3, 10, 10]);
            let c = conv.lower_into(&mut g, 0).unwrap();
            g.add(Op::Relu, vec![c]);
            let plan = plan_of(g, true);
            assert_eq!(plan.summary.fused_relu, 1);
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
        }
    }

    #[test]
    fn hoisted_quant_chain_matches_unfused_exactly() {
        let q1 = QuantizedConv2d::new(3, 4, 3, Conv2dParams::same(3), 63);
        let q2 = QuantizedConv2d::new(4, 2, 3, Conv2dParams::same(3), 64);
        let x = Tensor::randn(&[1, 3, 9, 9], 65);
        for algo in [ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            let ctx = ExecCtx::new(algo);
            let want = q2.forward(&q1.forward(&x, &ctx), &ctx);

            let mut g = Graph::new("t", &[3, 9, 9]);
            let a = q1.lower_into(&mut g, 0).unwrap();
            q2.lower_into(&mut g, a).unwrap();
            let plan = plan_of(g, true);
            assert_eq!(plan.summary.hoisted_quant, 1);
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
        }
    }

    #[test]
    fn elided_pad_matches_explicit_pad_layer() {
        let conv = Conv2d::new(2, 3, 3, Conv2dParams::default(), 66);
        let x = Tensor::randn(&[1, 2, 8, 8], 67);
        for algo in [ConvAlgo::Direct, ConvAlgo::Sliding, ConvAlgo::Im2colGemm] {
            let ctx = ExecCtx::new(algo);
            let padded = zero_pad2d(&x, 1, 1);
            let want = conv.forward(&padded, &ctx);

            let mut g = Graph::new("t", &[2, 8, 8]);
            let p = g.add(Op::Pad2d { ph: 1, pw: 1 }, vec![0]);
            conv.lower_into(&mut g, p).unwrap();
            let plan = plan_of(g, true);
            assert_eq!(plan.summary.elided_pads, 1);
            let got = plan.run(&x, &ctx);
            assert_eq!(got.as_slice(), want.as_slice(), "{algo:?}");
        }
    }

    #[test]
    fn unfused_plan_reproduces_the_graph_verbatim() {
        let conv = Conv2d::new(3, 4, 3, Conv2dParams::same(3), 68);
        let x = Tensor::randn(&[1, 3, 8, 8], 69);
        let ctx = ExecCtx::default();
        let want = ReLU.forward(&conv.forward(&x, &ctx), &ctx);

        let mut g = Graph::new("t", &[3, 8, 8]);
        let c = conv.lower_into(&mut g, 0).unwrap();
        g.add(Op::Relu, vec![c]);
        let plan = plan_of(g, false);
        assert_eq!(plan.summary, PassSummary::default());
        assert_eq!(plan.graph.nodes.len(), 3);
        assert_eq!(plan.run(&x, &ctx).as_slice(), want.as_slice());
    }

    #[test]
    #[should_panic(expected = "expects input")]
    fn plan_rejects_wrong_input_shape() {
        let g = Graph::new("t", &[3, 8, 8]);
        let plan = plan_of(g, false);
        plan.run(&Tensor::zeros(&[1, 3, 4, 4]), &ExecCtx::default());
    }
}
