//! The typed intermediate representation: a DAG of [`Node`]s with static
//! shape facts, produced by [`crate::nn::Model::lower`] and rewritten by
//! [`super::passes`].
//!
//! Every node lists its input node ids (always smaller than its own —
//! the graph is topologically ordered by construction, and the passes
//! only ever rewire edges *backwards*), carries the output shape
//! inferred at build time, and two post-pass facts the executor honours:
//!
//! * [`Node::fused_relu`] — the epilogue-fusion pass folded a following
//!   ReLU into this node's output write.
//! * [`Node::quant_out`] — the quantize-boundary pass decided this
//!   node's consumers take i8 activation codes directly, so the f32
//!   tensor between them is never materialised.

use crate::kernels::{Conv2dParams, PoolParams};
use crate::nn::Layer;
use crate::tensor::{Tensor, TensorT, WeightScales};
use std::sync::Arc;

/// Index of a node in [`Graph::nodes`].
pub type NodeId = usize;

/// A graph operation. Weight-carrying ops own their parameters (cloned
/// from the layer at lowering time; replicas share the *compiled plan*,
/// so the clone happens once per model, not per replica or request).
pub enum Op {
    /// The graph input placeholder (always node 0).
    Input,
    /// f32 2-D convolution (weights `[c_out, c_in/g, kh, kw]`).
    Conv2d {
        /// Weights.
        w: Tensor,
        /// Bias `[c_out]`.
        bias: Vec<f32>,
        /// Stride / padding / groups.
        params: Conv2dParams,
    },
    /// Int8-weight 2-D convolution (pre-quantized codes + scales).
    QuantConv2d {
        /// Weight codes.
        qw: TensorT<i8>,
        /// Weight scales (per-tensor or per-output-channel).
        wq: WeightScales,
        /// Bias `[c_out]` in f32.
        bias: Vec<f32>,
        /// Stride / padding / groups.
        params: Conv2dParams,
    },
    /// Fully connected layer (`w` is `[out, in]`).
    Linear {
        /// Weights.
        w: Tensor,
        /// Bias `[out]`.
        bias: Vec<f32>,
    },
    /// Elementwise `max(v, 0)`.
    Relu,
    /// Row-wise softmax over the last dimension.
    Softmax,
    /// Flatten `[n, …]` to `[n, prod(rest)]`.
    Flatten,
    /// Max pooling.
    MaxPool2d(PoolParams),
    /// Average pooling (`count_include_pad`).
    AvgPool2d(PoolParams),
    /// Global average pooling to `[n, c, 1, 1]`.
    GlobalAvgPool,
    /// Explicit zero padding of the spatial dims.
    Pad2d {
        /// Rows added on top and bottom.
        ph: usize,
        /// Columns added left and right.
        pw: usize,
    },
    /// Channel concatenation of exactly two NCHW inputs.
    Concat,
    /// A layer without a typed lowering: executed via its
    /// [`Layer::forward`], opaque to every pass.
    Opaque(Arc<dyn Layer>),
}

impl Op {
    /// Short stable name for rendering.
    pub fn name(&self) -> &'static str {
        match self {
            Op::Input => "input",
            Op::Conv2d { .. } => "conv2d",
            Op::QuantConv2d { .. } => "quant-conv2d",
            Op::Linear { .. } => "linear",
            Op::Relu => "relu",
            Op::Softmax => "softmax",
            Op::Flatten => "flatten",
            Op::MaxPool2d(_) => "max-pool2d",
            Op::AvgPool2d(_) => "avg-pool2d",
            Op::GlobalAvgPool => "global-avg-pool",
            Op::Pad2d { .. } => "pad2d",
            Op::Concat => "concat",
            Op::Opaque(_) => "opaque",
        }
    }

    /// Output shape from the input shapes.
    fn infer_shape(&self, ins: &[&[usize]]) -> Vec<usize> {
        match self {
            Op::Input => unreachable!("Input has no predecessors"),
            Op::Conv2d { w, params, .. } => conv_out_shape(ins[0], w.dims(), params),
            Op::QuantConv2d { qw, params, .. } => conv_out_shape(ins[0], qw.dims(), params),
            Op::Linear { w, .. } => {
                assert_eq!(ins[0].len(), 2, "Linear input must be [n, d]");
                assert_eq!(ins[0][1], w.dim(1), "Linear dim mismatch");
                vec![ins[0][0], w.dim(0)]
            }
            Op::Relu | Op::Softmax => ins[0].to_vec(),
            Op::Flatten => vec![ins[0][0], ins[0][1..].iter().product()],
            Op::MaxPool2d(p) | Op::AvgPool2d(p) => {
                let (oh, ow) = p.out_size(ins[0][2], ins[0][3]);
                vec![ins[0][0], ins[0][1], oh, ow]
            }
            Op::GlobalAvgPool => vec![ins[0][0], ins[0][1], 1, 1],
            Op::Pad2d { ph, pw } => {
                vec![ins[0][0], ins[0][1], ins[0][2] + 2 * ph, ins[0][3] + 2 * pw]
            }
            Op::Concat => {
                assert_eq!(ins.len(), 2, "Concat takes two inputs");
                assert_eq!(ins[0][0], ins[1][0], "batch mismatch");
                assert_eq!(ins[0][2..], ins[1][2..], "spatial mismatch");
                vec![ins[0][0], ins[0][1] + ins[1][1], ins[0][2], ins[0][3]]
            }
            Op::Opaque(l) => l.out_shape(ins[0]),
        }
    }

    /// FLOPs for one evaluation at the given input shapes (same
    /// conventions as the [`Layer::flops`] impls).
    fn flops(&self, ins: &[&[usize]], out: &[usize]) -> u64 {
        let numel = |s: &[usize]| s.iter().product::<usize>() as u64;
        match self {
            Op::Input | Op::Flatten | Op::Pad2d { .. } | Op::Concat => 0,
            Op::Conv2d { w, .. } => {
                let taps = w.dim(1) * w.dim(2) * w.dim(3);
                numel(out) * (2 * taps as u64 + 1)
            }
            Op::QuantConv2d { qw, .. } => {
                let taps = qw.dim(1) * qw.dim(2) * qw.dim(3);
                numel(out) * (2 * taps as u64 + 1)
            }
            Op::Linear { w, .. } => {
                (ins[0][0] * w.dim(0) * (2 * w.dim(1) + 1)) as u64
            }
            Op::Relu | Op::GlobalAvgPool => numel(ins[0]),
            Op::Softmax => 3 * numel(ins[0]),
            Op::MaxPool2d(p) => numel(out) * (p.k.0 * p.k.1 - 1) as u64,
            Op::AvgPool2d(p) => numel(out) * (p.k.0 * p.k.1) as u64,
            Op::Opaque(l) => l.flops(ins[0]),
        }
    }
}

fn conv_out_shape(x: &[usize], w: &[usize], p: &Conv2dParams) -> Vec<usize> {
    assert_eq!(x.len(), 4, "conv input must be NCHW");
    assert_eq!(x[1], w[1] * p.groups, "conv channel mismatch");
    let (oh, ow) = p.out_size(x[2], x[3], w[2], w[3]);
    vec![x[0], w[0], oh, ow]
}

/// One graph node: an op, its input edges and the statically inferred
/// output shape, plus the pass-assigned fusion facts.
pub struct Node {
    /// The operation.
    pub op: Op,
    /// Producer node ids (each `< ` this node's own id).
    pub inputs: Vec<NodeId>,
    /// Output shape (batch dimension included).
    pub shape: Vec<usize>,
    /// Epilogue fusion: apply ReLU in this node's output write.
    pub fused_relu: bool,
    /// Quantize-boundary hoisting: output stays i8 codes +
    /// [`crate::tensor::QuantParams`] (only ever set on `QuantConv2d`).
    pub quant_out: bool,
}

/// The typed graph a [`crate::nn::Model`] lowers into: nodes in
/// topological order (node 0 is [`Op::Input`]), one designated output.
pub struct Graph {
    /// Model name (carried into reports and the CLI).
    pub name: String,
    /// Per-example input shape `[c, h, w]` (no batch dimension — plans
    /// accept any batch, like [`crate::nn::Model::forward`]).
    pub input_shape: Vec<usize>,
    /// The nodes, topologically ordered.
    pub nodes: Vec<Node>,
    /// The output node.
    pub output: NodeId,
}

impl Graph {
    /// New graph holding only the input placeholder (node 0), which is
    /// also the initial output.
    pub fn new(name: impl Into<String>, input_shape: &[usize]) -> Self {
        // Shape inference runs with a symbolic batch of 1; execution
        // accepts any batch (shapes scale linearly in dim 0).
        let shape = std::iter::once(1).chain(input_shape.iter().copied()).collect();
        Graph {
            name: name.into(),
            input_shape: input_shape.to_vec(),
            nodes: vec![Node {
                op: Op::Input,
                inputs: Vec::new(),
                shape,
                fused_relu: false,
                quant_out: false,
            }],
            output: 0,
        }
    }

    /// Append a node, inferring its shape from its inputs' shapes, and
    /// make it the current output.
    ///
    /// # Panics
    /// If an input id is out of range or the shapes are incompatible.
    pub fn add(&mut self, op: Op, inputs: Vec<NodeId>) -> NodeId {
        let id = self.nodes.len();
        for &i in &inputs {
            assert!(i < id, "node input {i} must precede the node");
        }
        let in_shapes: Vec<&[usize]> =
            inputs.iter().map(|&i| self.nodes[i].shape.as_slice()).collect();
        let shape = op.infer_shape(&in_shapes);
        self.nodes.push(Node { op, inputs, shape, fused_relu: false, quant_out: false });
        self.output = id;
        id
    }

    /// Designate the output node.
    pub fn set_output(&mut self, id: NodeId) {
        assert!(id < self.nodes.len(), "output {id} out of range");
        self.output = id;
    }

    /// How many nodes consume each node's output (the output node gets
    /// one extra use for the caller).
    pub fn consumer_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                counts[i] += 1;
            }
        }
        counts[self.output] += 1;
        counts
    }

    /// Drop nodes unreachable from the output (the dead ReLU/Pad2d
    /// nodes the passes leave behind) and remap ids. Node 0 (the input)
    /// is always kept; topological order is preserved.
    pub fn compact(&mut self) {
        let mut live = vec![false; self.nodes.len()];
        live[0] = true;
        live[self.output] = true;
        // Reverse topological sweep: a node's inputs are live if it is.
        for id in (0..self.nodes.len()).rev() {
            if live[id] {
                for &i in &self.nodes[id].inputs {
                    live[i] = true;
                }
            }
        }
        let mut remap = vec![usize::MAX; self.nodes.len()];
        let mut kept = 0usize;
        for (id, &l) in live.iter().enumerate() {
            if l {
                remap[id] = kept;
                kept += 1;
            }
        }
        let mut idx = 0usize;
        self.nodes.retain(|_| {
            let keep = live[idx];
            idx += 1;
            keep
        });
        for n in &mut self.nodes {
            for i in &mut n.inputs {
                *i = remap[*i];
            }
        }
        self.output = remap[self.output];
    }

    /// FLOPs for one evaluation of node `id` at batch `n` (the
    /// per-node term [`Graph::flops`] sums; the planner's cost input).
    pub fn node_flops(&self, id: NodeId, n: usize) -> u64 {
        let node = &self.nodes[id];
        let ins: Vec<Vec<usize>> =
            node.inputs.iter().map(|&i| scale_batch(&self.nodes[i].shape, n)).collect();
        let ins_ref: Vec<&[usize]> = ins.iter().map(|s| s.as_slice()).collect();
        node.op.flops(&ins_ref, &scale_batch(&node.shape, n))
    }

    /// Bytes node `id`'s output tensor occupies at batch `n`: one byte
    /// per element for a `quant_out` node (i8 codes), four otherwise.
    /// The input placeholder is borrowed from the caller, so node 0
    /// reports 0 — matching what the executor actually allocates.
    pub fn node_activation_bytes(&self, id: NodeId, n: usize) -> u64 {
        if id == 0 {
            return 0;
        }
        let node = &self.nodes[id];
        let numel: usize = scale_batch(&node.shape, n).iter().product();
        numel as u64 * if node.quant_out { 1 } else { 4 }
    }

    /// Total FLOPs for one forward pass at batch `n` (same conventions
    /// as [`crate::nn::Model::flops`]).
    pub fn flops(&self, n: usize) -> u64 {
        (0..self.nodes.len()).map(|id| self.node_flops(id, n)).sum()
    }

    /// Bytes of activation memory the executor writes for one forward
    /// pass at batch `n`: every non-input node's output tensor, at 4
    /// bytes per element (f32 serving) or 1 for a `quant_out` node.
    /// This is the graph-level memory-traffic metric
    /// `benches/graph_fusion.rs` reports — fusion removes whole nodes,
    /// so it shrinks this sum directly.
    pub fn activation_bytes(&self, n: usize) -> u64 {
        (0..self.nodes.len()).map(|id| self.node_activation_bytes(id, n)).sum()
    }

    /// Human-readable rendering (the CLI `compile` subcommand's
    /// before/after view): one line per node with fusion annotations.
    pub fn render(&self) -> String {
        let mut s = format!("graph \"{}\" (input {:?})\n", self.name, self.input_shape);
        for (id, node) in self.nodes.iter().enumerate() {
            let mut attrs = String::new();
            if node.fused_relu {
                attrs.push_str(" +relu");
            }
            if node.quant_out {
                attrs.push_str(" +i8-out");
            }
            let ins = if node.inputs.is_empty() {
                String::new()
            } else {
                format!(
                    " <- {}",
                    node.inputs.iter().map(|i| format!("%{i}")).collect::<Vec<_>>().join(", ")
                )
            };
            let marker = if id == self.output { "  (output)" } else { "" };
            s.push_str(&format!(
                "  %{id}: {}{attrs} {:?}{ins}{marker}\n",
                node.op.name(),
                node.shape
            ));
        }
        s
    }
}

fn scale_batch(shape: &[usize], n: usize) -> Vec<usize> {
    let mut s = shape.to_vec();
    if !s.is_empty() {
        s[0] *= n;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv_op(c_in: usize, c_out: usize, k: usize, params: Conv2dParams) -> Op {
        Op::Conv2d {
            w: Tensor::randn(&[c_out, c_in, k, k], 1),
            bias: vec![0.0; c_out],
            params,
        }
    }

    #[test]
    fn shapes_infer_along_a_chain() {
        let mut g = Graph::new("t", &[3, 8, 8]);
        let c = g.add(conv_op(3, 4, 3, Conv2dParams::same(3)), vec![0]);
        assert_eq!(g.nodes[c].shape, vec![1, 4, 8, 8]);
        let r = g.add(Op::Relu, vec![c]);
        let f = g.add(Op::Flatten, vec![r]);
        assert_eq!(g.nodes[f].shape, vec![1, 4 * 8 * 8]);
        assert_eq!(g.output, f);
    }

    #[test]
    fn compact_drops_unreachable_nodes() {
        let mut g = Graph::new("t", &[3, 8, 8]);
        let c = g.add(conv_op(3, 4, 3, Conv2dParams::same(3)), vec![0]);
        let _dead = g.add(Op::Relu, vec![c]);
        g.set_output(c);
        g.compact();
        assert_eq!(g.nodes.len(), 2);
        assert_eq!(g.output, 1);
    }

    #[test]
    fn consumer_counts_include_the_output_use() {
        let mut g = Graph::new("t", &[3, 8, 8]);
        let c = g.add(conv_op(3, 4, 3, Conv2dParams::same(3)), vec![0]);
        let counts = g.consumer_counts();
        assert_eq!(counts[0], 1);
        assert_eq!(counts[c], 1); // the external output use
    }

    #[test]
    fn activation_bytes_count_quant_nodes_as_one_byte() {
        let mut g = Graph::new("t", &[3, 8, 8]);
        let c = g.add(conv_op(3, 4, 3, Conv2dParams::same(3)), vec![0]);
        let full = g.activation_bytes(1);
        g.nodes[c].quant_out = true;
        assert_eq!(g.activation_bytes(1) * 4, full);
    }

    #[test]
    fn render_mentions_ops_and_output() {
        let mut g = Graph::new("t", &[3, 8, 8]);
        g.add(conv_op(3, 4, 3, Conv2dParams::same(3)), vec![0]);
        let s = g.render();
        assert!(s.contains("conv2d"));
        assert!(s.contains("(output)"));
    }
}
