//! Typed graph IR, optimization passes and the compiled-plan executor —
//! the compilation layer between [`crate::nn`]'s layer list and the
//! [`crate::kernels`].
//!
//! A [`crate::nn::Model`] *lowers* into a [`Graph`] of typed [`Node`]s
//! (static shape and dtype facts per edge), [`passes::optimize`]
//! rewrites it — epilogue fusion, pad elision, quantize-boundary
//! hoisting, see [`passes`] for the exactness argument behind each —
//! and the result executes as a [`CompiledPlan`] through an ordinary
//! [`crate::exec::ExecCtx`]. The paper's memory-bound thesis is what
//! motivates every pass: each one removes a full read+write of an
//! activation tensor, which on commodity CPUs is worth more than the
//! arithmetic it rearranges.
//!
//! On top of compilation sits the whole-model [`planner`]: it consumes
//! the graph's per-node FLOP/byte accounting plus the cached
//! [`crate::autotune::DispatchProfile`] and assigns each conv node a
//! [`PlannedChoice`] — algorithm × worker split — under a peak-memory
//! budget; [`CompiledPlan::with_choices`] makes the executor honour it.
//!
//! The `SWCONV_NO_FUSE` environment variable (any non-empty value other
//! than `"0"`) disables the pass pipeline process-wide —
//! [`crate::nn::Model::compile`] then returns a verbatim, unfused plan.
//! The CLI's `--no-fuse` flag sets the same switch. This mirrors the
//! `SWCONV_NO_POOL` escape hatch for the worker pool: a one-knob A/B
//! lever for benchmarks and CI; `SWCONV_FORCE_PLAN` ([`plan_forced`])
//! is the planner's own lever — every compile attaches a planner plan,
//! so the whole suite runs the planned routing.

pub mod ir;
pub mod passes;
pub mod plan;
pub mod planner;

pub use ir::{Graph, Node, NodeId, Op};
pub use passes::{optimize, PassSummary};
pub use plan::CompiledPlan;
pub use planner::{
    min_feasible_budget, plan_model, ModelPlan, PlanAlgo, PlanError, PlannedChoice,
};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;

static FUSION_DISABLED: AtomicBool = AtomicBool::new(false);
static FUSION_INIT: Once = Once::new();

static PLAN_FORCED: AtomicBool = AtomicBool::new(false);
static PLAN_INIT: Once = Once::new();

/// Should every [`crate::nn::Model::compile`] attach a planner-produced
/// per-node plan? First call consults the `SWCONV_FORCE_PLAN`
/// environment variable (any non-empty value other than `"0"`); later
/// calls (and [`set_plan_forced`]) just read/write the cached flag. The
/// CI plan leg runs the whole test suite with this set, so every zoo
/// model exercises the planned routing paths end to end — legal because
/// the executor honours a choice only where it provably preserves bits
/// (int8 routes are exact; an f32 choice outside the running ctx's
/// FP-summation family degrades to the ctx route, worker cap intact).
pub fn plan_forced() -> bool {
    PLAN_INIT.call_once(|| {
        let forced =
            matches!(std::env::var("SWCONV_FORCE_PLAN"), Ok(v) if !v.is_empty() && v != "0");
        PLAN_FORCED.store(forced, Ordering::Relaxed);
    });
    PLAN_FORCED.load(Ordering::Relaxed)
}

/// Override the forced-plan switch programmatically. Wins over the
/// environment variable regardless of call order.
pub fn set_plan_forced(forced: bool) {
    PLAN_INIT.call_once(|| {});
    PLAN_FORCED.store(forced, Ordering::Relaxed);
}

/// Is graph fusion disabled process-wide? First call consults the
/// `SWCONV_NO_FUSE` environment variable; later calls (and
/// [`set_fusion_disabled`]) just read/write the cached flag.
pub fn fusion_disabled() -> bool {
    FUSION_INIT.call_once(|| {
        let disabled = matches!(std::env::var("SWCONV_NO_FUSE"), Ok(v) if !v.is_empty() && v != "0");
        FUSION_DISABLED.store(disabled, Ordering::Relaxed);
    });
    FUSION_DISABLED.load(Ordering::Relaxed)
}

/// Override the fusion switch programmatically (the CLI's `--no-fuse`).
/// Wins over the environment variable regardless of call order.
pub fn set_fusion_disabled(disabled: bool) {
    FUSION_INIT.call_once(|| {});
    FUSION_DISABLED.store(disabled, Ordering::Relaxed);
}
