//! Typed graph IR, optimization passes and the compiled-plan executor —
//! the compilation layer between [`crate::nn`]'s layer list and the
//! [`crate::kernels`].
//!
//! A [`crate::nn::Model`] *lowers* into a [`Graph`] of typed [`Node`]s
//! (static shape and dtype facts per edge), [`passes::optimize`]
//! rewrites it — epilogue fusion, pad elision, quantize-boundary
//! hoisting, see [`passes`] for the exactness argument behind each —
//! and the result executes as a [`CompiledPlan`] through an ordinary
//! [`crate::exec::ExecCtx`]. The paper's memory-bound thesis is what
//! motivates every pass: each one removes a full read+write of an
//! activation tensor, which on commodity CPUs is worth more than the
//! arithmetic it rearranges.
//!
//! The `SWCONV_NO_FUSE` environment variable (any non-empty value other
//! than `"0"`) disables the pass pipeline process-wide —
//! [`crate::nn::Model::compile`] then returns a verbatim, unfused plan.
//! The CLI's `--no-fuse` flag sets the same switch. This mirrors the
//! `SWCONV_NO_POOL` escape hatch for the worker pool: a one-knob A/B
//! lever for benchmarks and CI.

pub mod ir;
pub mod passes;
pub mod plan;

pub use ir::{Graph, Node, NodeId, Op};
pub use passes::{optimize, PassSummary};
pub use plan::CompiledPlan;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;

static FUSION_DISABLED: AtomicBool = AtomicBool::new(false);
static FUSION_INIT: Once = Once::new();

/// Is graph fusion disabled process-wide? First call consults the
/// `SWCONV_NO_FUSE` environment variable; later calls (and
/// [`set_fusion_disabled`]) just read/write the cached flag.
pub fn fusion_disabled() -> bool {
    FUSION_INIT.call_once(|| {
        let disabled = matches!(std::env::var("SWCONV_NO_FUSE"), Ok(v) if !v.is_empty() && v != "0");
        FUSION_DISABLED.store(disabled, Ordering::Relaxed);
    });
    FUSION_DISABLED.load(Ordering::Relaxed)
}

/// Override the fusion switch programmatically (the CLI's `--no-fuse`).
/// Wins over the environment variable regardless of call order.
pub fn set_fusion_disabled(disabled: bool) {
    FUSION_INIT.call_once(|| {});
    FUSION_DISABLED.store(disabled, Ordering::Relaxed);
}
