//! Typed graph IR, optimization passes and the compiled-plan executor —
//! the compilation layer between [`crate::nn`]'s layer list and the
//! [`crate::kernels`].
//!
//! A [`crate::nn::Model`] *lowers* into a [`Graph`] of typed [`Node`]s
//! (static shape and dtype facts per edge), [`passes::optimize`]
//! rewrites it — epilogue fusion, pad elision, quantize-boundary
//! hoisting, see [`passes`] for the exactness argument behind each —
//! and the result executes as a [`CompiledPlan`] through an ordinary
//! [`crate::exec::ExecCtx`]. The paper's memory-bound thesis is what
//! motivates every pass: each one removes a full read+write of an
//! activation tensor, which on commodity CPUs is worth more than the
//! arithmetic it rearranges.
//!
//! On top of compilation sits the whole-model [`planner`]: it consumes
//! the graph's per-node FLOP/byte accounting plus the cached
//! [`crate::autotune::DispatchProfile`] and assigns each conv node a
//! [`PlannedChoice`] — algorithm × worker split — under a peak-memory
//! budget; [`CompiledPlan::with_choices`] makes the executor honour it.
//!
//! The `SWCONV_NO_FUSE` environment variable (any non-empty value other
//! than `"0"`) disables the pass pipeline process-wide —
//! [`crate::nn::Model::compile`] then returns a verbatim, unfused plan.
//! The CLI's `--no-fuse` flag sets the same switch. This mirrors the
//! `SWCONV_NO_POOL` escape hatch for the worker pool: a one-knob A/B
//! lever for benchmarks and CI; `SWCONV_FORCE_PLAN` ([`plan_forced`])
//! is the planner's own lever — every compile attaches a planner plan,
//! so the whole suite runs the planned routing.

pub mod ir;
pub mod passes;
pub mod plan;
pub mod planner;
pub mod tiling;

pub use ir::{Graph, Node, NodeId, Op};
pub use passes::{optimize, PassSummary};
pub use plan::CompiledPlan;
pub use planner::{
    min_feasible_budget, plan_model, ModelPlan, PlanAlgo, PlanError, PlannedChoice,
};
pub use tiling::{ChainTiling, TileMode, TilingPlan};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Once;

static FUSION_DISABLED: AtomicBool = AtomicBool::new(false);
static FUSION_INIT: Once = Once::new();

static PLAN_FORCED: AtomicBool = AtomicBool::new(false);
static PLAN_INIT: Once = Once::new();

static TILE_FORCED: AtomicBool = AtomicBool::new(false);
static TILE_INIT: Once = Once::new();

/// Forced tile shape (`--tile HxW`), packed `h << 32 | w`; 0 = unset.
static TILE_SHAPE: AtomicU64 = AtomicU64::new(0);

/// Should every [`crate::nn::Model::compile`] attach a planner-produced
/// per-node plan? First call consults the `SWCONV_FORCE_PLAN`
/// environment variable (any non-empty value other than `"0"`); later
/// calls (and [`set_plan_forced`]) just read/write the cached flag. The
/// CI plan leg runs the whole test suite with this set, so every zoo
/// model exercises the planned routing paths end to end — legal because
/// the executor honours a choice only where it provably preserves bits
/// (int8 routes are exact; an f32 choice outside the running ctx's
/// FP-summation family degrades to the ctx route, worker cap intact).
pub fn plan_forced() -> bool {
    PLAN_INIT.call_once(|| {
        let forced =
            matches!(std::env::var("SWCONV_FORCE_PLAN"), Ok(v) if !v.is_empty() && v != "0");
        PLAN_FORCED.store(forced, Ordering::Relaxed);
    });
    PLAN_FORCED.load(Ordering::Relaxed)
}

/// Override the forced-plan switch programmatically. Wins over the
/// environment variable regardless of call order.
pub fn set_plan_forced(forced: bool) {
    PLAN_INIT.call_once(|| {});
    PLAN_FORCED.store(forced, Ordering::Relaxed);
}

/// Should every [`CompiledPlan::run`] execute its fusable conv/pool
/// chains tile-by-tile? First call consults the `SWCONV_FORCE_TILE`
/// environment variable (any non-empty value other than `"0"`); later
/// calls (and [`set_tiling_forced`]) just read/write the cached flag.
/// The CI tiling leg runs the whole test suite with this set, so every
/// zoo model exercises the halo-aware region kernels end to end —
/// legal because tiled execution is bit-identical to untiled by
/// construction (see [`tiling`]).
pub fn tiling_forced() -> bool {
    TILE_INIT.call_once(|| {
        let forced =
            matches!(std::env::var("SWCONV_FORCE_TILE"), Ok(v) if !v.is_empty() && v != "0");
        TILE_FORCED.store(forced, Ordering::Relaxed);
    });
    TILE_FORCED.load(Ordering::Relaxed)
}

/// Override the forced-tiling switch programmatically (the CLI's
/// `--tile`). Wins over the environment variable regardless of call
/// order.
pub fn set_tiling_forced(forced: bool) {
    TILE_INIT.call_once(|| {});
    TILE_FORCED.store(forced, Ordering::Relaxed);
}

/// The forced tile shape (`--tile HxW`), if one is set. When present,
/// [`tiling::analyze`] uses this exact output-tile shape for every
/// chain instead of sizing tiles from the cache budget.
pub fn forced_tile_shape() -> Option<(usize, usize)> {
    let packed = TILE_SHAPE.load(Ordering::Relaxed);
    if packed == 0 {
        None
    } else {
        Some(((packed >> 32) as usize, (packed & 0xffff_ffff) as usize))
    }
}

/// Set (or with `None` clear) the forced tile shape. Dimensions are
/// clamped to `1..=u32::MAX`; `(h, w)` is the output-space tile in
/// rows × columns.
pub fn set_forced_tile_shape(shape: Option<(usize, usize)>) {
    let packed = match shape {
        None => 0,
        Some((h, w)) => {
            let h = (h.max(1) as u64).min(u32::MAX as u64);
            let w = (w.max(1) as u64).min(u32::MAX as u64);
            (h << 32) | w
        }
    };
    TILE_SHAPE.store(packed, Ordering::Relaxed);
}

/// Is graph fusion disabled process-wide? First call consults the
/// `SWCONV_NO_FUSE` environment variable; later calls (and
/// [`set_fusion_disabled`]) just read/write the cached flag.
pub fn fusion_disabled() -> bool {
    FUSION_INIT.call_once(|| {
        let disabled = matches!(std::env::var("SWCONV_NO_FUSE"), Ok(v) if !v.is_empty() && v != "0");
        FUSION_DISABLED.store(disabled, Ordering::Relaxed);
    });
    FUSION_DISABLED.load(Ordering::Relaxed)
}

/// Override the fusion switch programmatically (the CLI's `--no-fuse`).
/// Wins over the environment variable regardless of call order.
pub fn set_fusion_disabled(disabled: bool) {
    FUSION_INIT.call_once(|| {});
    FUSION_DISABLED.store(disabled, Ordering::Relaxed);
}
