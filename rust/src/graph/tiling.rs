//! Cache-blocked tiling analysis: keep fused conv chains L2-resident.
//!
//! The [`super::plan`] executor materialises every intermediate
//! activation at full size, so a deep conv→relu→conv→pool chain writes
//! each tensor to DRAM and reads it right back. This module finds the
//! *fusable chains* — maximal runs of consecutive single-consumer
//! window/elementwise nodes the sliding kernels can evaluate per output
//! rect — and partitions each chain's final output plane into spatial
//! tiles sized so the whole chain's per-tile working set fits the
//! detected L2 budget ([`crate::exec::CacheInfo::tile_budget_bytes`]).
//! The executor then runs each chain tile-by-tile through the
//! [`crate::kernels::region`] kernels, with every intermediate
//! materialised only at tile size.
//!
//! ## Halo inference
//!
//! A tile of the chain's *final* output pins, walking backwards through
//! the chain via [`input_region`], the input rect every link needs —
//! the tile's *halo*, growing by `k − stride` per window op. The
//! backward rects double as each link's output rect, so one tile of the
//! chain is just the region kernels chained over those rects.
//!
//! ## Eligibility mirrors the untiled router
//!
//! Tiled execution must be **bit-identical** to untiled, so a node is
//! chain-eligible only when the untiled executor would provably run the
//! position-uniform sliding kernel for it — the same resolution the
//! executor applies: a planned choice is honoured only within the ctx
//! route's FP-summation family (`f32_family_compatible`), a
//! `Tuned` ctx resolves per filter width through the attached profile,
//! and GEMM/direct routes are never tiled. Int8 convs additionally run
//! head-only (their output is dequantized f32; a second int8 conv would
//! re-quantize against a tensor-wide max the tile cannot see), and
//! quantize-boundary (`quant_out`) nodes are excluded for the same
//! reason.
//!
//! ## Cost model
//!
//! Per-tile working set = the max over links of (input tile + output
//! tile + local padded plane + pool row scratch), with the untiled
//! working set being the same expression at the full-plane "tile" —
//! so a full-plane tile costs exactly the untiled estimate and any
//! smaller tile strictly shrinks it. [`TileMode::OverBudget`] (the
//! planner) tiles only chains whose untiled set exceeds the budget;
//! [`TileMode::ForceAll`] (`SWCONV_FORCE_TILE`, `--tile`) tiles every
//! eligible chain so parity suites cover the region kernels everywhere.

use super::ir::{Graph, Node, NodeId, Op};
use super::planner::{default_route, f32_family_compatible, PlanAlgo, PlannedChoice};
use crate::autotune::TunedAlgo;
use crate::exec::{CacheInfo, ExecCtx};
use crate::kernels::region::{input_region, Rect};
use crate::kernels::rowconv::Q8_MAX_TAPS;
use crate::kernels::sliding2d::SlideVariant;
use crate::kernels::ConvAlgo;
use crate::simd::LANES;
use crate::tensor::Dtype;

/// How one chain node executes per tile — the routing decision the
/// analysis froze so the executor never re-derives it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Link {
    /// f32 sliding conv with the resolved row-kernel variant.
    ConvF32(SlideVariant),
    /// bf16 sliding conv (f32 boundary, bf16 rounding at the write).
    ConvBf16,
    /// int8 sliding conv with fused dequant (chain head only).
    ConvQ8,
    /// Sliding pool; `true` = max, `false` = avg.
    Pool(bool),
    /// Elementwise ReLU (identity geometry).
    Relu,
}

/// One chain node's link kind plus its window geometry and plane
/// shapes — everything the per-tile executor and the cost model need.
#[derive(Clone, Copy, Debug)]
pub(crate) struct LinkGeom {
    /// How the node executes per tile.
    pub(crate) link: Link,
    /// Window `(kh, kw)` (`(1, 1)` for ReLU).
    pub(crate) k: (usize, usize),
    /// Stride `(sh, sw)`.
    pub(crate) stride: (usize, usize),
    /// Padding `(ph, pw)`.
    pub(crate) pad: (usize, usize),
    /// Input channels.
    pub(crate) c_in: usize,
    /// Output channels.
    pub(crate) c_out: usize,
    /// Input plane `(h, w)`.
    pub(crate) in_hw: (usize, usize),
    /// Output plane `(h, w)`.
    pub(crate) out_hw: (usize, usize),
}

/// Which chains the analysis should tile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileMode {
    /// Tile every eligible chain, even when the untiled working set
    /// already fits cache — the `SWCONV_FORCE_TILE` / `--tile` mode,
    /// and what the parity suites sweep.
    ForceAll,
    /// Tile only chains whose untiled intra-chain working set exceeds
    /// the cache budget (and where tiling actually shrinks it) — the
    /// planner's default.
    OverBudget,
}

/// One tiled chain: nodes `start..=end` run fused, tile-by-tile, with
/// the chain result landing in `end`'s slot.
#[derive(Clone, Debug)]
pub struct ChainTiling {
    /// First node of the chain (consumes the chain's external input).
    pub start: NodeId,
    /// Last node of the chain (produces the chain's observable output).
    pub end: NodeId,
    /// Output-space tile shape `(rows, cols)` on `end`'s plane.
    pub tile: (usize, usize),
    /// Estimated per-tile working set at that shape, in bytes.
    pub tiled_bytes: u64,
    /// Estimated untiled intra-chain working set, in bytes.
    pub untiled_bytes: u64,
    /// Per-node link kinds and geometry, `start` first.
    pub(crate) geoms: Vec<LinkGeom>,
}

impl ChainTiling {
    /// The chain end's output plane `(h, w)`.
    pub fn out_hw(&self) -> (usize, usize) {
        self.geoms.last().expect("chains have ≥ 2 nodes").out_hw
    }

    /// The row-major tile grid over the chain end's output plane:
    /// `tile`-sized rects, clamped at the right/bottom edges. Covers
    /// the plane exactly, without overlap.
    pub fn tiles(&self) -> Vec<Rect> {
        let (oh, ow) = self.out_hw();
        let (th, tw) = self.tile;
        let mut v = Vec::new();
        let mut y0 = 0;
        while y0 < oh {
            let y1 = (y0 + th).min(oh);
            let mut x0 = 0;
            while x0 < ow {
                let x1 = (x0 + tw).min(ow);
                v.push(Rect { y0, y1, x0, x1 });
                x0 = x1;
            }
            y0 = y1;
        }
        v
    }

    /// The output rect of *each* chain node (`start` first) for one
    /// tile of the chain end: `tile` walked backwards through
    /// [`input_region`]. The analysis validated every grid tile's walk
    /// stays non-empty, so this cannot fail on a rect from
    /// [`ChainTiling::tiles`].
    pub(crate) fn backward_rects(&self, tile: Rect) -> Vec<Rect> {
        backward_rects(&self.geoms, tile).expect("tile grid validated at analysis time")
    }

    /// One human-readable summary line (the `compile` report).
    pub fn render(&self) -> String {
        let (oh, ow) = self.out_hw();
        let grid = self.tiles().len();
        format!(
            "chain %{}..%{}: tile {}x{} of {}x{} ({} tiles), per-tile ~{}, untiled ~{}",
            self.start,
            self.end,
            self.tile.0,
            self.tile.1,
            oh,
            ow,
            grid,
            fmt_bytes(self.tiled_bytes),
            fmt_bytes(self.untiled_bytes),
        )
    }
}

/// The tiling decisions for one compiled graph under one ctx: zero or
/// more non-overlapping [`ChainTiling`]s, in node order.
#[derive(Clone, Debug, Default)]
pub struct TilingPlan {
    /// The tiled chains (non-overlapping node ranges, ascending).
    pub chains: Vec<ChainTiling>,
}

impl TilingPlan {
    /// True when nothing gets tiled.
    pub fn is_empty(&self) -> bool {
        self.chains.is_empty()
    }

    /// The chain whose first node is `id`, if any — how the executor
    /// probes "does a tiled chain start here?" per node.
    pub fn chain_starting_at(&self, id: NodeId) -> Option<&ChainTiling> {
        self.chains.iter().find(|c| c.start == id)
    }
}

/// Analyze a graph's fusable chains and size their tiles from the
/// detected cache hierarchy (honouring a CLI-forced tile shape,
/// [`super::forced_tile_shape`]). `choices` is the planner's per-node
/// assignment when one is attached — eligibility must see it, because
/// it changes what the untiled executor runs.
pub fn analyze(
    graph: &Graph,
    choices: Option<&[Option<PlannedChoice>]>,
    ctx: &ExecCtx,
    batch: usize,
    mode: TileMode,
) -> TilingPlan {
    let budget = CacheInfo::detect().tile_budget_bytes() as u64;
    analyze_with(graph, choices, ctx, batch, mode, budget, super::forced_tile_shape())
}

/// [`analyze`] with the cache budget and forced tile shape passed
/// explicitly (testable without environment overrides).
pub(crate) fn analyze_with(
    graph: &Graph,
    choices: Option<&[Option<PlannedChoice>]>,
    ctx: &ExecCtx,
    batch: usize,
    mode: TileMode,
    budget: u64,
    forced: Option<(usize, usize)>,
) -> TilingPlan {
    let mut chains = Vec::new();
    for (start, end, geoms) in find_chains(graph, choices, ctx) {
        let Some(ct) = size_chain(start, end, geoms, batch, budget, forced) else {
            continue;
        };
        let keep = match mode {
            TileMode::ForceAll => true,
            TileMode::OverBudget => {
                ct.untiled_bytes > budget && ct.tiled_bytes < ct.untiled_bytes
            }
        };
        if keep {
            chains.push(ct);
        }
    }
    TilingPlan { chains }
}

/// The maximal fusable chains: runs of ≥ 2 consecutive node ids where
/// every node is link-eligible under this ctx (+ optional plan), every
/// non-head node's only input is its predecessor, and every
/// intermediate has exactly one consumer (so skipping its full-size
/// materialisation is unobservable).
pub(crate) fn find_chains(
    graph: &Graph,
    choices: Option<&[Option<PlannedChoice>]>,
    ctx: &ExecCtx,
) -> Vec<(NodeId, NodeId, Vec<LinkGeom>)> {
    let uses = graph.consumer_counts();
    let choice_at =
        |id: usize| choices.and_then(|c| c.get(id)).and_then(|o| o.as_ref());
    let n = graph.nodes.len();
    let mut res = Vec::new();
    let mut id = 1;
    while id < n {
        if uses[id] == 0 {
            id += 1; // dead node — the executor skips it
            continue;
        }
        let node = &graph.nodes[id];
        let head = match link_kind(node, choice_at(id), ctx, true) {
            Some(l) if node.inputs.len() == 1 => l,
            _ => {
                id += 1;
                continue;
            }
        };
        // An i8-codes input (quantize-boundary producer) only feeds a
        // `QuantConv2d` head; every other head needs an f32 input.
        let q8_input = graph.nodes[node.inputs[0]].quant_out;
        let head_ok = match head {
            Link::ConvQ8 => !q8_input || matches!(node.op, Op::QuantConv2d { .. }),
            _ => !q8_input,
        };
        if !head_ok {
            id += 1;
            continue;
        }
        let Some(mut geoms) = link_geom(graph, id, head) else {
            id += 1;
            continue;
        };
        let mut end = id;
        while end + 1 < n {
            let nid = end + 1;
            // The would-be intermediate `end` must be consumed only by
            // `nid` (the output node carries an extra external use, so
            // it can never become an intermediate).
            if uses[nid] == 0 || uses[end] != 1 || graph.nodes[nid].inputs != [end] {
                break;
            }
            let Some(link) = link_kind(&graph.nodes[nid], choice_at(nid), ctx, false) else {
                break;
            };
            let Some(g) = link_geom(graph, nid, link) else {
                break;
            };
            geoms.push(g);
            end = nid;
        }
        if end > id {
            res.push((id, end, geoms));
            id = end + 1;
        } else {
            id += 1;
        }
    }
    res
}

/// Can this node run as a chain link under this ctx (+ optional
/// planner choice), and how? Mirrors the untiled executor's routing
/// exactly — `None` whenever the untiled path might run anything but
/// the position-uniform sliding kernel.
pub(crate) fn link_kind(
    node: &Node,
    choice: Option<&PlannedChoice>,
    ctx: &ExecCtx,
    head: bool,
) -> Option<Link> {
    if node.quant_out || node.shape.len() != 4 {
        return None; // i8-codes output, or post-flatten elementwise
    }
    match &node.op {
        Op::Relu => Some(Link::Relu),
        Op::MaxPool2d(_) => Some(Link::Pool(true)),
        Op::AvgPool2d(_) => Some(Link::Pool(false)),
        Op::Conv2d { w, .. } => {
            let (c_in_g, kh, kw) = (w.dim(1), w.dim(2), w.dim(3));
            match ctx.dtype() {
                Dtype::F32 => f32_conv_link(kw, choice, ctx),
                Dtype::Bf16 => bf16_sliding_routed(kw, ctx).then_some(Link::ConvBf16),
                Dtype::I8 => (head
                    && c_in_g * kh * kw <= Q8_MAX_TAPS
                    && q8_sliding_routed(kw, choice, ctx))
                .then_some(Link::ConvQ8),
                // No i32 conv kernel family to mirror — leave untiled.
                Dtype::I32 => None,
            }
        }
        Op::QuantConv2d { qw, .. } => {
            // Always runs int8, regardless of the serving dtype.
            let (c_in_g, kh, kw) = (qw.dim(1), qw.dim(2), qw.dim(3));
            (head && c_in_g * kh * kw <= Q8_MAX_TAPS && q8_sliding_routed(kw, choice, ctx))
                .then_some(Link::ConvQ8)
        }
        _ => None,
    }
}

/// f32 conv link resolution — the untiled executor honours a planned
/// choice only within the ctx route's FP-summation family, then the
/// surviving algorithm must be the sliding kernel with a variant that
/// supports the width (an unsupported `Auto` falls back to the direct
/// kernel untiled, so it is not position-uniform → not tileable).
fn f32_conv_link(kw: usize, choice: Option<&PlannedChoice>, ctx: &ExecCtx) -> Option<Link> {
    let route = default_route(ctx, kw, ctx.dtype());
    let honoured = choice.filter(|c| f32_family_compatible(c.algo, route));
    let variant = match honoured {
        Some(c) => {
            if c.algo != PlanAlgo::Sliding {
                return None;
            }
            SlideVariant::Auto
        }
        None => match ctx.algo {
            ConvAlgo::Sliding => SlideVariant::Auto,
            ConvAlgo::SlidingGeneric => SlideVariant::Generic,
            ConvAlgo::SlidingCompound => SlideVariant::Compound,
            ConvAlgo::Tuned => {
                if ctx.tuned_choice(kw).0 != TunedAlgo::Sliding {
                    return None;
                }
                SlideVariant::Auto
            }
            ConvAlgo::Direct | ConvAlgo::Im2colGemm => return None,
        },
    };
    variant.supports(kw).then_some(Link::ConvF32(variant))
}

/// Does the untiled bf16 path run the sliding bf16 kernel under this
/// ctx? (Non-sliding routes widen to f32 and run the f32 kernel with
/// bf16 rounding applied outside — a different summation, not
/// tileable.) The planner never re-routes bf16 nodes (its candidate
/// set is sliding-only), so no choice parameter.
fn bf16_sliding_routed(kw: usize, ctx: &ExecCtx) -> bool {
    match ctx.algo {
        ConvAlgo::Sliding | ConvAlgo::SlidingGeneric | ConvAlgo::SlidingCompound => true,
        ConvAlgo::Tuned => ctx.tuned_choice_for(kw, Dtype::Bf16).0 == TunedAlgo::Sliding,
        ConvAlgo::Direct | ConvAlgo::Im2colGemm => false,
    }
}

/// Does the untiled int8 path run the sliding int8 kernel? Planned:
/// `Direct | Sliding` both map to the sliding kernel
/// (`conv2d_q8_raw_planned_ctx`); unplanned: anything but an explicit
/// (or tuned) GEMM route (`conv2d_q8_raw_routed_ctx`).
fn q8_sliding_routed(kw: usize, choice: Option<&PlannedChoice>, ctx: &ExecCtx) -> bool {
    match choice {
        Some(c) => matches!(c.algo, PlanAlgo::Direct | PlanAlgo::Sliding),
        None => {
            let gemm = ctx.algo == ConvAlgo::Im2colGemm
                || (ctx.algo == ConvAlgo::Tuned
                    && ctx.tuned_choice_for(kw, Dtype::I8).0 == TunedAlgo::Gemm);
            !gemm
        }
    }
}

/// Window geometry + plane shapes for one chain node. `None` when the
/// shapes are not the `[1, c, h, w]` the tiler expects (symbolic batch
/// 1 — the executor scales by the runtime batch).
fn link_geom(graph: &Graph, id: NodeId, link: Link) -> Option<LinkGeom> {
    let node = &graph.nodes[id];
    let in_shape = &graph.nodes[node.inputs[0]].shape;
    if node.shape.len() != 4 || in_shape.len() != 4 {
        return None;
    }
    let (k, stride, pad) = match &node.op {
        Op::Conv2d { w, params, .. } => ((w.dim(2), w.dim(3)), params.stride, params.pad),
        Op::QuantConv2d { qw, params, .. } => {
            ((qw.dim(2), qw.dim(3)), params.stride, params.pad)
        }
        Op::MaxPool2d(p) | Op::AvgPool2d(p) => (p.k, p.stride, p.pad),
        Op::Relu => ((1, 1), (1, 1), (0, 0)),
        _ => return None,
    };
    Some(LinkGeom {
        link,
        k,
        stride,
        pad,
        c_in: in_shape[1],
        c_out: node.shape[1],
        in_hw: (in_shape[2], in_shape[3]),
        out_hw: (node.shape[2], node.shape[3]),
    })
}

/// Walk one final-output tile backwards through the chain: the output
/// rect each link must produce (`start` first; the last entry is
/// `tile` itself). `None` if any intermediate rect clamps to empty —
/// a link would be asked for zero output (only reachable with padding
/// ≥ the data span); such tile shapes are rejected.
fn backward_rects(geoms: &[LinkGeom], tile: Rect) -> Option<Vec<Rect>> {
    let mut rects = vec![tile; geoms.len()];
    let mut r = tile;
    for (j, g) in geoms.iter().enumerate().rev() {
        if r.is_empty() {
            return None;
        }
        rects[j] = r;
        r = input_region(r, g.k, g.stride, g.pad, g.in_hw.0, g.in_hw.1);
        // `r` is now link j's *input* rect == link j−1's output rect.
        // The head's input rect (final `r`) may clamp freely — the head
        // reads the full input tensor, empty just means all-padding.
    }
    Some(rects)
}

/// Size one chain's tile: start from the full output plane (or the
/// forced shape) and halve the larger tile dimension until the
/// per-tile working set fits the budget or the tile is 1×1. Returns
/// `None` when the tile grid fails [`backward_rects`] validation.
fn size_chain(
    start: NodeId,
    end: NodeId,
    geoms: Vec<LinkGeom>,
    batch: usize,
    budget: u64,
    forced: Option<(usize, usize)>,
) -> Option<ChainTiling> {
    let (oh, ow) = geoms.last()?.out_hw;
    let untiled_bytes = tile_working_bytes(&geoms, (oh, ow), batch);
    let (mut th, mut tw) = match forced {
        Some((h, w)) => (h.min(oh), w.min(ow)),
        None => (oh, ow),
    };
    if forced.is_none() {
        while tile_working_bytes(&geoms, (th, tw), batch) > budget && (th > 1 || tw > 1) {
            if th >= tw {
                th = th.div_ceil(2);
            } else {
                tw = tw.div_ceil(2);
            }
        }
    }
    let tiled_bytes = tile_working_bytes(&geoms, (th, tw), batch);
    let chain = ChainTiling { start, end, tile: (th, tw), tiled_bytes, untiled_bytes, geoms };
    // Validate the backward walk on the grid's corner tiles: rect
    // bounds are monotone in the tile's bounds per axis, so emptiness
    // (a window fully inside padding) can only first appear on the
    // extreme tile rows/columns.
    let tiles = chain.tiles();
    let rows = oh.div_ceil(th);
    let cols = ow.div_ceil(tw);
    let corners = [0, cols - 1, (rows - 1) * cols, rows * cols - 1];
    for idx in corners {
        backward_rects(&chain.geoms, tiles[idx])?;
    }
    Some(chain)
}

/// Estimated working set (bytes) of running the chain at output tile
/// shape `tile` and batch `n`: the max over links of input tile +
/// output tile + the link's local padded plane(s) and row scratch.
/// Evaluated at the full output plane this is the *untiled* intra-chain
/// working set (the full-size activations + the untiled kernels' full
/// padded planes), so tiled and untiled estimates are one expression.
fn tile_working_bytes(geoms: &[LinkGeom], tile: (usize, usize), n: usize) -> u64 {
    let f4 = 4u64;
    let mut peak = 0u64;
    let (mut eh, mut ew) = tile;
    for g in geoms.iter().rev() {
        let eh_c = eh.min(g.out_hw.0).max(1);
        let ew_c = ew.min(g.out_hw.1).max(1);
        // Unclamped halo extent (interior tile: the worst case) …
        let ih = (eh_c - 1) * g.stride.0 + g.k.0;
        let iw = (ew_c - 1) * g.stride.1 + g.k.1;
        // … and the in-plane portion actually buffered.
        let ih_c = ih.min(g.in_hw.0);
        let iw_c = iw.min(g.in_hw.1);
        let inb = (n * g.c_in * ih_c * iw_c) as u64 * f4;
        let outb = (n * g.c_out * eh_c * ew_c) as u64 * f4;
        // Local padded plane geometry (matches `kernels::region`).
        let hp_l = ih;
        let ulen = (ew_c - 1) * g.stride.1 + 1;
        let local = match g.link {
            Link::Relu => 0,
            // Per-plane padded buffer + the horizontal-combine rows.
            Link::Pool(_) => {
                (hp_l * (ulen + g.k.1 + 4 * LANES) + hp_l * (ulen + LANES) + ulen) as u64 * f4
            }
            // Per-image all-channel padded buffer + the row accumulator.
            Link::ConvF32(_) => {
                (g.c_in * hp_l * (ulen + g.k.1 + 2 * LANES) + ulen) as u64 * f4
            }
            Link::ConvBf16 => {
                (g.c_in * hp_l * (ulen + g.k.1 + 2 * LANES)) as u64 * 2 + ulen as u64 * f4
            }
            Link::ConvQ8 => {
                (g.c_in * hp_l * (ulen + g.k.1 + 2 * LANES)) as u64 + ulen as u64 * f4
            }
        };
        peak = peak.max(inb + outb + local);
        eh = ih_c;
        ew = iw_c;
    }
    peak
}

/// Human-readable byte count for the render lines.
fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 20 {
        format!("{:.1} MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1} KiB", b as f64 / (1u64 << 10) as f64)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{Conv2dParams, PoolParams};
    use crate::tensor::Tensor;

    fn conv_op(c_in: usize, c_out: usize, k: usize, params: Conv2dParams) -> Op {
        Op::Conv2d {
            w: Tensor::randn(&[c_out, c_in / params.groups, k, k], 7),
            bias: vec![0.1; c_out],
            params,
        }
    }

    /// conv(4→8,k3,same) → relu → conv(8→8,k3,same) → maxpool(2,s2) on
    /// a 16×16 input: one maximal 4-node chain ending on an 8×8 plane.
    fn chain_graph() -> Graph {
        let mut g = Graph::new("t", &[4, 16, 16]);
        let c1 = g.add(conv_op(4, 8, 3, Conv2dParams::same(3)), vec![0]);
        let r1 = g.add(Op::Relu, vec![c1]);
        let c2 = g.add(conv_op(8, 8, 3, Conv2dParams::same(3)), vec![r1]);
        let _p1 = g.add(Op::MaxPool2d(PoolParams::with_stride(2, 2)), vec![c2]);
        g
    }

    #[test]
    fn force_all_full_plane_when_budget_large() {
        let g = chain_graph();
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let plan = analyze_with(&g, None, &ctx, 1, TileMode::ForceAll, u64::MAX, None);
        assert_eq!(plan.chains.len(), 1);
        let c = &plan.chains[0];
        assert_eq!((c.start, c.end), (1, 4));
        assert_eq!(c.tile, (8, 8), "budget never binds → full output plane");
        assert_eq!(c.tiled_bytes, c.untiled_bytes);
        assert_eq!(c.tiles().len(), 1);
        assert_eq!(c.tiles()[0], Rect::full(8, 8));
    }

    #[test]
    fn tight_budget_shrinks_tile() {
        let g = chain_graph();
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let plan = analyze_with(&g, None, &ctx, 1, TileMode::ForceAll, 6 << 10, None);
        let c = &plan.chains[0];
        assert!(c.tile < (8, 8), "tile must shrink under a 6 KiB budget, got {:?}", c.tile);
        assert!(c.tiled_bytes < c.untiled_bytes);
        // The grid still covers the plane exactly.
        let area: usize = c.tiles().iter().map(Rect::area).sum();
        assert_eq!(area, 64);
    }

    #[test]
    fn forced_shape_overrides_budget() {
        let g = chain_graph();
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let plan =
            analyze_with(&g, None, &ctx, 1, TileMode::ForceAll, u64::MAX, Some((3, 5)));
        let c = &plan.chains[0];
        assert_eq!(c.tile, (3, 5));
        let tiles = c.tiles();
        assert_eq!(tiles.len(), 6, "ceil(8/3) x ceil(8/5) grid");
        let area: usize = tiles.iter().map(Rect::area).sum();
        assert_eq!(area, 64);
        // Every grid tile's backward walk reaches the head non-empty.
        for t in tiles {
            let rects = c.backward_rects(t);
            assert_eq!(rects.len(), 4);
            assert_eq!(rects[3], t);
            assert!(rects.iter().all(|r| !r.is_empty()));
        }
    }

    #[test]
    fn over_budget_mode_only_tiles_spilling_chains() {
        let g = chain_graph();
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let huge = analyze_with(&g, None, &ctx, 1, TileMode::OverBudget, u64::MAX, None);
        assert!(huge.is_empty(), "everything fits → nothing to tile");
        let tiny = analyze_with(&g, None, &ctx, 1, TileMode::OverBudget, 6 << 10, None);
        assert_eq!(tiny.chains.len(), 1);
        assert!(tiny.chains[0].tile < (8, 8));
    }

    #[test]
    fn gemm_ctx_yields_no_conv_chains() {
        let g = chain_graph();
        let ctx = ExecCtx::new(ConvAlgo::Im2colGemm);
        let plan = analyze_with(&g, None, &ctx, 1, TileMode::ForceAll, u64::MAX, None);
        assert!(
            plan.chains.iter().all(|c| (c.start..=c.end).all(|id| id != 1 && id != 3)),
            "GEMM-routed convs must stay untiled"
        );
    }

    #[test]
    fn i8_ctx_runs_int8_convs_head_only() {
        let g = chain_graph();
        let ctx = ExecCtx::new(ConvAlgo::Sliding).with_dtype(Dtype::I8);
        let plan = analyze_with(&g, None, &ctx, 1, TileMode::ForceAll, u64::MAX, None);
        let spans: Vec<_> = plan.chains.iter().map(|c| (c.start, c.end)).collect();
        assert_eq!(spans, vec![(1, 2), (3, 4)], "second conv must start its own chain");
        assert_eq!(plan.chains[0].geoms[0].link, Link::ConvQ8);
        assert_eq!(plan.chains[1].geoms[0].link, Link::ConvQ8);
    }

    #[test]
    fn branch_breaks_the_chain() {
        let mut g = Graph::new("t", &[4, 16, 16]);
        let c1 = g.add(conv_op(4, 8, 3, Conv2dParams::same(3)), vec![0]);
        let r1 = g.add(Op::Relu, vec![c1]);
        let _c2 = g.add(conv_op(8, 8, 3, Conv2dParams::same(3)), vec![r1]);
        // Second consumer of c1 (also the graph output): c1 now has two
        // uses, so no chain may run past it.
        let _r2 = g.add(Op::Relu, vec![c1]);
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let plan = analyze_with(&g, None, &ctx, 1, TileMode::ForceAll, u64::MAX, None);
        assert!(
            plan.chains.iter().all(|c| !(c.start <= c1 && c1 < c.end)),
            "a multi-consumer node can end a chain but never be an intermediate"
        );
        // r1 → c2 still chains.
        assert!(plan.chains.iter().any(|c| (c.start, c.end) == (r1, _c2)));
    }

    #[test]
    fn planner_choice_gates_eligibility() {
        let g = chain_graph();
        let mk = |algo| {
            let mut v: Vec<Option<PlannedChoice>> = vec![None; g.nodes.len()];
            for id in [1usize, 3] {
                v[id] = Some(PlannedChoice {
                    algo,
                    threads: 1,
                    dtype: Dtype::F32,
                    workspace_bytes: 0,
                    predicted_gflops: 1.0,
                });
            }
            v
        };
        // Under a sliding ctx, a planned Gemm is outside the route's
        // family → not honoured → the ctx's sliding route still runs.
        let sliding = ExecCtx::new(ConvAlgo::Sliding);
        let choices = mk(PlanAlgo::Gemm);
        let plan = analyze_with(
            &g,
            Some(&choices),
            &sliding,
            1,
            TileMode::ForceAll,
            u64::MAX,
            None,
        );
        assert_eq!(plan.chains.len(), 1);
        assert_eq!((plan.chains[0].start, plan.chains[0].end), (1, 4));
        // Under a GEMM ctx, a planned GemmLowMem *is* honoured — and is
        // not the sliding kernel, so the convs stay untiled.
        let gemm = ExecCtx::new(ConvAlgo::Im2colGemm);
        let choices = mk(PlanAlgo::GemmLowMem);
        let plan = analyze_with(
            &g,
            Some(&choices),
            &gemm,
            1,
            TileMode::ForceAll,
            u64::MAX,
            None,
        );
        assert!(plan.chains.iter().all(|c| (c.start..=c.end).all(|id| id != 1 && id != 3)));
    }

    #[test]
    fn pathological_padding_rejects_the_tile_grid() {
        // relu → conv(k3, pad 3): output rows 0..2 read only padding,
        // so a 1-row tile asks the relu link for an empty rect. The
        // full-plane tile is fine.
        let mut g = Graph::new("t", &[2, 4, 4]);
        let r = g.add(Op::Relu, vec![0]);
        let p = Conv2dParams { stride: (1, 1), pad: (3, 3), groups: 1 };
        let _c = g.add(conv_op(2, 2, 3, p), vec![r]);
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let forced =
            analyze_with(&g, None, &ctx, 1, TileMode::ForceAll, u64::MAX, Some((1, 8)));
        assert!(forced.is_empty(), "1-row tiles hit an empty intermediate rect");
        let full = analyze_with(&g, None, &ctx, 1, TileMode::ForceAll, u64::MAX, None);
        assert_eq!(full.chains.len(), 1);
    }

    #[test]
    fn working_set_shrinks_monotonically_with_tile() {
        let g = chain_graph();
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let plan = analyze_with(&g, None, &ctx, 1, TileMode::ForceAll, u64::MAX, None);
        let geoms = &plan.chains[0].geoms;
        let full = tile_working_bytes(geoms, (8, 8), 1);
        let half = tile_working_bytes(geoms, (4, 8), 1);
        let quarter = tile_working_bytes(geoms, (4, 4), 1);
        assert!(half < full && quarter < half);
        // Batch scales the activation term.
        assert!(tile_working_bytes(geoms, (4, 4), 8) > quarter);
    }
}
