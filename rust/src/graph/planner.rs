//! The whole-model inference planner: per-layer algorithm ×
//! thread-split choices under a peak-memory budget.
//!
//! The autotuner ([`crate::autotune`]) picks the fastest kernel per
//! (filter-width bucket, threads, dtype, ISA) in isolation; ZNNi
//! (arXiv:1606.05688) observes that the end-to-end win comes from
//! planning per *layer* across the whole network — a kernel that wins a
//! microbenchmark can lose once its column-matrix footprint evicts the
//! neighbouring layers' activations, and the right thread split for one
//! conv depends on how much transient scratch the budget has left. This
//! module searches that space:
//!
//! * **Inputs** — the compiled graph's per-node FLOP and activation-byte
//!   accounting ([`Graph::node_flops`] / [`Graph::node_activation_bytes`]),
//!   the cached [`DispatchProfile`]'s measured GFLOPS
//!   ([`DispatchProfile::measured_at`]), and a configurable peak-memory
//!   budget.
//! * **Search** — dynamic programming over the topologically ordered
//!   node sequence. Because every candidate's workspace is transient
//!   (checked back into the arena before the next node runs), the DP
//!   value function separates: the optimal plan is the per-node argmin
//!   of predicted time among candidates whose `live frontier +
//!   workspace` fits the budget, where the live frontier is the same
//!   consumer-countdown simulation the executor performs
//!   ([`CompiledPlan::run`] recycles a buffer the moment its last
//!   consumer has run). Fan-out (Concat/Fire) needs no special casing:
//!   both branches' tensors are live in the frontier until the join
//!   consumes them, so each branch is planned under the barrier's
//!   residual budget automatically.
//! * **Candidates** — per conv node: algorithm ∈ {direct, one-shot
//!   im2col+GEMM, **low-memory strip GEMM**
//!   ([`crate::kernels::im2col::conv2d_im2col_lowmem_epi_ctx`] — the
//!   Anderson-et-al. accumulating-im2col/kn2row point below the full
//!   im2col footprint), sliding} × worker split ∈ powers of two up to
//!   the ctx's thread count. The dtype axis is an *input*, not a free
//!   variable: serving dtype is part of the request contract (planned
//!   output must stay bitwise-equal to the unplanned plan), so the
//!   planner derives each node's compute dtype from it (`QuantConv2d`
//!   always runs int8; `Conv2d` follows the serving dtype) and plans
//!   within that dtype's kernel set.
//!
//! **The bitwise contract prunes the candidate set.** Planning is a
//! footprint/throughput lever, never an accuracy lever, and the f32
//! kernels do *not* share one floating-point summation order: the
//! sliding row kernels run one fused-multiply-add chain seeded with the
//! bias, the GEMM microkernel adds `KC`-block partial sums into the
//! output, and the direct oracle uses unfused scalar multiply-adds —
//! same arithmetic, different rounding (the kernel-equivalence suite
//! bounds the difference, it does not claim zero). So for f32 nodes the
//! planner only re-routes within the family of the route the unplanned
//! executor would take ([`ExecCtx`] algo, with `Tuned` resolved per
//! filter width): one-shot GEMM ↔ strip GEMM is the one real f32
//! interchange (the strip decomposition is order-exact, see
//! [`crate::kernels::im2col`]), plus any worker split (partitioning
//! never changes results). Int8 accumulation is exact — one right
//! answer — so every int8 route is interchangeable, and the planner
//! roams the full set there. This is what lets `tests/plan_parity.rs`
//! assert bitwise equality before any benchmark timing.

use super::ir::{Graph, Node, NodeId, Op};
use super::plan::CompiledPlan;
use super::tiling::{self, TileMode, TilingPlan};
use crate::autotune::{DispatchProfile, TunedAlgo};
use crate::exec::ExecCtx;
use crate::kernels::gemm::{pack_a_len, pack_b_len};
use crate::kernels::im2col::lowmem_strip_cols;
use crate::kernels::Conv2dParams;
use crate::simd::{IsaLevel, LANES};
use crate::tensor::{padded2d_size, Dtype};
use std::fmt;

/// Algorithm a planned conv node is forced to run — the per-node
/// generalisation of [`crate::kernels::ConvAlgo`]'s ctx-wide choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanAlgo {
    /// Naïve direct loops (no workspace, lowest throughput).
    Direct,
    /// One-shot im2col + blocked GEMM (fastest GEMM route, full
    /// `kh·kw ×` column-matrix bloat per worker).
    Gemm,
    /// Accumulating-im2col strip GEMM: bounded column strip re-expanded
    /// per GEMM call — the memory frontier below full im2col.
    GemmLowMem,
    /// Sliding Window with the paper's auto row policy.
    Sliding,
}

impl PlanAlgo {
    /// Short stable name for reports and the CLI.
    pub fn name(self) -> &'static str {
        match self {
            PlanAlgo::Direct => "direct",
            PlanAlgo::Gemm => "gemm",
            PlanAlgo::GemmLowMem => "gemm-lowmem",
            PlanAlgo::Sliding => "sliding",
        }
    }
}

/// The planner's decision for one conv node: which kernel, how many
/// workers, in which compute dtype, with the workspace and throughput
/// the decision was costed at.
#[derive(Clone, Debug)]
pub struct PlannedChoice {
    /// Kernel to run.
    pub algo: PlanAlgo,
    /// Worker cap for this node's parallel regions (≤ the ctx's thread
    /// count; applied via [`ExecCtx::set_thread_cap`], which never
    /// changes results — only footprint and speed).
    pub threads: usize,
    /// Compute dtype the node was planned for (derived from the serving
    /// dtype, never searched — see the module docs).
    pub dtype: Dtype,
    /// Predicted transient workspace in bytes (scratch + any
    /// quantize/accumulator intermediates), at the planned worker count.
    pub workspace_bytes: u64,
    /// Predicted sustained throughput for this node, in GFLOP/s.
    pub predicted_gflops: f64,
}

/// A complete plan for one model at one (batch, dtype, threads)
/// operating point: per-node choices plus the predicted peak memory and
/// end-to-end time the search settled on.
#[derive(Clone, Debug)]
pub struct ModelPlan {
    /// Model name (from the graph).
    pub model: String,
    /// Serving dtype the plan was built for.
    pub dtype: Dtype,
    /// Ctx thread count the candidate splits were drawn from.
    pub threads: usize,
    /// Batch size the footprints and times were computed at.
    pub batch: usize,
    /// The budget the plan was constrained to (`None` = unbudgeted).
    pub budget_bytes: Option<u64>,
    /// One entry per graph node; `None` for non-conv nodes.
    pub choices: Vec<Option<PlannedChoice>>,
    /// Predicted peak of `live activation frontier + workspace` over the
    /// node sequence, in bytes. Always ≤ the budget when one was given.
    pub predicted_peak_bytes: u64,
    /// Predicted end-to-end time for one batch, in nanoseconds.
    pub predicted_ns: f64,
    /// Total FLOPs for one batch (the graph's own accounting).
    pub flops: u64,
    /// The cache-footprint term's per-chain tiling decisions: chains
    /// whose untiled working set spills the detected L2 tile budget and
    /// whose tiled execution lowers the predicted peak. Empty when the
    /// plan was unbudgeted, when no chain spills, or when tiling would
    /// not help. Attach to the compiled plan via
    /// [`CompiledPlan::with_tiling`].
    pub tiling: TilingPlan,
}

impl ModelPlan {
    /// Predicted end-to-end throughput in GFLOP/s.
    pub fn predicted_gflops(&self) -> f64 {
        self.flops as f64 / self.predicted_ns.max(1.0)
    }

    /// Human-readable rendering: one line per planned node, then the
    /// predicted peak vs. budget and throughput summary (what the CLI
    /// `plan` subcommand prints).
    pub fn render(&self, graph: &Graph) -> String {
        let mut s = format!(
            "plan \"{}\" batch={} dtype={} threads={}\n",
            self.model,
            self.batch,
            self.dtype.name(),
            self.threads
        );
        for (id, choice) in self.choices.iter().enumerate() {
            if let Some(c) = choice {
                let node = &graph.nodes[id];
                s.push_str(&format!(
                    "  %{id}: {:<12} k={:<2} -> {:<11} x{:<2} {:<4} ws {:>9}  {:6.2} GFLOP/s\n",
                    node.op.name(),
                    conv_geometry(node, graph, self.batch).map_or(0, |g| g.kw),
                    c.algo.name(),
                    c.threads,
                    c.dtype.name(),
                    fmt_bytes(c.workspace_bytes),
                    c.predicted_gflops,
                ));
            }
        }
        for chain in &self.tiling.chains {
            s.push_str(&format!("  tiled {}\n", chain.render()));
        }
        let budget = match self.budget_bytes {
            Some(b) => fmt_bytes(b),
            None => "unbounded".to_string(),
        };
        s.push_str(&format!(
            "  predicted peak {} (budget {budget}), predicted {:.2} GFLOP/s ({:.3} ms/batch)\n",
            fmt_bytes(self.predicted_peak_bytes),
            self.predicted_gflops(),
            self.predicted_ns / 1e6,
        ));
        s
    }
}

/// Why planning failed. The planner never silently falls back: an
/// unsatisfiable budget is reported with the smallest budget that
/// *would* work, so callers can surface an actionable error.
#[derive(Clone, Debug, PartialEq)]
pub enum PlanError {
    /// No assignment of candidates keeps every node's live frontier +
    /// workspace within the budget.
    Infeasible {
        /// Model name.
        model: String,
        /// First node whose minimal footprint exceeds the budget.
        node: NodeId,
        /// That node's op name.
        op: &'static str,
        /// The smallest budget (bytes) any plan for this operating
        /// point can satisfy.
        min_bytes: u64,
        /// The budget that was asked for.
        budget: u64,
    },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::Infeasible { model, node, op, min_bytes, budget } => write!(
                f,
                "no feasible plan for \"{model}\" under {budget} bytes: node %{node} ({op}) \
                 needs at least {min_bytes} bytes of live activations + workspace"
            ),
        }
    }
}

impl std::error::Error for PlanError {}

/// Conv geometry the workspace and throughput models need, extracted
/// once per node.
struct ConvGeometry {
    c_in: usize,
    c_in_g: usize,
    c_out: usize,
    c_out_g: usize,
    kh: usize,
    kw: usize,
    h: usize,
    w: usize,
    oh: usize,
    ow: usize,
    groups: usize,
    n: usize,
    params: Conv2dParams,
    /// Whether the input arrives as hoisted i8 codes (the producer's
    /// `quant_out` fact) — then the quantize step costs no workspace.
    input_is_q8: bool,
}

fn conv_geometry(node: &Node, graph: &Graph, batch: usize) -> Option<ConvGeometry> {
    let (wdims, params) = match &node.op {
        Op::Conv2d { w, params, .. } => (w.dims().to_vec(), *params),
        Op::QuantConv2d { qw, params, .. } => (qw.dims().to_vec(), *params),
        _ => return None,
    };
    let in_node = &graph.nodes[node.inputs[0]];
    let in_shape = &in_node.shape;
    Some(ConvGeometry {
        c_in: in_shape[1],
        c_in_g: wdims[1],
        c_out: wdims[0],
        c_out_g: wdims[0] / params.groups,
        kh: wdims[2],
        kw: wdims[3],
        h: in_shape[2],
        w: in_shape[3],
        oh: node.shape[2],
        ow: node.shape[3],
        groups: params.groups,
        n: in_shape[0] * batch,
        params,
        input_is_q8: in_node.quant_out,
    })
}

/// The compute dtype a node runs in, given the serving dtype:
/// `QuantConv2d` is int8 whatever the ctx serves in; `Conv2d` follows
/// the serving dtype (its own dtype dispatch in the executor).
fn node_dtype(node: &Node, serving: Dtype) -> Dtype {
    match node.op {
        Op::QuantConv2d { .. } => Dtype::I8,
        _ => serving,
    }
}

/// The algorithm the *unplanned* executor would route a conv of filter
/// width `kw` and compute dtype `nd` through under this ctx: the ctx's
/// algo, with the forced sliding variants collapsed onto the sliding
/// family (every row kernel accumulates in the same order, so variants
/// are bit-identical) and `Tuned` resolved to the profile's — or the
/// paper policy's — winner for this width.
pub(crate) fn default_route(ctx: &ExecCtx, kw: usize, nd: Dtype) -> PlanAlgo {
    use crate::kernels::ConvAlgo;
    match ctx.algo {
        ConvAlgo::Direct => PlanAlgo::Direct,
        ConvAlgo::Im2colGemm => PlanAlgo::Gemm,
        ConvAlgo::Sliding | ConvAlgo::SlidingGeneric | ConvAlgo::SlidingCompound => {
            PlanAlgo::Sliding
        }
        ConvAlgo::Tuned => tuned_equiv(ctx.tuned_choice_for(kw, nd).0),
    }
}

/// Whether a planned f32 algorithm can replace `route` without changing
/// bits: it must sit in the same floating-point summation family.
/// One-shot GEMM ↔ strip GEMM is the only cross-kernel f32 interchange
/// (order-exact strip decomposition); everything else crosses a
/// rounding boundary (see the module docs).
pub(crate) fn f32_family_compatible(algo: PlanAlgo, route: PlanAlgo) -> bool {
    algo == route
        || matches!(
            (algo, route),
            (PlanAlgo::Gemm | PlanAlgo::GemmLowMem, PlanAlgo::Gemm | PlanAlgo::GemmLowMem)
        )
}

/// Candidate kernels for one node, given its compute dtype and the
/// route the unplanned executor would take ([`default_route`]). Int8
/// accumulation is exact, so every int8 route is a candidate (there is
/// no int8 direct kernel); bf16 routes everything through the sliding
/// kernel; f32 candidates are pinned to the default route's bitwise
/// family — the planner must never trade accuracy for footprint.
fn candidate_algos(dtype: Dtype, route: PlanAlgo) -> &'static [PlanAlgo] {
    match dtype {
        Dtype::I8 => &[PlanAlgo::Sliding, PlanAlgo::Gemm, PlanAlgo::GemmLowMem],
        Dtype::Bf16 => &[PlanAlgo::Sliding],
        Dtype::F32 | Dtype::I32 => match route {
            PlanAlgo::Gemm | PlanAlgo::GemmLowMem => &[PlanAlgo::Gemm, PlanAlgo::GemmLowMem],
            PlanAlgo::Direct => &[PlanAlgo::Direct],
            PlanAlgo::Sliding => &[PlanAlgo::Sliding],
        },
    }
}

/// Relative-throughput prior per algorithm, used to derate the
/// profile's measured winner GFLOPS onto the non-winning candidates
/// (the cache records only each bucket's winner): predicted =
/// measured · r(algo)/r(winner), clamped below the winner — a
/// non-winner never out-predicts the measurement that beat it.
fn derate(algo: PlanAlgo) -> f64 {
    match algo {
        PlanAlgo::Sliding => 1.0,
        PlanAlgo::Gemm => 0.80,
        PlanAlgo::GemmLowMem => 0.72,
        PlanAlgo::Direct => 0.15,
    }
}

fn tuned_equiv(algo: TunedAlgo) -> PlanAlgo {
    match algo {
        TunedAlgo::Direct => PlanAlgo::Direct,
        TunedAlgo::Gemm => PlanAlgo::Gemm,
        TunedAlgo::Sliding => PlanAlgo::Sliding,
    }
}

/// Predicted sustained GFLOP/s for one candidate: the profile's
/// measured winner throughput at the nearest (k, threads, dtype, ISA)
/// bucket, derated when the candidate is not that bucket's winner;
/// without a profile (or no matching-dtype bucket), a flat paper-policy
/// prior with imperfect thread scaling.
fn predicted_gflops(
    profile: Option<&DispatchProfile>,
    k: usize,
    threads: usize,
    dtype: Dtype,
    isa: IsaLevel,
    algo: PlanAlgo,
) -> f64 {
    match profile.and_then(|p| p.measured_at(k, threads, dtype, isa)) {
        Some((winner, gflops)) => {
            let w = tuned_equiv(winner);
            if w == algo {
                gflops
            } else {
                (gflops * derate(algo) / derate(w)).min(gflops * 0.95)
            }
        }
        None => {
            // No measurement: a flat prior whose only job is to rank
            // candidates sanely (sliding wins, as the paper policy
            // assumes) and to reward — imperfectly — wider splits.
            const BASE_GFLOPS: f64 = 4.0;
            BASE_GFLOPS * (1.0 + 0.8 * (threads.max(1) - 1) as f64) * derate(algo)
        }
    }
}

/// Transient workspace in bytes for one candidate, mirroring what each
/// kernel actually draws from the arena (plus the quantize/accumulator
/// intermediates of the int8 boundary wrappers). A model, not an
/// accountant: its job is to order candidates correctly and scale with
/// the worker count, so narrowing the split is a real memory lever.
fn workspace_bytes(g: &ConvGeometry, dtype: Dtype, algo: PlanAlgo, threads: usize) -> u64 {
    let kdim = g.c_in_g * g.kh * g.kw;
    let ohw = g.oh * g.ow;
    let out_numel = (g.n * g.c_out * ohw) as u64;
    let in_numel = (g.n * g.c_in * g.h * g.w) as u64;
    let f4 = std::mem::size_of::<f32>() as u64;
    // GEMM-family kernels fan out one (image, group) per work item;
    // sliding fans out output planes.
    let gemm_workers = threads.min((g.n * g.groups).max(1)) as u64;
    let slide_workers = threads.min((g.n * g.c_out).max(1)) as u64;
    let strip = lowmem_strip_cols(kdim).min(ohw.max(1));
    // int8 boundary intermediates: activation codes (skipped when the
    // producer already hands over codes) + the exact-i32 accumulator.
    let q8_boundary = if dtype == Dtype::I8 {
        let codes = if g.input_is_q8 { 0 } else { in_numel };
        codes + out_numel * 4
    } else {
        0
    };
    // Sliding kernels pad the whole input once (shared across workers)
    // with the row kernels' overhang slack, then keep one output-row
    // accumulator per worker.
    let slide_padded = |esize: u64| {
        let (hp, wp) =
            padded2d_size(g.h, g.w, g.params.pad.0, g.params.pad.1, 2 * LANES + g.kw);
        (g.n * g.c_in * hp * wp) as u64 * esize + slide_workers * (wp as u64) * f4
    };
    match (dtype, algo) {
        (Dtype::I8, PlanAlgo::Sliding) => q8_boundary + slide_padded(1),
        (Dtype::I8, PlanAlgo::Gemm) => q8_boundary + gemm_workers * (kdim * ohw) as u64,
        (Dtype::I8, PlanAlgo::GemmLowMem) => {
            q8_boundary
                + gemm_workers * ((kdim * strip) as u64 + (g.c_out_g * strip) as u64 * 4)
        }
        (_, PlanAlgo::Direct) => 0,
        (_, PlanAlgo::Gemm) => {
            gemm_workers * (kdim * ohw + pack_a_len() + pack_b_len(ohw)) as u64 * f4
        }
        (_, PlanAlgo::GemmLowMem) => {
            gemm_workers
                * (kdim * strip + pack_a_len() + pack_b_len(strip) + g.c_out_g * strip) as u64
                * f4
        }
        (_, PlanAlgo::Sliding) => slide_padded(f4),
    }
}

/// Fixed cost model for nodes the planner has no choices for: treated
/// as memory-bound streaming over their input + output bytes plus their
/// (usually negligible) FLOPs. Only the *relative* ranking of conv
/// candidates matters for the plan; this term just keeps `predicted_ns`
/// an end-to-end figure.
fn fixed_node_ns(graph: &Graph, id: NodeId, batch: usize) -> f64 {
    let node = &graph.nodes[id];
    let in_bytes: u64 =
        node.inputs.iter().map(|&i| graph.node_activation_bytes(i, batch)).sum();
    let bytes = in_bytes + graph.node_activation_bytes(id, batch);
    const STREAM_BYTES_PER_NS: f64 = 8.0; // ~8 GB/s effective streaming
    const SCALAR_FLOPS_PER_NS: f64 = 4.0;
    bytes as f64 / STREAM_BYTES_PER_NS + graph.node_flops(id, batch) as f64 / SCALAR_FLOPS_PER_NS
}

/// Candidate worker splits: powers of two up to the ctx thread count,
/// plus the count itself when it is not a power of two.
fn thread_splits(threads: usize) -> Vec<usize> {
    let mut ts = Vec::new();
    let mut v = 1usize;
    while v < threads {
        ts.push(v);
        v *= 2;
    }
    ts.push(threads.max(1));
    ts
}

/// The smallest peak (bytes) any plan can achieve for this operating
/// point: per node, the live activation frontier plus the cheapest
/// candidate's workspace, maximised over the sequence. A budget below
/// this is infeasible by construction; [`plan_model`] reports it in
/// [`PlanError::Infeasible`]. Returns `(min_bytes, argmax_node)`.
fn min_feasible_peak(graph: &Graph, batch: usize, ctx: &ExecCtx) -> (u64, NodeId) {
    let mut worst = (0u64, 0usize);
    sweep_live(graph, batch, |id, node, live_during| {
        let min_ws = match conv_geometry(node, graph, batch) {
            Some(g) => {
                let nd = node_dtype(node, ctx.dtype());
                candidate_algos(nd, default_route(ctx, g.kw, nd))
                    .iter()
                    .map(|&a| workspace_bytes(&g, nd, a, 1))
                    .min()
                    .unwrap_or(0)
            }
            None => 0,
        };
        if live_during + min_ws > worst.0 {
            worst = (live_during + min_ws, id);
        }
    });
    worst
}

/// Public form of the feasibility floor: the smallest `--mem-budget`
/// that admits any plan for this compiled model at the ctx's operating
/// point (its serving dtype picks the kernel sets, its algo pins each
/// f32 node's bitwise family).
pub fn min_feasible_budget(plan: &CompiledPlan, batch: usize, ctx: &ExecCtx) -> u64 {
    min_feasible_peak(&plan.graph, batch, ctx).0
}

/// Walk the graph in execution order, calling `f(id, node, live_during)`
/// for every live node with the executor's consumer-countdown live
/// frontier (bytes of produced-and-still-needed activations, including
/// the node's own output being written).
fn sweep_live(graph: &Graph, batch: usize, mut f: impl FnMut(NodeId, &Node, u64)) {
    let uses = graph.consumer_counts();
    let mut remaining = uses.clone();
    let mut live_bytes = 0u64;
    for id in 1..graph.nodes.len() {
        if uses[id] == 0 {
            continue; // dead node — the executor skips it too
        }
        let node = &graph.nodes[id];
        let out_bytes = graph.node_activation_bytes(id, batch);
        f(id, node, live_bytes + out_bytes);
        live_bytes += out_bytes;
        for &i in &node.inputs {
            remaining[i] -= 1;
            if remaining[i] == 0 {
                live_bytes = live_bytes.saturating_sub(graph.node_activation_bytes(i, batch));
            }
        }
    }
}

/// Plan the compiled model for one operating point.
///
/// * `batch` — batch size footprints and times are computed at.
/// * `ctx` — supplies the serving dtype, the thread count candidates
///   are drawn from, the ISA level, and (optionally) the measured
///   [`DispatchProfile`] throughput predictions come from.
/// * `budget_bytes` — peak-memory budget over `live activation frontier
///   + transient workspace`; `None` plans purely for speed.
///
/// Returns the plan, or [`PlanError::Infeasible`] — an explicit error,
/// never a silent fallback — when no candidate assignment fits the
/// budget (the error carries the smallest budget that would).
pub fn plan_model(
    compiled: &CompiledPlan,
    batch: usize,
    ctx: &ExecCtx,
    budget_bytes: Option<u64>,
) -> Result<ModelPlan, PlanError> {
    let graph = &compiled.graph;
    let dtype = ctx.dtype();
    let threads = ctx.threads();
    let (min_bytes, worst_node) = min_feasible_peak(graph, batch, ctx);
    if let Some(budget) = budget_bytes {
        if budget < min_bytes {
            return Err(PlanError::Infeasible {
                model: graph.name.clone(),
                node: worst_node,
                op: graph.nodes[worst_node].op.name(),
                min_bytes,
                budget,
            });
        }
    }

    let profile = ctx.profile().map(|p| p.as_ref());
    let splits = thread_splits(threads);
    let mut choices: Vec<Option<PlannedChoice>> = vec![None; graph.nodes.len()];
    let mut predicted_ns = 0.0f64;
    let mut peak = 0u64;
    sweep_live(graph, batch, |id, node, live_during| {
        let Some(g) = conv_geometry(node, graph, batch) else {
            predicted_ns += fixed_node_ns(graph, id, batch);
            peak = peak.max(live_during);
            return;
        };
        let nd = node_dtype(node, dtype);
        let flops = graph.node_flops(id, batch) as f64;
        // Per-node argmin of predicted time over (algo × split), among
        // candidates that fit the residual budget. Ties (identical
        // predicted time) break toward the smaller footprint, then the
        // narrower split — the cheaper plan when speed is equal.
        let mut best: Option<(f64, u64, usize, PlanAlgo, f64)> = None;
        for &algo in candidate_algos(nd, default_route(ctx, g.kw, nd)) {
            for &t in &splits {
                let ws = workspace_bytes(&g, nd, algo, t);
                if let Some(budget) = budget_bytes {
                    if live_during + ws > budget {
                        continue;
                    }
                }
                let gf = predicted_gflops(profile, g.kw, t, nd, ctx.isa(), algo);
                let ns = flops / gf.max(1e-9);
                let cand = (ns, ws, t, algo, gf);
                let better = match &best {
                    None => true,
                    Some(b) => (cand.0, cand.1, cand.2) < (b.0, b.1, b.2),
                };
                if better {
                    best = Some(cand);
                }
            }
        }
        // `min_feasible_peak` proved a 1-worker minimum-footprint
        // candidate fits every node, so `best` is always present.
        let (ns, ws, t, algo, gf) = best.expect("budget pre-check guarantees a candidate");
        choices[id] = Some(PlannedChoice {
            algo,
            threads: t,
            dtype: nd,
            workspace_bytes: ws,
            predicted_gflops: gf,
        });
        predicted_ns += ns;
        peak = peak.max(live_during + ws);
    });

    // Cache-footprint term: under a budget, chains whose untiled
    // working set spills the detected L2 tile budget are candidates for
    // tiled execution. A tiled chain's interior activations never
    // materialise at full size — the executor recycles per-tile buffers
    // through the arena — so the chain's cost in the peak model becomes
    // `threads × per-tile working set` instead of `interior frontier +
    // per-node workspace`. Tiling is adopted only when that predicted
    // peak is no worse than the untiled one (values are bit-identical
    // either way; this is purely a footprint/locality decision).
    let mut tiling = TilingPlan::default();
    if budget_bytes.is_some() {
        let t = tiling::analyze(graph, Some(&choices), ctx, batch, TileMode::OverBudget);
        if !t.is_empty() {
            let tiled_peak = tiled_sweep_peak(graph, batch, &choices, &t, threads);
            if tiled_peak <= peak {
                peak = tiled_peak;
                tiling = t;
            }
        }
    }

    let plan = ModelPlan {
        model: graph.name.clone(),
        dtype,
        threads,
        batch,
        budget_bytes,
        choices,
        predicted_peak_bytes: peak,
        predicted_ns,
        flops: graph.flops(batch),
        tiling,
    };
    debug_assert!(
        match budget_bytes {
            Some(b) => plan.predicted_peak_bytes <= b,
            None => true,
        },
        "planned peak exceeds the budget it was planned under"
    );
    Ok(plan)
}

/// Predicted peak of `live frontier + workspace` when the given chains
/// run tiled: interior chain activations never enter the frontier, and
/// each chain instead costs `threads ×` its per-tile working set
/// (every worker holds one tile's halo, output and kernel scratch)
/// while the chain's own output is being written. Mirrors the tiled
/// executor's consumer-countdown recycling exactly as [`sweep_live`]
/// mirrors the untiled one.
fn tiled_sweep_peak(
    graph: &Graph,
    batch: usize,
    choices: &[Option<PlannedChoice>],
    tiling: &TilingPlan,
    threads: usize,
) -> u64 {
    let uses = graph.consumer_counts();
    let mut remaining = uses.clone();
    let mut live = 0u64;
    let mut peak = 0u64;
    let n = graph.nodes.len();
    let mut id = 1;
    while id < n {
        if uses[id] == 0 {
            id += 1;
            continue;
        }
        if let Some(chain) = tiling.chain_starting_at(id) {
            let out_bytes = graph.node_activation_bytes(chain.end, batch);
            let ws = threads.max(1) as u64 * chain.tiled_bytes;
            peak = peak.max(live + out_bytes + ws);
            live += out_bytes;
            // Only the head input is consumed; interiors never exist.
            let head_in = graph.nodes[id].inputs[0];
            remaining[head_in] -= 1;
            if remaining[head_in] == 0 {
                live = live.saturating_sub(graph.node_activation_bytes(head_in, batch));
            }
            id = chain.end + 1;
            continue;
        }
        let node = &graph.nodes[id];
        let out_bytes = graph.node_activation_bytes(id, batch);
        let ws = choices[id].as_ref().map_or(0, |c| c.workspace_bytes);
        peak = peak.max(live + out_bytes + ws);
        live += out_bytes;
        for &i in &node.inputs {
            remaining[i] -= 1;
            if remaining[i] == 0 {
                live = live.saturating_sub(graph.node_activation_bytes(i, batch));
            }
        }
        id += 1;
    }
    peak
}

fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 20 {
        format!("{:.1} MiB", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1} KiB", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ConvAlgo;
    use crate::nn::layers::{Conv2d, MaxPool2d, QuantizedConv2d, ReLU};
    use crate::nn::Model;
    use crate::tensor::Tensor;

    fn conv_chain() -> Model {
        Model::new("chain", &[3, 24, 24])
            .push(Conv2d::new(3, 8, 3, Conv2dParams::same(3), 11))
            .push(ReLU)
            .push(Conv2d::new(8, 8, 5, Conv2dParams::same(5), 12))
            .push(MaxPool2d(crate::kernels::PoolParams::square(2)))
            .push(Conv2d::new(8, 4, 3, Conv2dParams::same(3), 13))
    }

    #[test]
    fn unbudgeted_plan_covers_every_conv_node() {
        let compiled = conv_chain().compile_with(true);
        let ctx = ExecCtx::with_threads(ConvAlgo::Tuned, 4);
        let plan = plan_model(&compiled, 2, &ctx, None).unwrap();
        let convs: Vec<_> = plan.choices.iter().flatten().collect();
        assert_eq!(convs.len(), 3, "one choice per conv node");
        for c in &convs {
            assert_eq!(c.dtype, Dtype::F32);
            assert!(c.threads >= 1 && c.threads <= 4);
            assert!(c.predicted_gflops > 0.0);
        }
        assert!(plan.predicted_ns > 0.0);
        assert!(plan.predicted_peak_bytes > 0);
        assert_eq!(plan.flops, compiled.flops(2));
    }

    #[test]
    fn budget_at_the_floor_is_feasible_and_respected() {
        let compiled = conv_chain().compile_with(true);
        let ctx = ExecCtx::with_threads(ConvAlgo::Tuned, 4);
        let floor = min_feasible_budget(&compiled, 1, &ctx);
        let plan = plan_model(&compiled, 1, &ctx, Some(floor)).unwrap();
        assert!(
            plan.predicted_peak_bytes <= floor,
            "peak {} over floor budget {floor}",
            plan.predicted_peak_bytes
        );
    }

    #[test]
    fn infeasible_budget_is_an_explicit_error() {
        let compiled = conv_chain().compile_with(true);
        let ctx = ExecCtx::new(ConvAlgo::Tuned);
        let floor = min_feasible_budget(&compiled, 1, &ctx);
        let err = plan_model(&compiled, 1, &ctx, Some(floor - 1)).unwrap_err();
        let PlanError::Infeasible { min_bytes, budget, ref model, .. } = err;
        assert_eq!(min_bytes, floor);
        assert_eq!(budget, floor - 1);
        assert_eq!(model, "chain");
        let msg = err.to_string();
        assert!(msg.contains("no feasible plan") && msg.contains("chain"), "{msg}");
    }

    #[test]
    fn tight_budgets_shift_toward_smaller_workspaces() {
        // A spatially large conv where one-shot GEMM's column matrix
        // dwarfs the strip variant's bounded scratch.
        let m = Model::new("wide", &[8, 64, 64]).push(Conv2d::new(
            8,
            8,
            5,
            Conv2dParams::same(5),
            21,
        ));
        let compiled = m.compile_with(true);
        // A GEMM-routed ctx: the f32 candidate family is then
        // {one-shot, strip}, so the budget has a real algorithm lever.
        let ctx = ExecCtx::with_threads(ConvAlgo::Im2colGemm, 4);
        let open = plan_model(&compiled, 1, &ctx, None).unwrap();
        let floor = min_feasible_budget(&compiled, 1, &ctx);
        let tight = plan_model(&compiled, 1, &ctx, Some(floor)).unwrap();
        let ws_open: u64 =
            open.choices.iter().flatten().map(|c| c.workspace_bytes).sum();
        let ws_tight: u64 =
            tight.choices.iter().flatten().map(|c| c.workspace_bytes).sum();
        assert!(
            ws_tight <= ws_open,
            "tight plan must not use more workspace ({ws_tight} > {ws_open})"
        );
        assert!(tight.predicted_peak_bytes <= floor);
        // Unbudgeted, the faster one-shot GEMM wins; at the floor the
        // strip variant is the only way to fit.
        let algo_of = |p: &ModelPlan| p.choices.iter().flatten().next().unwrap().algo;
        assert_eq!(algo_of(&open), PlanAlgo::Gemm);
        assert_eq!(algo_of(&tight), PlanAlgo::GemmLowMem);
    }

    #[test]
    fn f32_candidates_stay_inside_the_ctx_routes_bitwise_family() {
        let compiled = conv_chain().compile_with(true);
        for (algo, allowed) in [
            (ConvAlgo::Sliding, &[PlanAlgo::Sliding][..]),
            (ConvAlgo::Im2colGemm, &[PlanAlgo::Gemm, PlanAlgo::GemmLowMem][..]),
            (ConvAlgo::Direct, &[PlanAlgo::Direct][..]),
        ] {
            let ctx = ExecCtx::with_threads(algo, 4);
            let plan = plan_model(&compiled, 1, &ctx, None).unwrap();
            for c in plan.choices.iter().flatten() {
                assert!(
                    allowed.contains(&c.algo),
                    "{algo:?} ctx planned {:?} — outside its bitwise family",
                    c.algo
                );
            }
        }
    }

    #[test]
    fn family_compatibility_is_the_gemm_interchange_plus_identity() {
        use PlanAlgo::*;
        for a in [Direct, Gemm, GemmLowMem, Sliding] {
            assert!(f32_family_compatible(a, a), "{a:?} with itself");
        }
        assert!(f32_family_compatible(Gemm, GemmLowMem));
        assert!(f32_family_compatible(GemmLowMem, Gemm));
        assert!(!f32_family_compatible(Sliding, Gemm));
        assert!(!f32_family_compatible(Direct, Sliding));
        assert!(!f32_family_compatible(GemmLowMem, Direct));
    }

    #[test]
    fn quant_nodes_plan_in_int8_with_no_direct_candidate() {
        let m = Model::new("q", &[3, 16, 16])
            .push(QuantizedConv2d::new(3, 6, 3, Conv2dParams::same(3), 31))
            .push(QuantizedConv2d::new(6, 4, 3, Conv2dParams::same(3), 32));
        let compiled = m.compile_with(true);
        let ctx = ExecCtx::with_threads(ConvAlgo::Tuned, 2);
        let plan = plan_model(&compiled, 1, &ctx, None).unwrap();
        for c in plan.choices.iter().flatten() {
            assert_eq!(c.dtype, Dtype::I8);
            assert_ne!(c.algo, PlanAlgo::Direct, "int8 has no direct kernel");
        }
    }

    #[test]
    fn lowmem_workspace_undercuts_oneshot_gemm_on_large_extents() {
        let g = ConvGeometry {
            c_in: 16,
            c_in_g: 16,
            c_out: 16,
            c_out_g: 16,
            kh: 5,
            kw: 5,
            h: 64,
            w: 64,
            oh: 64,
            ow: 64,
            groups: 1,
            n: 1,
            params: Conv2dParams::same(5),
            input_is_q8: false,
        };
        let full = workspace_bytes(&g, Dtype::F32, PlanAlgo::Gemm, 1);
        let strip = workspace_bytes(&g, Dtype::F32, PlanAlgo::GemmLowMem, 1);
        assert!(
            strip * 4 < full,
            "strip GEMM ({strip}) should be far below one-shot ({full})"
        );
        // Workspace scales with the split — narrowing threads is a
        // genuine memory lever for the GEMM family.
        let wide = workspace_bytes(&g, Dtype::F32, PlanAlgo::Gemm, 4);
        assert_eq!(wide, full, "one image, one group: split cannot widen scratch");
        let g2 = ConvGeometry { n: 4, ..g };
        assert!(
            workspace_bytes(&g2, Dtype::F32, PlanAlgo::Gemm, 4)
                > workspace_bytes(&g2, Dtype::F32, PlanAlgo::Gemm, 1)
        );
    }

    #[test]
    fn profile_throughput_derates_non_winners_below_the_winner() {
        use crate::autotune::ProfileEntry;
        use crate::kernels::rowconv::RowKernel;
        let p = DispatchProfile::from_entries(vec![ProfileEntry {
            k: 3,
            threads: 1,
            dtype: Dtype::F32,
            isa: IsaLevel::Scalar,
            algo: TunedAlgo::Sliding,
            slide: RowKernel::Custom,
            gflops: 10.0,
        }]);
        let win =
            predicted_gflops(Some(&p), 3, 1, Dtype::F32, IsaLevel::Scalar, PlanAlgo::Sliding);
        assert_eq!(win, 10.0);
        for algo in [PlanAlgo::Gemm, PlanAlgo::GemmLowMem, PlanAlgo::Direct] {
            let lose = predicted_gflops(Some(&p), 3, 1, Dtype::F32, IsaLevel::Scalar, algo);
            assert!(lose < win, "{algo:?} predicted {lose} >= winner {win}");
        }
        // Direct winner: sliding's prediction is clamped below it, not
        // extrapolated above the measurement.
        let pd = DispatchProfile::from_entries(vec![ProfileEntry {
            k: 3,
            threads: 1,
            dtype: Dtype::F32,
            isa: IsaLevel::Scalar,
            algo: TunedAlgo::Direct,
            slide: RowKernel::Custom,
            gflops: 10.0,
        }]);
        let clamped =
            predicted_gflops(Some(&pd), 3, 1, Dtype::F32, IsaLevel::Scalar, PlanAlgo::Sliding);
        assert!(clamped <= 9.5, "non-winner must stay below the measured winner");
    }

    #[test]
    fn thread_splits_are_powers_of_two_plus_the_count() {
        assert_eq!(thread_splits(1), vec![1]);
        assert_eq!(thread_splits(4), vec![1, 2, 4]);
        assert_eq!(thread_splits(6), vec![1, 2, 4, 6]);
        assert_eq!(thread_splits(0), vec![1]);
    }

    #[test]
    fn render_lists_choices_and_budget() {
        let compiled = conv_chain().compile_with(true);
        let ctx = ExecCtx::with_threads(ConvAlgo::Tuned, 2);
        let plan =
            plan_model(&compiled, 1, &ctx, Some(64 << 20)).unwrap();
        let s = plan.render(&compiled.graph);
        assert!(s.contains("conv2d"), "{s}");
        assert!(s.contains("predicted peak"), "{s}");
        assert!(s.contains("GFLOP/s"), "{s}");
    }

    #[test]
    fn fanout_branches_are_both_live_at_the_join() {
        // input -> two convs -> concat: while the second branch runs,
        // the first branch's output must still be in the frontier.
        let w1 = Tensor::randn(&[4, 3, 3, 3], 41);
        let w2 = Tensor::randn(&[4, 3, 3, 3], 42);
        let mut g = Graph::new("fan", &[3, 12, 12]);
        let a = g.add(
            Op::Conv2d { w: w1, bias: vec![0.0; 4], params: Conv2dParams::same(3) },
            vec![0],
        );
        let b = g.add(
            Op::Conv2d { w: w2, bias: vec![0.0; 4], params: Conv2dParams::same(3) },
            vec![0],
        );
        g.add(Op::Concat, vec![a, b]);
        let branch = g.node_activation_bytes(a, 1);
        let concat_bytes = g.node_activation_bytes(3, 1);
        let mut live_at_concat = 0;
        sweep_live(&g, 1, |id, _node, live| {
            if id == 3 {
                live_at_concat = live;
            }
        });
        assert_eq!(
            live_at_concat,
            2 * branch + concat_bytes,
            "both branches + the join output are live at the barrier"
        );
    }

    #[test]
    fn unbudgeted_plans_stay_untiled_and_adopted_chains_shrink() {
        let compiled = conv_chain().compile_with(true);
        let ctx = ExecCtx::with_threads(ConvAlgo::Sliding, 2);
        let open = plan_model(&compiled, 1, &ctx, None).unwrap();
        assert!(open.tiling.is_empty(), "unbudgeted plans never tile");
        let floor = min_feasible_budget(&compiled, 1, &ctx);
        let tight = plan_model(&compiled, 1, &ctx, Some(floor)).unwrap();
        assert!(tight.predicted_peak_bytes <= floor, "tiling must never raise the peak");
        for c in &tight.tiling.chains {
            assert!(
                c.tiled_bytes < c.untiled_bytes,
                "adopted chain {}..{} does not shrink its working set",
                c.start,
                c.end
            );
        }
    }

    #[test]
    fn tiled_sweep_peak_drops_interior_activations() {
        // With a small forced tile, the chain's interior activations
        // leave the frontier and the predicted peak collapses to the
        // chain output plus one worker's tile working set.
        let compiled = conv_chain().compile_with(true);
        let g = &compiled.graph;
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let t = tiling::analyze_with(g, None, &ctx, 1, TileMode::ForceAll, u64::MAX, Some((2, 2)));
        assert!(!t.is_empty(), "sliding ctx must yield a chain");
        let choices = vec![None; g.nodes.len()];
        let tiled = tiled_sweep_peak(g, 1, &choices, &t, 1);
        let mut untiled = 0u64;
        sweep_live(g, 1, |_, _, live| untiled = untiled.max(live));
        assert!(tiled < untiled, "tiled peak {tiled} must undercut untiled {untiled}");
    }

    #[test]
    fn unused_batch_scales_peak_linearly() {
        let compiled = conv_chain().compile_with(true);
        let ctx = ExecCtx::new(ConvAlgo::Tuned);
        let p1 = plan_model(&compiled, 1, &ctx, None).unwrap();
        let p4 = plan_model(&compiled, 4, &ctx, None).unwrap();
        assert!(p4.predicted_peak_bytes > p1.predicted_peak_bytes);
        assert_eq!(p4.flops, 4 * p1.flops);
    }
}
