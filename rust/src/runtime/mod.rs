//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from Rust. Python runs once at build time (`make
//! artifacts`) and never on the request path.
//!
//! * [`json`] — minimal JSON parser (offline substitute for serde_json).
//! * [`manifest`] — `artifacts/manifest.json` schema: one entry per
//!   lowered (model, algo, shape) variant.
//! * [`engine`] — `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//!   → `compile` → `execute`, with an executable cache keyed by artifact
//!   name. HLO **text** is the interchange format: jax ≥ 0.5 emits protos
//!   with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//!   text parser reassigns ids (see /opt/xla-example/README.md).

pub mod engine;
pub mod json;
pub mod manifest;

pub use engine::Engine;
pub use manifest::{ArtifactSpec, Manifest};
