//! The PJRT execution engine: compile-once, execute-many.
//!
//! Follows the /opt/xla-example/load_hlo pattern: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Executables are cached by artifact
//! name so the request path pays only buffer transfer + execution.

use super::manifest::{ArtifactSpec, Manifest};
use crate::error::{bail, Result};
#[cfg(feature = "pjrt")]
use crate::error::anyhow;
use crate::tensor::Tensor;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::PathBuf;

/// A compiled-artifact execution engine on the PJRT CPU client.
///
/// Only available with the `pjrt` cargo feature (which needs the
/// vendored `xla` crate); the default offline build gets a stub with the
/// same API whose constructor errors, so everything above it (the
/// coordinator's `PjrtBackend`, the CLI's `artifacts-check`) degrades to
/// a clear message instead of failing to compile.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Create an engine over an artifact directory (must contain
    /// `manifest.json`; see `python/compile/aot.py`).
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Engine> {
        let dir = artifacts_dir.into();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Engine { client, manifest, cache: HashMap::new() })
    }

    /// The manifest the engine serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform string (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an artifact (no-op if cached). Returns the artifact spec.
    pub fn load(&mut self, name: &str) -> Result<&ArtifactSpec> {
        if !self.cache.contains_key(name) {
            let spec = self
                .manifest
                .find(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
                .clone();
            let path = spec.path(&self.manifest.dir);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling '{name}': {e}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.manifest.find(name).unwrap())
    }

    /// Compile every artifact in the manifest (warm-up at startup so the
    /// request path never compiles).
    pub fn load_all(&mut self) -> Result<usize> {
        // Only executable artifacts: the manifest also lists raw-weight
        // blobs (kind "weights") that are not HLO.
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.file.ends_with(".hlo.txt"))
            .map(|a| a.name.clone())
            .collect();
        for n in &names {
            self.load(n)?;
        }
        Ok(names.len())
    }

    /// Execute an artifact on f32 tensors. Shapes must match the
    /// manifest; the single (tupled) output is returned as a [`Tensor`].
    pub fn execute(&mut self, name: &str, inputs: &[&Tensor]) -> Result<Tensor> {
        self.load(name)?;
        let spec = self.manifest.find(name).unwrap().clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, want)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.dims() != &want[..] {
                bail!(
                    "artifact '{name}' input {i}: shape {:?} != manifest {:?}",
                    t.dims(),
                    want
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.dims().iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.as_slice())
                    .reshape(&dims)
                    .map_err(|e| anyhow!("building literal: {e}"))
            })
            .collect::<Result<_>>()?;

        let exe = self.cache.get(name).expect("loaded above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing '{name}': {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = lit.to_tuple1().map_err(|e| anyhow!("untupling result: {e}"))?;
        let values: Vec<f32> =
            out.to_vec().map_err(|e| anyhow!("reading result: {e}"))?;
        let expect: usize = spec.output.iter().product();
        if values.len() != expect {
            bail!(
                "artifact '{name}' returned {} values, manifest says {:?}",
                values.len(),
                spec.output
            );
        }
        Ok(Tensor::from_vec(values, &spec.output))
    }
}

/// Stub engine for builds without the `pjrt` feature: loads the manifest
/// (so "missing artifacts" is still the first error users see) and then
/// refuses to construct.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Always errors after validating the artifact directory: executing
    /// artifacts needs the `pjrt` feature.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Engine> {
        let dir = artifacts_dir.into();
        let _ = Manifest::load(&dir)?;
        bail!(
            "swconv was built without the `pjrt` feature; to execute AOT \
             artifacts from {}, vendor the `xla` crate, declare it in \
             rust/Cargo.toml (the offline default manifest deliberately \
             omits it), and rebuild with `--features pjrt`",
            dir.display()
        )
    }

    /// The manifest the engine serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform string.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Unavailable without the `pjrt` feature.
    pub fn load(&mut self, name: &str) -> Result<&ArtifactSpec> {
        bail!("cannot compile artifact '{name}': built without the `pjrt` feature")
    }

    /// Unavailable without the `pjrt` feature.
    pub fn load_all(&mut self) -> Result<usize> {
        bail!("cannot compile artifacts: built without the `pjrt` feature")
    }

    /// Unavailable without the `pjrt` feature.
    pub fn execute(&mut self, name: &str, _inputs: &[&Tensor]) -> Result<Tensor> {
        bail!("cannot execute artifact '{name}': built without the `pjrt` feature")
    }
}

/// Default artifact directory (`$SWCONV_ARTIFACTS` or `./artifacts`).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("SWCONV_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine tests that need real artifacts live in rust/tests/
    // (integration) so `cargo test --lib` passes before `make artifacts`.

    #[test]
    fn missing_dir_is_error() {
        let e = Engine::new("/nonexistent/path/xyz");
        assert!(e.is_err());
    }

    #[test]
    fn default_dir_env_override() {
        std::env::set_var("SWCONV_ARTIFACTS", "/tmp/zzz");
        assert_eq!(default_artifacts_dir(), PathBuf::from("/tmp/zzz"));
        std::env::remove_var("SWCONV_ARTIFACTS");
        assert_eq!(default_artifacts_dir(), PathBuf::from("artifacts"));
    }
}
