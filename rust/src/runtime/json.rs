//! Minimal JSON parser (serde is unavailable offline — see DESIGN.md
//! §Substitutions). Supports the full JSON grammar minus float exponent
//! edge cases irrelevant to our manifests; strings support the standard
//! escapes.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (stored as f64, like JavaScript).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (ordered by key for deterministic output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer value (numbers only, truncated).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse failure with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Description.
    pub msg: String,
    /// Byte offset in the input.
    pub at: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.into(), at: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn nested_document() {
        let doc = r#"{"artifacts": [{"name": "m", "inputs": [[1,3,64,64]], "algo": "sliding"}], "version": 1}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").and_then(Json::as_usize), Some(1));
        let arts = j.get("artifacts").and_then(Json::as_arr).unwrap();
        assert_eq!(arts[0].get("name").and_then(Json::as_str), Some("m"));
        let shape = arts[0].get("inputs").and_then(Json::as_arr).unwrap()[0]
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect::<Vec<_>>();
        assert_eq!(shape, vec![1, 3, 64, 64]);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""π é""#).unwrap();
        assert_eq!(j.as_str(), Some("π é"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn errors_have_offsets() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("12x").is_err());
        let e = Json::parse("  q").unwrap_err();
        assert_eq!(e.at, 2);
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" { \"a\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(j.get("a").and_then(Json::as_arr).unwrap().len(), 2);
    }
}
