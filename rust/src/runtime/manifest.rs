//! The artifact manifest written by `python/compile/aot.py`.
//!
//! ```json
//! {
//!   "version": 1,
//!   "artifacts": [
//!     {
//!       "name": "conv2d_sliding_c3_64x64_k5",
//!       "file": "conv2d_sliding_c3_64x64_k5.hlo.txt",
//!       "kind": "conv2d",
//!       "algo": "sliding",
//!       "inputs": [[1, 3, 64, 64], [8, 3, 5, 5]],
//!       "output": [1, 8, 60, 60]
//!     }
//!   ]
//! }
//! ```

use super::json::Json;
use crate::error::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-lowered computation.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    /// Unique artifact name (cache key).
    pub name: String,
    /// HLO text file, relative to the manifest's directory.
    pub file: String,
    /// What the computation is ("conv2d", "model", …).
    pub kind: String,
    /// Which L1 kernel family it was lowered with ("sliding", "gemm",
    /// "ref", …).
    pub algo: String,
    /// Input shapes, in argument order.
    pub inputs: Vec<Vec<usize>>,
    /// Output shape.
    pub output: Vec<usize>,
}

impl ArtifactSpec {
    /// Absolute path of the HLO text file.
    pub fn path(&self, dir: &Path) -> PathBuf {
        dir.join(&self.file)
    }
}

/// The full manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Directory the manifest was loaded from (artifact paths are
    /// relative to it).
    pub dir: PathBuf,
    /// All artifacts.
    pub artifacts: Vec<ArtifactSpec>,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("shape is not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("shape dim is not a number")))
        .collect()
}

impl Manifest {
    /// Parse a manifest from JSON text (paths resolved against `dir`).
    pub fn parse(text: &str, dir: impl Into<PathBuf>) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest is not valid JSON")?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing 'artifacts' array"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for (i, a) in arts.iter().enumerate() {
            let field = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact {i}: missing '{k}'"))?
                    .to_string())
            };
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {i}: missing 'inputs'"))?
                .iter()
                .map(shape_of)
                .collect::<Result<Vec<_>>>()?;
            let output = shape_of(
                a.get("output").ok_or_else(|| anyhow!("artifact {i}: missing 'output'"))?,
            )?;
            artifacts.push(ArtifactSpec {
                name: field("name")?,
                file: field("file")?,
                kind: field("kind")?,
                algo: field("algo")?,
                inputs,
                output,
            });
        }
        // Names must be unique: they key the executable cache.
        let mut names: Vec<&str> = artifacts.iter().map(|a| a.name.as_str()).collect();
        names.sort_unstable();
        if names.windows(2).any(|w| w[0] == w[1]) {
            bail!("duplicate artifact names in manifest");
        }
        Ok(Manifest { dir: dir.into(), artifacts })
    }

    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Find an artifact by name.
    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All artifacts of a given kind.
    pub fn of_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
        "version": 1,
        "artifacts": [
            {"name": "a", "file": "a.hlo.txt", "kind": "conv2d", "algo": "sliding",
             "inputs": [[1,3,8,8],[4,3,3,3]], "output": [1,4,6,6]},
            {"name": "b", "file": "b.hlo.txt", "kind": "model", "algo": "gemm",
             "inputs": [[1,1,28,28]], "output": [1,10]}
        ]
    }"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(DOC, "/tmp/arts").unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.find("a").unwrap();
        assert_eq!(a.inputs, vec![vec![1, 3, 8, 8], vec![4, 3, 3, 3]]);
        assert_eq!(a.output, vec![1, 4, 6, 6]);
        assert_eq!(a.path(&m.dir), PathBuf::from("/tmp/arts/a.hlo.txt"));
        assert_eq!(m.of_kind("model").len(), 1);
        assert!(m.find("zzz").is_none());
    }

    #[test]
    fn rejects_duplicates() {
        let doc = DOC.replace("\"name\": \"b\"", "\"name\": \"a\"");
        assert!(Manifest::parse(&doc, ".").is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#, ".").is_err());
        assert!(Manifest::parse(r#"{}"#, ".").is_err());
        assert!(Manifest::parse("not json", ".").is_err());
    }
}
