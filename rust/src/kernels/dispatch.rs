//! Algorithm selection: one entry point per primitive, parameterised by
//! [`ConvAlgo`] so callers (layers, benchmarks, the coordinator's router)
//! can pit implementations against each other on identical inputs.

use super::direct::{conv1d_direct_ctx, conv2d_direct_ctx, conv2d_direct_epi_ctx};
use super::epilogue::Epilogue;
use super::im2col::{
    conv2d_im2col_ctx, conv2d_im2col_epi_ctx, conv2d_im2col_q8_raw_ctx,
};
use super::sliding1d::conv1d_sliding_ctx;
use super::sliding2d::{
    conv2d_sliding_bf16_ctx, conv2d_sliding_ctx, conv2d_sliding_epi_ctx,
    conv2d_sliding_q8_raw_ctx, dequantize_conv_acc, SlideVariant,
};
use super::{Conv1dParams, Conv2dParams};
use crate::autotune::TunedAlgo;
use crate::exec::ExecCtx;
use crate::tensor::{
    from_bf16, quantize, to_bf16, QuantParams, Tensor, TensorT, WeightScales,
};

/// Which convolution implementation to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ConvAlgo {
    /// Naïve scalar loops — oracle/baseline.
    Direct,
    /// `im2col` + blocked GEMM — the `MlasConv`-style baseline.
    Im2colGemm,
    /// Sliding Window, paper §2 auto policy (custom 3/5 → generic ≤17 →
    /// compound).
    Sliding,
    /// Sliding Window, forced generic in-vector kernel (k ≤ 17).
    SlidingGeneric,
    /// Sliding Window, forced compound-vector kernel.
    SlidingCompound,
    /// Measured dispatch: per filter width, the winner recorded in the
    /// ctx's [`crate::autotune::DispatchProfile`] (direct / GEMM /
    /// sliding with the tuned row family). Without a profile this is
    /// exactly the paper policy, i.e. [`ConvAlgo::Sliding`].
    Tuned,
}

impl ConvAlgo {
    /// All algorithms, in the order benchmarks report them.
    pub const ALL: [ConvAlgo; 6] = [
        ConvAlgo::Direct,
        ConvAlgo::Im2colGemm,
        ConvAlgo::Sliding,
        ConvAlgo::SlidingGeneric,
        ConvAlgo::SlidingCompound,
        ConvAlgo::Tuned,
    ];

    /// Short stable name for reports and the CLI.
    pub fn name(self) -> &'static str {
        match self {
            ConvAlgo::Direct => "direct",
            ConvAlgo::Im2colGemm => "gemm",
            ConvAlgo::Sliding => "sliding",
            ConvAlgo::SlidingGeneric => "sliding-generic",
            ConvAlgo::SlidingCompound => "sliding-compound",
            ConvAlgo::Tuned => "tuned",
        }
    }

    /// Parse a CLI name (inverse of [`ConvAlgo::name`]).
    pub fn parse(s: &str) -> Option<ConvAlgo> {
        Self::ALL.into_iter().find(|a| a.name() == s)
    }

    /// Whether this algorithm can evaluate filter width `kw`.
    pub fn supports_width(self, kw: usize) -> bool {
        match self {
            ConvAlgo::SlidingGeneric => SlideVariant::Generic.supports(kw),
            ConvAlgo::SlidingCompound => SlideVariant::Compound.supports(kw),
            _ => true,
        }
    }
}

/// 2-D convolution with the chosen algorithm.
///
/// * `x` — `[n, c_in, h, w]`, `w` — `[c_out, c_in/groups, kh, kw]`,
///   `bias` — optional `[c_out]`. Returns `[n, c_out, oh, ow]`.
///
/// Single-threaded convenience wrapper over [`conv2d_ctx`]: runs on the
/// thread's shared context ([`crate::exec::with_thread_ctx`]), so
/// repeated calls still reuse scratch buffers across calls.
pub fn conv2d(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    algo: ConvAlgo,
) -> Tensor {
    crate::exec::with_thread_ctx(algo, |ctx| conv2d_ctx(x, w, bias, p, ctx))
}

/// 2-D convolution with the algorithm, thread count and scratch arena of
/// the given execution context.
pub fn conv2d_ctx(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> Tensor {
    match ctx.algo {
        ConvAlgo::Direct => conv2d_direct_ctx(x, w, bias, p, ctx),
        ConvAlgo::Im2colGemm => conv2d_im2col_ctx(x, w, bias, p, ctx),
        ConvAlgo::Sliding => conv2d_sliding_ctx(x, w, bias, p, SlideVariant::Auto, ctx),
        ConvAlgo::SlidingGeneric => {
            conv2d_sliding_ctx(x, w, bias, p, SlideVariant::Generic, ctx)
        }
        ConvAlgo::SlidingCompound => {
            conv2d_sliding_ctx(x, w, bias, p, SlideVariant::Compound, ctx)
        }
        // Pure routing: resolve the width's measured winner, then run
        // that kernel unchanged — the output is bit-identical to calling
        // the chosen algorithm directly.
        ConvAlgo::Tuned => match ctx.tuned_choice(w.dim(3)).0 {
            TunedAlgo::Direct => conv2d_direct_ctx(x, w, bias, p, ctx),
            TunedAlgo::Gemm => conv2d_im2col_ctx(x, w, bias, p, ctx),
            TunedAlgo::Sliding => {
                conv2d_sliding_ctx(x, w, bias, p, SlideVariant::Auto, ctx)
            }
        },
    }
}

/// [`conv2d_ctx`] with a fused output [`Epilogue`]: the same per-algo
/// routing (including `Tuned` profile resolution), but bias and the
/// optional ReLU are folded into the chosen kernel's output write. This
/// is what the graph executor's fused conv nodes call — one memory pass
/// instead of conv → bias → ReLU, with bit-identical results.
pub fn conv2d_epi_ctx(
    x: &Tensor,
    w: &Tensor,
    epi: Epilogue<'_>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> Tensor {
    match ctx.algo {
        ConvAlgo::Direct => conv2d_direct_epi_ctx(x, w, epi, p, ctx),
        ConvAlgo::Im2colGemm => conv2d_im2col_epi_ctx(x, w, epi, p, ctx),
        ConvAlgo::Sliding => conv2d_sliding_epi_ctx(x, w, epi, p, SlideVariant::Auto, ctx),
        ConvAlgo::SlidingGeneric => {
            conv2d_sliding_epi_ctx(x, w, epi, p, SlideVariant::Generic, ctx)
        }
        ConvAlgo::SlidingCompound => {
            conv2d_sliding_epi_ctx(x, w, epi, p, SlideVariant::Compound, ctx)
        }
        ConvAlgo::Tuned => match ctx.tuned_choice(w.dim(3)).0 {
            TunedAlgo::Direct => conv2d_direct_epi_ctx(x, w, epi, p, ctx),
            TunedAlgo::Gemm => conv2d_im2col_epi_ctx(x, w, epi, p, ctx),
            TunedAlgo::Sliding => {
                conv2d_sliding_epi_ctx(x, w, epi, p, SlideVariant::Auto, ctx)
            }
        },
    }
}

/// 1-D convolution with the chosen algorithm (`Im2colGemm` and the forced
/// sliding variants collapse to their natural 1-D counterparts).
///
/// Single-threaded convenience wrapper around [`conv1d_ctx`] on the
/// thread's shared context (scratch reused across calls).
pub fn conv1d(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    algo: ConvAlgo,
) -> Tensor {
    crate::exec::with_thread_ctx(algo, |ctx| conv1d_ctx(x, w, bias, p, ctx))
}

/// 1-D convolution with the algorithm, thread count and scratch arena of
/// the given execution context.
pub fn conv1d_ctx(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    ctx: &ExecCtx,
) -> Tensor {
    match ctx.algo {
        ConvAlgo::Direct => conv1d_direct_ctx(x, w, bias, p, ctx),
        // A 1-D convolution is a 2-D one with kh = 1: reuse the kernels.
        ConvAlgo::Im2colGemm => {
            let (c_in, l) = (x.dim(0), x.dim(1));
            let (c_out, _, k) = (w.dim(0), w.dim(1), w.dim(2));
            let x4 = x.clone().reshape(&[1, c_in, 1, l]);
            let w4 = w.clone().reshape(&[c_out, c_in, 1, k]);
            let p4 = Conv2dParams { stride: (1, p.stride), pad: (0, p.pad), groups: 1 };
            let y = conv2d_im2col_ctx(&x4, &w4, bias, &p4, ctx);
            let lo = y.dim(3);
            y.reshape(&[c_out, lo])
        }
        // The sliding variants — and `Tuned`, whose profile buckets are
        // measured on 2-D planes — all take the 1-D sliding path (its
        // row loop already applies the paper's auto policy per width).
        _ => conv1d_sliding_ctx(x, w, bias, p, ctx),
    }
}

/// f32-boundary quantized 2-D convolution: dynamically quantize the
/// activations (per-tensor symmetric, scale from this batch's
/// `max_abs`), run the int8 kernel the ctx's algorithm routes to, and
/// dequantize back to f32 (`+ bias`).
///
/// This is what the quantized nn layers call per forward pass — the
/// weight codes `qw`/`wq` are quantized once ahead of time, the
/// activations per call. Routing honours [`ExecCtx::algo`]:
/// `Im2colGemm` runs the int8 im2col+GEMM baseline, `Tuned` asks the
/// profile's **`I8` buckets** explicitly
/// ([`ExecCtx::tuned_choice_for`] — this layer runs int8 whatever the
/// ctx's own serving dtype, so f32 crossovers are never borrowed), and
/// everything else — including `Direct`, which has no int8 kernel —
/// takes the quantized sliding path.
pub fn conv2d_q8_ctx(
    x: &Tensor,
    qw: &TensorT<i8>,
    wq: QuantParams,
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> Tensor {
    conv2d_q8_epi_ctx(x, qw, &WeightScales::PerTensor(wq), bias, false, p, ctx)
}

/// The int8 accumulation core with the ctx's algorithm routing: run the
/// exact-i32 kernel `ConvAlgo` resolves to — the int8 im2col+GEMM
/// baseline for `Im2colGemm` (and a `Tuned` profile whose **`I8`
/// bucket** picks GEMM), the quantized sliding kernel for everything
/// else — on already-quantized activation codes. Both kernels produce
/// the identical i32 accumulator, so routing never changes values.
pub fn conv2d_q8_raw_routed_ctx(
    qx: &TensorT<i8>,
    qw: &TensorT<i8>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> TensorT<i32> {
    let use_gemm = match ctx.algo {
        ConvAlgo::Im2colGemm => true,
        ConvAlgo::Tuned => {
            ctx.tuned_choice_for(qw.dim(3), crate::tensor::Dtype::I8).0 == TunedAlgo::Gemm
        }
        _ => false,
    };
    if use_gemm {
        conv2d_im2col_q8_raw_ctx(qx, qw, p, ctx)
    } else {
        conv2d_sliding_q8_raw_ctx(qx, qw, p, ctx)
    }
}

/// [`conv2d_q8_ctx`] generalised to [`WeightScales`] (per-tensor or
/// per-output-channel) and a fused ReLU in the dequant write: dynamic
/// per-tensor activation quantization, the routed exact-i32 kernel,
/// then `raw · x_scale · w_scale[c_out] + bias` (and `max(v, 0.0)` when
/// `relu`) stored in a single pass.
pub fn conv2d_q8_epi_ctx(
    x: &Tensor,
    qw: &TensorT<i8>,
    wq: &WeightScales,
    bias: Option<&[f32]>,
    relu: bool,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> Tensor {
    if let Some(b) = bias {
        assert_eq!(b.len(), qw.dim(0), "bias length");
    }
    let xq = QuantParams::for_tensor(x);
    let qx = quantize(x, xq);
    let raw = conv2d_q8_raw_routed_ctx(&qx, qw, p, ctx);
    dequantize_conv_acc(&raw, xq, wq, bias, relu)
}

/// f32-boundary bfloat16 2-D convolution: round both operands to bf16
/// storage, run the bf16 sliding kernel, widen the result back to f32.
///
/// Algorithms without a bf16 kernel (`Direct`, `Im2colGemm`, and a
/// `Tuned` lookup that resolves to them — consulted from the profile's
/// **`Bf16` buckets** via [`ExecCtx::tuned_choice_for`]) apply the same
/// storage rounding on the operands, compute in f32, and round the
/// output back through bf16 — numerically the identical contract
/// (bf16-rounded operands and outputs, f32 accumulation), just without
/// the halved streaming traffic.
pub fn conv2d_bf16_ctx(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> Tensor {
    let xb = to_bf16(x);
    let wb = to_bf16(w);
    let fallback = match ctx.algo {
        ConvAlgo::Direct | ConvAlgo::Im2colGemm => Some(ctx.algo),
        ConvAlgo::Tuned => match ctx.tuned_choice_for(w.dim(3), crate::tensor::Dtype::Bf16).0 {
            TunedAlgo::Direct => Some(ConvAlgo::Direct),
            TunedAlgo::Gemm => Some(ConvAlgo::Im2colGemm),
            TunedAlgo::Sliding => None,
        },
        _ => None,
    };
    let y = match fallback {
        Some(ConvAlgo::Im2colGemm) => {
            conv2d_im2col_ctx(&from_bf16(&xb), &from_bf16(&wb), bias, p, ctx)
        }
        Some(_) => conv2d_direct_ctx(&from_bf16(&xb), &from_bf16(&wb), bias, p, ctx),
        None => return from_bf16(&conv2d_sliding_bf16_ctx(&xb, &wb, bias, p, ctx)),
    };
    // Match the sliding path's output precision: bf16 storage rounding
    // on the way out, so routing never changes the numeric contract.
    from_bf16(&to_bf16(&y))
}

/// [`conv2d_bf16_ctx`] with a fused ReLU: the activation is applied
/// **in place** over the widened f32 output — the exact operation a
/// standalone ReLU layer performs on that tensor (`max(v, 0.0)` on
/// already-bf16-rounded values), so the fusion saves the separate
/// activation tensor, not a rounding step, and stays bit-identical.
pub fn conv2d_bf16_epi_ctx(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    relu: bool,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> Tensor {
    let mut y = conv2d_bf16_ctx(x, w, bias, p, ctx);
    if relu {
        for v in y.as_mut_slice() {
            *v = v.max(0.0);
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for a in ConvAlgo::ALL {
            assert_eq!(ConvAlgo::parse(a.name()), Some(a));
        }
        assert_eq!(ConvAlgo::parse("nope"), None);
    }

    #[test]
    fn all_algos_agree_2d() {
        let x = Tensor::randn(&[1, 3, 12, 14], 81);
        let w = Tensor::randn(&[4, 3, 5, 5], 82);
        let p = Conv2dParams::same(5);
        let reference = conv2d(&x, &w, None, &p, ConvAlgo::Direct);
        for algo in ConvAlgo::ALL {
            let y = conv2d(&x, &w, None, &p, algo);
            let d = y.max_abs_diff(&reference);
            assert!(d < 2e-3, "{algo:?}: diff {d}");
        }
    }

    #[test]
    fn all_algos_agree_1d() {
        let x = Tensor::randn(&[2, 60], 83);
        let w = Tensor::randn(&[3, 2, 7], 84);
        let p = Conv1dParams { stride: 1, pad: 3 };
        let reference = conv1d(&x, &w, None, &p, ConvAlgo::Direct);
        for algo in ConvAlgo::ALL {
            let y = conv1d(&x, &w, None, &p, algo);
            let d = y.max_abs_diff(&reference);
            assert!(d < 2e-3, "{algo:?}: diff {d}");
        }
    }

    #[test]
    fn supports_width_policy() {
        assert!(ConvAlgo::SlidingGeneric.supports_width(17));
        assert!(!ConvAlgo::SlidingGeneric.supports_width(18));
        assert!(ConvAlgo::SlidingCompound.supports_width(64));
        assert!(ConvAlgo::Sliding.supports_width(10_000)); // falls back to direct
        assert!(ConvAlgo::Tuned.supports_width(10_000)); // same fallback
    }

    #[test]
    fn tuned_without_profile_is_bitwise_paper_policy() {
        let x = Tensor::randn(&[1, 3, 12, 14], 85);
        let w = Tensor::randn(&[4, 3, 5, 5], 86);
        let p = Conv2dParams::same(5);
        let paper = conv2d(&x, &w, None, &p, ConvAlgo::Sliding);
        let tuned = conv2d(&x, &w, None, &p, ConvAlgo::Tuned);
        assert_eq!(paper.as_slice(), tuned.as_slice());
    }

    #[test]
    fn tuned_routes_to_the_profiled_winner_bit_for_bit() {
        use crate::autotune::{DispatchProfile, ProfileEntry, TunedAlgo};
        use crate::kernels::rowconv::RowKernel;
        use std::sync::Arc;

        let x = Tensor::randn(&[1, 2, 10, 12], 87);
        let w = Tensor::randn(&[3, 2, 5, 5], 88);
        let p = Conv2dParams::default();
        for (algo, reference) in [
            (TunedAlgo::Direct, ConvAlgo::Direct),
            (TunedAlgo::Gemm, ConvAlgo::Im2colGemm),
            (TunedAlgo::Sliding, ConvAlgo::Sliding),
        ] {
            let profile = DispatchProfile::from_entries(vec![ProfileEntry {
                k: 5,
                threads: 1,
                dtype: crate::tensor::Dtype::F32,
                isa: crate::simd::IsaLevel::Scalar,
                algo,
                slide: RowKernel::Custom,
                gflops: 1.0,
            }]);
            let ctx = ExecCtx::new(ConvAlgo::Tuned).with_profile(Arc::new(profile));
            let tuned = conv2d_ctx(&x, &w, None, &p, &ctx);
            let want = conv2d(&x, &w, None, &p, reference);
            assert_eq!(
                tuned.as_slice(),
                want.as_slice(),
                "{algo:?} must be routed bit-for-bit"
            );
        }
    }
}
