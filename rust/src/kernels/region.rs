//! Halo-aware **region variants** of the sliding conv/pool kernels:
//! each entry point computes one output sub-rectangle (a *tile*) of the
//! corresponding whole-tensor kernel, reading only the input *halo*
//! that tile needs. [`crate::graph::tiling`] sizes the tiles so a whole
//! fused chain's per-tile working set stays L2-resident, and
//! [`crate::graph::plan`] drives these kernels tile-by-tile.
//!
//! ## The bitwise contract
//!
//! Tiled execution must be **bit-identical** to the untiled kernels for
//! every dtype, thread count and ISA level. The f32/bf16/i8 row
//! convolution kernels ([`crate::kernels::rowconv`]) make this easy:
//! they are *position-uniform* — output position `j` depends only on
//! `src[j..j+k)` combined in a fixed ascending-tap order, independent
//! of where the row starts or ends (partial vectors are masked, never
//! reassociated). So a region call evaluates each output element with
//! the exact same FP operation sequence as the untiled call, and the
//! kernels here replicate the untiled loop nests (`cig → ky` row
//! accumulation order, bias-seeded accumulators, epilogue-at-write).
//!
//! The one non-uniform primitive is the pooling horizontal combine
//! ([`crate::kernels::pool`]'s `sliding_combine_row`): unit-stride
//! positions `u < V` (where `V` rounds the untiled unit-stride output
//! width `ow1` down to a multiple of `LANES`) are combined by the
//! log-step *ladder* — a fixed combination tree independent of the
//! position's lane or block, so ladder values are position-uniform too
//! — while positions `u ≥ V` use a scalar ascending fold. `max` is
//! associative so the split is invisible, but `sum` (avg-pool) is not:
//! [`pool2d_sliding_region`] therefore replicates the *untiled* `V`
//! split exactly — ladder for tile positions below `V` (computed by
//! rounding the tile's span up to whole lanes and discarding the
//! extras, legal by per-lane uniformity), explicit scalar fold at and
//! above `V`, and the untiled all-scalar path when `k > LANES`.
//!
//! ## Halo geometry
//!
//! For an output rect `[oy0, oy1) × [ox0, ox1)` of a window op with
//! kernel `(kh, kw)`, stride `(sh, sw)` and pad `(ph, pw)`, the padded
//! input rows read are `[oy0·sh, (oy1−1)·sh + kh)` and the unit-stride
//! horizontal positions are `u ∈ [ox0·sw, (ox1−1)·sw]`, each reading
//! padded columns `[u, u+kw)`. [`input_region`] translates that to the
//! clamped *input-plane* rect — the tile's halo — which
//! [`crate::graph::tiling`] chains backwards through a fused group so
//! every intermediate is materialised only at tile size.
//!
//! Kernels here take their input as a [`SrcView`]: a dense copy of the
//! halo rect (or the whole plane, for a chain head) plus its position
//! in the full plane, and write a dense `[n, c_out, tile_h, tile_w]`
//! output slice. Per-tile local buffers live in a [`RegionScratch`]
//! checked out of the arena once per worker.

use super::epilogue::Epilogue;
use super::pool::{sliding_combine_row, Combine, PoolParams};
use super::rowconv::{row_conv_bf16_at, row_conv_q8_at, Q8_MAX_TAPS, RowKernel};
use super::sliding2d::SlideVariant;
use super::Conv2dParams;
use crate::exec::ExecCtx;
use crate::simd::LANES;
use crate::tensor::{Bf16, QuantParams, Tensor, TensorT, WeightScales};

/// A half-open rectangle `[y0, y1) × [x0, x1)` in plane coordinates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rect {
    pub y0: usize,
    pub y1: usize,
    pub x0: usize,
    pub x1: usize,
}

impl Rect {
    /// The whole `h × w` plane.
    pub fn full(h: usize, w: usize) -> Rect {
        Rect { y0: 0, y1: h, x0: 0, x1: w }
    }

    /// Rectangle height (`y1 - y0`).
    pub fn h(&self) -> usize {
        self.y1 - self.y0
    }

    /// Rectangle width (`x1 - x0`).
    pub fn w(&self) -> usize {
        self.x1 - self.x0
    }

    /// Element count.
    pub fn area(&self) -> usize {
        self.h() * self.w()
    }

    /// True when either side is zero.
    pub fn is_empty(&self) -> bool {
        self.y0 >= self.y1 || self.x0 >= self.x1
    }
}

/// The input-plane rect a window op must read to produce output rect
/// `out` — the tile's halo, clamped to the `in_h × in_w` plane (the
/// out-of-plane remainder is padding, synthesised locally by the region
/// kernels). May come back empty for tiles that read only padding;
/// [`crate::graph::tiling`] treats such chains as untileable.
pub fn input_region(
    out: Rect,
    k: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
    in_h: usize,
    in_w: usize,
) -> Rect {
    assert!(!out.is_empty(), "empty output rect");
    let (kh, kw) = k;
    let (sh, sw) = stride;
    let (ph, pw) = pad;
    let pr0 = out.y0 * sh;
    let pr1 = (out.y1 - 1) * sh + kh;
    let pc0 = out.x0 * sw;
    let pc1 = (out.x1 - 1) * sw + kw;
    Rect {
        y0: pr0.saturating_sub(ph).min(in_h),
        y1: pr1.saturating_sub(ph).min(in_h),
        x0: pc0.saturating_sub(pw).min(in_w),
        x1: pc1.saturating_sub(pw).min(in_w),
    }
}

/// A dense view of the sub-rect `rect` of every channel plane of an
/// `[n, c, full.0, full.1]` activation: `data` is
/// `[n, c, rect.h(), rect.w()]`. A chain head passes the whole input
/// tensor (`rect == full plane`); chain intermediates pass the tile
/// buffer the previous region call produced.
pub struct SrcView<'a, T> {
    pub data: &'a [T],
    pub c: usize,
    pub rect: Rect,
    /// Full plane size `(h, w)` the rect lives in.
    pub full: (usize, usize),
}

/// Reusable per-tile scratch for the region kernels: local padded
/// planes and row accumulators per dtype. Every kernel `clear`s and
/// re-grows the buffers it needs, so one warm `RegionScratch` (checked
/// out of the arena once per worker via [`RegionScratch::from_ctx`])
/// serves every tile of a parallel region allocation-free once its
/// capacity has peaked.
#[derive(Default)]
pub struct RegionScratch {
    padded_f32: Vec<f32>,
    row_f32: Vec<f32>,
    hrows: Vec<f32>,
    acc: Vec<f32>,
    padded_i8: Vec<i8>,
    row_i32: Vec<i32>,
    padded_bf16: Vec<Bf16>,
}

impl RegionScratch {
    /// Check the scratch vectors out of the ctx's arena (zero-length;
    /// they grow to tile size on first use and keep their capacity).
    pub fn from_ctx(ctx: &ExecCtx) -> Self {
        RegionScratch {
            padded_f32: ctx.take(0, 0.0),
            row_f32: ctx.take(0, 0.0),
            hrows: ctx.take(0, 0.0),
            acc: ctx.take(0, 0.0),
            padded_i8: ctx.take_elems(0, 0i8),
            row_i32: ctx.take_elems(0, 0i32),
            padded_bf16: ctx.take_elems(0, Bf16::ZERO),
        }
    }

    /// Return every buffer to the ctx's arena.
    pub fn release(self, ctx: &ExecCtx) {
        ctx.put(self.padded_f32);
        ctx.put(self.row_f32);
        ctx.put(self.hrows);
        ctx.put(self.acc);
        ctx.put_elems(self.padded_i8);
        ctx.put_elems(self.row_i32);
        ctx.put_elems(self.padded_bf16);
    }
}

/// Local padded-plane geometry for one output rect: the padded-plane
/// row/column window the region call covers.
struct RegionGeom {
    /// First padded-plane row the tile reads (`oy0 · sh`).
    pr0: usize,
    /// Local padded height (`(oy1−1)·sh + kh − pr0`).
    hp_l: usize,
    /// First unit-stride position / padded column (`ox0 · sw`).
    u0: usize,
    /// Unit-stride positions the tile samples (`(ox1−1)·sw + 1 − u0`).
    ulen: usize,
    /// Local padded width: `ulen + kw` data-relevant columns plus
    /// vector-load slack.
    wp_l: usize,
}

fn region_geom(out: Rect, k: (usize, usize), stride: (usize, usize), slack: usize) -> RegionGeom {
    assert!(!out.is_empty(), "empty output rect");
    let (kh, kw) = k;
    let (sh, sw) = stride;
    let pr0 = out.y0 * sh;
    let hp_l = (out.y1 - 1) * sh + kh - pr0;
    let u0 = out.x0 * sw;
    let ulen = (out.x1 - 1) * sw + 1 - u0;
    RegionGeom { pr0, hp_l, u0, ulen, wp_l: ulen + kw + slack }
}

/// Fill one channel's local padded plane (rows `[pr0, pr0+hp_l)`,
/// columns `[u0, u0+wp_l)` of the full padded plane) from a
/// [`SrcView`], mapping elements through `map` (identity, or the
/// f32→bf16 narrowing). The caller has pre-filled `local` with the pad
/// value; this copies the in-plane portion that the view covers.
/// Columns the view does not cover are either convolution padding or
/// vector-load slack — slack lanes are computed and discarded, so any
/// finite fill value is sound there.
#[allow(clippy::too_many_arguments)]
fn fill_local_padded<S: Copy, T: Copy>(
    src: &SrcView<'_, S>,
    ni: usize,
    ci: usize,
    g: &RegionGeom,
    pad: (usize, usize),
    local: &mut [T],
    map: impl Fn(S) -> T,
) {
    let (ph, pw) = pad;
    let fh = src.full.0;
    let r = src.rect;
    let (rh, rw) = (r.h(), r.w());
    let area = rh * rw;
    let plane = &src.data[(ni * src.c + ci) * area..][..area];
    // Column span of the view inside the local buffer.
    let lc0 = (pw + r.x0).saturating_sub(g.u0);
    let lc1 = (pw + r.x1).saturating_sub(g.u0).min(g.wp_l);
    if lc1 <= lc0 {
        return;
    }
    let s0 = g.u0 + lc0 - pw - r.x0;
    for lr in 0..g.hp_l {
        let gr = g.pr0 + lr;
        if gr < ph {
            continue; // top padding
        }
        let iy = gr - ph;
        if iy >= fh {
            break; // bottom padding
        }
        if iy < r.y0 || iy >= r.y1 {
            continue; // outside the view: padding or unused slack rows
        }
        let srow = &plane[(iy - r.y0) * rw..][..rw];
        let drow = &mut local[lr * g.wp_l + lc0..lr * g.wp_l + lc1];
        for (d, s) in drow.iter_mut().zip(&srow[s0..s0 + (lc1 - lc0)]) {
            *d = map(*s);
        }
    }
}

/// Region variant of
/// [`super::sliding2d::conv2d_sliding_epi_ctx`]: compute output rect
/// `out` of the f32 sliding convolution into the dense tile slice `dst`
/// (`[n, c_out, out.h(), out.w()]`). Bit-identical to the untiled
/// kernel on that rect — same row kernel resolution, same bias-seeded
/// `cig → ky` accumulation, same epilogue-at-write. Unlike the untiled
/// `Auto`, an unsupported filter width panics instead of falling back
/// to the direct kernel: the tiling analysis never selects such convs.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_sliding_region_epi_ctx(
    n: usize,
    src: &SrcView<'_, f32>,
    w: &Tensor,
    epi: Epilogue<'_>,
    p: &Conv2dParams,
    variant: SlideVariant,
    out: Rect,
    dst: &mut [f32],
    scratch: &mut RegionScratch,
    ctx: &ExecCtx,
) {
    let bias = epi.bias;
    assert_eq!(w.rank(), 4, "weights must be [cout, cin/g, kh, kw]");
    let c_in = src.c;
    let (c_out, c_in_g, kh, kw) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
    let g = p.groups;
    assert!(g >= 1 && c_in % g == 0 && c_out % g == 0, "bad groups {g}");
    assert_eq!(c_in / g, c_in_g, "weight c_in/{g} mismatch");
    if let Some(b) = bias {
        assert_eq!(b.len(), c_out, "bias length");
    }
    assert!(variant.supports(kw), "{variant:?} cannot evaluate filter width {kw} in a region");
    assert_eq!(src.data.len(), n * c_in * src.rect.area(), "src view length");
    let (th, tw) = (out.h(), out.w());
    assert_eq!(dst.len(), n * c_out * th * tw, "dst tile length");
    let row_fn = match variant {
        SlideVariant::Auto => ctx.tuned_row_kernel(kw).row_fn_at(kw, ctx.isa()),
        SlideVariant::Generic => RowKernel::Generic.row_fn_at(kw, ctx.isa()),
        SlideVariant::Compound => RowKernel::Compound.row_fn_at(kw, ctx.isa()),
    };
    // Right slack matches the untiled kernel's: 2·LANES beyond the
    // `ulen + kw` data-relevant columns.
    let geom = region_geom(out, (kh, kw), p.stride, 2 * LANES);
    let (sh, sw) = p.stride;
    let ws = w.as_slice();
    let c_out_g = c_out / g;
    let plane_l = geom.hp_l * geom.wp_l;

    let RegionScratch { padded_f32, row_f32, .. } = scratch;
    row_f32.clear();
    row_f32.resize(geom.ulen, 0.0);
    for ni in 0..n {
        padded_f32.clear();
        padded_f32.resize(c_in * plane_l, 0.0);
        for ci in 0..c_in {
            fill_local_padded(
                src,
                ni,
                ci,
                &geom,
                p.pad,
                &mut padded_f32[ci * plane_l..(ci + 1) * plane_l],
                |v| v,
            );
        }
        for co in 0..c_out {
            let grp = co / c_out_g;
            let b = bias.map_or(0.0, |b| b[co]);
            let oplane = &mut dst[(ni * c_out + co) * th * tw..][..th * tw];
            for (ty, oy) in (out.y0..out.y1).enumerate() {
                let iy0 = oy * sh - geom.pr0;
                row_f32.fill(b);
                for cig in 0..c_in_g {
                    let ci = grp * c_in_g + cig;
                    let plane = &padded_f32[ci * plane_l..(ci + 1) * plane_l];
                    for ky in 0..kh {
                        let srow = &plane[(iy0 + ky) * geom.wp_l..];
                        let wrow = &ws[((co * c_in_g + cig) * kh + ky) * kw..][..kw];
                        row_fn(srow, wrow, row_f32, geom.ulen);
                    }
                }
                let orow = &mut oplane[ty * tw..ty * tw + tw];
                if epi.relu {
                    for (tx, v) in orow.iter_mut().enumerate() {
                        *v = row_f32[tx * sw].max(0.0);
                    }
                } else {
                    for (tx, v) in orow.iter_mut().enumerate() {
                        *v = row_f32[tx * sw];
                    }
                }
            }
        }
    }
}

/// Region variant of the int8 sliding convolution **with the fused
/// dequant epilogue**: computes output rect `out` of
/// [`super::sliding2d::conv2d_sliding_q8_raw_ctx`] and applies the
/// shared dequant expression
/// (`raw · x_scale · w_scale[co] + bias`, optional ReLU — exactly
/// `dequantize_conv_acc`) at the tile write. Integer accumulation is
/// exact, so the raw tile agrees bit for bit with the untiled
/// accumulator; the dequant evaluates the identical f32 expression per
/// element.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_sliding_q8_region_ctx(
    n: usize,
    src: &SrcView<'_, i8>,
    qw: &TensorT<i8>,
    xq: QuantParams,
    wq: &WeightScales,
    bias: Option<&[f32]>,
    relu: bool,
    p: &Conv2dParams,
    out: Rect,
    dst: &mut [f32],
    scratch: &mut RegionScratch,
    ctx: &ExecCtx,
) {
    assert_eq!(qw.rank(), 4, "weights must be [cout, cin/g, kh, kw]");
    assert!(
        xq.is_symmetric() && wq.is_symmetric(),
        "int8 conv kernels require symmetric quantization (zero_point == 0)"
    );
    let c_in = src.c;
    let (c_out, c_in_g, kh, kw) = (qw.dim(0), qw.dim(1), qw.dim(2), qw.dim(3));
    let g = p.groups;
    assert!(g >= 1 && c_in % g == 0 && c_out % g == 0, "bad groups {g}");
    assert_eq!(c_in / g, c_in_g, "weight c_in/{g} mismatch");
    assert!(
        c_in_g * kh * kw <= Q8_MAX_TAPS,
        "int8 conv with {} taps could overflow the i32 accumulator (max {Q8_MAX_TAPS})",
        c_in_g * kh * kw
    );
    if let Some(b) = bias {
        assert_eq!(b.len(), c_out, "bias length");
    }
    assert_eq!(src.data.len(), n * c_in * src.rect.area(), "src view length");
    let (th, tw) = (out.h(), out.w());
    assert_eq!(dst.len(), n * c_out * th * tw, "dst tile length");
    let row_fn = row_conv_q8_at(ctx.isa());
    let geom = region_geom(out, (kh, kw), p.stride, 2 * LANES);
    let (sh, sw) = p.stride;
    let ws = qw.as_slice();
    let c_out_g = c_out / g;
    let plane_l = geom.hp_l * geom.wp_l;

    let RegionScratch { padded_i8, row_i32, .. } = scratch;
    row_i32.clear();
    row_i32.resize(geom.ulen, 0);
    for ni in 0..n {
        padded_i8.clear();
        padded_i8.resize(c_in * plane_l, 0i8);
        for ci in 0..c_in {
            fill_local_padded(
                src,
                ni,
                ci,
                &geom,
                p.pad,
                &mut padded_i8[ci * plane_l..(ci + 1) * plane_l],
                |v| v,
            );
        }
        for co in 0..c_out {
            let grp = co / c_out_g;
            let b = bias.map_or(0.0, |b| b[co]);
            let scale = xq.scale * wq.scale(co);
            let oplane = &mut dst[(ni * c_out + co) * th * tw..][..th * tw];
            for (ty, oy) in (out.y0..out.y1).enumerate() {
                let iy0 = oy * sh - geom.pr0;
                row_i32.fill(0);
                for cig in 0..c_in_g {
                    let ci = grp * c_in_g + cig;
                    let plane = &padded_i8[ci * plane_l..(ci + 1) * plane_l];
                    for ky in 0..kh {
                        let srow = &plane[(iy0 + ky) * geom.wp_l..];
                        let wrow = &ws[((co * c_in_g + cig) * kh + ky) * kw..][..kw];
                        row_fn(srow, wrow, row_i32, geom.ulen);
                    }
                }
                let orow = &mut oplane[ty * tw..ty * tw + tw];
                for (tx, v) in orow.iter_mut().enumerate() {
                    let val = row_i32[tx * sw] as f32 * scale + b;
                    *v = if relu { val.max(0.0) } else { val };
                }
            }
        }
    }
}

/// Region variant of the bf16 sliding convolution **fused into an f32
/// chain**: the f32 tile input is narrowed to bf16 codes during the
/// local pad fill (exactly the codes `to_bf16` would produce), the
/// weights arrive already narrowed-and-widened (`to_bf16(w)` expanded
/// back to f32, once per chain — `wf`, with dims `wdims`), accumulation
/// is f32 via the bf16 row kernel, and each output value rounds through
/// bf16 storage (`Bf16::from_f32(v).to_f32()`) before the optional
/// ReLU — exactly the untiled
/// `from_bf16(conv2d_sliding_bf16_ctx(to_bf16(x), …))` + epilogue
/// sequence of [`super::dispatch::conv2d_bf16_epi_ctx`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_sliding_bf16_region_ctx(
    n: usize,
    src: &SrcView<'_, f32>,
    wf: &[f32],
    wdims: (usize, usize, usize, usize),
    bias: Option<&[f32]>,
    relu: bool,
    p: &Conv2dParams,
    out: Rect,
    dst: &mut [f32],
    scratch: &mut RegionScratch,
    ctx: &ExecCtx,
) {
    let c_in = src.c;
    let (c_out, c_in_g, kh, kw) = wdims;
    let g = p.groups;
    assert!(g >= 1 && c_in % g == 0 && c_out % g == 0, "bad groups {g}");
    assert_eq!(c_in / g, c_in_g, "weight c_in/{g} mismatch");
    assert_eq!(wf.len(), c_out * c_in_g * kh * kw, "widened weight length");
    if let Some(b) = bias {
        assert_eq!(b.len(), c_out, "bias length");
    }
    assert_eq!(src.data.len(), n * c_in * src.rect.area(), "src view length");
    let (th, tw) = (out.h(), out.w());
    assert_eq!(dst.len(), n * c_out * th * tw, "dst tile length");
    let row_fn = row_conv_bf16_at(ctx.isa());
    let geom = region_geom(out, (kh, kw), p.stride, 2 * LANES);
    let (sh, sw) = p.stride;
    let c_out_g = c_out / g;
    let plane_l = geom.hp_l * geom.wp_l;

    let RegionScratch { padded_bf16, row_f32, .. } = scratch;
    row_f32.clear();
    row_f32.resize(geom.ulen, 0.0);
    for ni in 0..n {
        padded_bf16.clear();
        padded_bf16.resize(c_in * plane_l, Bf16::ZERO);
        for ci in 0..c_in {
            fill_local_padded(
                src,
                ni,
                ci,
                &geom,
                p.pad,
                &mut padded_bf16[ci * plane_l..(ci + 1) * plane_l],
                Bf16::from_f32,
            );
        }
        for co in 0..c_out {
            let grp = co / c_out_g;
            let b = bias.map_or(0.0, |b| b[co]);
            let oplane = &mut dst[(ni * c_out + co) * th * tw..][..th * tw];
            for (ty, oy) in (out.y0..out.y1).enumerate() {
                let iy0 = oy * sh - geom.pr0;
                row_f32.fill(b);
                for cig in 0..c_in_g {
                    let ci = grp * c_in_g + cig;
                    let plane = &padded_bf16[ci * plane_l..(ci + 1) * plane_l];
                    for ky in 0..kh {
                        let srow = &plane[(iy0 + ky) * geom.wp_l..];
                        let wrow = &wf[((co * c_in_g + cig) * kh + ky) * kw..][..kw];
                        row_fn(srow, wrow, row_f32, geom.ulen);
                    }
                }
                let orow = &mut oplane[ty * tw..ty * tw + tw];
                for (tx, v) in orow.iter_mut().enumerate() {
                    let val = Bf16::from_f32(row_f32[tx * sw]).to_f32();
                    *v = if relu { val.max(0.0) } else { val };
                }
            }
        }
    }
}

/// Region variant of the shared 2-D pooling skeleton
/// (`pool2d_sliding`): computes output rect `out` of max pooling
/// (`max = true`) or average pooling (`max = false`,
/// `count_include_pad = true`, the `1/(kh·kw)` scale applied at the
/// tile write exactly as the untiled epilogue pass applies it to the
/// stored sum). See the module docs for how the horizontal combine
/// replicates the untiled ladder/scalar `V` split bit for bit.
pub fn pool2d_sliding_region(
    n: usize,
    src: &SrcView<'_, f32>,
    p: &PoolParams,
    max: bool,
    out: Rect,
    dst: &mut [f32],
    scratch: &mut RegionScratch,
) {
    let op = if max { Combine::Max } else { Combine::Sum };
    let inv = 1.0 / (p.k.0 * p.k.1) as f32;
    let c = src.c;
    let (kh, kw) = p.k;
    let (sh, sw) = p.stride;
    let (_, fw) = src.full;
    assert_eq!(src.data.len(), n * c * src.rect.area(), "src view length");
    let (th, tw) = (out.h(), out.w());
    assert_eq!(dst.len(), n * c * th * tw, "dst tile length");
    // Untiled unit-stride width and its ladder/scalar split point.
    let ow1 = fw + 2 * p.pad.1 - kw + 1;
    let v_split = ow1 - ow1 % LANES;
    let geom = region_geom(out, (kh, kw), p.stride, 4 * LANES);
    let plane_l = geom.hp_l * geom.wp_l;
    // Tile positions computed by the ladder: unit-stride positions
    // `u0 + t` with `u0 + t < v_split`, rounded up to whole lanes for
    // the ladder call (per-lane uniformity makes the extra lanes
    // correct-but-unused; the scalar fold below overwrites the ones
    // that the untiled kernel computes serially).
    let nv = v_split.saturating_sub(geom.u0).min(geom.ulen);
    let nv_r = nv.div_ceil(LANES) * LANES;
    let hseg_w = geom.ulen + LANES; // row stride in `hrows`; slack for the round-up
    let RegionScratch { padded_f32, hrows, acc, .. } = scratch;
    acc.clear();
    acc.resize(geom.ulen, 0.0);
    hrows.clear();
    hrows.resize(geom.hp_l * hseg_w, 0.0);
    for ni in 0..n {
        for ci in 0..c {
            padded_f32.clear();
            padded_f32.resize(plane_l, op.identity());
            fill_local_padded(src, ni, ci, &geom, p.pad, padded_f32, |v| v);
            // Horizontal combine per local padded row, replicating the
            // untiled kernel's position → ladder/scalar assignment.
            for lr in 0..geom.hp_l {
                let srow = &padded_f32[lr * geom.wp_l..];
                let hrow = &mut hrows[lr * hseg_w..(lr + 1) * hseg_w];
                if kw > LANES {
                    // Untiled kernel is all-scalar at these widths.
                    sliding_combine_row(srow, kw, hrow, geom.ulen, op);
                    continue;
                }
                if nv_r > 0 {
                    sliding_combine_row(srow, kw, hrow, nv_r, op);
                }
                for t in nv..geom.ulen {
                    let mut a = srow[t];
                    for j in 1..kw {
                        a = op.scalar(a, srow[t + j]);
                    }
                    hrow[t] = a;
                }
            }
            let oplane = &mut dst[(ni * c + ci) * th * tw..][..th * tw];
            for (ty, oy) in (out.y0..out.y1).enumerate() {
                let iy0 = oy * sh - geom.pr0;
                acc.copy_from_slice(&hrows[iy0 * hseg_w..iy0 * hseg_w + geom.ulen]);
                for ky in 1..kh {
                    let row = &hrows[(iy0 + ky) * hseg_w..(iy0 + ky) * hseg_w + geom.ulen];
                    for (a, &r) in acc.iter_mut().zip(row.iter()) {
                        *a = op.scalar(*a, r);
                    }
                }
                let orow = &mut oplane[ty * tw..ty * tw + tw];
                if max {
                    for (tx, v) in orow.iter_mut().enumerate() {
                        *v = acc[tx * sw];
                    }
                } else {
                    for (tx, v) in orow.iter_mut().enumerate() {
                        *v = acc[tx * sw] * inv;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::pool::{avg_pool2d_ctx, max_pool2d_ctx};
    use crate::kernels::sliding2d::{
        conv2d_sliding_bf16_ctx, conv2d_sliding_epi_ctx, conv2d_sliding_q8_raw_ctx,
        dequantize_conv_acc,
    };
    use crate::kernels::ConvAlgo;
    use crate::tensor::{from_bf16, quantize, to_bf16};

    fn tiles(oh: usize, ow: usize, th: usize, tw: usize) -> Vec<Rect> {
        let mut v = Vec::new();
        let mut y0 = 0;
        while y0 < oh {
            let y1 = (y0 + th).min(oh);
            let mut x0 = 0;
            while x0 < ow {
                let x1 = (x0 + tw).min(ow);
                v.push(Rect { y0, y1, x0, x1 });
                x0 = x1;
            }
            y0 = y1;
        }
        v
    }

    fn paste(full: &mut [f32], c: usize, oh: usize, ow: usize, n: usize, r: Rect, tile: &[f32]) {
        let (th, tw) = (r.h(), r.w());
        for ni in 0..n {
            for ci in 0..c {
                for ty in 0..th {
                    let dst =
                        &mut full[((ni * c + ci) * oh + r.y0 + ty) * ow + r.x0..][..tw];
                    dst.copy_from_slice(&tile[((ni * c + ci) * th + ty) * tw..][..tw]);
                }
            }
        }
    }

    /// Copy the sub-rect `r` of every `[n, c, h, w]` plane into a dense
    /// buffer — what the tiled executor's intermediate buffers hold.
    fn crop(x: &[f32], n: usize, c: usize, h: usize, w: usize, r: Rect) -> Vec<f32> {
        let mut out = Vec::with_capacity(n * c * r.area());
        for ni in 0..n {
            for ci in 0..c {
                for iy in r.y0..r.y1 {
                    out.extend_from_slice(&x[((ni * c + ci) * h + iy) * w + r.x0..][..r.w()]);
                }
            }
        }
        assert_eq!(out.len(), n * c * r.area());
        out
    }

    #[allow(clippy::too_many_arguments)]
    fn conv_region_case(
        xdims: &[usize],
        wdims: &[usize],
        p: &Conv2dParams,
        variant: SlideVariant,
        relu: bool,
        tile: (usize, usize),
        cropped: bool,
        seed: u64,
    ) {
        let x = Tensor::randn(xdims, seed);
        let w = Tensor::randn(wdims, seed + 1);
        let bias: Vec<f32> = (0..wdims[0]).map(|i| 0.05 * i as f32 - 0.1).collect();
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let want = conv2d_sliding_epi_ctx(
            &x,
            &w,
            Epilogue::from_bias(Some(&bias)).with_relu(relu),
            p,
            variant,
            &ctx,
        );
        let (n, c_in, h, win) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
        let (c_out, oh, ow) = (want.dim(1), want.dim(2), want.dim(3));
        let mut got = vec![0.0f32; n * c_out * oh * ow];
        let mut rs = RegionScratch::default();
        for r in tiles(oh, ow, tile.0, tile.1) {
            let mut t = vec![0.0f32; n * c_out * r.area()];
            let epi = Epilogue::from_bias(Some(&bias)).with_relu(relu);
            if cropped {
                let ir = input_region(r, (w.dim(2), w.dim(3)), p.stride, p.pad, h, win);
                let data = crop(x.as_slice(), n, c_in, h, win, ir);
                let src = SrcView { data: &data, c: c_in, rect: ir, full: (h, win) };
                conv2d_sliding_region_epi_ctx(n, &src, &w, epi, p, variant, r, &mut t, &mut rs, &ctx);
            } else {
                let src = SrcView {
                    data: x.as_slice(),
                    c: c_in,
                    rect: Rect::full(h, win),
                    full: (h, win),
                };
                conv2d_sliding_region_epi_ctx(n, &src, &w, epi, p, variant, r, &mut t, &mut rs, &ctx);
            }
            paste(&mut got, c_out, oh, ow, n, r, &t);
        }
        assert_eq!(
            &got[..],
            want.as_slice(),
            "{xdims:?} {wdims:?} {p:?} {variant:?} relu={relu} tile={tile:?} cropped={cropped}"
        );
    }

    #[test]
    fn conv_f32_region_matches_untiled_bitwise() {
        let p = Conv2dParams::same(3);
        for tile in [(1, 64), (64, 64), (3, 5), (2, 1)] {
            conv_region_case(&[2, 3, 11, 13], &[4, 3, 3, 3], &p, SlideVariant::Auto, true, tile, false, 11);
        }
    }

    #[test]
    fn conv_f32_region_matches_on_cropped_views() {
        let p = Conv2dParams::same(5);
        conv_region_case(&[1, 2, 12, 17], &[3, 2, 5, 5], &p, SlideVariant::Auto, false, (4, 6), true, 21);
        conv_region_case(&[1, 2, 12, 17], &[3, 2, 5, 5], &p, SlideVariant::Generic, true, (1, 17), true, 22);
    }

    #[test]
    fn conv_f32_region_strided_grouped() {
        let p = Conv2dParams { stride: (2, 2), pad: (1, 1), groups: 2 };
        for tile in [(2, 3), (64, 64), (1, 2)] {
            conv_region_case(&[2, 4, 12, 14], &[6, 2, 3, 3], &p, SlideVariant::Auto, false, tile, true, 31);
        }
    }

    #[test]
    fn conv_f32_region_compound_variant() {
        let p = Conv2dParams::default();
        conv_region_case(&[1, 1, 9, 40], &[2, 1, 3, 17], &p, SlideVariant::Compound, false, (3, 7), true, 41);
    }

    #[test]
    fn pool_region_matches_untiled_bitwise() {
        // Width chosen so ow1 % LANES != 0 — exercises the ladder/scalar
        // V split that average pooling's non-associative sum exposes.
        let x = Tensor::randn(&[2, 3, 13, 21], 51);
        let (n, c, h, w) = (2, 3, 13, 21);
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        for p in [
            PoolParams::square(2),
            PoolParams::with_stride(3, 2),
            PoolParams { k: (3, 3), stride: (1, 1), pad: (1, 1) },
        ] {
            let (oh, ow) = p.out_size(h, w);
            for max in [true, false] {
                let want = if max {
                    max_pool2d_ctx(&x, &p, &ctx)
                } else {
                    avg_pool2d_ctx(&x, &p, &ctx)
                };
                for tile in [(1, ow), (oh, ow), (3, 4), (2, 1)] {
                    let mut got = vec![0.0f32; n * c * oh * ow];
                    let mut rs = RegionScratch::default();
                    for r in tiles(oh, ow, tile.0, tile.1) {
                        let ir = input_region(r, p.k, p.stride, p.pad, h, w);
                        let data = crop(x.as_slice(), n, c, h, w, ir);
                        let src = SrcView { data: &data, c, rect: ir, full: (h, w) };
                        let mut t = vec![0.0f32; n * c * r.area()];
                        pool2d_sliding_region(n, &src, &p, max, r, &mut t, &mut rs);
                        paste(&mut got, c, oh, ow, n, r, &t);
                    }
                    assert_eq!(&got[..], want.as_slice(), "{p:?} max={max} tile={tile:?}");
                }
            }
        }
    }

    #[test]
    fn q8_region_matches_untiled_bitwise() {
        let x = Tensor::randn(&[2, 3, 10, 12], 61);
        let w = Tensor::randn(&[4, 3, 3, 3], 62);
        let bias: Vec<f32> = (0..4).map(|i| 0.1 * i as f32).collect();
        let p = Conv2dParams::same(3);
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let xq = QuantParams::for_tensor(&x);
        let qx = quantize(&x, xq);
        let wqp = QuantParams::for_tensor(&w);
        let qw = quantize(&w, wqp);
        let wq = WeightScales::PerTensor(wqp);
        for relu in [false, true] {
            let raw = conv2d_sliding_q8_raw_ctx(&qx, &qw, &p, &ctx);
            let want = dequantize_conv_acc(&raw, xq, &wq, Some(&bias), relu);
            let (oh, ow) = (want.dim(2), want.dim(3));
            for tile in [(1, ow), (4, 5), (2, 2)] {
                let mut got = vec![0.0f32; 2 * 4 * oh * ow];
                let mut rs = RegionScratch::default();
                for r in tiles(oh, ow, tile.0, tile.1) {
                    let src = SrcView {
                        data: qx.as_slice(),
                        c: 3,
                        rect: Rect::full(10, 12),
                        full: (10, 12),
                    };
                    let mut t = vec![0.0f32; 2 * 4 * r.area()];
                    conv2d_sliding_q8_region_ctx(
                        2, &src, &qw, xq, &wq, Some(&bias), relu, &p, r, &mut t, &mut rs, &ctx,
                    );
                    paste(&mut got, 4, oh, ow, 2, r, &t);
                }
                assert_eq!(&got[..], want.as_slice(), "relu={relu} tile={tile:?}");
            }
        }
    }

    #[test]
    fn bf16_region_matches_untiled_bitwise() {
        let x = Tensor::randn(&[1, 2, 9, 14], 71);
        let w = Tensor::randn(&[3, 2, 3, 3], 72);
        let bias: Vec<f32> = (0..3).map(|i| 0.1 * i as f32 - 0.05).collect();
        let p = Conv2dParams::same(3);
        let ctx = ExecCtx::new(ConvAlgo::Sliding);
        let xb = to_bf16(&x);
        let wb = to_bf16(&w);
        let wf: Vec<f32> = wb.as_slice().iter().map(|v| v.to_f32()).collect();
        for relu in [false, true] {
            let mut want = from_bf16(&conv2d_sliding_bf16_ctx(&xb, &wb, Some(&bias), &p, &ctx));
            if relu {
                for v in want.as_mut_slice() {
                    *v = v.max(0.0);
                }
            }
            let (oh, ow) = (want.dim(2), want.dim(3));
            for tile in [(1, ow), (3, 5)] {
                let mut got = vec![0.0f32; 3 * oh * ow];
                let mut rs = RegionScratch::default();
                for r in tiles(oh, ow, tile.0, tile.1) {
                    let src = SrcView {
                        data: x.as_slice(),
                        c: 2,
                        rect: Rect::full(9, 14),
                        full: (9, 14),
                    };
                    let mut t = vec![0.0f32; 3 * r.area()];
                    conv2d_sliding_bf16_region_ctx(
                        1, &src, &wf, (3, 2, 3, 3), Some(&bias), relu, &p, r, &mut t, &mut rs,
                        &ctx,
                    );
                    paste(&mut got, 3, oh, ow, 1, r, &t);
                }
                assert_eq!(&got[..], want.as_slice(), "relu={relu} tile={tile:?}");
            }
        }
    }

    #[test]
    fn input_region_halo_math() {
        // 3x3 same-pad conv: interior tile needs a 1-px halo.
        let r = input_region(
            Rect { y0: 4, y1: 8, x0: 4, x1: 8 },
            (3, 3),
            (1, 1),
            (1, 1),
            16,
            16,
        );
        assert_eq!(r, Rect { y0: 3, y1: 9, x0: 3, x1: 9 });
        // Corner tile: the padding clamps away.
        let r = input_region(Rect { y0: 0, y1: 4, x0: 0, x1: 4 }, (3, 3), (1, 1), (1, 1), 16, 16);
        assert_eq!(r, Rect { y0: 0, y1: 5, x0: 0, x1: 5 });
        // Stride-2 pooling: adjacent tiles read disjoint rows.
        let r = input_region(Rect { y0: 2, y1: 4, x0: 0, x1: 4 }, (2, 2), (2, 2), (0, 0), 16, 8);
        assert_eq!(r, Rect { y0: 4, y1: 8, x0: 0, x1: 8 });
        // Fully-padded tile clamps to empty.
        let r = input_region(Rect { y0: 0, y1: 1, x0: 0, x1: 1 }, (1, 1), (1, 1), (2, 2), 4, 4);
        assert!(r.is_empty());
    }
}
