//! `im2col` + GEMM convolution — the baseline the paper measures against
//! (a stand-in for ONNX Runtime's `MlasConv`).
//!
//! The input window of every output position is copied into a column of a
//! `[c_in·kh·kw, oh·ow]` matrix, after which convolution is one GEMM with
//! the `[c_out, c_in·kh·kw]` weight matrix. This is the approach whose
//! "memory bloating problem" motivates the paper: the column matrix is
//! `kh·kw` times larger than the input tensor, and building it is pure
//! memory traffic. [`im2col_bytes`] reports the bloat so the benchmark
//! harness can plot it.

use super::epilogue::Epilogue;
use super::gemm::{gemm_q8, pack_a_len, pack_b_len, sgemm_with_scratch, NR};
use super::sliding2d::dequantize_conv_acc;
use super::Conv2dParams;
use crate::exec::ExecCtx;
use crate::tensor::{Element, QuantParams, Tensor, TensorT, WeightScales};

/// Per-worker byte budget the accumulating (low-memory) im2col variant
/// targets for its f32 column strip — roughly half an L2 slice, the
/// Anderson-et-al. trade: a bounded strip is re-expanded per GEMM call
/// instead of materialising the full `kh·kw ×` bloated column matrix.
const LOWMEM_COL_BYTES: usize = 256 << 10;

/// Size in bytes of the column matrix `im2col` materialises for one image
/// of one group — the paper's memory-bloat metric.
pub fn im2col_bytes(c_in_g: usize, kh: usize, kw: usize, oh: usize, ow: usize) -> usize {
    c_in_g * kh * kw * oh * ow * std::mem::size_of::<f32>()
}

/// Expand one `(image, group)` into the column matrix (any element
/// type — the int8 baseline materialises i8 columns, so its bloat is
/// byte-for-byte what an int8 `MlasConv` would pay).
///
/// `col` is `[c_in_g * kh * kw, oh * ow]` row-major; out-of-image taps
/// (from padding) become the element's additive zero.
#[allow(clippy::too_many_arguments)]
fn im2col_plane<E: Element>(
    x: &TensorT<E>,
    ni: usize,
    ci0: usize,
    c_in_g: usize,
    kh: usize,
    kw: usize,
    p: &Conv2dParams,
    oh: usize,
    ow: usize,
    col: &mut [E],
) {
    let (h, w) = (x.dim(2), x.dim(3));
    let (sh, sw) = p.stride;
    let (ph, pw) = p.pad;
    let ohw = oh * ow;
    for cig in 0..c_in_g {
        let plane = x.plane(ni, ci0 + cig);
        for ky in 0..kh {
            for kx in 0..kw {
                let row = &mut col[((cig * kh + ky) * kw + kx) * ohw..][..ohw];
                for oy in 0..oh {
                    let iy = oy * sh + ky;
                    let dst = &mut row[oy * ow..oy * ow + ow];
                    if iy < ph || iy >= h + ph {
                        dst.fill(E::default());
                        continue;
                    }
                    let src_row = &plane[(iy - ph) * w..(iy - ph) * w + w];
                    // Columns: ix = ox*sw + kx - pw must lie in [0, w).
                    if sw == 1 {
                        // Contiguous copy with zero head/tail.
                        for (ox, d) in dst.iter_mut().enumerate() {
                            let ix = ox + kx;
                            *d = if ix < pw || ix >= w + pw {
                                E::default()
                            } else {
                                src_row[ix - pw]
                            };
                        }
                    } else {
                        for (ox, d) in dst.iter_mut().enumerate() {
                            let ix = ox * sw + kx;
                            *d = if ix < pw || ix >= w + pw {
                                E::default()
                            } else {
                                src_row[ix - pw]
                            };
                        }
                    }
                }
            }
        }
    }
}

/// Output-column strip width the low-memory GEMM variant expands at a
/// time for a `kdim`-row column matrix: as many columns as keep the f32
/// strip within [`LOWMEM_COL_BYTES`], but never less than one GEMM
/// panel ([`NR`] — `pack_b` zero-pads ragged panels, so a narrower
/// strip would waste packed lanes without saving memory).
pub fn lowmem_strip_cols(kdim: usize) -> usize {
    let per_col = kdim.max(1) * std::mem::size_of::<f32>();
    (LOWMEM_COL_BYTES / per_col).max(NR)
}

/// [`im2col_plane`] restricted to output columns `[j0, j0 + len)` of the
/// flattened `oh·ow` axis: fills `col` as `[c_in_g·kh·kw, len]`
/// row-major. Each element is the **same** input tap the full expansion
/// would place at flattened column `j0 + j`, so strip-wise GEMM over
/// consecutive strips reads exactly the taps the one-shot expansion
/// reads (per-column scalar addressing — the strip trades copy
/// throughput for footprint).
#[allow(clippy::too_many_arguments)]
fn im2col_strip<E: Element>(
    x: &TensorT<E>,
    ni: usize,
    ci0: usize,
    c_in_g: usize,
    kh: usize,
    kw: usize,
    p: &Conv2dParams,
    ow: usize,
    j0: usize,
    len: usize,
    col: &mut [E],
) {
    let (h, w) = (x.dim(2), x.dim(3));
    let (sh, sw) = p.stride;
    let (ph, pw) = p.pad;
    for cig in 0..c_in_g {
        let plane = x.plane(ni, ci0 + cig);
        for ky in 0..kh {
            for kx in 0..kw {
                let row = &mut col[((cig * kh + ky) * kw + kx) * len..][..len];
                for (j, d) in row.iter_mut().enumerate() {
                    let (oy, ox) = ((j0 + j) / ow, (j0 + j) % ow);
                    let (iy, ix) = (oy * sh + ky, ox * sw + kx);
                    *d = if iy < ph || iy >= h + ph || ix < pw || ix >= w + pw {
                        E::default()
                    } else {
                        plane[(iy - ph) * w + (ix - pw)]
                    };
                }
            }
        }
    }
}

/// 2-D convolution via `im2col` + blocked GEMM.
///
/// Same contract as [`super::direct::conv2d_direct`].
pub fn conv2d_im2col(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv2dParams,
) -> Tensor {
    crate::exec::with_thread_ctx(crate::kernels::ConvAlgo::Im2colGemm, |ctx| {
        conv2d_im2col_ctx(x, w, bias, p, ctx)
    })
}

/// [`conv2d_im2col`] with an execution context: each `(image, group)` is
/// one work item — its column matrix comes from the ctx's scratch arena
/// and its GEMM writes a contiguous `[c_out/g, oh·ow]` output block, so
/// items fan out over the ctx's threads with no shared mutable state.
pub fn conv2d_im2col_ctx(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> Tensor {
    conv2d_im2col_epi_ctx(x, w, Epilogue::from_bias(bias), p, ctx)
}

/// [`conv2d_im2col_ctx`] with a fused output [`Epilogue`]: bias and the
/// optional ReLU are folded over each group's cache-resident GEMM
/// output block ([`Epilogue::apply_rows`]) before it leaves L2, instead
/// of as separate full-tensor memory passes. With `relu == false` the
/// arithmetic is the unfused kernel's bias loop verbatim — bit-identical.
pub fn conv2d_im2col_epi_ctx(
    x: &Tensor,
    w: &Tensor,
    epi: Epilogue<'_>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> Tensor {
    let bias = epi.bias;
    assert_eq!(x.rank(), 4);
    assert_eq!(w.rank(), 4);
    let (n, c_in, h, win) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (c_out, c_in_g, kh, kw) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
    let g = p.groups;
    assert!(g >= 1 && c_in % g == 0 && c_out % g == 0, "bad groups {g}");
    assert_eq!(c_in / g, c_in_g);
    if let Some(b) = bias {
        assert_eq!(b.len(), c_out);
    }
    let (oh, ow) = p.out_size(h, win, kh, kw);
    let (c_out_g, ohw) = (c_out / g, oh * ow);
    let kdim = c_in_g * kh * kw;

    let mut out = Tensor::zeros(&[n, c_out, oh, ow]);
    let ws = w.as_slice();
    // One work item per (image, group): the output block
    // [ni, grp*c_out_g .. (grp+1)*c_out_g) is contiguous in NCHW, so
    // item index ni*g + grp maps straight onto chunked output storage.
    // Per-worker scratch (column matrix + GEMM packing buffers): one
    // arena checkout per parallel region (im2col_plane and the packers
    // overwrite every element they read, so reuse across items is safe),
    // keeping steady-state arena traffic allocation-free. The arena —
    // not sgemm's thread-locals — is what makes this hold on pool
    // workers too: checked-in buffers outlive the region and stay
    // trimmable, instead of each resident worker pinning its own
    // packing scratch forever.
    ctx.par_chunks_with(
        out.as_mut_slice(),
        c_out_g * ohw,
        || {
            (
                ctx.take_unfilled(kdim * ohw),
                ctx.take_unfilled(pack_a_len()),
                ctx.take_unfilled(pack_b_len(ohw)),
            )
        },
        |item, cblk, (col, pa, pb)| {
            let (ni, grp) = (item / g, item % g);
            im2col_plane(x, ni, grp * c_in_g, c_in_g, kh, kw, p, oh, ow, col);
            // Weight block for this group is contiguous:
            // rows [grp*c_out_g .. (grp+1)*c_out_g) of the flattened
            // [c_out, kdim] weight matrix.
            let wmat = &ws[grp * c_out_g * kdim..(grp + 1) * c_out_g * kdim];
            sgemm_with_scratch(c_out_g, kdim, ohw, wmat, col, cblk, pa, pb);
            epi.apply_rows(cblk, c_out_g, ohw, grp * c_out_g);
        },
        |(col, pa, pb)| {
            ctx.put(col);
            ctx.put(pa);
            ctx.put(pb);
        },
    );
    out
}

/// Low-memory (accumulating-im2col / kn2row-style) variant of
/// [`conv2d_im2col_epi_ctx`]: instead of materialising the whole
/// `[kdim, oh·ow]` column matrix per `(image, group)`, output columns
/// are processed in strips of [`lowmem_strip_cols`] — expand the strip,
/// run one strip GEMM into a small staging block, apply the epilogue,
/// scatter the rows into the output — so per-worker scratch is bounded
/// by the strip budget instead of growing with the spatial extent.
///
/// **Bit-identical to the one-shot kernel**: the blocked GEMM packs B
/// in [`NR`]-wide zero-padded panels and accumulates each output
/// element over the K blocks in a fixed order that never depends on the
/// N extent, and the epilogue is per-element — so computing columns
/// `[j0, j0+len)` via a separate GEMM call reproduces the full call's
/// FP sequence for those columns exactly. This is what puts the memory
/// frontier below full-im2col in the planner's candidate set without
/// costing output parity.
pub fn conv2d_im2col_lowmem_epi_ctx(
    x: &Tensor,
    w: &Tensor,
    epi: Epilogue<'_>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> Tensor {
    let bias = epi.bias;
    assert_eq!(x.rank(), 4);
    assert_eq!(w.rank(), 4);
    let (n, c_in, h, win) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (c_out, c_in_g, kh, kw) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
    let g = p.groups;
    assert!(g >= 1 && c_in % g == 0 && c_out % g == 0, "bad groups {g}");
    assert_eq!(c_in / g, c_in_g);
    if let Some(b) = bias {
        assert_eq!(b.len(), c_out);
    }
    let (oh, ow) = p.out_size(h, win, kh, kw);
    let (c_out_g, ohw) = (c_out / g, oh * ow);
    let kdim = c_in_g * kh * kw;
    let strip = lowmem_strip_cols(kdim).min(ohw.max(1));

    let mut out = Tensor::zeros(&[n, c_out, oh, ow]);
    let ws = w.as_slice();
    ctx.par_chunks_with(
        out.as_mut_slice(),
        c_out_g * ohw,
        || {
            (
                ctx.take_unfilled(kdim * strip),
                ctx.take_unfilled(pack_a_len()),
                ctx.take_unfilled(pack_b_len(strip)),
                ctx.take_unfilled(c_out_g * strip),
            )
        },
        |item, cblk, (col, pa, pb, sblk)| {
            let (ni, grp) = (item / g, item % g);
            let wmat = &ws[grp * c_out_g * kdim..(grp + 1) * c_out_g * kdim];
            let mut j0 = 0;
            while j0 < ohw {
                let len = strip.min(ohw - j0);
                im2col_strip(x, ni, grp * c_in_g, c_in_g, kh, kw, p, ow, j0, len, col);
                let stage = &mut sblk[..c_out_g * len];
                stage.fill(0.0);
                sgemm_with_scratch(c_out_g, kdim, len, wmat, &col[..kdim * len], stage, pa, pb);
                epi.apply_rows(stage, c_out_g, len, grp * c_out_g);
                for r in 0..c_out_g {
                    cblk[r * ohw + j0..r * ohw + j0 + len]
                        .copy_from_slice(&stage[r * len..(r + 1) * len]);
                }
                j0 += len;
            }
        },
        |(col, pa, pb, sblk)| {
            ctx.put(col);
            ctx.put(pa);
            ctx.put(pb);
            ctx.put(sblk);
        },
    );
    out
}

/// Low-memory strip variant of [`conv2d_im2col_q8_raw_ctx`] (int8
/// codes, exact-i32 accumulation): the i8 column strip and i32 staging
/// block are bounded by [`lowmem_strip_cols`], and integer GEMM is
/// order-exact, so the output is bit-identical to both the one-shot
/// int8 im2col baseline and the quantized sliding kernel.
pub fn conv2d_im2col_lowmem_q8_raw_ctx(
    x: &TensorT<i8>,
    w: &TensorT<i8>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> TensorT<i32> {
    assert_eq!(x.rank(), 4);
    assert_eq!(w.rank(), 4);
    let (n, c_in, h, win) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (c_out, c_in_g, kh, kw) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
    let g = p.groups;
    assert!(g >= 1 && c_in % g == 0 && c_out % g == 0, "bad groups {g}");
    assert_eq!(c_in / g, c_in_g);
    assert!(
        c_in_g * kh * kw <= crate::kernels::rowconv::Q8_MAX_TAPS,
        "int8 conv with {} taps could overflow the i32 accumulator",
        c_in_g * kh * kw
    );
    let (oh, ow) = p.out_size(h, win, kh, kw);
    let (c_out_g, ohw) = (c_out / g, oh * ow);
    let kdim = c_in_g * kh * kw;
    let strip = lowmem_strip_cols(kdim).min(ohw.max(1));

    let mut out = TensorT::<i32>::zeros(&[n, c_out, oh, ow]);
    let ws = w.as_slice();
    ctx.par_chunks_with(
        out.as_mut_slice(),
        c_out_g * ohw,
        || {
            (
                ctx.take_elems_unfilled::<i8>(kdim * strip),
                ctx.take_elems_unfilled::<i32>(c_out_g * strip),
            )
        },
        |item, cblk, (col, sblk)| {
            let (ni, grp) = (item / g, item % g);
            let wmat = &ws[grp * c_out_g * kdim..(grp + 1) * c_out_g * kdim];
            let mut j0 = 0;
            while j0 < ohw {
                let len = strip.min(ohw - j0);
                im2col_strip(x, ni, grp * c_in_g, c_in_g, kh, kw, p, ow, j0, len, col);
                let stage = &mut sblk[..c_out_g * len];
                stage.fill(0);
                gemm_q8(c_out_g, kdim, len, wmat, &col[..kdim * len], stage);
                for r in 0..c_out_g {
                    cblk[r * ohw + j0..r * ohw + j0 + len]
                        .copy_from_slice(&stage[r * len..(r + 1) * len]);
                }
                j0 += len;
            }
        },
        |(col, sblk)| {
            ctx.put_elems(col);
            ctx.put_elems(sblk);
        },
    );
    out
}

/// Quantized int8 `im2col` + GEMM convolution, **raw accumulator**
/// output — the baseline the quantized sliding kernel is measured
/// against (`BENCH_quant.json`).
///
/// Identical structure to [`conv2d_im2col_ctx`]: each `(image, group)`
/// expands an **i8** column matrix from the arena (the same `kh·kw ×`
/// memory bloat, now in bytes) and runs one exact-i32 [`gemm_q8`] into
/// a contiguous output block. Requires symmetric quantization (codes
/// sum directly; zero padding is the code 0). Exact integer arithmetic
/// makes this bit-identical to
/// [`super::sliding2d::conv2d_sliding_q8_raw_ctx`].
pub fn conv2d_im2col_q8_raw_ctx(
    x: &TensorT<i8>,
    w: &TensorT<i8>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> TensorT<i32> {
    assert_eq!(x.rank(), 4);
    assert_eq!(w.rank(), 4);
    let (n, c_in, h, win) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (c_out, c_in_g, kh, kw) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
    let g = p.groups;
    assert!(g >= 1 && c_in % g == 0 && c_out % g == 0, "bad groups {g}");
    assert_eq!(c_in / g, c_in_g);
    assert!(
        c_in_g * kh * kw <= crate::kernels::rowconv::Q8_MAX_TAPS,
        "int8 conv with {} taps could overflow the i32 accumulator",
        c_in_g * kh * kw
    );
    let (oh, ow) = p.out_size(h, win, kh, kw);
    let (c_out_g, ohw) = (c_out / g, oh * ow);
    let kdim = c_in_g * kh * kw;

    let mut out = TensorT::<i32>::zeros(&[n, c_out, oh, ow]);
    let ws = w.as_slice();
    ctx.par_chunks_with(
        out.as_mut_slice(),
        c_out_g * ohw,
        || ctx.take_elems_unfilled::<i8>(kdim * ohw),
        |item, cblk, col| {
            let (ni, grp) = (item / g, item % g);
            im2col_plane(x, ni, grp * c_in_g, c_in_g, kh, kw, p, oh, ow, col);
            let wmat = &ws[grp * c_out_g * kdim..(grp + 1) * c_out_g * kdim];
            gemm_q8(c_out_g, kdim, ohw, wmat, col, cblk);
        },
        |col| ctx.put_elems(col),
    );
    out
}

/// [`conv2d_im2col_q8_raw_ctx`] with dequantized `f32` output
/// (`· x_scale · w_scale`, plus the f32 `bias`). Both quantizations
/// must be symmetric.
pub fn conv2d_im2col_q8_ctx(
    x: &TensorT<i8>,
    xq: QuantParams,
    w: &TensorT<i8>,
    wq: QuantParams,
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> Tensor {
    if let Some(b) = bias {
        assert_eq!(b.len(), w.dim(0), "bias length");
    }
    let raw = conv2d_im2col_q8_raw_ctx(x, w, p, ctx);
    dequantize_conv_acc(&raw, xq, &WeightScales::PerTensor(wq), bias, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::direct::conv2d_direct;

    fn against_direct(xdims: &[usize], wdims: &[usize], p: &Conv2dParams, seed: u64) {
        let x = Tensor::randn(xdims, seed);
        let w = Tensor::randn(wdims, seed + 1);
        let bias: Vec<f32> = (0..wdims[0]).map(|i| i as f32 * 0.1).collect();
        let y = conv2d_im2col(&x, &w, Some(&bias), p);
        let y_ref = conv2d_direct(&x, &w, Some(&bias), p);
        let d = y.max_abs_diff(&y_ref);
        assert!(d < 1e-3, "{xdims:?} {wdims:?} {p:?}: diff {d}");
    }

    #[test]
    fn matches_direct_basic() {
        against_direct(&[1, 3, 8, 8], &[4, 3, 3, 3], &Conv2dParams::default(), 11);
    }

    #[test]
    fn matches_direct_padded() {
        against_direct(&[2, 2, 7, 9], &[3, 2, 5, 5], &Conv2dParams::same(5), 12);
    }

    #[test]
    fn matches_direct_strided() {
        let p = Conv2dParams { stride: (2, 3), pad: (1, 2), groups: 1 };
        against_direct(&[1, 4, 11, 13], &[2, 4, 3, 5], &p, 13);
    }

    #[test]
    fn matches_direct_grouped() {
        let p = Conv2dParams { stride: (1, 1), pad: (1, 1), groups: 2 };
        against_direct(&[1, 4, 6, 6], &[6, 2, 3, 3], &p, 14);
    }

    #[test]
    fn matches_direct_depthwise() {
        let p = Conv2dParams { stride: (1, 1), pad: (0, 0), groups: 4 };
        against_direct(&[1, 4, 6, 6], &[4, 1, 3, 3], &p, 15);
    }

    #[test]
    fn matches_direct_1x1_pointwise() {
        against_direct(&[1, 8, 5, 5], &[16, 8, 1, 1], &Conv2dParams::default(), 16);
    }

    #[test]
    fn matches_direct_wide_filter() {
        against_direct(&[1, 1, 4, 40], &[1, 1, 3, 21], &Conv2dParams::default(), 17);
    }

    #[test]
    fn bloat_metric() {
        // k=5 on 3 channels, 28x28 output: col is 75x784 floats.
        assert_eq!(im2col_bytes(3, 5, 5, 28, 28), 75 * 784 * 4);
    }

    #[test]
    fn strip_width_is_bounded_and_panel_aligned() {
        // Small kdim: capped by the byte budget.
        let s = lowmem_strip_cols(800);
        assert_eq!(s, (256 << 10) / (800 * 4));
        // Huge kdim: clamped up to one GEMM panel.
        assert_eq!(lowmem_strip_cols(1 << 24), NR);
        assert!(lowmem_strip_cols(0) >= NR, "degenerate kdim stays total");
    }

    /// The low-memory strip kernel is **bit-identical** to the one-shot
    /// im2col kernel (not merely close): strip GEMM reproduces the full
    /// call's per-element FP accumulation sequence. kdim is chosen large
    /// enough that the strip is narrower than `oh·ow`, so multiple
    /// strips (including a ragged tail) are actually exercised.
    #[test]
    fn lowmem_matches_oneshot_bitwise_f32() {
        let p = Conv2dParams::same(5);
        let x = Tensor::randn(&[2, 32, 12, 12], 31);
        let w = Tensor::randn(&[4, 32, 5, 5], 32);
        let kdim = 32 * 5 * 5;
        assert!(lowmem_strip_cols(kdim) < 144, "test must span several strips");
        let bias: Vec<f32> = (0..4).map(|i| i as f32 * 0.3 - 0.5).collect();
        for threads in [1usize, 4] {
            let ctx = ExecCtx::with_threads(crate::kernels::ConvAlgo::Im2colGemm, threads);
            for relu in [false, true] {
                let epi = Epilogue::from_bias(Some(&bias)).with_relu(relu);
                let full = conv2d_im2col_epi_ctx(&x, &w, epi, &p, &ctx);
                let strip = conv2d_im2col_lowmem_epi_ctx(&x, &w, epi, &p, &ctx);
                assert_eq!(
                    full.as_slice(),
                    strip.as_slice(),
                    "threads={threads} relu={relu}"
                );
            }
        }
    }

    #[test]
    fn lowmem_matches_oneshot_bitwise_f32_strided_grouped() {
        let p = Conv2dParams { stride: (2, 3), pad: (1, 2), groups: 2 };
        let x = Tensor::randn(&[1, 4, 11, 13], 33);
        let w = Tensor::randn(&[6, 2, 3, 5], 34);
        let ctx = ExecCtx::default();
        let full = conv2d_im2col_epi_ctx(&x, &w, Epilogue::from_bias(None), &p, &ctx);
        let strip = conv2d_im2col_lowmem_epi_ctx(&x, &w, Epilogue::from_bias(None), &p, &ctx);
        assert_eq!(full.as_slice(), strip.as_slice());
    }

    /// Strip width 1: a 1x1 output plane clamps the strip to a single
    /// column (`lowmem_strip_cols(..).min(ohw)`), so every GEMM call
    /// sees a one-column B panel. Also checks [`im2col_strip`] at
    /// `len = 1` against the full expansion, column by column, on a
    /// padded + strided case where per-column addressing matters.
    #[test]
    fn strip_width_one_column() {
        // ohw == 1: valid conv where the filter covers the whole input.
        let p = Conv2dParams::default();
        let x = Tensor::randn(&[1, 3, 4, 4], 41);
        let w = Tensor::randn(&[2, 3, 4, 4], 42);
        let ctx = ExecCtx::default();
        let full = conv2d_im2col_epi_ctx(&x, &w, Epilogue::from_bias(None), &p, &ctx);
        let strip = conv2d_im2col_lowmem_epi_ctx(&x, &w, Epilogue::from_bias(None), &p, &ctx);
        assert_eq!(full.dims(), &[1, 2, 1, 1]);
        assert_eq!(full.as_slice(), strip.as_slice());

        // One-column expansions tile the full column matrix exactly.
        let p = Conv2dParams { stride: (2, 1), pad: (1, 2), groups: 1 };
        let x = Tensor::randn(&[1, 2, 5, 6], 43);
        let (kh, kw) = (3, 3);
        let (oh, ow) = p.out_size(5, 6, kh, kw);
        let kdim = 2 * kh * kw;
        let mut whole = vec![0.0f32; kdim * oh * ow];
        im2col_plane(&x, 0, 0, 2, kh, kw, &p, oh, ow, &mut whole);
        let mut col = vec![0.0f32; kdim];
        for j in 0..oh * ow {
            im2col_strip(&x, 0, 0, 2, kh, kw, &p, ow, j, 1, &mut col);
            for r in 0..kdim {
                assert_eq!(col[r], whole[r * oh * ow + j], "row {r} col {j}");
            }
        }
    }

    /// Strip >= total columns: with a tiny kdim the budgeted strip far
    /// exceeds `oh·ow`, so the low-memory path degenerates to a single
    /// full-width strip per (image, group) — and must still be
    /// bit-identical, not just on multi-strip shapes.
    #[test]
    fn single_strip_covers_all_columns() {
        let p = Conv2dParams::same(3);
        let x = Tensor::randn(&[2, 2, 9, 9], 44);
        let w = Tensor::randn(&[3, 2, 3, 3], 45);
        assert!(
            lowmem_strip_cols(2 * 3 * 3) >= 81,
            "strip must cover the whole output plane"
        );
        for threads in [1usize, 4] {
            let ctx = ExecCtx::with_threads(crate::kernels::ConvAlgo::Im2colGemm, threads);
            let full = conv2d_im2col_epi_ctx(&x, &w, Epilogue::from_bias(None), &p, &ctx);
            let strip = conv2d_im2col_lowmem_epi_ctx(&x, &w, Epilogue::from_bias(None), &p, &ctx);
            assert_eq!(full.as_slice(), strip.as_slice(), "threads={threads}");
        }
    }

    /// Non-divisible remainder: `oh·ow % strip != 0`, so the last strip
    /// of every (image, group) is ragged — narrower than the budgeted
    /// width — and its zero-padded GEMM panels must not leak into the
    /// output.
    #[test]
    fn ragged_tail_strip() {
        let p = Conv2dParams::same(5);
        let x = Tensor::randn(&[1, 26, 14, 13], 46);
        let w = Tensor::randn(&[3, 26, 5, 5], 47);
        let kdim = 26 * 5 * 5;
        let (ohw, strip) = (14 * 13, lowmem_strip_cols(kdim));
        assert!(strip < ohw, "must span several strips");
        assert_ne!(ohw % strip, 0, "tail strip must be ragged");
        let ctx = ExecCtx::default();
        let full = conv2d_im2col_epi_ctx(&x, &w, Epilogue::from_bias(None), &p, &ctx);
        let strip = conv2d_im2col_lowmem_epi_ctx(&x, &w, Epilogue::from_bias(None), &p, &ctx);
        assert_eq!(full.as_slice(), strip.as_slice());
    }

    #[test]
    fn lowmem_matches_oneshot_bitwise_q8() {
        let p = Conv2dParams::same(3);
        let xf = Tensor::randn(&[2, 40, 10, 10], 35);
        let wf = Tensor::randn(&[5, 40, 3, 3], 36);
        let xq = QuantParams::for_tensor(&xf);
        let wq = QuantParams::for_tensor(&wf);
        let x = crate::tensor::quantize(&xf, xq);
        let w = crate::tensor::quantize(&wf, wq);
        for threads in [1usize, 3] {
            let ctx = ExecCtx::with_threads(crate::kernels::ConvAlgo::Im2colGemm, threads);
            let full = conv2d_im2col_q8_raw_ctx(&x, &w, &p, &ctx);
            let strip = conv2d_im2col_lowmem_q8_raw_ctx(&x, &w, &p, &ctx);
            assert_eq!(full.as_slice(), strip.as_slice(), "threads={threads}");
        }
    }
}
