//! Blocked, register-tiled single-precision GEMM.
//!
//! This is the substrate of the `im2col` convolution baseline — our
//! stand-in for the highly tuned GEMM inside ONNX Runtime's `MlasConv`
//! (which the paper measures against). The structure follows the classic
//! BLIS/MLAS design so that the *memory behaviour* of the baseline is
//! faithful:
//!
//! * `KC × NR` panels of `B` packed contiguously,
//! * `MR × KC` strips of `A` packed contiguously,
//! * an `MR × NR` register micro-kernel (`MR = 8` rows × `NR = 32` columns
//!   = 16 accumulator vectors) running rank-1 updates from the packed
//!   panels.
//!
//! Loop order: `kc` (K blocking) → `mc` (M blocking) → `jr` (NR panels) →
//! `ir` (MR strips) → micro-kernel. Packing buffers are reused across
//! calls — via thread-locals in [`sgemm`], or caller-provided (arena)
//! scratch in [`sgemm_with_scratch`] — to keep allocation off the hot
//! path.

use super::epilogue::Epilogue;
use crate::simd::{F32xL, LANES};
use std::cell::RefCell;

/// Micro-kernel rows.
pub const MR: usize = 8;
/// Micro-kernel columns (two hardware vectors).
pub const NR: usize = 2 * LANES;
/// K-dimension cache block.
pub const KC: usize = 256;
/// M-dimension cache block.
pub const MC: usize = 64;

thread_local! {
    static PACK_A: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    static PACK_B: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Packing-buffer length for `A` strips (independent of the problem size).
pub fn pack_a_len() -> usize {
    MC.div_ceil(MR) * MR * KC
}

/// Packing-buffer length for `B` panels of an `N`-column GEMM.
pub fn pack_b_len(n: usize) -> usize {
    n.div_ceil(NR) * NR * KC
}

/// [`sgemm_with_scratch`] with a fused output [`Epilogue`]: after the
/// blocked product, bias (row `r` of `C` gets `epi.bias[row0 + r]`) and
/// the optional ReLU are applied over `C` while it is still
/// cache-resident, instead of as separate full-matrix memory passes.
/// A no-op epilogue leaves `C` byte-identical to the plain GEMM.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_epi_with_scratch(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    pa: &mut Vec<f32>,
    pb: &mut Vec<f32>,
    epi: Epilogue<'_>,
    row0: usize,
) {
    sgemm_with_scratch(m, k, n, a, b, c, pa, pb);
    epi.apply_rows(c, m, n, row0);
}

/// `C += A · B` for row-major `A[M×K]`, `B[K×N]`, `C[M×N]`.
///
/// `C` must be pre-initialised (zeros for a plain product); the routine
/// accumulates into it. Packing scratch comes from thread-locals; the
/// `exec` subsystem's parallel regions call [`sgemm_with_scratch`] with
/// arena buffers instead — pool workers are long-lived now, but their
/// thread-locals would still pin one packing buffer per worker for the
/// pool's lifetime, while arena scratch is shared, accounted and
/// trimmable.
///
/// # Panics
/// If any slice is shorter than its shape requires.
pub fn sgemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    PACK_A.with(|pa| {
        PACK_B.with(|pb| {
            sgemm_with_scratch(m, k, n, a, b, c, &mut pa.borrow_mut(), &mut pb.borrow_mut())
        })
    });
}

/// [`sgemm`] with caller-provided packing scratch (resized as needed to
/// [`pack_a_len`] / [`pack_b_len`] elements).
#[allow(clippy::too_many_arguments)]
pub fn sgemm_with_scratch(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    pa: &mut Vec<f32>,
    pb: &mut Vec<f32>,
) {
    assert!(a.len() >= m * k, "A too short");
    assert!(b.len() >= k * n, "B too short");
    assert!(c.len() >= m * n, "C too short");
    if m == 0 || k == 0 || n == 0 {
        return;
    }

    let n_panels = n.div_ceil(NR);
    pa.resize(pack_a_len(), 0.0);
    pb.resize(pack_b_len(n), 0.0);

    let mut kb = 0;
    while kb < k {
        let kc = KC.min(k - kb);
        pack_b(pb, b, kb, kc, n);
        let mut mb = 0;
        while mb < m {
            let mc = MC.min(m - mb);
            pack_a(pa, a, mb, mc, kb, kc, k);
            // Panels of C.
            for jp in 0..n_panels {
                let j0 = jp * NR;
                let nr = NR.min(n - j0);
                for ip in 0..mc.div_ceil(MR) {
                    let i0 = mb + ip * MR;
                    let mr = MR.min(m - i0);
                    micro_kernel(
                        kc,
                        &pa[ip * MR * KC..],
                        &pb[jp * NR * KC..],
                        c,
                        i0,
                        j0,
                        mr,
                        nr,
                        n,
                    );
                }
            }
            mb += mc;
        }
        kb += kc;
    }
}

/// Pack `B[kb..kb+kc, :]` into `NR`-wide column panels, p-major inside a
/// panel, zero-padding ragged right edges.
fn pack_b(pb: &mut [f32], b: &[f32], kb: usize, kc: usize, n: usize) {
    let n_panels = n.div_ceil(NR);
    for jp in 0..n_panels {
        let j0 = jp * NR;
        let nr = NR.min(n - j0);
        let dst = &mut pb[jp * NR * KC..];
        for p in 0..kc {
            let src = &b[(kb + p) * n + j0..(kb + p) * n + j0 + nr];
            let d = &mut dst[p * NR..p * NR + NR];
            d[..nr].copy_from_slice(src);
            d[nr..].fill(0.0);
        }
    }
}

/// Pack `A[mb..mb+mc, kb..kb+kc]` into `MR`-tall row strips, p-major
/// inside a strip, zero-padding ragged bottom edges.
fn pack_a(pa: &mut [f32], a: &[f32], mb: usize, mc: usize, kb: usize, kc: usize, k: usize) {
    for ip in 0..mc.div_ceil(MR) {
        let i0 = mb + ip * MR;
        let mr = MR.min(mb + mc - i0);
        let dst = &mut pa[ip * MR * KC..];
        for p in 0..kc {
            let d = &mut dst[p * MR..p * MR + MR];
            for r in 0..MR {
                d[r] = if r < mr { a[(i0 + r) * k + (kb + p)] } else { 0.0 };
            }
        }
    }
}

/// `MR × NR` register tile: `C[i0.., j0..] += strip(A) · panel(B)`.
///
/// Full-size tiles store straight through vector stores; ragged edges go
/// through a scalar tail.
#[allow(clippy::too_many_arguments)]
#[inline]
fn micro_kernel(
    kc: usize,
    pa: &[f32],
    pb: &[f32],
    c: &mut [f32],
    i0: usize,
    j0: usize,
    mr: usize,
    nr: usize,
    ldc: usize,
) {
    // PERF: the accumulators must be *named locals*, not an indexed
    // array — LLVM keeps indexed arrays on the stack, turning every FMA
    // into a load+fma+store round-trip (measured 3.5 GFLOP/s vs ~14 with
    // registers; EXPERIMENTS.md §Perf). With 16 named zmm accumulators
    // plus two B vectors and one broadcast this fits the 32-register
    // AVX-512 file exactly like the BLIS/MLAS kernels do.
    macro_rules! kernel_body {
        ($($a0:ident $a1:ident),+) => {{
            $(let mut $a0 = F32xL::zero(); let mut $a1 = F32xL::zero();)+
            let mut ap = pa.chunks_exact(MR);
            let mut bp = pb.chunks_exact(NR);
            for _ in 0..kc {
                let a = ap.next().unwrap();
                let b = bp.next().unwrap();
                let b0 = F32xL::load(b);
                let b1 = F32xL::load(&b[LANES..]);
                let mut r = 0;
                $(
                    let av = F32xL::splat(a[r]);
                    $a0 = av.mul_add(b0, $a0);
                    $a1 = av.mul_add(b1, $a1);
                    r += 1;
                )+
                let _ = r;
            }
            let acc: [[F32xL; 2]; MR] = [$([$a0, $a1]),+];
            acc
        }};
    }
    let acc = kernel_body!(a00 a01, a10 a11, a20 a21, a30 a31, a40 a41, a50 a51, a60 a61, a70 a71);

    if mr == MR && nr == NR {
        for (r, acc_r) in acc.iter().enumerate() {
            let row = &mut c[(i0 + r) * ldc + j0..];
            let v0 = F32xL::load(&row[..LANES]) + acc_r[0];
            let v1 = F32xL::load(&row[LANES..2 * LANES]) + acc_r[1];
            v0.store(row);
            v1.store(&mut row[LANES..]);
        }
    } else {
        for r in 0..mr {
            let row = &mut c[(i0 + r) * ldc + j0..];
            for j in 0..nr {
                let v = if j < LANES { acc[r][0].0[j] } else { acc[r][1].0[j - LANES] };
                row[j] += v;
            }
        }
    }
}

/// Integer GEMM for the int8 convolution baseline:
/// `C += A · B` for row-major `A[M×K]` (i8), `B[K×N]` (i8),
/// `C[M×N]` (i32, exact accumulation).
///
/// Deliberately simpler than [`sgemm`]: an `i·p·j` loop with a
/// unit-stride inner over `N` that LLVM autovectorizes (widening
/// `i8 → i32` multiply-adds). What the int8 im2col baseline pays for —
/// and what the quantized sliding kernel avoids — is *materialising and
/// re-streaming the `k²`-bloated column matrix*, which this loop order
/// reproduces faithfully: every row of `A` streams the whole packed
/// column matrix `B`. Because i32 accumulation is exact, loop order
/// does not affect the result bit-wise.
///
/// # Panics
/// If any slice is shorter than its shape requires.
pub fn gemm_q8(m: usize, k: usize, n: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
    assert!(a.len() >= m * k, "A too short");
    assert!(b.len() >= k * n, "B too short");
    assert!(c.len() >= m * n, "C too short");
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &ap) in arow.iter().enumerate() {
            let av = ap as i32;
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv as i32;
            }
        }
    }
}

/// Reference scalar GEMM for tests.
pub fn sgemm_ref(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            for j in 0..n {
                c[i * n + j] += av * b[p * n + j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::XorShiftRng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = XorShiftRng::new(seed);
        (0..n).map(|_| r.uniform(-1.0, 1.0)).collect()
    }

    fn check(m: usize, k: usize, n: usize) {
        let a = rand_vec(m * k, 1 + m as u64);
        let b = rand_vec(k * n, 2 + n as u64);
        let mut c = vec![0.0; m * n];
        let mut c_ref = vec![0.0; m * n];
        sgemm(m, k, n, &a, &b, &mut c);
        sgemm_ref(m, k, n, &a, &b, &mut c_ref);
        for i in 0..m * n {
            assert!(
                (c[i] - c_ref[i]).abs() < 1e-3 * (1.0 + c_ref[i].abs()),
                "({m},{k},{n}) idx {i}: {} vs {}",
                c[i],
                c_ref[i]
            );
        }
    }

    #[test]
    fn exact_tile_sizes() {
        check(MR, KC, NR);
        check(2 * MR, 8, 2 * NR);
    }

    #[test]
    fn ragged_everything() {
        check(1, 1, 1);
        check(3, 5, 7);
        check(MR + 3, KC + 10, NR + 5);
        check(MC + 9, 17, NR - 1);
    }

    #[test]
    fn tall_skinny_and_wide() {
        check(200, 9, 4);
        check(4, 9, 200);
        check(1, 300, 65);
    }

    #[test]
    fn accumulates_into_c() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 4.0];
        let mut c = vec![10.0; 4]; // 2x2
        sgemm(2, 1, 2, &a, &b, &mut c);
        assert_eq!(c, vec![13.0, 14.0, 16.0, 18.0]);
    }

    #[test]
    fn gemm_q8_matches_scalar_reference() {
        let (m, k, n) = (5usize, 9usize, 37usize);
        let mut r = XorShiftRng::new(77);
        let a: Vec<i8> = (0..m * k).map(|_| r.uniform(-127.0, 127.0) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|_| r.uniform(-127.0, 127.0) as i8).collect();
        let mut c = vec![3i32; m * n];
        gemm_q8(m, k, n, &a, &b, &mut c);
        for i in 0..m {
            for j in 0..n {
                let want: i32 = 3 + (0..k)
                    .map(|p| a[i * k + p] as i32 * b[p * n + j] as i32)
                    .sum::<i32>();
                assert_eq!(c[i * n + j], want, "({i},{j})");
            }
        }
    }

    #[test]
    fn zero_dims_are_noop() {
        let mut c = vec![5.0];
        sgemm(0, 3, 1, &[], &[0.0; 3], &mut c);
        sgemm(1, 0, 1, &[], &[], &mut c);
        assert_eq!(c, vec![5.0]);
    }
}
