//! 2-D Sliding Window convolution — the paper's headline contribution.
//!
//! A 2-D convolution is evaluated as a vertical accumulation of 1-D
//! vector-slide row convolutions: for output row `oy`, each filter row
//! `ky` contributes a 1-D convolution of padded input row `oy + ky`.
//! The input is traversed exactly once per filter row, in row-major
//! streaming order, and **no intermediate matrix is materialised** —
//! contrast `im2col`, which copies every window (a `kh·kw ×` blow-up)
//! before its GEMM. Arithmetic-operation count is identical to
//! GEMM/direct; the speedup comes from the memory access pattern
//! (paper §2).
//!
//! The row kernel is chosen by [`SlideVariant`]:
//! * `Auto` — tuned selection: when the [`ExecCtx`] carries a measured
//!   [`crate::autotune::DispatchProfile`], the profile's winner for this
//!   filter width and thread count; otherwise the paper's policy
//!   (custom kernels for k = 3 and 5, the generic in-vector kernel up
//!   to k = 17, compound vectors beyond).
//! * `Generic` / `Compound` — forced, for the ablation studies
//!   (custom-vs-generic, and the k = 17 crossover where the compound
//!   kernel beats the in-vector one).

use super::direct::conv2d_direct_epi_ctx;
use super::epilogue::Epilogue;
use super::rowconv::{
    row_conv_bf16_at, row_conv_q8_at, RowKernel, COMPOUND_MAX_K, GENERIC_MAX_K, Q8_MAX_TAPS,
};
use super::Conv2dParams;
use crate::exec::ExecCtx;
use crate::simd::LANES;
use crate::tensor::{
    pad2d_into, padded2d_size, Bf16, QuantParams, Tensor, TensorT, WeightScales,
};

/// Which row kernel the 2-D sliding convolution uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlideVariant {
    /// The ctx's measured profile winner when one is attached
    /// ([`crate::exec::ExecCtx::tuned_row_kernel`]); the paper's §2
    /// policy — custom (k=3,5) → generic (k≤17) → compound — otherwise.
    Auto,
    /// Force the straightforward in-vector Vector Slide (k ≤ 17).
    Generic,
    /// Force the compound-vector kernel (any k ≤ [`COMPOUND_MAX_K`]).
    Compound,
}

impl SlideVariant {
    /// Whether this variant can evaluate filter width `k`.
    pub fn supports(self, k: usize) -> bool {
        match self {
            SlideVariant::Auto => k <= COMPOUND_MAX_K,
            SlideVariant::Generic => k <= GENERIC_MAX_K,
            SlideVariant::Compound => k <= COMPOUND_MAX_K,
        }
    }

}

/// 2-D convolution via the Sliding Window kernels (same contract as
/// [`super::direct::conv2d_direct`]).
///
/// Filter widths the variant cannot handle fall back to the direct
/// kernel (only possible beyond [`COMPOUND_MAX_K`] with `Auto`).
///
/// # Panics
/// If `variant` is forced (`Generic`/`Compound`) and cannot handle `kw`.
pub fn conv2d_sliding(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    variant: SlideVariant,
) -> Tensor {
    crate::exec::with_thread_ctx(crate::kernels::ConvAlgo::Sliding, |ctx| {
        conv2d_sliding_ctx(x, w, bias, p, variant, ctx)
    })
}

/// [`conv2d_sliding`] with an execution context: the padded input and the
/// per-worker row accumulator come from the ctx's scratch arena (zero
/// steady-state allocations), and output planes `(n, c_out)` fan out over
/// the ctx's threads. Per-plane arithmetic is identical for every thread
/// count, so results are bit-identical to the single-threaded kernel.
pub fn conv2d_sliding_ctx(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    variant: SlideVariant,
    ctx: &ExecCtx,
) -> Tensor {
    conv2d_sliding_epi_ctx(x, w, Epilogue::from_bias(bias), p, variant, ctx)
}

/// [`conv2d_sliding_ctx`] with a fused output [`Epilogue`]: the bias
/// seeds the row accumulator exactly as in the unfused kernel, and a
/// requested ReLU is applied at the output write — `max(v, 0.0)` on the
/// stored value, bit-identical to running a separate ReLU pass over the
/// unfused output, without the extra read+write of the activation
/// tensor.
pub fn conv2d_sliding_epi_ctx(
    x: &Tensor,
    w: &Tensor,
    epi: Epilogue<'_>,
    p: &Conv2dParams,
    variant: SlideVariant,
    ctx: &ExecCtx,
) -> Tensor {
    let bias = epi.bias;
    assert_eq!(x.rank(), 4, "input must be NCHW");
    assert_eq!(w.rank(), 4, "weights must be [cout, cin/g, kh, kw]");
    let (n, c_in, h, win) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (c_out, c_in_g, kh, kw) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
    let g = p.groups;
    assert!(g >= 1 && c_in % g == 0 && c_out % g == 0, "bad groups {g}");
    assert_eq!(c_in / g, c_in_g, "weight c_in/{g} mismatch");
    if let Some(b) = bias {
        assert_eq!(b.len(), c_out, "bias length");
    }
    if !variant.supports(kw) {
        match variant {
            SlideVariant::Auto => return conv2d_direct_epi_ctx(x, w, epi, p, ctx),
            _ => panic!("{variant:?} cannot evaluate filter width {kw}"),
        }
    }
    let (oh, ow) = p.out_size(h, win, kh, kw);
    let (sh, sw) = p.stride;
    // Unit-stride geometry; strided outputs subsample it.
    let ow1 = win + 2 * p.pad.1 - kw + 1;
    // Auto resolves the row family once per conv, not per row call: the
    // ctx's tuned winner for (kw, threads), or the paper's §2 policy
    // when no profile is attached — the same functions `row_conv_auto`
    // dispatches to, so an unprofiled Auto is bit-identical to the
    // pre-autotune kernel. Every variant resolves at the ctx's ISA
    // level; the intrinsic kernels are bit-identical to the portable
    // ones, so the level never changes results.
    let row_fn = match variant {
        SlideVariant::Auto => ctx.tuned_row_kernel(kw).row_fn_at(kw, ctx.isa()),
        SlideVariant::Generic => RowKernel::Generic.row_fn_at(kw, ctx.isa()),
        SlideVariant::Compound => RowKernel::Compound.row_fn_at(kw, ctx.isa()),
    };

    // Pad once into arena scratch: convolution padding plus vector-load
    // slack on the right.
    let (hp, wp) = padded2d_size(h, win, p.pad.0, p.pad.1, 2 * LANES + kw);
    let mut padded = ctx.take(n * c_in * hp * wp, 0.0);
    pad2d_into(x, p.pad.0, p.pad.1, 2 * LANES + kw, &mut padded);

    let ws = w.as_slice();
    let c_out_g = c_out / g;
    let mut out = Tensor::zeros(&[n, c_out, oh, ow]);
    let padded_ref: &[f32] = &padded;
    // Per-worker row accumulator: checked out of the arena once per
    // parallel region (not per output plane), so steady-state arena
    // traffic is deterministic and allocation-free.
    ctx.par_chunks_with(
        out.as_mut_slice(),
        oh * ow,
        || ctx.take_unfilled(ow1),
        |item, oplane, scratch| {
            let (ni, co) = (item / c_out, item % c_out);
            let grp = co / c_out_g;
            let b = bias.map_or(0.0, |b| b[co]);
            for oy in 0..oh {
                let iy0 = oy * sh;
                scratch.fill(b);
                for cig in 0..c_in_g {
                    let ci = grp * c_in_g + cig;
                    let plane =
                        &padded_ref[(ni * c_in + ci) * hp * wp..(ni * c_in + ci + 1) * hp * wp];
                    for ky in 0..kh {
                        let src = &plane[(iy0 + ky) * wp..];
                        let wrow = &ws[((co * c_in_g + cig) * kh + ky) * kw..][..kw];
                        row_fn(src, wrow, scratch, ow1);
                    }
                }
                let orow = &mut oplane[oy * ow..oy * ow + ow];
                if epi.relu {
                    for (ox, v) in orow.iter_mut().enumerate() {
                        *v = scratch[if sw == 1 { ox } else { ox * sw }].max(0.0);
                    }
                } else if sw == 1 {
                    orow.copy_from_slice(&scratch[..ow]);
                } else {
                    for (ox, v) in orow.iter_mut().enumerate() {
                        *v = scratch[ox * sw];
                    }
                }
            }
        },
        |scratch| ctx.put(scratch),
    );
    ctx.put(padded);
    out
}

/// Validate the shared NCHW/weight geometry and return
/// `(n, c_in, h, w, c_out, c_in_g, kh, kw)`.
fn conv2d_geometry<A: crate::tensor::Element, B: crate::tensor::Element>(
    x: &TensorT<A>,
    w: &TensorT<B>,
    p: &Conv2dParams,
) -> (usize, usize, usize, usize, usize, usize, usize, usize) {
    assert_eq!(x.rank(), 4, "input must be NCHW");
    assert_eq!(w.rank(), 4, "weights must be [cout, cin/g, kh, kw]");
    let (n, c_in, h, win) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (c_out, c_in_g, kh, kw) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
    let g = p.groups;
    assert!(g >= 1 && c_in % g == 0 && c_out % g == 0, "bad groups {g}");
    assert_eq!(c_in / g, c_in_g, "weight c_in/{g} mismatch");
    (n, c_in, h, win, c_out, c_in_g, kh, kw)
}

/// Quantized int8 2-D sliding convolution, **raw accumulator** output.
///
/// `x` and `w` hold i8 codes under *symmetric* per-tensor quantization
/// (the caller's [`QuantParams`] with `zero_point == 0`; zero padding is
/// then the code 0). The output is the exact i32 accumulator
/// `Σ x_code · w_code` per tap — dequantize with
/// `x_scale · w_scale` (see [`conv2d_sliding_q8_ctx`]). Because the
/// accumulation is exact integer arithmetic, this agrees **bit for
/// bit** with the int8 im2col+GEMM baseline
/// ([`super::im2col::conv2d_im2col_q8_raw_ctx`]) — the speedup
/// comparison between the two is purely about memory access pattern.
///
/// Same parallel/scratch structure as [`conv2d_sliding_ctx`]: the i8
/// padded input and the per-worker i32 row accumulator come from the
/// ctx's (dtype-generic) arena; output planes fan out over its threads.
/// [`super::rowconv::row_conv_q8`] covers every filter width (the ISA
/// dispatch picks an exact intrinsic equivalent when one is available,
/// see [`row_conv_q8_at`]), so there is no variant
/// parameter and no direct fallback.
pub fn conv2d_sliding_q8_raw_ctx(
    x: &TensorT<i8>,
    w: &TensorT<i8>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> TensorT<i32> {
    let (n, c_in, h, win, c_out, c_in_g, kh, kw) = conv2d_geometry(x, w, p);
    assert!(
        c_in_g * kh * kw <= Q8_MAX_TAPS,
        "int8 conv with {} taps could overflow the i32 accumulator (max {Q8_MAX_TAPS})",
        c_in_g * kh * kw
    );
    let (oh, ow) = p.out_size(h, win, kh, kw);
    let (sh, sw) = p.stride;
    let ow1 = win + 2 * p.pad.1 - kw + 1;

    let (hp, wp) = padded2d_size(h, win, p.pad.0, p.pad.1, 2 * LANES + kw);
    let mut padded: Vec<i8> = ctx.take_elems(n * c_in * hp * wp, 0i8);
    pad2d_into(x, p.pad.0, p.pad.1, 2 * LANES + kw, &mut padded);

    let ws = w.as_slice();
    let c_out_g = c_out / p.groups;
    let mut out = TensorT::<i32>::zeros(&[n, c_out, oh, ow]);
    let padded_ref: &[i8] = &padded;
    let row_fn = row_conv_q8_at(ctx.isa());
    ctx.par_chunks_with(
        out.as_mut_slice(),
        oh * ow,
        || ctx.take_elems_unfilled::<i32>(ow1),
        |item, oplane, scratch| {
            let (ni, co) = (item / c_out, item % c_out);
            let grp = co / c_out_g;
            for oy in 0..oh {
                let iy0 = oy * sh;
                scratch.fill(0);
                for cig in 0..c_in_g {
                    let ci = grp * c_in_g + cig;
                    let plane =
                        &padded_ref[(ni * c_in + ci) * hp * wp..(ni * c_in + ci + 1) * hp * wp];
                    for ky in 0..kh {
                        let src = &plane[(iy0 + ky) * wp..];
                        let wrow = &ws[((co * c_in_g + cig) * kh + ky) * kw..][..kw];
                        row_fn(src, wrow, scratch, ow1);
                    }
                }
                let orow = &mut oplane[oy * ow..oy * ow + ow];
                if sw == 1 {
                    orow.copy_from_slice(&scratch[..ow]);
                } else {
                    for (ox, v) in orow.iter_mut().enumerate() {
                        *v = scratch[ox * sw];
                    }
                }
            }
        },
        |scratch| ctx.put_elems(scratch),
    );
    ctx.put_elems(padded);
    out
}

/// `(c_out, inner)` extraction shared by the accumulator epilogues:
/// accepts the two conv output layouts, `[n, c_out, oh, ow]` (rank 4)
/// and `[c_out, lo]` (rank 2).
fn acc_channel_geometry(raw: &TensorT<i32>) -> (usize, usize) {
    match raw.rank() {
        4 => (raw.dim(1), raw.dim(2) * raw.dim(3)),
        2 => (raw.dim(0), raw.dim(1)),
        r => panic!("conv accumulator epilogue expects rank 4 or rank 2, got rank {r}"),
    }
}

/// Dequantize a raw i32 convolution accumulator:
/// `out = raw · (x_scale · w_scale[c_out]) + bias`, then an optional
/// fused ReLU. Shared by every int8 path — 2-D sliding, 2-D im2col and
/// 1-D sliding — so their f32 outputs agree exactly too. The weight
/// scales may be per-tensor or per-output-channel
/// ([`WeightScales`]); `relu` applies `max(v, 0.0)` to the stored
/// value, bit-identical to a separate ReLU pass over the unfused
/// output.
pub(crate) fn dequantize_conv_acc(
    raw: &TensorT<i32>,
    xq: QuantParams,
    wq: &WeightScales,
    bias: Option<&[f32]>,
    relu: bool,
) -> Tensor {
    assert!(
        xq.is_symmetric() && wq.is_symmetric(),
        "int8 conv kernels require symmetric quantization (zero_point == 0)"
    );
    let (c_out, inner) = acc_channel_geometry(raw);
    let mut out = Tensor::zeros(raw.dims());
    let rs = raw.as_slice();
    for (i, (o, &r)) in out.as_mut_slice().iter_mut().zip(rs).enumerate() {
        let co = (i / inner) % c_out;
        let b = bias.map_or(0.0, |b| b[co]);
        let v = r as f32 * (xq.scale * wq.scale(co)) + b;
        *o = if relu { v.max(0.0) } else { v };
    }
    out
}

/// The quantize-boundary epilogue: dequantize a raw i32 convolution
/// accumulator and **re-quantize the result to i8 codes directly**,
/// without materialising the f32 activation tensor in between.
///
/// Streaming two-pass over the accumulator: pass 1 computes the f32
/// value each element *would* dequantize to and folds its magnitude
/// into a max (starting from `0.0`, exactly like
/// [`crate::tensor::TensorT::max_abs`]); pass 2 quantizes every value
/// under the resulting symmetric [`QuantParams`]. Because each pass
/// evaluates the *identical* f32 expression the unfused path stores
/// (`raw · x_scale · w_scale[c_out] + bias`, then the optional ReLU),
/// the returned codes and params are bit-equivalent to
/// `dequantize → [relu →] QuantParams::for_tensor → quantize` — the
/// hoisting pass changes memory traffic, never values.
pub(crate) fn quantize_conv_acc(
    raw: &TensorT<i32>,
    xq: QuantParams,
    wq: &WeightScales,
    bias: Option<&[f32]>,
    relu: bool,
) -> (TensorT<i8>, QuantParams) {
    assert!(
        xq.is_symmetric() && wq.is_symmetric(),
        "int8 conv kernels require symmetric quantization (zero_point == 0)"
    );
    let (c_out, inner) = acc_channel_geometry(raw);
    let rs = raw.as_slice();
    let value = |i: usize, r: i32| -> f32 {
        let co = (i / inner) % c_out;
        let b = bias.map_or(0.0, |b| b[co]);
        let v = r as f32 * (xq.scale * wq.scale(co)) + b;
        if relu {
            v.max(0.0)
        } else {
            v
        }
    };
    let mut max_abs = 0.0f32;
    for (i, &r) in rs.iter().enumerate() {
        max_abs = max_abs.max(value(i, r).abs());
    }
    let q = QuantParams::symmetric(max_abs);
    let mut codes = vec![0i8; raw.numel()];
    for (i, (c, &r)) in codes.iter_mut().zip(rs).enumerate() {
        *c = q.quantize_value(value(i, r));
    }
    (TensorT::from_vec(codes, raw.dims()), q)
}

/// Quantized int8 2-D sliding convolution with dequantized `f32`
/// output: [`conv2d_sliding_q8_raw_ctx`] followed by the shared
/// per-tensor dequant (`· x_scale · w_scale`, plus the f32 `bias`).
///
/// Both quantizations must be symmetric ([`QuantParams::is_symmetric`]).
pub fn conv2d_sliding_q8_ctx(
    x: &TensorT<i8>,
    xq: QuantParams,
    w: &TensorT<i8>,
    wq: QuantParams,
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> Tensor {
    if let Some(b) = bias {
        assert_eq!(b.len(), w.dim(0), "bias length");
    }
    let raw = conv2d_sliding_q8_raw_ctx(x, w, p, ctx);
    dequantize_conv_acc(&raw, xq, &WeightScales::PerTensor(wq), bias, false)
}

/// bfloat16 2-D sliding convolution: bf16 storage in and out, f32
/// accumulation inside ([`super::rowconv::row_conv_bf16`], or its
/// intrinsic equivalent via [`row_conv_bf16_at`]).
///
/// The padded input stays bf16 (half the streaming traffic of the f32
/// kernel); the weight tensor is widened to f32 once per call into
/// arena scratch; the per-worker row accumulator is f32; each output
/// value rounds back to bf16 storage. Covers every filter width (no
/// register-pair constraint), same parallel structure as
/// [`conv2d_sliding_ctx`].
pub fn conv2d_sliding_bf16_ctx(
    x: &TensorT<Bf16>,
    w: &TensorT<Bf16>,
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> TensorT<Bf16> {
    let (n, c_in, h, win, c_out, c_in_g, kh, kw) = conv2d_geometry(x, w, p);
    if let Some(b) = bias {
        assert_eq!(b.len(), c_out, "bias length");
    }
    let (oh, ow) = p.out_size(h, win, kh, kw);
    let (sh, sw) = p.stride;
    let ow1 = win + 2 * p.pad.1 - kw + 1;

    let (hp, wp) = padded2d_size(h, win, p.pad.0, p.pad.1, 2 * LANES + kw);
    let mut padded: Vec<Bf16> = ctx.take_elems(n * c_in * hp * wp, Bf16::ZERO);
    pad2d_into(x, p.pad.0, p.pad.1, 2 * LANES + kw, &mut padded);

    // Widen the weights once per conv (they are small and reused by
    // every output plane).
    let mut wf: Vec<f32> = ctx.take_elems_unfilled(w.numel());
    for (d, s) in wf.iter_mut().zip(w.as_slice()) {
        *d = s.to_f32();
    }

    let c_out_g = c_out / p.groups;
    let mut out = TensorT::<Bf16>::zeros(&[n, c_out, oh, ow]);
    let padded_ref: &[Bf16] = &padded;
    let wf_ref: &[f32] = &wf;
    let row_fn = row_conv_bf16_at(ctx.isa());
    ctx.par_chunks_with(
        out.as_mut_slice(),
        oh * ow,
        || ctx.take_elems_unfilled::<f32>(ow1),
        |item, oplane, scratch| {
            let (ni, co) = (item / c_out, item % c_out);
            let grp = co / c_out_g;
            let b = bias.map_or(0.0, |b| b[co]);
            for oy in 0..oh {
                let iy0 = oy * sh;
                scratch.fill(b);
                for cig in 0..c_in_g {
                    let ci = grp * c_in_g + cig;
                    let plane =
                        &padded_ref[(ni * c_in + ci) * hp * wp..(ni * c_in + ci + 1) * hp * wp];
                    for ky in 0..kh {
                        let src = &plane[(iy0 + ky) * wp..];
                        let wrow = &wf_ref[((co * c_in_g + cig) * kh + ky) * kw..][..kw];
                        row_fn(src, wrow, scratch, ow1);
                    }
                }
                let orow = &mut oplane[oy * ow..oy * ow + ow];
                for (ox, v) in orow.iter_mut().enumerate() {
                    *v = Bf16::from_f32(scratch[if sw == 1 { ox } else { ox * sw }]);
                }
            }
        },
        |scratch| ctx.put_elems(scratch),
    );
    ctx.put_elems(wf);
    ctx.put_elems(padded);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::direct::conv2d_direct;

    fn against_direct(
        xdims: &[usize],
        wdims: &[usize],
        p: &Conv2dParams,
        variant: SlideVariant,
        seed: u64,
    ) {
        let x = Tensor::randn(xdims, seed);
        let w = Tensor::randn(wdims, seed + 1);
        let bias: Vec<f32> = (0..wdims[0]).map(|i| 0.05 * i as f32).collect();
        let got = conv2d_sliding(&x, &w, Some(&bias), p, variant);
        let want = conv2d_direct(&x, &w, Some(&bias), p);
        let d = got.max_abs_diff(&want);
        assert!(d < 2e-3, "{xdims:?} {wdims:?} {p:?} {variant:?}: diff {d}");
    }

    #[test]
    fn auto_matches_direct_small_filters() {
        for k in [1, 2, 3, 4, 5, 7] {
            against_direct(
                &[1, 2, 10, 12],
                &[3, 2, k, k],
                &Conv2dParams::default(),
                SlideVariant::Auto,
                40 + k as u64,
            );
        }
    }

    #[test]
    fn auto_matches_direct_generic_range() {
        for k in [9, 16, 17] {
            against_direct(
                &[1, 1, 20, 40],
                &[2, 1, 3, k],
                &Conv2dParams::default(),
                SlideVariant::Auto,
                50 + k as u64,
            );
        }
    }

    #[test]
    fn auto_matches_direct_compound_range() {
        for k in [18, 24, 33, 49] {
            against_direct(
                &[1, 1, 8, 80],
                &[1, 1, 2, k],
                &Conv2dParams::default(),
                SlideVariant::Auto,
                60 + k as u64,
            );
        }
    }

    #[test]
    fn forced_generic_matches() {
        against_direct(
            &[1, 2, 9, 30],
            &[2, 2, 3, 3],
            &Conv2dParams::default(),
            SlideVariant::Generic,
            70,
        );
    }

    #[test]
    fn forced_compound_matches_even_small_k() {
        against_direct(
            &[1, 2, 9, 30],
            &[2, 2, 5, 5],
            &Conv2dParams::default(),
            SlideVariant::Compound,
            71,
        );
    }

    #[test]
    fn crossover_width_17_both_variants_agree() {
        // k=17 can be evaluated by either kernel family — the paper's
        // crossover observation. Both must be exact.
        for v in [SlideVariant::Generic, SlideVariant::Compound] {
            against_direct(&[1, 1, 6, 64], &[1, 1, 1, 17], &Conv2dParams::default(), v, 72);
        }
    }

    #[test]
    fn padded_same_matches() {
        against_direct(
            &[2, 3, 13, 13],
            &[4, 3, 5, 5],
            &Conv2dParams::same(5),
            SlideVariant::Auto,
            73,
        );
    }

    #[test]
    fn strided_matches() {
        let p = Conv2dParams { stride: (2, 2), pad: (1, 1), groups: 1 };
        against_direct(&[1, 3, 12, 14], &[2, 3, 3, 3], &p, SlideVariant::Auto, 74);
    }

    #[test]
    fn grouped_and_depthwise_match() {
        let p = Conv2dParams { stride: (1, 1), pad: (1, 1), groups: 2 };
        against_direct(&[1, 4, 8, 8], &[6, 2, 3, 3], &p, SlideVariant::Auto, 75);
        let dw = Conv2dParams { stride: (1, 1), pad: (2, 2), groups: 8 };
        against_direct(&[1, 8, 9, 9], &[8, 1, 5, 5], &dw, SlideVariant::Auto, 76);
    }

    #[test]
    fn tall_filter_rows_accumulate() {
        against_direct(
            &[1, 1, 30, 10],
            &[1, 1, 11, 3],
            &Conv2dParams::default(),
            SlideVariant::Auto,
            77,
        );
    }

    #[test]
    fn huge_width_falls_back_to_direct() {
        against_direct(
            &[1, 1, 3, 160],
            &[1, 1, 1, COMPOUND_MAX_K + 5],
            &Conv2dParams::default(),
            SlideVariant::Auto,
            78,
        );
    }

    /// A profiled ctx steers `Auto` to the measured row family: forcing
    /// compound through the profile must match the forced-compound
    /// variant bit for bit (and an unprofiled ctx must keep matching
    /// the paper policy — covered by the dispatch tests).
    #[test]
    fn auto_with_profile_uses_tuned_row_family() {
        use crate::autotune::{DispatchProfile, ProfileEntry, TunedAlgo};
        use crate::exec::ExecCtx;
        use crate::kernels::rowconv::RowKernel;
        use crate::tensor::Dtype;
        use std::sync::Arc;

        let x = Tensor::randn(&[1, 2, 9, 30], 90);
        let w = Tensor::randn(&[2, 2, 5, 5], 91);
        let p = Conv2dParams::default();
        let profile = DispatchProfile::from_entries(vec![ProfileEntry {
            k: 5,
            threads: 1,
            dtype: Dtype::F32,
            isa: crate::simd::IsaLevel::Scalar,
            algo: TunedAlgo::Sliding,
            slide: RowKernel::Compound,
            gflops: 1.0,
        }]);
        let ctx = ExecCtx::new(crate::kernels::ConvAlgo::Sliding)
            .with_profile(Arc::new(profile));
        let tuned = conv2d_sliding_ctx(&x, &w, None, &p, SlideVariant::Auto, &ctx);
        let forced = conv2d_sliding(&x, &w, None, &p, SlideVariant::Compound);
        assert_eq!(tuned.as_slice(), forced.as_slice());
    }

    #[test]
    #[should_panic(expected = "cannot evaluate")]
    fn forced_generic_rejects_wide_filters() {
        let x = Tensor::zeros(&[1, 1, 4, 40]);
        let w = Tensor::zeros(&[1, 1, 1, 20]);
        let _ = conv2d_sliding(&x, &w, None, &Conv2dParams::default(), SlideVariant::Generic);
    }
}
