//! The fused kernel epilogue — what the graph compiler's epilogue-fusion
//! pass threads into the convolution/GEMM output write.
//!
//! The paper's thesis is that convolution on commodity CPUs is
//! memory-bound; a separate bias-add or ReLU layer pays a full extra
//! read+write of the activation tensor for a trivial amount of
//! arithmetic. An [`Epilogue`] folds both into the kernel's *existing*
//! output write:
//!
//! * **bias** rides wherever the kernel already seeds or adds it —
//!   pre-accumulation for the sliding/direct kernels (the row
//!   accumulator is `fill`ed with the bias), post-GEMM for the im2col
//!   path (added over the cache-resident output block).
//! * **ReLU** is applied by [`Epilogue::activate`] at the single point
//!   where each output value is stored.
//!
//! Bit-exactness contract: `max(v, 0.0)` applied at the write site is
//! the *same* floating-point operation a standalone ReLU layer applies
//! to the stored value, so a fused kernel is bit-identical to the
//! unfused kernel followed by a ReLU pass. (The epilogue deliberately
//! does **not** live inside the row kernels of
//! [`super::rowconv`] — a row call produces *partial* sums that later
//! filter rows and channels still accumulate into; activation is only
//! legal once the accumulation is complete, i.e. at the output write.)

/// Fused output epilogue for the convolution/GEMM kernels: optional
/// per-output-channel bias and an optional ReLU, applied in the
/// kernel's output write instead of as separate memory passes.
#[derive(Clone, Copy, Debug, Default)]
pub struct Epilogue<'a> {
    /// Per-output-channel bias `[c_out]` (added exactly where the
    /// unfused kernel adds it).
    pub bias: Option<&'a [f32]>,
    /// Apply `max(v, 0.0)` to every output value at the write site.
    pub relu: bool,
}

impl<'a> Epilogue<'a> {
    /// Bias-only epilogue — what the pre-existing kernel entry points
    /// (bias parameter, no activation) wrap themselves in.
    pub fn from_bias(bias: Option<&'a [f32]>) -> Self {
        Epilogue { bias, relu: false }
    }

    /// Same epilogue with the ReLU flag set.
    pub fn with_relu(self, relu: bool) -> Self {
        Epilogue { relu, ..self }
    }

    /// True when the epilogue changes nothing (no bias, no activation).
    pub fn is_noop(&self) -> bool {
        self.bias.is_none() && !self.relu
    }

    /// Activation half of the epilogue: `max(v, 0.0)` when `relu` is
    /// set, identity otherwise. Bias is *not* applied here — each
    /// kernel adds it where its unfused variant always has.
    #[inline(always)]
    pub fn activate(&self, v: f32) -> f32 {
        if self.relu {
            v.max(0.0)
        } else {
            v
        }
    }

    /// Post-GEMM application over a row-major `[rows, cols]` output
    /// block whose row `r` is output channel `row0 + r` (the im2col
    /// path: bias and activation folded over the cache-resident block,
    /// before it ever leaves L2). When the epilogue is a no-op the
    /// block is untouched — bit-identical to the unfused path.
    pub fn apply_rows(&self, c: &mut [f32], rows: usize, cols: usize, row0: usize) {
        if self.is_noop() {
            return;
        }
        for r in 0..rows {
            let row = &mut c[r * cols..(r + 1) * cols];
            match (self.bias, self.relu) {
                (Some(b), true) => {
                    let bv = b[row0 + r];
                    for v in row.iter_mut() {
                        *v = (*v + bv).max(0.0);
                    }
                }
                (Some(b), false) => {
                    let bv = b[row0 + r];
                    for v in row.iter_mut() {
                        *v += bv;
                    }
                }
                (None, true) => {
                    for v in row.iter_mut() {
                        *v = v.max(0.0);
                    }
                }
                (None, false) => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_detection() {
        assert!(Epilogue::from_bias(None).is_noop());
        assert!(!Epilogue::from_bias(None).with_relu(true).is_noop());
        let b = [1.0];
        assert!(!Epilogue::from_bias(Some(&b)).is_noop());
    }

    #[test]
    fn activate_clamps_only_with_relu() {
        let plain = Epilogue::from_bias(None);
        assert_eq!(plain.activate(-2.0), -2.0);
        let relu = plain.with_relu(true);
        assert_eq!(relu.activate(-2.0), 0.0);
        assert_eq!(relu.activate(3.0), 3.0);
    }

    #[test]
    fn apply_rows_matches_manual() {
        let bias = [1.0, -10.0];
        let mut c = vec![1.0, -2.0, 3.0, 4.0];
        Epilogue::from_bias(Some(&bias)).with_relu(true).apply_rows(&mut c, 2, 2, 0);
        assert_eq!(c, vec![2.0, 0.0, 0.0, 0.0]);

        let mut c2 = vec![-1.0, 2.0];
        Epilogue::from_bias(None).with_relu(true).apply_rows(&mut c2, 1, 2, 0);
        assert_eq!(c2, vec![0.0, 2.0]);
    }

    #[test]
    fn apply_rows_respects_row_offset() {
        let bias = [0.0, 0.0, 5.0];
        let mut c = vec![1.0, 1.0];
        Epilogue::from_bias(Some(&bias)).apply_rows(&mut c, 1, 2, 2);
        assert_eq!(c, vec![6.0, 6.0]);
    }
}
