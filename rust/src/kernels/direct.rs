//! Naïve direct convolution — the correctness oracle every other kernel is
//! tested against, and the "same arithmetic-operation count" baseline the
//! paper compares memory behaviour with.
//!
//! Seven nested scalar loops, no blocking, no vectorisation hints. It
//! performs exactly `2 · N · Cout · OH · OW · (Cin/g) · kh · kw` FLOPs —
//! the same count as GEMM and sliding convolution (paper §2: "the number
//! of arithmetic operations performed by the sliding convolution is the
//! same as the naïve or GEMM-based algorithms").

use super::epilogue::Epilogue;
use super::{Conv1dParams, Conv2dParams};
use crate::exec::ExecCtx;
use crate::tensor::Tensor;

/// Direct 2-D convolution (cross-correlation, DNN convention).
///
/// * `x` — input `[n, c_in, h, w]`
/// * `w` — weights `[c_out, c_in / groups, kh, kw]`
/// * `bias` — optional `[c_out]`
///
/// Returns `[n, c_out, oh, ow]`.
///
/// # Panics
/// On any shape inconsistency.
pub fn conv2d_direct(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv2dParams,
) -> Tensor {
    crate::exec::with_thread_ctx(crate::kernels::ConvAlgo::Direct, |ctx| {
        conv2d_direct_ctx(x, w, bias, p, ctx)
    })
}

/// [`conv2d_direct`] with an execution context: output planes `(n, c_out)`
/// are independent work items fanned out over the ctx's threads.
pub fn conv2d_direct_ctx(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> Tensor {
    conv2d_direct_epi_ctx(x, w, Epilogue::from_bias(bias), p, ctx)
}

/// [`conv2d_direct_ctx`] with a fused output [`Epilogue`]: bias seeds
/// the accumulator exactly as in the unfused kernel, a requested ReLU
/// is applied to each value as it is stored (bit-identical to a
/// separate ReLU pass).
pub fn conv2d_direct_epi_ctx(
    x: &Tensor,
    w: &Tensor,
    epi: Epilogue<'_>,
    p: &Conv2dParams,
    ctx: &ExecCtx,
) -> Tensor {
    let bias = epi.bias;
    assert_eq!(x.rank(), 4, "input must be NCHW");
    assert_eq!(w.rank(), 4, "weights must be [cout, cin/g, kh, kw]");
    let (n, c_in, h, win) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (c_out, c_in_g, kh, kw) = (w.dim(0), w.dim(1), w.dim(2), w.dim(3));
    let g = p.groups;
    assert!(g >= 1 && c_in % g == 0 && c_out % g == 0, "bad groups {g}");
    assert_eq!(c_in / g, c_in_g, "weight c_in/{g} mismatch");
    if let Some(b) = bias {
        assert_eq!(b.len(), c_out, "bias length");
    }
    let (oh, ow) = p.out_size(h, win, kh, kw);
    let (sh, sw) = p.stride;
    let (ph, pw) = p.pad;

    let mut out = Tensor::zeros(&[n, c_out, oh, ow]);
    ctx.par_chunks(out.as_mut_slice(), oh * ow, |item, oplane| {
        let (ni, co) = (item / c_out, item % c_out);
        let grp = co / (c_out / g);
        let b = bias.map_or(0.0, |b| b[co]);
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = b;
                for cig in 0..c_in_g {
                    let ci = grp * c_in_g + cig;
                    for ky in 0..kh {
                        let iy = oy * sh + ky;
                        if iy < ph || iy >= h + ph {
                            continue;
                        }
                        for kx in 0..kw {
                            let ix = ox * sw + kx;
                            if ix < pw || ix >= win + pw {
                                continue;
                            }
                            acc += x.at4(ni, ci, iy - ph, ix - pw)
                                * w.at4(co, cig, ky, kx);
                        }
                    }
                }
                oplane[oy * ow + ox] = epi.activate(acc);
            }
        }
    });
    out
}

/// Direct 1-D convolution.
///
/// * `x` — `[c_in, l]`
/// * `w` — `[c_out, c_in, k]`
///
/// Returns `[c_out, l_out]`.
pub fn conv1d_direct(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv1dParams,
) -> Tensor {
    crate::exec::with_thread_ctx(crate::kernels::ConvAlgo::Direct, |ctx| {
        conv1d_direct_ctx(x, w, bias, p, ctx)
    })
}

/// [`conv1d_direct`] with an execution context: output rows are
/// independent work items fanned out over the ctx's threads.
pub fn conv1d_direct_ctx(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&[f32]>,
    p: &Conv1dParams,
    ctx: &ExecCtx,
) -> Tensor {
    conv1d_direct_epi_ctx(x, w, Epilogue::from_bias(bias), p, ctx)
}

/// [`conv1d_direct_ctx`] with a fused output [`Epilogue`] (same
/// contract as [`conv2d_direct_epi_ctx`]).
pub fn conv1d_direct_epi_ctx(
    x: &Tensor,
    w: &Tensor,
    epi: Epilogue<'_>,
    p: &Conv1dParams,
    ctx: &ExecCtx,
) -> Tensor {
    let bias = epi.bias;
    assert_eq!(x.rank(), 2, "input must be [c, l]");
    assert_eq!(w.rank(), 3, "weights must be [cout, cin, k]");
    let (c_in, l) = (x.dim(0), x.dim(1));
    let (c_out, c_in_w, k) = (w.dim(0), w.dim(1), w.dim(2));
    assert_eq!(c_in, c_in_w, "c_in mismatch");
    let lo = p.out_len(l, k);

    let xs = x.as_slice();
    let ws = w.as_slice();
    let mut out = Tensor::zeros(&[c_out, lo]);
    ctx.par_chunks(out.as_mut_slice(), lo, |co, orow| {
        let b = bias.map_or(0.0, |b| b[co]);
        for (o, ov) in orow.iter_mut().enumerate() {
            let mut acc = b;
            for ci in 0..c_in {
                for j in 0..k {
                    let i = o * p.stride + j;
                    if i < p.pad || i >= l + p.pad {
                        continue;
                    }
                    acc += xs[ci * l + i - p.pad] * ws[(co * c_in + ci) * k + j];
                }
            }
            *ov = epi.activate(acc);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 1x1x3x3 input, 1x1x2x2 ones filter: each output is the window sum.
    #[test]
    fn conv2d_window_sums() {
        let x = Tensor::iota(&[1, 1, 3, 3]);
        let w = Tensor::full(&[1, 1, 2, 2], 1.0);
        let y = conv2d_direct(&x, &w, None, &Conv2dParams::default());
        assert_eq!(y.dims(), &[1, 1, 2, 2]);
        // windows: [0,1,3,4]=8, [1,2,4,5]=12, [3,4,6,7]=20, [4,5,7,8]=24
        assert_eq!(y.as_slice(), &[8.0, 12.0, 20.0, 24.0]);
    }

    #[test]
    fn conv2d_bias_added() {
        let x = Tensor::zeros(&[1, 1, 2, 2]);
        let w = Tensor::full(&[2, 1, 1, 1], 1.0);
        let y = conv2d_direct(&x, &w, Some(&[1.5, -2.0]), &Conv2dParams::default());
        assert_eq!(y.at4(0, 0, 0, 0), 1.5);
        assert_eq!(y.at4(0, 1, 1, 1), -2.0);
    }

    #[test]
    fn conv2d_padding_zero_border() {
        // 1x1 input, 3x3 ones filter, same padding: output = input value.
        let x = Tensor::full(&[1, 1, 1, 1], 4.0);
        let w = Tensor::full(&[1, 1, 3, 3], 1.0);
        let y = conv2d_direct(&x, &w, None, &Conv2dParams::same(3));
        assert_eq!(y.dims(), &[1, 1, 1, 1]);
        assert_eq!(y.as_slice()[0], 4.0);
    }

    #[test]
    fn conv2d_stride_subsamples() {
        let x = Tensor::iota(&[1, 1, 4, 4]);
        let w = Tensor::full(&[1, 1, 1, 1], 1.0);
        let p = Conv2dParams { stride: (2, 2), pad: (0, 0), groups: 1 };
        let y = conv2d_direct(&x, &w, None, &p);
        assert_eq!(y.dims(), &[1, 1, 2, 2]);
        assert_eq!(y.as_slice(), &[0.0, 2.0, 8.0, 10.0]);
    }

    #[test]
    fn conv2d_depthwise_groups() {
        // 2 channels, groups=2: each output channel sees only its input.
        let mut x = Tensor::zeros(&[1, 2, 1, 2]);
        x.as_mut_slice().copy_from_slice(&[1.0, 2.0, 10.0, 20.0]);
        let w = Tensor::full(&[2, 1, 1, 1], 1.0);
        let p = Conv2dParams { stride: (1, 1), pad: (0, 0), groups: 2 };
        let y = conv2d_direct(&x, &w, None, &p);
        assert_eq!(y.as_slice(), &[1.0, 2.0, 10.0, 20.0]);
    }

    #[test]
    fn conv2d_multichannel_sums_channels() {
        let x = Tensor::full(&[1, 3, 2, 2], 1.0);
        let w = Tensor::full(&[1, 3, 2, 2], 1.0);
        let y = conv2d_direct(&x, &w, None, &Conv2dParams::default());
        assert_eq!(y.as_slice(), &[12.0]); // 3 channels * 4 taps
    }

    #[test]
    fn conv1d_basic() {
        let x = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[1, 4]);
        let w = Tensor::from_vec(vec![1.0, -1.0], &[1, 1, 2]);
        let y = conv1d_direct(&x, &w, None, &Conv1dParams::default());
        assert_eq!(y.as_slice(), &[-1.0, -1.0, -1.0]);
    }

    #[test]
    fn conv1d_padded_stride() {
        let x = Tensor::from_vec(vec![1.0, 1.0, 1.0], &[1, 3]);
        let w = Tensor::from_vec(vec![1.0, 1.0, 1.0], &[1, 1, 3]);
        let p = Conv1dParams { stride: 2, pad: 1 };
        let y = conv1d_direct(&x, &w, None, &p);
        // padded signal 0 1 1 1 0; windows at 0 and 2: [0,1,1]=2, [1,1,0]=2
        assert_eq!(y.as_slice(), &[2.0, 2.0]);
    }
}
