//! Pooling as a Sliding Window Sum (the paper's abstract: "both pooling
//! and convolution 1-D primitives could be expressed as sliding sums and
//! evaluated by compute kernels with a shared structure").
//!
//! Horizontal pooling over a row is the log-step sliding combine —
//! `O(log k)` vector ops per output vector instead of `k − 1` — followed
//! by a vertical elementwise combine across `kh` rows. Max pooling uses
//! the same kernel with `max` as the combiner (idempotent, so the
//! doubling decomposition is trivially valid); average pooling runs the
//! sum kernel and scales by `1/(kh·kw)` (padding counted, the ONNX
//! `count_include_pad` convention).

use crate::exec::ExecCtx;
use crate::simd::{slide_dyn, F32xL, LANES};
use crate::tensor::{pad2d_into, padded2d_size, Bf16, Tensor, TensorT};

/// Pooling hyper-parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolParams {
    /// Window `(kh, kw)`.
    pub k: (usize, usize),
    /// Stride `(sh, sw)`; `None` in constructors means stride = window.
    pub stride: (usize, usize),
    /// Padding `(ph, pw)`.
    pub pad: (usize, usize),
}

impl PoolParams {
    /// Square window with stride = window (the common non-overlapping case).
    pub fn square(k: usize) -> Self {
        PoolParams { k: (k, k), stride: (k, k), pad: (0, 0) }
    }

    /// Square window with explicit stride.
    pub fn with_stride(k: usize, s: usize) -> Self {
        PoolParams { k: (k, k), stride: (s, s), pad: (0, 0) }
    }

    /// Output spatial size.
    pub fn out_size(&self, h: usize, w: usize) -> (usize, usize) {
        let hp = h + 2 * self.pad.0;
        let wp = w + 2 * self.pad.1;
        assert!(hp >= self.k.0 && wp >= self.k.1, "pool window larger than input");
        ((hp - self.k.0) / self.stride.0 + 1, (wp - self.k.1) / self.stride.1 + 1)
    }
}

/// The combiner a sliding pool kernel uses.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Combine {
    Sum,
    Max,
}

impl Combine {
    #[inline(always)]
    pub(crate) fn vec(self, a: F32xL, b: F32xL) -> F32xL {
        match self {
            Combine::Sum => a + b,
            Combine::Max => a.max(b),
        }
    }

    #[inline(always)]
    pub(crate) fn scalar(self, a: f32, b: f32) -> f32 {
        match self {
            Combine::Sum => a + b,
            Combine::Max => a.max(b),
        }
    }

    pub(crate) fn identity(self) -> f32 {
        match self {
            Combine::Sum => 0.0,
            Combine::Max => f32::NEG_INFINITY,
        }
    }
}

/// Log-step sliding combine over one padded row.
///
/// `dst[i] = op(src[i], src[i+1], …, src[i+k-1])` built by doubling —
/// the shared structure of the paper's sum/max/avg kernels. Requires
/// `k ≤ LANES` (callers fall back to the serial loop beyond; pooling
/// windows that large do not occur in practice).
pub(crate) fn sliding_combine_row(
    src: &[f32],
    k: usize,
    dst: &mut [f32],
    out_len: usize,
    op: Combine,
) {
    debug_assert!(k >= 1);
    if k > LANES {
        for i in 0..out_len {
            let mut acc = src[i];
            for j in 1..k {
                acc = op.scalar(acc, src[i + j]);
            }
            dst[i] = acc;
        }
        return;
    }
    debug_assert!(out_len == 0 || src.len() >= out_len - 1 + k - 1 + 3 * LANES);
    let mut i = 0;
    while i + LANES <= out_len {
        let x0 = F32xL::load(&src[i..]);
        let x1 = F32xL::load(&src[i + LANES..]);
        let x2 = F32xL::load(&src[i + 2 * LANES..]);
        let (mut s0, mut s1, mut s2) = (x0, x1, x2);
        let mut width = 1usize;
        let bits = usize::BITS - k.leading_zeros();
        for bit in (0..bits - 1).rev() {
            let t0 = op.vec(s0, slide_dyn(s0, s1, width));
            let t1 = op.vec(s1, slide_dyn(s1, s2, width));
            let t2 = op.vec(s2, slide_dyn(s2, s2, width));
            (s0, s1, s2) = (t0, t1, t2);
            width *= 2;
            if (k >> bit) & 1 == 1 {
                let t0 = op.vec(s0, slide_dyn(x0, x1, width));
                let t1 = op.vec(s1, slide_dyn(x1, x2, width));
                (s0, s1) = (t0, t1);
                width += 1;
            }
        }
        debug_assert_eq!(width, k);
        s0.store(&mut dst[i..]);
        i += LANES;
    }
    for o in i..out_len {
        let mut acc = src[o];
        for j in 1..k {
            acc = op.scalar(acc, src[o + j]);
        }
        dst[o] = acc;
    }
}

/// Shared 2-D pooling skeleton: horizontal sliding combine per input row,
/// then vertical combine across `kh` rows, then stride subsampling.
/// Channel planes `(n, c)` are independent work items fanned out over the
/// ctx's threads; all buffers come from the ctx's scratch arena.
fn pool2d_sliding(x: &Tensor, p: &PoolParams, op: Combine, ctx: &ExecCtx) -> Tensor {
    assert_eq!(x.rank(), 4, "pooling expects NCHW");
    let (n, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (kh, kw) = p.k;
    let (oh, ow) = p.out_size(h, w);
    let (sh, sw) = p.stride;
    let ow1 = w + 2 * p.pad.1 - kw + 1;

    let (hp, wp) = padded2d_size(h, w, p.pad.0, p.pad.1, 3 * LANES + kw);
    let mut padded = ctx.take(n * c * hp * wp, op.identity());
    pad2d_into(x, p.pad.0, p.pad.1, 3 * LANES + kw, &mut padded);

    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    let padded_ref: &[f32] = &padded;
    // Per-worker scratch (horizontal rows + vertical accumulator): one
    // arena checkout per parallel region, so steady-state arena traffic
    // is deterministic and allocation-free.
    ctx.par_chunks_with(
        out.as_mut_slice(),
        oh * ow,
        || (ctx.take_unfilled(hp * ow1), ctx.take_unfilled(ow1)),
        |item, oplane, (hrows, acc)| {
            let plane = &padded_ref[item * hp * wp..(item + 1) * hp * wp];
            // Horizontal results for every padded input row of this plane.
            for iy in 0..hp {
                sliding_combine_row(
                    &plane[iy * wp..],
                    kw,
                    &mut hrows[iy * ow1..(iy + 1) * ow1],
                    ow1,
                    op,
                );
            }
            for oy in 0..oh {
                let iy0 = oy * sh;
                // Vertical combine of kh horizontal rows (vectorises as a
                // simple elementwise loop over the row).
                acc.copy_from_slice(&hrows[iy0 * ow1..(iy0 + 1) * ow1]);
                for ky in 1..kh {
                    let row = &hrows[(iy0 + ky) * ow1..(iy0 + ky + 1) * ow1];
                    for (a, &r) in acc.iter_mut().zip(row.iter()) {
                        *a = op.scalar(*a, r);
                    }
                }
                let orow = &mut oplane[oy * ow..oy * ow + ow];
                for (ox, v) in orow.iter_mut().enumerate() {
                    *v = acc[ox * sw];
                }
            }
        },
        |(hrows, acc)| {
            ctx.put(hrows);
            ctx.put(acc);
        },
    );
    ctx.put(padded);
    out
}

/// Max pooling via the sliding-window kernel.
pub fn max_pool2d(x: &Tensor, p: &PoolParams) -> Tensor {
    crate::exec::with_thread_ctx(crate::kernels::ConvAlgo::Sliding, |ctx| {
        max_pool2d_ctx(x, p, ctx)
    })
}

/// [`max_pool2d`] with an execution context (threads + scratch arena).
pub fn max_pool2d_ctx(x: &Tensor, p: &PoolParams, ctx: &ExecCtx) -> Tensor {
    pool2d_sliding(x, p, Combine::Max, ctx)
}

/// Average pooling via the sliding-window sum kernel
/// (`count_include_pad = true`).
pub fn avg_pool2d(x: &Tensor, p: &PoolParams) -> Tensor {
    crate::exec::with_thread_ctx(crate::kernels::ConvAlgo::Sliding, |ctx| {
        avg_pool2d_ctx(x, p, ctx)
    })
}

/// [`avg_pool2d`] with an execution context (threads + scratch arena).
pub fn avg_pool2d_ctx(x: &Tensor, p: &PoolParams, ctx: &ExecCtx) -> Tensor {
    let inv = 1.0 / (p.k.0 * p.k.1) as f32;
    let mut y = pool2d_sliding(x, p, Combine::Sum, ctx);
    for v in y.as_mut_slice() {
        *v *= inv;
    }
    y
}

/// Quantized int8 max pooling: i8 codes in, i8 codes out, same
/// [`PoolParams`] contract as [`max_pool2d_ctx`].
///
/// `max` commutes with any monotone code mapping (the affine dequant
/// has positive scale), so pooling the **codes** is exactly pooling the
/// reals — no dequantize/requantize round-trip, no accumulator, and the
/// quantization parameters pass through unchanged. Padding is
/// `i8::MIN` (the code-domain −∞). The horizontal window runs a simple
/// `O(k)` max per output (`vpmaxsb` saturates the port width without a
/// log-step ladder at these window sizes); planes fan out over the
/// ctx's threads with per-worker arena scratch like every other kernel.
pub fn max_pool2d_q8_ctx(x: &TensorT<i8>, p: &PoolParams, ctx: &ExecCtx) -> TensorT<i8> {
    assert_eq!(x.rank(), 4, "pooling expects NCHW");
    let (n, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (kh, kw) = p.k;
    let (oh, ow) = p.out_size(h, w);
    let (sh, sw) = p.stride;
    let ow1 = w + 2 * p.pad.1 - kw + 1;

    let (hp, wp) = padded2d_size(h, w, p.pad.0, p.pad.1, 0);
    let mut padded: Vec<i8> = ctx.take_elems(n * c * hp * wp, i8::MIN);
    pad2d_into(x, p.pad.0, p.pad.1, 0, &mut padded);

    let mut out = TensorT::<i8>::zeros(&[n, c, oh, ow]);
    let padded_ref: &[i8] = &padded;
    ctx.par_chunks_with(
        out.as_mut_slice(),
        oh * ow,
        || (ctx.take_elems_unfilled::<i8>(hp * ow1), ctx.take_elems_unfilled::<i8>(ow1)),
        |item, oplane, (hrows, acc)| {
            let plane = &padded_ref[item * hp * wp..(item + 1) * hp * wp];
            for iy in 0..hp {
                let src = &plane[iy * wp..iy * wp + wp];
                for (ox, d) in hrows[iy * ow1..(iy + 1) * ow1].iter_mut().enumerate() {
                    *d = src[ox..ox + kw].iter().copied().max().expect("kw >= 1");
                }
            }
            for oy in 0..oh {
                let iy0 = oy * sh;
                acc.copy_from_slice(&hrows[iy0 * ow1..(iy0 + 1) * ow1]);
                for ky in 1..kh {
                    let row = &hrows[(iy0 + ky) * ow1..(iy0 + ky + 1) * ow1];
                    for (a, &r) in acc.iter_mut().zip(row.iter()) {
                        *a = (*a).max(r);
                    }
                }
                let orow = &mut oplane[oy * ow..oy * ow + ow];
                for (ox, v) in orow.iter_mut().enumerate() {
                    *v = acc[ox * sw];
                }
            }
        },
        |(hrows, acc)| {
            ctx.put_elems(hrows);
            ctx.put_elems(acc);
        },
    );
    ctx.put_elems(padded);
    out
}

/// Shared bf16 2-D pooling skeleton: bf16 storage traffic, f32
/// combine. Each padded row widens into a per-worker f32 buffer, the
/// f32 log-step [`sliding_combine_row`] runs unchanged (the "shared
/// structure" of the paper's pooling argument), and outputs round back
/// to bf16.
fn pool2d_sliding_bf16(
    x: &TensorT<Bf16>,
    p: &PoolParams,
    op: Combine,
    ctx: &ExecCtx,
) -> TensorT<Bf16> {
    assert_eq!(x.rank(), 4, "pooling expects NCHW");
    let (n, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (kh, kw) = p.k;
    let (oh, ow) = p.out_size(h, w);
    let (sh, sw) = p.stride;
    let ow1 = w + 2 * p.pad.1 - kw + 1;

    let (hp, wp) = padded2d_size(h, w, p.pad.0, p.pad.1, 3 * LANES + kw);
    let mut padded: Vec<Bf16> = ctx.take_elems(n * c * hp * wp, Bf16::from_f32(op.identity()));
    pad2d_into(x, p.pad.0, p.pad.1, 3 * LANES + kw, &mut padded);

    let mut out = TensorT::<Bf16>::zeros(&[n, c, oh, ow]);
    let padded_ref: &[Bf16] = &padded;
    ctx.par_chunks_with(
        out.as_mut_slice(),
        oh * ow,
        || {
            (
                ctx.take_elems_unfilled::<f32>(wp),
                ctx.take_elems_unfilled::<f32>(hp * ow1),
                ctx.take_elems_unfilled::<f32>(ow1),
            )
        },
        |item, oplane, (rowf, hrows, acc)| {
            let plane = &padded_ref[item * hp * wp..(item + 1) * hp * wp];
            for iy in 0..hp {
                for (d, s) in rowf.iter_mut().zip(&plane[iy * wp..(iy + 1) * wp]) {
                    *d = s.to_f32();
                }
                sliding_combine_row(rowf, kw, &mut hrows[iy * ow1..(iy + 1) * ow1], ow1, op);
            }
            for oy in 0..oh {
                let iy0 = oy * sh;
                acc.copy_from_slice(&hrows[iy0 * ow1..(iy0 + 1) * ow1]);
                for ky in 1..kh {
                    let row = &hrows[(iy0 + ky) * ow1..(iy0 + ky + 1) * ow1];
                    for (a, &r) in acc.iter_mut().zip(row.iter()) {
                        *a = op.scalar(*a, r);
                    }
                }
                let inv = match op {
                    Combine::Sum => 1.0 / (kh * kw) as f32,
                    Combine::Max => 1.0,
                };
                let orow = &mut oplane[oy * ow..oy * ow + ow];
                for (ox, v) in orow.iter_mut().enumerate() {
                    *v = Bf16::from_f32(acc[ox * sw] * inv);
                }
            }
        },
        |(rowf, hrows, acc)| {
            ctx.put_elems(rowf);
            ctx.put_elems(hrows);
            ctx.put_elems(acc);
        },
    );
    ctx.put_elems(padded);
    out
}

/// bfloat16 max pooling (bf16 in/out, f32 combine).
pub fn max_pool2d_bf16_ctx(x: &TensorT<Bf16>, p: &PoolParams, ctx: &ExecCtx) -> TensorT<Bf16> {
    pool2d_sliding_bf16(x, p, Combine::Max, ctx)
}

/// bfloat16 average pooling (bf16 in/out, f32 sum then scale,
/// `count_include_pad = true` like [`avg_pool2d_ctx`]).
pub fn avg_pool2d_bf16_ctx(x: &TensorT<Bf16>, p: &PoolParams, ctx: &ExecCtx) -> TensorT<Bf16> {
    pool2d_sliding_bf16(x, p, Combine::Sum, ctx)
}

/// Naïve max pooling — baseline + oracle.
pub fn max_pool2d_naive(x: &Tensor, p: &PoolParams) -> Tensor {
    pool2d_naive(x, p, Combine::Max)
}

/// Naïve average pooling — baseline + oracle.
pub fn avg_pool2d_naive(x: &Tensor, p: &PoolParams) -> Tensor {
    let inv = 1.0 / (p.k.0 * p.k.1) as f32;
    let mut y = pool2d_naive(x, p, Combine::Sum);
    for v in y.as_mut_slice() {
        *v *= inv;
    }
    y
}

fn pool2d_naive(x: &Tensor, p: &PoolParams, op: Combine) -> Tensor {
    let (n, c, h, w) = (x.dim(0), x.dim(1), x.dim(2), x.dim(3));
    let (kh, kw) = p.k;
    let (oh, ow) = p.out_size(h, w);
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    for ni in 0..n {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = op.identity();
                    for ky in 0..kh {
                        let iy = oy * p.stride.0 + ky;
                        for kx in 0..kw {
                            let ix = ox * p.stride.1 + kx;
                            let v = if iy < p.pad.0
                                || iy >= h + p.pad.0
                                || ix < p.pad.1
                                || ix >= w + p.pad.1
                            {
                                op.identity()
                            } else {
                                x.at4(ni, ci, iy - p.pad.0, ix - p.pad.1)
                            };
                            acc = op.scalar(acc, v);
                        }
                    }
                    *out.at4_mut(ni, ci, oy, ox) = acc;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn against_naive_max(dims: &[usize], p: &PoolParams, seed: u64) {
        let x = Tensor::randn(dims, seed);
        let got = max_pool2d(&x, p);
        let want = max_pool2d_naive(&x, p);
        assert_eq!(got.dims(), want.dims());
        let d = got.max_abs_diff(&want);
        assert!(d == 0.0, "{dims:?} {p:?}: diff {d}");
    }

    fn against_naive_avg(dims: &[usize], p: &PoolParams, seed: u64) {
        let x = Tensor::randn(dims, seed);
        let got = avg_pool2d(&x, p);
        let want = avg_pool2d_naive(&x, p);
        let d = got.max_abs_diff(&want);
        assert!(d < 1e-5, "{dims:?} {p:?}: diff {d}");
    }

    #[test]
    fn max_matches_naive_all_windows() {
        for k in 1..=8 {
            against_naive_max(&[1, 2, 17, 23], &PoolParams::with_stride(k, 1), 100 + k as u64);
        }
    }

    #[test]
    fn max_matches_naive_large_windows() {
        for k in [13, 16] {
            against_naive_max(&[1, 1, 20, 40], &PoolParams::with_stride(k, 1), 200 + k as u64);
        }
    }

    #[test]
    fn max_matches_naive_nonoverlapping() {
        against_naive_max(&[2, 3, 16, 16], &PoolParams::square(2), 300);
        against_naive_max(&[1, 1, 18, 18], &PoolParams::square(3), 301);
    }

    #[test]
    fn avg_matches_naive() {
        for k in [2, 3, 5, 7] {
            against_naive_avg(&[1, 2, 15, 19], &PoolParams::with_stride(k, 1), 400 + k as u64);
            against_naive_avg(&[1, 2, 16, 16], &PoolParams::square(k.min(4)), 500 + k as u64);
        }
    }

    #[test]
    fn padded_max_ignores_border() {
        let x = Tensor::full(&[1, 1, 2, 2], -5.0);
        let p = PoolParams { k: (3, 3), stride: (1, 1), pad: (1, 1) };
        let y = max_pool2d(&x, &p);
        // Padding is -inf for max, so every output is -5.
        assert!(y.as_slice().iter().all(|&v| v == -5.0));
        assert_eq!(y.dims(), &[1, 1, 2, 2]);
    }

    #[test]
    fn padded_avg_counts_pad_as_zero() {
        let x = Tensor::full(&[1, 1, 1, 1], 9.0);
        let p = PoolParams { k: (3, 3), stride: (1, 1), pad: (1, 1) };
        let y = avg_pool2d(&x, &p);
        assert!((y.as_slice()[0] - 1.0).abs() < 1e-6); // 9 / 9 taps
    }

    #[test]
    fn global_pool() {
        let x = Tensor::iota(&[1, 1, 4, 4]);
        let y = avg_pool2d(&x, &PoolParams::square(4));
        assert_eq!(y.dims(), &[1, 1, 1, 1]);
        assert!((y.as_slice()[0] - 7.5).abs() < 1e-6);
        let m = max_pool2d(&x, &PoolParams::square(4));
        assert_eq!(m.as_slice()[0], 15.0);
    }

    #[test]
    fn q8_max_pool_commutes_with_quantization() {
        use crate::tensor::{quantize, QuantParams};
        let ctx = ExecCtx::default();
        for p in [
            PoolParams::with_stride(3, 1),
            PoolParams::square(2),
            PoolParams { k: (3, 3), stride: (1, 1), pad: (1, 1) },
        ] {
            let x = Tensor::randn(&[1, 2, 11, 13], 900);
            let q = QuantParams::for_tensor(&x);
            // max over codes == codes of max: quantization is monotone.
            let got = max_pool2d_q8_ctx(&quantize(&x, q), &p, &ctx);
            let want = quantize(&max_pool2d_naive(&x, &p), q);
            assert_eq!(got.as_slice(), want.as_slice(), "{p:?}");
        }
    }

    #[test]
    fn bf16_pools_track_f32_within_storage_rounding() {
        use crate::tensor::{from_bf16, to_bf16};
        let ctx = ExecCtx::default();
        let x = Tensor::randn(&[1, 2, 12, 12], 901);
        for p in [PoolParams::with_stride(3, 1), PoolParams::square(2)] {
            let m = from_bf16(&max_pool2d_bf16_ctx(&to_bf16(&x), &p, &ctx));
            let mf = max_pool2d_naive(&x, &p);
            assert!(m.max_abs_diff(&mf) <= mf.max_abs() / 128.0, "max {p:?}");
            let a = from_bf16(&avg_pool2d_bf16_ctx(&to_bf16(&x), &p, &ctx));
            let af = avg_pool2d_naive(&x, &p);
            assert!(a.max_abs_diff(&af) <= af.max_abs() / 64.0 + 0.02, "avg {p:?}");
        }
    }

    #[test]
    fn window_wider_than_lanes_serial_path() {
        let p = PoolParams { k: (1, 20), stride: (1, 1), pad: (0, 0) };
        against_naive_max(&[1, 1, 2, 80], &p, 600);
        against_naive_avg(&[1, 1, 2, 80], &p, 601);
    }
}
