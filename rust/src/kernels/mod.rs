//! Convolution and pooling kernels — the paper's contribution and its
//! baselines.
//!
//! | module | role |
//! |---|---|
//! | [`direct`]    | naïve direct convolution — correctness oracle + baseline |
//! | [`epilogue`]  | fused output epilogue (bias + ReLU in the kernel's output write) for the graph compiler |
//! | [`gemm`]      | blocked, register-tiled SGEMM (packing + 8×32 micro-kernel) |
//! | [`im2col`]    | `im2col` + GEMM convolution — the `MlasConv` stand-in |
//! | [`sliding1d`] | 1-D Vector Slide convolution + log-step sliding sums |
//! | [`sliding2d`] | 2-D sliding convolution: generic (k ≤ 17), compound (k > 17), custom k=3/k=5 |
//! | [`pool`]      | max/avg pooling via log-step sliding combines |
//! | [`region`]    | halo-aware region (tile) variants of the sliding conv/pool kernels, bit-identical per output rect — what [`crate::graph::tiling`] drives |
//! | [`dispatch`]  | filter-size–driven algorithm selection (paper §2 policy, or a measured [`crate::autotune`] profile via [`ConvAlgo::Tuned`]) |
//!
//! The public entry points are [`conv2d`], [`conv1d`] and the pooling
//! functions re-exported from [`pool`]; each takes a [`ConvAlgo`] so the
//! benchmark harness can pit implementations against each other on
//! identical inputs.
//!
//! Every entry point also has a `*_ctx` variant ([`conv2d_ctx`],
//! [`conv1d_ctx`], `conv2d_sliding_ctx`, `max_pool2d_ctx`, …) taking a
//! [`crate::exec::ExecCtx`]: work items (independent output planes, rows
//! or group blocks) fan out over the ctx's worker threads, and
//! padded/scratch/column buffers come from its reusable arena instead of
//! per-call `vec![0.0; …]`. The plain functions are single-threaded
//! wrappers that build a throwaway ctx.
//!
//! Every sliding primitive also has reduced-precision variants — the
//! dtype dimension the element layer ([`crate::tensor::Element`]) makes
//! uniform: `_q8` (int8 codes, exact i32 accumulation, symmetric
//! [`crate::tensor::QuantParams`]) for `rowconv`/`sliding1d`/
//! `sliding2d`/`pool`, `_bf16` (bfloat16 storage, f32 accumulation) for
//! the same, plus an int8 `im2col`+GEMM baseline
//! ([`im2col::conv2d_im2col_q8_raw_ctx`] over [`gemm::gemm_q8`]) so the
//! quantized speedup comparison stays honest. The f32-boundary wrappers
//! [`conv2d_q8_ctx`] / [`conv2d_bf16_ctx`] quantize/round on the way in
//! and dequantize/widen on the way out — what the nn layers call when
//! the ctx's [`crate::tensor::Dtype`] asks for reduced precision.

pub mod direct;
pub mod epilogue;
pub mod gemm;
pub mod rowconv;
pub mod im2col;
pub mod sliding1d;
pub mod sliding2d;
pub mod pool;
pub mod region;
pub mod dispatch;

pub use dispatch::{
    conv1d, conv1d_ctx, conv2d, conv2d_bf16_ctx, conv2d_bf16_epi_ctx, conv2d_ctx,
    conv2d_epi_ctx, conv2d_q8_ctx, conv2d_q8_epi_ctx, conv2d_q8_raw_routed_ctx, ConvAlgo,
};
pub use epilogue::Epilogue;
pub(crate) use sliding2d::{dequantize_conv_acc, quantize_conv_acc};
pub use pool::{
    avg_pool2d, avg_pool2d_bf16_ctx, avg_pool2d_ctx, max_pool2d, max_pool2d_bf16_ctx,
    max_pool2d_ctx, max_pool2d_q8_ctx, PoolParams,
};

/// Hyper-parameters of a 2-D convolution (dilation fixed at 1, as in the
/// paper).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dParams {
    /// Stride `(sh, sw)`.
    pub stride: (usize, usize),
    /// Zero padding `(ph, pw)` applied on every side.
    pub pad: (usize, usize),
    /// Channel groups; `groups == c_in` gives a depthwise convolution.
    pub groups: usize,
}

impl Default for Conv2dParams {
    fn default() -> Self {
        Conv2dParams { stride: (1, 1), pad: (0, 0), groups: 1 }
    }
}

impl Conv2dParams {
    /// Unit-stride convolution with the given padding.
    pub fn with_pad(ph: usize, pw: usize) -> Self {
        Conv2dParams { stride: (1, 1), pad: (ph, pw), groups: 1 }
    }

    /// "Same" padding for odd k×k filters at stride 1.
    pub fn same(k: usize) -> Self {
        assert!(k % 2 == 1, "same padding needs odd filter size");
        Conv2dParams { stride: (1, 1), pad: (k / 2, k / 2), groups: 1 }
    }

    /// Output spatial size for an `h × w` input and `kh × kw` filter.
    ///
    /// # Panics
    /// If the filter (plus padding) does not fit the input.
    pub fn out_size(&self, h: usize, w: usize, kh: usize, kw: usize) -> (usize, usize) {
        let hp = h + 2 * self.pad.0;
        let wp = w + 2 * self.pad.1;
        assert!(hp >= kh && wp >= kw, "filter {kh}x{kw} larger than padded input {hp}x{wp}");
        ((hp - kh) / self.stride.0 + 1, (wp - kw) / self.stride.1 + 1)
    }
}

/// Hyper-parameters of a 1-D convolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv1dParams {
    /// Stride along the signal.
    pub stride: usize,
    /// Zero padding on both ends.
    pub pad: usize,
}

impl Default for Conv1dParams {
    fn default() -> Self {
        Conv1dParams { stride: 1, pad: 0 }
    }
}

impl Conv1dParams {
    /// Output length for input length `l` and filter width `k`.
    pub fn out_len(&self, l: usize, k: usize) -> usize {
        let lp = l + 2 * self.pad;
        assert!(lp >= k, "filter {k} larger than padded signal {lp}");
        (lp - k) / self.stride + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_size_valid() {
        let p = Conv2dParams::default();
        assert_eq!(p.out_size(8, 8, 3, 3), (6, 6));
    }

    #[test]
    fn out_size_same() {
        let p = Conv2dParams::same(5);
        assert_eq!(p.out_size(8, 8, 5, 5), (8, 8));
    }

    #[test]
    fn out_size_strided() {
        let p = Conv2dParams { stride: (2, 2), pad: (1, 1), groups: 1 };
        assert_eq!(p.out_size(8, 8, 3, 3), (4, 4));
    }

    #[test]
    #[should_panic(expected = "larger than padded")]
    fn out_size_too_small_panics() {
        Conv2dParams::default().out_size(2, 2, 3, 3);
    }

    #[test]
    fn out_len_1d() {
        let p = Conv1dParams { stride: 1, pad: 2 };
        assert_eq!(p.out_len(10, 5), 10);
    }
}
